//! Compiler explorer: show the MPU backend's work on a kernel — the
//! assembled mini-PTX, Algorithm-1 location annotations per instruction,
//! branch re-convergence points, and the register-location breakdown.
//!
//! ```sh
//! cargo run --release --example compiler_explorer [workload]
//! ```

use mpu::compiler::compile;
use mpu::isa::instr::Loc;
use mpu::workloads::{prepare, Device, Scale, Workload};

struct NullDev {
    top: u64,
}
impl Device for NullDev {
    fn alloc_bytes(&mut self, bytes: usize) -> u64 {
        let a = self.top;
        self.top += bytes as u64;
        a
    }
    fn write_f32(&mut self, _a: u64, _d: &[f32]) {}
}

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "axpy".into());
    let w = Workload::from_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown workload `{name}`"))?;
    let mut dev = NullDev { top: 0 };
    let p = prepare(w, Scale::Tiny, &mut dev)?;
    let k = compile(&p.kernel)?;

    println!("kernel `{}` — {} instructions", k.name, k.instrs.len());
    println!("{:>4}  {:<4} {:<8} instruction", "pc", "loc", "reconv");
    for (pc, i) in k.instrs.iter().enumerate() {
        let loc = match i.loc {
            Loc::N => "N",
            Loc::F => "F",
            Loc::B => "B",
            Loc::U => "U",
        };
        let rc = k.reconv[pc].map(|r| r.to_string()).unwrap_or_default();
        println!("{pc:>4}  {loc:<4} {rc:<8} {i}");
    }
    println!(
        "\nregister locations (Fig. 14): {} near / {} far / {} both / {} unknown",
        k.loc_stats.near, k.loc_stats.far, k.loc_stats.both, k.loc_stats.unknown
    );
    println!(
        "physical pools: near RF {} regs, far RF {} regs (near-bank file can be half-sized, §VI-B)",
        k.pools.near[0] + k.pools.near[1],
        k.pools.far[0] + k.pools.far[1],
    );
    Ok(())
}
