// Perf driver: simulate the 3 slowest workloads repeatedly.
use mpu::config::MachineConfig;
use mpu::coordinator::run_workload;
use mpu::workloads::Workload;
fn main() {
    let cfg = MachineConfig::scaled();
    let t0 = std::time::Instant::now();
    let mut cycles = 0u64;
    for w in [Workload::Nw, Workload::Ttrans, Workload::Kmeans, Workload::Blur] {
        let r = run_workload(w, &cfg).unwrap();
        cycles += r.cycles;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("simulated {cycles} cycles in {dt:.2}s = {:.2} Mcycles/s", cycles as f64 / dt / 1e6);
}
