//! Architecture sweep: explore the §VI-C design space on one workload —
//! row-buffer count × smem placement × offload policy × scheduler —
//! and print a ranked table.
//!
//! ```sh
//! cargo run --release --example arch_sweep [workload]
//! ```

use mpu::config::{MachineConfig, OffloadPolicy, SchedPolicy, SmemLocation};
use mpu::coordinator::run_workload;
use mpu::workloads::Workload;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "hist".into());
    let w = Workload::from_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown workload `{name}`"))?;
    let mut results: Vec<(String, u64, f64)> = Vec::new();
    for bufs in [1usize, 4] {
        for smem in [SmemLocation::NearBank, SmemLocation::FarBank] {
            for pol in [OffloadPolicy::CompilerAnnotated, OffloadPolicy::AllFarBank] {
                for sched in [SchedPolicy::Gto, SchedPolicy::RoundRobin] {
                    let mut cfg = MachineConfig::scaled();
                    cfg.row_buffers_per_bank = bufs;
                    cfg.smem_location = smem;
                    cfg.offload_policy = pol;
                    cfg.sched_policy = sched;
                    let r = run_workload(w, &cfg)?;
                    anyhow::ensure!(r.correct, "incorrect under sweep point");
                    let label = format!(
                        "rowbuf={bufs} smem={} policy={} sched={}",
                        if smem == SmemLocation::NearBank { "near" } else { "far" },
                        match pol {
                            OffloadPolicy::CompilerAnnotated => "annotated",
                            _ => "all_fb",
                        },
                        if sched == SchedPolicy::Gto { "gto" } else { "rr" },
                    );
                    results.push((label, r.cycles, r.stats.row_miss_rate()));
                }
            }
        }
    }
    results.sort_by_key(|r| r.1);
    println!("arch sweep on `{}` (best first):", w.name());
    let best = results[0].1 as f64;
    for (label, cycles, miss) in &results {
        println!(
            "{cycles:>9} cycles  ({:.2}x vs best)  miss {:>5.1}%  {label}",
            *cycles as f64 / best,
            miss * 100.0
        );
    }
    Ok(())
}
