//! End-to-end driver: the full three-layer system on the whole Table-I
//! suite.
//!
//! For every workload: build inputs, run the cycle-level MPU simulator
//! (L3 Rust), load the JAX/Pallas AOT artifact (L2+L1) via PJRT and
//! execute the XLA golden on the *same inputs*, cross-check the
//! simulator's memory image bit-for-bit (within f32 tolerance), run the
//! GPU baseline, and report the paper's headline metrics (speedup +
//! energy reduction). Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use mpu::config::MachineConfig;
use mpu::coordinator::report::{f2, Table};
use mpu::coordinator::{compile_for, geomean, run_workload_gpu_scaled};
use mpu::core::Machine;
use mpu::energy::mpu_energy;
use mpu::runtime::{artifacts_available, validate_against_xla, XlaGolden};
use mpu::workloads::{prepare, Scale, Workload};

fn main() -> anyhow::Result<()> {
    let scale = if std::env::args().any(|a| a == "--tiny") { Scale::Tiny } else { Scale::Small };
    let cfg = MachineConfig::scaled();
    let gcfg = mpu::config::GpuConfig::matched(&cfg);
    let golden = if artifacts_available(scale) {
        Some(XlaGolden::new()?)
    } else {
        eprintln!("WARNING: artifacts/ missing — run `make artifacts` for the XLA cross-check");
        None
    };

    let mut t = Table::new(
        "End-to-end: simulator vs XLA golden vs GPU baseline",
        &["workload", "sim==golden", "sim==XLA", "speedup", "energy_red", "near%", "GB/s"],
    );
    let mut speedups = Vec::new();
    let mut energies = Vec::new();
    let t0 = std::time::Instant::now();
    for w in Workload::ALL {
        // L3: MPU simulation.
        let mut m = Machine::new(&cfg);
        let p = prepare(w, scale, &mut m)?;
        let k = compile_for(&p, &cfg)?;
        m.launch(k, p.launch, &p.params, p.home_fn())?;
        let stats = m.run()?;
        let sim_out = m.read_f32s(p.out_addr, p.out_len);

        // Check vs pure-Rust golden.
        let max_err = sim_out
            .iter()
            .zip(&p.golden)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        let rust_ok = max_err <= p.tol.max(f32::EPSILON);

        // Check vs the AOT-compiled JAX/Pallas golden via PJRT.
        let xla_ok = match &golden {
            Some(g) => {
                let v = validate_against_xla(g, &p, scale, &sim_out)?;
                if v.passed { "yes".to_string() } else { format!("NO ({})", v.mismatches) }
            }
            None => "skip".to_string(),
        };

        // GPU baseline on identical inputs.
        let gpu = run_workload_gpu_scaled(w, &gcfg, &cfg, scale)?;
        let speedup = gpu.cycles as f64 / stats.cycles.max(1) as f64;
        let e_mpu = mpu_energy(&stats, &cfg.energy).total();
        let e_red = gpu.energy.total() / e_mpu.max(1e-30);
        speedups.push(speedup);
        energies.push(e_red);

        t.row(vec![
            w.name().into(),
            if rust_ok { "yes".into() } else { format!("NO ({max_err:.1e})") },
            xla_ok,
            f2(speedup),
            f2(e_red),
            format!("{:.0}%", stats.near_fraction() * 100.0),
            f2(stats.dram_bytes_per_cycle()),
        ]);
    }
    t.row(vec![
        "GEOMEAN".into(),
        String::new(),
        String::new(),
        f2(geomean(&speedups)),
        f2(geomean(&energies)),
        String::new(),
        String::new(),
    ]);
    t.emit("end_to_end");
    println!(
        "\npaper headline: 3.46x speedup, 2.57x energy reduction — measured geomeans above.\nwall time: {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
