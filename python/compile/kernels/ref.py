"""Pure-jnp oracles for the twelve Table-I workloads.

These are the L2-level reference semantics: every Pallas kernel in this
package is checked against the matching function here (pytest), and the
AOT'd models must agree with the Rust simulator's functional output.

All functions take/return *flat* float32 arrays (plus static shape
arguments) so the Rust PJRT bridge can feed them as rank-1 literals.
"""

import jax
import jax.numpy as jnp


def axpy(x, y, alpha):
    """y' = alpha*x + y. alpha is a (1,) array."""
    return alpha[0] * x + y


def pr(x):
    """Per-block partial sums, (32,): the device's fixed-order pairwise
    reduction writes block b's partial to slot b, where block b owns the
    grid-stride elements i with (i // 128) % 32 == b."""
    return jnp.sum(x.reshape(-1, 32, 128), axis=(0, 2))


def gemv(a_t, x, m, n):
    """y = A @ x with A given column-major as flat a_t (row-major (n, m))."""
    return jnp.dot(x, a_t.reshape(n, m), preferred_element_type=jnp.float32)


def _clamp_pad(img):
    """Edge-clamped 1-pixel pad (h, w) -> (h+2, w+2)."""
    return jnp.pad(img, 1, mode="edge")


def ttrans(inp, m, n):
    """out[j*m + i] = in[i*n + j]."""
    return inp.reshape(m, n).T.reshape(-1)


def blur(img, w, h):
    """3x3 box blur, clamped edges; img flat (h*w,)."""
    x = _clamp_pad(img.reshape(h, w))
    s = jnp.zeros((h, w), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            s = s + x[dy : dy + h, dx : dx + w]
    return (s * jnp.float32(0.111111112)).reshape(-1)


def conv(img, wts, w, h):
    """3x3 convolution with clamped edges; weights wts flat (9,)."""
    x = _clamp_pad(img.reshape(h, w))
    s = jnp.zeros((h, w), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            s = s + x[dy : dy + h, dx : dx + w] * wts[dy * 3 + dx]
    return s.reshape(-1)


def maxp(img, w, h):
    """2x2 max-pool, stride 2."""
    x = img.reshape(h, w).reshape(h // 2, 2, w // 2, 2)
    return x.max(axis=(1, 3)).reshape(-1)


def upsamp(img, w, h):
    """2x nearest-neighbour upsample."""
    x = img.reshape(h, w)
    return jnp.repeat(jnp.repeat(x, 2, axis=0), 2, axis=1).reshape(-1)


def hist(data, bins=256):
    """256-bin histogram of floor(data); counts as f32."""
    idx = data.astype(jnp.int32)
    return jax.nn.one_hot(idx, bins, dtype=jnp.float32).sum(axis=0)


def kmeans(points, cents, n, k=8, d=4):
    """Nearest-centroid index per point (as f32).

    points: flat column-major (d*n,) -> (d, n); cents: flat (k*d,).
    """
    pts = points.reshape(d, n).T  # (n, d)
    c = cents.reshape(k, d)
    dist = ((pts[:, None, :] - c[None, :, :]) ** 2).sum(-1)  # (n, k)
    return jnp.argmin(dist, axis=1).astype(jnp.float32)


def knn(lat, lng, qlat=45.0, qlng=90.0):
    """Euclidean distance to the query point."""
    return jnp.sqrt((lat - qlat) ** 2 + (lng - qlng) ** 2)


def nw(a, b):
    """Needleman-Wunsch score matrix (flattened (n+1)^2).

    match +1 / mismatch -1 / gap -1, borders -i / -j. Row-by-row scan:
    within a row, F[i][j] = max(t[j], F[i][j-1] - 1) is a sequential
    recurrence handled by an inner lax.scan.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    n = a.shape[0]
    rs = n + 1
    border = -jnp.arange(rs, dtype=jnp.float32)

    def row_step(prev_row, i):
        ai = a[i - 1]
        s = jnp.where(b == ai, jnp.float32(1.0), jnp.float32(-1.0))
        diag = prev_row[:-1] + s
        up = prev_row[1:] - 1.0
        t = jnp.maximum(diag, up)
        left0 = -i.astype(jnp.float32)

        def cell(carry, tj):
            v = jnp.maximum(tj, carry - 1.0)
            return v, v

        _, vals = jax.lax.scan(cell, left0, t)
        row = jnp.concatenate([left0[None], vals])
        return row, row

    _, rows = jax.lax.scan(row_step, border, jnp.arange(1, n + 1))
    f = jnp.concatenate([border[None, :], rows], axis=0)
    return f.reshape(-1)
