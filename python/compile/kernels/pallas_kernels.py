"""Layer-1 Pallas kernels for the twelve workloads.

All kernels run in *interpret* mode (the CPU PJRT plugin cannot execute
Mosaic custom-calls — see /opt/xla-example/README.md); on a real TPU the
same BlockSpecs express the HBM->VMEM schedule. Block shapes follow the
VMEM budget table in DESIGN.md §9: element-wise kernels stream 1024-wide
strips, GEMV tiles rows at 128 so the (n, 128) A-tile plus x fit in VMEM
and feed the MXU via `jnp.dot`, stencils operate on whole row bands
(images here are thin: W×16).

Every kernel is checked against the pure-jnp oracle in `ref.py` by
`python/tests/test_kernel.py`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_INTERPRET = True


def _strip_grid(n, bs=1024):
    bs = min(bs, n)
    assert n % bs == 0, f"size {n} not divisible by strip {bs}"
    return bs, n // bs


def axpy(x, y, alpha):
    """Strip-mined alpha*x + y."""
    n = x.shape[0]
    bs, grid = _strip_grid(n)

    def kernel(a_ref, x_ref, y_ref, o_ref):
        o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        interpret=_INTERPRET,
    )(alpha, x, y)


def pr(x):
    """Two-stage reduction to (32,) per-block partials: 128-wide strip
    sums in the kernel, then strip s = k*32 + b folds into block b
    (mirrors the CUDA fixed-order block tree writing partials[b])."""
    n = x.shape[0]
    grid = n // 128

    def kernel(x_ref, o_ref):
        o_ref[...] = jnp.sum(x_ref[...])[None]

    partial = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.float32),
        grid=(grid,),
        in_specs=[pl.BlockSpec((128,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        interpret=_INTERPRET,
    )(x)
    return jnp.sum(partial.reshape(-1, 32), axis=0)


def gemv(a_t, x, m, n):
    """Row-tiled y = A@x; A arrives flat column-major -> (n, m) row-major.
    Each grid step loads an (n, 128) A-tile and the full x into VMEM and
    issues one MXU-shaped dot."""
    bs = 128 if m % 128 == 0 else m
    grid = m // bs
    a2 = a_t.reshape(n, m)

    def kernel(a_ref, x_ref, o_ref):
        o_ref[...] = jnp.dot(x_ref[...], a_ref[...], preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((n, bs), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        interpret=_INTERPRET,
    )(a2, x)


def ttrans(inp, m, n):
    """Tiled transpose: read an (tile_m, n) row band, write its transpose
    as an (n, tile_m) column band."""
    tm = 32 if m % 32 == 0 else m
    grid = m // tm
    x2 = inp.reshape(m, n)

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...].T

    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        grid=(grid,),
        in_specs=[pl.BlockSpec((tm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n, tm), lambda i: (0, i)),
        interpret=_INTERPRET,
    )(x2)
    return out.reshape(-1)


def _stencil_call(kernel, img, w, h, extra=None, out_shape=None):
    """Whole-band stencil helper: thin images (h ≤ 16 rows) fit in one
    VMEM block, so the halo exchange is internal to the block."""
    x2 = img.reshape(h, w)
    out_shape = out_shape or (h, w)
    ins = [x2] if extra is None else [x2, extra]
    in_specs = [pl.BlockSpec(x2.shape, lambda: (0, 0))]
    if extra is not None:
        in_specs.append(pl.BlockSpec(extra.shape, lambda: tuple(0 for _ in extra.shape)))
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(out_shape, lambda: (0, 0)),
        interpret=_INTERPRET,
    )(*ins)
    return out.reshape(-1)


def blur(img, w, h):
    def kernel(x_ref, o_ref):
        x = jnp.pad(x_ref[...], 1, mode="edge")
        s = jnp.zeros_like(x_ref[...])
        for dy in range(3):
            for dx in range(3):
                s = s + x[dy : dy + x_ref.shape[0], dx : dx + x_ref.shape[1]]
        o_ref[...] = s * jnp.float32(0.111111112)

    return _stencil_call(kernel, img, w, h)


def conv(img, wts, w, h):
    def kernel(x_ref, w_ref, o_ref):
        x = jnp.pad(x_ref[...], 1, mode="edge")
        s = jnp.zeros_like(x_ref[...])
        for dy in range(3):
            for dx in range(3):
                s = s + x[dy : dy + x_ref.shape[0], dx : dx + x_ref.shape[1]] * w_ref[dy * 3 + dx]
        o_ref[...] = s

    return _stencil_call(kernel, img, w, h, extra=wts)


def maxp(img, w, h):
    def kernel(x_ref, o_ref):
        x = x_ref[...]
        o_ref[...] = x.reshape(x.shape[0] // 2, 2, x.shape[1] // 2, 2).max(axis=(1, 3))

    return _stencil_call(kernel, img, w, h, out_shape=(h // 2, w // 2))


def upsamp(img, w, h):
    def kernel(x_ref, o_ref):
        x = x_ref[...]
        o_ref[...] = jnp.repeat(jnp.repeat(x, 2, axis=0), 2, axis=1)

    return _stencil_call(kernel, img, w, h, out_shape=(h * 2, w * 2))


def hist(data, bins=256):
    """Privatized per-strip histograms via a one-hot matmul (the MXU
    formulation of binning), summed across strips — mirroring the CUDA
    shared-memory privatization + global flush."""
    n = data.shape[0]
    bs, grid = _strip_grid(n)

    def kernel(x_ref, o_ref):
        idx = x_ref[...].astype(jnp.int32)
        o_ref[...] = jax.nn.one_hot(idx, bins, dtype=jnp.float32).sum(axis=0)[None, :]

    partial = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((grid, bins), jnp.float32),
        grid=(grid,),
        in_specs=[pl.BlockSpec((bs,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, bins), lambda i: (i, 0)),
        interpret=_INTERPRET,
    )(data)
    return partial.sum(axis=0)


def kmeans(points, cents, n, k=8, d=4):
    """Point-tiled nearest-centroid assignment: a (d, bs) point tile and
    the full centroid table in VMEM per step."""
    bs = 1024 if n % 1024 == 0 else n
    grid = n // bs
    p2 = points.reshape(d, n)
    c2 = cents.reshape(k, d)

    def kernel(p_ref, c_ref, o_ref):
        pts = p_ref[...].T  # (bs, d)
        dist = ((pts[:, None, :] - c_ref[...][None, :, :]) ** 2).sum(-1)
        o_ref[...] = jnp.argmin(dist, axis=1).astype(jnp.float32)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((d, bs), lambda i: (0, i)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        interpret=_INTERPRET,
    )(p2, c2)


def knn(lat, lng, qlat=45.0, qlng=90.0):
    n = lat.shape[0]
    bs, grid = _strip_grid(n)

    def kernel(a_ref, b_ref, o_ref):
        da = a_ref[...] - qlat
        db = b_ref[...] - qlng
        o_ref[...] = jnp.sqrt(da * da + db * db)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        interpret=_INTERPRET,
    )(lat, lng)


def nw(a, b):
    """Wavefront DP inside one kernel: the whole score matrix fits in
    VMEM at these sizes ((n+1)^2 × 4 B ≈ 65 KiB for n=127); the scans are
    the same as the oracle's."""
    n = a.shape[0]
    rs = n + 1

    def kernel(a_ref, b_ref, o_ref):
        f = ref.nw(a_ref[...], b_ref[...])
        o_ref[...] = f

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((rs * rs,), jnp.float32),
        in_specs=[
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((rs * rs,), lambda: (0,)),
        interpret=_INTERPRET,
    )(a, b)


# Static-shape convenience wrappers used by the AOT models.
WORKLOADS = {
    "axpy": axpy,
    "pr": pr,
    "gemv": gemv,
    "ttrans": ttrans,
    "blur": blur,
    "conv": conv,
    "maxp": maxp,
    "upsamp": upsamp,
    "hist": hist,
    "kmeans": kmeans,
    "knn": knn,
    "nw": nw,
}


def partial_for(name, **static):
    return functools.partial(WORKLOADS[name], **static)
