"""Layer-2 models: one jax function per workload × scale, calling the
Layer-1 Pallas kernels, with flat-vector signatures matching what the
Rust runtime feeds from `Prepared::xla_inputs`.

The static shapes here MUST mirror `rust/src/workloads/*` (`Scale::Tiny`
/ `Scale::Small`); `python/tests/test_model.py` pins them.
"""

from .kernels import pallas_kernels as K

# (workload, scale) -> dict of static sizes; keep in sync with rust.
SIZES = {
    ("axpy", "tiny"): dict(n=4096),
    ("axpy", "small"): dict(n=65536),
    ("pr", "tiny"): dict(n=4096),
    ("pr", "small"): dict(n=65536),
    ("gemv", "tiny"): dict(m=4096, n=16),
    ("gemv", "small"): dict(m=8192, n=64),
    ("ttrans", "tiny"): dict(m=64, n=64),
    ("ttrans", "small"): dict(m=128, n=128),
    ("blur", "tiny"): dict(w=4096, h=4),
    ("blur", "small"): dict(w=4096, h=16),
    ("conv", "tiny"): dict(w=4096, h=4),
    ("conv", "small"): dict(w=4096, h=16),
    ("maxp", "tiny"): dict(w=4096, h=4),
    ("maxp", "small"): dict(w=4096, h=16),
    ("upsamp", "tiny"): dict(w=2048, h=4),
    ("upsamp", "small"): dict(w=2048, h=16),
    ("hist", "tiny"): dict(n=8192),
    ("hist", "small"): dict(n=65536),
    ("kmeans", "tiny"): dict(n=4096, k=8, d=4),
    ("kmeans", "small"): dict(n=16384, k=8, d=4),
    ("knn", "tiny"): dict(n=4096),
    ("knn", "small"): dict(n=32768),
    ("nw", "tiny"): dict(n=64),
    ("nw", "small"): dict(n=128),
}

SCALES = ("tiny", "small")
WORKLOADS = sorted({w for (w, _) in SIZES})


def input_shapes(workload, scale):
    """Flat input shapes, in the order the Rust side sends them."""
    s = SIZES[(workload, scale)]
    if workload == "axpy":
        return [(s["n"],), (s["n"],), (1,)]
    if workload == "pr":
        return [(s["n"],)]
    if workload == "gemv":
        return [(s["m"] * s["n"],), (s["n"],)]
    if workload == "ttrans":
        return [(s["m"] * s["n"],)]
    if workload in ("blur", "maxp", "upsamp"):
        return [(s["w"] * s["h"],)]
    if workload == "conv":
        return [(s["w"] * s["h"],), (9,)]
    if workload == "hist":
        return [(s["n"],)]
    if workload == "kmeans":
        return [(s["d"] * s["n"],), (s["k"] * s["d"],)]
    if workload == "knn":
        return [(s["n"],), (s["n"],)]
    if workload == "nw":
        return [(s["n"],), (s["n"],)]
    raise KeyError(workload)


def build(workload, scale):
    """Return fn(*flat_inputs) -> (flat_output,) with static shapes."""
    s = SIZES[(workload, scale)]

    if workload == "axpy":
        fn = lambda x, y, alpha: (K.axpy(x, y, alpha),)
    elif workload == "pr":
        fn = lambda x: (K.pr(x),)
    elif workload == "gemv":
        fn = lambda a, x: (K.gemv(a, x, s["m"], s["n"]),)
    elif workload == "ttrans":
        fn = lambda x: (K.ttrans(x, s["m"], s["n"]),)
    elif workload == "blur":
        fn = lambda x: (K.blur(x, s["w"], s["h"]),)
    elif workload == "conv":
        fn = lambda x, w: (K.conv(x, w, s["w"], s["h"]),)
    elif workload == "maxp":
        fn = lambda x: (K.maxp(x, s["w"], s["h"]),)
    elif workload == "upsamp":
        fn = lambda x: (K.upsamp(x, s["w"], s["h"]),)
    elif workload == "hist":
        fn = lambda x: (K.hist(x),)
    elif workload == "kmeans":
        fn = lambda p, c: (K.kmeans(p, c, s["n"], s["k"], s["d"]),)
    elif workload == "knn":
        fn = lambda a, b: (K.knn(a, b),)
    elif workload == "nw":
        fn = lambda a, b: (K.nw(a, b),)
    else:
        raise KeyError(workload)
    return fn
