"""AOT lowering: every workload model → HLO *text* in artifacts/.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts [--only axpy,...]
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(workload: str, scale: str) -> str:
    fn = model.build(workload, scale)
    shapes = model.input_shapes(workload, scale)
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated workload filter")
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    only = {w for w in args.only.split(",") if w}
    for w in model.WORKLOADS:
        if only and w not in only:
            continue
        for scale in model.SCALES:
            text = lower_one(w, scale)
            path = out / f"{w}_{scale}.hlo.txt"
            path.write_text(text)
            print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
