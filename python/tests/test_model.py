"""L2 model tests: static shapes stay in sync with the Rust workloads,
every model traces/loweres, and HLO text is well-formed."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_sizes_cover_all_workload_scale_pairs():
    assert set(model.SIZES) == {(w, s) for w in model.WORKLOADS for s in model.SCALES}
    assert len(model.WORKLOADS) == 12


# Pin the sizes to the Rust side (rust/src/workloads/*.rs).
RUST_SIZES = {
    ("axpy", "tiny"): dict(n=4096),
    ("gemv", "small"): dict(m=8192, n=64),
    ("blur", "small"): dict(w=4096, h=16),
    ("hist", "tiny"): dict(n=8192),
    ("kmeans", "small"): dict(n=16384, k=8, d=4),
    ("nw", "small"): dict(n=128),
    ("upsamp", "tiny"): dict(w=2048, h=4),
}


@pytest.mark.parametrize("key", sorted(RUST_SIZES))
def test_sizes_match_rust(key):
    assert model.SIZES[key] == RUST_SIZES[key]


@pytest.mark.parametrize("workload", model.WORKLOADS)
def test_models_run_and_output_is_flat(workload):
    fn = model.build(workload, "tiny")
    shapes = model.input_shapes(workload, "tiny")
    rng = np.random.default_rng(1)
    args = [jnp.asarray(rng.uniform(0, 1, s).astype(np.float32)) for s in shapes]
    out = fn(*args)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].ndim == 1
    assert out[0].dtype == jnp.float32


@pytest.mark.parametrize("workload", ["axpy", "gemv", "nw"])
def test_hlo_text_emits(workload):
    text = aot.lower_one(workload, "tiny")
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: the root is a tuple.
    assert "tuple(" in text or ") tuple" in text


def test_models_are_jittable():
    for workload in ["hist", "kmeans", "maxp"]:
        fn = model.build(workload, "tiny")
        shapes = model.input_shapes(workload, "tiny")
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
        jax.jit(fn).lower(*specs)  # must not raise
