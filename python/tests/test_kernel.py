"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Deterministic seeded-numpy parameter sweeps stand in for `hypothesis`
(not available offline): every kernel is exercised across several shapes
and several seeds per shape.
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import pallas_kernels as K
from compile.kernels import ref


def rng(seed):
    return np.random.default_rng(seed)


def f32(a):
    return np.asarray(a, dtype=np.float32)


@pytest.mark.parametrize("n", [1024, 4096, 8192])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_axpy(n, seed):
    r = rng(seed)
    x, y = f32(r.uniform(-1, 1, n)), f32(r.uniform(-1, 1, n))
    alpha = f32([1.5])
    assert_allclose(K.axpy(x, y, alpha), ref.axpy(x, y, alpha), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [1024, 4096, 65536])
@pytest.mark.parametrize("seed", [0, 3])
def test_pr(n, seed):
    x = f32(rng(seed).uniform(0, 1, n))
    assert_allclose(K.pr(x), ref.pr(x), rtol=1e-5)


@pytest.mark.parametrize("m,n", [(1024, 16), (4096, 16), (8192, 64)])
@pytest.mark.parametrize("seed", [0, 5])
def test_gemv(m, n, seed):
    r = rng(seed)
    a = f32(r.uniform(-1, 1, m * n))
    x = f32(r.uniform(-1, 1, n))
    assert_allclose(K.gemv(a, x, m, n), ref.gemv(a, x, m, n), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,n", [(64, 64), (128, 128), (32, 96)])
def test_ttrans(m, n):
    x = f32(rng(7).uniform(-1, 1, m * n))
    assert_allclose(K.ttrans(x, m, n), ref.ttrans(x, m, n))


@pytest.mark.parametrize("w,h", [(64, 4), (4096, 4), (256, 16)])
@pytest.mark.parametrize("seed", [0, 9])
def test_blur(w, h, seed):
    img = f32(rng(seed).uniform(0, 1, w * h))
    assert_allclose(K.blur(img, w, h), ref.blur(img, w, h), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("w,h", [(64, 4), (4096, 4)])
def test_conv(w, h):
    r = rng(11)
    img = f32(r.uniform(0, 1, w * h))
    wts = f32(r.uniform(-0.5, 0.5, 9))
    assert_allclose(K.conv(img, wts, w, h), ref.conv(img, wts, w, h), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("w,h", [(64, 4), (4096, 4), (128, 8)])
def test_maxp(w, h):
    img = f32(rng(13).uniform(-1, 1, w * h))
    assert_allclose(K.maxp(img, w, h), ref.maxp(img, w, h))


@pytest.mark.parametrize("w,h", [(64, 4), (2048, 4)])
def test_upsamp(w, h):
    img = f32(rng(17).uniform(0, 1, w * h))
    assert_allclose(K.upsamp(img, w, h), ref.upsamp(img, w, h))


@pytest.mark.parametrize("n", [1024, 8192])
@pytest.mark.parametrize("seed", [0, 19])
def test_hist(n, seed):
    data = f32(rng(seed).integers(0, 256, n))
    got = K.hist(data)
    assert_allclose(got, ref.hist(data))
    assert float(np.sum(np.asarray(got))) == n  # counts conserve mass


@pytest.mark.parametrize("n", [1024, 4096])
@pytest.mark.parametrize("seed", [0, 23])
def test_kmeans(n, seed):
    r = rng(seed)
    pts = f32(r.uniform(-2, 2, 4 * n))
    cents = f32(r.uniform(-2, 2, 8 * 4))
    assert_allclose(K.kmeans(pts, cents, n), ref.kmeans(pts, cents, n))


@pytest.mark.parametrize("n", [1024, 4096])
def test_knn(n):
    r = rng(29)
    lat = f32(r.uniform(0, 90, n))
    lng = f32(r.uniform(0, 180, n))
    assert_allclose(K.knn(lat, lng), ref.knn(lat, lng), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [16, 64])
@pytest.mark.parametrize("seed", [0, 31])
def test_nw(n, seed):
    r = rng(seed)
    a = f32(r.integers(0, 4, n))
    b = f32(r.integers(0, 4, n))
    assert_allclose(K.nw(a, b), ref.nw(a, b))


def test_nw_oracle_against_python_dp():
    """Cross-check the jnp scan formulation against a plain-python DP."""
    r = rng(37)
    n = 24
    a = f32(r.integers(0, 4, n))
    b = f32(r.integers(0, 4, n))
    rs = n + 1
    f = np.zeros((rs, rs), dtype=np.float32)
    f[:, 0] = -np.arange(rs)
    f[0, :] = -np.arange(rs)
    for i in range(1, rs):
        for j in range(1, rs):
            s = 1.0 if a[i - 1] == b[j - 1] else -1.0
            f[i, j] = max(f[i - 1, j - 1] + s, f[i - 1, j] - 1.0, f[i, j - 1] - 1.0)
    assert_allclose(np.asarray(ref.nw(a, b)).reshape(rs, rs), f)
