//! # MPU — Memory-centric Processing Unit
//!
//! A comprehensive reproduction of *"MPU: Towards Bandwidth-abundant SIMT
//! Processor via Near-bank Computing"* (Xie, Gu, Ding, Niu, Zheng, Xie;
//! cs.AR 2021) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate contains, in one coherent framework:
//!
//! * a **mini-PTX ISA** and assembler ([`isa`]) in which the paper's twelve
//!   Table-I workloads are written;
//! * the **MPU compiler backend** ([`compiler`]): branch re-convergence
//!   analysis (post-dominators), the paper's Algorithm-1 *location
//!   annotation* pass, liveness, and graph-coloring register allocation
//!   with separate near-bank / far-bank physical register pools;
//! * a **static kernel analyzer** ([`analysis`], `mpu lint`): a generic
//!   monotone dataflow framework over the compiler's CFG with
//!   uninitialized-use, divergence, barrier-divergence, shared-memory
//!   race, and memory-access-pattern passes, validated against the
//!   simulator's dynamically observed address traces;
//! * a **shared SIMT frontend** ([`core::frontend`]): one implementation
//!   of block dispatch, warp scheduling, barriers, scoreboard and
//!   functional execution behind an **event-driven run loop** (warp
//!   wake-up heap + batched `advance_to` memory fast-forward, with the
//!   per-cycle reference loop retained as the timing oracle), generic
//!   over a pluggable `MemorySystem` + `OffloadModel` backend — every
//!   machine below is this frontend plus a memory system;
//! * a **cycle-level functional + timing simulator** of the MPU
//!   architecture ([`core`], [`dram`], [`mem`], [`noc`]): hybrid
//!   far-bank/near-bank pipeline with instruction offloading, register
//!   track table and register move engine, hybrid LSU
//!   (LSU / LSU-Remote / LSU-Extension), near-bank units, DRAM banks with
//!   FR-FCFS + open-page + multiple activated row-buffers (MASA), TSV
//!   buses, a 2D-mesh NoC and near-bank shared memory;
//! * a **V100-like GPU baseline**, an **ideal-bandwidth roofline**
//!   machine, a PIM-style **MPU-no-offload** preset and a **PonB**
//!   (processing-on-base-logic-die) baseline ([`gpu`], `MachineKind`,
//!   `PipelineMode`);
//! * **energy and area models** with the paper's Table-II/III
//!   coefficients ([`energy`]);
//! * the twelve **workloads** with input generators and golden models
//!   ([`workloads`]);
//! * a **PJRT runtime bridge** ([`runtime`]) that loads the JAX/Pallas
//!   AOT-compiled golden models (`artifacts/*.hlo.txt`) and validates the
//!   simulator's functional output against XLA;
//! * the **experiment coordinator** ([`coordinator`]) that regenerates
//!   every figure and table of the paper's evaluation section, built on
//!   a **parallel sweep engine** ([`coordinator::sweep`]: shared kernel
//!   compile cache + rayon fan-out) with a stable-schema JSON perf
//!   emitter ([`coordinator::bench`], `BENCH_suite.json`);
//! * the **sweep service** ([`coordinator::service`]): a long-running
//!   daemon (`mpu serve`) with a priority job queue, cross-request
//!   in-flight dedup, a JSONL-over-TCP protocol with streamed submits
//!   and a version handshake ([`coordinator::proto`]) and a persistent
//!   content-addressed on-disk result store ([`coordinator::store`],
//!   with `mpu store gc` compaction) as the second tier under the
//!   sweep engine's `SimCache`;
//! * the **sweep federation** ([`coordinator::federation`]): shard one
//!   batch across many worker daemons by consistent hashing on the
//!   stable store keys (`mpu serve --workers` / `mpu submit
//!   --workers`), merge the streamed results back into point order,
//!   and redistribute a dead worker's unfinished points mid-batch;
//! * the **offload-policy autotuner** ([`tuner`], `mpu tune`): treats
//!   the Algorithm-1 placement decision as a searchable artifact — an
//!   explicit per-kernel, per-pc policy table inside the config
//!   fingerprint — and searches it (exhaustive / greedy + seeded
//!   annealing) through the same cache, store and federation tiers,
//!   emitting a schema-versioned `TUNE_report.json`.
//!
//! ## Quickstart
//!
//! ```no_run
//! use mpu::config::MachineConfig;
//! use mpu::coordinator::run_workload;
//! use mpu::workloads::Workload;
//!
//! let cfg = MachineConfig::scaled();
//! let report = run_workload(Workload::Axpy, &cfg).unwrap();
//! println!("AXPY: {} cycles, {:.1} GB/s", report.cycles, report.dram_gbps());
//! ```

pub mod config;
pub mod sim;
pub mod isa;
pub mod compiler;
pub mod analysis;
pub mod mem;
pub mod dram;
pub mod noc;
pub mod core;
pub mod gpu;
pub mod energy;
pub mod workloads;
pub mod runtime;
pub mod coordinator;
pub mod tuner;

pub use config::MachineConfig;
pub use coordinator::{run_workload, RunReport};
