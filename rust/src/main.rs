//! `mpu` — command-line driver for the MPU reproduction.
//!
//! Subcommands:
//!   run <workload> [key=val ...] [--tiny|--paper-scale] [--gpu]
//!   suite [key=val ...]              run all 12 workloads (MPU vs GPU)
//!   compile <workload>               show backend annotations
//!   validate [--tiny]                cross-check vs XLA artifacts
//!   list                             list workloads (Table I)
//!   config                           print the Table-II configuration
//!
//! The CLI is hand-rolled (no clap in the offline crate set).

use mpu::config::{GpuConfig, MachineConfig};
use mpu::coordinator::report::{f2, Table};
use mpu::coordinator::{compile_for, geomean, run_pair, run_workload_gpu_scaled, run_workload_scaled};
use mpu::runtime::{artifacts_available, validate_against_xla, XlaGolden};
use mpu::workloads::{prepare, Scale, Workload};

fn usage() -> ! {
    eprintln!(
        "usage: mpu <run|suite|compile|validate|list|config> [args]\n\
         \n  mpu run axpy row_buffers_per_bank=2 --gpu\
         \n  mpu suite offload_policy=hw\
         \n  mpu compile gemv\
         \n  mpu validate --tiny\
         \n  mpu list | mpu config"
    );
    std::process::exit(2);
}

fn parse_cfg(args: &[String]) -> MachineConfig {
    let mut cfg = if args.iter().any(|a| a == "--paper-scale") {
        MachineConfig::paper()
    } else {
        MachineConfig::scaled()
    };
    for a in args {
        if let Some((k, v)) = a.split_once('=') {
            if let Err(e) = cfg.set(k, v) {
                eprintln!("config error: {e}");
                std::process::exit(2);
            }
        }
    }
    cfg
}

fn scale_of(args: &[String]) -> Scale {
    if args.iter().any(|a| a == "--tiny") {
        Scale::Tiny
    } else {
        Scale::Small
    }
}

struct NullDev {
    top: u64,
}
impl mpu::workloads::Device for NullDev {
    fn alloc_bytes(&mut self, b: usize) -> u64 {
        let a = self.top;
        self.top += b as u64;
        a
    }
    fn write_f32(&mut self, _a: u64, _d: &[f32]) {}
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let rest = &args[1..];

    match cmd.as_str() {
        "list" => {
            println!("Table-I workloads:");
            for w in Workload::ALL {
                println!("  {:<8} smem={}", w.name(), if w.uses_smem() { "yes" } else { "no" });
            }
        }
        "config" => {
            let cfg = parse_cfg(rest);
            println!("{cfg:#?}");
            println!(
                "\npeak bank BW: {:.0} B/cycle   peak TSV BW: {:.0} B/cycle   ratio {:.1}x",
                cfg.peak_bank_bytes_per_cycle(),
                cfg.peak_tsv_bytes_per_cycle(),
                cfg.peak_bank_bytes_per_cycle() / cfg.peak_tsv_bytes_per_cycle()
            );
        }
        "run" => {
            let Some(name) = rest.first() else { usage() };
            let w = Workload::from_name(name).unwrap_or_else(|| usage());
            let cfg = parse_cfg(&rest[1..]);
            let scale = scale_of(rest);
            if rest.iter().any(|a| a == "--gpu") {
                let g = run_workload_gpu_scaled(w, &GpuConfig::matched(&cfg), &cfg, scale)?;
                println!(
                    "GPU {}: {} cycles, correct={} (max_err {:.2e}), {:.1} GB/s, {:.3} mJ",
                    w.name(),
                    g.cycles,
                    g.correct,
                    g.max_err,
                    g.dram_gbps(),
                    g.energy.total() * 1e3
                );
            } else {
                let r = run_workload_scaled(w, &cfg, scale)?;
                println!(
                    "MPU {}: {} cycles, correct={} (max_err {:.2e}), near {:.0}%, {:.1} GB/s, rowmiss {:.1}%, {:.3} mJ",
                    w.name(),
                    r.cycles,
                    r.correct,
                    r.max_err,
                    r.stats.near_fraction() * 100.0,
                    r.dram_gbps(),
                    r.stats.row_miss_rate() * 100.0,
                    r.energy.total() * 1e3
                );
            }
        }
        "suite" => {
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            let mut t = Table::new("suite: MPU vs GPU", &["workload", "speedup", "energy_red", "ok"]);
            let mut sp = Vec::new();
            for w in Workload::ALL {
                let p = run_pair(w, &cfg, scale)?;
                sp.push(p.speedup());
                t.row(vec![
                    w.name().into(),
                    f2(p.speedup()),
                    f2(p.energy_reduction()),
                    (p.mpu.correct && p.gpu.correct).to_string(),
                ]);
            }
            t.row(vec!["GEOMEAN".into(), f2(geomean(&sp)), String::new(), String::new()]);
            t.emit("suite");
        }
        "compile" => {
            let Some(name) = rest.first() else { usage() };
            let w = Workload::from_name(name).unwrap_or_else(|| usage());
            let mut dev = NullDev { top: 0 };
            let p = prepare(w, Scale::Tiny, &mut dev)?;
            let k = mpu::compiler::compile(&p.kernel)?;
            for (pc, i) in k.instrs.iter().enumerate() {
                println!("{pc:>4}  {:?}  {}", i.loc, i);
            }
            println!(
                "\nregisters: N {} / F {} / B {}; near pool {} regs, far pool {} regs",
                k.loc_stats.near,
                k.loc_stats.far,
                k.loc_stats.both,
                k.pools.near[0] + k.pools.near[1],
                k.pools.far[0] + k.pools.far[1]
            );
        }
        "validate" => {
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            anyhow::ensure!(artifacts_available(scale), "artifacts missing: run `make artifacts`");
            let golden = XlaGolden::new()?;
            for w in Workload::ALL {
                let mut m = mpu::core::Machine::new(&cfg);
                let p = prepare(w, scale, &mut m)?;
                let k = compile_for(&p, &cfg)?;
                m.launch(k, p.launch, &p.params, p.home_fn())?;
                m.run()?;
                let out = m.read_f32s(p.out_addr, p.out_len);
                let v = validate_against_xla(&golden, &p, scale, &out)?;
                println!(
                    "{:>8}: {} (max_err {:.2e})",
                    w.name(),
                    if v.passed { "OK" } else { "MISMATCH" },
                    v.max_err
                );
                anyhow::ensure!(v.passed, "{} diverged from the XLA golden", w.name());
            }
        }
        _ => usage(),
    }
    Ok(())
}
