//! `mpu` — command-line driver for the MPU reproduction.
//!
//! Subcommands:
//!   run <workload> [key=val ...] [--tiny|--paper-scale]
//!       [--machine mpu|gpu|ideal|mpu_nooff | --gpu]
//!   suite [key=val ...] [--tiny] [--out FILE] [--variants] [--strict]
//!                                    run all 12 workloads (MPU vs GPU,
//!                                    plus the ideal-bandwidth roofline
//!                                    and MPU-no-offload variants with
//!                                    --variants) through the parallel
//!                                    sweep engine and write
//!                                    BENCH_suite.json; --strict exits
//!                                    non-zero on any incorrect run
//!   check-json <file>                validate a BENCH_suite.json against
//!                                    schema v1 + correctness (CI gate)
//!   compile <workload>               show backend annotations
//!   validate [--tiny]                cross-check vs XLA artifacts
//!   list                             list workloads (Table I)
//!   config                           print the Table-II configuration
//!
//! The CLI is hand-rolled (no clap in the offline crate set).

use mpu::config::{MachineConfig, MachineKind};
use mpu::coordinator::bench::{
    all_correct, suite_json_with_variants, write_suite_json, SUITE_JSON,
};
use mpu::coordinator::report::{f2, Table};
use mpu::coordinator::sweep::{run_suite, run_suite_kind, Sweep, Target};
use mpu::coordinator::{compile_for, KernelCache};
use mpu::runtime::{artifacts_available, validate_against_xla, XlaGolden};
use mpu::workloads::{prepare, Scale, Workload};
use std::path::Path;

fn usage() -> ! {
    eprintln!(
        "usage: mpu <run|suite|check-json|compile|validate|list|config> [args]\n\
         \n  mpu run axpy row_buffers_per_bank=2 --machine ideal\
         \n  mpu suite offload_policy=hw --out BENCH_suite.json\
         \n  mpu suite --tiny --variants --strict\
         \n  mpu check-json BENCH_suite.json\
         \n  mpu compile gemv\
         \n  mpu validate --tiny\
         \n  mpu list | mpu config"
    );
    std::process::exit(2);
}

fn parse_cfg(args: &[String]) -> MachineConfig {
    let mut cfg = if args.iter().any(|a| a == "--paper-scale") {
        MachineConfig::paper()
    } else {
        MachineConfig::scaled()
    };
    for a in args {
        if let Some((k, v)) = a.split_once('=') {
            if let Err(e) = cfg.set(k, v) {
                eprintln!("config error: {e}");
                std::process::exit(2);
            }
        }
    }
    cfg
}

fn scale_of(args: &[String]) -> Scale {
    if args.iter().any(|a| a == "--tiny") {
        Scale::Tiny
    } else {
        Scale::Small
    }
}

/// `--out FILE` value, defaulting to `BENCH_suite.json`.
fn out_path(args: &[String]) -> String {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--out" {
            match it.next() {
                Some(p) => return p.clone(),
                None => {
                    eprintln!("--out requires a file path");
                    std::process::exit(2);
                }
            }
        }
    }
    SUITE_JSON.to_string()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let rest = &args[1..];

    match cmd.as_str() {
        "list" => {
            println!("Table-I workloads:");
            for w in Workload::ALL {
                println!("  {:<8} smem={}", w.name(), if w.uses_smem() { "yes" } else { "no" });
            }
        }
        "config" => {
            let cfg = parse_cfg(rest);
            println!("{cfg:#?}");
            println!(
                "\npeak bank BW: {:.0} B/cycle   peak TSV BW: {:.0} B/cycle   ratio {:.1}x",
                cfg.peak_bank_bytes_per_cycle(),
                cfg.peak_tsv_bytes_per_cycle(),
                cfg.peak_bank_bytes_per_cycle() / cfg.peak_tsv_bytes_per_cycle()
            );
        }
        "run" => {
            let Some(name) = rest.first() else { usage() };
            let w = Workload::from_name(name).unwrap_or_else(|| usage());
            let cfg = parse_cfg(&rest[1..]);
            let scale = scale_of(rest);
            // `--machine <kind>` selects any frontend variant; `--gpu`
            // stays as a shorthand for `--machine gpu`.
            let mut kind = MachineKind::Mpu;
            if rest.iter().any(|a| a == "--gpu") {
                kind = MachineKind::Gpu;
            }
            let mut it = rest.iter();
            while let Some(a) = it.next() {
                if a == "--machine" {
                    let Some(k) = it.next().and_then(|v| MachineKind::from_name(v)) else {
                        eprintln!("--machine needs one of: mpu gpu ideal mpu_nooff");
                        std::process::exit(2);
                    };
                    kind = k;
                }
            }
            let target = Target::for_kind(kind, &cfg);
            let results = Sweep::new().point(kind.name(), w, scale, target).run()?;
            let r = &results[0].report;
            match kind {
                MachineKind::Gpu | MachineKind::IdealBw => println!(
                    "{} {}: {} cycles, correct={} (max_err {:.2e}), {:.1} GB/s, {:.3} mJ",
                    kind.name().to_uppercase(),
                    w.name(),
                    r.cycles,
                    r.correct,
                    r.max_err,
                    r.dram_gbps(),
                    r.energy.total() * 1e3
                ),
                MachineKind::Mpu | MachineKind::MpuNoOffload => println!(
                    "{} {}: {} cycles, correct={} (max_err {:.2e}), near {:.0}%, {:.1} GB/s, rowmiss {:.1}%, {:.3} mJ",
                    kind.name().to_uppercase(),
                    w.name(),
                    r.cycles,
                    r.correct,
                    r.max_err,
                    r.stats.near_fraction() * 100.0,
                    r.dram_gbps(),
                    r.stats.row_miss_rate() * 100.0,
                    r.energy.total() * 1e3
                ),
            }
        }
        "suite" => {
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            let with_variants = rest.iter().any(|a| a == "--variants");
            let strict = rest.iter().any(|a| a == "--strict");
            let t0 = std::time::Instant::now();
            let pairs = run_suite(&cfg, scale)?;
            let mut variants: Vec<(String, Vec<mpu::RunReport>)> = Vec::new();
            if with_variants {
                for kind in [MachineKind::IdealBw, MachineKind::MpuNoOffload] {
                    let runs = run_suite_kind(&cfg, scale, kind)?;
                    variants.push((kind.name().to_string(), runs));
                }
            }
            let doc = suite_json_with_variants(scale, &pairs, &variants);
            let mut t = Table::new("suite: MPU vs GPU", &["workload", "speedup", "energy_red", "ok"]);
            for p in &pairs {
                t.row(vec![
                    p.mpu.workload.name().into(),
                    f2(p.speedup()),
                    f2(p.energy_reduction()),
                    (p.mpu.correct && p.gpu.correct).to_string(),
                ]);
            }
            t.row(vec!["GEOMEAN".into(), f2(doc.geomean_speedup), f2(doc.geomean_energy_reduction), String::new()]);
            t.emit("suite");
            for v in &doc.variants {
                println!(
                    "variant {:<10} geomean speedup vs GPU: {:.2}x",
                    v.variant, v.geomean_speedup_vs_gpu
                );
            }
            let out = out_path(rest);
            write_suite_json(Path::new(&out), &doc)?;
            println!(
                "\nwrote {} ({} workloads, {} extra variants, geomean speedup {:.2}x) in {:.1}s",
                out,
                doc.workloads.len(),
                doc.variants.len(),
                doc.geomean_speedup,
                t0.elapsed().as_secs_f64()
            );
            if strict {
                anyhow::ensure!(all_correct(&doc), "suite has incorrect runs (see table above)");
            }
        }
        "check-json" => {
            let Some(path) = rest.first() else { usage() };
            let body = std::fs::read_to_string(path)?;
            let v: serde_json::Value = serde_json::from_str(&body)?;
            anyhow::ensure!(v["schema_version"] == 1, "schema_version must be 1");
            for key in ["suite", "scale", "geomean_speedup", "geomean_energy_reduction"] {
                anyhow::ensure!(!v[key].is_null(), "missing key `{key}`");
            }
            let workloads = v["workloads"].as_array().ok_or_else(|| anyhow::anyhow!("missing workloads"))?;
            anyhow::ensure!(
                workloads.len() == Workload::ALL.len(),
                "expected {} workloads, found {}",
                Workload::ALL.len(),
                workloads.len()
            );
            let mut checked = 0usize;
            for w in workloads {
                for col in ["mpu", "gpu"] {
                    anyhow::ensure!(
                        w[col]["correct"] == true,
                        "workload {} incorrect on {}",
                        w["workload"],
                        col
                    );
                    checked += 1;
                }
            }
            if let Some(variants) = v["variants"].as_array() {
                for var in variants {
                    let Some(ws) = var["workloads"].as_array() else { continue };
                    for w in ws {
                        anyhow::ensure!(
                            w["entry"]["correct"] == true,
                            "workload {} incorrect on variant {}",
                            w["workload"],
                            var["variant"]
                        );
                        checked += 1;
                    }
                }
            }
            println!("{path}: schema v1 OK, {checked} machine runs all correct");
        }
        "compile" => {
            let Some(name) = rest.first() else { usage() };
            let w = Workload::from_name(name).unwrap_or_else(|| usage());
            let k = KernelCache::new().get(w, true)?;
            for (pc, i) in k.instrs.iter().enumerate() {
                println!("{pc:>4}  {:?}  {}", i.loc, i);
            }
            println!(
                "\nregisters: N {} / F {} / B {}; near pool {} regs, far pool {} regs",
                k.loc_stats.near,
                k.loc_stats.far,
                k.loc_stats.both,
                k.pools.near[0] + k.pools.near[1],
                k.pools.far[0] + k.pools.far[1]
            );
        }
        "validate" => {
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            anyhow::ensure!(artifacts_available(scale), "artifacts missing: run `make artifacts`");
            let golden = XlaGolden::new()?;
            for w in Workload::ALL {
                let mut m = mpu::core::Machine::new(&cfg);
                let p = prepare(w, scale, &mut m)?;
                let k = compile_for(&p, &cfg)?;
                m.launch(k, p.launch, &p.params, p.home_fn())?;
                m.run()?;
                let out = m.read_f32s(p.out_addr, p.out_len);
                let v = validate_against_xla(&golden, &p, scale, &out)?;
                println!(
                    "{:>8}: {} (max_err {:.2e})",
                    w.name(),
                    if v.passed { "OK" } else { "MISMATCH" },
                    v.max_err
                );
                anyhow::ensure!(v.passed, "{} diverged from the XLA golden", w.name());
            }
        }
        _ => usage(),
    }
    Ok(())
}
