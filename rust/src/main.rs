//! `mpu` — command-line driver for the MPU reproduction.
//!
//! Subcommands:
//!   run <workload> [key=val ...] [--tiny|--paper-scale] [--gpu]
//!   suite [key=val ...] [--tiny] [--out FILE]
//!                                    run all 12 workloads (MPU vs GPU)
//!                                    through the parallel sweep engine
//!                                    and write BENCH_suite.json
//!   compile <workload>               show backend annotations
//!   validate [--tiny]                cross-check vs XLA artifacts
//!   list                             list workloads (Table I)
//!   config                           print the Table-II configuration
//!
//! The CLI is hand-rolled (no clap in the offline crate set).

use mpu::config::{GpuConfig, MachineConfig};
use mpu::coordinator::bench::{suite_json, write_suite_json, SUITE_JSON};
use mpu::coordinator::report::{f2, Table};
use mpu::coordinator::sweep::{run_suite, Sweep, Target};
use mpu::coordinator::{compile_for, KernelCache};
use mpu::runtime::{artifacts_available, validate_against_xla, XlaGolden};
use mpu::workloads::{prepare, Scale, Workload};
use std::path::Path;

fn usage() -> ! {
    eprintln!(
        "usage: mpu <run|suite|compile|validate|list|config> [args]\n\
         \n  mpu run axpy row_buffers_per_bank=2 --gpu\
         \n  mpu suite offload_policy=hw --out BENCH_suite.json\
         \n  mpu compile gemv\
         \n  mpu validate --tiny\
         \n  mpu list | mpu config"
    );
    std::process::exit(2);
}

fn parse_cfg(args: &[String]) -> MachineConfig {
    let mut cfg = if args.iter().any(|a| a == "--paper-scale") {
        MachineConfig::paper()
    } else {
        MachineConfig::scaled()
    };
    for a in args {
        if let Some((k, v)) = a.split_once('=') {
            if let Err(e) = cfg.set(k, v) {
                eprintln!("config error: {e}");
                std::process::exit(2);
            }
        }
    }
    cfg
}

fn scale_of(args: &[String]) -> Scale {
    if args.iter().any(|a| a == "--tiny") {
        Scale::Tiny
    } else {
        Scale::Small
    }
}

/// `--out FILE` value, defaulting to `BENCH_suite.json`.
fn out_path(args: &[String]) -> String {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--out" {
            match it.next() {
                Some(p) => return p.clone(),
                None => {
                    eprintln!("--out requires a file path");
                    std::process::exit(2);
                }
            }
        }
    }
    SUITE_JSON.to_string()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let rest = &args[1..];

    match cmd.as_str() {
        "list" => {
            println!("Table-I workloads:");
            for w in Workload::ALL {
                println!("  {:<8} smem={}", w.name(), if w.uses_smem() { "yes" } else { "no" });
            }
        }
        "config" => {
            let cfg = parse_cfg(rest);
            println!("{cfg:#?}");
            println!(
                "\npeak bank BW: {:.0} B/cycle   peak TSV BW: {:.0} B/cycle   ratio {:.1}x",
                cfg.peak_bank_bytes_per_cycle(),
                cfg.peak_tsv_bytes_per_cycle(),
                cfg.peak_bank_bytes_per_cycle() / cfg.peak_tsv_bytes_per_cycle()
            );
        }
        "run" => {
            let Some(name) = rest.first() else { usage() };
            let w = Workload::from_name(name).unwrap_or_else(|| usage());
            let cfg = parse_cfg(&rest[1..]);
            let scale = scale_of(rest);
            let on_gpu = rest.iter().any(|a| a == "--gpu");
            let target = if on_gpu {
                Target::Gpu(GpuConfig::matched(&cfg), cfg.clone())
            } else {
                Target::Mpu(cfg.clone())
            };
            let label = if on_gpu { "gpu" } else { "mpu" };
            let results = Sweep::new().point(label, w, scale, target).run()?;
            let r = &results[0].report;
            if on_gpu {
                println!(
                    "GPU {}: {} cycles, correct={} (max_err {:.2e}), {:.1} GB/s, {:.3} mJ",
                    w.name(),
                    r.cycles,
                    r.correct,
                    r.max_err,
                    r.dram_gbps(),
                    r.energy.total() * 1e3
                );
            } else {
                println!(
                    "MPU {}: {} cycles, correct={} (max_err {:.2e}), near {:.0}%, {:.1} GB/s, rowmiss {:.1}%, {:.3} mJ",
                    w.name(),
                    r.cycles,
                    r.correct,
                    r.max_err,
                    r.stats.near_fraction() * 100.0,
                    r.dram_gbps(),
                    r.stats.row_miss_rate() * 100.0,
                    r.energy.total() * 1e3
                );
            }
        }
        "suite" => {
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            let t0 = std::time::Instant::now();
            let pairs = run_suite(&cfg, scale)?;
            let mut t = Table::new("suite: MPU vs GPU", &["workload", "speedup", "energy_red", "ok"]);
            for p in &pairs {
                t.row(vec![
                    p.mpu.workload.name().into(),
                    f2(p.speedup()),
                    f2(p.energy_reduction()),
                    (p.mpu.correct && p.gpu.correct).to_string(),
                ]);
            }
            let doc = suite_json(scale, &pairs);
            t.row(vec!["GEOMEAN".into(), f2(doc.geomean_speedup), f2(doc.geomean_energy_reduction), String::new()]);
            t.emit("suite");
            let out = out_path(rest);
            write_suite_json(Path::new(&out), &doc)?;
            println!(
                "\nwrote {} ({} workloads, geomean speedup {:.2}x) in {:.1}s",
                out,
                doc.workloads.len(),
                doc.geomean_speedup,
                t0.elapsed().as_secs_f64()
            );
        }
        "compile" => {
            let Some(name) = rest.first() else { usage() };
            let w = Workload::from_name(name).unwrap_or_else(|| usage());
            let k = KernelCache::new().get(w, true)?;
            for (pc, i) in k.instrs.iter().enumerate() {
                println!("{pc:>4}  {:?}  {}", i.loc, i);
            }
            println!(
                "\nregisters: N {} / F {} / B {}; near pool {} regs, far pool {} regs",
                k.loc_stats.near,
                k.loc_stats.far,
                k.loc_stats.both,
                k.pools.near[0] + k.pools.near[1],
                k.pools.far[0] + k.pools.far[1]
            );
        }
        "validate" => {
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            anyhow::ensure!(artifacts_available(scale), "artifacts missing: run `make artifacts`");
            let golden = XlaGolden::new()?;
            for w in Workload::ALL {
                let mut m = mpu::core::Machine::new(&cfg);
                let p = prepare(w, scale, &mut m)?;
                let k = compile_for(&p, &cfg)?;
                m.launch(k, p.launch, &p.params, p.home_fn())?;
                m.run()?;
                let out = m.read_f32s(p.out_addr, p.out_len);
                let v = validate_against_xla(&golden, &p, scale, &out)?;
                println!(
                    "{:>8}: {} (max_err {:.2e})",
                    w.name(),
                    if v.passed { "OK" } else { "MISMATCH" },
                    v.max_err
                );
                anyhow::ensure!(v.passed, "{} diverged from the XLA golden", w.name());
            }
        }
        _ => usage(),
    }
    Ok(())
}
