//! `mpu` — command-line driver for the MPU reproduction.
//!
//! Subcommands:
//!   run <workload> [key=val ...] [--tiny|--paper-scale]
//!       [--machine mpu|gpu|ideal|mpu_nooff | --gpu] [--threads N]
//!       [--loc-stats]                --loc-stats additionally prints the
//!                                    compiler's Fig.-14 register-location
//!                                    breakdown (N/F/B/U counts and
//!                                    fractions)
//!   suite [key=val ...] [--tiny] [--out FILE] [--variants] [--strict]
//!         [--store DIR] [--threads N] [--perf [--repeat N]]
//!                                    run all 12 workloads (MPU vs GPU,
//!                                    plus the ideal-bandwidth roofline
//!                                    and MPU-no-offload variants with
//!                                    --variants) through the parallel
//!                                    sweep engine and write
//!                                    BENCH_suite.json; --strict exits
//!                                    non-zero on any incorrect run;
//!                                    --store reuses/feeds the on-disk
//!                                    result store; --threads shards
//!                                    each machine's issue phase across
//!                                    N worker threads (bit-identical
//!                                    results for any N); --perf
//!                                    additionally re-simulates every
//!                                    variant × workload fresh +
//!                                    serially and writes the
//!                                    simulator-throughput report
//!                                    BENCH_simperf.json; --repeat N
//!                                    times each --perf point N times
//!                                    after an untimed warmup pass and
//!                                    records the median wall-ms
//!   cycles [--tiny] [--out FILE] [--check FILE]
//!                                    golden per-workload cycle counts
//!                                    for all four machine variants
//!                                    (one simulation pass serves both
//!                                    flags); --check fails on ANY
//!                                    exact-cycle drift vs the given
//!                                    golden file
//!   lint [--workload W] [--machine K] [--tiny] [--json] [--out FILE]
//!        [--deny warnings]           static kernel analysis (uninit /
//!                                    divergence / barrier / race /
//!                                    access-pattern passes) over the
//!                                    Table-I workloads; exits non-zero
//!                                    on errors (and on warnings with
//!                                    --deny warnings); --json prints
//!                                    the structured report
//!   check-json <file>                validate a BENCH_suite.json against
//!                                    schema v1 + correctness (CI gate)
//!   check-json --compare <old> <new> additionally diff per-workload
//!                                    cycles; exits non-zero on any >5%
//!                                    cycle regression vs the baseline
//!   check-json --compare-perf <old> <new>
//!                                    diff two BENCH_simperf.json docs
//!                                    per (variant × workload) point;
//!                                    exits non-zero on any >20%
//!                                    simulator-throughput (cycles/s)
//!                                    regression vs the baseline
//!   serve [--addr A] [--store DIR] [--store-max-mb N] [--no-store]
//!         [--workers H:P,H:P,...] [--coordinator A] [serve knobs]
//!                                    long-running sweep daemon (JSONL
//!                                    over TCP) with the persistent
//!                                    on-disk result store; with
//!                                    --workers (or MPU_WORKERS) it
//!                                    runs as a federation coordinator
//!                                    that shards submits across the
//!                                    worker daemons by consistent
//!                                    hashing and merges their
//!                                    streamed results; --coordinator
//!                                    self-registers the worker with a
//!                                    running coordinator (join on
//!                                    boot, drain on shutdown); every
//!                                    serving knob resolves CLI flag >
//!                                    MPU_* env > default (see the
//!                                    knob table in the usage text)
//!   submit [suite|<workload>...] [--tiny] [--variants a,b] [--priority N]
//!          [--fresh] [--strict] [--stream] [--addr A] [--client-id ID]
//!          [--workers H:P,...] [key=val ...]
//!                                    submit a batch to the daemon;
//!                                    --stream prints progress as
//!                                    points complete; --workers fans
//!                                    the batch out client-side across
//!                                    a worker fleet; --client-id names
//!                                    the fair-share lane the batch
//!                                    queues in
//!   status [--addr A] [--watch [--interval-ms N]]
//!                                    daemon + store counters (adds
//!                                    queue depth, in-flight count and
//!                                    per-worker liveness against a
//!                                    busy daemon / coordinator);
//!                                    --watch rerenders the live
//!                                    metrics view every N ms
//!   metrics [--addr A] [--out METRICS.json]
//!                                    one schema-versioned metrics
//!                                    snapshot: queue/in-flight depths,
//!                                    cache hit rates, per-client
//!                                    fair-share rows, per-worker
//!                                    liveness and cycles/s; --out
//!                                    writes the METRICS.json document
//!                                    `mpu check-json` validates
//!   fleet {join|drain} <worker> [--addr A]
//!                                    hot fleet membership against a
//!                                    running coordinator: join adds
//!                                    (or un-drains) a worker without
//!                                    a restart, drain lets it finish
//!                                    in-flight points while new ones
//!                                    remap to the survivors
//!   store {stats|gc} [--store DIR] [--max-age-days D] [--max-mb N]
//!                                    inspect or garbage-collect the
//!                                    on-disk result store: gc drops
//!                                    schema-stale entries eagerly,
//!                                    expires entries older than D
//!                                    days, LRU-evicts to the byte cap
//!                                    and compacts index.json
//!   shutdown [--addr A]              stop the daemon
//!   tune [<workload>...|--all] [--tiny] [--budget N] [--seed S]
//!        [--threads N] [--store DIR] [--workers H:P,...]
//!        [--out FILE] [--append-suite FILE] [key=val ...]
//!                                    offload-policy autotuner: search
//!                                    explicit per-pc policy tables
//!                                    (exhaustive for small kernels,
//!                                    greedy + seeded annealing beyond)
//!                                    against the CompilerAnnotated /
//!                                    HardwareDefault / no-offload
//!                                    baselines and write the
//!                                    schema-versioned TUNE_report.json;
//!                                    every candidate is just another
//!                                    config fingerprint, so --store
//!                                    and --workers dedup evaluations
//!                                    through the usual cache tiers;
//!                                    --append-suite folds the tuning
//!                                    appendix into an existing
//!                                    BENCH_suite.json
//!   compile <workload>               show backend annotations
//!   validate [--tiny]                cross-check vs XLA artifacts
//!   list                             list workloads (Table I)
//!   config                           print the Table-II configuration
//!
//! The CLI is hand-rolled (no clap in the offline crate set).

use mpu::config::{MachineConfig, MachineKind, ServeConfig, SERVE_KNOBS};
use mpu::coordinator::bench::{
    all_correct, simperf_json_repeated, suite_json_with_variants, write_simperf_json,
    write_suite_json, SuiteStats, SIMPERF_JSON, SUITE_JSON,
};
use mpu::coordinator::proto::{
    self, MetricsBody, Response, StreamOutcome, SubmitRequest, METRICS_SCHEMA_VERSION,
};
use mpu::coordinator::report::{f2, Table};
use mpu::coordinator::sweep::{
    run_suite_kind, run_suite_kind_threaded, run_suite_threaded, SimCache, Sweep, Target,
};
use mpu::coordinator::{
    compile_for, fault, Coordinator, DiskStore, FaultPlan, FedEvent, Federation, GcOptions,
    KernelCache, RetryPolicy, Service, StoreConfig, SweepServer, Timeouts,
};
use mpu::analysis::{lint_workload, LintReport};
use mpu::runtime::{artifacts_available, validate_against_xla, XlaGolden};
use mpu::tuner::{self, TuneOptions};
use mpu::workloads::{prepare, Scale, Workload};
use std::path::Path;
use std::sync::Arc;

fn usage() -> ! {
    eprintln!(
        "usage: mpu <run|suite|cycles|lint|check-json|serve|submit|status|metrics|fleet|shutdown|store|tune|compile|validate|list|config> [args]\n\
         \n  mpu run axpy row_buffers_per_bank=2 --machine ideal\
         \n  mpu run axpy --tiny --loc-stats\
         \n  mpu tune axpy gemv --tiny --budget 16 --store .mpu-store\
         \n  mpu tune --all --tiny --out TUNE_report.json --append-suite BENCH_suite.json\
         \n  mpu lint --deny warnings --json --out LINT_report.json\
         \n  mpu lint --workload gemv\
         \n  mpu suite offload_policy=hw --out BENCH_suite.json\
         \n  mpu suite --tiny --variants --strict --perf --repeat 3\
         \n  mpu suite --threads 4\
         \n  mpu cycles --tiny --out CYCLES_tiny.json\
         \n  mpu cycles --tiny --check baselines/CYCLES_tiny.json\
         \n  mpu check-json BENCH_suite.json\
         \n  mpu check-json --compare baselines/BENCH_suite.small.json BENCH_suite.json\
         \n  mpu check-json --compare-perf baselines/BENCH_simperf.json BENCH_simperf.json\
         \n  mpu serve --addr 127.0.0.1:7117 --store .mpu-store\
         \n  mpu serve --addr 127.0.0.1:7200 --workers 127.0.0.1:7201,127.0.0.1:7202\
         \n  mpu serve --max-queue 4096 --faults \"seed=42,disconnect=0.1\"\
         \n  mpu submit suite --tiny --variants mpu,gpu --stream\
         \n  mpu submit suite --tiny --workers 127.0.0.1:7201,127.0.0.1:7202\
         \n  mpu submit suite --tiny --client-id alice --stream\
         \n  mpu serve --addr 127.0.0.1:7203 --coordinator 127.0.0.1:7200\
         \n  mpu fleet join 127.0.0.1:7203 --addr 127.0.0.1:7200\
         \n  mpu fleet drain 127.0.0.1:7202 --addr 127.0.0.1:7200\
         \n  mpu status | mpu status --watch --interval-ms 500\
         \n  mpu metrics --out METRICS.json | mpu check-json METRICS.json\
         \n  mpu shutdown\
         \n  mpu store stats | mpu store gc --max-age-days 30\
         \n  mpu compile gemv\
         \n  mpu validate --tiny\
         \n  mpu list | mpu config\
         \n\
         \nserving knobs (CLI flag > MPU_* env > default):\
         \n{}",
        ServeConfig::knob_help()
    );
    std::process::exit(2);
}

/// Flags that consume the next argument as their value. Shared by the
/// positional scan and the `key=val` config scan, so a flag value that
/// happens to contain `=` (a `--faults` spec) is never misread as a
/// machine-config pair.
const VALUE_FLAGS: [&str; 28] = [
    "--variants",
    "--priority",
    "--addr",
    "--out",
    "--store",
    "--store-max-mb",
    "--machine",
    "--workers",
    "--max-age-days",
    "--max-mb",
    "--workload",
    "--deny",
    "--threads",
    "--repeat",
    "--budget",
    "--seed",
    "--append-suite",
    "--faults",
    "--max-queue",
    "--connect-timeout-ms",
    "--io-timeout-ms",
    "--retries",
    "--backoff-ms",
    "--client-id",
    "--max-client-queue",
    "--client-weights",
    "--coordinator",
    "--interval-ms",
];

/// The `key=val` machine-configuration pairs among `args`, skipping
/// the values of [`VALUE_FLAGS`].
fn config_pairs(args: &[String]) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if VALUE_FLAGS.contains(&a.as_str()) {
            it.next();
        } else if !a.starts_with("--") {
            if let Some((k, v)) = a.split_once('=') {
                out.push((k.to_string(), v.to_string()));
            }
        }
    }
    out
}

fn parse_cfg(args: &[String]) -> MachineConfig {
    let mut cfg = if args.iter().any(|a| a == "--paper-scale") {
        MachineConfig::paper()
    } else {
        MachineConfig::scaled()
    };
    for (k, v) in config_pairs(args) {
        if let Err(e) = cfg.set(&k, &v) {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    }
    cfg
}

fn scale_of(args: &[String]) -> Scale {
    if args.iter().any(|a| a == "--tiny") {
        Scale::Tiny
    } else {
        Scale::Small
    }
}

/// Value of a `--flag VALUE` pair, if present.
fn flag_value(args: &[String], flag: &str) -> Option<String> {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == flag {
            match it.next() {
                Some(v) => return Some(v.clone()),
                None => {
                    eprintln!("{flag} requires a value");
                    std::process::exit(2);
                }
            }
        }
    }
    None
}

/// Positive-integer value of a `--flag N` pair, defaulting to 1.
fn usize_flag(args: &[String], flag: &str) -> usize {
    flag_value(args, flag)
        .map(|v| {
            v.parse::<usize>().ok().filter(|&n| n >= 1).unwrap_or_else(|| {
                eprintln!("{flag} needs a positive integer, got `{v}`");
                std::process::exit(2);
            })
        })
        .unwrap_or(1)
}

/// `--out FILE` value, defaulting to `BENCH_suite.json`.
fn out_path(args: &[String]) -> String {
    flag_value(args, "--out").unwrap_or_else(|| SUITE_JSON.to_string())
}

/// Positional arguments: everything that is not a `--flag` (or its
/// value) and not a `key=val` configuration pair.
fn positionals(args: &[String]) -> Vec<String> {
    let mut out = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if VALUE_FLAGS.contains(&a.as_str()) {
            it.next();
        } else if !a.starts_with("--") && !a.contains('=') {
            out.push(a.clone());
        }
    }
    out
}

/// Resolve every serving knob for this invocation: each flag in
/// [`SERVE_KNOBS`] is read from the command line and layered over the
/// `MPU_*` environment and the built-in defaults (CLI > env > default).
fn serve_cfg(args: &[String]) -> ServeConfig {
    let mut b = ServeConfig::builder();
    for knob in SERVE_KNOBS {
        b = b.cli_flag(knob.flag, flag_value(args, knob.flag));
    }
    b.build().unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

/// Typed client for the addressed daemon, carrying the resolved retry
/// policy and client identity. `deadline` applies the socket timeouts
/// too — right for probes and streamed submits; a blocking interactive
/// submit legitimately runs for minutes and stays deadline-free.
fn client_from(cfg: &ServeConfig, deadline: bool) -> proto::Client {
    let mut c = proto::Client::new(cfg.addr.clone())
        .with_retry(RetryPolicy {
            attempts: cfg.retries,
            base_delay: cfg.backoff,
            ..RetryPolicy::default()
        })
        .with_identity(cfg.client_id.clone());
    if deadline {
        c = c.with_timeouts(Timeouts { connect: cfg.connect_timeout, io: cfg.io_timeout });
    }
    c
}

/// `check-json --compare` gate: per-workload MPU/GPU cycle deltas, >5%
/// regressions fail.
fn compare_docs(old_path: &str, new_path: &str) -> anyhow::Result<()> {
    const REGRESSION_PCT: f64 = 5.0;
    let load = |p: &str| -> anyhow::Result<serde_json::Value> {
        Ok(serde_json::from_str(&std::fs::read_to_string(p)?)?)
    };
    let old = load(old_path)?;
    let new = load(new_path)?;
    anyhow::ensure!(
        old["scale"] == new["scale"],
        "scale mismatch: baseline is {} but candidate is {}",
        old["scale"],
        new["scale"]
    );
    let by_name = |doc: &serde_json::Value| -> Vec<(String, u64, u64)> {
        doc["workloads"]
            .as_array()
            .map(|ws| {
                ws.iter()
                    .filter_map(|w| {
                        Some((
                            w["workload"].as_str()?.to_string(),
                            w["mpu"]["cycles"].as_u64()?,
                            w["gpu"]["cycles"].as_u64()?,
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let old_ws = by_name(&old);
    let new_ws = by_name(&new);
    anyhow::ensure!(!old_ws.is_empty(), "baseline {old_path} has no workload cycles");
    anyhow::ensure!(!new_ws.is_empty(), "candidate {new_path} has no workload cycles");
    let mut t = Table::new(
        "cycle deltas vs baseline (positive = slower)",
        &["workload", "mpu Δ%", "gpu Δ%"],
    );
    let mut regressions: Vec<String> = Vec::new();
    let mut compared = 0usize;
    for (name, new_mpu, new_gpu) in &new_ws {
        let Some((_, old_mpu, old_gpu)) = old_ws.iter().find(|(n, _, _)| n == name) else {
            t.row(vec![name.clone(), "(new)".into(), "(new)".into()]);
            continue;
        };
        let delta = |old_c: u64, new_c: u64| {
            (new_c as f64 - old_c as f64) / (old_c as f64).max(1.0) * 100.0
        };
        let dm = delta(*old_mpu, *new_mpu);
        let dg = delta(*old_gpu, *new_gpu);
        t.row(vec![name.clone(), format!("{dm:+.2}"), format!("{dg:+.2}")]);
        compared += 1;
        if dm > REGRESSION_PCT {
            regressions.push(format!("{name} mpu cycles {old_mpu} -> {new_mpu} ({dm:+.2}%)"));
        }
        if dg > REGRESSION_PCT {
            regressions.push(format!("{name} gpu cycles {old_gpu} -> {new_gpu} ({dg:+.2}%)"));
        }
    }
    for (name, _, _) in &old_ws {
        if !new_ws.iter().any(|(n, _, _)| n == name) {
            regressions.push(format!("{name} present in baseline but missing from candidate"));
        }
    }
    t.emit("compare");
    if let (Some(og), Some(ng)) =
        (old["geomean_speedup"].as_f64(), new["geomean_speedup"].as_f64())
    {
        println!("geomean speedup: baseline {og:.3} -> candidate {ng:.3}");
    }
    println!("compared {compared} workloads against {old_path}");
    anyhow::ensure!(
        regressions.is_empty(),
        "cycle regressions over {REGRESSION_PCT}%:\n  {}",
        regressions.join("\n  ")
    );
    Ok(())
}

/// `check-json --compare-perf` gate: per-(variant × workload)
/// simulator-throughput (cycles/s) deltas between two
/// `BENCH_simperf.json` documents; >20% regressions fail. Wall-clock
/// throughput is noisier than cycle counts, so the threshold is wider
/// than `--compare`'s and only *drops* fail — speedups are the point.
fn compare_perf_docs(old_path: &str, new_path: &str) -> anyhow::Result<()> {
    const REGRESSION_PCT: f64 = 20.0;
    let load = |p: &str| -> anyhow::Result<serde_json::Value> {
        Ok(serde_json::from_str(&std::fs::read_to_string(p)?)?)
    };
    let old = load(old_path)?;
    let new = load(new_path)?;
    anyhow::ensure!(
        old["scale"] == new["scale"],
        "scale mismatch: baseline is {} but candidate is {}",
        old["scale"],
        new["scale"]
    );
    let points = |doc: &serde_json::Value| -> Vec<(String, String, f64)> {
        doc["points"]
            .as_array()
            .map(|ps| {
                ps.iter()
                    .filter_map(|p| {
                        Some((
                            p["variant"].as_str()?.to_string(),
                            p["workload"].as_str()?.to_string(),
                            p["cycles_per_sec"].as_f64()?,
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let old_ps = points(&old);
    let new_ps = points(&new);
    anyhow::ensure!(!old_ps.is_empty(), "baseline {old_path} has no throughput points");
    anyhow::ensure!(!new_ps.is_empty(), "candidate {new_path} has no throughput points");
    let mut t = Table::new(
        "simulator-throughput deltas vs baseline (positive = faster)",
        &["variant", "workload", "base Mcyc/s", "new Mcyc/s", "Δ%"],
    );
    let mut regressions: Vec<String> = Vec::new();
    let mut compared = 0usize;
    for (variant, workload, new_cps) in &new_ps {
        let Some((_, _, old_cps)) =
            old_ps.iter().find(|(v, w, _)| v == variant && w == workload)
        else {
            t.row(vec![variant.clone(), workload.clone(), "(new)".into(), f2(new_cps / 1e6), String::new()]);
            continue;
        };
        let delta = (new_cps - old_cps) / old_cps.max(1e-9) * 100.0;
        t.row(vec![
            variant.clone(),
            workload.clone(),
            f2(old_cps / 1e6),
            f2(new_cps / 1e6),
            format!("{delta:+.1}"),
        ]);
        compared += 1;
        if delta < -REGRESSION_PCT {
            regressions.push(format!(
                "{variant}/{workload} cycles/s {:.2e} -> {:.2e} ({delta:+.1}%)",
                old_cps, new_cps
            ));
        }
    }
    for (variant, workload, _) in &old_ps {
        if !new_ps.iter().any(|(v, w, _)| v == variant && w == workload) {
            regressions
                .push(format!("{variant}/{workload} present in baseline but missing from candidate"));
        }
    }
    t.emit("compare-perf");
    if let (Some(og), Some(ng)) = (
        old["geomean_cycles_per_sec"].as_f64(),
        new["geomean_cycles_per_sec"].as_f64(),
    ) {
        println!(
            "geomean throughput: baseline {:.2} -> candidate {:.2} Mcycles/s ({:+.1}%)",
            og / 1e6,
            ng / 1e6,
            (ng - og) / og.max(1e-9) * 100.0
        );
    }
    println!("compared {compared} points against {old_path}");
    anyhow::ensure!(
        regressions.is_empty(),
        "simulator-throughput regressions over {REGRESSION_PCT}%:\n  {}",
        regressions.join("\n  ")
    );
    Ok(())
}

/// A required numeric field that must be present and finite. NaN/Inf
/// serialize to JSON `null`, so the null check doubles as the NaN gate.
fn finite_field(v: &serde_json::Value, key: &str) -> anyhow::Result<f64> {
    v[key]
        .as_f64()
        .filter(|x| x.is_finite())
        .ok_or_else(|| anyhow::anyhow!("key `{key}` missing or not a finite number"))
}

/// Shared validation of tuning entries: `TUNE_report.json` workload
/// rows and the `tuning` appendix rows of a `BENCH_suite.json`.
fn check_tuning_rows(ws: &[serde_json::Value], ctx: &str) -> anyhow::Result<usize> {
    anyhow::ensure!(!ws.is_empty(), "{ctx}: empty workload list");
    for w in ws {
        let name = w["workload"].as_str().unwrap_or("?");
        let tuned = w["tuned_cycles"]
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("{ctx}: {name} missing tuned_cycles"))?;
        let ann = w["annotated_cycles"]
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("{ctx}: {name} missing annotated_cycles"))?;
        anyhow::ensure!(
            tuned <= ann,
            "{ctx}: {name} tuned {tuned} cycles worse than annotated {ann} — the \
             Algorithm-1 seed is in the search space, so this must never happen"
        );
        for key in ["speedup_vs_annotated", "speedup_vs_hw_default", "speedup_vs_nooff"] {
            let s = finite_field(w, key).map_err(|e| anyhow::anyhow!("{ctx}: {name}: {e}"))?;
            anyhow::ensure!(s > 0.0, "{ctx}: {name} non-positive {key} {s}");
        }
    }
    Ok(ws.len())
}

/// `check-json` gate for a `TUNE_report.json` document.
fn check_tune_doc(v: &serde_json::Value) -> anyhow::Result<usize> {
    anyhow::ensure!(v["schema_version"] == 1, "schema_version must be 1");
    for key in ["scale", "budget", "seed", "evaluations", "simulated", "mem_hits", "disk_hits"] {
        anyhow::ensure!(!v[key].is_null(), "missing key `{key}`");
    }
    finite_field(v, "geomean_speedup_vs_annotated")?;
    let ws = v["workloads"].as_array().ok_or_else(|| anyhow::anyhow!("missing workloads"))?;
    for w in ws {
        for key in ["kernel", "search_mode", "best_policy", "candidate_pcs", "loc_stats"] {
            anyhow::ensure!(
                !w[key].is_null(),
                "workload {} missing key `{key}`",
                w["workload"]
            );
        }
    }
    check_tuning_rows(ws, "tune report")
}

/// `check-json` gate for the append-only `tuning` appendix of a
/// `BENCH_suite.json` document.
fn check_tuning_appendix(v: &serde_json::Value) -> anyhow::Result<usize> {
    for key in ["scale", "budget", "seed"] {
        anyhow::ensure!(!v[key].is_null(), "tuning appendix missing key `{key}`");
    }
    for key in [
        "geomean_speedup_vs_annotated",
        "geomean_speedup_vs_hw_default",
        "geomean_speedup_vs_nooff",
    ] {
        finite_field(v, key).map_err(|e| anyhow::anyhow!("tuning appendix: {e}"))?;
    }
    let ws = v["workloads"]
        .as_array()
        .ok_or_else(|| anyhow::anyhow!("tuning appendix missing workloads"))?;
    check_tuning_rows(ws, "tuning appendix")
}

/// `check-json` gate for a `METRICS.json` document (the serialized
/// `metrics` protocol record). Returns (client lanes, worker rows).
fn check_metrics_doc(v: &serde_json::Value) -> anyhow::Result<(usize, usize)> {
    anyhow::ensure!(
        v["schema_version"] == METRICS_SCHEMA_VERSION,
        "metrics schema_version must be {METRICS_SCHEMA_VERSION}"
    );
    for key in [
        "proto_version",
        "uptime_ms",
        "queue_depth",
        "inflight",
        "active_requests",
        "requests",
        "points",
        "simulated",
        "admission_rejected",
        "retries",
        "degraded_batches",
    ] {
        anyhow::ensure!(v[key].is_u64(), "key `{key}` missing or not an unsigned integer");
    }
    let rate = finite_field(v, "cache_hit_rate")?;
    anyhow::ensure!((0.0..=1.0).contains(&rate), "cache_hit_rate {rate} outside [0, 1]");
    let cps = finite_field(v, "sim_cycles_per_sec")?;
    anyhow::ensure!(cps >= 0.0, "negative sim_cycles_per_sec {cps}");
    let clients = v["clients"].as_array().cloned().unwrap_or_default();
    for c in &clients {
        anyhow::ensure!(c["client_id"].is_string(), "client row missing client_id");
        anyhow::ensure!(
            c["weight"].as_u64().is_some_and(|w| w >= 1),
            "client {} weight must be >= 1",
            c["client_id"]
        );
    }
    let workers = v["workers"].as_array().cloned().unwrap_or_default();
    for w in &workers {
        anyhow::ensure!(w["addr"].is_string(), "worker row missing addr");
        anyhow::ensure!(w["alive"].is_boolean(), "worker {} missing alive flag", w["addr"]);
    }
    Ok((clients.len(), workers.len()))
}

/// Human rendering of a `metrics` snapshot (`mpu metrics`, one frame
/// of `mpu status --watch`).
fn print_metrics(addr: &str, m: &MetricsBody) {
    println!("mpu metrics at {addr} (proto v{}, schema v{})", m.proto_version, m.schema_version);
    println!("  uptime          {:.1}s", m.uptime_ms as f64 / 1e3);
    println!("  queue depth     {} (limit {})", m.queue_depth, m.queue_limit);
    println!("  in flight       {}", m.inflight);
    println!("  active submits  {}", m.active_requests);
    println!("  requests        {}", m.requests);
    println!("  points          {}", m.points);
    println!(
        "  simulated       {} (mem={} disk={} dedup={}, hit rate {:.1}%)",
        m.simulated,
        m.mem_hits,
        m.disk_hits,
        m.dedup_waits,
        m.cache_hit_rate * 100.0
    );
    println!("  rejected        {}", m.admission_rejected);
    println!("  retries         {}", m.retries);
    println!("  degraded        {}", m.degraded_batches);
    println!("  sim cycles/s    {:.2}M", m.sim_cycles_per_sec / 1e6);
    if let Some(st) = &m.store {
        println!(
            "  store           {} entries, {}/{} KiB, hits={} misses={} evictions={}",
            st.entries,
            st.bytes / 1024,
            st.max_bytes / 1024,
            st.hits,
            st.misses,
            st.evictions
        );
    }
    if !m.clients.is_empty() {
        println!("  clients ({}):", m.clients.len());
        for c in &m.clients {
            println!(
                "    {:<16} weight={} queued={} completed={} rejected={}",
                c.client_id, c.weight, c.queued, c.completed, c.rejected
            );
        }
    }
    if !m.workers.is_empty() {
        println!("  workers ({}):", m.workers.len());
        for w in &m.workers {
            if w.alive {
                println!(
                    "    {:<21} {:<8} proto v{} points={} simulated={} queue={} inflight={} {:.2}Mcyc/s",
                    w.addr,
                    if w.draining { "draining" } else { "alive" },
                    w.proto_version,
                    w.points,
                    w.simulated,
                    w.queue_depth,
                    w.inflight,
                    w.sim_cycles_per_sec / 1e6
                );
            } else {
                println!(
                    "    {:<21} DEAD{}",
                    w.addr,
                    if w.draining { " (draining)" } else { "" }
                );
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let rest = &args[1..];

    match cmd.as_str() {
        "list" => {
            println!("Table-I workloads:");
            for w in Workload::ALL {
                println!("  {:<8} smem={}", w.name(), if w.uses_smem() { "yes" } else { "no" });
            }
        }
        "config" => {
            let cfg = parse_cfg(rest);
            println!("{cfg:#?}");
            println!(
                "\npeak bank BW: {:.0} B/cycle   peak TSV BW: {:.0} B/cycle   ratio {:.1}x",
                cfg.peak_bank_bytes_per_cycle(),
                cfg.peak_tsv_bytes_per_cycle(),
                cfg.peak_bank_bytes_per_cycle() / cfg.peak_tsv_bytes_per_cycle()
            );
        }
        "run" => {
            let Some(name) = rest.first() else { usage() };
            let w = Workload::from_name(name).unwrap_or_else(|| usage());
            let cfg = parse_cfg(&rest[1..]);
            let scale = scale_of(rest);
            // `--machine <kind>` selects any frontend variant; `--gpu`
            // stays as a shorthand for `--machine gpu`.
            let mut kind = MachineKind::Mpu;
            if rest.iter().any(|a| a == "--gpu") {
                kind = MachineKind::Gpu;
            }
            let mut it = rest.iter();
            while let Some(a) = it.next() {
                if a == "--machine" {
                    let Some(k) = it.next().and_then(|v| MachineKind::from_name(v)) else {
                        eprintln!("--machine needs one of: mpu gpu ideal mpu_nooff");
                        std::process::exit(2);
                    };
                    kind = k;
                }
            }
            let target = Target::for_kind(kind, &cfg);
            let results = Sweep::new()
                .point(kind.name(), w, scale, target)
                .threads(usize_flag(rest, "--threads"))
                .run()?;
            let r = &results[0].report;
            match kind {
                MachineKind::Gpu | MachineKind::IdealBw => println!(
                    "{} {}: {} cycles, correct={} (max_err {:.2e}), {:.1} GB/s, {:.3} mJ",
                    kind.name().to_uppercase(),
                    w.name(),
                    r.cycles,
                    r.correct,
                    r.max_err,
                    r.dram_gbps(),
                    r.energy.total() * 1e3
                ),
                MachineKind::Mpu | MachineKind::MpuNoOffload => println!(
                    "{} {}: {} cycles, correct={} (max_err {:.2e}), near {:.0}%, {:.1} GB/s, rowmiss {:.1}%, {:.3} mJ",
                    kind.name().to_uppercase(),
                    w.name(),
                    r.cycles,
                    r.correct,
                    r.max_err,
                    r.stats.near_fraction() * 100.0,
                    r.dram_gbps(),
                    r.stats.row_miss_rate() * 100.0,
                    r.energy.total() * 1e3
                ),
            }
            if rest.iter().any(|a| a == "--loc-stats") {
                // Fig.-14 compile-time register-location breakdown.
                let ls = &r.loc_stats;
                println!(
                    "loc-stats {}: N={} F={} B={} U={} (near {:.1}% / far {:.1}% / both {:.1}%)",
                    w.name(),
                    ls.near,
                    ls.far,
                    ls.both,
                    ls.unknown,
                    ls.near_frac() * 100.0,
                    ls.far_frac() * 100.0,
                    ls.both_frac() * 100.0
                );
            }
        }
        "suite" => {
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            let with_variants = rest.iter().any(|a| a == "--variants");
            let strict = rest.iter().any(|a| a == "--strict");
            // Optional persistent tier: repeated suite invocations (any
            // process) skip already-simulated points via the store.
            if let Some(dir) = flag_value(rest, "--store") {
                let store = DiskStore::open(StoreConfig::new(dir))?;
                SimCache::global().attach_store(Arc::new(store));
            }
            let threads = usize_flag(rest, "--threads");
            let t0 = std::time::Instant::now();
            let pairs = run_suite_threaded(&cfg, scale, threads)?;
            let mut variants: Vec<(String, Vec<mpu::RunReport>)> = Vec::new();
            if with_variants {
                for kind in [MachineKind::IdealBw, MachineKind::MpuNoOffload] {
                    let runs = run_suite_kind_threaded(&cfg, scale, kind, threads)?;
                    variants.push((kind.name().to_string(), runs));
                }
            }
            let mut doc = suite_json_with_variants(scale, &pairs, &variants);
            let mut suite_stats = SuiteStats::from_cache(SimCache::global());
            for p in &pairs {
                suite_stats.record_run(&p.mpu);
                suite_stats.record_run(&p.gpu);
            }
            for (_, runs) in &variants {
                for r in runs {
                    suite_stats.record_run(r);
                }
            }
            doc.stats = Some(suite_stats);
            let mut t = Table::new("suite: MPU vs GPU", &["workload", "speedup", "energy_red", "ok"]);
            for p in &pairs {
                t.row(vec![
                    p.mpu.workload.name().into(),
                    f2(p.speedup()),
                    f2(p.energy_reduction()),
                    (p.mpu.correct && p.gpu.correct).to_string(),
                ]);
            }
            t.row(vec!["GEOMEAN".into(), f2(doc.geomean_speedup), f2(doc.geomean_energy_reduction), String::new()]);
            t.emit("suite");
            for v in &doc.variants {
                println!(
                    "variant {:<10} geomean speedup vs GPU: {:.2}x",
                    v.variant, v.geomean_speedup_vs_gpu
                );
            }
            let out = out_path(rest);
            write_suite_json(Path::new(&out), &doc)?;
            println!(
                "\nwrote {} ({} workloads, {} extra variants, geomean speedup {:.2}x) in {:.1}s",
                out,
                doc.workloads.len(),
                doc.variants.len(),
                doc.geomean_speedup,
                t0.elapsed().as_secs_f64()
            );
            if strict {
                anyhow::ensure!(all_correct(&doc), "suite has incorrect runs (see table above)");
            }
            if rest.iter().any(|a| a == "--perf") {
                // Simulator-throughput harness: re-simulate every
                // (variant × workload) point fresh and serially —
                // bypassing the caches and the rayon pool — so the
                // wall-times measure the simulator's hot loop itself.
                // With --repeat N each point is timed N times (after one
                // untimed warmup pass) and the median wall-ms recorded,
                // damping scheduler noise in the committed trajectory.
                let repeat = usize_flag(rest, "--repeat");
                let build = || {
                    let mut sw = Sweep::new();
                    for kind in MachineKind::ALL {
                        sw = sw.suite_kind(kind, scale, &cfg);
                    }
                    sw.fresh().serial()
                };
                let t0 = std::time::Instant::now();
                if repeat > 1 {
                    build().run()?; // warmup: touch every allocation path once
                }
                let mut passes = Vec::with_capacity(repeat);
                for _ in 0..repeat {
                    passes.push(build().run()?);
                }
                let mut results = passes.remove(0);
                for (i, r) in results.iter_mut().enumerate() {
                    let mut walls: Vec<f64> = std::iter::once(r.report.sim_wall_ms)
                        .chain(passes.iter().map(|p| p[i].report.sim_wall_ms))
                        .collect();
                    walls.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    let median = walls[(walls.len() - 1) / 2];
                    r.report.sim_wall_ms = median;
                    r.report.sim_cycles_per_sec = if median > 0.0 {
                        r.report.cycles as f64 / (median / 1e3)
                    } else {
                        0.0
                    };
                }
                let perf = simperf_json_repeated(scale, &results, true, true, repeat);
                let mut t = Table::new(
                    "simulator throughput (fresh, serial)",
                    &["variant", "workload", "cycles", "wall_ms", "Mcyc/s"],
                );
                for p in &perf.points {
                    t.row(vec![
                        p.variant.clone(),
                        p.workload.clone(),
                        p.cycles.to_string(),
                        format!("{:.2}", p.wall_ms),
                        format!("{:.2}", p.cycles_per_sec / 1e6),
                    ]);
                }
                t.emit("simperf");
                write_simperf_json(Path::new(SIMPERF_JSON), &perf)?;
                println!(
                    "wrote {} ({} points, sim {:.0} ms / harness {:.0} ms, geomean {:.2} Mcycles/s)",
                    SIMPERF_JSON,
                    perf.points.len(),
                    perf.total_wall_ms,
                    t0.elapsed().as_secs_f64() * 1e3,
                    perf.geomean_cycles_per_sec / 1e6
                );
            }
        }
        "cycles" => {
            // Golden cycle counts: exact per-workload cycles for every
            // machine variant — the timing contract the event-driven
            // simulator core must preserve. One simulation pass feeds
            // both flags: `--out` writes the golden, `--check` fails on
            // ANY drift vs an existing one (no tolerance: cycle counts
            // are deterministic). With neither flag, writes the default
            // file name.
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            let mut variants = serde_json::Map::new();
            for kind in MachineKind::ALL {
                let runs = run_suite_kind(&cfg, scale, kind)?;
                let mut per = serde_json::Map::new();
                for r in &runs {
                    per.insert(r.workload.name().to_string(), serde_json::json!(r.cycles));
                }
                variants.insert(kind.name().to_string(), serde_json::Value::Object(per));
            }
            let doc = serde_json::json!({
                "schema_version": 1,
                "suite": "cycles",
                "scale": scale.name(),
                "variants": serde_json::Value::Object(variants),
            });
            // One simulation pass serves both flags: write first (so a
            // drift failure still leaves the candidate file around for
            // committing/diffing), then check. With neither flag, write
            // the default name.
            let check = flag_value(rest, "--check");
            let out = match (flag_value(rest, "--out"), check.is_some()) {
                (Some(o), _) => Some(o),
                (None, false) => Some(format!("CYCLES_{}.json", scale.name())),
                (None, true) => None,
            };
            if let Some(out) = &out {
                let mut body = serde_json::to_string_pretty(&doc)?;
                body.push('\n');
                std::fs::write(out, body)?;
                let n: usize = doc["variants"]
                    .as_object()
                    .unwrap()
                    .values()
                    .map(|v| v.as_object().unwrap().len())
                    .sum();
                println!("wrote {out} ({n} (variant × workload) cycle counts at {} scale)", scale.name());
            }
            if let Some(golden_path) = check {
                let want: serde_json::Value =
                    serde_json::from_str(&std::fs::read_to_string(&golden_path)?)?;
                anyhow::ensure!(
                    want["scale"] == doc["scale"],
                    "scale mismatch: golden is {} but this run is {}",
                    want["scale"],
                    doc["scale"]
                );
                let mut drifts: Vec<String> = Vec::new();
                let empty = serde_json::Map::new();
                let want_vars = want["variants"].as_object().unwrap_or(&empty);
                let got_vars = doc["variants"].as_object().unwrap();
                for (variant, got_wls) in got_vars {
                    let Some(want_wls) = want_vars.get(variant).and_then(|v| v.as_object()) else {
                        drifts.push(format!("variant `{variant}` missing from golden"));
                        continue;
                    };
                    for (wl, got) in got_wls.as_object().unwrap() {
                        match want_wls.get(wl) {
                            Some(want_c) if want_c == got => {}
                            Some(want_c) => drifts.push(format!(
                                "{variant}/{wl}: golden {want_c} vs {got}"
                            )),
                            None => drifts.push(format!("{variant}/{wl}: missing from golden")),
                        }
                    }
                    for wl in want_wls.keys() {
                        if !got_wls.as_object().unwrap().contains_key(wl) {
                            drifts.push(format!("{variant}/{wl}: in golden but not simulated"));
                        }
                    }
                }
                for variant in want_vars.keys() {
                    if !got_vars.contains_key(variant) {
                        drifts.push(format!("variant `{variant}` in golden but not simulated"));
                    }
                }
                anyhow::ensure!(
                    drifts.is_empty(),
                    "cycle-count drift vs {golden_path} (timing is a contract — if the change is intentional, refresh the golden and say so in the PR):\n  {}",
                    drifts.join("\n  ")
                );
                let n: usize = got_vars.values().map(|v| v.as_object().unwrap().len()).sum();
                println!("{golden_path}: {n} (variant × workload) cycle counts exactly match");
            }
        }
        "lint" => {
            // Static kernel analysis over the Table-I workloads (or one
            // of them with --workload). Errors always fail; warnings fail
            // under `--deny warnings`.
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            if let Some(k) = flag_value(rest, "--machine") {
                // Linting is machine-independent (all variants share the
                // warp size), but validate the name for CLI consistency.
                if MachineKind::from_name(&k).is_none() {
                    eprintln!("--machine needs one of: mpu gpu ideal mpu_nooff");
                    std::process::exit(2);
                }
            }
            let deny_warnings = match flag_value(rest, "--deny").as_deref() {
                None => false,
                Some("warnings") => true,
                Some(other) => {
                    eprintln!("--deny only supports `warnings`, got `{other}`");
                    std::process::exit(2);
                }
            };
            let which: Vec<Workload> = match flag_value(rest, "--workload") {
                Some(name) => {
                    vec![Workload::from_name(&name).unwrap_or_else(|| {
                        eprintln!("unknown workload `{name}` (see `mpu list`)");
                        std::process::exit(2);
                    })]
                }
                None => Workload::ALL.to_vec(),
            };
            let mut wls = Vec::new();
            for w in which {
                wls.push(lint_workload(w, scale, cfg.warp_size)?);
            }
            let report = LintReport::new(scale, wls);
            let json = rest.iter().any(|a| a == "--json");
            if json {
                println!("{}", serde_json::to_string_pretty(&report)?);
            } else {
                for wl in &report.workloads {
                    for d in &wl.lint.diagnostics {
                        println!(
                            "{}:{}: {}[{}] {}\n    {}",
                            wl.lint.kernel, d.pc, d.severity, d.code, d.message, d.instr
                        );
                    }
                }
                println!(
                    "lint: {} workload(s), {} error(s), {} warning(s), {} info(s)",
                    report.workloads.len(),
                    report.errors,
                    report.warnings,
                    report.infos
                );
            }
            if let Some(out) = flag_value(rest, "--out") {
                let mut body = serde_json::to_string_pretty(&report)?;
                body.push('\n');
                std::fs::write(&out, body)?;
                println!("wrote {out}");
            }
            if report.errors > 0 || (deny_warnings && report.warnings > 0) {
                std::process::exit(1);
            }
        }
        "check-json" if rest.first().map(|a| a == "--compare-perf").unwrap_or(false) => {
            let (Some(old), Some(new)) = (rest.get(1), rest.get(2)) else {
                eprintln!("check-json --compare-perf needs <baseline> <candidate>");
                std::process::exit(2);
            };
            compare_perf_docs(old, new)?;
            println!("{new}: no simulator-throughput regressions over 20% vs {old}");
        }
        "check-json" if rest.first().map(|a| a == "--compare").unwrap_or(false) => {
            let (Some(old), Some(new)) = (rest.get(1), rest.get(2)) else {
                eprintln!("check-json --compare needs <baseline> <candidate>");
                std::process::exit(2);
            };
            compare_docs(old, new)?;
            println!("{new}: no cycle regressions over 5% vs {old}");
        }
        "check-json" => {
            let Some(path) = rest.first() else { usage() };
            let body = std::fs::read_to_string(path)?;
            let v: serde_json::Value = serde_json::from_str(&body)?;
            if v["report"] == "tune" {
                let n = check_tune_doc(&v)?;
                println!("{path}: tune schema v1 OK, {n} workloads tuned, none worse than annotated");
                return Ok(());
            }
            if v["report"] == "metrics" {
                let (clients, workers) = check_metrics_doc(&v)?;
                println!(
                    "{path}: metrics schema v{METRICS_SCHEMA_VERSION} OK \
                     ({clients} client lanes, {workers} workers)"
                );
                return Ok(());
            }
            anyhow::ensure!(v["schema_version"] == 1, "schema_version must be 1");
            for key in ["suite", "scale", "geomean_speedup", "geomean_energy_reduction"] {
                anyhow::ensure!(!v[key].is_null(), "missing key `{key}`");
            }
            let workloads = v["workloads"].as_array().ok_or_else(|| anyhow::anyhow!("missing workloads"))?;
            anyhow::ensure!(
                workloads.len() == Workload::ALL.len(),
                "expected {} workloads, found {}",
                Workload::ALL.len(),
                workloads.len()
            );
            let mut checked = 0usize;
            for w in workloads {
                for col in ["mpu", "gpu"] {
                    anyhow::ensure!(
                        w[col]["correct"] == true,
                        "workload {} incorrect on {}",
                        w["workload"],
                        col
                    );
                    checked += 1;
                }
            }
            if let Some(variants) = v["variants"].as_array() {
                for var in variants {
                    let Some(ws) = var["workloads"].as_array() else { continue };
                    for w in ws {
                        anyhow::ensure!(
                            w["entry"]["correct"] == true,
                            "workload {} incorrect on variant {}",
                            w["workload"],
                            var["variant"]
                        );
                        checked += 1;
                    }
                }
            }
            if !v["tuning"].is_null() {
                let n = check_tuning_appendix(&v["tuning"])?;
                println!("{path}: tuning appendix OK ({n} workloads, none worse than annotated)");
            }
            println!("{path}: schema v1 OK, {checked} machine runs all correct");
        }
        "serve" => {
            let cfg = serve_cfg(rest);
            // Deterministic fault injection (chaos testing): --faults /
            // MPU_FAULTS arms the process-wide fault plane before any
            // socket or store is touched.
            if let Some(spec) = &cfg.faults {
                let plan = FaultPlan::parse(spec)?;
                if !plan.is_empty() {
                    println!("mpu serve: fault injection ACTIVE ({spec})");
                }
                fault::activate(plan);
            }
            let timeouts = Timeouts { connect: cfg.connect_timeout, io: cfg.io_timeout };
            let retry = RetryPolicy {
                attempts: cfg.retries,
                base_delay: cfg.backoff,
                ..RetryPolicy::default()
            };
            if !cfg.workers.is_empty() {
                // Coordinator mode: no local simulation — submits are
                // sharded across the worker daemons by consistent
                // hashing on the stable store keys.
                let fed = Federation::with_config(cfg.workers.clone(), timeouts, retry)?;
                let reachable = fed.handshake()?;
                let n = fed.workers().len();
                let co = Arc::new(Coordinator::new(fed));
                let server = SweepServer::bind_coordinator(co, &cfg.addr)?;
                println!(
                    "mpu serve: coordinating {n} workers ({reachable} reachable) on {}",
                    server.addr()
                );
                server.run()?;
                println!("mpu serve: shut down");
                return Ok(());
            }
            let no_store = rest.iter().any(|a| a == "--no-store");
            let store_dir = cfg.store_dir.clone().filter(|_| !no_store);
            let store = match &store_dir {
                Some(dir) => Some(DiskStore::open(
                    StoreConfig::new(dir).max_bytes(cfg.store_max_bytes),
                )?),
                None => None,
            };
            let svc = Arc::new(Service::new(store));
            svc.set_max_queue(cfg.max_queue);
            svc.set_max_client_queue(cfg.max_client_queue);
            svc.set_client_weights(cfg.client_weights.clone());
            let server = SweepServer::bind(svc, &cfg.addr)?;
            let self_addr = server.addr().to_string();
            match &store_dir {
                Some(dir) => println!(
                    "mpu serve: listening on {self_addr} (store {}, cap {} MiB)",
                    dir.display(),
                    cfg.store_max_bytes / (1024 * 1024)
                ),
                None => println!("mpu serve: listening on {self_addr} (no store)"),
            }
            // Hot self-registration: join the coordinator once our
            // accept loop is live (it handshakes us back, so the join
            // retries until the first accept), drain on shutdown so
            // new points remap to the survivors without a restart.
            if let Some(co) = cfg.coordinator.clone() {
                let me = self_addr.clone();
                std::thread::spawn(move || {
                    let client = proto::Client::new(co.clone());
                    for attempt in 1u32..=20 {
                        match client.join(&me) {
                            Ok(fleet) => {
                                println!(
                                    "mpu serve: joined coordinator {co} ({} workers)",
                                    fleet.len()
                                );
                                return;
                            }
                            Err(e) if attempt == 20 => {
                                eprintln!("mpu serve: joining coordinator {co} failed: {e}");
                            }
                            Err(_) => std::thread::sleep(std::time::Duration::from_millis(250)),
                        }
                    }
                });
            }
            server.run()?;
            if let Some(co) = &cfg.coordinator {
                match proto::Client::new(co.clone()).drain(&self_addr) {
                    Ok(_) => println!("mpu serve: drained from coordinator {co}"),
                    Err(e) => eprintln!("mpu serve: drain from coordinator {co} failed: {e}"),
                }
            }
            println!("mpu serve: shut down");
        }
        "submit" => {
            let cfg = serve_cfg(rest);
            let mut suite = false;
            let mut workloads: Vec<String> = Vec::new();
            for a in positionals(rest) {
                if a == "suite" {
                    suite = true;
                } else {
                    workloads.push(a);
                }
            }
            let variants = flag_value(rest, "--variants")
                .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
                .unwrap_or_else(|| vec!["mpu".to_string(), "gpu".to_string()]);
            let priority = flag_value(rest, "--priority")
                .map(|v| {
                    v.parse::<i32>().unwrap_or_else(|_| {
                        eprintln!("--priority needs an integer, got `{v}`");
                        std::process::exit(2);
                    })
                })
                .unwrap_or(0);
            let config: Vec<(String, String)> = config_pairs(rest);
            let stream = rest.iter().any(|a| a == "--stream");
            if let Some(spec) = &cfg.faults {
                fault::activate(FaultPlan::parse(spec)?);
            }
            let timeouts = Timeouts { connect: cfg.connect_timeout, io: cfg.io_timeout };
            let retry = RetryPolicy {
                attempts: cfg.retries,
                base_delay: cfg.backoff,
                ..RetryPolicy::default()
            };
            let req = SubmitRequest {
                suite,
                workloads,
                scale: scale_of(rest).name().to_string(),
                variants,
                config,
                priority,
                fresh: rest.iter().any(|a| a == "--fresh"),
                stream,
                ..SubmitRequest::default()
            };
            // Precedence: an explicit --workers federates; an explicit
            // --addr talks to that daemon (even with MPU_WORKERS set —
            // the addressed daemon may itself be the coordinator); only
            // with neither flag does MPU_WORKERS federate client-side.
            let fed_workers = match flag_value(rest, "--workers") {
                Some(v) => ServeConfig::parse_workers(&v),
                None if flag_value(rest, "--addr").is_none() => cfg.workers.clone(),
                None => vec![],
            };
            let reply = if !fed_workers.is_empty() {
                // Client-side federation (--workers or MPU_WORKERS):
                // shard the batch across the worker fleet directly, no
                // coordinator daemon needed. A storeless local service
                // backstops total fleet death (degraded mode).
                let mut fed = Federation::with_config(fed_workers, timeouts, retry)?;
                fed.set_fallback(Arc::new(Service::new(None)));
                fed.handshake()?;
                let fr = fed.submit_streamed(&req, |ev| {
                    if stream {
                        if let FedEvent::Progress { completed, total, elapsed_ms } = ev {
                            eprintln!("progress: {completed}/{total} ({elapsed_ms} ms)");
                        }
                    }
                })?;
                fr.reply
            } else if stream {
                // Streamed submits ride the resilient path: socket
                // deadlines, bounded backoff on transient failures, and
                // a request id so retries dedup onto the in-flight job.
                let client = client_from(&cfg, true);
                match client.submit_resilient(&req, |resp| {
                    if let Response::Progress(p) = resp {
                        eprintln!(
                            "progress: {}/{} ({} ms)",
                            p.completed, p.total, p.elapsed_ms
                        );
                    }
                })? {
                    StreamOutcome::Done(reply) => reply,
                    StreamOutcome::ServerError(m) => anyhow::bail!("server error: {m}"),
                    StreamOutcome::Busy { retry_after_ms } => anyhow::bail!(
                        "server busy (queue full) after retries; retry after {retry_after_ms} ms"
                    ),
                }
            } else {
                // Blocking interactive submit: no socket deadline (a
                // cold batch legitimately simulates for minutes).
                match client_from(&cfg, false).submit(&req)? {
                    Response::Done(reply) => reply,
                    Response::Error { message } => anyhow::bail!("server error: {message}"),
                    Response::Busy { retry_after_ms } => {
                        anyhow::bail!("server busy, retry after {retry_after_ms} ms")
                    }
                    _ => anyhow::bail!("unexpected response to submit"),
                }
            };
            let mut t =
                Table::new("submitted batch", &["label", "workload", "cycles", "ok", "source"]);
            for r in &reply.results {
                t.row(vec![
                    r.label.clone(),
                    r.workload.clone(),
                    r.cycles.to_string(),
                    r.correct.to_string(),
                    r.source.clone(),
                ]);
            }
            t.emit("submit");
            // Stable machine-greppable summary (the CI smoke gate parses
            // `simulated=` and `disk=`).
            let degraded_note = if reply.degraded { " degraded=1" } else { "" };
            println!(
                "submit: points={} simulated={} cached={} (mem={} disk={} dedup={}) in {}ms{}",
                reply.points,
                reply.simulated,
                reply.cached(),
                reply.mem_hits,
                reply.disk_hits,
                reply.deduped,
                reply.elapsed_ms,
                degraded_note
            );
            if rest.iter().any(|a| a == "--strict") {
                let bad: Vec<&str> = reply
                    .results
                    .iter()
                    .filter(|r| !r.correct)
                    .map(|r| r.workload.as_str())
                    .collect();
                anyhow::ensure!(bad.is_empty(), "incorrect runs: {}", bad.join(", "));
            }
        }
        "status" => {
            let cfg = serve_cfg(rest);
            let client = client_from(&cfg, true);
            if rest.iter().any(|a| a == "--watch") {
                let interval = flag_value(rest, "--interval-ms")
                    .map(|v| {
                        v.parse::<u64>().ok().filter(|&n| n >= 1).unwrap_or_else(|| {
                            eprintln!("--interval-ms needs a positive integer, got `{v}`");
                            std::process::exit(2);
                        })
                    })
                    .unwrap_or(1000);
                // Live metrics view: rerender until interrupted. A
                // fetch error is one stale frame, not an exit — the
                // daemon may be restarting.
                loop {
                    match client.metrics() {
                        Ok(m) => {
                            print!("\x1b[2J\x1b[H");
                            print_metrics(client.addr(), &m);
                            println!("\n(watching every {interval} ms — ctrl-c to stop)");
                        }
                        Err(e) => println!("metrics fetch failed: {e}"),
                    }
                    use std::io::Write as _;
                    std::io::stdout().flush().ok();
                    std::thread::sleep(std::time::Duration::from_millis(interval));
                }
            }
            let addr = client.addr().to_string();
            let s = client.status()?;
            println!("mpu daemon at {addr} (proto v{})", s.proto_version);
            println!("  uptime          {:.1}s", s.uptime_ms as f64 / 1e3);
            println!("  requests        {}", s.requests);
            println!("  points          {}", s.points);
            println!("  simulated       {}", s.simulated);
            println!("  mem hits        {}", s.mem_hits);
            println!("  disk hits       {}", s.disk_hits);
            println!("  dedup waits     {}", s.dedup_waits);
            println!("  kernels         {}", s.kernels_compiled);
            println!("  mem entries     {}", s.mem_entries);
            println!("  queue depth     {}", s.queue_depth);
            println!("  queue-limit     {}", s.queue_limit);
            println!("  in flight       {}", s.inflight);
            println!("  active submits  {}", s.active_requests);
            println!("  rejected        {}", s.admission_rejected);
            println!("  retries         {}", s.retries);
            println!("  degraded        {}", s.degraded_batches);
            match &s.store {
                Some(st) => println!(
                    "  store           {} entries, {}/{} KiB, hits={} misses={} evictions={} corrupt_dropped={} write_failures={} quarantined={}{}",
                    st.entries,
                    st.bytes / 1024,
                    st.max_bytes / 1024,
                    st.hits,
                    st.misses,
                    st.evictions,
                    st.corrupt_dropped,
                    st.write_failures,
                    st.quarantined,
                    if st.degraded { " DEGRADED" } else { "" }
                ),
                None => println!("  store           (none)"),
            }
            if let Some(workers) = &s.workers {
                println!("  workers ({}):", workers.len());
                for w in workers {
                    if w.alive {
                        println!(
                            "    {:<21} alive  proto v{}  points={} simulated={} queue={} inflight={}",
                            w.addr, w.proto_version, w.points, w.simulated, w.queue_depth, w.inflight
                        );
                    } else {
                        println!("    {:<21} DEAD", w.addr);
                    }
                }
            }
        }
        "metrics" => {
            let cfg = serve_cfg(rest);
            let client = client_from(&cfg, true);
            let m = client.metrics()?;
            match flag_value(rest, "--out") {
                Some(out) => {
                    let mut body = serde_json::to_string_pretty(&m)?;
                    body.push('\n');
                    std::fs::write(&out, body)?;
                    println!(
                        "wrote {out} (metrics schema v{}, {} clients, {} workers)",
                        m.schema_version,
                        m.clients.len(),
                        m.workers.len()
                    );
                }
                None => print_metrics(client.addr(), &m),
            }
        }
        "fleet" => {
            let pos = positionals(rest);
            let (Some(action), Some(worker)) = (pos.first(), pos.get(1)) else {
                eprintln!("mpu fleet needs an action and a worker: fleet {{join|drain}} H:P [--addr COORDINATOR]");
                std::process::exit(2);
            };
            let cfg = serve_cfg(rest);
            let client = client_from(&cfg, true);
            let fleet = match action.as_str() {
                "join" => client.join(worker)?,
                "drain" => client.drain(worker)?,
                other => {
                    eprintln!("unknown fleet action `{other}` (join | drain)");
                    std::process::exit(2);
                }
            };
            println!("fleet at {} ({} workers):", client.addr(), fleet.len());
            for w in &fleet {
                println!("  {:<21} {}", w.addr, if w.draining { "draining" } else { "active" });
            }
        }
        "shutdown" => {
            let cfg = serve_cfg(rest);
            let client = client_from(&cfg, true);
            client.shutdown()?;
            println!("mpu daemon at {} stopped", client.addr());
        }
        "store" => {
            // Daemonless store maintenance: stats + the beyond-LRU GC
            // (eager schema sweeps, age expiry, index compaction).
            let env = ServeConfig::from_env();
            let Some(action) = rest.first().map(|s| s.as_str()) else {
                eprintln!("mpu store needs an action: stats | gc");
                std::process::exit(2);
            };
            let dir = flag_value(rest, "--store")
                .map(std::path::PathBuf::from)
                .or(env.store_dir)
                .expect("store dir always defaults");
            let store =
                DiskStore::open(StoreConfig::new(dir.clone()).max_bytes(env.store_max_bytes))?;
            match action {
                "stats" => {
                    let st = store.stats();
                    println!(
                        "store {}: entries={} bytes={} KiB (cap {} KiB)",
                        dir.display(),
                        st.entries,
                        st.bytes / 1024,
                        st.max_bytes / 1024
                    );
                    println!(
                        "  hits={} misses={} evictions={} corrupt_dropped={} quarantined={}",
                        st.hits, st.misses, st.evictions, st.corrupt_dropped, st.quarantined
                    );
                }
                "gc" => {
                    let max_age = flag_value(rest, "--max-age-days").map(|v| {
                        // 100 years caps the product well under the
                        // Duration::from_secs_f64 panic threshold.
                        let days = v
                            .parse::<f64>()
                            .ok()
                            .filter(|d| d.is_finite() && (0.0..=36_500.0).contains(d))
                            .unwrap_or_else(|| {
                                eprintln!(
                                    "--max-age-days needs a number in [0, 36500], got `{v}`"
                                );
                                std::process::exit(2);
                            });
                        std::time::Duration::from_secs_f64(days * 86_400.0)
                    });
                    let max_bytes = flag_value(rest, "--max-mb").map(|v| {
                        let mb = v.parse::<u64>().unwrap_or_else(|_| {
                            eprintln!("--max-mb needs an integer, got `{v}`");
                            std::process::exit(2);
                        });
                        mb * 1024 * 1024
                    });
                    let rep = store.gc(&GcOptions { max_age, max_bytes })?;
                    println!(
                        "store gc {}: scanned={} stale_dropped={} expired={} evicted={} \
                         dangling_dropped={} kept={} ({} KiB)",
                        dir.display(),
                        rep.scanned,
                        rep.stale_dropped,
                        rep.expired,
                        rep.evicted,
                        rep.dangling_dropped,
                        rep.kept,
                        rep.kept_bytes / 1024
                    );
                }
                other => {
                    eprintln!("unknown store action `{other}` (stats | gc)");
                    std::process::exit(2);
                }
            }
        }
        "tune" => {
            // Offload-policy autotuner: each candidate policy table is
            // just another config fingerprint, so --store / --workers
            // dedup its evaluation through the usual cache tiers.
            let scale = scale_of(rest);
            let mut workloads: Vec<Workload> = Vec::new();
            let mut names = positionals(rest);
            if let Some(name) = flag_value(rest, "--workload") {
                names.push(name);
            }
            for name in names {
                let w = Workload::from_name(&name).unwrap_or_else(|| {
                    eprintln!("unknown workload `{name}` (see `mpu list`)");
                    std::process::exit(2);
                });
                if !workloads.contains(&w) {
                    workloads.push(w);
                }
            }
            if rest.iter().any(|a| a == "--all") || workloads.is_empty() {
                workloads = Workload::ALL.to_vec();
            }
            let defaults = TuneOptions::default();
            let budget = flag_value(rest, "--budget")
                .map(|v| {
                    v.parse::<usize>().ok().filter(|&n| n >= 1).unwrap_or_else(|| {
                        eprintln!("--budget needs a positive integer, got `{v}`");
                        std::process::exit(2);
                    })
                })
                .unwrap_or(defaults.budget);
            let seed = flag_value(rest, "--seed")
                .map(|v| {
                    v.parse::<u64>().unwrap_or_else(|_| {
                        eprintln!("--seed needs an unsigned integer, got `{v}`");
                        std::process::exit(2);
                    })
                })
                .unwrap_or(defaults.seed);
            let workers = flag_value(rest, "--workers")
                .map(|v| ServeConfig::parse_workers(&v))
                .unwrap_or_default();
            if let Some(dir) = flag_value(rest, "--store") {
                let store = DiskStore::open(StoreConfig::new(dir))?;
                SimCache::global().attach_store(Arc::new(store));
            }
            let base_overrides: Vec<(String, String)> = config_pairs(rest);
            let opts = TuneOptions {
                workloads,
                scale,
                budget,
                seed,
                threads: usize_flag(rest, "--threads"),
                workers,
                base_overrides,
            };
            let t0 = std::time::Instant::now();
            let report = tuner::tune(&opts, SimCache::global())?;
            let mut t = Table::new(
                "tune: explicit policy vs baselines",
                &["workload", "pcs", "mode", "tuned", "annotated", "speedup", "vs_hw", "vs_nooff"],
            );
            for w in &report.workloads {
                t.row(vec![
                    w.workload.clone(),
                    w.candidate_pcs.to_string(),
                    w.search_mode.clone(),
                    w.tuned_cycles.to_string(),
                    w.annotated_cycles.to_string(),
                    format!("{:.3}x", w.speedup_vs_annotated),
                    format!("{:.3}x", w.speedup_vs_hw_default),
                    format!("{:.3}x", w.speedup_vs_nooff),
                ]);
            }
            t.emit("tune");
            let out = flag_value(rest, "--out").unwrap_or_else(|| tuner::TUNE_REPORT.to_string());
            let mut body = serde_json::to_string_pretty(&report)?;
            body.push('\n');
            std::fs::write(&out, body)?;
            println!(
                "wrote {} ({} workloads, geomean speedup vs annotated {:.3}x) in {:.1}s",
                out,
                report.workloads.len(),
                report.geomean_speedup_vs_annotated,
                t0.elapsed().as_secs_f64()
            );
            // Stable machine-greppable summary (the CI smoke gate parses
            // `simulated=`).
            println!(
                "tune: workloads={} evaluations={} simulated={} cached={} (mem={} disk={}) geomean_speedup={:.4}",
                report.workloads.len(),
                report.evaluations,
                report.simulated,
                report.mem_hits + report.disk_hits,
                report.mem_hits,
                report.disk_hits,
                report.geomean_speedup_vs_annotated
            );
            if let Some(suite_path) = flag_value(rest, "--append-suite") {
                // Append-only by construction: the suite doc is parsed
                // as a generic JSON value, only the `tuning` key is
                // (re)placed, every other field survives byte-for-byte.
                let body = std::fs::read_to_string(&suite_path)?;
                let mut doc: serde_json::Value = serde_json::from_str(&body)?;
                anyhow::ensure!(
                    doc["schema_version"] == 1,
                    "{suite_path}: not a schema-v1 suite document"
                );
                doc["tuning"] = serde_json::to_value(report.appendix())?;
                let mut body = serde_json::to_string_pretty(&doc)?;
                body.push('\n');
                std::fs::write(&suite_path, body)?;
                println!("appended tuning appendix to {suite_path}");
            }
        }
        "compile" => {
            let Some(name) = rest.first() else { usage() };
            let w = Workload::from_name(name).unwrap_or_else(|| usage());
            let k = KernelCache::new().get(w, true)?;
            for (pc, i) in k.instrs.iter().enumerate() {
                println!("{pc:>4}  {:?}  {}", i.loc, i);
            }
            println!(
                "\nregisters: N {} / F {} / B {}; near pool {} regs, far pool {} regs",
                k.loc_stats.near,
                k.loc_stats.far,
                k.loc_stats.both,
                k.pools.near[0] + k.pools.near[1],
                k.pools.far[0] + k.pools.far[1]
            );
        }
        "validate" => {
            let cfg = parse_cfg(rest);
            let scale = scale_of(rest);
            anyhow::ensure!(artifacts_available(scale), "artifacts missing: run `make artifacts`");
            let golden = XlaGolden::new()?;
            for w in Workload::ALL {
                let mut m = mpu::core::Machine::new(&cfg);
                let p = prepare(w, scale, &mut m)?;
                let k = compile_for(&p, &cfg)?;
                m.launch(k, p.launch, &p.params, p.home_fn())?;
                m.run()?;
                let out = m.read_f32s(p.out_addr, p.out_len);
                let v = validate_against_xla(&golden, &p, scale, &out)?;
                println!(
                    "{:>8}: {} (max_err {:.2e})",
                    w.name(),
                    if v.passed { "OK" } else { "MISMATCH" },
                    v.max_err
                );
                anyhow::ensure!(v.passed, "{} diverged from the XLA golden", w.name());
            }
        }
        _ => usage(),
    }
    Ok(())
}
