//! Table rendering for bench harnesses: fixed-width text tables on
//! stdout plus TSV files under `reports/` for plotting.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

/// A simple column-aligned table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }

    /// Print to stdout and save as TSV under `reports/<name>.tsv`.
    pub fn emit(&self, name: &str) {
        print!("{}", self.render());
        let dir = Path::new("reports");
        let _ = fs::create_dir_all(dir);
        let mut tsv = String::new();
        let _ = writeln!(tsv, "{}", self.headers.join("\t"));
        for r in &self.rows {
            let _ = writeln!(tsv, "{}", r.join("\t"));
        }
        let _ = fs::write(dir.join(format!("{name}.tsv")), tsv);
    }
}

/// Format helpers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f1pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("demo", &["wl", "speedup"]);
        t.row(vec!["axpy".into(), "3.46".into()]);
        t.row(vec!["nw".into(), "1.10".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("axpy"));
        assert!(s.contains("3.46"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn format_helpers() {
        assert_eq!(f2(3.456), "3.46");
        assert_eq!(f1pct(0.559), "55.9%");
    }
}
