//! Parallel sweep engine — the repo's hottest path (running experiments)
//! made parallel, reusable and incremental.
//!
//! A [`Sweep`] is an ordered set of (variant label × workload × scale ×
//! target machine) points. [`Sweep::run`] compiles each distinct kernel
//! once into a shared [`KernelCache`], fans the independent simulations
//! out across threads with rayon, and returns [`SweepResult`]s in point
//! order. Because the simulator is deterministic, finished points are
//! also memoized in a process-wide [`SimCache`] keyed on
//! `(workload, scale, machine-variant, config-hash)` — repeated `Sweep`
//! invocations in one process (benches iterating on labels, tests,
//! long-lived drivers) skip already-simulated points entirely. Use
//! [`Sweep::fresh`] to force re-simulation.
//!
//! The CLI, every `fig*` bench and the examples build their experiments
//! on top of this instead of hand-rolled serial loops.

use super::store::DiskStore;
use super::{check, PairReport, RunReport};
use crate::compiler::{compile_with, DecodedKernel};
use crate::config::{GpuConfig, IdealConfig, MachineConfig, MachineKind, SmemLocation};
use crate::core::Machine;
use crate::energy::{gpu_energy, mpu_energy};
use crate::gpu::{GpuMachine, IdealMachine};
use crate::workloads::{prepare, Scale, SizeOnlyDev, Workload};
use anyhow::Result;
use rayon::prelude::*;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Stable 64-bit FNV-1a. The configuration fingerprints feed the
/// on-disk result store's keys, so they must not depend on the std
/// hasher (which is allowed to change between Rust releases and is
/// randomized in some configurations). The federation's consistent-hash
/// ring ([`super::federation`]) reuses it so point placement is stable
/// across processes and releases too.
pub fn stable_hash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Canonical serialization a configuration is fingerprinted through.
fn ser_cfg<T: serde::Serialize>(v: &T) -> String {
    serde_json::to_string(v).expect("machine configurations serialize")
}

/// Target machine of a sweep point.
#[derive(Clone, Debug)]
pub enum Target {
    /// The MPU machine under a configuration variant.
    Mpu(MachineConfig),
    /// The GPU baseline; the `MachineConfig` keeps compilation (shared
    /// memory placement) consistent with the MPU variant it is compared
    /// against.
    Gpu(GpuConfig, MachineConfig),
    /// The ideal-bandwidth roofline machine (same compilation-consistency
    /// convention as `Gpu`).
    Ideal(IdealConfig, MachineConfig),
}

impl Target {
    /// Build the target for a [`MachineKind`] relative to an MPU
    /// configuration (the `mpu suite --variants` primitive).
    pub fn for_kind(kind: MachineKind, cfg: &MachineConfig) -> Target {
        match kind {
            MachineKind::Mpu => Target::Mpu(cfg.clone()),
            MachineKind::Gpu => Target::Gpu(GpuConfig::matched(cfg), cfg.clone()),
            MachineKind::IdealBw => Target::Ideal(IdealConfig::matched(cfg), cfg.clone()),
            MachineKind::MpuNoOffload => Target::Mpu(cfg.no_offload()),
        }
    }

    /// Whether this target compiles kernels for near-bank shared memory
    /// (the kernel-cache key alongside the workload).
    pub fn smem_near(&self) -> bool {
        let cfg = match self {
            Target::Mpu(c) => c,
            Target::Gpu(_, c) => c,
            Target::Ideal(_, c) => c,
        };
        cfg.smem_location == SmemLocation::NearBank
    }

    /// Stable variant discriminant + configuration fingerprint: FNV-1a
    /// over the serde-JSON rendering of the configuration(s). Field
    /// names are part of the serialization, so adding or changing any
    /// knob still produces a new cache key, while — unlike the former
    /// `DefaultHasher`-over-`Debug` fingerprint — the key no longer
    /// shifts with std hasher or `Debug`-format changes across Rust
    /// releases (the ROADMAP's "store entries silently go cold" item).
    fn fingerprint(&self) -> (&'static str, u64) {
        let (kind, repr) = match self {
            Target::Mpu(c) => ("mpu", ser_cfg(c)),
            Target::Gpu(g, c) => ("gpu", format!("{}|{}", ser_cfg(g), ser_cfg(c))),
            Target::Ideal(i, c) => ("ideal", format!("{}|{}", ser_cfg(i), ser_cfg(c))),
        };
        (kind, stable_hash(&repr))
    }
}

/// One simulation of the sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Variant label, e.g. `"mpu"`, `"gpu"`, `"rowbuf=4"`.
    pub label: String,
    pub workload: Workload,
    pub scale: Scale,
    pub target: Target,
}

impl SweepPoint {
    /// Stable content-addressed cache key of this point — the string
    /// form of the [`SimCache`] key, used as the on-disk store's entry
    /// name and the sweep service's dedup key. Labels are *not* part of
    /// it: two labels over the same configuration share one entry.
    pub fn cache_key(&self) -> String {
        let (kind, cfg_hash) = self.target.fingerprint();
        format!("{}-{}-{}-{:016x}", self.workload.name(), self.scale.name(), kind, cfg_hash)
    }

    /// Compile (through `cache`) and simulate this point — the single
    /// target-dispatch site shared by [`Sweep::run_with_cache`] and the
    /// sweep service.
    pub fn simulate(&self, cache: &KernelCache) -> Result<RunReport> {
        self.simulate_with_threads(cache, 1)
    }

    /// [`SweepPoint::simulate`] with the machine's issue phase sharded
    /// across `threads` workers (bit-identical results for any value —
    /// the sim cache can stay keyed on configuration alone).
    pub fn simulate_with_threads(&self, cache: &KernelCache, threads: usize) -> Result<RunReport> {
        let kernel = cache.get(self.workload, self.target.smem_near())?;
        match &self.target {
            Target::Mpu(cfg) => run_mpu_with(self.workload, cfg, self.scale, kernel, threads),
            Target::Gpu(gcfg, _) => run_gpu_with(self.workload, gcfg, self.scale, kernel, threads),
            Target::Ideal(icfg, _) => {
                run_ideal_with(self.workload, icfg, self.scale, kernel, threads)
            }
        }
    }
}

/// Result of one sweep point (returned in point order).
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub label: String,
    pub scale: Scale,
    pub report: RunReport,
}

/// Compile a workload's kernel without touching a real device (the
/// kernel text depends only on the workload, not the problem scale),
/// pre-decoded into its macro-op form.
pub fn compile_kernel(w: Workload, smem_near: bool) -> Result<Arc<DecodedKernel>> {
    let mut dev = SizeOnlyDev::default();
    let p = prepare(w, Scale::Tiny, &mut dev)?;
    Ok(Arc::new(DecodedKernel::new(compile_with(&p.kernel, smem_near)?)))
}

/// Shared compile cache: each (workload, smem placement) kernel is
/// compiled *and decoded* exactly once per sweep; runners borrow the
/// same macro-op array through the `Arc`.
#[derive(Default)]
pub struct KernelCache {
    map: Mutex<HashMap<(Workload, bool), Arc<DecodedKernel>>>,
}

impl KernelCache {
    pub fn new() -> KernelCache {
        KernelCache::default()
    }

    /// Decoded kernel for a workload under a shared-memory placement.
    /// Compilation happens under the lock so a cold key is compiled
    /// exactly once even when a parallel sweep starts on an empty cache
    /// (compiling is microseconds against the simulations it feeds).
    pub fn get(&self, w: Workload, smem_near: bool) -> Result<Arc<DecodedKernel>> {
        let mut map = self.map.lock().unwrap();
        if let Some(k) = map.get(&(w, smem_near)) {
            return Ok(Arc::clone(k));
        }
        let k = compile_kernel(w, smem_near)?;
        map.insert((w, smem_near), Arc::clone(&k));
        Ok(k)
    }

    /// Number of distinct kernels compiled so far.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Cache key of one simulated point: workload × scale × machine-variant
/// discriminant × configuration hash.
type SimKey = (Workload, Scale, &'static str, u64);

/// Which tier served a point (see [`SimCache::get_or_run_traced`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheTier {
    /// In-process memoization hit.
    Memory,
    /// Served from the persistent on-disk store.
    Disk,
    /// Actually simulated.
    Simulated,
}

/// Process-wide simulation-result cache (the ROADMAP's incremental
/// re-runs). The simulator is deterministic, so a memoized
/// [`RunReport`] is indistinguishable from a fresh run; labels are
/// *not* part of the key, so the same configuration under two sweep
/// labels simulates once.
///
/// Two tiers: the in-process map, and — once a [`DiskStore`] is
/// attached — the persistent on-disk store, which survives process
/// restarts (warm results in milliseconds across CLI invocations and
/// daemon restarts). Disk hits are promoted into the memory tier;
/// simulations are written through to both.
#[derive(Default)]
pub struct SimCache {
    map: Mutex<HashMap<SimKey, RunReport>>,
    hits: AtomicU64,
    disk_hits: AtomicU64,
    disk: OnceLock<Arc<DiskStore>>,
}

impl SimCache {
    pub fn new() -> SimCache {
        SimCache::default()
    }

    /// The process-wide cache used by [`Sweep::run`].
    pub fn global() -> &'static SimCache {
        static GLOBAL: OnceLock<SimCache> = OnceLock::new();
        GLOBAL.get_or_init(SimCache::default)
    }

    /// Cached points.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Memory-tier cache hits served so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Disk-tier hits served so far (0 when no store is attached).
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }

    /// Attach the persistent on-disk tier. First attachment wins;
    /// returns `false` (and drops `store`) if one was already attached.
    pub fn attach_store(&self, store: Arc<DiskStore>) -> bool {
        self.disk.set(store).is_ok()
    }

    /// The attached on-disk tier, if any.
    pub fn store(&self) -> Option<&Arc<DiskStore>> {
        self.disk.get()
    }

    /// Memory bound: cached points beyond this flush the cache (reports
    /// carry output/golden vectors, so an unbounded config sweep would
    /// otherwise grow without any reuse to show for it). Large enough
    /// that a whole 4-variant suite (48 points) plus ablation sweeps
    /// stay resident.
    const MAX_ENTRIES: usize = 256;

    /// Return the memoized report for `pt` or simulate it with `run`.
    /// The lock is not held during simulation; two racing threads on the
    /// same cold key may both simulate (deterministic, so harmless).
    pub fn get_or_run(
        &self,
        pt: &SweepPoint,
        run: impl FnOnce() -> Result<RunReport>,
    ) -> Result<RunReport> {
        self.get_or_run_traced(pt, run).map(|(r, _)| r)
    }

    /// [`SimCache::get_or_run`] plus which tier served the point —
    /// memory, the attached on-disk store, or a fresh simulation. The
    /// sweep service uses the trace to report re-simulation counts.
    pub fn get_or_run_traced(
        &self,
        pt: &SweepPoint,
        run: impl FnOnce() -> Result<RunReport>,
    ) -> Result<(RunReport, CacheTier)> {
        let (kind, cfg_hash) = pt.target.fingerprint();
        let key: SimKey = (pt.workload, pt.scale, kind, cfg_hash);
        if let Some(r) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((r.clone(), CacheTier::Memory));
        }
        if let Some(store) = self.disk.get() {
            if let Some(r) = store.load(&pt.cache_key()) {
                self.disk_hits.fetch_add(1, Ordering::Relaxed);
                self.insert(key, r.clone());
                return Ok((r, CacheTier::Disk));
            }
        }
        let r = run()?;
        self.insert(key, r.clone());
        if let Some(store) = self.disk.get() {
            store.store(&pt.cache_key(), pt.scale, &r);
        }
        Ok((r, CacheTier::Simulated))
    }

    /// Force-publish a freshly simulated report into both tiers,
    /// overwriting whatever they held (the `fresh` refresh path: a
    /// forced re-simulation must repair a stale persistent entry, not
    /// leave it in place).
    pub fn put(&self, pt: &SweepPoint, r: &RunReport) {
        let (kind, cfg_hash) = pt.target.fingerprint();
        self.insert((pt.workload, pt.scale, kind, cfg_hash), r.clone());
        if let Some(store) = self.disk.get() {
            store.store(&pt.cache_key(), pt.scale, r);
        }
    }

    fn insert(&self, key: SimKey, r: RunReport) {
        let mut map = self.map.lock().unwrap();
        if map.len() >= Self::MAX_ENTRIES {
            map.clear();
        }
        map.insert(key, r);
    }
}

/// Run one workload on the MPU machine with an already-decoded kernel.
pub fn run_mpu_with(
    w: Workload,
    cfg: &MachineConfig,
    scale: Scale,
    kernel: Arc<DecodedKernel>,
    threads: usize,
) -> Result<RunReport> {
    let mut m = Machine::new(cfg);
    m.set_threads(threads);
    let p = prepare(w, scale, &mut m)?;
    let loc_stats = kernel.loc_stats.clone();
    m.launch(kernel, p.launch, &p.params, p.home_fn())?;
    let t0 = Instant::now();
    let stats = m.run()?;
    let sim_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let output = m.read_f32s(p.out_addr, p.out_len);
    let (correct, max_err) = check(&output, &p.golden, p.tol);
    let energy = mpu_energy(&stats, &cfg.energy);
    Ok(RunReport {
        workload: w,
        machine: "mpu",
        cycles: stats.cycles,
        sim_cycles_per_sec: super::sim_rate(stats.cycles, sim_wall_ms),
        sim_wall_ms,
        stats,
        energy,
        correct,
        max_err,
        output,
        golden: p.golden,
        loc_stats,
    })
}

/// Run one workload on the GPU baseline with an already-decoded kernel.
pub fn run_gpu_with(
    w: Workload,
    gcfg: &GpuConfig,
    scale: Scale,
    kernel: Arc<DecodedKernel>,
    threads: usize,
) -> Result<RunReport> {
    let mut g = GpuMachine::new(gcfg);
    g.set_threads(threads);
    let p = prepare(w, scale, &mut g)?;
    let loc_stats = kernel.loc_stats.clone();
    g.launch(kernel, p.launch, &p.params)?;
    let t0 = Instant::now();
    let stats = g.run()?;
    let sim_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let output = g.read_f32s(p.out_addr, p.out_len);
    let (correct, max_err) = check(&output, &p.golden, p.tol);
    let energy = gpu_energy(&stats, &gcfg.energy);
    Ok(RunReport {
        workload: w,
        machine: "gpu",
        cycles: stats.cycles,
        sim_cycles_per_sec: super::sim_rate(stats.cycles, sim_wall_ms),
        sim_wall_ms,
        stats,
        energy,
        correct,
        max_err,
        output,
        golden: p.golden,
        loc_stats,
    })
}

/// Run one workload on the ideal-bandwidth roofline machine.
pub fn run_ideal_with(
    w: Workload,
    icfg: &IdealConfig,
    scale: Scale,
    kernel: Arc<DecodedKernel>,
    threads: usize,
) -> Result<RunReport> {
    let mut m = IdealMachine::new(icfg);
    m.set_threads(threads);
    let p = prepare(w, scale, &mut m)?;
    let loc_stats = kernel.loc_stats.clone();
    m.launch(kernel, p.launch, &p.params)?;
    let t0 = Instant::now();
    let stats = m.run()?;
    let sim_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let output = m.read_f32s(p.out_addr, p.out_len);
    let (correct, max_err) = check(&output, &p.golden, p.tol);
    let energy = gpu_energy(&stats, &icfg.energy);
    Ok(RunReport {
        workload: w,
        machine: "ideal",
        cycles: stats.cycles,
        sim_cycles_per_sec: super::sim_rate(stats.cycles, sim_wall_ms),
        sim_wall_ms,
        stats,
        energy,
        correct,
        max_err,
        output,
        golden: p.golden,
        loc_stats,
    })
}

/// Builder for a set of sweep points.
pub struct Sweep {
    points: Vec<SweepPoint>,
    serial: bool,
    reuse: bool,
    threads: usize,
}

impl Default for Sweep {
    fn default() -> Sweep {
        Sweep { points: Vec::new(), serial: false, reuse: true, threads: 1 }
    }
}

impl Sweep {
    pub fn new() -> Sweep {
        Sweep::default()
    }

    /// Force serial execution (deterministic profiling, debugging).
    pub fn serial(mut self) -> Sweep {
        self.serial = true;
        self
    }

    /// Shard each machine's issue phase across `n` worker threads
    /// (results are bit-identical for any value — see
    /// `SimtFrontend::set_threads` — so this composes with the caches).
    pub fn threads(mut self, n: usize) -> Sweep {
        self.threads = n.max(1);
        self
    }

    /// Bypass the process-wide [`SimCache`] (e.g. when timing the
    /// simulator itself).
    pub fn fresh(mut self) -> Sweep {
        self.reuse = false;
        self
    }

    /// Add one point.
    pub fn point(mut self, label: &str, workload: Workload, scale: Scale, target: Target) -> Sweep {
        self.points.push(SweepPoint { label: label.to_string(), workload, scale, target });
        self
    }

    /// Add all twelve Table-I workloads on an MPU machine variant.
    pub fn suite_mpu(self, label: &str, scale: Scale, cfg: &MachineConfig) -> Sweep {
        Workload::ALL
            .iter()
            .fold(self, |s, &w| s.point(label, w, scale, Target::Mpu(cfg.clone())))
    }

    /// Add all twelve workloads on the GPU baseline matched to `cfg`.
    pub fn suite_gpu(self, label: &str, scale: Scale, cfg: &MachineConfig) -> Sweep {
        let gcfg = GpuConfig::matched(cfg);
        Workload::ALL
            .iter()
            .fold(self, |s, &w| s.point(label, w, scale, Target::Gpu(gcfg.clone(), cfg.clone())))
    }

    /// Add all twelve workloads on any [`MachineKind`] variant matched
    /// to `cfg`, labelled with the kind's stable name.
    pub fn suite_kind(self, kind: MachineKind, scale: Scale, cfg: &MachineConfig) -> Sweep {
        let target = Target::for_kind(kind, cfg);
        Workload::ALL
            .iter()
            .fold(self, |s, &w| s.point(kind.name(), w, scale, target.clone()))
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Run every point — in parallel unless [`Sweep::serial`] — compiling
    /// each distinct kernel once and reusing memoized results from
    /// `sim_cache`. Results come back in point order; the first
    /// simulation error aborts the sweep.
    pub fn run_with_cache(self, sim_cache: &SimCache) -> Result<Vec<SweepResult>> {
        let cache = KernelCache::new();
        let reuse = self.reuse;
        let threads = self.threads;
        let run_one = |pt: &SweepPoint| -> Result<SweepResult> {
            let simulate = || pt.simulate_with_threads(&cache, threads);
            let report =
                if reuse { sim_cache.get_or_run(pt, simulate)? } else { simulate()? };
            Ok(SweepResult { label: pt.label.clone(), scale: pt.scale, report })
        };
        if self.serial {
            self.points.iter().map(run_one).collect()
        } else {
            self.points.par_iter().map(run_one).collect()
        }
    }

    /// Run against the process-wide [`SimCache`].
    pub fn run(self) -> Result<Vec<SweepResult>> {
        let cache = SimCache::global();
        self.run_with_cache(cache)
    }
}

/// Reports of one variant, in the order its points were added.
pub fn select<'a>(results: &'a [SweepResult], label: &str) -> Vec<&'a RunReport> {
    results.iter().filter(|r| r.label == label).map(|r| &r.report).collect()
}

/// The full Table-I suite, MPU vs GPU, as pairs — run through the
/// parallel engine (the Fig. 8/9 and `BENCH_suite.json` primitive).
pub fn run_suite(cfg: &MachineConfig, scale: Scale) -> Result<Vec<PairReport>> {
    run_suite_threaded(cfg, scale, 1)
}

/// [`run_suite`] with each machine's issue phase sharded across
/// `threads` workers (bit-identical results for any value).
pub fn run_suite_threaded(
    cfg: &MachineConfig,
    scale: Scale,
    threads: usize,
) -> Result<Vec<PairReport>> {
    let results = Sweep::new()
        .suite_mpu("mpu", scale, cfg)
        .suite_gpu("gpu", scale, cfg)
        .threads(threads)
        .run()?;
    let mut mpu = Vec::new();
    let mut gpu = Vec::new();
    for r in results {
        if r.label == "mpu" {
            mpu.push(r.report);
        } else {
            gpu.push(r.report);
        }
    }
    anyhow::ensure!(mpu.len() == gpu.len(), "unbalanced suite results");
    Ok(mpu.into_iter().zip(gpu).map(|(m, g)| PairReport { mpu: m, gpu: g }).collect())
}

/// The full Table-I suite on one [`MachineKind`] variant, in
/// `Workload::ALL` order.
pub fn run_suite_kind(cfg: &MachineConfig, scale: Scale, kind: MachineKind) -> Result<Vec<RunReport>> {
    run_suite_kind_threaded(cfg, scale, kind, 1)
}

/// [`run_suite_kind`] with per-machine issue-phase sharding.
pub fn run_suite_kind_threaded(
    cfg: &MachineConfig,
    scale: Scale,
    kind: MachineKind,
    threads: usize,
) -> Result<Vec<RunReport>> {
    let results = Sweep::new().suite_kind(kind, scale, cfg).threads(threads).run()?;
    Ok(results.into_iter().map(|r| r.report).collect())
}

/// `--tiny` smoke scale from the CLI args (shared by the benches so the
/// whole figure suite can be smoke-run in seconds by hand or in CI).
pub fn scale_from_args() -> Scale {
    if std::env::args().any(|a| a == "--tiny") {
        Scale::Tiny
    } else {
        Scale::Small
    }
}

/// First non-flag CLI argument — the conventional workload-name slot of
/// the examples — or `default` (shared so flag handling stays in one
/// place).
pub fn workload_from_args(default: &str) -> String {
    std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| default.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_cache_compiles_each_variant_once() {
        let cache = KernelCache::new();
        let a = cache.get(Workload::Axpy, true).unwrap();
        let b = cache.get(Workload::Axpy, true).unwrap();
        assert_eq!(a.instrs.len(), b.instrs.len());
        assert_eq!(cache.len(), 1);
        cache.get(Workload::Axpy, false).unwrap();
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn sweep_returns_results_in_point_order() {
        let cfg = MachineConfig::scaled();
        let results = Sweep::new()
            .point("a", Workload::Axpy, Scale::Tiny, Target::Mpu(cfg.clone()))
            .point("b", Workload::Knn, Scale::Tiny, Target::Mpu(cfg.clone()))
            .point("g", Workload::Axpy, Scale::Tiny, Target::Gpu(GpuConfig::matched(&cfg), cfg.clone()))
            .run()
            .unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].label, "a");
        assert_eq!(results[0].report.workload, Workload::Axpy);
        assert_eq!(results[1].report.workload, Workload::Knn);
        assert_eq!(results[2].report.machine, "gpu");
        assert!(results.iter().all(|r| r.report.correct));
        let sel = select(&results, "a");
        assert_eq!(sel.len(), 1);
        assert_eq!(sel[0].workload, Workload::Axpy);
    }

    #[test]
    fn parallel_sweep_matches_serial_single_run() {
        let cfg = MachineConfig::scaled();
        let serial = super::super::run_workload_scaled(Workload::Axpy, &cfg, Scale::Tiny).unwrap();
        let results = Sweep::new()
            .point("mpu", Workload::Axpy, Scale::Tiny, Target::Mpu(cfg.clone()))
            .run()
            .unwrap();
        assert_eq!(results[0].report.cycles, serial.cycles);
        assert_eq!(results[0].report.output, serial.output);
    }

    #[test]
    fn sim_cache_skips_repeated_points_and_keys_on_config() {
        let cache = SimCache::new();
        let cfg = MachineConfig::scaled();
        let mk = || {
            Sweep::new().point("mpu", Workload::Axpy, Scale::Tiny, Target::Mpu(cfg.clone()))
        };
        let first = mk().run_with_cache(&cache).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.hits(), 0);
        // Second invocation in the same process: served from cache,
        // identical result. A different label does not re-simulate.
        let again = Sweep::new()
            .point("relabelled", Workload::Axpy, Scale::Tiny, Target::Mpu(cfg.clone()))
            .run_with_cache(&cache)
            .unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(again[0].report.cycles, first[0].report.cycles);
        assert_eq!(again[0].label, "relabelled");
        // Any config knob change produces a new key.
        let mut cfg2 = cfg.clone();
        cfg2.row_buffers_per_bank = 1;
        Sweep::new()
            .point("mpu", Workload::Axpy, Scale::Tiny, Target::Mpu(cfg2))
            .run_with_cache(&cache)
            .unwrap();
        assert_eq!(cache.len(), 2);
        // A different scale too.
        Sweep::new()
            .point("mpu", Workload::Axpy, Scale::Small, Target::Mpu(cfg.clone()))
            .run_with_cache(&cache)
            .unwrap();
        assert_eq!(cache.len(), 3);
        // `fresh()` bypasses the cache entirely.
        let before = cache.hits();
        Sweep::new()
            .point("mpu", Workload::Axpy, Scale::Tiny, Target::Mpu(cfg.clone()))
            .fresh()
            .run_with_cache(&cache)
            .unwrap();
        assert_eq!(cache.hits(), before);
    }

    #[test]
    fn config_fingerprint_is_stable_and_serde_based() {
        // FNV-1a known vectors: the store key must never move with a
        // Rust release (the old DefaultHasher fingerprint did).
        assert_eq!(stable_hash(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(stable_hash("a"), 0xaf63_dc4c_8601_ec8c);
        // Same config → same fingerprint across independent clones.
        let cfg = MachineConfig::scaled();
        let a = Target::Mpu(cfg.clone()).fingerprint();
        let b = Target::Mpu(cfg.clone()).fingerprint();
        assert_eq!(a, b);
        // Any knob change moves the key (serde includes field names and
        // values).
        let mut cfg2 = cfg.clone();
        cfg2.row_buffers_per_bank = 1;
        assert_ne!(a.1, Target::Mpu(cfg2).fingerprint().1);
        // The GPU/ideal fingerprints also cover the compilation-side
        // MachineConfig they are matched to.
        let mut smem_far = cfg.clone();
        smem_far.smem_location = crate::config::SmemLocation::FarBank;
        let g1 = Target::for_kind(MachineKind::Gpu, &cfg).fingerprint();
        let g2 = Target::for_kind(MachineKind::Gpu, &smem_far).fingerprint();
        assert_ne!(g1.1, g2.1);
    }

    #[test]
    fn policy_table_fingerprint_known_vector() {
        use crate::config::{OffloadPolicy, OffloadPolicyTable};
        use crate::isa::instr::Loc;
        // The explicit offload-policy table rides inside the config
        // fingerprint. Pin its canonical serde rendering and FNV-1a hash
        // (computed independently) so candidate-policy cache keys never
        // silently move: BTreeMaps give deterministic ordering and
        // integer pcs serialize as JSON string keys.
        let mut table = OffloadPolicyTable::default();
        table.set("axpy", 5, Loc::F);
        table.set("axpy", 2, Loc::N);
        let j = serde_json::to_string(&table).unwrap();
        assert_eq!(j, r#"{"kernels":{"axpy":{"2":"N","5":"F"}}}"#);
        assert_eq!(stable_hash(&j), 0x4cf6_6c8d_11ab_a92e);
        assert_eq!(stable_hash(r#"{"kernels":{}}"#), 0xbbaf_21e2_0a98_a969);
        // Round trip through the federation wire format (`cfg.set`).
        let mut cfg = MachineConfig::scaled();
        cfg.set("offload_policy", "explicit").unwrap();
        cfg.set("offload_table", &j).unwrap();
        assert_eq!(cfg.offload_policy, OffloadPolicy::Explicit);
        assert_eq!(cfg.offload_table, table);
        // A non-empty table moves the whole-config fingerprint, and two
        // different tables land on different keys — every candidate
        // policy is its own cache entry.
        let base = Target::Mpu(MachineConfig::scaled()).fingerprint();
        let with_table = Target::Mpu(cfg.clone()).fingerprint();
        assert_ne!(base.1, with_table.1);
        let mut cfg2 = cfg.clone();
        cfg2.offload_table.set("axpy", 2, Loc::F);
        assert_ne!(with_table.1, Target::Mpu(cfg2).fingerprint().1);
    }

    #[test]
    fn target_for_kind_covers_all_variants() {
        let cfg = MachineConfig::scaled();
        for kind in MachineKind::ALL {
            let t = Target::for_kind(kind, &cfg);
            match (kind, &t) {
                (MachineKind::Mpu, Target::Mpu(c)) => {
                    assert_eq!(c.offload_policy, cfg.offload_policy)
                }
                (MachineKind::MpuNoOffload, Target::Mpu(c)) => {
                    assert_eq!(c.offload_policy, crate::config::OffloadPolicy::AllFarBank)
                }
                (MachineKind::Gpu, Target::Gpu(..)) => {}
                (MachineKind::IdealBw, Target::Ideal(..)) => {}
                _ => panic!("{kind:?} mapped to the wrong target"),
            }
        }
        // MPU and MPU-no-offload must not collide in the cache.
        let (k1, h1) = Target::for_kind(MachineKind::Mpu, &cfg).fingerprint();
        let (k2, h2) = Target::for_kind(MachineKind::MpuNoOffload, &cfg).fingerprint();
        assert_eq!(k1, k2);
        assert_ne!(h1, h2);
    }
}
