//! Experiment coordinator: runs prepared workloads on the MPU machine
//! and the GPU baseline, validates outputs against the pure-Rust golden
//! (and, via [`crate::runtime`], the AOT-compiled XLA golden), and
//! derives every §VI metric the benches report.
//!
//! The single-run helpers below are thin wrappers over the parallel
//! [`sweep`] engine, which compiles each kernel once into a shared cache
//! and fans independent simulations out across threads; [`bench`] turns
//! sweep results into the stable-schema `BENCH_suite.json` perf output.
//! The [`service`] module makes the engine resident (`mpu serve`): a
//! priority job queue with cross-request in-flight dedup behind a JSONL
//! TCP [`proto`]col, backed by the persistent content-addressed result
//! [`store`] that sits under [`SimCache`] as its second tier. The
//! [`federation`] module scales the service past one machine: a
//! coordinator shards batches across worker daemons by consistent
//! hashing on the stable store keys, merges their streamed results,
//! and redistributes the points of workers that die mid-batch.

pub mod bench;
pub mod fault;
pub mod federation;
pub mod proto;
pub mod report;
pub mod service;
pub mod store;
pub mod sweep;

use crate::compiler::{compile_with, CompiledKernel, LocStats};
use crate::config::{GpuConfig, MachineConfig, SmemLocation};
use crate::energy::EnergyBreakdown;
use crate::sim::Stats;
use crate::workloads::{Prepared, Scale, Workload};
use anyhow::Result;

pub use fault::{FaultClass, FaultInjector, FaultPlan, RetryPolicy, Timeouts};
pub use federation::{Coordinator, FedEvent, FedReply, Federation};
pub use service::{Service, SweepServer};
pub use store::{DiskStore, GcOptions, GcReport, StoreConfig};
pub use sweep::{run_suite, run_suite_kind, KernelCache, SimCache, Sweep, SweepResult, Target};

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub workload: Workload,
    pub machine: &'static str,
    pub cycles: u64,
    /// Wall-clock milliseconds the producing simulation took
    /// (`SimtFrontend::run` only — prepare/compile/check excluded).
    /// Cache and store hits return the original simulation's cost.
    pub sim_wall_ms: f64,
    /// Simulated cycles per wall-clock second of the producing
    /// simulation — the simulator-throughput metric `BENCH_simperf.json`
    /// tracks across PRs.
    pub sim_cycles_per_sec: f64,
    pub stats: Stats,
    pub energy: EnergyBreakdown,
    /// Output matched the pure-Rust golden within tolerance.
    pub correct: bool,
    pub max_err: f32,
    /// Device output (for the XLA cross-check).
    pub output: Vec<f32>,
    /// Pure-Rust golden the output was checked against (kept so failure
    /// reports can show both sides).
    pub golden: Vec<f32>,
    /// Compile-time register-location stats (Fig. 14).
    pub loc_stats: LocStats,
}

impl RunReport {
    /// Achieved DRAM bandwidth in GB/s at the 1 GHz core clock.
    pub fn dram_gbps(&self) -> f64 {
        self.stats.dram_bytes_per_cycle() // bytes/cycle × 1 GHz = GB/s
    }
}

/// Simulated cycles per wall-clock second (0 when no wall time was
/// observed, e.g. a sub-resolution run).
pub(crate) fn sim_rate(cycles: u64, wall_ms: f64) -> f64 {
    if wall_ms > 0.0 {
        cycles as f64 / (wall_ms / 1e3)
    } else {
        0.0
    }
}

pub(crate) fn check(out: &[f32], golden: &[f32], tol: f32) -> (bool, f32) {
    let mut max_err = 0f32;
    for (a, b) in out.iter().zip(golden) {
        let e = (a - b).abs();
        if e > max_err {
            max_err = e;
        }
    }
    (max_err <= tol.max(f32::EPSILON), max_err)
}

/// Compile a prepared workload consistently with the machine config.
pub fn compile_for(p: &Prepared, cfg: &MachineConfig) -> Result<CompiledKernel> {
    compile_with(&p.kernel, cfg.smem_location == SmemLocation::NearBank)
}

/// Run one workload on the MPU machine (default Small scale).
pub fn run_workload(w: Workload, cfg: &MachineConfig) -> Result<RunReport> {
    run_workload_scaled(w, cfg, Scale::Small)
}

/// Run one workload on the MPU machine at a given problem scale.
pub fn run_workload_scaled(w: Workload, cfg: &MachineConfig, scale: Scale) -> Result<RunReport> {
    let kernel = sweep::compile_kernel(w, cfg.smem_location == SmemLocation::NearBank)?;
    sweep::run_mpu_with(w, cfg, scale, kernel, 1)
}

/// Run one workload on the GPU baseline.
pub fn run_workload_gpu(w: Workload, gcfg: &GpuConfig, cfg: &MachineConfig) -> Result<RunReport> {
    run_workload_gpu_scaled(w, gcfg, cfg, Scale::Small)
}

pub fn run_workload_gpu_scaled(
    w: Workload,
    gcfg: &GpuConfig,
    cfg: &MachineConfig,
    scale: Scale,
) -> Result<RunReport> {
    let kernel = sweep::compile_kernel(w, cfg.smem_location == SmemLocation::NearBank)?;
    sweep::run_gpu_with(w, gcfg, scale, kernel, 1)
}

/// MPU-vs-GPU pair for one workload (the Fig. 8 / Fig. 9 primitive).
pub struct PairReport {
    pub mpu: RunReport,
    pub gpu: RunReport,
}

impl PairReport {
    pub fn speedup(&self) -> f64 {
        self.gpu.cycles as f64 / self.mpu.cycles.max(1) as f64
    }
    pub fn energy_reduction(&self) -> f64 {
        self.gpu.energy.total() / self.mpu.energy.total().max(1e-30)
    }
}

/// Run the MPU/GPU pair at a scale.
pub fn run_pair(w: Workload, cfg: &MachineConfig, scale: Scale) -> Result<PairReport> {
    let gcfg = GpuConfig::matched(cfg);
    Ok(PairReport {
        mpu: run_workload_scaled(w, cfg, scale)?,
        gpu: run_workload_gpu_scaled(w, &gcfg, cfg, scale)?,
    })
}

/// Geometric mean helper (the paper reports means over the suite).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[5.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_pair_runs_correct_and_faster() {
        let cfg = MachineConfig::scaled();
        let pair = run_pair(Workload::Axpy, &cfg, Scale::Tiny).unwrap();
        assert!(pair.mpu.correct, "MPU output wrong (max_err {})", pair.mpu.max_err);
        assert!(pair.gpu.correct, "GPU output wrong (max_err {})", pair.gpu.max_err);
        assert!(pair.speedup() > 1.0, "speedup {}", pair.speedup());
        assert!(pair.energy_reduction() > 1.0, "energy red {}", pair.energy_reduction());
    }

    #[test]
    fn run_report_carries_golden() {
        let cfg = MachineConfig::scaled();
        let r = run_workload_scaled(Workload::Axpy, &cfg, Scale::Tiny).unwrap();
        assert_eq!(r.golden.len(), r.output.len());
        assert!(!r.golden.is_empty());
    }
}
