//! JSONL request/response protocol of the sweep service.
//!
//! One JSON object per line in both directions, over a local TCP socket
//! (std-only). A connection may carry any number of requests; every
//! request gets exactly one response line.
//!
//! ```text
//! -> {"cmd":"ping"}
//! <- {"resp":"pong","proto_version":1}
//! -> {"cmd":"submit","suite":true,"scale":"tiny","variants":["mpu","gpu"]}
//! <- {"resp":"done","points":24,"simulated":24,...,"results":[...]}
//! -> {"cmd":"status"}
//! <- {"resp":"status","requests":1,...}
//! -> {"cmd":"shutdown"}
//! <- {"resp":"bye"}
//! ```
//!
//! Fields are append-only once released, mirroring the
//! `BENCH_suite.json` schema discipline.

use crate::config::{MachineConfig, MachineKind};
use crate::coordinator::sweep::{SweepPoint, Target};
use crate::workloads::{Scale, Workload};
use anyhow::{anyhow, Context, Result};
use serde::{Deserialize, Serialize};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;

/// Protocol version; a server rejects nothing by version yet, but
/// reports it in `pong`/`status` so clients can detect skew.
pub const PROTO_VERSION: u32 = 1;

/// A client request (one per line).
#[derive(Clone, Debug, Serialize, Deserialize)]
#[serde(tag = "cmd", rename_all = "snake_case")]
pub enum Request {
    /// Liveness check.
    Ping,
    /// Daemon + store counters.
    Status,
    /// Run a batch of sweep points and return their results.
    Submit(SubmitRequest),
    /// Stop the daemon: drains submits already executing (their clients
    /// still get results), responds `bye`, then stops accepting.
    Shutdown,
}

/// A batch of sweep points: `{workloads | suite} × variants` under one
/// machine configuration.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct SubmitRequest {
    /// Run the whole Table-I suite (overrides `workloads`).
    #[serde(default)]
    pub suite: bool,
    /// Explicit workload names (ignored when `suite` is set).
    #[serde(default)]
    pub workloads: Vec<String>,
    /// Problem scale name (`"tiny"` | `"small"`).
    #[serde(default = "default_scale")]
    pub scale: String,
    /// Machine-variant names ([`MachineKind`]); default `["mpu","gpu"]`.
    #[serde(default = "default_variants")]
    pub variants: Vec<String>,
    /// Configuration knob overrides, applied to the scaled machine in
    /// order (`MachineConfig::set` key/value pairs).
    #[serde(default)]
    pub config: Vec<(String, String)>,
    /// Scheduling priority: higher runs first across queued requests.
    #[serde(default)]
    pub priority: i32,
    /// Force re-simulation, bypassing every cache tier.
    #[serde(default)]
    pub fresh: bool,
}

fn default_scale() -> String {
    "small".to_string()
}

fn default_variants() -> Vec<String> {
    vec!["mpu".to_string(), "gpu".to_string()]
}

impl SubmitRequest {
    /// Expand into concrete sweep points (variant-major, each variant in
    /// workload order) — the server-side entry to the sweep engine.
    pub fn points(&self) -> Result<Vec<SweepPoint>> {
        let mut cfg = MachineConfig::scaled();
        for (k, v) in &self.config {
            cfg.set(k, v).map_err(|e| anyhow!("config error: {e}"))?;
        }
        let scale = Scale::from_name(&self.scale)
            .ok_or_else(|| anyhow!("unknown scale `{}` (tiny|small)", self.scale))?;
        let workloads: Vec<Workload> = if self.suite {
            Workload::ALL.to_vec()
        } else {
            self.workloads
                .iter()
                .map(|n| {
                    Workload::from_name(n).ok_or_else(|| anyhow!("unknown workload `{n}`"))
                })
                .collect::<Result<_>>()?
        };
        anyhow::ensure!(!workloads.is_empty(), "no workloads requested");
        anyhow::ensure!(!self.variants.is_empty(), "no variants requested");
        let mut points = Vec::with_capacity(workloads.len() * self.variants.len());
        for name in &self.variants {
            let kind = MachineKind::from_name(name)
                .ok_or_else(|| anyhow!("unknown machine variant `{name}`"))?;
            let target = Target::for_kind(kind, &cfg);
            for &w in &workloads {
                points.push(SweepPoint {
                    label: kind.name().to_string(),
                    workload: w,
                    scale,
                    target: target.clone(),
                });
            }
        }
        Ok(points)
    }
}

/// A server response (one per request).
#[derive(Clone, Debug, Serialize, Deserialize)]
#[serde(tag = "resp", rename_all = "snake_case")]
pub enum Response {
    Pong { proto_version: u32 },
    Error { message: String },
    Status(StatusBody),
    Done(SubmitReply),
    Bye,
}

/// Result of one submitted batch.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct SubmitReply {
    /// Points in the batch.
    pub points: usize,
    /// Points this request actually simulated (cold everywhere).
    pub simulated: usize,
    /// Served from the in-process memory tier.
    pub mem_hits: usize,
    /// Served from the persistent on-disk store.
    pub disk_hits: usize,
    /// Coalesced onto an identical point already in flight for another
    /// request.
    pub deduped: usize,
    pub elapsed_ms: u64,
    /// Per-point summaries, in request (variant-major) order.
    pub results: Vec<PointSummary>,
}

impl SubmitReply {
    /// Points served without re-simulation.
    pub fn cached(&self) -> usize {
        self.mem_hits + self.disk_hits + self.deduped
    }
}

/// One point's result summary (the full `RunReport` stays server-side;
/// suite JSON remains the vehicle for complete stats).
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct PointSummary {
    pub label: String,
    pub workload: String,
    pub scale: String,
    pub machine: String,
    pub cycles: u64,
    pub correct: bool,
    pub max_err: f32,
    pub dram_gbps: f64,
    pub energy_j: f64,
    /// Which tier served it: `sim` | `mem` | `disk` | `dedup`.
    pub source: String,
}

/// Daemon counters for `mpu status`.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct StatusBody {
    pub proto_version: u32,
    pub uptime_ms: u64,
    /// Submit requests served.
    pub requests: u64,
    /// Points across all submits.
    pub points: u64,
    pub simulated: u64,
    pub mem_hits: u64,
    pub disk_hits: u64,
    pub dedup_waits: u64,
    /// Distinct kernels compiled since start.
    pub kernels_compiled: usize,
    /// Entries resident in the memory tier.
    pub mem_entries: usize,
    /// On-disk store counters (absent when the daemon runs storeless).
    pub store: Option<super::store::StoreStats>,
}

/// Send one request and read one response over a fresh connection.
pub fn request(addr: &str, req: &Request) -> Result<Response> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to mpu serve at {addr}"))?;
    let mut w = BufWriter::new(stream.try_clone()?);
    let line = serde_json::to_string(req)?;
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    anyhow::ensure!(!reply.trim().is_empty(), "server closed the connection without replying");
    serde_json::from_str(&reply).context("malformed response line")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_round_trip_as_jsonl() {
        let req = Request::Submit(SubmitRequest {
            suite: true,
            workloads: vec![],
            scale: "tiny".into(),
            variants: vec!["mpu".into(), "gpu".into()],
            config: vec![("row_buffers_per_bank".into(), "2".into())],
            priority: 3,
            fresh: false,
        });
        let line = serde_json::to_string(&req).unwrap();
        assert!(!line.contains('\n'), "one request must fit one line");
        assert!(line.contains("\"cmd\":\"submit\""));
        let back: Request = serde_json::from_str(&line).unwrap();
        match back {
            Request::Submit(s) => {
                assert!(s.suite);
                assert_eq!(s.priority, 3);
                assert_eq!(s.variants.len(), 2);
            }
            other => panic!("round-trip changed the variant: {other:?}"),
        }
    }

    #[test]
    fn submit_defaults_fill_in() {
        let s: Request = serde_json::from_str(r#"{"cmd":"submit","workloads":["axpy"]}"#).unwrap();
        match s {
            Request::Submit(s) => {
                assert_eq!(s.scale, "small");
                assert_eq!(s.variants, vec!["mpu".to_string(), "gpu".to_string()]);
                assert_eq!(s.priority, 0);
                assert!(!s.fresh && !s.suite);
            }
            other => panic!("expected submit, got {other:?}"),
        }
    }

    #[test]
    fn points_expand_variant_major() {
        let s = SubmitRequest {
            suite: false,
            workloads: vec!["axpy".into(), "knn".into()],
            scale: "tiny".into(),
            variants: vec!["mpu".into(), "ideal".into()],
            config: vec![],
            priority: 0,
            fresh: false,
        };
        let pts = s.points().unwrap();
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0].label, "mpu");
        assert_eq!(pts[0].workload, Workload::Axpy);
        assert_eq!(pts[2].label, "ideal");
        assert_eq!(pts[3].workload, Workload::Knn);
    }

    #[test]
    fn bad_names_are_rejected() {
        let mut s = SubmitRequest {
            suite: false,
            workloads: vec!["nope".into()],
            scale: "tiny".into(),
            variants: vec!["mpu".into()],
            config: vec![],
            priority: 0,
            fresh: false,
        };
        assert!(s.points().is_err());
        s.workloads = vec!["axpy".into()];
        s.scale = "huge".into();
        assert!(s.points().is_err());
        s.scale = "tiny".into();
        s.variants = vec!["tpu".into()];
        assert!(s.points().is_err());
        s.variants = vec![];
        assert!(s.points().is_err());
    }
}
