//! JSONL request/response protocol of the sweep service.
//!
//! One JSON object per line in both directions, over a local TCP socket
//! (std-only). A connection may carry any number of requests; every
//! request gets exactly one response line — except a **streamed**
//! submit (`"stream":true`, protocol v2), which gets incremental
//! `progress`/`result` records and a terminal `done` (or `error`)
//! record:
//!
//! ```text
//! -> {"cmd":"ping"}
//! <- {"resp":"pong","proto_version":2}
//! -> {"cmd":"hello","proto_version":2,"proto_major":1}
//! <- {"resp":"hello","proto_version":2,"proto_major":1,"features":[...]}
//! -> {"cmd":"submit","suite":true,"scale":"tiny","variants":["mpu","gpu"]}
//! <- {"resp":"done","points":24,"simulated":24,...,"results":[...]}
//! -> {"cmd":"submit","suite":true,"scale":"tiny","stream":true}
//! <- {"resp":"result","index":0,"point":{...}}
//! <- {"resp":"progress","completed":1,"total":24,"elapsed_ms":12}
//! <- ...
//! <- {"resp":"done","points":24,...,"results":[...]}
//! -> {"cmd":"status"}
//! <- {"resp":"status","requests":1,...}
//! -> {"cmd":"shutdown"}
//! <- {"resp":"bye"}
//! ```
//!
//! Fields are append-only once released, mirroring the
//! `BENCH_suite.json` schema discipline: a v1 client's blocking
//! `submit` keeps working against a v2 server (the new request fields
//! all default off), and a v2 client talking to a v1 server sees the
//! old single-reply behaviour. The explicit [`Request::Hello`]
//! handshake exists for the cases serde defaults cannot paper over: a
//! **major**-version mismatch is rejected with a clear error instead of
//! being silently misinterpreted, and the `features` list tells a
//! coordinator whether a worker understands `point_specs` streaming.

use crate::config::{MachineConfig, MachineKind};
use crate::coordinator::fault::{self, FaultClass, RetryPolicy, Timeouts};
use crate::coordinator::sweep::{stable_hash, SweepPoint, Target};
use crate::coordinator::RunReport;
use crate::workloads::{Scale, Workload};
use anyhow::{anyhow, Context, Result};
use serde::{Deserialize, Serialize};
use std::collections::HashSet;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Protocol feature level. v2 adds the `hello` handshake, streamed
/// submits (`stream`), explicit per-point batches (`point_specs`),
/// full-report transfer (`return_reports` + `result.report`) and the
/// queue/worker fields of `status`. v3 adds admission control (the
/// `busy` response + `retry_after_ms`), idempotent retried submits
/// (`request_id`), the `degraded` reply flag, and the
/// retry/degradation counters of `status`. v4 adds the operability
/// surface: the `metrics` record, per-client identity (`client_id` on
/// `hello`/`submit`) driving fair-share scheduling and per-client
/// quotas, hot fleet membership (`join`/`drain` + the `fleet`
/// response), and per-spec config overrides (`point_specs[].config`).
/// All additions are append-only, so v1–v4 share [`PROTO_MAJOR`] 1.
pub const PROTO_VERSION: u32 = 4;

/// Compatibility epoch. Bumped only when a change cannot be expressed
/// append-only; a server rejects a `hello` from a different major with
/// a clear error instead of misinterpreting its requests.
pub const PROTO_MAJOR: u32 = 1;

/// Wire-protocol feature names reported in the `hello` response (a
/// coordinator requires `point_specs` + `stream` from its workers).
/// Only capabilities with an actual protocol surface belong here —
/// the list is append-only once released.
pub const FEATURES: [&str; 9] = [
    "stream",
    "point_specs",
    "return_reports",
    "busy",
    "request_id",
    "metrics",
    "membership",
    "client_id",
    "spec_config",
];

fn default_proto_major() -> u32 {
    PROTO_MAJOR
}

/// A client request (one per line).
#[derive(Clone, Debug, Serialize, Deserialize)]
#[serde(tag = "cmd", rename_all = "snake_case")]
pub enum Request {
    /// Liveness check.
    Ping,
    /// Version/feature handshake (v2). Optional before `submit`; a
    /// major mismatch is rejected here so skewed clients fail loudly.
    Hello {
        proto_version: u32,
        #[serde(default = "default_proto_major")]
        proto_major: u32,
        /// Client identity (v4): becomes the connection's default
        /// identity for fair-share scheduling and per-client quotas.
        #[serde(default, skip_serializing_if = "Option::is_none")]
        client_id: Option<String>,
    },
    /// Daemon + store counters.
    Status,
    /// Operational metrics snapshot (v4): queue/in-flight depths,
    /// store hit rates, per-client and per-worker rows.
    Metrics,
    /// Run a batch of sweep points and return their results.
    Submit(SubmitRequest),
    /// Hot fleet membership (v4, coordinator only): register `addr` as
    /// a worker. The consistent-hash ring grows at the next
    /// redistribution round — no restart. Idempotent; re-joining a
    /// draining worker cancels the drain.
    Join { addr: String },
    /// Hot fleet membership (v4, coordinator only): mark `addr`
    /// draining. In-flight shares finish; new points remap to
    /// survivors via the PR-5 redistribution path.
    Drain { addr: String },
    /// Stop the daemon: drains submits already executing (their clients
    /// still get results), responds `bye`, then stops accepting.
    Shutdown,
}

/// A batch of sweep points: `{workloads | suite | point_specs} ×
/// variants` under one machine configuration.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct SubmitRequest {
    /// Run the whole Table-I suite (overrides `workloads`).
    #[serde(default)]
    pub suite: bool,
    /// Explicit workload names (ignored when `suite` is set).
    #[serde(default)]
    pub workloads: Vec<String>,
    /// Problem scale name (`"tiny"` | `"small"`).
    #[serde(default = "default_scale")]
    pub scale: String,
    /// Machine-variant names ([`MachineKind`]); default `["mpu","gpu"]`.
    #[serde(default = "default_variants")]
    pub variants: Vec<String>,
    /// Configuration knob overrides, applied to the scaled machine in
    /// order (`MachineConfig::set` key/value pairs).
    #[serde(default)]
    pub config: Vec<(String, String)>,
    /// Scheduling priority: higher runs first across queued requests.
    #[serde(default)]
    pub priority: i32,
    /// Force re-simulation, bypassing every cache tier.
    #[serde(default)]
    pub fresh: bool,
    /// Stream incremental `progress`/`result` records per completed
    /// point before the terminal `done` (v2; defaults off, so v1
    /// clients keep the single blocking reply).
    #[serde(default)]
    pub stream: bool,
    /// Explicit (workload × variant) points, overriding the
    /// `{workloads|suite} × variants` cross product (v2). This is how a
    /// coordinator ships each worker exactly its consistent-hash share,
    /// which is not expressible as a cross product.
    #[serde(default)]
    pub point_specs: Vec<PointSpec>,
    /// Attach the full serialized report to each streamed `result`
    /// record (v2; coordinators use it to merge byte-identical
    /// results).
    #[serde(default)]
    pub return_reports: bool,
    /// Idempotency token (v3). A retried submit that carries the same
    /// `request_id` attaches to the batch already in flight instead of
    /// re-enqueueing — a dropped-reply retry never re-simulates.
    #[serde(default, skip_serializing_if = "Option::is_none")]
    pub request_id: Option<String>,
    /// Client identity (v4) for fair-share scheduling and per-client
    /// quotas. Overrides the connection's `hello` identity; absent
    /// everywhere means the shared `"anon"` bucket.
    #[serde(default, skip_serializing_if = "Option::is_none")]
    pub client_id: Option<String>,
}

impl Default for SubmitRequest {
    /// The serde defaults: a blocking `{mpu,gpu} × workloads` submit at
    /// Small scale (what a bare `{"cmd":"submit"}` line means).
    fn default() -> SubmitRequest {
        SubmitRequest {
            suite: false,
            workloads: vec![],
            scale: default_scale(),
            variants: default_variants(),
            config: vec![],
            priority: 0,
            fresh: false,
            stream: false,
            point_specs: vec![],
            return_reports: false,
            request_id: None,
            client_id: None,
        }
    }
}

/// One explicit sweep point of a `point_specs` batch (scale and base
/// config come from the enclosing request; `config` layers per-spec
/// overrides on top — v4, how `mpu tune` ships a whole candidate
/// generation as one batch).
#[derive(Clone, Debug, PartialEq, Eq, Serialize, Deserialize)]
pub struct PointSpec {
    pub workload: String,
    pub variant: String,
    /// Per-spec knob overrides (v4), applied after the request-level
    /// `config`. Empty (the default) is wire-identical to v2/v3.
    #[serde(default, skip_serializing_if = "Vec::is_empty")]
    pub config: Vec<(String, String)>,
}

fn default_scale() -> String {
    "small".to_string()
}

fn default_variants() -> Vec<String> {
    vec!["mpu".to_string(), "gpu".to_string()]
}

impl SubmitRequest {
    /// Expand into concrete sweep points — the server-side entry to the
    /// sweep engine. `point_specs` (when present) wins; otherwise the
    /// `{workloads|suite} × variants` cross product expands
    /// variant-major, each variant in workload order.
    pub fn points(&self) -> Result<Vec<SweepPoint>> {
        let mut cfg = MachineConfig::scaled();
        for (k, v) in &self.config {
            cfg.set(k, v).map_err(|e| anyhow!("config error: {e}"))?;
        }
        let scale = Scale::from_name(&self.scale)
            .ok_or_else(|| anyhow!("unknown scale `{}` (tiny|small)", self.scale))?;
        if !self.point_specs.is_empty() {
            let mut points = Vec::with_capacity(self.point_specs.len());
            for spec in &self.point_specs {
                let w = Workload::from_name(&spec.workload)
                    .ok_or_else(|| anyhow!("unknown workload `{}`", spec.workload))?;
                let kind = MachineKind::from_name(&spec.variant)
                    .ok_or_else(|| anyhow!("unknown machine variant `{}`", spec.variant))?;
                let target = if spec.config.is_empty() {
                    Target::for_kind(kind, &cfg)
                } else {
                    let mut spec_cfg = cfg.clone();
                    for (k, v) in &spec.config {
                        spec_cfg.set(k, v).map_err(|e| anyhow!("config error: {e}"))?;
                    }
                    Target::for_kind(kind, &spec_cfg)
                };
                points.push(SweepPoint {
                    label: kind.name().to_string(),
                    workload: w,
                    scale,
                    target,
                });
            }
            return Ok(points);
        }
        let workloads: Vec<Workload> = if self.suite {
            Workload::ALL.to_vec()
        } else {
            self.workloads
                .iter()
                .map(|n| {
                    Workload::from_name(n).ok_or_else(|| anyhow!("unknown workload `{n}`"))
                })
                .collect::<Result<_>>()?
        };
        anyhow::ensure!(!workloads.is_empty(), "no workloads requested");
        anyhow::ensure!(!self.variants.is_empty(), "no variants requested");
        let mut points = Vec::with_capacity(workloads.len() * self.variants.len());
        for name in &self.variants {
            let kind = MachineKind::from_name(name)
                .ok_or_else(|| anyhow!("unknown machine variant `{name}`"))?;
            let target = Target::for_kind(kind, &cfg);
            for &w in &workloads {
                points.push(SweepPoint {
                    label: kind.name().to_string(),
                    workload: w,
                    scale,
                    target: target.clone(),
                });
            }
        }
        Ok(points)
    }
}

/// A server response. Blocking requests get exactly one; a streamed
/// submit gets `result`/`progress` records and a terminal
/// `done`/`error`.
#[derive(Clone, Debug, Serialize, Deserialize)]
#[serde(tag = "resp", rename_all = "snake_case")]
pub enum Response {
    Pong {
        proto_version: u32,
    },
    /// Handshake reply (v2).
    Hello {
        proto_version: u32,
        proto_major: u32,
        features: Vec<String>,
    },
    Error {
        message: String,
    },
    Status(StatusBody),
    /// Operational metrics snapshot (v4).
    Metrics(MetricsBody),
    /// Fleet membership ack (v4): the post-`join`/`drain` worker list,
    /// draining workers marked.
    Fleet { workers: Vec<FleetWorker> },
    /// Streamed: one completed point (v2).
    Result(ResultBody),
    /// Streamed: running completion count (v2).
    Progress(ProgressBody),
    Done(SubmitReply),
    /// Admission control (v3): the queue is full; retry the submit
    /// after `retry_after_ms`. Pre-v3 clients that do not understand
    /// `busy` surface it as an unexpected-reply error, which is still
    /// better than unbounded queueing server-side.
    Busy { retry_after_ms: u64 },
    Bye,
}

/// One streamed completed point.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct ResultBody {
    /// Index into the submitted batch's point order.
    pub index: usize,
    pub point: PointSummary,
    /// Full serialized report, present when the request set
    /// `return_reports`.
    #[serde(default, skip_serializing_if = "Option::is_none")]
    pub report: Option<WireReport>,
}

/// Streamed completion counter; `completed` is monotonically
/// increasing and reaches `total` exactly at the terminal record.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct ProgressBody {
    pub completed: usize,
    pub total: usize,
    pub elapsed_ms: u64,
}

/// Result of one submitted batch.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct SubmitReply {
    /// Points in the batch.
    pub points: usize,
    /// Points this request actually simulated (cold everywhere).
    pub simulated: usize,
    /// Served from the in-process memory tier.
    pub mem_hits: usize,
    /// Served from the persistent on-disk store.
    pub disk_hits: usize,
    /// Coalesced onto an identical point already in flight for another
    /// request.
    pub deduped: usize,
    pub elapsed_ms: u64,
    /// Per-point summaries, in request (variant-major) order.
    pub results: Vec<PointSummary>,
    /// The batch was served in a degraded mode (v3): a coordinator
    /// whose workers all died fell back to local simulation. Results
    /// are still exact — only the serving path was impaired.
    #[serde(default)]
    pub degraded: bool,
}

impl SubmitReply {
    /// Points served without re-simulation.
    pub fn cached(&self) -> usize {
        self.mem_hits + self.disk_hits + self.deduped
    }
}

/// One point's result summary (the full `RunReport` stays server-side
/// unless `return_reports` streams it; suite JSON remains the vehicle
/// for complete stats).
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct PointSummary {
    pub label: String,
    pub workload: String,
    pub scale: String,
    pub machine: String,
    pub cycles: u64,
    pub correct: bool,
    pub max_err: f32,
    pub dram_gbps: f64,
    pub energy_j: f64,
    /// Which tier served it: `sim` | `mem` | `disk` | `dedup`.
    pub source: String,
}

/// A full [`RunReport`] in wire form (owned strings so it round-trips
/// through serde; the on-disk store's entry body is the same shape plus
/// key/schema fields). Coordinators merge these so a federated submit
/// returns byte-identical reports to a single-daemon one.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct WireReport {
    pub workload: String,
    pub scale: String,
    pub machine: String,
    pub cycles: u64,
    #[serde(default)]
    pub sim_wall_ms: f64,
    #[serde(default)]
    pub sim_cycles_per_sec: f64,
    pub stats: crate::sim::Stats,
    pub energy: crate::energy::EnergyBreakdown,
    pub correct: bool,
    pub max_err: f32,
    pub output: Vec<f32>,
    pub golden: Vec<f32>,
    pub loc_stats: crate::compiler::LocStats,
}

impl WireReport {
    pub fn from_report(scale: Scale, r: &RunReport) -> WireReport {
        WireReport {
            workload: r.workload.name().to_string(),
            scale: scale.name().to_string(),
            machine: r.machine.to_string(),
            cycles: r.cycles,
            sim_wall_ms: r.sim_wall_ms,
            sim_cycles_per_sec: r.sim_cycles_per_sec,
            stats: r.stats.clone(),
            energy: r.energy,
            correct: r.correct,
            max_err: r.max_err,
            output: r.output.clone(),
            golden: r.golden.clone(),
            loc_stats: r.loc_stats.clone(),
        }
    }

    /// Reconstruct the in-memory report; `None` when the workload,
    /// scale or machine name is foreign (a skewed peer).
    pub fn into_report(self) -> Option<RunReport> {
        let workload = Workload::from_name(&self.workload)?;
        Scale::from_name(&self.scale)?;
        let machine = super::store::machine_static(&self.machine)?;
        Some(RunReport {
            workload,
            machine,
            cycles: self.cycles,
            sim_wall_ms: self.sim_wall_ms,
            sim_cycles_per_sec: self.sim_cycles_per_sec,
            stats: self.stats,
            energy: self.energy,
            correct: self.correct,
            max_err: self.max_err,
            output: self.output,
            golden: self.golden,
            loc_stats: self.loc_stats,
        })
    }
}

/// Daemon counters for `mpu status`. The queue/in-flight/worker fields
/// are v2 append-only additions (defaulted so v2 clients parse v1
/// replies).
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct StatusBody {
    pub proto_version: u32,
    pub uptime_ms: u64,
    /// Submit requests served.
    pub requests: u64,
    /// Points across all submits.
    pub points: u64,
    pub simulated: u64,
    pub mem_hits: u64,
    pub disk_hits: u64,
    pub dedup_waits: u64,
    /// Distinct kernels compiled since start.
    pub kernels_compiled: usize,
    /// Entries resident in the memory tier.
    pub mem_entries: usize,
    /// On-disk store counters (absent when the daemon runs storeless).
    pub store: Option<super::store::StoreStats>,
    /// Compatibility epoch (v2; 0 from a v1 server).
    #[serde(default)]
    pub proto_major: u32,
    /// Points queued but not yet claimed by a runner (v2).
    #[serde(default)]
    pub queue_depth: usize,
    /// Simulations currently executing or awaited by a dedup waiter
    /// (v2).
    #[serde(default)]
    pub inflight: usize,
    /// Submit requests currently executing (v2).
    #[serde(default)]
    pub active_requests: u64,
    /// Per-worker liveness, present only from a coordinator (v2).
    #[serde(default)]
    pub workers: Option<Vec<WorkerStatus>>,
    /// Submits refused with `busy` because the queue was full (v3).
    #[serde(default)]
    pub admission_rejected: u64,
    /// Admission cap on queued points; 0 means unbounded (v3).
    #[serde(default)]
    pub queue_limit: usize,
    /// Worker-link operations retried after transient failure (v3;
    /// coordinator only).
    #[serde(default)]
    pub retries: u64,
    /// Batches served via the degraded local-fallback path (v3;
    /// coordinator only).
    #[serde(default)]
    pub degraded_batches: u64,
}

/// One worker's liveness row in a coordinator's `status` reply.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct WorkerStatus {
    pub addr: String,
    pub alive: bool,
    /// The worker's protocol version (0 when unreachable).
    #[serde(default)]
    pub proto_version: u32,
    /// Worker-side lifetime counters (0 when unreachable).
    #[serde(default)]
    pub points: u64,
    #[serde(default)]
    pub simulated: u64,
    #[serde(default)]
    pub queue_depth: usize,
    #[serde(default)]
    pub inflight: usize,
}

/// Schema version of the `metrics` record / `METRICS.json` document.
pub const METRICS_SCHEMA_VERSION: u32 = 1;

fn metrics_report_tag() -> String {
    "metrics".to_string()
}

/// Operational metrics snapshot (v4) — the body of the `metrics`
/// response and, unchanged, of a dumped `METRICS.json`. Every field
/// beyond the schema header is `#[serde(default)]`, so the document
/// stays append-only under the same discipline as `status`.
#[derive(Clone, Debug, Default, Serialize, Deserialize)]
pub struct MetricsBody {
    /// Document schema version ([`METRICS_SCHEMA_VERSION`]).
    pub schema_version: u32,
    /// Document discriminator, always `"metrics"` (routes
    /// `mpu check-json`).
    #[serde(default = "metrics_report_tag")]
    pub report: String,
    #[serde(default)]
    pub proto_version: u32,
    #[serde(default)]
    pub uptime_ms: u64,
    /// Points queued but not yet claimed by a runner.
    #[serde(default)]
    pub queue_depth: usize,
    /// Admission cap on queued points; 0 means unbounded.
    #[serde(default)]
    pub queue_limit: usize,
    /// Simulations currently executing or awaited by a dedup waiter.
    #[serde(default)]
    pub inflight: usize,
    /// Submit requests currently executing.
    #[serde(default)]
    pub active_requests: u64,
    /// Lifetime submit requests served.
    #[serde(default)]
    pub requests: u64,
    /// Lifetime points across all submits.
    #[serde(default)]
    pub points: u64,
    #[serde(default)]
    pub simulated: u64,
    #[serde(default)]
    pub mem_hits: u64,
    #[serde(default)]
    pub disk_hits: u64,
    #[serde(default)]
    pub dedup_waits: u64,
    /// Fraction of lifetime points served without re-simulation
    /// (memory + disk + dedup over points); 0 before any traffic.
    #[serde(default)]
    pub cache_hit_rate: f64,
    /// Submits refused with `busy` (queue or quota full).
    #[serde(default)]
    pub admission_rejected: u64,
    /// Worker-link operations retried after transient failure
    /// (coordinator only).
    #[serde(default)]
    pub retries: u64,
    /// Batches served via the degraded local-fallback path
    /// (coordinator only).
    #[serde(default)]
    pub degraded_batches: u64,
    /// Aggregate simulation throughput: lifetime simulated cycles over
    /// lifetime simulation wall time (cycles/s; 0 before the first
    /// simulation).
    #[serde(default)]
    pub sim_cycles_per_sec: f64,
    /// On-disk store counters (absent when the daemon runs storeless).
    #[serde(default)]
    pub store: Option<super::store::StoreStats>,
    /// Per-client fair-share rows, sorted by client id.
    #[serde(default)]
    pub clients: Vec<ClientMetrics>,
    /// Per-worker rows (coordinator only).
    #[serde(default)]
    pub workers: Vec<WorkerMetrics>,
}

/// One client's fair-share row in a `metrics` reply.
#[derive(Clone, Debug, Default, Serialize, Deserialize)]
pub struct ClientMetrics {
    pub client_id: String,
    /// Deficit-round-robin weight (pops per scheduling turn).
    #[serde(default)]
    pub weight: u64,
    /// Points currently queued for this client.
    #[serde(default)]
    pub queued: usize,
    /// Lifetime points completed for this client.
    #[serde(default)]
    pub completed: u64,
    /// Submits refused because this client's quota was full.
    #[serde(default)]
    pub rejected: u64,
}

/// One worker's row in a coordinator's `metrics` reply.
#[derive(Clone, Debug, Default, Serialize, Deserialize)]
pub struct WorkerMetrics {
    pub addr: String,
    pub alive: bool,
    /// The worker is draining: it finishes in-flight shares but new
    /// points remap to survivors.
    #[serde(default)]
    pub draining: bool,
    #[serde(default)]
    pub proto_version: u32,
    #[serde(default)]
    pub points: u64,
    #[serde(default)]
    pub simulated: u64,
    #[serde(default)]
    pub queue_depth: usize,
    #[serde(default)]
    pub inflight: usize,
    /// The worker's aggregate simulation throughput (cycles/s).
    #[serde(default)]
    pub sim_cycles_per_sec: f64,
}

/// One worker row in a `fleet` membership ack.
#[derive(Clone, Debug, Serialize, Deserialize)]
pub struct FleetWorker {
    pub addr: String,
    #[serde(default)]
    pub draining: bool,
}

/// Connect to `addr`, consulting the fault plane first: an active
/// [`FaultClass::Connect`] rule can refuse the connection before any
/// socket is opened, exactly like a dead peer.
fn connect_checked(addr: &str, timeout: Option<Duration>) -> Result<TcpStream> {
    if fault::should_fail(FaultClass::Connect, addr) {
        anyhow::bail!("connecting to mpu serve at {addr}: connection refused (injected)");
    }
    match timeout {
        None => TcpStream::connect(addr)
            .with_context(|| format!("connecting to mpu serve at {addr}")),
        Some(t) => {
            let sa = addr
                .to_socket_addrs()
                .with_context(|| format!("resolving {addr}"))?
                .next()
                .ok_or_else(|| anyhow!("{addr} resolves to no address"))?;
            TcpStream::connect_timeout(&sa, t)
                .with_context(|| format!("connecting to mpu serve at {addr}"))
        }
    }
}

/// A [`TcpStream`] wrapper that consults the fault plane on every read
/// and write: an active `disconnect` rule resets the connection
/// mid-stream, a `stall` rule makes the call time out as if the peer
/// hung with the socket open. Inert (two atomic loads) when no plan is
/// active.
pub(crate) struct FaultStream {
    inner: TcpStream,
    ctx: String,
}

impl FaultStream {
    pub(crate) fn new(inner: TcpStream, ctx: &str) -> FaultStream {
        FaultStream { inner, ctx: ctx.to_string() }
    }

    fn fault(&self) -> Option<io::Error> {
        if fault::should_fail(FaultClass::Disconnect, &self.ctx) {
            return Some(io::Error::new(
                io::ErrorKind::ConnectionReset,
                "injected disconnect",
            ));
        }
        if fault::should_fail(FaultClass::Stall, &self.ctx) {
            return Some(io::Error::new(
                io::ErrorKind::TimedOut,
                "injected stall (deadline elapsed)",
            ));
        }
        None
    }
}

impl Read for FaultStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if let Some(e) = self.fault() {
            return Err(e);
        }
        self.inner.read(buf)
    }
}

impl Write for FaultStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if let Some(e) = self.fault() {
            return Err(e);
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// Send one request and read one response over a fresh connection.
/// Deadline-free (a blocking submit may legitimately run for minutes);
/// callers with liveness requirements use [`request_with_timeout`].
pub fn request(addr: &str, req: &Request) -> Result<Response> {
    let stream = connect_checked(addr, None)?;
    request_over(stream, req)
}

/// [`request`] with connect/read/write timeouts — the coordinator's
/// liveness probes must not hang on a half-dead worker.
pub fn request_with_timeout(addr: &str, req: &Request, timeout: Duration) -> Result<Response> {
    let stream = connect_checked(addr, Some(timeout))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    request_over(stream, req)
}

fn request_over(stream: TcpStream, req: &Request) -> Result<Response> {
    let mut w = BufWriter::new(stream.try_clone()?);
    let line = serde_json::to_string(req)?;
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    anyhow::ensure!(!reply.trim().is_empty(), "server closed the connection without replying");
    serde_json::from_str(&reply).context("malformed response line")
}

/// Outcome of a [`hello`] handshake against a *reachable* server —
/// kept separate from transport failures (`Err`) because the two must
/// be handled differently: a rejection means version skew and is
/// fatal, an unreachable peer is merely dead and can be routed around.
#[derive(Debug)]
pub enum HelloOutcome {
    Compatible {
        proto_version: u32,
        proto_major: u32,
        features: Vec<String>,
    },
    /// The server answered but rejected the handshake (major-version
    /// mismatch) or does not speak `hello` at all (a pre-v2 server
    /// replies with a bad-request error).
    Rejected(String),
}

/// Handshake with a server. `Err` is transport-level (unreachable);
/// [`HelloOutcome::Rejected`] is a live server refusing our version.
pub fn hello(addr: &str, timeout: Duration) -> Result<HelloOutcome> {
    hello_as(addr, timeout, None)
}

/// [`hello`] carrying a client identity (v4): the server adopts it as
/// the connection's default for fair-share accounting.
pub fn hello_as(
    addr: &str,
    timeout: Duration,
    client_id: Option<&str>,
) -> Result<HelloOutcome> {
    let req = Request::Hello {
        proto_version: PROTO_VERSION,
        proto_major: PROTO_MAJOR,
        client_id: client_id.map(|s| s.to_string()),
    };
    match request_with_timeout(addr, &req, timeout)? {
        Response::Hello { proto_version, proto_major, features } => {
            Ok(HelloOutcome::Compatible { proto_version, proto_major, features })
        }
        Response::Error { message } => Ok(HelloOutcome::Rejected(message)),
        other => Ok(HelloOutcome::Rejected(format!("unexpected hello reply: {other:?}"))),
    }
}

/// Terminal outcome of a streamed submit, separating "the server
/// rejected the batch" (fatal for the whole federation — a config
/// error fails everywhere) from transport errors (`Err`, which a
/// coordinator treats as a dead worker and redistributes).
#[derive(Debug)]
pub enum StreamOutcome {
    Done(SubmitReply),
    ServerError(String),
    /// The server's admission queue is full (v3); retry after the
    /// indicated delay.
    Busy { retry_after_ms: u64 },
}

/// Submit with `stream` forced on, invoking `on_event` for every
/// incremental `result`/`progress` record. Returns when the terminal
/// `done`/`error`/`busy` record arrives; a connection that drops
/// mid-stream is an `Err` (the events already delivered remain valid —
/// that is what lets a coordinator keep a dead worker's completed
/// points).
pub fn submit_streamed(
    addr: &str,
    req: &SubmitRequest,
    on_event: impl FnMut(&Response),
) -> Result<StreamOutcome> {
    submit_streamed_with(addr, req, None, on_event)
}

/// [`submit_streamed`] with optional socket deadlines. Both directions
/// pass through the fault plane ([`FaultStream`]), so chaos runs can
/// reset or stall the stream mid-flight.
pub fn submit_streamed_with(
    addr: &str,
    req: &SubmitRequest,
    timeouts: Option<Timeouts>,
    mut on_event: impl FnMut(&Response),
) -> Result<StreamOutcome> {
    let mut req = req.clone();
    req.stream = true;
    let stream = connect_checked(addr, timeouts.map(|t| t.connect))?;
    if let Some(t) = timeouts {
        stream.set_read_timeout(Some(t.io))?;
        stream.set_write_timeout(Some(t.io))?;
    }
    let mut w = BufWriter::new(FaultStream::new(stream.try_clone()?, addr));
    let line = serde_json::to_string(&Request::Submit(req))?;
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    let reader = BufReader::new(FaultStream::new(stream, addr));
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp: Response = serde_json::from_str(&line).context("malformed stream record")?;
        match resp {
            Response::Done(reply) => return Ok(StreamOutcome::Done(reply)),
            Response::Error { message } => return Ok(StreamOutcome::ServerError(message)),
            Response::Busy { retry_after_ms } => {
                return Ok(StreamOutcome::Busy { retry_after_ms })
            }
            other => on_event(&other),
        }
    }
    anyhow::bail!("{addr}: connection closed before the terminal done record")
}

/// Mint a process-unique request id for idempotent retries. The id
/// only needs to be unique per server conversation; a stable tag hash
/// plus pid plus a process-wide counter is enough without pulling in
/// ambient randomness.
pub fn new_request_id(tag: &str) -> String {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    format!("{:016x}-{}-{n}", stable_hash(tag), std::process::id())
}

/// A streamed submit hardened for operation under failure: socket
/// deadlines, bounded seeded-jitter backoff, `busy` honoring, and an
/// idempotency `request_id` so a retry after a dropped reply attaches
/// to the in-flight batch instead of re-simulating. Replayed `result`
/// records from earlier attempts are deduplicated client-side by batch
/// index, so `on_event` sees each point at most once.
pub fn submit_resilient(
    addr: &str,
    req: &SubmitRequest,
    timeouts: Timeouts,
    retry: &RetryPolicy,
    mut on_event: impl FnMut(&Response),
) -> Result<StreamOutcome> {
    let mut req = req.clone();
    if req.request_id.is_none() {
        req.request_id = Some(new_request_id(addr));
    }
    let mut seen: HashSet<usize> = HashSet::new();
    let mut failures: u32 = 0;
    loop {
        let outcome = submit_streamed_with(addr, &req, Some(timeouts), |ev| {
            if let Response::Result(body) = ev {
                if !seen.insert(body.index) {
                    return;
                }
            }
            on_event(ev);
        });
        match outcome {
            Ok(StreamOutcome::Done(reply)) => return Ok(StreamOutcome::Done(reply)),
            Ok(StreamOutcome::ServerError(msg)) => {
                // The server rejected the batch itself (bad config,
                // unknown workload): retrying cannot help.
                return Ok(StreamOutcome::ServerError(msg));
            }
            Ok(StreamOutcome::Busy { retry_after_ms }) => {
                failures += 1;
                if failures >= retry.attempts {
                    return Ok(StreamOutcome::Busy { retry_after_ms });
                }
                let delay = retry
                    .delay(addr, failures - 1)
                    .max(Duration::from_millis(retry_after_ms));
                std::thread::sleep(delay);
            }
            Err(e) => {
                failures += 1;
                if failures >= retry.attempts {
                    return Err(e);
                }
                std::thread::sleep(retry.delay(addr, failures - 1));
            }
        }
    }
}

/// A typed client for the sweep service: one value holding the
/// address, identity, socket deadlines and retry policy that
/// `mpu submit/status/shutdown` and the federation's worker links used
/// to each re-derive by hand. Every method opens a fresh connection
/// (the protocol is stateless per line), so a `Client` is cheap to
/// clone and freely shared across threads.
#[derive(Clone, Debug)]
pub struct Client {
    addr: String,
    client_id: Option<String>,
    timeouts: Option<Timeouts>,
    retry: RetryPolicy,
}

impl Client {
    /// A client with no socket deadlines and the default retry policy —
    /// right for interactive CLI use against a local daemon, where a
    /// blocking submit may legitimately run for minutes.
    pub fn new(addr: impl Into<String>) -> Client {
        Client {
            addr: addr.into(),
            client_id: None,
            timeouts: None,
            retry: RetryPolicy::default(),
        }
    }

    /// Apply connect/io socket deadlines to every call.
    pub fn with_timeouts(mut self, timeouts: Timeouts) -> Client {
        self.timeouts = Some(timeouts);
        self
    }

    /// Replace the retry policy used by [`Client::submit_resilient`].
    pub fn with_retry(mut self, retry: RetryPolicy) -> Client {
        self.retry = retry;
        self
    }

    /// Attach a client identity (v4): sent on `hello` and stamped onto
    /// every submit that does not already carry one.
    pub fn with_identity(mut self, client_id: Option<String>) -> Client {
        self.client_id = client_id;
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn retry_policy(&self) -> &RetryPolicy {
        &self.retry
    }

    pub fn timeouts(&self) -> Option<Timeouts> {
        self.timeouts
    }

    /// One request, one response, honoring the configured deadlines.
    pub fn request(&self, req: &Request) -> Result<Response> {
        match self.timeouts {
            None => request(&self.addr, req),
            Some(t) => request_with_timeout(&self.addr, req, t.connect.max(t.io)),
        }
    }

    /// [`Client::request`] with an explicit per-call deadline (liveness
    /// probes want a tight bound regardless of the submit deadlines).
    pub fn request_timed(&self, req: &Request, timeout: Duration) -> Result<Response> {
        request_with_timeout(&self.addr, req, timeout)
    }

    /// Version/feature handshake carrying this client's identity.
    pub fn hello(&self, timeout: Duration) -> Result<HelloOutcome> {
        hello_as(&self.addr, timeout, self.client_id.as_deref())
    }

    pub fn status(&self) -> Result<StatusBody> {
        match self.request(&Request::Status)? {
            Response::Status(s) => Ok(s),
            Response::Error { message } => Err(anyhow!("{}: {message}", self.addr)),
            other => Err(anyhow!("{}: unexpected status reply: {other:?}", self.addr)),
        }
    }

    /// [`Client::status`] with a tight probe deadline.
    pub fn status_timed(&self, timeout: Duration) -> Result<StatusBody> {
        match self.request_timed(&Request::Status, timeout)? {
            Response::Status(s) => Ok(s),
            Response::Error { message } => Err(anyhow!("{}: {message}", self.addr)),
            other => Err(anyhow!("{}: unexpected status reply: {other:?}", self.addr)),
        }
    }

    pub fn metrics(&self) -> Result<MetricsBody> {
        match self.request(&Request::Metrics)? {
            Response::Metrics(m) => Ok(m),
            Response::Error { message } => Err(anyhow!("{}: {message}", self.addr)),
            other => Err(anyhow!("{}: unexpected metrics reply: {other:?}", self.addr)),
        }
    }

    pub fn shutdown(&self) -> Result<()> {
        match self.request(&Request::Shutdown)? {
            Response::Bye => Ok(()),
            Response::Error { message } => Err(anyhow!("{}: {message}", self.addr)),
            other => Err(anyhow!("{}: unexpected shutdown reply: {other:?}", self.addr)),
        }
    }

    /// Register a worker with a coordinator (v4).
    pub fn join(&self, worker: &str) -> Result<Vec<FleetWorker>> {
        self.fleet_request(Request::Join { addr: worker.to_string() })
    }

    /// Mark a worker draining on a coordinator (v4).
    pub fn drain(&self, worker: &str) -> Result<Vec<FleetWorker>> {
        self.fleet_request(Request::Drain { addr: worker.to_string() })
    }

    fn fleet_request(&self, req: Request) -> Result<Vec<FleetWorker>> {
        match self.request(&req)? {
            Response::Fleet { workers } => Ok(workers),
            Response::Error { message } => Err(anyhow!("{}: {message}", self.addr)),
            other => Err(anyhow!("{}: unexpected fleet reply: {other:?}", self.addr)),
        }
    }

    /// Stamp this client's identity onto a request that lacks one.
    fn identify(&self, req: &SubmitRequest) -> SubmitRequest {
        let mut req = req.clone();
        if req.client_id.is_none() {
            req.client_id = self.client_id.clone();
        }
        req
    }

    /// Blocking submit: one request line, one terminal reply.
    pub fn submit(&self, req: &SubmitRequest) -> Result<Response> {
        self.request(&Request::Submit(self.identify(req)))
    }

    /// One streamed submit attempt (no retries) — the federation keeps
    /// its own per-share retry loop and calls this.
    pub fn stream(
        &self,
        req: &SubmitRequest,
        on_event: impl FnMut(&Response),
    ) -> Result<StreamOutcome> {
        submit_streamed_with(&self.addr, &self.identify(req), self.timeouts, on_event)
    }

    /// Streamed submit with the full resilience stack: deadlines,
    /// bounded backoff, `busy` honoring, idempotent `request_id`
    /// retries, and client-side replay dedup.
    pub fn submit_resilient(
        &self,
        req: &SubmitRequest,
        on_event: impl FnMut(&Response),
    ) -> Result<StreamOutcome> {
        submit_resilient(
            &self.addr,
            &self.identify(req),
            self.timeouts.unwrap_or_default(),
            &self.retry,
            on_event,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plain_submit() -> SubmitRequest {
        SubmitRequest { scale: "tiny".into(), variants: vec![], ..SubmitRequest::default() }
    }

    #[test]
    fn requests_round_trip_as_jsonl() {
        let req = Request::Submit(SubmitRequest {
            suite: true,
            scale: "tiny".into(),
            config: vec![("row_buffers_per_bank".into(), "2".into())],
            priority: 3,
            stream: true,
            ..SubmitRequest::default()
        });
        let line = serde_json::to_string(&req).unwrap();
        assert!(!line.contains('\n'), "one request must fit one line");
        assert!(line.contains("\"cmd\":\"submit\""));
        let back: Request = serde_json::from_str(&line).unwrap();
        match back {
            Request::Submit(s) => {
                assert!(s.suite);
                assert_eq!(s.priority, 3);
                assert_eq!(s.variants.len(), 2);
                assert!(s.stream);
            }
            other => panic!("round-trip changed the variant: {other:?}"),
        }
    }

    #[test]
    fn v1_submit_lines_still_parse_with_v2_defaults_off() {
        // A v1 client predates stream/point_specs/return_reports; its
        // raw line must parse into the blocking defaults.
        let s: Request = serde_json::from_str(r#"{"cmd":"submit","workloads":["axpy"]}"#).unwrap();
        match s {
            Request::Submit(s) => {
                assert_eq!(s.scale, "small");
                assert_eq!(s.variants, vec!["mpu".to_string(), "gpu".to_string()]);
                assert_eq!(s.priority, 0);
                assert!(!s.fresh && !s.suite);
                assert!(!s.stream, "v1 lines must stay blocking");
                assert!(s.point_specs.is_empty());
                assert!(!s.return_reports);
            }
            other => panic!("expected submit, got {other:?}"),
        }
    }

    #[test]
    fn hello_round_trips_and_defaults_major() {
        let line = r#"{"cmd":"hello","proto_version":2}"#;
        match serde_json::from_str::<Request>(line).unwrap() {
            Request::Hello { proto_version, proto_major, client_id } => {
                assert_eq!(proto_version, 2);
                assert_eq!(proto_major, PROTO_MAJOR);
                assert!(client_id.is_none(), "pre-v4 hello has no identity");
            }
            other => panic!("expected hello, got {other:?}"),
        }
        let resp = Response::Hello {
            proto_version: PROTO_VERSION,
            proto_major: PROTO_MAJOR,
            features: FEATURES.iter().map(|f| f.to_string()).collect(),
        };
        let body = serde_json::to_string(&resp).unwrap();
        assert!(body.contains("\"resp\":\"hello\""));
        assert!(body.contains("point_specs"));
    }

    #[test]
    fn points_expand_variant_major() {
        let mut s = plain_submit();
        s.workloads = vec!["axpy".into(), "knn".into()];
        s.variants = vec!["mpu".into(), "ideal".into()];
        let pts = s.points().unwrap();
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0].label, "mpu");
        assert_eq!(pts[0].workload, Workload::Axpy);
        assert_eq!(pts[2].label, "ideal");
        assert_eq!(pts[3].workload, Workload::Knn);
    }

    #[test]
    fn point_specs_override_the_cross_product() {
        let mut s = plain_submit();
        // The cross-product fields are stale/empty; point_specs wins.
        s.workloads = vec!["axpy".into()];
        s.variants = vec!["gpu".into()];
        s.point_specs = vec![
            PointSpec { workload: "knn".into(), variant: "mpu".into(), config: vec![] },
            PointSpec { workload: "axpy".into(), variant: "ideal".into(), config: vec![] },
        ];
        let pts = s.points().unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].workload, Workload::Knn);
        assert_eq!(pts[0].label, "mpu");
        assert_eq!(pts[1].workload, Workload::Axpy);
        assert_eq!(pts[1].label, "ideal");
        // A bogus spec is rejected like any other name.
        s.point_specs
            .push(PointSpec { workload: "nope".into(), variant: "mpu".into(), config: vec![] });
        assert!(s.points().is_err());
    }

    #[test]
    fn bad_names_are_rejected() {
        let mut s = plain_submit();
        s.workloads = vec!["nope".into()];
        s.variants = vec!["mpu".into()];
        assert!(s.points().is_err());
        s.workloads = vec!["axpy".into()];
        s.scale = "huge".into();
        assert!(s.points().is_err());
        s.scale = "tiny".into();
        s.variants = vec!["tpu".into()];
        assert!(s.points().is_err());
        s.variants = vec![];
        assert!(s.points().is_err());
    }

    #[test]
    fn status_body_v1_reply_parses_with_defaults() {
        // A v1 server's status reply lacks every v2 field; a v2 client
        // must still parse it (append-only discipline).
        let v1 = r#"{"resp":"status","proto_version":1,"uptime_ms":5,"requests":1,
            "points":2,"simulated":2,"mem_hits":0,"disk_hits":0,"dedup_waits":0,
            "kernels_compiled":1,"mem_entries":2,"store":null}"#;
        match serde_json::from_str::<Response>(v1).unwrap() {
            Response::Status(s) => {
                assert_eq!(s.proto_version, 1);
                assert_eq!(s.proto_major, 0, "v1 reply defaults major to 0");
                assert_eq!(s.queue_depth, 0);
                assert_eq!(s.inflight, 0);
                assert!(s.workers.is_none());
            }
            other => panic!("expected status, got {other:?}"),
        }
    }

    #[test]
    fn request_id_round_trips_and_defaults_off() {
        // v2 lines lack request_id; it must default to None.
        let s: Request = serde_json::from_str(r#"{"cmd":"submit","suite":true}"#).unwrap();
        match s {
            Request::Submit(s) => assert!(s.request_id.is_none()),
            other => panic!("expected submit, got {other:?}"),
        }
        let mut req = SubmitRequest { suite: true, ..SubmitRequest::default() };
        // None is skipped on the wire (v2 servers never see the field).
        let line = serde_json::to_string(&Request::Submit(req.clone())).unwrap();
        assert!(!line.contains("request_id"));
        req.request_id = Some("abc-1".into());
        let line = serde_json::to_string(&Request::Submit(req)).unwrap();
        assert!(line.contains(r#""request_id":"abc-1""#));
        match serde_json::from_str::<Request>(&line).unwrap() {
            Request::Submit(s) => assert_eq!(s.request_id.as_deref(), Some("abc-1")),
            other => panic!("expected submit, got {other:?}"),
        }
    }

    #[test]
    fn busy_response_round_trips() {
        let line = serde_json::to_string(&Response::Busy { retry_after_ms: 200 }).unwrap();
        assert!(line.contains(r#""resp":"busy""#));
        match serde_json::from_str::<Response>(&line).unwrap() {
            Response::Busy { retry_after_ms } => assert_eq!(retry_after_ms, 200),
            other => panic!("expected busy, got {other:?}"),
        }
    }

    #[test]
    fn v2_done_and_status_parse_with_v3_defaults() {
        // A v2 server's done reply has no `degraded`; a v3 client must
        // parse it as the non-degraded default.
        let v2 = r#"{"resp":"done","points":1,"simulated":1,"mem_hits":0,
            "disk_hits":0,"deduped":0,"elapsed_ms":3,"results":[]}"#;
        match serde_json::from_str::<Response>(v2).unwrap() {
            Response::Done(r) => assert!(!r.degraded),
            other => panic!("expected done, got {other:?}"),
        }
        let v2 = r#"{"resp":"status","proto_version":2,"uptime_ms":5,"requests":1,
            "points":2,"simulated":2,"mem_hits":0,"disk_hits":0,"dedup_waits":0,
            "kernels_compiled":1,"mem_entries":2,"store":null}"#;
        match serde_json::from_str::<Response>(v2).unwrap() {
            Response::Status(s) => {
                assert_eq!(s.admission_rejected, 0);
                assert_eq!(s.queue_limit, 0);
                assert_eq!(s.retries, 0);
                assert_eq!(s.degraded_batches, 0);
            }
            other => panic!("expected status, got {other:?}"),
        }
    }

    #[test]
    fn request_ids_are_unique() {
        let a = new_request_id("w1");
        let b = new_request_id("w1");
        assert_ne!(a, b);
        assert!(a.contains('-'));
    }

    #[test]
    fn v4_metrics_and_membership_records_round_trip() {
        let req = serde_json::to_string(&Request::Metrics).unwrap();
        assert!(req.contains(r#""cmd":"metrics""#));
        let body = MetricsBody {
            schema_version: METRICS_SCHEMA_VERSION,
            report: "metrics".into(),
            queue_depth: 3,
            cache_hit_rate: 0.5,
            clients: vec![ClientMetrics {
                client_id: "alice".into(),
                weight: 3,
                queued: 2,
                completed: 7,
                rejected: 1,
            }],
            workers: vec![WorkerMetrics {
                addr: "127.0.0.1:7201".into(),
                alive: true,
                draining: true,
                sim_cycles_per_sec: 1e6,
                ..WorkerMetrics::default()
            }],
            ..MetricsBody::default()
        };
        let line = serde_json::to_string(&Response::Metrics(body)).unwrap();
        assert!(line.contains(r#""resp":"metrics""#));
        match serde_json::from_str::<Response>(&line).unwrap() {
            Response::Metrics(m) => {
                assert_eq!(m.schema_version, METRICS_SCHEMA_VERSION);
                assert_eq!(m.clients[0].client_id, "alice");
                assert!(m.workers[0].draining);
            }
            other => panic!("expected metrics, got {other:?}"),
        }
        for (req, wire) in [
            (Request::Join { addr: "w:1".into() }, r#""cmd":"join""#),
            (Request::Drain { addr: "w:1".into() }, r#""cmd":"drain""#),
        ] {
            let line = serde_json::to_string(&req).unwrap();
            assert!(line.contains(wire), "{line}");
            serde_json::from_str::<Request>(&line).unwrap();
        }
        let ack = Response::Fleet {
            workers: vec![FleetWorker { addr: "w:1".into(), draining: false }],
        };
        let line = serde_json::to_string(&ack).unwrap();
        match serde_json::from_str::<Response>(&line).unwrap() {
            Response::Fleet { workers } => assert_eq!(workers[0].addr, "w:1"),
            other => panic!("expected fleet, got {other:?}"),
        }
    }

    #[test]
    fn v3_lines_parse_with_v4_defaults() {
        // A v3 client's hello and submit lack client_id; a v3 spec
        // lacks per-spec config. All must parse to the v4 defaults.
        let s: Request = serde_json::from_str(
            r#"{"cmd":"submit","point_specs":[{"workload":"axpy","variant":"mpu"}]}"#,
        )
        .unwrap();
        match s {
            Request::Submit(s) => {
                assert!(s.client_id.is_none());
                assert!(s.point_specs[0].config.is_empty());
            }
            other => panic!("expected submit, got {other:?}"),
        }
        // And the v4 fields are skipped on the wire when defaulted, so
        // a v3 server never sees unknown keys from a v4 client.
        let line = serde_json::to_string(&Request::Submit(SubmitRequest {
            point_specs: vec![PointSpec {
                workload: "axpy".into(),
                variant: "mpu".into(),
                config: vec![],
            }],
            ..SubmitRequest::default()
        }))
        .unwrap();
        assert!(!line.contains("client_id"));
        assert!(!line.contains("config\":[]"));
        // A v4 metrics doc parsed by a future reader keeps defaults for
        // fields it predates (append-only discipline, like status).
        let v4 = r#"{"resp":"metrics","schema_version":1,"report":"metrics"}"#;
        match serde_json::from_str::<Response>(v4).unwrap() {
            Response::Metrics(m) => {
                assert_eq!(m.queue_depth, 0);
                assert!(m.clients.is_empty() && m.workers.is_empty());
                assert!(m.store.is_none());
            }
            other => panic!("expected metrics, got {other:?}"),
        }
    }

    #[test]
    fn per_spec_config_overrides_the_base_config() {
        let mut s = plain_submit();
        s.config = vec![("row_buffers_per_bank".into(), "2".into())];
        s.point_specs = vec![
            PointSpec { workload: "axpy".into(), variant: "mpu".into(), config: vec![] },
            PointSpec {
                workload: "axpy".into(),
                variant: "mpu".into(),
                config: vec![("row_buffers_per_bank".into(), "4".into())],
            },
        ];
        let pts = s.points().unwrap();
        assert_eq!(pts.len(), 2);
        assert_ne!(
            pts[0].cache_key(),
            pts[1].cache_key(),
            "per-spec overrides must yield a distinct point"
        );
        // A bad per-spec knob is rejected like a bad base knob.
        s.point_specs[1].config = vec![("warp_speed".into(), "9".into())];
        assert!(s.points().is_err());
    }

    #[test]
    fn client_stamps_identity_onto_submits() {
        let c = Client::new("127.0.0.1:1").with_identity(Some("alice".into()));
        let stamped = c.identify(&SubmitRequest::default());
        assert_eq!(stamped.client_id.as_deref(), Some("alice"));
        // An explicit per-request identity wins over the client's.
        let own = SubmitRequest {
            client_id: Some("bob".into()),
            ..SubmitRequest::default()
        };
        assert_eq!(c.identify(&own).client_id.as_deref(), Some("bob"));
    }

    #[test]
    fn wire_report_round_trips() {
        let cfg = MachineConfig::scaled();
        let r = crate::coordinator::run_workload_scaled(Workload::Axpy, &cfg, Scale::Tiny)
            .unwrap();
        let wire = WireReport::from_report(Scale::Tiny, &r);
        let body = serde_json::to_string(&wire).unwrap();
        let back: WireReport = serde_json::from_str(&body).unwrap();
        let rr = back.into_report().expect("known names reconstruct");
        assert_eq!(rr.workload, r.workload);
        assert_eq!(rr.machine, r.machine);
        assert_eq!(rr.cycles, r.cycles);
        assert_eq!(rr.stats, r.stats);
        let a: Vec<u32> = rr.output.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = r.output.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "outputs must survive the wire bit-exactly");
        // Foreign machine names are rejected, not trusted.
        let mut alien = WireReport::from_report(Scale::Tiny, &r);
        alien.machine = "tpu".into();
        assert!(alien.into_report().is_none());
    }
}
