//! Multi-daemon sweep federation: shard one batch across worker
//! daemons by consistent hashing, merge the streamed results back into
//! point order, and survive worker deaths by redistributing their
//! unfinished points.
//!
//! Topology: any number of `mpu serve` **workers** (each a full local
//! [`Service`](super::service::Service) with its own two-tier
//! cache/store), fronted either by a client-side [`Federation`]
//! (`mpu submit --workers a,b,c`) or by a resident [`Coordinator`]
//! daemon (`mpu serve --workers a,b,c`) that speaks the same JSONL
//! protocol to its own clients.
//!
//! Sharding: each point maps onto a hash ring by the stable FNV-1a of
//! its content-addressed store key (`SweepPoint::cache_key`), with
//! [`VNODES`] virtual nodes per worker hashed from the worker address.
//! Consistent hashing means a worker-set change only remaps the points
//! of the workers that changed — the rest of the fleet keeps its warm
//! stores. Workers run their shares concurrently and stream results
//! back (`stream` + `point_specs` + `return_reports`, protocol v2);
//! the federation records each completed point as it arrives, so when
//! a worker dies mid-batch only its *unfinished* points are
//! repartitioned over the survivors on the next round.

use super::fault::{RetryPolicy, Timeouts};
use super::proto::{
    self, PointSpec, PointSummary, ProgressBody, Request, Response, ResultBody, StatusBody,
    StreamOutcome, SubmitReply, SubmitRequest, WireReport, WorkerStatus, PROTO_MAJOR,
    PROTO_VERSION,
};
use super::service::{summarize, write_line, PointSource, Service};
use super::sweep::stable_hash;
use super::RunReport;
use anyhow::Result;
use std::collections::HashMap;
use std::io::BufWriter;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Virtual nodes per worker on the hash ring. Enough that a small
/// fleet's shares stay balanced (the imbalance of a 2-worker ring is a
/// few percent, not a coin flip).
pub const VNODES: usize = 64;

/// Liveness-probe / handshake timeout.
const PROBE_TIMEOUT: Duration = Duration::from_secs(2);

/// An incremental federation event, forwarded to the submitting
/// client: one merged `result` per completed point (indices in the
/// *original* batch order) and a monotonically increasing `progress`.
pub enum FedEvent<'a> {
    Result {
        index: usize,
        summary: &'a PointSummary,
        report: Option<&'a WireReport>,
    },
    Progress {
        completed: usize,
        total: usize,
        elapsed_ms: u64,
    },
}

/// A merged federated reply: the protocol reply (point order) plus the
/// full reports when the request asked for them (`return_reports`).
pub struct FedReply {
    pub reply: SubmitReply,
    /// One entry per point, `Some` only when `return_reports` was set
    /// and the worker's report reconstructed cleanly.
    pub reports: Vec<Option<RunReport>>,
}

/// A fixed set of worker daemons a batch can be sharded across.
pub struct Federation {
    workers: Vec<String>,
    /// Socket deadlines on worker links.
    timeouts: Timeouts,
    /// Bounded backoff applied before a worker failure is treated as
    /// fatal (transient errors) or as death (transport errors).
    retry: RetryPolicy,
    /// Local simulation fallback for a batch whose workers all died;
    /// `None` keeps the historical all-dead hard failure.
    fallback: Option<Arc<Service>>,
    retries: AtomicU64,
    degraded_batches: AtomicU64,
}

/// Shared mutable state of one federated submit: the merge slots and
/// the caller's event sink, behind one lock so events are emitted in a
/// consistent order across worker threads.
struct Merge<F> {
    summaries: Vec<Option<PointSummary>>,
    reports: Vec<Option<WireReport>>,
    completed: usize,
    on_event: F,
}

impl Federation {
    pub fn new(workers: Vec<String>) -> Result<Federation> {
        Federation::with_config(workers, Timeouts::default(), RetryPolicy::default())
    }

    /// [`Federation::new`] with explicit deadlines and retry policy
    /// (from [`ServeConfig`](crate::config::ServeConfig) knobs).
    pub fn with_config(
        workers: Vec<String>,
        timeouts: Timeouts,
        retry: RetryPolicy,
    ) -> Result<Federation> {
        let workers: Vec<String> =
            workers.into_iter().map(|w| w.trim().to_string()).filter(|w| !w.is_empty()).collect();
        anyhow::ensure!(!workers.is_empty(), "a federation needs at least one worker address");
        Ok(Federation {
            workers,
            timeouts,
            retry,
            fallback: None,
            retries: AtomicU64::new(0),
            degraded_batches: AtomicU64::new(0),
        })
    }

    /// Attach a local [`Service`] to simulate the leftover points of a
    /// batch whose workers have all died (graceful degradation; the
    /// reply carries `degraded: true`).
    pub fn set_fallback(&mut self, svc: Arc<Service>) {
        self.fallback = Some(svc);
    }

    /// Worker-link operations retried after a transient failure.
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Batches that fell back to local simulation.
    pub fn degraded_batches(&self) -> u64 {
        self.degraded_batches.load(Ordering::Relaxed)
    }

    pub fn workers(&self) -> &[String] {
        &self.workers
    }

    /// Handshake with every reachable worker; a *live* worker that
    /// rejects the handshake (protocol-major skew, pre-v2 server) or
    /// lacks the `point_specs`/`stream` features is a hard error — it
    /// would corrupt batches. Only an unreachable worker is tolerated:
    /// submits route around dead workers anyway.
    pub fn handshake(&self) -> Result<usize> {
        let mut reachable = 0;
        for addr in &self.workers {
            match proto::hello(addr, PROBE_TIMEOUT) {
                Ok(proto::HelloOutcome::Compatible { proto_version, proto_major, features }) => {
                    anyhow::ensure!(
                        proto_major == PROTO_MAJOR,
                        "worker {addr} speaks protocol major {proto_major}, coordinator \
                         speaks {PROTO_MAJOR}"
                    );
                    for need in ["stream", "point_specs"] {
                        anyhow::ensure!(
                            features.iter().any(|f| f == need),
                            "worker {addr} (proto v{proto_version}) lacks the `{need}` \
                             feature a coordinator requires — upgrade it"
                        );
                    }
                    reachable += 1;
                }
                Ok(proto::HelloOutcome::Rejected(msg)) => {
                    anyhow::bail!("worker {addr} rejected the handshake: {msg}")
                }
                Err(_) => continue,
            }
        }
        Ok(reachable)
    }

    /// The hash ring over a set of worker indices.
    fn ring(&self, alive: &[usize]) -> Vec<(u64, usize)> {
        let mut ring = Vec::with_capacity(alive.len() * VNODES);
        for &wi in alive {
            for v in 0..VNODES {
                ring.push((stable_hash(&format!("{}#{v}", self.workers[wi])), wi));
            }
        }
        ring.sort_unstable();
        ring
    }

    /// Partition `pending` (indices into `keys`) across the `alive`
    /// workers by consistent hashing on the stable store key. Returns
    /// `(worker index, point indices)` shares, sorted by worker.
    pub fn partition(
        &self,
        keys: &[String],
        pending: &[usize],
        alive: &[usize],
    ) -> Vec<(usize, Vec<usize>)> {
        let ring = self.ring(alive);
        let mut shares: HashMap<usize, Vec<usize>> = HashMap::new();
        for &pi in pending {
            let h = stable_hash(&keys[pi]);
            let at = ring.partition_point(|&(pos, _)| pos < h);
            let (_, wi) = ring[at % ring.len()];
            shares.entry(wi).or_default().push(pi);
        }
        let mut out: Vec<(usize, Vec<usize>)> = shares.into_iter().collect();
        out.sort();
        out
    }

    /// Shard a batch across the fleet, streaming merged events as
    /// points complete. Every worker link gets deadlines and a bounded
    /// seeded-backoff retry (idempotent via `request_id`); points of a
    /// worker that stays dead are repartitioned across the survivors
    /// (their already-streamed results are kept). The submit fails only
    /// when a worker keeps rejecting the batch (a config error fails
    /// everywhere) or when no alive worker remains *and* no local
    /// fallback is attached — with one, the leftovers are simulated
    /// locally and the reply is flagged `degraded`.
    pub fn submit_streamed(
        &self,
        req: &SubmitRequest,
        on_event: impl FnMut(FedEvent<'_>) + Send,
    ) -> Result<FedReply> {
        let points = req.points()?;
        let total = points.len();
        let keys: Vec<String> = points.iter().map(|p| p.cache_key()).collect();
        let specs: Vec<PointSpec> = points
            .iter()
            .map(|p| PointSpec { workload: p.workload.name().to_string(), variant: p.label.clone() })
            .collect();
        let t0 = Instant::now();
        let merge = Mutex::new(Merge {
            summaries: vec![None; total],
            reports: vec![None; total],
            completed: 0,
            on_event,
        });
        let mut alive: Vec<bool> = vec![true; self.workers.len()];
        let mut degraded = false;
        loop {
            let pending: Vec<usize> = {
                let m = merge.lock().unwrap();
                (0..total).filter(|&i| m.summaries[i].is_none()).collect()
            };
            if pending.is_empty() {
                break;
            }
            let alive_idx: Vec<usize> =
                (0..alive.len()).filter(|&i| alive[i]).collect();
            if alive_idx.is_empty() {
                let Some(fallback) = &self.fallback else {
                    anyhow::bail!(
                        "every worker died with {} of {total} points unfinished",
                        pending.len()
                    );
                };
                // Graceful degradation: the whole fleet is gone, so
                // simulate the leftover points locally. Results stay
                // exact; the reply's `degraded` flag records that the
                // serving path was impaired.
                self.degraded_batches.fetch_add(1, Ordering::Relaxed);
                degraded = true;
                let fb_points: Vec<_> = pending.iter().map(|&i| points[i].clone()).collect();
                let job = fallback.submit(fb_points, req.priority, req.fresh);
                let results = job.wait()?;
                let mut guard = merge.lock().unwrap();
                let m = &mut *guard;
                for (&global, pr) in pending.iter().zip(&results) {
                    if m.summaries[global].is_some() {
                        continue;
                    }
                    m.summaries[global] = Some(summarize(&pr.point, &pr.report, pr.source));
                    m.reports[global] = req
                        .return_reports
                        .then(|| WireReport::from_report(pr.point.scale, &pr.report));
                    m.completed += 1;
                    let completed = m.completed;
                    let summary = m.summaries[global].as_ref().unwrap();
                    let report = m.reports[global].as_ref();
                    (m.on_event)(FedEvent::Result { index: global, summary, report });
                    (m.on_event)(FedEvent::Progress {
                        completed,
                        total,
                        elapsed_ms: t0.elapsed().as_millis() as u64,
                    });
                }
                break;
            }
            let shares = self.partition(&keys, &pending, &alive_idx);
            let outcomes: Vec<(usize, Result<StreamOutcome>)> = std::thread::scope(|scope| {
                let handles: Vec<_> = shares
                    .iter()
                    .map(|(wi, share)| {
                        let wi = *wi;
                        let addr = self.workers[wi].as_str();
                        let share = share.clone();
                        let wreq = SubmitRequest {
                            scale: req.scale.clone(),
                            config: req.config.clone(),
                            priority: req.priority,
                            fresh: req.fresh,
                            point_specs: share.iter().map(|&i| specs[i].clone()).collect(),
                            return_reports: req.return_reports,
                            stream: true,
                            suite: false,
                            workloads: vec![],
                            variants: vec![],
                            // One id per share, reused across retry
                            // attempts: a retried stream attaches to
                            // the worker's in-flight job instead of
                            // re-simulating, and replays of finished
                            // points hit the duplicate-index skip in
                            // the merge below.
                            request_id: Some(proto::new_request_id(addr)),
                        };
                        let merge = &merge;
                        let timeouts = self.timeouts;
                        let retry = self.retry;
                        let retries_ctr = &self.retries;
                        scope.spawn(move || {
                            let merge_one = |resp: &Response| {
                                let Response::Result(body) = resp else { return };
                                // The worker's indices address its share.
                                let Some(&global) = share.get(body.index) else { return };
                                let mut guard = merge.lock().unwrap();
                                let m = &mut *guard;
                                if m.summaries[global].is_some() {
                                    return;
                                }
                                m.summaries[global] = Some(body.point.clone());
                                m.reports[global] = body.report.clone();
                                m.completed += 1;
                                let completed = m.completed;
                                let summary = m.summaries[global].as_ref().unwrap();
                                let report = m.reports[global].as_ref();
                                (m.on_event)(FedEvent::Result { index: global, summary, report });
                                (m.on_event)(FedEvent::Progress {
                                    completed,
                                    total,
                                    elapsed_ms: t0.elapsed().as_millis() as u64,
                                });
                            };
                            // Bounded retry with seeded-jitter backoff:
                            // transient rejections, busy signals and
                            // transport hiccups get `retry.attempts`
                            // tries before the worker is treated as
                            // failed/dead for this batch.
                            let mut failures: u32 = 0;
                            let res = loop {
                                let attempt = proto::submit_streamed_with(
                                    addr,
                                    &wreq,
                                    Some(timeouts),
                                    |resp| merge_one(resp),
                                );
                                match attempt {
                                    Ok(StreamOutcome::Done(reply)) => {
                                        break Ok(StreamOutcome::Done(reply))
                                    }
                                    Ok(StreamOutcome::ServerError(msg)) => {
                                        failures += 1;
                                        if failures >= retry.attempts {
                                            break Ok(StreamOutcome::ServerError(msg));
                                        }
                                    }
                                    Ok(StreamOutcome::Busy { retry_after_ms }) => {
                                        failures += 1;
                                        if failures >= retry.attempts {
                                            break Err(anyhow::anyhow!(
                                                "worker {addr} stayed busy through \
                                                 {failures} attempts"
                                            ));
                                        }
                                        retries_ctr.fetch_add(1, Ordering::Relaxed);
                                        std::thread::sleep(
                                            retry
                                                .delay(addr, failures - 1)
                                                .max(Duration::from_millis(retry_after_ms)),
                                        );
                                        continue;
                                    }
                                    Err(e) => {
                                        failures += 1;
                                        if failures >= retry.attempts {
                                            break Err(e);
                                        }
                                    }
                                }
                                retries_ctr.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(retry.delay(addr, failures - 1));
                            };
                            (wi, res)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("worker thread panicked")).collect()
            });
            let mut lost_worker = false;
            for (wi, res) in outcomes {
                match res {
                    Ok(StreamOutcome::Done(_)) => {}
                    // A rejected batch (unknown workload, bad config) is
                    // fatal: the same request fails on every worker.
                    Ok(StreamOutcome::ServerError(msg)) => {
                        anyhow::bail!("worker {} rejected the batch: {msg}", self.workers[wi])
                    }
                    // Transport death: mark dead, redistribute next round.
                    Err(_) => {
                        alive[wi] = false;
                        lost_worker = true;
                    }
                }
            }
            let still_pending = {
                let m = merge.lock().unwrap();
                (0..total).filter(|&i| m.summaries[i].is_none()).count()
            };
            if still_pending > 0 && !lost_worker {
                anyhow::bail!(
                    "workers reported done but {still_pending} of {total} points never \
                     arrived (protocol skew?)"
                );
            }
        }
        let m = merge.into_inner().unwrap();
        let summaries: Vec<PointSummary> =
            m.summaries.into_iter().map(|s| s.expect("merged batch has empty slot")).collect();
        let count = |want: PointSource| {
            summaries
                .iter()
                .filter(|s| PointSource::from_name(&s.source) == Some(want))
                .count()
        };
        let reply = SubmitReply {
            points: total,
            simulated: count(PointSource::Simulated),
            mem_hits: count(PointSource::MemHit),
            disk_hits: count(PointSource::DiskHit),
            deduped: count(PointSource::Dedup),
            elapsed_ms: t0.elapsed().as_millis() as u64,
            results: summaries,
            degraded,
        };
        Ok(FedReply {
            reply,
            reports: m.reports.into_iter().map(|r| r.and_then(|w| w.into_report())).collect(),
        })
    }

    /// Blocking federated submit (no event forwarding).
    pub fn submit(&self, req: &SubmitRequest) -> Result<FedReply> {
        self.submit_streamed(req, |_| {})
    }

    /// Probe every worker's `status` — the coordinator's per-worker
    /// liveness view. Probes run concurrently so a fleet of dead
    /// workers costs one probe timeout, not one per worker.
    pub fn worker_statuses(&self) -> Vec<WorkerStatus> {
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .workers
                .iter()
                .map(|addr| {
                    scope.spawn(move || {
                        match proto::request_with_timeout(addr, &Request::Status, PROBE_TIMEOUT) {
                            Ok(Response::Status(s)) => WorkerStatus {
                                addr: addr.clone(),
                                alive: true,
                                proto_version: s.proto_version,
                                points: s.points,
                                simulated: s.simulated,
                                queue_depth: s.queue_depth,
                                inflight: s.inflight,
                            },
                            _ => WorkerStatus {
                                addr: addr.clone(),
                                alive: false,
                                proto_version: 0,
                                points: 0,
                                simulated: 0,
                                queue_depth: 0,
                                inflight: 0,
                            },
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("status probe panicked")).collect()
        })
    }
}

/// The resident coordinator daemon (`mpu serve --workers ...`): the
/// same JSONL server surface as a local daemon, but submits are
/// federated across the worker fleet instead of simulated in-process.
pub struct Coordinator {
    fed: Federation,
    started: Instant,
    requests: AtomicU64,
    points: AtomicU64,
    active: Mutex<u64>,
    idle_cv: Condvar,
}

impl Coordinator {
    pub fn new(mut fed: Federation) -> Coordinator {
        // A resident coordinator always degrades gracefully: if the
        // whole fleet dies mid-batch it simulates the leftovers
        // locally (storeless) rather than failing the client.
        if fed.fallback.is_none() {
            fed.set_fallback(Arc::new(Service::new(None)));
        }
        Coordinator {
            fed,
            started: Instant::now(),
            requests: AtomicU64::new(0),
            points: AtomicU64::new(0),
            active: Mutex::new(0),
            idle_cv: Condvar::new(),
        }
    }

    pub fn federation(&self) -> &Federation {
        &self.fed
    }

    /// Drain latch for graceful shutdown (mirror of
    /// [`Service::wait_idle`](super::service::Service::wait_idle)).
    pub fn wait_idle(&self) {
        let mut n = self.active.lock().unwrap();
        while *n > 0 {
            n = self.idle_cv.wait(n).unwrap();
        }
    }

    /// Coordinator status: own request counters plus a per-worker
    /// liveness table and fleet-aggregated queue/in-flight depths.
    pub fn status(&self) -> StatusBody {
        let workers = self.fed.worker_statuses();
        let sum = |f: fn(&WorkerStatus) -> u64| workers.iter().filter(|w| w.alive).map(f).sum();
        StatusBody {
            proto_version: PROTO_VERSION,
            uptime_ms: self.started.elapsed().as_millis() as u64,
            requests: self.requests.load(Ordering::Relaxed),
            points: self.points.load(Ordering::Relaxed),
            simulated: sum(|w| w.simulated),
            mem_hits: 0,
            disk_hits: 0,
            dedup_waits: 0,
            kernels_compiled: 0,
            mem_entries: 0,
            store: None,
            proto_major: PROTO_MAJOR,
            queue_depth: workers.iter().filter(|w| w.alive).map(|w| w.queue_depth).sum(),
            inflight: workers.iter().filter(|w| w.alive).map(|w| w.inflight).sum(),
            active_requests: *self.active.lock().unwrap(),
            workers: Some(workers),
            admission_rejected: 0,
            queue_limit: 0,
            retries: self.fed.retries(),
            degraded_batches: self.fed.degraded_batches(),
        }
    }

    /// Serve one submit from a coordinator connection: federate it,
    /// forwarding merged `result`/`progress` records when the client
    /// asked to stream, then write the terminal `done`/`error`.
    pub fn serve_submit(
        &self,
        req: &SubmitRequest,
        writer: &mut BufWriter<TcpStream>,
    ) -> std::io::Result<()> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        *self.active.lock().unwrap() += 1;
        let stream = req.stream;
        let want_reports = req.return_reports;
        let mut io_err: Option<std::io::Error> = None;
        let res = self.fed.submit_streamed(req, |ev| {
            if !stream || io_err.is_some() {
                return;
            }
            let resp = match ev {
                FedEvent::Result { index, summary, report } => Response::Result(ResultBody {
                    index,
                    point: summary.clone(),
                    report: if want_reports { report.cloned() } else { None },
                }),
                FedEvent::Progress { completed, total, elapsed_ms } => {
                    Response::Progress(ProgressBody { completed, total, elapsed_ms })
                }
            };
            if let Err(e) = write_line(writer, &resp) {
                io_err = Some(e);
            }
        });
        {
            let mut n = self.active.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                self.idle_cv.notify_all();
            }
        }
        if let Some(e) = io_err {
            return Err(e);
        }
        let resp = match res {
            Ok(fr) => {
                self.points.fetch_add(fr.reply.points as u64, Ordering::Relaxed);
                Response::Done(fr.reply)
            }
            Err(e) => Response::Error { message: e.to_string() },
        };
        write_line(writer, &resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fed(addrs: &[&str]) -> Federation {
        Federation::new(addrs.iter().map(|a| a.to_string()).collect()).unwrap()
    }

    fn keys(n: usize) -> Vec<String> {
        // Shaped like real store keys.
        (0..n).map(|i| format!("wl{i}-tiny-mpu-{i:016x}")).collect()
    }

    #[test]
    fn empty_federation_is_rejected() {
        assert!(Federation::new(vec![]).is_err());
        assert!(Federation::new(vec!["  ".into(), "".into()]).is_err());
        let f = Federation::new(vec![" 127.0.0.1:1 ".into()]).unwrap();
        assert_eq!(f.workers(), ["127.0.0.1:1"]);
    }

    #[test]
    fn partition_covers_all_points_disjointly() {
        let f = fed(&["127.0.0.1:7201", "127.0.0.1:7202", "127.0.0.1:7203"]);
        let ks = keys(64);
        let pending: Vec<usize> = (0..ks.len()).collect();
        let shares = f.partition(&ks, &pending, &[0, 1, 2]);
        let mut seen: Vec<usize> = shares.iter().flat_map(|(_, pts)| pts.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, pending, "every point assigned exactly once");
        // Deterministic: the same inputs give the same shares.
        assert_eq!(f.partition(&ks, &pending, &[0, 1, 2]), shares);
    }

    #[test]
    fn removing_a_worker_only_remaps_its_share() {
        let f = fed(&["127.0.0.1:7201", "127.0.0.1:7202", "127.0.0.1:7203"]);
        let ks = keys(96);
        let pending: Vec<usize> = (0..ks.len()).collect();
        let owner_of = |shares: &Vec<(usize, Vec<usize>)>| {
            let mut owner = vec![usize::MAX; ks.len()];
            for (wi, pts) in shares {
                for &p in pts {
                    owner[p] = *wi;
                }
            }
            owner
        };
        let full = owner_of(&f.partition(&ks, &pending, &[0, 1, 2]));
        let reduced = owner_of(&f.partition(&ks, &pending, &[0, 2]));
        for (p, (&a, &b)) in full.iter().zip(&reduced).enumerate() {
            if a != 1 {
                assert_eq!(a, b, "point {p} moved although its worker survived");
            } else {
                assert!(b == 0 || b == 2, "dead worker's point must land on a survivor");
            }
        }
        // The dead worker's share actually existed (the ring is balanced
        // enough that 96 keys never all miss one of three workers).
        assert!(full.iter().any(|&w| w == 1));
    }

    #[test]
    fn two_worker_shares_are_nonempty_for_the_tiny_suite() {
        // The shard-smoke CI job asserts both workers simulate a
        // nonempty share of the 24-point tiny suite; pin that property
        // here with the real cache keys.
        use crate::coordinator::proto::SubmitRequest;
        let req = SubmitRequest {
            suite: true,
            scale: "tiny".into(),
            variants: vec!["mpu".into(), "gpu".into()],
            ..SubmitRequest::default()
        };
        let points = req.points().unwrap();
        let ks: Vec<String> = points.iter().map(|p| p.cache_key()).collect();
        let pending: Vec<usize> = (0..ks.len()).collect();
        let f = fed(&["127.0.0.1:7201", "127.0.0.1:7202"]);
        let shares = f.partition(&ks, &pending, &[0, 1]);
        assert_eq!(shares.len(), 2, "both workers must get a share: {shares:?}");
        assert!(shares.iter().all(|(_, pts)| !pts.is_empty()));
        let total: usize = shares.iter().map(|(_, pts)| pts.len()).sum();
        assert_eq!(total, 24);
    }
}
