//! Multi-daemon sweep federation: shard one batch across worker
//! daemons by consistent hashing, merge the streamed results back into
//! point order, and survive worker deaths by redistributing their
//! unfinished points.
//!
//! Topology: any number of `mpu serve` **workers** (each a full local
//! [`Service`](super::service::Service) with its own two-tier
//! cache/store), fronted either by a client-side [`Federation`]
//! (`mpu submit --workers a,b,c`) or by a resident [`Coordinator`]
//! daemon (`mpu serve --workers a,b,c`) that speaks the same JSONL
//! protocol to its own clients.
//!
//! Sharding: each point maps onto a hash ring by the stable FNV-1a of
//! its content-addressed store key (`SweepPoint::cache_key`), with
//! [`VNODES`] virtual nodes per worker hashed from the worker address.
//! Consistent hashing means a worker-set change only remaps the points
//! of the workers that changed — the rest of the fleet keeps its warm
//! stores. Workers run their shares concurrently and stream results
//! back (`stream` + `point_specs` + `return_reports`, protocol v2);
//! the federation records each completed point as it arrives, so when
//! a worker dies mid-batch only its *unfinished* points are
//! repartitioned over the survivors on the next round.

use super::fault::{RetryPolicy, Timeouts};
use super::proto::{
    self, FleetWorker, MetricsBody, PointSpec, PointSummary, ProgressBody, Request, Response,
    ResultBody, StatusBody, StreamOutcome, SubmitReply, SubmitRequest, WireReport, WorkerMetrics,
    WorkerStatus, METRICS_SCHEMA_VERSION, PROTO_MAJOR, PROTO_VERSION,
};
use super::service::{summarize, write_line, PointSource, Service};
use super::sweep::stable_hash;
use super::RunReport;
use anyhow::Result;
use std::collections::{HashMap, HashSet};
use std::io::BufWriter;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Virtual nodes per worker on the hash ring. Enough that a small
/// fleet's shares stay balanced (the imbalance of a 2-worker ring is a
/// few percent, not a coin flip).
pub const VNODES: usize = 64;

/// Liveness-probe / handshake timeout.
const PROBE_TIMEOUT: Duration = Duration::from_secs(2);

/// Reject a live worker whose protocol a coordinator cannot drive:
/// wrong major, or missing the streamed-shard features.
fn check_worker_features(
    addr: &str,
    proto_version: u32,
    proto_major: u32,
    features: &[String],
) -> Result<()> {
    anyhow::ensure!(
        proto_major == PROTO_MAJOR,
        "worker {addr} speaks protocol major {proto_major}, coordinator speaks {PROTO_MAJOR}"
    );
    // `spec_config` is required because shares forward per-spec config
    // overrides; an older worker would silently drop them and return
    // results for the wrong machine configuration.
    for need in ["stream", "point_specs", "spec_config"] {
        anyhow::ensure!(
            features.iter().any(|f| f == need),
            "worker {addr} (proto v{proto_version}) lacks the `{need}` feature a \
             coordinator requires — upgrade it"
        );
    }
    Ok(())
}

/// Consistent-hash partition of `pending` (indices into `keys`) across
/// `addrs`: [`VNODES`] vnodes per address, points assigned clockwise.
/// Returns `(address index, point indices)` shares, sorted. Depends
/// only on the addresses themselves, so membership changes remap only
/// the points of the workers that changed.
fn partition_addrs(
    addrs: &[String],
    keys: &[String],
    pending: &[usize],
) -> Vec<(usize, Vec<usize>)> {
    let mut ring = Vec::with_capacity(addrs.len() * VNODES);
    for (wi, addr) in addrs.iter().enumerate() {
        for v in 0..VNODES {
            ring.push((stable_hash(&format!("{addr}#{v}")), wi));
        }
    }
    ring.sort_unstable();
    let mut shares: HashMap<usize, Vec<usize>> = HashMap::new();
    for &pi in pending {
        let h = stable_hash(&keys[pi]);
        let at = ring.partition_point(|&(pos, _)| pos < h);
        let (_, wi) = ring[at % ring.len()];
        shares.entry(wi).or_default().push(pi);
    }
    let mut out: Vec<(usize, Vec<usize>)> = shares.into_iter().collect();
    out.sort();
    out
}

/// An incremental federation event, forwarded to the submitting
/// client: one merged `result` per completed point (indices in the
/// *original* batch order) and a monotonically increasing `progress`.
pub enum FedEvent<'a> {
    Result {
        index: usize,
        summary: &'a PointSummary,
        report: Option<&'a WireReport>,
    },
    Progress {
        completed: usize,
        total: usize,
        elapsed_ms: u64,
    },
}

/// A merged federated reply: the protocol reply (point order) plus the
/// full reports when the request asked for them (`return_reports`).
pub struct FedReply {
    pub reply: SubmitReply,
    /// One entry per point, `Some` only when `return_reports` was set
    /// and the worker's report reconstructed cleanly.
    pub reports: Vec<Option<RunReport>>,
}

/// One worker of the fleet: address plus drain state. A draining
/// worker keeps finishing the shares already streaming to it, but
/// redistribution rounds stop assigning it new points.
#[derive(Clone, Debug)]
struct WorkerEntry {
    addr: String,
    draining: bool,
}

/// The set of worker daemons a batch can be sharded across. Since v4
/// the membership is *hot*: [`Federation::join`] and
/// [`Federation::drain`] mutate the fleet while the coordinator runs,
/// and every redistribution round of an in-flight batch re-snapshots
/// the eligible workers — the consistent-hash ring grows and shrinks
/// without a restart.
pub struct Federation {
    workers: Mutex<Vec<WorkerEntry>>,
    /// Socket deadlines on worker links.
    timeouts: Timeouts,
    /// Bounded backoff applied before a worker failure is treated as
    /// fatal (transient errors) or as death (transport errors).
    retry: RetryPolicy,
    /// Local simulation fallback for a batch whose workers all died;
    /// `None` keeps the historical all-dead hard failure.
    fallback: Option<Arc<Service>>,
    retries: AtomicU64,
    degraded_batches: AtomicU64,
}

/// Shared mutable state of one federated submit: the merge slots and
/// the caller's event sink, behind one lock so events are emitted in a
/// consistent order across worker threads.
struct Merge<F> {
    summaries: Vec<Option<PointSummary>>,
    reports: Vec<Option<WireReport>>,
    completed: usize,
    on_event: F,
}

impl Federation {
    pub fn new(workers: Vec<String>) -> Result<Federation> {
        Federation::with_config(workers, Timeouts::default(), RetryPolicy::default())
    }

    /// [`Federation::new`] with explicit deadlines and retry policy
    /// (from [`ServeConfig`](crate::config::ServeConfig) knobs).
    pub fn with_config(
        workers: Vec<String>,
        timeouts: Timeouts,
        retry: RetryPolicy,
    ) -> Result<Federation> {
        let workers: Vec<WorkerEntry> = workers
            .into_iter()
            .map(|w| w.trim().to_string())
            .filter(|w| !w.is_empty())
            .map(|addr| WorkerEntry { addr, draining: false })
            .collect();
        anyhow::ensure!(!workers.is_empty(), "a federation needs at least one worker address");
        Ok(Federation {
            workers: Mutex::new(workers),
            timeouts,
            retry,
            fallback: None,
            retries: AtomicU64::new(0),
            degraded_batches: AtomicU64::new(0),
        })
    }

    /// Attach a local [`Service`] to simulate the leftover points of a
    /// batch whose workers have all died (graceful degradation; the
    /// reply carries `degraded: true`).
    pub fn set_fallback(&mut self, svc: Arc<Service>) {
        self.fallback = Some(svc);
    }

    /// Worker-link operations retried after a transient failure.
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Batches that fell back to local simulation.
    pub fn degraded_batches(&self) -> u64 {
        self.degraded_batches.load(Ordering::Relaxed)
    }

    /// Snapshot of the fleet's worker addresses (draining included).
    pub fn workers(&self) -> Vec<String> {
        self.workers.lock().unwrap().iter().map(|w| w.addr.clone()).collect()
    }

    /// Snapshot of the fleet for a membership ack or `metrics`.
    pub fn fleet(&self) -> Vec<FleetWorker> {
        self.workers
            .lock()
            .unwrap()
            .iter()
            .map(|w| FleetWorker { addr: w.addr.clone(), draining: w.draining })
            .collect()
    }

    /// Addresses eligible for new shares: not draining.
    fn eligible(&self) -> Vec<String> {
        self.workers
            .lock()
            .unwrap()
            .iter()
            .filter(|w| !w.draining)
            .map(|w| w.addr.clone())
            .collect()
    }

    /// Register a worker while the coordinator runs (v4). The worker
    /// must pass the same handshake a startup worker does — an
    /// unreachable or incompatible joiner is refused, not enqueued.
    /// Idempotent; re-joining a draining worker cancels the drain. New
    /// points start mapping to it at the next redistribution round.
    pub fn join(&self, addr: &str) -> Result<Vec<FleetWorker>> {
        let addr = addr.trim();
        anyhow::ensure!(!addr.is_empty(), "join: empty worker address");
        match proto::hello(addr, PROBE_TIMEOUT)? {
            proto::HelloOutcome::Compatible { proto_version, proto_major, features } => {
                check_worker_features(addr, proto_version, proto_major, &features)?;
            }
            proto::HelloOutcome::Rejected(msg) => {
                anyhow::bail!("worker {addr} rejected the handshake: {msg}")
            }
        }
        let mut workers = self.workers.lock().unwrap();
        match workers.iter_mut().find(|w| w.addr == addr) {
            Some(w) => w.draining = false,
            None => workers.push(WorkerEntry { addr: addr.to_string(), draining: false }),
        }
        drop(workers);
        Ok(self.fleet())
    }

    /// Mark a worker draining (v4): shares already streaming to it
    /// finish, but redistribution rounds stop assigning it new points.
    /// Draining the last eligible worker leaves batches to the
    /// degraded local fallback.
    pub fn drain(&self, addr: &str) -> Result<Vec<FleetWorker>> {
        let addr = addr.trim();
        let mut workers = self.workers.lock().unwrap();
        let Some(w) = workers.iter_mut().find(|w| w.addr == addr) else {
            anyhow::bail!("drain: {addr} is not in the fleet");
        };
        w.draining = true;
        drop(workers);
        Ok(self.fleet())
    }

    /// Handshake with every reachable worker; a *live* worker that
    /// rejects the handshake (protocol-major skew, pre-v2 server) or
    /// lacks the `point_specs`/`stream` features is a hard error — it
    /// would corrupt batches. Only an unreachable worker is tolerated:
    /// submits route around dead workers anyway.
    pub fn handshake(&self) -> Result<usize> {
        let mut reachable = 0;
        for addr in self.workers() {
            match proto::hello(&addr, PROBE_TIMEOUT) {
                Ok(proto::HelloOutcome::Compatible { proto_version, proto_major, features }) => {
                    check_worker_features(&addr, proto_version, proto_major, &features)?;
                    reachable += 1;
                }
                Ok(proto::HelloOutcome::Rejected(msg)) => {
                    anyhow::bail!("worker {addr} rejected the handshake: {msg}")
                }
                Err(_) => continue,
            }
        }
        Ok(reachable)
    }

    /// Partition `pending` (indices into `keys`) across the `alive`
    /// workers (indices into the current fleet snapshot) by consistent
    /// hashing on the stable store key. Returns `(worker index, point
    /// indices)` shares, sorted by worker.
    pub fn partition(
        &self,
        keys: &[String],
        pending: &[usize],
        alive: &[usize],
    ) -> Vec<(usize, Vec<usize>)> {
        let addrs = self.workers();
        let chosen: Vec<String> = alive.iter().map(|&i| addrs[i].clone()).collect();
        partition_addrs(&chosen, keys, pending)
            .into_iter()
            .map(|(ci, pts)| (alive[ci], pts))
            .collect()
    }

    /// Shard a batch across the fleet, streaming merged events as
    /// points complete. Every worker link gets deadlines and a bounded
    /// seeded-backoff retry (idempotent via `request_id`); points of a
    /// worker that stays dead are repartitioned across the survivors
    /// (their already-streamed results are kept). The submit fails only
    /// when a worker keeps rejecting the batch (a config error fails
    /// everywhere) or when no alive worker remains *and* no local
    /// fallback is attached — with one, the leftovers are simulated
    /// locally and the reply is flagged `degraded`.
    pub fn submit_streamed(
        &self,
        req: &SubmitRequest,
        on_event: impl FnMut(FedEvent<'_>) + Send,
    ) -> Result<FedReply> {
        let points = req.points()?;
        let total = points.len();
        let keys: Vec<String> = points.iter().map(|p| p.cache_key()).collect();
        // Shares are re-submitted as `point_specs`. When the request
        // already came as specs, forward them verbatim (they expand
        // 1:1, in order) so per-spec `config` overrides survive the
        // hop; otherwise derive one override-free spec per point.
        let specs: Vec<PointSpec> = if req.point_specs.is_empty() {
            points
                .iter()
                .map(|p| PointSpec {
                    workload: p.workload.name().to_string(),
                    variant: p.label.clone(),
                    config: vec![],
                })
                .collect()
        } else {
            req.point_specs.clone()
        };
        let t0 = Instant::now();
        let merge = Mutex::new(Merge {
            summaries: vec![None; total],
            reports: vec![None; total],
            completed: 0,
            on_event,
        });
        // Workers that died during *this batch*, by address. The fleet
        // itself is re-snapshotted every round, so a `join` grows the
        // ring mid-batch and a `drain` shrinks it — without disturbing
        // the shares already streaming.
        let mut dead: HashSet<String> = HashSet::new();
        let mut degraded = false;
        loop {
            let pending: Vec<usize> = {
                let m = merge.lock().unwrap();
                (0..total).filter(|&i| m.summaries[i].is_none()).collect()
            };
            if pending.is_empty() {
                break;
            }
            let round_workers: Vec<String> = self
                .eligible()
                .into_iter()
                .filter(|addr| !dead.contains(addr))
                .collect();
            if round_workers.is_empty() {
                let Some(fallback) = &self.fallback else {
                    anyhow::bail!(
                        "every worker died with {} of {total} points unfinished",
                        pending.len()
                    );
                };
                // Graceful degradation: the whole fleet is gone, so
                // simulate the leftover points locally. Results stay
                // exact; the reply's `degraded` flag records that the
                // serving path was impaired.
                self.degraded_batches.fetch_add(1, Ordering::Relaxed);
                degraded = true;
                let fb_points: Vec<_> = pending.iter().map(|&i| points[i].clone()).collect();
                let job = fallback.submit(fb_points, req.priority, req.fresh);
                let results = job.wait()?;
                let mut guard = merge.lock().unwrap();
                let m = &mut *guard;
                for (&global, pr) in pending.iter().zip(&results) {
                    if m.summaries[global].is_some() {
                        continue;
                    }
                    m.summaries[global] = Some(summarize(&pr.point, &pr.report, pr.source));
                    m.reports[global] = req
                        .return_reports
                        .then(|| WireReport::from_report(pr.point.scale, &pr.report));
                    m.completed += 1;
                    let completed = m.completed;
                    let summary = m.summaries[global].as_ref().unwrap();
                    let report = m.reports[global].as_ref();
                    (m.on_event)(FedEvent::Result { index: global, summary, report });
                    (m.on_event)(FedEvent::Progress {
                        completed,
                        total,
                        elapsed_ms: t0.elapsed().as_millis() as u64,
                    });
                }
                break;
            }
            let shares = partition_addrs(&round_workers, &keys, &pending);
            let outcomes: Vec<(usize, Result<StreamOutcome>)> = std::thread::scope(|scope| {
                let handles: Vec<_> = shares
                    .iter()
                    .map(|(wi, share)| {
                        let wi = *wi;
                        let addr = round_workers[wi].as_str();
                        let share = share.clone();
                        let wreq = SubmitRequest {
                            scale: req.scale.clone(),
                            config: req.config.clone(),
                            priority: req.priority,
                            fresh: req.fresh,
                            point_specs: share.iter().map(|&i| specs[i].clone()).collect(),
                            return_reports: req.return_reports,
                            stream: true,
                            suite: false,
                            workloads: vec![],
                            variants: vec![],
                            // The coordinator is the worker's client;
                            // end-user identity stays at the front door
                            // where fair share is enforced.
                            client_id: None,
                            // One id per share, reused across retry
                            // attempts: a retried stream attaches to
                            // the worker's in-flight job instead of
                            // re-simulating, and replays of finished
                            // points hit the duplicate-index skip in
                            // the merge below.
                            request_id: Some(proto::new_request_id(addr)),
                        };
                        let merge = &merge;
                        let timeouts = self.timeouts;
                        let retry = self.retry;
                        let retries_ctr = &self.retries;
                        scope.spawn(move || {
                            let merge_one = |resp: &Response| {
                                let Response::Result(body) = resp else { return };
                                // The worker's indices address its share.
                                let Some(&global) = share.get(body.index) else { return };
                                let mut guard = merge.lock().unwrap();
                                let m = &mut *guard;
                                if m.summaries[global].is_some() {
                                    return;
                                }
                                m.summaries[global] = Some(body.point.clone());
                                m.reports[global] = body.report.clone();
                                m.completed += 1;
                                let completed = m.completed;
                                let summary = m.summaries[global].as_ref().unwrap();
                                let report = m.reports[global].as_ref();
                                (m.on_event)(FedEvent::Result { index: global, summary, report });
                                (m.on_event)(FedEvent::Progress {
                                    completed,
                                    total,
                                    elapsed_ms: t0.elapsed().as_millis() as u64,
                                });
                            };
                            // Bounded retry with seeded-jitter backoff:
                            // transient rejections, busy signals and
                            // transport hiccups get `retry.attempts`
                            // tries before the worker is treated as
                            // failed/dead for this batch.
                            let mut failures: u32 = 0;
                            let res = loop {
                                let attempt = proto::submit_streamed_with(
                                    addr,
                                    &wreq,
                                    Some(timeouts),
                                    |resp| merge_one(resp),
                                );
                                match attempt {
                                    Ok(StreamOutcome::Done(reply)) => {
                                        break Ok(StreamOutcome::Done(reply))
                                    }
                                    Ok(StreamOutcome::ServerError(msg)) => {
                                        failures += 1;
                                        if failures >= retry.attempts {
                                            break Ok(StreamOutcome::ServerError(msg));
                                        }
                                    }
                                    Ok(StreamOutcome::Busy { retry_after_ms }) => {
                                        failures += 1;
                                        if failures >= retry.attempts {
                                            break Err(anyhow::anyhow!(
                                                "worker {addr} stayed busy through \
                                                 {failures} attempts"
                                            ));
                                        }
                                        retries_ctr.fetch_add(1, Ordering::Relaxed);
                                        std::thread::sleep(
                                            retry
                                                .delay(addr, failures - 1)
                                                .max(Duration::from_millis(retry_after_ms)),
                                        );
                                        continue;
                                    }
                                    Err(e) => {
                                        failures += 1;
                                        if failures >= retry.attempts {
                                            break Err(e);
                                        }
                                    }
                                }
                                retries_ctr.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(retry.delay(addr, failures - 1));
                            };
                            (wi, res)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("worker thread panicked")).collect()
            });
            let mut lost_worker = false;
            for (wi, res) in outcomes {
                match res {
                    Ok(StreamOutcome::Done(_)) => {}
                    // A rejected batch (unknown workload, bad config) is
                    // fatal: the same request fails on every worker.
                    Ok(StreamOutcome::ServerError(msg)) => {
                        anyhow::bail!("worker {} rejected the batch: {msg}", round_workers[wi])
                    }
                    // Transport death: mark dead, redistribute next round.
                    Err(_) => {
                        dead.insert(round_workers[wi].clone());
                        lost_worker = true;
                    }
                }
            }
            let still_pending = {
                let m = merge.lock().unwrap();
                (0..total).filter(|&i| m.summaries[i].is_none()).count()
            };
            // A drain between rounds also shrinks the worker set, so a
            // fully-done round with leftovers and no deaths can only be
            // protocol skew when the membership held still.
            let shrunk = {
                let now: HashSet<String> = self
                    .eligible()
                    .into_iter()
                    .filter(|addr| !dead.contains(addr))
                    .collect();
                round_workers.iter().any(|w| !now.contains(w))
            };
            if still_pending > 0 && !lost_worker && !shrunk {
                anyhow::bail!(
                    "workers reported done but {still_pending} of {total} points never \
                     arrived (protocol skew?)"
                );
            }
        }
        let m = merge.into_inner().unwrap();
        let summaries: Vec<PointSummary> =
            m.summaries.into_iter().map(|s| s.expect("merged batch has empty slot")).collect();
        let count = |want: PointSource| {
            summaries
                .iter()
                .filter(|s| PointSource::from_name(&s.source) == Some(want))
                .count()
        };
        let reply = SubmitReply {
            points: total,
            simulated: count(PointSource::Simulated),
            mem_hits: count(PointSource::MemHit),
            disk_hits: count(PointSource::DiskHit),
            deduped: count(PointSource::Dedup),
            elapsed_ms: t0.elapsed().as_millis() as u64,
            results: summaries,
            degraded,
        };
        Ok(FedReply {
            reply,
            reports: m.reports.into_iter().map(|r| r.and_then(|w| w.into_report())).collect(),
        })
    }

    /// Blocking federated submit (no event forwarding).
    pub fn submit(&self, req: &SubmitRequest) -> Result<FedReply> {
        self.submit_streamed(req, |_| {})
    }

    /// Probe every worker's `status` — the coordinator's per-worker
    /// liveness view. Probes run concurrently so a fleet of dead
    /// workers costs one probe timeout, not one per worker.
    pub fn worker_statuses(&self) -> Vec<WorkerStatus> {
        let entries = self.workers.lock().unwrap().clone();
        std::thread::scope(|scope| {
            let handles: Vec<_> = entries
                .iter()
                .map(|entry| {
                    let addr = entry.addr.clone();
                    scope.spawn(move || {
                        match proto::Client::new(addr.clone()).status_timed(PROBE_TIMEOUT) {
                            Ok(s) => WorkerStatus {
                                addr,
                                alive: true,
                                proto_version: s.proto_version,
                                points: s.points,
                                simulated: s.simulated,
                                queue_depth: s.queue_depth,
                                inflight: s.inflight,
                            },
                            Err(_) => WorkerStatus {
                                addr,
                                alive: false,
                                proto_version: 0,
                                points: 0,
                                simulated: 0,
                                queue_depth: 0,
                                inflight: 0,
                            },
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("status probe panicked")).collect()
        })
    }

    /// Probe every worker's `metrics` — the per-worker rows of a
    /// coordinator's `metrics` reply, drain flags included.
    pub fn worker_metrics(&self) -> Vec<WorkerMetrics> {
        let entries = self.workers.lock().unwrap().clone();
        std::thread::scope(|scope| {
            let handles: Vec<_> = entries
                .iter()
                .map(|entry| {
                    let addr = entry.addr.clone();
                    let draining = entry.draining;
                    scope.spawn(move || {
                        let probe = proto::Client::new(addr.clone())
                            .request_timed(&Request::Metrics, PROBE_TIMEOUT);
                        match probe {
                            Ok(Response::Metrics(m)) => WorkerMetrics {
                                addr,
                                alive: true,
                                draining,
                                proto_version: m.proto_version,
                                points: m.points,
                                simulated: m.simulated,
                                queue_depth: m.queue_depth,
                                inflight: m.inflight,
                                sim_cycles_per_sec: m.sim_cycles_per_sec,
                            },
                            _ => WorkerMetrics {
                                addr,
                                alive: false,
                                draining,
                                ..WorkerMetrics::default()
                            },
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("metrics probe panicked")).collect()
        })
    }
}

/// The resident coordinator daemon (`mpu serve --workers ...`): the
/// same JSONL server surface as a local daemon, but submits are
/// federated across the worker fleet instead of simulated in-process.
pub struct Coordinator {
    fed: Federation,
    started: Instant,
    requests: AtomicU64,
    points: AtomicU64,
    active: Mutex<u64>,
    idle_cv: Condvar,
}

impl Coordinator {
    pub fn new(mut fed: Federation) -> Coordinator {
        // A resident coordinator always degrades gracefully: if the
        // whole fleet dies mid-batch it simulates the leftovers
        // locally (storeless) rather than failing the client.
        if fed.fallback.is_none() {
            fed.set_fallback(Arc::new(Service::new(None)));
        }
        Coordinator {
            fed,
            started: Instant::now(),
            requests: AtomicU64::new(0),
            points: AtomicU64::new(0),
            active: Mutex::new(0),
            idle_cv: Condvar::new(),
        }
    }

    pub fn federation(&self) -> &Federation {
        &self.fed
    }

    /// Drain latch for graceful shutdown (mirror of
    /// [`Service::wait_idle`](super::service::Service::wait_idle)).
    pub fn wait_idle(&self) {
        let mut n = self.active.lock().unwrap();
        while *n > 0 {
            n = self.idle_cv.wait(n).unwrap();
        }
    }

    /// Coordinator status: own request counters plus a per-worker
    /// liveness table and fleet-aggregated queue/in-flight depths.
    pub fn status(&self) -> StatusBody {
        let workers = self.fed.worker_statuses();
        let sum = |f: fn(&WorkerStatus) -> u64| workers.iter().filter(|w| w.alive).map(f).sum();
        StatusBody {
            proto_version: PROTO_VERSION,
            uptime_ms: self.started.elapsed().as_millis() as u64,
            requests: self.requests.load(Ordering::Relaxed),
            points: self.points.load(Ordering::Relaxed),
            simulated: sum(|w| w.simulated),
            mem_hits: 0,
            disk_hits: 0,
            dedup_waits: 0,
            kernels_compiled: 0,
            mem_entries: 0,
            store: None,
            proto_major: PROTO_MAJOR,
            queue_depth: workers.iter().filter(|w| w.alive).map(|w| w.queue_depth).sum(),
            inflight: workers.iter().filter(|w| w.alive).map(|w| w.inflight).sum(),
            active_requests: *self.active.lock().unwrap(),
            workers: Some(workers),
            admission_rejected: 0,
            queue_limit: 0,
            retries: self.fed.retries(),
            degraded_batches: self.fed.degraded_batches(),
        }
    }

    /// Coordinator metrics: own request counters plus per-worker
    /// metric rows and fleet-aggregated depths/throughput. Cache and
    /// client rows live on the workers, not here — each worker's own
    /// `metrics` reply carries them.
    pub fn metrics(&self) -> MetricsBody {
        let workers = self.fed.worker_metrics();
        let alive = || workers.iter().filter(|w| w.alive);
        MetricsBody {
            schema_version: METRICS_SCHEMA_VERSION,
            report: "metrics".to_string(),
            proto_version: PROTO_VERSION,
            uptime_ms: self.started.elapsed().as_millis() as u64,
            queue_depth: alive().map(|w| w.queue_depth).sum(),
            queue_limit: 0,
            inflight: alive().map(|w| w.inflight).sum(),
            active_requests: *self.active.lock().unwrap(),
            requests: self.requests.load(Ordering::Relaxed),
            points: self.points.load(Ordering::Relaxed),
            simulated: alive().map(|w| w.simulated).sum(),
            mem_hits: 0,
            disk_hits: 0,
            dedup_waits: 0,
            cache_hit_rate: 0.0,
            admission_rejected: 0,
            retries: self.fed.retries(),
            degraded_batches: self.fed.degraded_batches(),
            sim_cycles_per_sec: alive().map(|w| w.sim_cycles_per_sec).sum(),
            store: None,
            clients: vec![],
            workers,
        }
    }

    /// Serve one submit from a coordinator connection: federate it,
    /// forwarding merged `result`/`progress` records when the client
    /// asked to stream, then write the terminal `done`/`error`.
    pub fn serve_submit(
        &self,
        req: &SubmitRequest,
        writer: &mut BufWriter<TcpStream>,
    ) -> std::io::Result<()> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        *self.active.lock().unwrap() += 1;
        let stream = req.stream;
        let want_reports = req.return_reports;
        let mut io_err: Option<std::io::Error> = None;
        let res = self.fed.submit_streamed(req, |ev| {
            if !stream || io_err.is_some() {
                return;
            }
            let resp = match ev {
                FedEvent::Result { index, summary, report } => Response::Result(ResultBody {
                    index,
                    point: summary.clone(),
                    report: if want_reports { report.cloned() } else { None },
                }),
                FedEvent::Progress { completed, total, elapsed_ms } => {
                    Response::Progress(ProgressBody { completed, total, elapsed_ms })
                }
            };
            if let Err(e) = write_line(writer, &resp) {
                io_err = Some(e);
            }
        });
        {
            let mut n = self.active.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                self.idle_cv.notify_all();
            }
        }
        if let Some(e) = io_err {
            return Err(e);
        }
        let resp = match res {
            Ok(fr) => {
                self.points.fetch_add(fr.reply.points as u64, Ordering::Relaxed);
                Response::Done(fr.reply)
            }
            Err(e) => Response::Error { message: e.to_string() },
        };
        write_line(writer, &resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fed(addrs: &[&str]) -> Federation {
        Federation::new(addrs.iter().map(|a| a.to_string()).collect()).unwrap()
    }

    fn keys(n: usize) -> Vec<String> {
        // Shaped like real store keys.
        (0..n).map(|i| format!("wl{i}-tiny-mpu-{i:016x}")).collect()
    }

    #[test]
    fn empty_federation_is_rejected() {
        assert!(Federation::new(vec![]).is_err());
        assert!(Federation::new(vec!["  ".into(), "".into()]).is_err());
        let f = Federation::new(vec![" 127.0.0.1:1 ".into()]).unwrap();
        assert_eq!(f.workers(), ["127.0.0.1:1"]);
    }

    #[test]
    fn partition_covers_all_points_disjointly() {
        let f = fed(&["127.0.0.1:7201", "127.0.0.1:7202", "127.0.0.1:7203"]);
        let ks = keys(64);
        let pending: Vec<usize> = (0..ks.len()).collect();
        let shares = f.partition(&ks, &pending, &[0, 1, 2]);
        let mut seen: Vec<usize> = shares.iter().flat_map(|(_, pts)| pts.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, pending, "every point assigned exactly once");
        // Deterministic: the same inputs give the same shares.
        assert_eq!(f.partition(&ks, &pending, &[0, 1, 2]), shares);
    }

    #[test]
    fn removing_a_worker_only_remaps_its_share() {
        let f = fed(&["127.0.0.1:7201", "127.0.0.1:7202", "127.0.0.1:7203"]);
        let ks = keys(96);
        let pending: Vec<usize> = (0..ks.len()).collect();
        let owner_of = |shares: &Vec<(usize, Vec<usize>)>| {
            let mut owner = vec![usize::MAX; ks.len()];
            for (wi, pts) in shares {
                for &p in pts {
                    owner[p] = *wi;
                }
            }
            owner
        };
        let full = owner_of(&f.partition(&ks, &pending, &[0, 1, 2]));
        let reduced = owner_of(&f.partition(&ks, &pending, &[0, 2]));
        for (p, (&a, &b)) in full.iter().zip(&reduced).enumerate() {
            if a != 1 {
                assert_eq!(a, b, "point {p} moved although its worker survived");
            } else {
                assert!(b == 0 || b == 2, "dead worker's point must land on a survivor");
            }
        }
        // The dead worker's share actually existed (the ring is balanced
        // enough that 96 keys never all miss one of three workers).
        assert!(full.iter().any(|&w| w == 1));
    }

    #[test]
    fn two_worker_shares_are_nonempty_for_the_tiny_suite() {
        // The shard-smoke CI job asserts both workers simulate a
        // nonempty share of the 24-point tiny suite; pin that property
        // here with the real cache keys.
        use crate::coordinator::proto::SubmitRequest;
        let req = SubmitRequest {
            suite: true,
            scale: "tiny".into(),
            variants: vec!["mpu".into(), "gpu".into()],
            ..SubmitRequest::default()
        };
        let points = req.points().unwrap();
        let ks: Vec<String> = points.iter().map(|p| p.cache_key()).collect();
        let pending: Vec<usize> = (0..ks.len()).collect();
        let f = fed(&["127.0.0.1:7201", "127.0.0.1:7202"]);
        let shares = f.partition(&ks, &pending, &[0, 1]);
        assert_eq!(shares.len(), 2, "both workers must get a share: {shares:?}");
        assert!(shares.iter().all(|(_, pts)| !pts.is_empty()));
        let total: usize = shares.iter().map(|(_, pts)| pts.len()).sum();
        assert_eq!(total, 24);
    }

    #[test]
    fn drain_marks_a_worker_and_excludes_it_from_new_shares() {
        let f = fed(&["127.0.0.1:7201", "127.0.0.1:7202"]);
        let fleet = f.drain("127.0.0.1:7202").unwrap();
        assert_eq!(fleet.len(), 2, "drain keeps the worker in the fleet: {fleet:?}");
        assert!(fleet.iter().any(|w| w.addr == "127.0.0.1:7202" && w.draining));
        assert!(fleet.iter().any(|w| w.addr == "127.0.0.1:7201" && !w.draining));
        // Still listed (in-flight shares finish there)...
        assert_eq!(f.workers().len(), 2);
        // ...but no longer eligible for new shares.
        assert_eq!(f.eligible(), ["127.0.0.1:7201"]);
        // Draining an unknown address is an operator typo, not a no-op.
        assert!(f.drain("127.0.0.1:9999").is_err());
    }

    #[test]
    fn growing_the_ring_only_remaps_points_onto_the_joiner() {
        // The membership-change half of consistent hashing: adding a
        // worker must never move a point between two survivors. (The
        // shrink direction is pinned by
        // `removing_a_worker_only_remaps_its_share`.)
        let two: Vec<String> = vec!["127.0.0.1:7201".into(), "127.0.0.1:7202".into()];
        let three: Vec<String> =
            vec!["127.0.0.1:7201".into(), "127.0.0.1:7202".into(), "127.0.0.1:7203".into()];
        let ks = keys(96);
        let pending: Vec<usize> = (0..ks.len()).collect();
        let owner_of = |addrs: &[String]| {
            let mut owner = vec![usize::MAX; ks.len()];
            for (wi, pts) in partition_addrs(addrs, &ks, &pending) {
                for &p in &pts {
                    owner[p] = wi;
                }
            }
            owner
        };
        let before = owner_of(&two);
        let after = owner_of(&three);
        let mut moved = 0;
        for (p, (&a, &b)) in before.iter().zip(&after).enumerate() {
            if a != b {
                assert_eq!(b, 2, "point {p} moved to a survivor instead of the joiner");
                moved += 1;
            }
        }
        assert!(moved > 0, "a three-way ring must hand the joiner some points");
        assert!(moved < ks.len(), "the joiner must not steal the whole batch");
    }
}
