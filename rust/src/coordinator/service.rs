//! The sweep service: a resident job queue + batch scheduler over the
//! sweep engine, and the TCP server that exposes it (`mpu serve`).
//!
//! Scheduling model:
//! - Every submitted batch becomes a [`Job`]; its points go into one
//!   global priority queue (higher [`SubmitRequest::priority`] first,
//!   FIFO within a priority). Within a batch, points are enqueued
//!   grouped by kernel (workload × smem placement) so the shared
//!   [`KernelCache`] sees consecutive same-kernel points.
//! - Each queued point gets one `rayon::spawn` task on the existing
//!   global pool; every task pops the *best* queued point, not "its
//!   own", which is what makes priorities effective.
//! - Identical points from different requests are deduplicated while in
//!   flight: the first claimant simulates, later ones wait on the same
//!   [`Flight`] and share the result. Completed points are served by
//!   the two-tier [`SimCache`] (memory + optional on-disk store).

use super::proto::{
    PointSummary, Request, Response, StatusBody, SubmitReply, SubmitRequest, PROTO_VERSION,
};
use super::store::DiskStore;
use super::sweep::{CacheTier, KernelCache, SimCache, SweepPoint};
use super::RunReport;
use anyhow::{anyhow, Result};
use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, HashMap};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Which path produced a point's result, from the submitting request's
/// point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PointSource {
    /// This request ran the simulation.
    Simulated,
    /// Memory-tier hit.
    MemHit,
    /// On-disk store hit.
    DiskHit,
    /// Coalesced onto another request's in-flight simulation.
    Dedup,
}

impl PointSource {
    pub fn name(&self) -> &'static str {
        match self {
            PointSource::Simulated => "sim",
            PointSource::MemHit => "mem",
            PointSource::DiskHit => "disk",
            PointSource::Dedup => "dedup",
        }
    }
}

/// One finished point of a job.
#[derive(Clone, Debug)]
pub struct PointResult {
    pub point: SweepPoint,
    pub report: RunReport,
    pub source: PointSource,
}

/// An in-flight simulation another request can wait on.
struct Flight {
    done: Mutex<Option<Result<RunReport, String>>>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Flight {
        Flight { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn publish(&self, res: Result<RunReport, String>) {
        *self.done.lock().unwrap() = Some(res);
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<RunReport> {
        let mut g = self.done.lock().unwrap();
        while g.is_none() {
            g = self.cv.wait(g).unwrap();
        }
        match g.as_ref().unwrap() {
            Ok(r) => Ok(r.clone()),
            Err(e) => Err(anyhow!("deduplicated simulation failed: {e}")),
        }
    }
}

/// A submitted batch: points, their slots, and a completion latch.
pub struct Job {
    points: Vec<SweepPoint>,
    fresh: bool,
    slots: Mutex<Vec<Option<Result<(RunReport, PointSource), String>>>>,
    remaining: Mutex<usize>,
    done_cv: Condvar,
}

impl Job {
    fn new(points: Vec<SweepPoint>, fresh: bool) -> Job {
        let n = points.len();
        Job {
            points,
            fresh,
            slots: Mutex::new(vec![None; n]),
            remaining: Mutex::new(n),
            done_cv: Condvar::new(),
        }
    }

    fn record(&self, idx: usize, res: Result<(RunReport, PointSource), String>) {
        self.slots.lock().unwrap()[idx] = Some(res);
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.done_cv.notify_all();
        }
    }

    /// Block until every point finished; the first failed point fails
    /// the whole batch.
    pub fn wait(&self) -> Result<Vec<PointResult>> {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.done_cv.wait(rem).unwrap();
        }
        drop(rem);
        let slots = std::mem::take(&mut *self.slots.lock().unwrap());
        let mut out = Vec::with_capacity(self.points.len());
        for (pt, slot) in self.points.iter().zip(slots) {
            match slot.expect("finished job with an empty slot") {
                Ok((report, source)) => {
                    out.push(PointResult { point: pt.clone(), report, source })
                }
                Err(e) => anyhow::bail!("{} [{}]: {e}", pt.workload.name(), pt.label),
            }
        }
        Ok(out)
    }
}

/// Queue entry: higher priority first, then submission order. `idx`
/// points into `job.points`.
struct QueuedPoint {
    priority: i32,
    seq: u64,
    idx: usize,
    job: Arc<Job>,
}

impl PartialEq for QueuedPoint {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for QueuedPoint {}
impl PartialOrd for QueuedPoint {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedPoint {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // Max-heap: greatest priority wins; within a priority the
        // earliest seq wins (so invert the seq ordering).
        self.priority.cmp(&other.priority).then(other.seq.cmp(&self.seq))
    }
}

#[derive(Default)]
struct ServiceCounters {
    requests: AtomicU64,
    points: AtomicU64,
    simulated: AtomicU64,
    mem_hits: AtomicU64,
    disk_hits: AtomicU64,
    dedup_waits: AtomicU64,
}

/// The resident sweep service. One instance per daemon; shared across
/// connections behind an `Arc`.
pub struct Service {
    cache: SimCache,
    kernels: KernelCache,
    inflight: Mutex<HashMap<String, Arc<Flight>>>,
    queue: Mutex<BinaryHeap<QueuedPoint>>,
    seq: AtomicU64,
    counters: ServiceCounters,
    started: Instant,
    /// Submits currently executing (the graceful-shutdown drain latch).
    active: Mutex<u64>,
    idle_cv: Condvar,
}

impl Service {
    /// Build a service; `store` becomes the persistent tier under the
    /// service's [`SimCache`].
    pub fn new(store: Option<DiskStore>) -> Service {
        let cache = SimCache::new();
        if let Some(s) = store {
            cache.attach_store(Arc::new(s));
        }
        Service {
            cache,
            kernels: KernelCache::new(),
            inflight: Mutex::new(HashMap::new()),
            queue: Mutex::new(BinaryHeap::new()),
            seq: AtomicU64::new(0),
            counters: ServiceCounters::default(),
            started: Instant::now(),
            active: Mutex::new(0),
            idle_cv: Condvar::new(),
        }
    }

    /// Block until no submit is executing — the shutdown path drains
    /// in-flight batches so their clients get results, not a dead
    /// socket.
    pub fn wait_idle(&self) {
        let mut n = self.active.lock().unwrap();
        while *n > 0 {
            n = self.idle_cv.wait(n).unwrap();
        }
    }

    /// The service's two-tier cache (tests introspect it).
    pub fn cache(&self) -> &SimCache {
        &self.cache
    }

    /// Enqueue a batch and fan its points out on the rayon pool.
    pub fn submit(self: &Arc<Self>, points: Vec<SweepPoint>, priority: i32, fresh: bool) -> Arc<Job> {
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        self.counters.points.fetch_add(points.len() as u64, Ordering::Relaxed);
        let job = Arc::new(Job::new(points, fresh));
        // Enqueue grouped by kernel so same-kernel points pop
        // consecutively (KernelCache compiles once either way; grouping
        // keeps the compile fully off the tail points' critical path).
        let mut order: Vec<usize> = (0..job.points.len()).collect();
        order.sort_by_key(|&i| {
            let p = &job.points[i];
            (p.workload.name(), p.target.smem_near(), i)
        });
        let n = order.len();
        {
            let mut q = self.queue.lock().unwrap();
            for idx in order {
                let seq = self.seq.fetch_add(1, Ordering::Relaxed);
                q.push(QueuedPoint { priority, seq, idx, job: job.clone() });
            }
        }
        for _ in 0..n {
            let svc = self.clone();
            rayon::spawn(move || svc.drain_one());
        }
        job
    }

    /// Expand a protocol request, run it, and summarize — the server's
    /// submit path, also used directly by tests.
    pub fn run_request(self: &Arc<Self>, req: &SubmitRequest) -> Result<SubmitReply> {
        let t0 = Instant::now();
        let points = req.points()?;
        let total = points.len();
        *self.active.lock().unwrap() += 1;
        let waited = {
            let job = self.submit(points, req.priority, req.fresh);
            job.wait()
        };
        {
            let mut n = self.active.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                self.idle_cv.notify_all();
            }
        }
        let results = waited?;
        let count = |s: PointSource| results.iter().filter(|r| r.source == s).count();
        Ok(SubmitReply {
            points: total,
            simulated: count(PointSource::Simulated),
            mem_hits: count(PointSource::MemHit),
            disk_hits: count(PointSource::DiskHit),
            deduped: count(PointSource::Dedup),
            elapsed_ms: t0.elapsed().as_millis() as u64,
            results: results
                .iter()
                .map(|r| PointSummary {
                    label: r.point.label.clone(),
                    workload: r.point.workload.name().to_string(),
                    scale: r.point.scale.name().to_string(),
                    machine: r.report.machine.to_string(),
                    cycles: r.report.cycles,
                    correct: r.report.correct,
                    max_err: r.report.max_err,
                    dram_gbps: r.report.dram_gbps(),
                    energy_j: r.report.energy.total(),
                    source: r.source.name().to_string(),
                })
                .collect(),
        })
    }

    /// Daemon counter snapshot.
    pub fn status(&self) -> StatusBody {
        StatusBody {
            proto_version: PROTO_VERSION,
            uptime_ms: self.started.elapsed().as_millis() as u64,
            requests: self.counters.requests.load(Ordering::Relaxed),
            points: self.counters.points.load(Ordering::Relaxed),
            simulated: self.counters.simulated.load(Ordering::Relaxed),
            mem_hits: self.counters.mem_hits.load(Ordering::Relaxed),
            disk_hits: self.counters.disk_hits.load(Ordering::Relaxed),
            dedup_waits: self.counters.dedup_waits.load(Ordering::Relaxed),
            kernels_compiled: self.kernels.len(),
            mem_entries: self.cache.len(),
            store: self.cache.store().map(|s| s.stats()),
        }
    }

    fn drain_one(self: Arc<Self>) {
        let qp = self.queue.lock().unwrap().pop();
        let Some(qp) = qp else { return };
        let pt = &qp.job.points[qp.idx];
        let res = match self.run_point(pt, qp.job.fresh) {
            Ok((report, source)) => {
                let ctr = match source {
                    PointSource::Simulated => &self.counters.simulated,
                    PointSource::MemHit => &self.counters.mem_hits,
                    PointSource::DiskHit => &self.counters.disk_hits,
                    PointSource::Dedup => &self.counters.dedup_waits,
                };
                ctr.fetch_add(1, Ordering::Relaxed);
                Ok((report, source))
            }
            Err(e) => Err(e.to_string()),
        };
        qp.job.record(qp.idx, res);
    }

    /// Run one point through dedup + the two-tier cache.
    fn run_point(&self, pt: &SweepPoint, fresh: bool) -> Result<(RunReport, PointSource)> {
        let simulate = || pt.simulate(&self.kernels);
        if fresh {
            // Forced re-simulation repairs both tiers: the fresh result
            // overwrites whatever the memory map and the store held.
            let r = simulate()?;
            self.cache.put(pt, &r);
            return Ok((r, PointSource::Simulated));
        }
        let key = pt.cache_key();
        enum Claim {
            Owner(Arc<Flight>),
            Waiter(Arc<Flight>),
        }
        let claim = {
            let mut inflight = self.inflight.lock().unwrap();
            match inflight.get(&key) {
                Some(f) => Claim::Waiter(f.clone()),
                None => {
                    let f = Arc::new(Flight::new());
                    inflight.insert(key.clone(), f.clone());
                    Claim::Owner(f)
                }
            }
        };
        match claim {
            Claim::Owner(flight) => {
                let res = self.cache.get_or_run_traced(pt, simulate);
                flight.publish(match &res {
                    Ok((r, _)) => Ok(r.clone()),
                    Err(e) => Err(e.to_string()),
                });
                self.inflight.lock().unwrap().remove(&key);
                res.map(|(r, tier)| {
                    let source = match tier {
                        CacheTier::Memory => PointSource::MemHit,
                        CacheTier::Disk => PointSource::DiskHit,
                        CacheTier::Simulated => PointSource::Simulated,
                    };
                    (r, source)
                })
            }
            Claim::Waiter(flight) => flight.wait().map(|r| (r, PointSource::Dedup)),
        }
    }
}

/// The TCP front of a [`Service`]: bind first (so tests can learn the
/// ephemeral port), then [`SweepServer::run`] the accept loop until a
/// `shutdown` request.
pub struct SweepServer {
    listener: TcpListener,
    svc: Arc<Service>,
    stop: Arc<AtomicBool>,
}

impl SweepServer {
    pub fn bind(svc: Arc<Service>, addr: &str) -> Result<SweepServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("binding mpu serve to {addr}: {e}"))?;
        Ok(SweepServer { listener, svc, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// Bound address (resolves `:0` test binds).
    pub fn addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local addr")
    }

    /// Accept loop: one thread per connection, any number of JSONL
    /// requests per connection. Returns after a `shutdown` request.
    pub fn run(self) -> Result<()> {
        let addr = self.addr();
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let svc = self.svc.clone();
            let stop = self.stop.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(svc, stream, stop, addr);
            });
        }
        Ok(())
    }
}

fn handle_conn(
    svc: Arc<Service>,
    stream: TcpStream,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match serde_json::from_str::<Request>(&line) {
            Err(e) => Response::Error { message: format!("bad request line: {e}") },
            Ok(Request::Ping) => Response::Pong { proto_version: PROTO_VERSION },
            Ok(Request::Status) => Response::Status(svc.status()),
            Ok(Request::Submit(req)) => match svc.run_request(&req) {
                Ok(reply) => Response::Done(reply),
                Err(e) => Response::Error { message: e.to_string() },
            },
            Ok(Request::Shutdown) => {
                // Drain batches still executing on other connections so
                // their clients get results, then stop accepting.
                svc.wait_idle();
                write_line(&mut writer, &Response::Bye)?;
                stop.store(true, Ordering::SeqCst);
                // Wake the accept loop so it observes the stop flag.
                let _ = TcpStream::connect(addr);
                return Ok(());
            }
        };
        write_line(&mut writer, &resp)?;
    }
    Ok(())
}

fn write_line(writer: &mut BufWriter<TcpStream>, resp: &Response) -> std::io::Result<()> {
    let body = serde_json::to_string(resp).expect("responses always serialize");
    writer.write_all(body.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::coordinator::sweep::Target;
    use crate::workloads::{Scale, Workload};

    #[test]
    fn queue_orders_by_priority_then_fifo() {
        let cfg = MachineConfig::scaled();
        let job = Arc::new(Job::new(
            vec![SweepPoint {
                label: "mpu".into(),
                workload: Workload::Axpy,
                scale: Scale::Tiny,
                target: Target::Mpu(cfg),
            }],
            false,
        ));
        let mut heap = BinaryHeap::new();
        for (priority, seq) in [(0, 0u64), (5, 1), (5, 2), (-1, 3), (0, 4)] {
            heap.push(QueuedPoint { priority, seq, idx: 0, job: job.clone() });
        }
        let popped: Vec<(i32, u64)> =
            std::iter::from_fn(|| heap.pop().map(|q| (q.priority, q.seq))).collect();
        assert_eq!(popped, vec![(5, 1), (5, 2), (0, 0), (0, 4), (-1, 3)]);
    }

    #[test]
    fn service_counts_simulations_and_mem_hits() {
        let svc = Arc::new(Service::new(None));
        let req = SubmitRequest {
            suite: false,
            workloads: vec!["axpy".into()],
            scale: "tiny".into(),
            variants: vec!["mpu".into()],
            config: vec![],
            priority: 0,
            fresh: false,
        };
        let first = svc.run_request(&req).unwrap();
        assert_eq!(first.points, 1);
        assert_eq!(first.simulated, 1);
        assert_eq!(first.cached(), 0);
        assert!(first.results[0].correct);
        assert_eq!(first.results[0].source, "sim");
        let second = svc.run_request(&req).unwrap();
        assert_eq!(second.simulated, 0);
        assert_eq!(second.mem_hits, 1);
        assert_eq!(second.results[0].cycles, first.results[0].cycles);
        let status = svc.status();
        assert_eq!(status.requests, 2);
        assert_eq!(status.points, 2);
        assert_eq!(status.simulated, 1);
        assert_eq!(status.mem_hits, 1);
        assert!(status.store.is_none());
    }

    #[test]
    fn fresh_requests_bypass_every_tier() {
        let svc = Arc::new(Service::new(None));
        let mut req = SubmitRequest {
            suite: false,
            workloads: vec!["axpy".into()],
            scale: "tiny".into(),
            variants: vec!["mpu".into()],
            config: vec![],
            priority: 0,
            fresh: false,
        };
        svc.run_request(&req).unwrap();
        req.fresh = true;
        let again = svc.run_request(&req).unwrap();
        assert_eq!(again.simulated, 1, "fresh must re-simulate");
    }
}
