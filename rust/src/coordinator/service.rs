//! The sweep service: a resident job queue + batch scheduler over the
//! sweep engine, and the TCP server that exposes it (`mpu serve`).
//!
//! Scheduling model:
//! - Every submitted batch becomes a [`Job`]; its points go into one
//!   global priority queue (higher [`SubmitRequest::priority`] first,
//!   FIFO within a priority). Within a batch, points are enqueued
//!   grouped by kernel (workload × smem placement) so the shared
//!   [`KernelCache`] sees consecutive same-kernel points.
//! - Each queued point gets one `rayon::spawn` task on the existing
//!   global pool; every task pops the *best* queued point, not "its
//!   own", which is what makes priorities effective.
//! - Identical points from different requests are deduplicated while in
//!   flight: the first claimant simulates, later ones wait on the same
//!   [`Flight`] and share the result. Completed points are served by
//!   the two-tier [`SimCache`] (memory + optional on-disk store).
//!
//! A streamed submit (`"stream":true`) walks the job's completion
//! order as points finish, emitting `result`/`progress` records before
//! the terminal `done` — long Small-scale batches report as they go
//! instead of blocking silently. The same [`SweepServer`] can also
//! front a [`Coordinator`](super::federation::Coordinator)
//! ([`ServeMode::Federated`]): submits are then partitioned across
//! worker daemons instead of simulated locally.

use super::federation::Coordinator;
use super::proto::{
    PointSummary, ProgressBody, Request, Response, ResultBody, StatusBody, SubmitReply,
    SubmitRequest, WireReport, FEATURES, PROTO_MAJOR, PROTO_VERSION,
};
use super::store::DiskStore;
use super::sweep::{CacheTier, KernelCache, SimCache, SweepPoint};
use super::RunReport;
use anyhow::{anyhow, Result};
use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Delay hint sent with a `busy` rejection.
const BUSY_RETRY_AFTER_MS: u64 = 200;

/// How many recent `request_id`s (with their jobs) the service keeps
/// so a retried submit can attach instead of re-enqueueing.
const RECENT_IDS: usize = 32;

/// Which path produced a point's result, from the submitting request's
/// point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PointSource {
    /// This request ran the simulation.
    Simulated,
    /// Memory-tier hit.
    MemHit,
    /// On-disk store hit.
    DiskHit,
    /// Coalesced onto another request's in-flight simulation.
    Dedup,
}

impl PointSource {
    pub fn name(&self) -> &'static str {
        match self {
            PointSource::Simulated => "sim",
            PointSource::MemHit => "mem",
            PointSource::DiskHit => "disk",
            PointSource::Dedup => "dedup",
        }
    }

    /// Inverse of [`PointSource::name`] (the wire form a coordinator
    /// reads back from worker summaries).
    pub fn from_name(s: &str) -> Option<PointSource> {
        match s {
            "sim" => Some(PointSource::Simulated),
            "mem" => Some(PointSource::MemHit),
            "disk" => Some(PointSource::DiskHit),
            "dedup" => Some(PointSource::Dedup),
            _ => None,
        }
    }
}

/// One finished point of a job.
#[derive(Clone, Debug)]
pub struct PointResult {
    pub point: SweepPoint,
    pub report: RunReport,
    pub source: PointSource,
}

/// Build the wire summary of one finished point (shared by the
/// blocking reply, the streamed `result` records and the federation).
pub fn summarize(point: &SweepPoint, report: &RunReport, source: PointSource) -> PointSummary {
    PointSummary {
        label: point.label.clone(),
        workload: point.workload.name().to_string(),
        scale: point.scale.name().to_string(),
        machine: report.machine.to_string(),
        cycles: report.cycles,
        correct: report.correct,
        max_err: report.max_err,
        dram_gbps: report.dram_gbps(),
        energy_j: report.energy.total(),
        source: source.name().to_string(),
    }
}

/// An in-flight simulation another request can wait on.
struct Flight {
    done: Mutex<Option<Result<RunReport, String>>>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Flight {
        Flight { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn publish(&self, res: Result<RunReport, String>) {
        *self.done.lock().unwrap() = Some(res);
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<RunReport> {
        let mut g = self.done.lock().unwrap();
        while g.is_none() {
            g = self.cv.wait(g).unwrap();
        }
        match g.as_ref().unwrap() {
            Ok(r) => Ok(r.clone()),
            Err(e) => Err(anyhow!("deduplicated simulation failed: {e}")),
        }
    }
}

type Slot = Option<Result<(RunReport, PointSource), String>>;

/// A submitted batch: points, their result slots, and the completion
/// order (which is what a streamed submit walks).
pub struct Job {
    points: Vec<SweepPoint>,
    fresh: bool,
    slots: Mutex<Vec<Slot>>,
    /// Indices of finished points, in completion order. Guarded by its
    /// own mutex, paired with `done_cv`.
    finished: Mutex<Vec<usize>>,
    done_cv: Condvar,
}

impl Job {
    fn new(points: Vec<SweepPoint>, fresh: bool) -> Job {
        let n = points.len();
        Job {
            points,
            fresh,
            slots: Mutex::new(vec![None; n]),
            finished: Mutex::new(Vec::with_capacity(n)),
            done_cv: Condvar::new(),
        }
    }

    fn record(&self, idx: usize, res: Result<(RunReport, PointSource), String>) {
        self.slots.lock().unwrap()[idx] = Some(res);
        let mut fin = self.finished.lock().unwrap();
        fin.push(idx);
        self.done_cv.notify_all();
    }

    /// Points in the batch.
    pub fn total(&self) -> usize {
        self.points.len()
    }

    /// Finished points so far.
    pub fn completed(&self) -> usize {
        self.finished.lock().unwrap().len()
    }

    /// The point at a batch index.
    pub fn point(&self, idx: usize) -> &SweepPoint {
        &self.points[idx]
    }

    /// A finished point's result (`None` while still pending).
    pub fn peek(&self, idx: usize) -> Slot {
        self.slots.lock().unwrap()[idx].clone()
    }

    /// Block until more than `seen` points have finished (or the job is
    /// fully done) and return the indices finished since `seen`, in
    /// completion order. Returns empty once `seen == total`.
    pub fn wait_past(&self, seen: usize) -> Vec<usize> {
        let mut fin = self.finished.lock().unwrap();
        while fin.len() <= seen && fin.len() < self.points.len() {
            fin = self.done_cv.wait(fin).unwrap();
        }
        fin[seen..].to_vec()
    }

    /// Block until every point finished; the first failed point fails
    /// the whole batch. Idempotent: slots are cloned, not consumed, so
    /// a streamed submit can peek results first and still build the
    /// terminal reply from here.
    pub fn wait(&self) -> Result<Vec<PointResult>> {
        {
            let mut fin = self.finished.lock().unwrap();
            while fin.len() < self.points.len() {
                fin = self.done_cv.wait(fin).unwrap();
            }
        }
        let slots = self.slots.lock().unwrap().clone();
        let mut out = Vec::with_capacity(self.points.len());
        for (pt, slot) in self.points.iter().zip(slots) {
            match slot.expect("finished job with an empty slot") {
                Ok((report, source)) => {
                    out.push(PointResult { point: pt.clone(), report, source })
                }
                Err(e) => anyhow::bail!("{} [{}]: {e}", pt.workload.name(), pt.label),
            }
        }
        Ok(out)
    }
}

/// Queue entry: higher priority first, then submission order. `idx`
/// points into `job.points`.
struct QueuedPoint {
    priority: i32,
    seq: u64,
    idx: usize,
    job: Arc<Job>,
}

impl PartialEq for QueuedPoint {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for QueuedPoint {}
impl PartialOrd for QueuedPoint {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedPoint {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // Max-heap: greatest priority wins; within a priority the
        // earliest seq wins (so invert the seq ordering).
        self.priority.cmp(&other.priority).then(other.seq.cmp(&self.seq))
    }
}

#[derive(Default)]
struct ServiceCounters {
    requests: AtomicU64,
    points: AtomicU64,
    simulated: AtomicU64,
    mem_hits: AtomicU64,
    disk_hits: AtomicU64,
    dedup_waits: AtomicU64,
    admission_rejected: AtomicU64,
}

/// The resident sweep service. One instance per daemon; shared across
/// connections behind an `Arc`.
pub struct Service {
    cache: SimCache,
    kernels: KernelCache,
    inflight: Mutex<HashMap<String, Arc<Flight>>>,
    queue: Mutex<BinaryHeap<QueuedPoint>>,
    seq: AtomicU64,
    counters: ServiceCounters,
    started: Instant,
    /// Submits currently executing (the graceful-shutdown drain latch).
    active: Mutex<u64>,
    idle_cv: Condvar,
    /// Admission cap on queued points; 0 disables backpressure.
    max_queue: AtomicUsize,
    /// Recently admitted `request_id`s and their jobs (retry dedup).
    recent: Mutex<VecDeque<(String, Arc<Job>)>>,
}

/// Admission-control verdict on a submit: started, or refused because
/// the queue is full (the client should retry after the hint).
pub enum Admission {
    Started(ActiveRequest),
    Busy { retry_after_ms: u64 },
}

/// A submit in execution: the job plus the RAII active-count guard the
/// graceful-shutdown drain waits on. Dropping it (reply sent, client
/// gone, error) releases the drain latch.
pub struct ActiveRequest {
    svc: Arc<Service>,
    job: Arc<Job>,
    started: Instant,
}

impl ActiveRequest {
    pub fn job(&self) -> &Arc<Job> {
        &self.job
    }

    pub fn elapsed_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Block until the batch finishes and build the blocking reply.
    pub fn wait_reply(&self) -> Result<SubmitReply> {
        let results = self.job.wait()?;
        let count = |s: PointSource| results.iter().filter(|r| r.source == s).count();
        Ok(SubmitReply {
            points: results.len(),
            simulated: count(PointSource::Simulated),
            mem_hits: count(PointSource::MemHit),
            disk_hits: count(PointSource::DiskHit),
            deduped: count(PointSource::Dedup),
            elapsed_ms: self.elapsed_ms(),
            results: results
                .iter()
                .map(|r| summarize(&r.point, &r.report, r.source))
                .collect(),
            degraded: false,
        })
    }
}

impl Drop for ActiveRequest {
    fn drop(&mut self) {
        let mut n = self.svc.active.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.svc.idle_cv.notify_all();
        }
    }
}

impl Service {
    /// Build a service; `store` becomes the persistent tier under the
    /// service's [`SimCache`].
    pub fn new(store: Option<DiskStore>) -> Service {
        let cache = SimCache::new();
        if let Some(s) = store {
            cache.attach_store(Arc::new(s));
        }
        Service {
            cache,
            kernels: KernelCache::new(),
            inflight: Mutex::new(HashMap::new()),
            queue: Mutex::new(BinaryHeap::new()),
            seq: AtomicU64::new(0),
            counters: ServiceCounters::default(),
            started: Instant::now(),
            active: Mutex::new(0),
            idle_cv: Condvar::new(),
            max_queue: AtomicUsize::new(0),
            recent: Mutex::new(VecDeque::new()),
        }
    }

    /// Set the admission cap on queued points (0 disables backpressure:
    /// every submit is admitted, as before v3).
    pub fn set_max_queue(&self, n: usize) {
        self.max_queue.store(n, Ordering::Relaxed);
    }

    /// Block until no submit is executing — the shutdown path drains
    /// in-flight batches so their clients get results, not a dead
    /// socket.
    pub fn wait_idle(&self) {
        let mut n = self.active.lock().unwrap();
        while *n > 0 {
            n = self.idle_cv.wait(n).unwrap();
        }
    }

    /// The service's two-tier cache (tests introspect it).
    pub fn cache(&self) -> &SimCache {
        &self.cache
    }

    /// Enqueue a batch and fan its points out on the rayon pool.
    pub fn submit(self: &Arc<Self>, points: Vec<SweepPoint>, priority: i32, fresh: bool) -> Arc<Job> {
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        self.counters.points.fetch_add(points.len() as u64, Ordering::Relaxed);
        let job = Arc::new(Job::new(points, fresh));
        // Enqueue grouped by kernel so same-kernel points pop
        // consecutively (KernelCache compiles once either way; grouping
        // keeps the compile fully off the tail points' critical path).
        let mut order: Vec<usize> = (0..job.points.len()).collect();
        order.sort_by_key(|&i| {
            let p = &job.points[i];
            (p.workload.name(), p.target.smem_near(), i)
        });
        let n = order.len();
        {
            let mut q = self.queue.lock().unwrap();
            for idx in order {
                let seq = self.seq.fetch_add(1, Ordering::Relaxed);
                q.push(QueuedPoint { priority, seq, idx, job: job.clone() });
            }
        }
        for _ in 0..n {
            let svc = self.clone();
            rayon::spawn(move || svc.drain_one());
        }
        job
    }

    /// Expand a protocol request and start it executing, subject to
    /// admission control. A request whose `request_id` matches a
    /// recently admitted batch attaches to that batch's job (a retry
    /// after a dropped reply never re-simulates); a full queue earns a
    /// `busy` with a retry hint instead of unbounded growth.
    pub fn try_begin_request(self: &Arc<Self>, req: &SubmitRequest) -> Result<Admission> {
        if let Some(id) = &req.request_id {
            let recent = self.recent.lock().unwrap();
            if let Some((_, job)) = recent.iter().find(|(rid, _)| rid == id) {
                let job = job.clone();
                drop(recent);
                *self.active.lock().unwrap() += 1;
                return Ok(Admission::Started(ActiveRequest {
                    svc: self.clone(),
                    job,
                    started: Instant::now(),
                }));
            }
        }
        let points = req.points()?;
        let limit = self.max_queue.load(Ordering::Relaxed);
        if limit > 0 && self.queue.lock().unwrap().len() >= limit {
            self.counters.admission_rejected.fetch_add(1, Ordering::Relaxed);
            return Ok(Admission::Busy { retry_after_ms: BUSY_RETRY_AFTER_MS });
        }
        *self.active.lock().unwrap() += 1;
        let started = Instant::now();
        let job = self.submit(points, req.priority, req.fresh);
        if let Some(id) = &req.request_id {
            self.remember(id, &job);
        }
        Ok(Admission::Started(ActiveRequest { svc: self.clone(), job, started }))
    }

    fn remember(&self, id: &str, job: &Arc<Job>) {
        let mut recent = self.recent.lock().unwrap();
        if recent.iter().any(|(rid, _)| rid == id) {
            return;
        }
        if recent.len() >= RECENT_IDS {
            recent.pop_front();
        }
        recent.push_back((id.to_string(), job.clone()));
    }

    /// [`Service::try_begin_request`] for callers without a busy path
    /// of their own: a rejection becomes an error.
    pub fn begin_request(self: &Arc<Self>, req: &SubmitRequest) -> Result<ActiveRequest> {
        match self.try_begin_request(req)? {
            Admission::Started(ar) => Ok(ar),
            Admission::Busy { retry_after_ms } => Err(anyhow!(
                "server busy (queue full); retry after {retry_after_ms} ms"
            )),
        }
    }

    /// Expand a protocol request, run it to completion, and summarize —
    /// the blocking submit path, also used directly by tests.
    pub fn run_request(self: &Arc<Self>, req: &SubmitRequest) -> Result<SubmitReply> {
        self.begin_request(req)?.wait_reply()
    }

    /// Points queued but not yet claimed by a runner.
    pub fn queue_depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Simulations currently in flight (dedup table size).
    pub fn inflight_len(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }

    /// Submit requests currently executing.
    pub fn active_requests(&self) -> u64 {
        *self.active.lock().unwrap()
    }

    /// Daemon counter snapshot.
    pub fn status(&self) -> StatusBody {
        StatusBody {
            proto_version: PROTO_VERSION,
            uptime_ms: self.started.elapsed().as_millis() as u64,
            requests: self.counters.requests.load(Ordering::Relaxed),
            points: self.counters.points.load(Ordering::Relaxed),
            simulated: self.counters.simulated.load(Ordering::Relaxed),
            mem_hits: self.counters.mem_hits.load(Ordering::Relaxed),
            disk_hits: self.counters.disk_hits.load(Ordering::Relaxed),
            dedup_waits: self.counters.dedup_waits.load(Ordering::Relaxed),
            kernels_compiled: self.kernels.len(),
            mem_entries: self.cache.len(),
            store: self.cache.store().map(|s| s.stats()),
            proto_major: PROTO_MAJOR,
            queue_depth: self.queue_depth(),
            inflight: self.inflight_len(),
            active_requests: self.active_requests(),
            workers: None,
            admission_rejected: self.counters.admission_rejected.load(Ordering::Relaxed),
            queue_limit: self.max_queue.load(Ordering::Relaxed),
            retries: 0,
            degraded_batches: 0,
        }
    }

    fn drain_one(self: Arc<Self>) {
        let qp = self.queue.lock().unwrap().pop();
        let Some(qp) = qp else { return };
        let pt = &qp.job.points[qp.idx];
        let res = match self.run_point(pt, qp.job.fresh) {
            Ok((report, source)) => {
                let ctr = match source {
                    PointSource::Simulated => &self.counters.simulated,
                    PointSource::MemHit => &self.counters.mem_hits,
                    PointSource::DiskHit => &self.counters.disk_hits,
                    PointSource::Dedup => &self.counters.dedup_waits,
                };
                ctr.fetch_add(1, Ordering::Relaxed);
                Ok((report, source))
            }
            Err(e) => Err(e.to_string()),
        };
        qp.job.record(qp.idx, res);
    }

    /// Run one point through dedup + the two-tier cache.
    fn run_point(&self, pt: &SweepPoint, fresh: bool) -> Result<(RunReport, PointSource)> {
        let simulate = || pt.simulate(&self.kernels);
        if fresh {
            // Forced re-simulation repairs both tiers: the fresh result
            // overwrites whatever the memory map and the store held.
            let r = simulate()?;
            self.cache.put(pt, &r);
            return Ok((r, PointSource::Simulated));
        }
        let key = pt.cache_key();
        enum Claim {
            Owner(Arc<Flight>),
            Waiter(Arc<Flight>),
        }
        let claim = {
            let mut inflight = self.inflight.lock().unwrap();
            match inflight.get(&key) {
                Some(f) => Claim::Waiter(f.clone()),
                None => {
                    let f = Arc::new(Flight::new());
                    inflight.insert(key.clone(), f.clone());
                    Claim::Owner(f)
                }
            }
        };
        match claim {
            Claim::Owner(flight) => {
                let res = self.cache.get_or_run_traced(pt, simulate);
                flight.publish(match &res {
                    Ok((r, _)) => Ok(r.clone()),
                    Err(e) => Err(e.to_string()),
                });
                self.inflight.lock().unwrap().remove(&key);
                res.map(|(r, tier)| {
                    let source = match tier {
                        CacheTier::Memory => PointSource::MemHit,
                        CacheTier::Disk => PointSource::DiskHit,
                        CacheTier::Simulated => PointSource::Simulated,
                    };
                    (r, source)
                })
            }
            Claim::Waiter(flight) => flight.wait().map(|r| (r, PointSource::Dedup)),
        }
    }
}

/// What a [`SweepServer`] fronts: a local simulating [`Service`], or a
/// [`Coordinator`] that shards submits across worker daemons.
#[derive(Clone)]
pub enum ServeMode {
    Local(Arc<Service>),
    Federated(Arc<Coordinator>),
}

impl ServeMode {
    fn status(&self) -> StatusBody {
        match self {
            ServeMode::Local(svc) => svc.status(),
            ServeMode::Federated(co) => co.status(),
        }
    }

    fn wait_idle(&self) {
        match self {
            ServeMode::Local(svc) => svc.wait_idle(),
            ServeMode::Federated(co) => co.wait_idle(),
        }
    }
}

/// The TCP front of a [`Service`] or [`Coordinator`]: bind first (so
/// tests can learn the ephemeral port), then [`SweepServer::run`] the
/// accept loop until a `shutdown` request.
pub struct SweepServer {
    listener: TcpListener,
    mode: ServeMode,
    stop: Arc<AtomicBool>,
}

impl SweepServer {
    /// Bind a local (simulating) daemon.
    pub fn bind(svc: Arc<Service>, addr: &str) -> Result<SweepServer> {
        SweepServer::bind_mode(ServeMode::Local(svc), addr)
    }

    /// Bind a coordinator daemon fronting a worker fleet.
    pub fn bind_coordinator(co: Arc<Coordinator>, addr: &str) -> Result<SweepServer> {
        SweepServer::bind_mode(ServeMode::Federated(co), addr)
    }

    pub fn bind_mode(mode: ServeMode, addr: &str) -> Result<SweepServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("binding mpu serve to {addr}: {e}"))?;
        Ok(SweepServer { listener, mode, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// Bound address (resolves `:0` test binds).
    pub fn addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local addr")
    }

    /// Accept loop: one thread per connection, any number of JSONL
    /// requests per connection. Returns after a `shutdown` request.
    pub fn run(self) -> Result<()> {
        let addr = self.addr();
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let mode = self.mode.clone();
            let stop = self.stop.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(mode, stream, stop, addr);
            });
        }
        Ok(())
    }
}

fn handle_conn(
    mode: ServeMode,
    stream: TcpStream,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        // Byte-level framing: a malformed frame — including invalid
        // UTF-8, which `lines()` would turn into a handler-killing
        // error — must reach the parser and earn an `error` reply,
        // leaving the connection serving. Only real transport errors
        // end the handler.
        buf.clear();
        if reader.read_until(b'\n', &mut buf)? == 0 {
            return Ok(()); // clean EOF
        }
        let raw = String::from_utf8_lossy(&buf);
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let req = match serde_json::from_str::<Request>(line) {
            Err(e) => {
                write_line(&mut writer, &Response::Error { message: format!("bad request line: {e}") })?;
                continue;
            }
            Ok(req) => req,
        };
        match req {
            Request::Ping => write_line(&mut writer, &Response::Pong { proto_version: PROTO_VERSION })?,
            Request::Hello { proto_version, proto_major } => {
                let resp = if proto_major != PROTO_MAJOR {
                    Response::Error {
                        message: format!(
                            "protocol major mismatch: client speaks v{proto_version} \
                             (major {proto_major}), this server speaks v{PROTO_VERSION} \
                             (major {PROTO_MAJOR}) — upgrade the older side"
                        ),
                    }
                } else {
                    Response::Hello {
                        proto_version: PROTO_VERSION,
                        proto_major: PROTO_MAJOR,
                        features: FEATURES.iter().map(|f| f.to_string()).collect(),
                    }
                };
                write_line(&mut writer, &resp)?;
            }
            Request::Status => write_line(&mut writer, &Response::Status(mode.status()))?,
            Request::Submit(req) => match &mode {
                ServeMode::Local(svc) => match svc.try_begin_request(&req) {
                    Err(e) => {
                        write_line(&mut writer, &Response::Error { message: e.to_string() })?
                    }
                    Ok(Admission::Busy { retry_after_ms }) => {
                        write_line(&mut writer, &Response::Busy { retry_after_ms })?
                    }
                    Ok(Admission::Started(ar)) => {
                        if req.stream {
                            stream_submit_local(&ar, &req, &mut writer)?;
                        } else {
                            let resp = match ar.wait_reply() {
                                Ok(reply) => Response::Done(reply),
                                Err(e) => Response::Error { message: e.to_string() },
                            };
                            write_line(&mut writer, &resp)?;
                        }
                    }
                },
                ServeMode::Federated(co) => {
                    co.serve_submit(&req, &mut writer)?;
                }
            },
            Request::Shutdown => {
                // Drain batches still executing on other connections so
                // their clients get results, then stop accepting.
                mode.wait_idle();
                write_line(&mut writer, &Response::Bye)?;
                stop.store(true, Ordering::SeqCst);
                // Wake the accept loop so it observes the stop flag.
                let _ = TcpStream::connect(addr);
                return Ok(());
            }
        }
    }
}

/// Serve one streamed submit already admitted to the local service:
/// emit a `result` record per completed point (in completion order)
/// and a `progress` record per wake-up, then the terminal
/// `done`/`error`. For a retried request attached to an in-flight job,
/// already-finished points replay immediately — the client dedups by
/// batch index.
fn stream_submit_local(
    ar: &ActiveRequest,
    req: &SubmitRequest,
    writer: &mut BufWriter<TcpStream>,
) -> std::io::Result<()> {
    let total = ar.job().total();
    // The terminal reply is assembled from the summaries accumulated
    // while streaming — no second full-report clone of every slot.
    let mut summaries: Vec<Option<PointSummary>> = vec![None; total];
    let mut failed = false;
    let mut seen = 0usize;
    while seen < total {
        let newly = ar.job().wait_past(seen);
        for &idx in &newly {
            match ar.job().peek(idx) {
                Some(Ok((report, source))) => {
                    let pt = ar.job().point(idx);
                    let summary = summarize(pt, &report, source);
                    let body = ResultBody {
                        index: idx,
                        point: summary.clone(),
                        report: req
                            .return_reports
                            .then(|| WireReport::from_report(pt.scale, &report)),
                    };
                    write_line(writer, &Response::Result(body))?;
                    summaries[idx] = Some(summary);
                }
                // Failed points carry no result record; the terminal
                // error reports them (blocking semantics fail the
                // whole batch).
                Some(Err(_)) => failed = true,
                None => {}
            }
        }
        seen += newly.len();
        let progress =
            ProgressBody { completed: seen, total, elapsed_ms: ar.elapsed_ms() };
        write_line(writer, &Response::Progress(progress))?;
    }
    let resp = if failed {
        match ar.wait_reply() {
            Ok(reply) => Response::Done(reply),
            Err(e) => Response::Error { message: e.to_string() },
        }
    } else {
        let results: Vec<PointSummary> =
            summaries.into_iter().map(|s| s.expect("streamed batch complete")).collect();
        let count = |src: &str| results.iter().filter(|r| r.source == src).count();
        Response::Done(SubmitReply {
            points: total,
            simulated: count("sim"),
            mem_hits: count("mem"),
            disk_hits: count("disk"),
            deduped: count("dedup"),
            elapsed_ms: ar.elapsed_ms(),
            results,
            degraded: false,
        })
    };
    write_line(writer, &resp)
}

pub(crate) fn write_line(
    writer: &mut BufWriter<TcpStream>,
    resp: &Response,
) -> std::io::Result<()> {
    let body = serde_json::to_string(resp).expect("responses always serialize");
    writer.write_all(body.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::coordinator::sweep::Target;
    use crate::workloads::{Scale, Workload};

    fn axpy_req() -> SubmitRequest {
        SubmitRequest {
            workloads: vec!["axpy".into()],
            scale: "tiny".into(),
            variants: vec!["mpu".into()],
            ..SubmitRequest::default()
        }
    }

    #[test]
    fn queue_orders_by_priority_then_fifo() {
        let cfg = MachineConfig::scaled();
        let job = Arc::new(Job::new(
            vec![SweepPoint {
                label: "mpu".into(),
                workload: Workload::Axpy,
                scale: Scale::Tiny,
                target: Target::Mpu(cfg),
            }],
            false,
        ));
        let mut heap = BinaryHeap::new();
        for (priority, seq) in [(0, 0u64), (5, 1), (5, 2), (-1, 3), (0, 4)] {
            heap.push(QueuedPoint { priority, seq, idx: 0, job: job.clone() });
        }
        let popped: Vec<(i32, u64)> =
            std::iter::from_fn(|| heap.pop().map(|q| (q.priority, q.seq))).collect();
        assert_eq!(popped, vec![(5, 1), (5, 2), (0, 0), (0, 4), (-1, 3)]);
    }

    #[test]
    fn service_counts_simulations_and_mem_hits() {
        let svc = Arc::new(Service::new(None));
        let req = axpy_req();
        let first = svc.run_request(&req).unwrap();
        assert_eq!(first.points, 1);
        assert_eq!(first.simulated, 1);
        assert_eq!(first.cached(), 0);
        assert!(first.results[0].correct);
        assert_eq!(first.results[0].source, "sim");
        let second = svc.run_request(&req).unwrap();
        assert_eq!(second.simulated, 0);
        assert_eq!(second.mem_hits, 1);
        assert_eq!(second.results[0].cycles, first.results[0].cycles);
        let status = svc.status();
        assert_eq!(status.requests, 2);
        assert_eq!(status.points, 2);
        assert_eq!(status.simulated, 1);
        assert_eq!(status.mem_hits, 1);
        assert!(status.store.is_none());
        // The busy-daemon fields are quiescent here but present.
        assert_eq!(status.proto_major, PROTO_MAJOR);
        assert_eq!(status.queue_depth, 0);
        assert_eq!(status.inflight, 0);
        assert_eq!(status.active_requests, 0);
        assert!(status.workers.is_none());
    }

    #[test]
    fn fresh_requests_bypass_every_tier() {
        let svc = Arc::new(Service::new(None));
        let mut req = axpy_req();
        svc.run_request(&req).unwrap();
        req.fresh = true;
        let again = svc.run_request(&req).unwrap();
        assert_eq!(again.simulated, 1, "fresh must re-simulate");
    }

    #[test]
    fn job_completion_order_and_incremental_waits() {
        // Drive a Job by hand: record results out of point order and
        // check the streamed-walk primitives see them incrementally.
        let cfg = MachineConfig::scaled();
        let mk = |w| SweepPoint {
            label: "mpu".into(),
            workload: w,
            scale: Scale::Tiny,
            target: Target::Mpu(cfg.clone()),
        };
        let job = Job::new(vec![mk(Workload::Axpy), mk(Workload::Knn)], false);
        assert_eq!(job.total(), 2);
        assert_eq!(job.completed(), 0);
        assert!(job.peek(0).is_none());
        let r = crate::coordinator::run_workload_scaled(
            Workload::Axpy,
            &cfg,
            Scale::Tiny,
        )
        .unwrap();
        job.record(1, Ok((r.clone(), PointSource::Simulated)));
        assert_eq!(job.completed(), 1);
        let newly = job.wait_past(0);
        assert_eq!(newly, vec![1], "completion order, not point order");
        assert!(job.peek(1).unwrap().is_ok());
        job.record(0, Ok((r, PointSource::MemHit)));
        let newly = job.wait_past(1);
        assert_eq!(newly, vec![0]);
        assert!(job.wait_past(2).is_empty(), "past the end returns empty");
        // wait() is idempotent over cloned slots.
        let results = job.wait().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].source, PointSource::MemHit);
        assert_eq!(results[1].source, PointSource::Simulated);
        assert_eq!(job.wait().unwrap().len(), 2);
    }

    #[test]
    fn full_queue_earns_busy_and_drains_back_to_admission() {
        let svc = Arc::new(Service::new(None));
        svc.set_max_queue(1);
        // Park a synthetic queued point so the backlog is at the cap
        // (no rayon task will ever pop it — it exists only to occupy
        // the queue).
        let cfg = MachineConfig::scaled();
        let parked = Arc::new(Job::new(
            vec![SweepPoint {
                label: "mpu".into(),
                workload: Workload::Axpy,
                scale: Scale::Tiny,
                target: Target::Mpu(cfg),
            }],
            false,
        ));
        svc.queue
            .lock()
            .unwrap()
            .push(QueuedPoint { priority: 0, seq: 0, idx: 0, job: parked });
        match svc.try_begin_request(&axpy_req()).unwrap() {
            Admission::Busy { retry_after_ms } => assert!(retry_after_ms > 0),
            Admission::Started(_) => panic!("full queue must refuse admission"),
        }
        assert_eq!(svc.status().admission_rejected, 1);
        assert_eq!(svc.status().queue_limit, 1);
        // Drain the parked point; admission recovers.
        svc.queue.lock().unwrap().pop();
        match svc.try_begin_request(&axpy_req()).unwrap() {
            Admission::Started(ar) => {
                ar.wait_reply().unwrap();
            }
            Admission::Busy { .. } => panic!("empty queue must admit"),
        }
    }

    #[test]
    fn retried_request_id_attaches_to_the_inflight_job() {
        let svc = Arc::new(Service::new(None));
        let mut req = axpy_req();
        req.fresh = true; // prove dedup is by request id, not cache
        req.request_id = Some("retry-me-1".into());
        let first = match svc.try_begin_request(&req).unwrap() {
            Admission::Started(ar) => ar,
            Admission::Busy { .. } => panic!("must admit"),
        };
        let second = match svc.try_begin_request(&req).unwrap() {
            Admission::Started(ar) => ar,
            Admission::Busy { .. } => panic!("must attach, not refuse"),
        };
        assert!(
            Arc::ptr_eq(first.job(), second.job()),
            "same request_id must attach to the same job"
        );
        let a = first.wait_reply().unwrap();
        let b = second.wait_reply().unwrap();
        assert_eq!(a.simulated, 1);
        assert_eq!(b.simulated, 1, "the attached view sees the same single run");
        assert_eq!(svc.status().requests, 1, "one logical batch, not two");
        assert_eq!(svc.status().points, 1);
        // A different id is a genuinely new batch.
        req.request_id = Some("retry-me-2".into());
        let third = svc.begin_request(&req).unwrap();
        third.wait_reply().unwrap();
        assert_eq!(svc.status().requests, 2);
    }

    #[test]
    fn point_source_names_round_trip() {
        for s in [
            PointSource::Simulated,
            PointSource::MemHit,
            PointSource::DiskHit,
            PointSource::Dedup,
        ] {
            assert_eq!(PointSource::from_name(s.name()), Some(s));
        }
        assert_eq!(PointSource::from_name("warp-drive"), None);
    }
}
