//! The sweep service: a resident job queue + batch scheduler over the
//! sweep engine, and the TCP server that exposes it (`mpu serve`).
//!
//! Scheduling model:
//! - Every submitted batch becomes a [`Job`] owned by a client
//!   identity (`client_id`, default `"anon"`). Points enter that
//!   client's priority queue (higher [`SubmitRequest::priority`]
//!   first, FIFO within a priority); clients take turns
//!   deficit-round-robin — `weight` pops per turn — so one greedy
//!   client cannot starve the rest ([`FairQueue`]). Within a batch,
//!   points are enqueued grouped by kernel (workload × smem placement)
//!   so the shared [`KernelCache`] sees consecutive same-kernel
//!   points.
//! - Each queued point gets one `rayon::spawn` task on the existing
//!   global pool; every task pops the *best* queued point, not "its
//!   own", which is what makes priorities effective.
//! - Identical points from different requests are deduplicated while in
//!   flight: the first claimant simulates, later ones wait on the same
//!   [`Flight`] and share the result. Completed points are served by
//!   the two-tier [`SimCache`] (memory + optional on-disk store).
//!
//! A streamed submit (`"stream":true`) walks the job's completion
//! order as points finish, emitting `result`/`progress` records before
//! the terminal `done` — long Small-scale batches report as they go
//! instead of blocking silently. The same [`SweepServer`] can also
//! front a [`Coordinator`](super::federation::Coordinator)
//! ([`ServeMode::Federated`]): submits are then partitioned across
//! worker daemons instead of simulated locally.

use super::federation::Coordinator;
use super::proto::{
    ClientMetrics, MetricsBody, PointSummary, ProgressBody, Request, Response, ResultBody,
    StatusBody, SubmitReply, SubmitRequest, WireReport, FEATURES, METRICS_SCHEMA_VERSION,
    PROTO_MAJOR, PROTO_VERSION,
};
use super::store::DiskStore;
use super::sweep::{CacheTier, KernelCache, SimCache, SweepPoint};
use super::RunReport;
use anyhow::{anyhow, Result};
use std::cmp::Ordering as CmpOrdering;
use std::collections::{BTreeMap, BinaryHeap, HashMap, VecDeque};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Delay hint sent with a `busy` rejection.
const BUSY_RETRY_AFTER_MS: u64 = 200;

/// How many recent `request_id`s (with their jobs) the service keeps
/// so a retried submit can attach instead of re-enqueueing.
const RECENT_IDS: usize = 32;

/// The fair-share bucket of submits that carry no `client_id`.
pub const ANON_CLIENT: &str = "anon";

/// Which path produced a point's result, from the submitting request's
/// point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PointSource {
    /// This request ran the simulation.
    Simulated,
    /// Memory-tier hit.
    MemHit,
    /// On-disk store hit.
    DiskHit,
    /// Coalesced onto another request's in-flight simulation.
    Dedup,
}

impl PointSource {
    pub fn name(&self) -> &'static str {
        match self {
            PointSource::Simulated => "sim",
            PointSource::MemHit => "mem",
            PointSource::DiskHit => "disk",
            PointSource::Dedup => "dedup",
        }
    }

    /// Inverse of [`PointSource::name`] (the wire form a coordinator
    /// reads back from worker summaries).
    pub fn from_name(s: &str) -> Option<PointSource> {
        match s {
            "sim" => Some(PointSource::Simulated),
            "mem" => Some(PointSource::MemHit),
            "disk" => Some(PointSource::DiskHit),
            "dedup" => Some(PointSource::Dedup),
            _ => None,
        }
    }
}

/// One finished point of a job.
#[derive(Clone, Debug)]
pub struct PointResult {
    pub point: SweepPoint,
    pub report: RunReport,
    pub source: PointSource,
}

/// Build the wire summary of one finished point (shared by the
/// blocking reply, the streamed `result` records and the federation).
pub fn summarize(point: &SweepPoint, report: &RunReport, source: PointSource) -> PointSummary {
    PointSummary {
        label: point.label.clone(),
        workload: point.workload.name().to_string(),
        scale: point.scale.name().to_string(),
        machine: report.machine.to_string(),
        cycles: report.cycles,
        correct: report.correct,
        max_err: report.max_err,
        dram_gbps: report.dram_gbps(),
        energy_j: report.energy.total(),
        source: source.name().to_string(),
    }
}

/// An in-flight simulation another request can wait on.
struct Flight {
    done: Mutex<Option<Result<RunReport, String>>>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Flight {
        Flight { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn publish(&self, res: Result<RunReport, String>) {
        *self.done.lock().unwrap() = Some(res);
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<RunReport> {
        let mut g = self.done.lock().unwrap();
        while g.is_none() {
            g = self.cv.wait(g).unwrap();
        }
        match g.as_ref().unwrap() {
            Ok(r) => Ok(r.clone()),
            Err(e) => Err(anyhow!("deduplicated simulation failed: {e}")),
        }
    }
}

type Slot = Option<Result<(RunReport, PointSource), String>>;

/// A submitted batch: points, their result slots, and the completion
/// order (which is what a streamed submit walks).
pub struct Job {
    points: Vec<SweepPoint>,
    fresh: bool,
    /// Fair-share owner of the batch ([`ANON_CLIENT`] when the submit
    /// carried no identity).
    client: String,
    slots: Mutex<Vec<Slot>>,
    /// Indices of finished points, in completion order. Guarded by its
    /// own mutex, paired with `done_cv`.
    finished: Mutex<Vec<usize>>,
    done_cv: Condvar,
}

impl Job {
    fn new(points: Vec<SweepPoint>, fresh: bool, client: String) -> Job {
        let n = points.len();
        Job {
            points,
            fresh,
            client,
            slots: Mutex::new(vec![None; n]),
            finished: Mutex::new(Vec::with_capacity(n)),
            done_cv: Condvar::new(),
        }
    }

    /// The client identity that owns this batch.
    pub fn client(&self) -> &str {
        &self.client
    }

    fn record(&self, idx: usize, res: Result<(RunReport, PointSource), String>) {
        self.slots.lock().unwrap()[idx] = Some(res);
        let mut fin = self.finished.lock().unwrap();
        fin.push(idx);
        self.done_cv.notify_all();
    }

    /// Points in the batch.
    pub fn total(&self) -> usize {
        self.points.len()
    }

    /// Finished points so far.
    pub fn completed(&self) -> usize {
        self.finished.lock().unwrap().len()
    }

    /// The point at a batch index.
    pub fn point(&self, idx: usize) -> &SweepPoint {
        &self.points[idx]
    }

    /// A finished point's result (`None` while still pending).
    pub fn peek(&self, idx: usize) -> Slot {
        self.slots.lock().unwrap()[idx].clone()
    }

    /// Block until more than `seen` points have finished (or the job is
    /// fully done) and return the indices finished since `seen`, in
    /// completion order. Returns empty once `seen == total`.
    pub fn wait_past(&self, seen: usize) -> Vec<usize> {
        let mut fin = self.finished.lock().unwrap();
        while fin.len() <= seen && fin.len() < self.points.len() {
            fin = self.done_cv.wait(fin).unwrap();
        }
        fin[seen..].to_vec()
    }

    /// Block until every point finished; the first failed point fails
    /// the whole batch. Idempotent: slots are cloned, not consumed, so
    /// a streamed submit can peek results first and still build the
    /// terminal reply from here.
    pub fn wait(&self) -> Result<Vec<PointResult>> {
        {
            let mut fin = self.finished.lock().unwrap();
            while fin.len() < self.points.len() {
                fin = self.done_cv.wait(fin).unwrap();
            }
        }
        let slots = self.slots.lock().unwrap().clone();
        let mut out = Vec::with_capacity(self.points.len());
        for (pt, slot) in self.points.iter().zip(slots) {
            match slot.expect("finished job with an empty slot") {
                Ok((report, source)) => {
                    out.push(PointResult { point: pt.clone(), report, source })
                }
                Err(e) => anyhow::bail!("{} [{}]: {e}", pt.workload.name(), pt.label),
            }
        }
        Ok(out)
    }
}

/// Queue entry: higher priority first, then submission order. `idx`
/// points into `job.points`.
struct QueuedPoint {
    priority: i32,
    seq: u64,
    idx: usize,
    job: Arc<Job>,
}

impl PartialEq for QueuedPoint {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for QueuedPoint {}
impl PartialOrd for QueuedPoint {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedPoint {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // Max-heap: greatest priority wins; within a priority the
        // earliest seq wins (so invert the seq ordering).
        self.priority.cmp(&other.priority).then(other.seq.cmp(&self.seq))
    }
}

/// One client's lane in the [`FairQueue`]: its own priority heap plus
/// lifetime fair-share accounting. The entry outlives its queued work
/// so `metrics` keeps reporting completed/rejected counts.
struct ClientLane {
    heap: BinaryHeap<QueuedPoint>,
    /// Deficit-round-robin weight: pops this client gets per turn.
    weight: u64,
    completed: u64,
    rejected: u64,
}

impl ClientLane {
    fn new(weight: u64) -> ClientLane {
        ClientLane { heap: BinaryHeap::new(), weight, completed: 0, rejected: 0 }
    }
}

/// Deficit-round-robin scheduler across client identities: each client
/// keeps its own priority heap (higher priority first, FIFO within),
/// and clients with queued work take turns of `weight` pops each, so
/// the interleave between two equal-weight clients is strict
/// alternation no matter how lopsided their backlogs are. With a
/// single client this degenerates to exactly the pre-v4 global heap.
struct FairQueue {
    lanes: BTreeMap<String, ClientLane>,
    /// Clients with queued work, in rotation order. Invariant: a
    /// client is in `rr` iff its lane's heap is non-empty.
    rr: VecDeque<String>,
    /// Pops left in the front client's turn.
    credit: u64,
    len: usize,
}

impl FairQueue {
    fn new() -> FairQueue {
        FairQueue { lanes: BTreeMap::new(), rr: VecDeque::new(), credit: 0, len: 0 }
    }

    fn lane(&mut self, client: &str, weight: u64) -> &mut ClientLane {
        self.lanes.entry(client.to_string()).or_insert_with(|| ClientLane::new(weight))
    }

    fn push(&mut self, client: &str, weight: u64, qp: QueuedPoint) {
        let lane = self.lane(client, weight);
        lane.weight = weight;
        if lane.heap.is_empty() {
            self.rr.push_back(client.to_string());
            if self.rr.len() == 1 {
                self.credit = lane.weight;
            }
        }
        lane.heap.push(qp);
        self.len += 1;
    }

    fn pop(&mut self) -> Option<QueuedPoint> {
        let front = self.rr.front()?.clone();
        if self.credit == 0 {
            self.rotate();
        }
        let name = self.rr.front().cloned().unwrap_or(front);
        let lane = self.lanes.get_mut(&name).expect("rr names an existing lane");
        let qp = lane.heap.pop().expect("rr lanes are non-empty");
        self.len -= 1;
        self.credit = self.credit.saturating_sub(1);
        if lane.heap.is_empty() {
            self.rr.pop_front();
            self.refresh_credit();
        } else if self.credit == 0 {
            self.rotate();
        }
        Some(qp)
    }

    /// Move the front client to the back and hand the turn on.
    fn rotate(&mut self) {
        if let Some(name) = self.rr.pop_front() {
            self.rr.push_back(name);
        }
        self.refresh_credit();
    }

    fn refresh_credit(&mut self) {
        self.credit = match self.rr.front() {
            Some(name) => self.lanes[name].weight.max(1),
            None => 0,
        };
    }

    fn len(&self) -> usize {
        self.len
    }

    /// Points queued for one client (0 for an unknown client).
    fn queued_for(&self, client: &str) -> usize {
        self.lanes.get(client).map_or(0, |l| l.heap.len())
    }

    fn note_completed(&mut self, client: &str, weight: u64) {
        self.lane(client, weight).completed += 1;
    }

    fn note_rejected(&mut self, client: &str, weight: u64) {
        self.lane(client, weight).rejected += 1;
    }

    /// Per-client `metrics` rows, sorted by client id (BTreeMap order).
    fn client_rows(&self) -> Vec<ClientMetrics> {
        self.lanes
            .iter()
            .map(|(id, lane)| ClientMetrics {
                client_id: id.clone(),
                weight: lane.weight,
                queued: lane.heap.len(),
                completed: lane.completed,
                rejected: lane.rejected,
            })
            .collect()
    }
}

#[derive(Default)]
struct ServiceCounters {
    requests: AtomicU64,
    points: AtomicU64,
    simulated: AtomicU64,
    mem_hits: AtomicU64,
    disk_hits: AtomicU64,
    dedup_waits: AtomicU64,
    admission_rejected: AtomicU64,
}

/// The resident sweep service. One instance per daemon; shared across
/// connections behind an `Arc`.
pub struct Service {
    cache: SimCache,
    kernels: KernelCache,
    inflight: Mutex<HashMap<String, Arc<Flight>>>,
    queue: Mutex<FairQueue>,
    seq: AtomicU64,
    counters: ServiceCounters,
    started: Instant,
    /// Submits currently executing (the graceful-shutdown drain latch).
    active: Mutex<u64>,
    idle_cv: Condvar,
    /// Admission cap on queued points; 0 disables backpressure.
    max_queue: AtomicUsize,
    /// Per-client admission cap on queued points; 0 disables quotas.
    max_client_queue: AtomicUsize,
    /// Configured deficit-round-robin weights (absent clients get 1).
    weights: Mutex<HashMap<String, u64>>,
    /// Recently admitted `request_id`s and their jobs (retry dedup).
    recent: Mutex<VecDeque<(String, Arc<Job>)>>,
    /// Lifetime simulated cycles and simulation wall time (µs) — the
    /// aggregate cycles/s the `metrics` record reports.
    sim_cycles: AtomicU64,
    sim_wall_us: AtomicU64,
}

/// Admission-control verdict on a submit: started, or refused because
/// the queue is full (the client should retry after the hint).
pub enum Admission {
    Started(ActiveRequest),
    Busy { retry_after_ms: u64 },
}

/// A submit in execution: the job plus the RAII active-count guard the
/// graceful-shutdown drain waits on. Dropping it (reply sent, client
/// gone, error) releases the drain latch.
pub struct ActiveRequest {
    svc: Arc<Service>,
    job: Arc<Job>,
    started: Instant,
}

impl ActiveRequest {
    pub fn job(&self) -> &Arc<Job> {
        &self.job
    }

    pub fn elapsed_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Block until the batch finishes and build the blocking reply.
    pub fn wait_reply(&self) -> Result<SubmitReply> {
        let results = self.job.wait()?;
        let count = |s: PointSource| results.iter().filter(|r| r.source == s).count();
        Ok(SubmitReply {
            points: results.len(),
            simulated: count(PointSource::Simulated),
            mem_hits: count(PointSource::MemHit),
            disk_hits: count(PointSource::DiskHit),
            deduped: count(PointSource::Dedup),
            elapsed_ms: self.elapsed_ms(),
            results: results
                .iter()
                .map(|r| summarize(&r.point, &r.report, r.source))
                .collect(),
            degraded: false,
        })
    }
}

impl Drop for ActiveRequest {
    fn drop(&mut self) {
        let mut n = self.svc.active.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.svc.idle_cv.notify_all();
        }
    }
}

impl Service {
    /// Build a service; `store` becomes the persistent tier under the
    /// service's [`SimCache`].
    pub fn new(store: Option<DiskStore>) -> Service {
        let cache = SimCache::new();
        if let Some(s) = store {
            cache.attach_store(Arc::new(s));
        }
        Service {
            cache,
            kernels: KernelCache::new(),
            inflight: Mutex::new(HashMap::new()),
            queue: Mutex::new(FairQueue::new()),
            seq: AtomicU64::new(0),
            counters: ServiceCounters::default(),
            started: Instant::now(),
            active: Mutex::new(0),
            idle_cv: Condvar::new(),
            max_queue: AtomicUsize::new(0),
            max_client_queue: AtomicUsize::new(0),
            weights: Mutex::new(HashMap::new()),
            recent: Mutex::new(VecDeque::new()),
            sim_cycles: AtomicU64::new(0),
            sim_wall_us: AtomicU64::new(0),
        }
    }

    /// Set the admission cap on queued points (0 disables backpressure:
    /// every submit is admitted, as before v3).
    pub fn set_max_queue(&self, n: usize) {
        self.max_queue.store(n, Ordering::Relaxed);
    }

    /// Set the per-client admission quota on queued points (v4; 0
    /// disables it). A client already holding `n` queued points gets
    /// `busy` instead of admission, independent of the global cap.
    pub fn set_max_client_queue(&self, n: usize) {
        self.max_client_queue.store(n, Ordering::Relaxed);
    }

    /// Install deficit-round-robin weights per client id; clients not
    /// listed weigh 1. Takes effect for newly enqueued work.
    pub fn set_client_weights(&self, weights: HashMap<String, u64>) {
        *self.weights.lock().unwrap() = weights;
    }

    fn weight_of(&self, client: &str) -> u64 {
        self.weights.lock().unwrap().get(client).copied().unwrap_or(1).max(1)
    }

    /// Block until no submit is executing — the shutdown path drains
    /// in-flight batches so their clients get results, not a dead
    /// socket.
    pub fn wait_idle(&self) {
        let mut n = self.active.lock().unwrap();
        while *n > 0 {
            n = self.idle_cv.wait(n).unwrap();
        }
    }

    /// The service's two-tier cache (tests introspect it).
    pub fn cache(&self) -> &SimCache {
        &self.cache
    }

    /// Enqueue a batch under a client identity and fan its points out
    /// on the rayon pool.
    pub fn submit_as(
        self: &Arc<Self>,
        points: Vec<SweepPoint>,
        priority: i32,
        fresh: bool,
        client: &str,
    ) -> Arc<Job> {
        self.counters.requests.fetch_add(1, Ordering::Relaxed);
        self.counters.points.fetch_add(points.len() as u64, Ordering::Relaxed);
        let weight = self.weight_of(client);
        let job = Arc::new(Job::new(points, fresh, client.to_string()));
        // Enqueue grouped by kernel so same-kernel points pop
        // consecutively (KernelCache compiles once either way; grouping
        // keeps the compile fully off the tail points' critical path).
        let mut order: Vec<usize> = (0..job.points.len()).collect();
        order.sort_by_key(|&i| {
            let p = &job.points[i];
            (p.workload.name(), p.target.smem_near(), i)
        });
        let n = order.len();
        {
            let mut q = self.queue.lock().unwrap();
            for idx in order {
                let seq = self.seq.fetch_add(1, Ordering::Relaxed);
                q.push(client, weight, QueuedPoint { priority, seq, idx, job: job.clone() });
            }
        }
        for _ in 0..n {
            let svc = self.clone();
            rayon::spawn(move || svc.drain_one());
        }
        job
    }

    /// [`Service::submit_as`] under the shared [`ANON_CLIENT`] bucket.
    pub fn submit(self: &Arc<Self>, points: Vec<SweepPoint>, priority: i32, fresh: bool) -> Arc<Job> {
        self.submit_as(points, priority, fresh, ANON_CLIENT)
    }

    /// Expand a protocol request and start it executing, subject to
    /// admission control. A request whose `request_id` matches a
    /// recently admitted batch attaches to that batch's job (a retry
    /// after a dropped reply never re-simulates); a full queue — global
    /// cap or the submitting client's quota — earns a `busy` with a
    /// retry hint instead of unbounded growth.
    pub fn try_begin_request(self: &Arc<Self>, req: &SubmitRequest) -> Result<Admission> {
        if let Some(id) = &req.request_id {
            let recent = self.recent.lock().unwrap();
            if let Some((_, job)) = recent.iter().find(|(rid, _)| rid == id) {
                let job = job.clone();
                drop(recent);
                *self.active.lock().unwrap() += 1;
                return Ok(Admission::Started(ActiveRequest {
                    svc: self.clone(),
                    job,
                    started: Instant::now(),
                }));
            }
        }
        let points = req.points()?;
        let client = req.client_id.as_deref().unwrap_or(ANON_CLIENT);
        let limit = self.max_queue.load(Ordering::Relaxed);
        let quota = self.max_client_queue.load(Ordering::Relaxed);
        let weight = self.weight_of(client);
        {
            let mut q = self.queue.lock().unwrap();
            let over_global = limit > 0 && q.len() >= limit;
            let over_quota = quota > 0 && q.queued_for(client) >= quota;
            if over_global || over_quota {
                if over_quota {
                    q.note_rejected(client, weight);
                }
                drop(q);
                self.counters.admission_rejected.fetch_add(1, Ordering::Relaxed);
                return Ok(Admission::Busy { retry_after_ms: BUSY_RETRY_AFTER_MS });
            }
        }
        *self.active.lock().unwrap() += 1;
        let started = Instant::now();
        let job = self.submit_as(points, req.priority, req.fresh, client);
        if let Some(id) = &req.request_id {
            self.remember(id, &job);
        }
        Ok(Admission::Started(ActiveRequest { svc: self.clone(), job, started }))
    }

    fn remember(&self, id: &str, job: &Arc<Job>) {
        let mut recent = self.recent.lock().unwrap();
        if recent.iter().any(|(rid, _)| rid == id) {
            return;
        }
        if recent.len() >= RECENT_IDS {
            recent.pop_front();
        }
        recent.push_back((id.to_string(), job.clone()));
    }

    /// [`Service::try_begin_request`] for callers without a busy path
    /// of their own: a rejection becomes an error.
    pub fn begin_request(self: &Arc<Self>, req: &SubmitRequest) -> Result<ActiveRequest> {
        match self.try_begin_request(req)? {
            Admission::Started(ar) => Ok(ar),
            Admission::Busy { retry_after_ms } => Err(anyhow!(
                "server busy (queue full); retry after {retry_after_ms} ms"
            )),
        }
    }

    /// Expand a protocol request, run it to completion, and summarize —
    /// the blocking submit path, also used directly by tests.
    pub fn run_request(self: &Arc<Self>, req: &SubmitRequest) -> Result<SubmitReply> {
        self.begin_request(req)?.wait_reply()
    }

    /// Points queued but not yet claimed by a runner.
    pub fn queue_depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Simulations currently in flight (dedup table size).
    pub fn inflight_len(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }

    /// Submit requests currently executing.
    pub fn active_requests(&self) -> u64 {
        *self.active.lock().unwrap()
    }

    /// Daemon counter snapshot.
    pub fn status(&self) -> StatusBody {
        StatusBody {
            proto_version: PROTO_VERSION,
            uptime_ms: self.started.elapsed().as_millis() as u64,
            requests: self.counters.requests.load(Ordering::Relaxed),
            points: self.counters.points.load(Ordering::Relaxed),
            simulated: self.counters.simulated.load(Ordering::Relaxed),
            mem_hits: self.counters.mem_hits.load(Ordering::Relaxed),
            disk_hits: self.counters.disk_hits.load(Ordering::Relaxed),
            dedup_waits: self.counters.dedup_waits.load(Ordering::Relaxed),
            kernels_compiled: self.kernels.len(),
            mem_entries: self.cache.len(),
            store: self.cache.store().map(|s| s.stats()),
            proto_major: PROTO_MAJOR,
            queue_depth: self.queue_depth(),
            inflight: self.inflight_len(),
            active_requests: self.active_requests(),
            workers: None,
            admission_rejected: self.counters.admission_rejected.load(Ordering::Relaxed),
            queue_limit: self.max_queue.load(Ordering::Relaxed),
            retries: 0,
            degraded_batches: 0,
        }
    }

    /// Aggregate simulation throughput: lifetime simulated cycles over
    /// lifetime simulation wall time.
    pub fn sim_cycles_per_sec(&self) -> f64 {
        let cycles = self.sim_cycles.load(Ordering::Relaxed) as f64;
        let wall_us = self.sim_wall_us.load(Ordering::Relaxed) as f64;
        if wall_us <= 0.0 {
            return 0.0;
        }
        cycles / (wall_us / 1e6)
    }

    /// Operational metrics snapshot (v4): everything `status` reports
    /// plus derived rates and per-client fair-share rows. A coordinator
    /// extends this with per-worker rows.
    pub fn metrics(&self) -> MetricsBody {
        let simulated = self.counters.simulated.load(Ordering::Relaxed);
        let mem_hits = self.counters.mem_hits.load(Ordering::Relaxed);
        let disk_hits = self.counters.disk_hits.load(Ordering::Relaxed);
        let dedup_waits = self.counters.dedup_waits.load(Ordering::Relaxed);
        let served = simulated + mem_hits + disk_hits + dedup_waits;
        let cache_hit_rate = if served == 0 {
            0.0
        } else {
            (mem_hits + disk_hits + dedup_waits) as f64 / served as f64
        };
        let (queue_depth, clients) = {
            let q = self.queue.lock().unwrap();
            (q.len(), q.client_rows())
        };
        MetricsBody {
            schema_version: METRICS_SCHEMA_VERSION,
            report: "metrics".to_string(),
            proto_version: PROTO_VERSION,
            uptime_ms: self.started.elapsed().as_millis() as u64,
            queue_depth,
            queue_limit: self.max_queue.load(Ordering::Relaxed),
            inflight: self.inflight_len(),
            active_requests: self.active_requests(),
            requests: self.counters.requests.load(Ordering::Relaxed),
            points: self.counters.points.load(Ordering::Relaxed),
            simulated,
            mem_hits,
            disk_hits,
            dedup_waits,
            cache_hit_rate,
            admission_rejected: self.counters.admission_rejected.load(Ordering::Relaxed),
            retries: 0,
            degraded_batches: 0,
            sim_cycles_per_sec: self.sim_cycles_per_sec(),
            store: self.cache.store().map(|s| s.stats()),
            clients,
            workers: vec![],
        }
    }

    fn drain_one(self: Arc<Self>) {
        let qp = self.queue.lock().unwrap().pop();
        let Some(qp) = qp else { return };
        let pt = &qp.job.points[qp.idx];
        let res = match self.run_point(pt, qp.job.fresh) {
            Ok((report, source)) => {
                let ctr = match source {
                    PointSource::Simulated => &self.counters.simulated,
                    PointSource::MemHit => &self.counters.mem_hits,
                    PointSource::DiskHit => &self.counters.disk_hits,
                    PointSource::Dedup => &self.counters.dedup_waits,
                };
                ctr.fetch_add(1, Ordering::Relaxed);
                if source == PointSource::Simulated {
                    self.sim_cycles.fetch_add(report.cycles, Ordering::Relaxed);
                    self.sim_wall_us
                        .fetch_add((report.sim_wall_ms * 1_000.0) as u64, Ordering::Relaxed);
                }
                Ok((report, source))
            }
            Err(e) => Err(e.to_string()),
        };
        let weight = self.weight_of(qp.job.client());
        self.queue.lock().unwrap().note_completed(qp.job.client(), weight);
        qp.job.record(qp.idx, res);
    }

    /// Run one point through dedup + the two-tier cache.
    fn run_point(&self, pt: &SweepPoint, fresh: bool) -> Result<(RunReport, PointSource)> {
        let simulate = || pt.simulate(&self.kernels);
        if fresh {
            // Forced re-simulation repairs both tiers: the fresh result
            // overwrites whatever the memory map and the store held.
            let r = simulate()?;
            self.cache.put(pt, &r);
            return Ok((r, PointSource::Simulated));
        }
        let key = pt.cache_key();
        enum Claim {
            Owner(Arc<Flight>),
            Waiter(Arc<Flight>),
        }
        let claim = {
            let mut inflight = self.inflight.lock().unwrap();
            match inflight.get(&key) {
                Some(f) => Claim::Waiter(f.clone()),
                None => {
                    let f = Arc::new(Flight::new());
                    inflight.insert(key.clone(), f.clone());
                    Claim::Owner(f)
                }
            }
        };
        match claim {
            Claim::Owner(flight) => {
                let res = self.cache.get_or_run_traced(pt, simulate);
                flight.publish(match &res {
                    Ok((r, _)) => Ok(r.clone()),
                    Err(e) => Err(e.to_string()),
                });
                self.inflight.lock().unwrap().remove(&key);
                res.map(|(r, tier)| {
                    let source = match tier {
                        CacheTier::Memory => PointSource::MemHit,
                        CacheTier::Disk => PointSource::DiskHit,
                        CacheTier::Simulated => PointSource::Simulated,
                    };
                    (r, source)
                })
            }
            Claim::Waiter(flight) => flight.wait().map(|r| (r, PointSource::Dedup)),
        }
    }
}

/// What a [`SweepServer`] fronts: a local simulating [`Service`], or a
/// [`Coordinator`] that shards submits across worker daemons.
#[derive(Clone)]
pub enum ServeMode {
    Local(Arc<Service>),
    Federated(Arc<Coordinator>),
}

impl ServeMode {
    fn status(&self) -> StatusBody {
        match self {
            ServeMode::Local(svc) => svc.status(),
            ServeMode::Federated(co) => co.status(),
        }
    }

    fn metrics(&self) -> MetricsBody {
        match self {
            ServeMode::Local(svc) => svc.metrics(),
            ServeMode::Federated(co) => co.metrics(),
        }
    }

    fn wait_idle(&self) {
        match self {
            ServeMode::Local(svc) => svc.wait_idle(),
            ServeMode::Federated(co) => co.wait_idle(),
        }
    }
}

/// The TCP front of a [`Service`] or [`Coordinator`]: bind first (so
/// tests can learn the ephemeral port), then [`SweepServer::run`] the
/// accept loop until a `shutdown` request.
pub struct SweepServer {
    listener: TcpListener,
    mode: ServeMode,
    stop: Arc<AtomicBool>,
}

impl SweepServer {
    /// Bind a local (simulating) daemon.
    pub fn bind(svc: Arc<Service>, addr: &str) -> Result<SweepServer> {
        SweepServer::bind_mode(ServeMode::Local(svc), addr)
    }

    /// Bind a coordinator daemon fronting a worker fleet.
    pub fn bind_coordinator(co: Arc<Coordinator>, addr: &str) -> Result<SweepServer> {
        SweepServer::bind_mode(ServeMode::Federated(co), addr)
    }

    pub fn bind_mode(mode: ServeMode, addr: &str) -> Result<SweepServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("binding mpu serve to {addr}: {e}"))?;
        Ok(SweepServer { listener, mode, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// Bound address (resolves `:0` test binds).
    pub fn addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local addr")
    }

    /// Accept loop: one thread per connection, any number of JSONL
    /// requests per connection. Returns after a `shutdown` request.
    pub fn run(self) -> Result<()> {
        let addr = self.addr();
        for stream in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let mode = self.mode.clone();
            let stop = self.stop.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(mode, stream, stop, addr);
            });
        }
        Ok(())
    }
}

fn handle_conn(
    mode: ServeMode,
    stream: TcpStream,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    // The connection's default fair-share identity, set by a v4
    // `hello` and inherited by submits that carry no `client_id`.
    let mut conn_client: Option<String> = None;
    loop {
        // Byte-level framing: a malformed frame — including invalid
        // UTF-8, which `lines()` would turn into a handler-killing
        // error — must reach the parser and earn an `error` reply,
        // leaving the connection serving. Only real transport errors
        // end the handler.
        buf.clear();
        if reader.read_until(b'\n', &mut buf)? == 0 {
            return Ok(()); // clean EOF
        }
        let raw = String::from_utf8_lossy(&buf);
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let req = match serde_json::from_str::<Request>(line) {
            Err(e) => {
                write_line(&mut writer, &Response::Error { message: format!("bad request line: {e}") })?;
                continue;
            }
            Ok(req) => req,
        };
        match req {
            Request::Ping => write_line(&mut writer, &Response::Pong { proto_version: PROTO_VERSION })?,
            Request::Hello { proto_version, proto_major, client_id } => {
                let resp = if proto_major != PROTO_MAJOR {
                    Response::Error {
                        message: format!(
                            "protocol major mismatch: client speaks v{proto_version} \
                             (major {proto_major}), this server speaks v{PROTO_VERSION} \
                             (major {PROTO_MAJOR}) — upgrade the older side"
                        ),
                    }
                } else {
                    if client_id.is_some() {
                        conn_client = client_id;
                    }
                    Response::Hello {
                        proto_version: PROTO_VERSION,
                        proto_major: PROTO_MAJOR,
                        features: FEATURES.iter().map(|f| f.to_string()).collect(),
                    }
                };
                write_line(&mut writer, &resp)?;
            }
            Request::Status => write_line(&mut writer, &Response::Status(mode.status()))?,
            Request::Metrics => write_line(&mut writer, &Response::Metrics(mode.metrics()))?,
            Request::Join { addr: worker } => {
                let resp = match &mode {
                    ServeMode::Local(_) => Response::Error {
                        message: "join: this daemon is a worker, not a coordinator".into(),
                    },
                    ServeMode::Federated(co) => match co.federation().join(&worker) {
                        Ok(workers) => Response::Fleet { workers },
                        Err(e) => Response::Error { message: e.to_string() },
                    },
                };
                write_line(&mut writer, &resp)?;
            }
            Request::Drain { addr: worker } => {
                let resp = match &mode {
                    ServeMode::Local(_) => Response::Error {
                        message: "drain: this daemon is a worker, not a coordinator".into(),
                    },
                    ServeMode::Federated(co) => match co.federation().drain(&worker) {
                        Ok(workers) => Response::Fleet { workers },
                        Err(e) => Response::Error { message: e.to_string() },
                    },
                };
                write_line(&mut writer, &resp)?;
            }
            Request::Submit(mut req) => {
                if req.client_id.is_none() {
                    req.client_id = conn_client.clone();
                }
                match &mode {
                    ServeMode::Local(svc) => match svc.try_begin_request(&req) {
                        Err(e) => {
                            write_line(&mut writer, &Response::Error { message: e.to_string() })?
                        }
                        Ok(Admission::Busy { retry_after_ms }) => {
                            write_line(&mut writer, &Response::Busy { retry_after_ms })?
                        }
                        Ok(Admission::Started(ar)) => {
                            if req.stream {
                                stream_submit_local(&ar, &req, &mut writer)?;
                            } else {
                                let resp = match ar.wait_reply() {
                                    Ok(reply) => Response::Done(reply),
                                    Err(e) => Response::Error { message: e.to_string() },
                                };
                                write_line(&mut writer, &resp)?;
                            }
                        }
                    },
                    ServeMode::Federated(co) => {
                        co.serve_submit(&req, &mut writer)?;
                    }
                }
            }
            Request::Shutdown => {
                // Drain batches still executing on other connections so
                // their clients get results, then stop accepting.
                mode.wait_idle();
                write_line(&mut writer, &Response::Bye)?;
                stop.store(true, Ordering::SeqCst);
                // Wake the accept loop so it observes the stop flag.
                let _ = TcpStream::connect(addr);
                return Ok(());
            }
        }
    }
}

/// Serve one streamed submit already admitted to the local service:
/// emit a `result` record per completed point (in completion order)
/// and a `progress` record per wake-up, then the terminal
/// `done`/`error`. For a retried request attached to an in-flight job,
/// already-finished points replay immediately — the client dedups by
/// batch index.
fn stream_submit_local(
    ar: &ActiveRequest,
    req: &SubmitRequest,
    writer: &mut BufWriter<TcpStream>,
) -> std::io::Result<()> {
    let total = ar.job().total();
    // The terminal reply is assembled from the summaries accumulated
    // while streaming — no second full-report clone of every slot.
    let mut summaries: Vec<Option<PointSummary>> = vec![None; total];
    let mut failed = false;
    let mut seen = 0usize;
    while seen < total {
        let newly = ar.job().wait_past(seen);
        for &idx in &newly {
            match ar.job().peek(idx) {
                Some(Ok((report, source))) => {
                    let pt = ar.job().point(idx);
                    let summary = summarize(pt, &report, source);
                    let body = ResultBody {
                        index: idx,
                        point: summary.clone(),
                        report: req
                            .return_reports
                            .then(|| WireReport::from_report(pt.scale, &report)),
                    };
                    write_line(writer, &Response::Result(body))?;
                    summaries[idx] = Some(summary);
                }
                // Failed points carry no result record; the terminal
                // error reports them (blocking semantics fail the
                // whole batch).
                Some(Err(_)) => failed = true,
                None => {}
            }
        }
        seen += newly.len();
        let progress =
            ProgressBody { completed: seen, total, elapsed_ms: ar.elapsed_ms() };
        write_line(writer, &Response::Progress(progress))?;
    }
    let resp = if failed {
        match ar.wait_reply() {
            Ok(reply) => Response::Done(reply),
            Err(e) => Response::Error { message: e.to_string() },
        }
    } else {
        let results: Vec<PointSummary> =
            summaries.into_iter().map(|s| s.expect("streamed batch complete")).collect();
        let count = |src: &str| results.iter().filter(|r| r.source == src).count();
        Response::Done(SubmitReply {
            points: total,
            simulated: count("sim"),
            mem_hits: count("mem"),
            disk_hits: count("disk"),
            deduped: count("dedup"),
            elapsed_ms: ar.elapsed_ms(),
            results,
            degraded: false,
        })
    };
    write_line(writer, &resp)
}

pub(crate) fn write_line(
    writer: &mut BufWriter<TcpStream>,
    resp: &Response,
) -> std::io::Result<()> {
    let body = serde_json::to_string(resp).expect("responses always serialize");
    writer.write_all(body.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::coordinator::sweep::Target;
    use crate::workloads::{Scale, Workload};

    fn axpy_req() -> SubmitRequest {
        SubmitRequest {
            workloads: vec!["axpy".into()],
            scale: "tiny".into(),
            variants: vec!["mpu".into()],
            ..SubmitRequest::default()
        }
    }

    fn dummy_job() -> Arc<Job> {
        let cfg = MachineConfig::scaled();
        Arc::new(Job::new(
            vec![SweepPoint {
                label: "mpu".into(),
                workload: Workload::Axpy,
                scale: Scale::Tiny,
                target: Target::Mpu(cfg),
            }],
            false,
            ANON_CLIENT.to_string(),
        ))
    }

    #[test]
    fn queue_orders_by_priority_then_fifo() {
        // A single client's lane is the pre-v4 global heap: priority
        // desc, FIFO within a priority.
        let job = dummy_job();
        let mut q = FairQueue::new();
        for (priority, seq) in [(0, 0u64), (5, 1), (5, 2), (-1, 3), (0, 4)] {
            q.push(ANON_CLIENT, 1, QueuedPoint { priority, seq, idx: 0, job: job.clone() });
        }
        let popped: Vec<(i32, u64)> =
            std::iter::from_fn(|| q.pop().map(|qp| (qp.priority, qp.seq))).collect();
        assert_eq!(popped, vec![(5, 1), (5, 2), (0, 0), (0, 4), (-1, 3)]);
    }

    #[test]
    fn fair_queue_interleaves_clients_deficit_round_robin() {
        // Two equal-weight clients with lopsided backlogs (alice
        // enqueues 4 points before bob's 2 arrive) still alternate
        // strictly; the straggler's backlog drains at the tail.
        let job = dummy_job();
        let mut q = FairQueue::new();
        let mut seq = 0u64;
        let mut push = |q: &mut FairQueue, client: &str, weight: u64| {
            q.push(client, weight, QueuedPoint { priority: 0, seq, idx: 0, job: job.clone() });
            seq += 1;
        };
        for _ in 0..4 {
            push(&mut q, "alice", 1);
        }
        for _ in 0..2 {
            push(&mut q, "bob", 1);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|qp| qp.seq)).collect();
        // alice holds seqs 0..4, bob 4..6: strict alternation, then
        // alice's leftover backlog.
        assert_eq!(order, vec![0, 4, 1, 5, 2, 3]);
        assert_eq!(q.len(), 0);

        // Weights skew the interleave: weight 2 earns two pops a turn.
        let mut q = FairQueue::new();
        for _ in 0..4 {
            push(&mut q, "alice", 2);
        }
        for _ in 0..2 {
            push(&mut q, "bob", 1);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|qp| qp.seq)).collect();
        assert_eq!(order, vec![6, 7, 10, 8, 9, 11]);
        // Lifetime rows survive the drain (metrics keeps reporting).
        q.note_completed("alice", 2);
        let rows = q.client_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].client_id, "alice");
        assert_eq!(rows[0].completed, 1);
        assert_eq!(rows[0].queued, 0);
    }

    #[test]
    fn service_counts_simulations_and_mem_hits() {
        let svc = Arc::new(Service::new(None));
        let req = axpy_req();
        let first = svc.run_request(&req).unwrap();
        assert_eq!(first.points, 1);
        assert_eq!(first.simulated, 1);
        assert_eq!(first.cached(), 0);
        assert!(first.results[0].correct);
        assert_eq!(first.results[0].source, "sim");
        let second = svc.run_request(&req).unwrap();
        assert_eq!(second.simulated, 0);
        assert_eq!(second.mem_hits, 1);
        assert_eq!(second.results[0].cycles, first.results[0].cycles);
        let status = svc.status();
        assert_eq!(status.requests, 2);
        assert_eq!(status.points, 2);
        assert_eq!(status.simulated, 1);
        assert_eq!(status.mem_hits, 1);
        assert!(status.store.is_none());
        // The busy-daemon fields are quiescent here but present.
        assert_eq!(status.proto_major, PROTO_MAJOR);
        assert_eq!(status.queue_depth, 0);
        assert_eq!(status.inflight, 0);
        assert_eq!(status.active_requests, 0);
        assert!(status.workers.is_none());
    }

    #[test]
    fn fresh_requests_bypass_every_tier() {
        let svc = Arc::new(Service::new(None));
        let mut req = axpy_req();
        svc.run_request(&req).unwrap();
        req.fresh = true;
        let again = svc.run_request(&req).unwrap();
        assert_eq!(again.simulated, 1, "fresh must re-simulate");
    }

    #[test]
    fn job_completion_order_and_incremental_waits() {
        // Drive a Job by hand: record results out of point order and
        // check the streamed-walk primitives see them incrementally.
        let cfg = MachineConfig::scaled();
        let mk = |w| SweepPoint {
            label: "mpu".into(),
            workload: w,
            scale: Scale::Tiny,
            target: Target::Mpu(cfg.clone()),
        };
        let job = Job::new(
            vec![mk(Workload::Axpy), mk(Workload::Knn)],
            false,
            ANON_CLIENT.to_string(),
        );
        assert_eq!(job.total(), 2);
        assert_eq!(job.completed(), 0);
        assert!(job.peek(0).is_none());
        let r = crate::coordinator::run_workload_scaled(
            Workload::Axpy,
            &cfg,
            Scale::Tiny,
        )
        .unwrap();
        job.record(1, Ok((r.clone(), PointSource::Simulated)));
        assert_eq!(job.completed(), 1);
        let newly = job.wait_past(0);
        assert_eq!(newly, vec![1], "completion order, not point order");
        assert!(job.peek(1).unwrap().is_ok());
        job.record(0, Ok((r, PointSource::MemHit)));
        let newly = job.wait_past(1);
        assert_eq!(newly, vec![0]);
        assert!(job.wait_past(2).is_empty(), "past the end returns empty");
        // wait() is idempotent over cloned slots.
        let results = job.wait().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].source, PointSource::MemHit);
        assert_eq!(results[1].source, PointSource::Simulated);
        assert_eq!(job.wait().unwrap().len(), 2);
    }

    #[test]
    fn full_queue_earns_busy_and_drains_back_to_admission() {
        let svc = Arc::new(Service::new(None));
        svc.set_max_queue(1);
        // Park a synthetic queued point so the backlog is at the cap
        // (no rayon task will ever pop it — it exists only to occupy
        // the queue).
        let parked = dummy_job();
        svc.queue
            .lock()
            .unwrap()
            .push(ANON_CLIENT, 1, QueuedPoint { priority: 0, seq: 0, idx: 0, job: parked });
        match svc.try_begin_request(&axpy_req()).unwrap() {
            Admission::Busy { retry_after_ms } => assert!(retry_after_ms > 0),
            Admission::Started(_) => panic!("full queue must refuse admission"),
        }
        assert_eq!(svc.status().admission_rejected, 1);
        assert_eq!(svc.status().queue_limit, 1);
        // Drain the parked point; admission recovers.
        svc.queue.lock().unwrap().pop();
        match svc.try_begin_request(&axpy_req()).unwrap() {
            Admission::Started(ar) => {
                ar.wait_reply().unwrap();
            }
            Admission::Busy { .. } => panic!("empty queue must admit"),
        }
    }

    #[test]
    fn retried_request_id_attaches_to_the_inflight_job() {
        let svc = Arc::new(Service::new(None));
        let mut req = axpy_req();
        req.fresh = true; // prove dedup is by request id, not cache
        req.request_id = Some("retry-me-1".into());
        let first = match svc.try_begin_request(&req).unwrap() {
            Admission::Started(ar) => ar,
            Admission::Busy { .. } => panic!("must admit"),
        };
        let second = match svc.try_begin_request(&req).unwrap() {
            Admission::Started(ar) => ar,
            Admission::Busy { .. } => panic!("must attach, not refuse"),
        };
        assert!(
            Arc::ptr_eq(first.job(), second.job()),
            "same request_id must attach to the same job"
        );
        let a = first.wait_reply().unwrap();
        let b = second.wait_reply().unwrap();
        assert_eq!(a.simulated, 1);
        assert_eq!(b.simulated, 1, "the attached view sees the same single run");
        assert_eq!(svc.status().requests, 1, "one logical batch, not two");
        assert_eq!(svc.status().points, 1);
        // A different id is a genuinely new batch.
        req.request_id = Some("retry-me-2".into());
        let third = svc.begin_request(&req).unwrap();
        third.wait_reply().unwrap();
        assert_eq!(svc.status().requests, 2);
    }

    #[test]
    fn client_quota_earns_busy_independently_per_client() {
        let svc = Arc::new(Service::new(None));
        svc.set_max_client_queue(1);
        // Park a point in alice's lane so her quota is exhausted (no
        // rayon task will pop it yet — nothing has been spawned).
        svc.queue.lock().unwrap().push(
            "alice",
            1,
            QueuedPoint { priority: 0, seq: 0, idx: 0, job: dummy_job() },
        );
        let mut req = axpy_req();
        req.client_id = Some("alice".into());
        match svc.try_begin_request(&req).unwrap() {
            Admission::Busy { retry_after_ms } => assert!(retry_after_ms > 0),
            Admission::Started(_) => panic!("over-quota client must be refused"),
        }
        let m = svc.metrics();
        assert_eq!(m.admission_rejected, 1);
        let alice = m.clients.iter().find(|c| c.client_id == "alice").unwrap();
        assert_eq!(alice.rejected, 1);
        assert_eq!(alice.queued, 1);
        // Another client is unaffected by alice's backlog.
        req.client_id = Some("bob".into());
        match svc.try_begin_request(&req).unwrap() {
            Admission::Started(_) => {}
            Admission::Busy { .. } => panic!("bob is under quota"),
        }
    }

    #[test]
    fn metrics_counters_and_rates_move_with_traffic() {
        let svc = Arc::new(Service::new(None));
        let m0 = svc.metrics();
        assert_eq!(m0.points, 0);
        assert_eq!(m0.cache_hit_rate, 0.0);
        assert_eq!(m0.sim_cycles_per_sec, 0.0);
        let mut req = axpy_req();
        req.client_id = Some("alice".into());
        svc.run_request(&req).unwrap();
        svc.run_request(&req).unwrap(); // warm rerun: memory hit
        let m = svc.metrics();
        assert_eq!(m.schema_version, METRICS_SCHEMA_VERSION);
        assert_eq!(m.report, "metrics");
        assert_eq!(m.proto_version, PROTO_VERSION);
        assert_eq!(m.requests, 2);
        assert_eq!(m.points, 2);
        assert_eq!(m.simulated, 1);
        assert_eq!(m.mem_hits, 1);
        assert!((m.cache_hit_rate - 0.5).abs() < 1e-9);
        assert!(m.sim_cycles_per_sec > 0.0, "simulation must register throughput");
        assert!(m.workers.is_empty(), "a worker daemon has no worker rows");
        let alice = m.clients.iter().find(|c| c.client_id == "alice").unwrap();
        assert_eq!(alice.completed, 2);
        assert_eq!(alice.queued, 0);
        assert_eq!(alice.weight, 1);
        // The body doubles as the METRICS.json document, unchanged.
        let doc = serde_json::to_value(&m).unwrap();
        assert_eq!(doc["report"], "metrics");
        assert_eq!(doc["schema_version"], METRICS_SCHEMA_VERSION);
    }

    #[test]
    fn point_source_names_round_trip() {
        for s in [
            PointSource::Simulated,
            PointSource::MemHit,
            PointSource::DiskHit,
            PointSource::Dedup,
        ] {
            assert_eq!(PointSource::from_name(s.name()), Some(s));
        }
        assert_eq!(PointSource::from_name("warp-drive"), None);
    }
}
