//! Persistent on-disk simulation-result store — the second cache tier
//! under [`super::sweep::SimCache`].
//!
//! Layout (all JSON, std-only):
//!
//! ```text
//! <root>/index.json            # schema version, logical clock, LRU book-keeping
//! <root>/entries/<key>.json    # one StoredEntry per simulated point
//! ```
//!
//! Keys are the content-addressed SimCache keys
//! (`<workload>-<scale>-<variant>-<confighash>`), so any configuration
//! knob change produces a new entry and identical points collapse to one
//! file across processes, CLI invocations and daemon restarts.
//!
//! Robustness rules:
//! - Every write is tmp-file + atomic rename.
//! - A corrupt or schema-mismatched entry is quarantined (moved to
//!   `<root>/quarantine/` for post-mortem, counted in `corrupt_dropped`
//!   and `quarantined`) and treated as a miss — never an error.
//! - A missing or corrupt index is rebuilt by scanning `entries/`.
//! - The store is bounded: once `total bytes > max_bytes`, entries are
//!   evicted least-recently-*accessed* first (loads refresh recency).
//! - Persistent write failures (full disk, dead mount) demote the store
//!   to memory-only caching: after [`DEGRADE_AFTER`] consecutive
//!   failures, writes are skipped (counted) and the disk is re-probed
//!   every [`PROBE_EVERY`]-th store so a healed disk re-engages
//!   automatically. The batch never aborts on store trouble.
//!
//! Every write funnels through [`atomic_write`], which doubles as the
//! store's fault-injection seam: an active [`fault::FaultPlan`] can
//! tear an entry or index write in half (modelling a crash mid-write)
//! or fail it with ENOSPC.
//!
//! One writer (the `mpu serve` daemon) is the intended steady state;
//! concurrent multi-process writers are safe for entry files (atomic
//! rename) but may lose index recency updates, which only perturbs LRU
//! order, never correctness.
//!
//! The `<confighash>` key component is a stable FNV-1a over the
//! serde-serialized configuration (see `Target::fingerprint`), so keys
//! survive Rust releases and std hasher changes; only an actual
//! configuration-shape or value change moves an entry's key. (Schema
//! v2; the former `DefaultHasher`-over-`Debug` fingerprint went cold —
//! safely, but silently — on toolchain updates.)

use super::fault::{self, FaultClass};
use super::proto::WireReport;
use super::RunReport;
use crate::workloads::Scale;
use anyhow::{Context, Result};
use serde::{Deserialize, Serialize};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, SystemTime};

/// Consecutive write failures before the store demotes itself to
/// memory-only caching.
const DEGRADE_AFTER: u64 = 3;

/// While degraded, every N-th store attempt probes the disk (the first
/// attempt after degrading probes immediately) so recovery is
/// automatic once the disk heals.
const PROBE_EVERY: u64 = 8;

/// Version of the on-disk entry/index schema. Bumping it invalidates
/// every existing entry (they are dropped on load, not migrated).
///
/// v2: stable serde-based config fingerprints in the keys (entries
/// written under the old `DefaultHasher` keys would never be read
/// again) plus the simulator-throughput fields (`sim_wall_ms`,
/// `sim_cycles_per_sec`).
pub const STORE_SCHEMA_VERSION: u32 = 2;

/// Configuration of a [`DiskStore`].
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Root directory (created if missing).
    pub root: PathBuf,
    /// Size cap over entry-file bytes; least-recently-accessed entries
    /// are evicted once exceeded.
    pub max_bytes: u64,
}

impl StoreConfig {
    pub fn new(root: impl Into<PathBuf>) -> StoreConfig {
        StoreConfig { root: root.into(), max_bytes: 512 * 1024 * 1024 }
    }

    pub fn max_bytes(mut self, max_bytes: u64) -> StoreConfig {
        self.max_bytes = max_bytes;
        self
    }
}

/// Counter snapshot of a store (serialized into `mpu status` and the
/// suite JSON `stats` appendix).
#[derive(Clone, Debug, Default, Serialize, Deserialize)]
#[serde(default)]
pub struct StoreStats {
    pub entries: usize,
    pub bytes: u64,
    pub max_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries dropped because they were unreadable or carried a stale
    /// schema version.
    pub corrupt_dropped: u64,
    /// Entry/index writes that failed (ENOSPC, dead mount, ...).
    pub write_failures: u64,
    /// Corrupt entries moved to `<root>/quarantine/` instead of lost.
    pub quarantined: u64,
    /// The store is currently in memory-only mode after persistent
    /// write failures (it re-probes the disk periodically).
    pub degraded: bool,
}

/// Knobs of an explicit GC pass (`mpu store gc`): age-based expiry
/// rides alongside the byte cap, and every pass eagerly drops
/// schema-stale/corrupt entries and compacts the index.
#[derive(Clone, Debug, Default)]
pub struct GcOptions {
    /// Drop entries whose file modification time is older than this.
    pub max_age: Option<Duration>,
    /// Byte-cap override for this pass (default: the store's cap).
    pub max_bytes: Option<u64>,
}

/// What one GC pass did.
#[derive(Clone, Debug, Default)]
pub struct GcReport {
    /// Entry files scanned.
    pub scanned: usize,
    /// Unreadable, unparseable, mis-keyed or schema-stale entries
    /// dropped eagerly (a plain load would have dropped them lazily on
    /// first touch; GC sweeps them all at once).
    pub stale_dropped: usize,
    /// Entries past [`GcOptions::max_age`].
    pub expired: usize,
    /// LRU evictions needed to get under the byte cap.
    pub evicted: usize,
    /// Index rows whose entry file had vanished (compacted away).
    pub dangling_dropped: usize,
    /// Surviving entries / bytes after the pass.
    pub kept: usize,
    pub kept_bytes: u64,
}

/// One serialized simulation result: the shared serde mirror of
/// [`RunReport`] ([`WireReport`], flattened so the on-disk JSON shape
/// is unchanged) plus the store's own key/schema envelope. One mirror
/// to maintain — the wire and store schemas cannot silently diverge.
#[derive(Serialize, Deserialize)]
struct StoredEntry {
    schema_version: u32,
    key: String,
    #[serde(flatten)]
    body: WireReport,
}

/// `machine` strings are `&'static str` in [`RunReport`]; map the known
/// values back (anything else means a foreign/corrupt entry). Shared
/// with the wire-report decoding in [`super::proto`].
pub(crate) fn machine_static(s: &str) -> Option<&'static str> {
    match s {
        "mpu" => Some("mpu"),
        "gpu" => Some("gpu"),
        "ideal" => Some("ideal"),
        _ => None,
    }
}

impl StoredEntry {
    fn from_report(key: &str, scale: Scale, r: &RunReport) -> StoredEntry {
        StoredEntry {
            schema_version: STORE_SCHEMA_VERSION,
            key: key.to_string(),
            body: WireReport::from_report(scale, r),
        }
    }

    fn into_report(self, key: &str) -> Option<RunReport> {
        if self.schema_version != STORE_SCHEMA_VERSION || self.key != key {
            return None;
        }
        // Name validation (workload/scale/machine) lives in the shared
        // wire mirror.
        self.body.into_report()
    }
}

#[derive(Serialize, Deserialize, Default)]
struct IndexEntry {
    bytes: u64,
    /// Logical-clock timestamp of the last load *or* store (LRU order).
    last_access: u64,
}

#[derive(Serialize, Deserialize, Default)]
struct Index {
    schema_version: u32,
    /// Monotonic logical clock; persisted so recency survives restarts.
    clock: u64,
    entries: BTreeMap<String, IndexEntry>,
}

/// The persistent result store. All operations are infallible from the
/// caller's perspective (a broken disk degrades to misses); `open` is
/// the only fallible step.
pub struct DiskStore {
    root: PathBuf,
    max_bytes: u64,
    index: Mutex<Index>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    corrupt_dropped: AtomicU64,
    write_failures: AtomicU64,
    consec_failures: AtomicU64,
    degraded: AtomicBool,
    skipped_since_probe: AtomicU64,
    quarantined: AtomicU64,
}

impl DiskStore {
    /// Open (or create) a store rooted at `cfg.root`.
    pub fn open(cfg: StoreConfig) -> Result<DiskStore> {
        let entries_dir = cfg.root.join("entries");
        std::fs::create_dir_all(&entries_dir)
            .with_context(|| format!("creating store dir {}", entries_dir.display()))?;
        let store = DiskStore {
            root: cfg.root,
            max_bytes: cfg.max_bytes,
            index: Mutex::new(Index { schema_version: STORE_SCHEMA_VERSION, ..Index::default() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            corrupt_dropped: AtomicU64::new(0),
            write_failures: AtomicU64::new(0),
            consec_failures: AtomicU64::new(0),
            degraded: AtomicBool::new(false),
            skipped_since_probe: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
        };
        let loaded = std::fs::read_to_string(store.index_path())
            .ok()
            .and_then(|body| serde_json::from_str::<Index>(&body).ok())
            .filter(|ix| ix.schema_version == STORE_SCHEMA_VERSION);
        let index = match loaded {
            Some(ix) => ix,
            // Missing/corrupt/stale index: rebuild from the entry files
            // (recency resets; entry-level schema checks still apply on
            // load, so a stale-schema tree degrades to misses).
            None => store.rebuild_index()?,
        };
        *store.index.lock().unwrap() = index;
        Ok(store)
    }

    fn index_path(&self) -> PathBuf {
        self.root.join("index.json")
    }

    fn entry_path(&self, key: &str) -> PathBuf {
        self.root.join("entries").join(format!("{key}.json"))
    }

    fn rebuild_index(&self) -> Result<Index> {
        let mut ix = Index { schema_version: STORE_SCHEMA_VERSION, ..Index::default() };
        let dir = self.root.join("entries");
        let mut names: Vec<(String, u64)> = Vec::new();
        for ent in std::fs::read_dir(&dir)? {
            let ent = ent?;
            let name = ent.file_name().to_string_lossy().into_owned();
            if let Some(key) = name.strip_suffix(".json") {
                let bytes = ent.metadata().map(|m| m.len()).unwrap_or(0);
                names.push((key.to_string(), bytes));
            }
        }
        names.sort();
        for (key, bytes) in names {
            ix.clock += 1;
            ix.entries.insert(key, IndexEntry { bytes, last_access: ix.clock });
        }
        Ok(ix)
    }

    /// Persist the index (best effort — an unwritable index only costs
    /// recency on the next open).
    fn persist_index(&self, ix: &Index) {
        if let Ok(body) = serde_json::to_string(ix) {
            if atomic_write(&self.index_path(), body.as_bytes(), FaultClass::TornIndex)
                .is_err()
            {
                self.write_failures.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Move a corrupt entry file to `<root>/quarantine/` for
    /// post-mortem instead of destroying the evidence; falls back to
    /// removal when the rename itself fails.
    fn quarantine(&self, key: &str, path: &Path) {
        let qdir = self.root.join("quarantine");
        let moved = std::fs::create_dir_all(&qdir)
            .and_then(|_| std::fs::rename(path, qdir.join(format!("{key}.json"))))
            .is_ok();
        if moved {
            self.quarantined.fetch_add(1, Ordering::Relaxed);
        } else {
            let _ = std::fs::remove_file(path);
        }
    }

    /// Load a result by key. `None` is a miss (absent, corrupt, or stale
    /// schema; the latter two also remove the file).
    pub fn load(&self, key: &str) -> Option<RunReport> {
        let path = self.entry_path(key);
        let body = match std::fs::read_to_string(&path) {
            Ok(b) => b,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                // Drop a dangling index entry so sizes stay truthful
                // (persisted lazily: next store() or Drop).
                self.index.lock().unwrap().entries.remove(key);
                return None;
            }
        };
        let report = serde_json::from_str::<StoredEntry>(&body)
            .ok()
            .and_then(|e| e.into_report(key));
        match report {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                // Recency bump is in-memory only: rewriting index.json
                // on every hit would serialize O(entries) JSON per load
                // across all workers. store()/Drop persist it; losing a
                // crash-window of recency only perturbs LRU order.
                let mut ix = self.index.lock().unwrap();
                ix.clock += 1;
                let clock = ix.clock;
                let bytes = body.len() as u64;
                ix.entries.insert(key.to_string(), IndexEntry { bytes, last_access: clock });
                Some(r)
            }
            None => {
                // Corrupt or schema-stale: recover by quarantining it.
                self.misses.fetch_add(1, Ordering::Relaxed);
                self.corrupt_dropped.fetch_add(1, Ordering::Relaxed);
                self.quarantine(key, &path);
                self.index.lock().unwrap().entries.remove(key);
                None
            }
        }
    }

    /// Store a result under a key (best effort; failures degrade to a
    /// future miss, and *persistent* failures demote the whole store to
    /// memory-only mode — the memory tier above is unaffected, so the
    /// batch always completes). Evicts least-recently-accessed entries
    /// if the cap is exceeded.
    pub fn store(&self, key: &str, scale: Scale, report: &RunReport) {
        let entry = StoredEntry::from_report(key, scale, report);
        let Ok(body) = serde_json::to_string(&entry) else { return };
        if self.degraded.load(Ordering::Relaxed) {
            // Memory-only mode: skip the disk, but probe it
            // periodically so a healed disk re-engages.
            let n = self.skipped_since_probe.fetch_add(1, Ordering::Relaxed);
            if n % PROBE_EVERY != 0 {
                return;
            }
        }
        match atomic_write(&self.entry_path(key), body.as_bytes(), FaultClass::TornEntry) {
            Err(_) => {
                self.write_failures.fetch_add(1, Ordering::Relaxed);
                let consec = self.consec_failures.fetch_add(1, Ordering::Relaxed) + 1;
                if consec >= DEGRADE_AFTER {
                    self.degraded.store(true, Ordering::Relaxed);
                }
                return;
            }
            Ok(()) => {
                self.consec_failures.store(0, Ordering::Relaxed);
                if self.degraded.swap(false, Ordering::Relaxed) {
                    self.skipped_since_probe.store(0, Ordering::Relaxed);
                }
            }
        }
        let mut ix = self.index.lock().unwrap();
        ix.clock += 1;
        let clock = ix.clock;
        ix.entries
            .insert(key.to_string(), IndexEntry { bytes: body.len() as u64, last_access: clock });
        self.evict_over_cap(&mut ix);
        self.persist_index(&ix);
    }

    /// Evict LRU entries until under the byte cap. The most recently
    /// accessed entry always survives, even if it alone exceeds the cap.
    fn evict_over_cap(&self, ix: &mut Index) {
        self.evict_to_cap(ix, self.max_bytes);
    }

    /// [`DiskStore::evict_over_cap`] against an explicit cap; returns
    /// the number of evictions.
    fn evict_to_cap(&self, ix: &mut Index, cap: u64) -> usize {
        let mut evicted = 0;
        loop {
            let total: u64 = ix.entries.values().map(|e| e.bytes).sum();
            if total <= cap || ix.entries.len() <= 1 {
                return evicted;
            }
            let victim = ix
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_access)
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { return evicted };
            ix.entries.remove(&victim);
            let _ = std::fs::remove_file(self.entry_path(&victim));
            self.evictions.fetch_add(1, Ordering::Relaxed);
            evicted += 1;
        }
    }

    /// One full garbage-collection / compaction pass — the "beyond
    /// LRU" maintenance the resident daemon's write path never does:
    ///
    /// 1. scan `entries/` (the files are the truth, not the index);
    /// 2. eagerly drop corrupt, mis-keyed and schema-stale entries
    ///    (a plain `load` drops them lazily, one miss at a time);
    /// 3. drop entries older than [`GcOptions::max_age`] (file mtime);
    /// 4. LRU-evict down to the byte cap (recency carried over from
    ///    the index for known keys);
    /// 5. rewrite a compacted `index.json` (dangling rows gone, byte
    ///    counts recomputed).
    pub fn gc(&self, opts: &GcOptions) -> Result<GcReport> {
        let mut report = GcReport::default();
        let dir = self.root.join("entries");
        let now = SystemTime::now();
        let mut ix = self.index.lock().unwrap();
        let mut file_keys: std::collections::HashSet<String> = std::collections::HashSet::new();
        let mut survivors: Vec<(String, u64)> = Vec::new();
        for ent in std::fs::read_dir(&dir)? {
            let ent = ent?;
            let name = ent.file_name().to_string_lossy().into_owned();
            let Some(key) = name.strip_suffix(".json") else { continue };
            let path = ent.path();
            report.scanned += 1;
            file_keys.insert(key.to_string());
            let parsed = std::fs::read_to_string(&path)
                .ok()
                .map(|body| {
                    let bytes = body.len() as u64;
                    let intact = serde_json::from_str::<StoredEntry>(&body)
                        .map(|e| e.schema_version == STORE_SCHEMA_VERSION && e.key == key)
                        .unwrap_or(false);
                    (bytes, intact)
                });
            let Some((bytes, intact)) = parsed else {
                self.quarantine(key, &path);
                report.stale_dropped += 1;
                self.corrupt_dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            };
            if !intact {
                self.quarantine(key, &path);
                report.stale_dropped += 1;
                self.corrupt_dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            if let Some(max_age) = opts.max_age {
                let age = std::fs::metadata(&path)
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|mtime| now.duration_since(mtime).ok());
                // An unreadable mtime never expires an entry.
                if age.map(|a| a >= max_age).unwrap_or(false) {
                    let _ = std::fs::remove_file(&path);
                    report.expired += 1;
                    continue;
                }
            }
            survivors.push((key.to_string(), bytes));
        }
        report.dangling_dropped =
            ix.entries.keys().filter(|k| !file_keys.contains(*k)).count();
        // Rebuild the index from the survivors, carrying recency over
        // for keys the old index knew (unknown files get fresh clocks,
        // i.e. most-recent — they are someone's live writes).
        survivors.sort();
        let mut entries = BTreeMap::new();
        let mut clock = ix.clock;
        for (key, bytes) in survivors {
            let last_access = match ix.entries.get(&key) {
                Some(e) => e.last_access,
                None => {
                    clock += 1;
                    clock
                }
            };
            entries.insert(key, IndexEntry { bytes, last_access });
        }
        ix.clock = clock;
        ix.entries = entries;
        report.evicted = self.evict_to_cap(&mut ix, opts.max_bytes.unwrap_or(self.max_bytes));
        report.kept = ix.entries.len();
        report.kept_bytes = ix.entries.values().map(|e| e.bytes).sum();
        self.persist_index(&ix);
        Ok(report)
    }

    /// Number of entries currently indexed.
    pub fn len(&self) -> usize {
        self.index.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total indexed entry bytes.
    pub fn total_bytes(&self) -> u64 {
        self.index.lock().unwrap().entries.values().map(|e| e.bytes).sum()
    }

    /// Root directory of the store.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Counter snapshot.
    pub fn stats(&self) -> StoreStats {
        StoreStats {
            entries: self.len(),
            bytes: self.total_bytes(),
            max_bytes: self.max_bytes,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            corrupt_dropped: self.corrupt_dropped.load(Ordering::Relaxed),
            write_failures: self.write_failures.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
        }
    }
}

impl Drop for DiskStore {
    /// Persist the final recency state (loads only bump it in memory).
    fn drop(&mut self) {
        let ix = self.index.lock().unwrap();
        self.persist_index(&ix);
    }
}

/// Write via tmp file + rename so readers never observe a torn file.
///
/// This is the store's fault-injection seam: an active plan can fail
/// the write with ENOSPC, or tear it — half the body written straight
/// to the final path, the way a crash mid-write (or a rename across a
/// dying filesystem) leaves it. A torn write reports success; the
/// corruption is discovered on the next load, which is exactly the
/// recovery path the quarantine logic exists for.
fn atomic_write(path: &Path, body: &[u8], tear: FaultClass) -> std::io::Result<()> {
    let ctx = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    if fault::should_fail(FaultClass::Enospc, &ctx) {
        return Err(std::io::Error::other("injected ENOSPC (storage full)"));
    }
    if fault::should_fail(tear, &ctx) {
        std::fs::write(path, &body[..body.len() / 2])?;
        return Ok(());
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, body)?;
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::coordinator::run_workload_scaled;
    use crate::workloads::Workload;

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("mpu_store_unit")
            .join(format!("{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_report() -> RunReport {
        let cfg = MachineConfig::scaled();
        run_workload_scaled(Workload::Axpy, &cfg, Scale::Tiny).unwrap()
    }

    #[test]
    fn round_trip_preserves_the_report() {
        let store = DiskStore::open(StoreConfig::new(tmp_root("rt"))).unwrap();
        let r = sample_report();
        store.store("axpy-tiny-mpu-0000000000000000", Scale::Tiny, &r);
        let back = store.load("axpy-tiny-mpu-0000000000000000").unwrap();
        assert_eq!(back.workload, r.workload);
        assert_eq!(back.machine, r.machine);
        assert_eq!(back.cycles, r.cycles);
        assert_eq!(back.stats.cycles, r.stats.cycles);
        assert_eq!(back.correct, r.correct);
        let a: Vec<u32> = back.output.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = r.output.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "stored output must round-trip bit-exactly");
        assert!(back.sim_wall_ms >= 0.0);
        assert_eq!(back.sim_cycles_per_sec, r.sim_cycles_per_sec);
        assert_eq!(store.stats().hits, 1);
        assert_eq!(store.stats().entries, 1);
    }

    #[test]
    fn absent_key_is_a_miss() {
        let store = DiskStore::open(StoreConfig::new(tmp_root("miss"))).unwrap();
        assert!(store.load("nope-tiny-mpu-0000000000000000").is_none());
        assert_eq!(store.stats().misses, 1);
        assert_eq!(store.stats().hits, 0);
    }

    #[test]
    fn lru_eviction_by_last_access_under_byte_cap() {
        let r = sample_report();
        let root = tmp_root("lru");
        // Measure one entry, then cap the store at ~2.5 entries.
        let probe = DiskStore::open(StoreConfig::new(root.clone())).unwrap();
        probe.store("k0", Scale::Tiny, &r);
        let one = probe.total_bytes();
        assert!(one > 0);
        drop(probe);
        let _ = std::fs::remove_dir_all(&root);

        let store =
            DiskStore::open(StoreConfig::new(root).max_bytes(one * 5 / 2)).unwrap();
        store.store("k0", Scale::Tiny, &r);
        store.store("k1", Scale::Tiny, &r);
        // Touch k0 so k1 becomes the LRU victim.
        assert!(store.load("k0").is_some());
        store.store("k2", Scale::Tiny, &r);
        assert_eq!(store.stats().evictions, 1);
        assert!(store.load("k1").is_none(), "LRU entry k1 should be evicted");
        assert!(store.load("k0").is_some());
        assert!(store.load("k2").is_some());
    }

    #[test]
    fn gc_drops_stale_schema_eagerly_and_compacts_the_index() {
        let root = tmp_root("gc_stale");
        let r = sample_report();
        let store = DiskStore::open(StoreConfig::new(root.clone())).unwrap();
        store.store("ka", Scale::Tiny, &r);
        store.store("kb", Scale::Tiny, &r);
        store.store("kc", Scale::Tiny, &r);
        // kb goes schema-stale; kc's file vanishes behind the index's
        // back (a crashed writer / manual deletion).
        let kb = root.join("entries").join("kb.json");
        let mut v: serde_json::Value =
            serde_json::from_str(&std::fs::read_to_string(&kb).unwrap()).unwrap();
        v["schema_version"] = serde_json::json!(STORE_SCHEMA_VERSION + 1);
        std::fs::write(&kb, serde_json::to_string(&v).unwrap()).unwrap();
        std::fs::remove_file(root.join("entries").join("kc.json")).unwrap();

        let report = store.gc(&GcOptions::default()).unwrap();
        assert_eq!(report.scanned, 2, "kc's file is gone before the scan");
        assert_eq!(report.stale_dropped, 1, "kb dropped eagerly");
        assert_eq!(report.dangling_dropped, 1, "kc compacted out of the index");
        assert_eq!(report.expired, 0);
        assert_eq!(report.kept, 1);
        assert!(!kb.exists());
        assert_eq!(store.len(), 1);
        assert!(store.load("ka").is_some());
        // The compacted index survives a fresh open.
        drop(store);
        let again = DiskStore::open(StoreConfig::new(root)).unwrap();
        assert_eq!(again.len(), 1);
    }

    #[test]
    fn gc_age_expiry_and_byte_cap() {
        let root = tmp_root("gc_age");
        let r = sample_report();
        let store = DiskStore::open(StoreConfig::new(root.clone())).unwrap();
        store.store("ka", Scale::Tiny, &r);
        store.store("kb", Scale::Tiny, &r);
        // A generous max_age keeps everything (the files are seconds
        // old at most).
        let report = store
            .gc(&GcOptions { max_age: Some(Duration::from_secs(3600)), max_bytes: None })
            .unwrap();
        assert_eq!(report.expired, 0);
        assert_eq!(report.kept, 2);
        // max_age zero expires every entry regardless of the cap.
        let report =
            store.gc(&GcOptions { max_age: Some(Duration::ZERO), max_bytes: None }).unwrap();
        assert_eq!(report.expired, 2);
        assert_eq!(report.kept, 0);
        assert_eq!(store.len(), 0);
        // Byte-cap override: three entries, cap sized for ~one. The
        // most recently accessed entry always survives.
        store.store("k0", Scale::Tiny, &r);
        store.store("k1", Scale::Tiny, &r);
        store.store("k2", Scale::Tiny, &r);
        let one = store.total_bytes() / 3;
        let report = store
            .gc(&GcOptions { max_age: None, max_bytes: Some(one * 3 / 2) })
            .unwrap();
        assert_eq!(report.evicted, 2, "LRU pair evicted under the pass cap");
        assert_eq!(report.kept, 1);
        assert!(store.load("k2").is_some(), "most recent entry survives");
    }

    #[test]
    fn index_rebuilds_after_deletion() {
        let root = tmp_root("reix");
        let r = sample_report();
        {
            let store = DiskStore::open(StoreConfig::new(root.clone())).unwrap();
            store.store("ka", Scale::Tiny, &r);
            store.store("kb", Scale::Tiny, &r);
        }
        std::fs::remove_file(root.join("index.json")).unwrap();
        let store = DiskStore::open(StoreConfig::new(root)).unwrap();
        assert_eq!(store.len(), 2, "index should rebuild from entries/");
        assert!(store.load("ka").is_some());
    }
}
