//! Deterministic fault-injection plane for the sweep service.
//!
//! A `FaultPlan` is parsed from a compact spec string (`--faults` /
//! `MPU_FAULTS`) and activated process-wide. Every injection point in the
//! transport, store, and federation layers consults [`should_fail`] with a
//! stable context string; decisions are drawn from a seeded [`Prng`] stream
//! per `(class, ctx)` pair, so a decision at call `k` is a pure function of
//! `(seed, class, ctx, k)` — independent of thread interleaving. The same
//! seed replays the same fault schedule exactly.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::sim::prng::Prng;

use super::sweep::stable_hash;

/// The injectable failure classes, one per infrastructure seam.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultClass {
    /// TCP connect refused before the handshake.
    Connect,
    /// Mid-stream connection reset on a socket read or write.
    Disconnect,
    /// Stalled socket I/O: the read/write times out as if the peer hung.
    Stall,
    /// Entry file write torn in half (crash mid-write).
    TornEntry,
    /// `index.json` write torn in half (crash mid-write).
    TornIndex,
    /// Store write fails with "no space left on device".
    Enospc,
}

impl FaultClass {
    pub const ALL: [FaultClass; 6] = [
        FaultClass::Connect,
        FaultClass::Disconnect,
        FaultClass::Stall,
        FaultClass::TornEntry,
        FaultClass::TornIndex,
        FaultClass::Enospc,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultClass::Connect => "connect",
            FaultClass::Disconnect => "disconnect",
            FaultClass::Stall => "stall",
            FaultClass::TornEntry => "torn_entry",
            FaultClass::TornIndex => "torn_index",
            FaultClass::Enospc => "enospc",
        }
    }

    pub fn from_name(name: &str) -> Option<FaultClass> {
        FaultClass::ALL.into_iter().find(|c| c.name() == name)
    }

    fn tag(self) -> u64 {
        stable_hash(self.name())
    }
}

/// Per-class injection rule: probability per call, optional cap on how many
/// times the fault fires per `(class, ctx)` stream.
#[derive(Debug, Clone, Copy)]
pub struct FaultRule {
    pub rate: f64,
    pub budget: Option<u64>,
}

/// A parsed fault specification: seed plus per-class rules.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    rules: Vec<(FaultClass, FaultRule)>,
}

impl FaultPlan {
    /// Parse a spec like `seed=42,connect=1.0:2,disconnect=0.3`.
    ///
    /// Grammar: comma-separated terms, each either `seed=<u64>` or
    /// `<class>=<rate>[:<budget>]` with rate in `[0, 1]`. The default seed
    /// is 1 so a bare class list is still deterministic.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut seed = 1u64;
        let mut rules: Vec<(FaultClass, FaultRule)> = Vec::new();
        for term in spec.split(',') {
            let term = term.trim();
            if term.is_empty() {
                continue;
            }
            let (key, val) = term
                .split_once('=')
                .with_context(|| format!("fault term `{term}` is not key=value"))?;
            let key = key.trim();
            let val = val.trim();
            if key == "seed" {
                seed = val
                    .parse()
                    .with_context(|| format!("bad fault seed `{val}`"))?;
                continue;
            }
            let Some(class) = FaultClass::from_name(key) else {
                bail!(
                    "unknown fault class `{key}` (expected one of {})",
                    FaultClass::ALL
                        .iter()
                        .map(|c| c.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            };
            let (rate_s, budget) = match val.split_once(':') {
                Some((r, b)) => {
                    let b: u64 = b
                        .parse()
                        .with_context(|| format!("bad fault budget `{b}` for `{key}`"))?;
                    (r, Some(b))
                }
                None => (val, None),
            };
            let rate: f64 = rate_s
                .parse()
                .with_context(|| format!("bad fault rate `{rate_s}` for `{key}`"))?;
            if !(0.0..=1.0).contains(&rate) {
                bail!("fault rate for `{key}` must be in [0, 1], got {rate}");
            }
            if let Some(slot) = rules.iter_mut().find(|(c, _)| *c == class) {
                slot.1 = FaultRule { rate, budget };
            } else {
                rules.push((class, FaultRule { rate, budget }));
            }
        }
        Ok(FaultPlan { seed, rules })
    }

    pub fn rule(&self, class: FaultClass) -> Option<FaultRule> {
        self.rules
            .iter()
            .find(|(c, _)| *c == class)
            .map(|(_, r)| *r)
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

/// One injection decision, recorded for replay verification.
#[derive(Debug, Clone)]
pub struct FaultEvent {
    pub class: FaultClass,
    pub ctx: String,
    pub call: u64,
    pub fired: bool,
}

struct StreamState {
    prng: Prng,
    calls: u64,
    fired: u64,
}

/// Draws fault decisions from seeded per-`(class, ctx)` streams and keeps an
/// event log so a chaos run can be replay-checked against the same plan.
pub struct FaultInjector {
    plan: FaultPlan,
    streams: Mutex<HashMap<(FaultClass, u64), StreamState>>,
    log: Mutex<Vec<FaultEvent>>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector {
            plan,
            streams: Mutex::new(HashMap::new()),
            log: Mutex::new(Vec::new()),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Decide whether the fault of `class` fires for this call at `ctx`.
    ///
    /// The decision stream for a `(class, ctx)` pair is seeded
    /// `plan.seed ^ class.tag() ^ stable_hash(ctx)`; budgets are tracked per
    /// stream so every decision stays a pure function of the call index.
    pub fn check(&self, class: FaultClass, ctx: &str) -> bool {
        let Some(rule) = self.plan.rule(class) else {
            return false;
        };
        let key = (class, stable_hash(ctx));
        let mut streams = self.streams.lock().unwrap_or_else(|e| e.into_inner());
        let st = streams.entry(key).or_insert_with(|| StreamState {
            prng: Prng::new(self.plan.seed ^ class.tag() ^ stable_hash(ctx)),
            calls: 0,
            fired: 0,
        });
        st.calls += 1;
        // Always draw so the stream position depends only on the call count.
        let drew = st.prng.chance(rule.rate);
        let fire = drew && st.fired < rule.budget.unwrap_or(u64::MAX);
        if fire {
            st.fired += 1;
        }
        let ev = FaultEvent {
            class,
            ctx: ctx.to_string(),
            call: st.calls,
            fired: fire,
        };
        drop(streams);
        self.log.lock().unwrap_or_else(|e| e.into_inner()).push(ev);
        fire
    }

    /// Snapshot of every decision drawn so far, in draw order.
    pub fn log(&self) -> Vec<FaultEvent> {
        self.log
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// How many faults of `class` actually fired.
    pub fn injected(&self, class: FaultClass) -> u64 {
        self.log
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .filter(|e| e.class == class && e.fired)
            .count() as u64
    }

    pub fn total_injected(&self) -> u64 {
        self.log
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .filter(|e| e.fired)
            .count() as u64
    }
}

// --- process-wide fault plane -----------------------------------------------

static ACTIVE: AtomicBool = AtomicBool::new(false);

fn slot() -> &'static Mutex<Option<Arc<FaultInjector>>> {
    static SLOT: OnceLock<Mutex<Option<Arc<FaultInjector>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Install `plan` as the process-wide fault plane and return its injector.
pub fn activate(plan: FaultPlan) -> Arc<FaultInjector> {
    let inj = Arc::new(FaultInjector::new(plan));
    *slot().lock().unwrap_or_else(|e| e.into_inner()) = Some(Arc::clone(&inj));
    ACTIVE.store(true, Ordering::SeqCst);
    inj
}

/// Remove the process-wide fault plane (all injection points become no-ops).
pub fn deactivate() {
    ACTIVE.store(false, Ordering::SeqCst);
    *slot().lock().unwrap_or_else(|e| e.into_inner()) = None;
}

/// The currently active injector, if any.
pub fn active() -> Option<Arc<FaultInjector>> {
    if !ACTIVE.load(Ordering::SeqCst) {
        return None;
    }
    slot().lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Fast-path query used by the injection points. False when no plan is active.
pub fn should_fail(class: FaultClass, ctx: &str) -> bool {
    match active() {
        Some(inj) => inj.check(class, ctx),
        None => false,
    }
}

// --- hardening knobs ---------------------------------------------------------

/// Socket deadlines applied to client and federation connections.
#[derive(Debug, Clone, Copy)]
pub struct Timeouts {
    pub connect: Duration,
    pub io: Duration,
}

impl Default for Timeouts {
    fn default() -> Timeouts {
        Timeouts {
            connect: Duration::from_millis(5_000),
            io: Duration::from_millis(300_000),
        }
    }
}

/// Bounded exponential backoff with seeded jitter — like the fault plane,
/// retry pacing has no ambient randomness.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub attempts: u32,
    pub base_delay: Duration,
    pub max_delay: Duration,
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            attempts: 4,
            base_delay: Duration::from_millis(50),
            max_delay: Duration::from_millis(2_000),
            seed: 0x6d70_755f_7265_7472, // "mpu_retr"
        }
    }
}

impl RetryPolicy {
    /// Delay before retry number `attempt` (0-based) of the operation at
    /// `ctx`: exponential growth capped at `max_delay`, scaled by a
    /// deterministic jitter fraction in `[0.5, 1.0]`.
    pub fn delay(&self, ctx: &str, attempt: u32) -> Duration {
        let exp = self
            .base_delay
            .saturating_mul(1u32.checked_shl(attempt.min(16)).unwrap_or(u32::MAX));
        let capped = exp.min(self.max_delay);
        let mut prng = Prng::new(
            self.seed ^ stable_hash(ctx) ^ (attempt as u64).wrapping_mul(0x9E37_79B9),
        );
        let frac = 0.5 + 0.5 * prng.f32() as f64;
        Duration::from_secs_f64(capped.as_secs_f64() * frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let plan = FaultPlan::parse("seed=42, connect=1.0:2, disconnect=0.3").unwrap();
        assert_eq!(plan.seed, 42);
        let c = plan.rule(FaultClass::Connect).unwrap();
        assert_eq!(c.rate, 1.0);
        assert_eq!(c.budget, Some(2));
        let d = plan.rule(FaultClass::Disconnect).unwrap();
        assert_eq!(d.rate, 0.3);
        assert_eq!(d.budget, None);
        assert!(plan.rule(FaultClass::Enospc).is_none());
    }

    #[test]
    fn default_seed_and_empty_terms() {
        let plan = FaultPlan::parse("stall=0.5,,").unwrap();
        assert_eq!(plan.seed, 1);
        assert!(!plan.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(FaultPlan::parse("connect").is_err());
        assert!(FaultPlan::parse("warp_divergence=0.5").is_err());
        assert!(FaultPlan::parse("connect=1.5").is_err());
        assert!(FaultPlan::parse("connect=-0.1").is_err());
        assert!(FaultPlan::parse("connect=0.5:x").is_err());
        assert!(FaultPlan::parse("seed=abc").is_err());
    }

    #[test]
    fn same_plan_replays_identically() {
        let plan = FaultPlan::parse("seed=7,disconnect=0.4,stall=0.9:3").unwrap();
        let a = FaultInjector::new(plan.clone());
        for i in 0..200 {
            let ctx = format!("peer{}", i % 3);
            a.check(FaultClass::Disconnect, &ctx);
            a.check(FaultClass::Stall, &ctx);
        }
        let b = FaultInjector::new(plan);
        for ev in a.log() {
            assert_eq!(b.check(ev.class, &ev.ctx), ev.fired, "event {ev:?}");
        }
    }

    #[test]
    fn budget_caps_per_context_stream() {
        let plan = FaultPlan::parse("seed=3,connect=1.0:2").unwrap();
        let inj = FaultInjector::new(plan);
        for _ in 0..10 {
            inj.check(FaultClass::Connect, "a");
            inj.check(FaultClass::Connect, "b");
        }
        // rate 1.0 fires on every draw until the per-(class,ctx) budget runs out.
        assert_eq!(inj.injected(FaultClass::Connect), 4);
        let fired_a: Vec<bool> = inj
            .log()
            .iter()
            .filter(|e| e.ctx == "a")
            .map(|e| e.fired)
            .collect();
        assert_eq!(&fired_a[..3], &[true, true, false]);
    }

    #[test]
    fn contexts_are_independent_streams() {
        let plan = FaultPlan::parse("seed=11,stall=0.5").unwrap();
        let inj = FaultInjector::new(plan.clone());
        let a: Vec<bool> = (0..64).map(|_| inj.check(FaultClass::Stall, "a")).collect();
        // Interleaving another context does not perturb a's stream.
        let inj2 = FaultInjector::new(plan);
        let mut a2 = Vec::new();
        for _ in 0..64 {
            inj2.check(FaultClass::Stall, "noise");
            a2.push(inj2.check(FaultClass::Stall, "a"));
        }
        assert_eq!(a, a2);
    }

    #[test]
    fn deactivate_clears_the_plane() {
        let inj = activate(FaultPlan::parse("seed=1,connect=1.0").unwrap());
        assert!(should_fail(FaultClass::Connect, "x"));
        assert_eq!(inj.injected(FaultClass::Connect), 1);
        deactivate();
        assert!(!should_fail(FaultClass::Connect, "x"));
        assert!(active().is_none());
    }

    #[test]
    fn retry_delay_is_deterministic_and_bounded() {
        let p = RetryPolicy::default();
        assert_eq!(p.delay("w1", 0), p.delay("w1", 0));
        assert_ne!(p.delay("w1", 0), p.delay("w2", 0));
        for attempt in 0..40 {
            let d = p.delay("w1", attempt);
            assert!(d <= p.max_delay);
            assert!(d >= p.base_delay / 2 || attempt == 0);
        }
        // Growth: attempt 3 should be well above attempt 0's ceiling.
        assert!(p.delay("w1", 3) > p.base_delay);
    }
}
