//! Stable-schema JSON perf output (`BENCH_suite.json`).
//!
//! `cargo run --release -- suite` writes one [`SuiteJson`] document
//! covering all twelve Table-I workloads on both machines, so every PR
//! has a perf trajectory to beat. Schema (version 1):
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "suite": "table1",
//!   "scale": "small",
//!   "geomean_speedup": 3.1,
//!   "geomean_energy_reduction": 2.4,
//!   "workloads": [
//!     { "workload": "axpy", "speedup": 3.4, "energy_reduction": 2.6,
//!       "mpu": { "machine": "mpu", "cycles": 123, "dram_gbps": 810.0, ... },
//!       "gpu": { ... } }
//!   ]
//! }
//! ```
//!
//! Fields are append-only: tooling that consumes version 1 keys must
//! keep working across future PRs.

use super::sweep::SweepResult;
use super::{geomean, PairReport, RunReport};
use crate::analysis::WorkloadLintSummary;
use crate::energy::EnergyBreakdown;
use crate::sim::Stats;
use crate::workloads::{Scale, Workload};
use anyhow::Result;
use serde::Serialize;
use std::path::Path;

/// Canonical file name the suite baseline is written to.
pub const SUITE_JSON: &str = "BENCH_suite.json";

/// Canonical file name of the simulator-throughput report
/// (`mpu suite --perf`).
pub const SIMPERF_JSON: &str = "BENCH_simperf.json";

/// Stable lower-case name of a problem scale.
pub fn scale_name(scale: Scale) -> &'static str {
    scale.name()
}

/// Cache/store counters of the run that produced a suite document
/// (append-only schema-v1 addition under the `stats` key; absent in
/// documents from older producers and from library callers).
#[derive(Clone, Debug, Serialize)]
pub struct SuiteStats {
    /// Points resident in the in-process `SimCache`.
    pub sim_cache_entries: usize,
    /// Memory-tier hits served during this process.
    pub sim_cache_hits: u64,
    /// On-disk-store hits served during this process.
    pub sim_cache_disk_hits: u64,
    /// Persistent store counters (absent when no store is attached).
    pub store: Option<crate::coordinator::store::StoreStats>,
    /// Total wall-clock ms spent simulating the runs in this document
    /// (append-only v1 addition; cache hits count the original
    /// simulation's cost).
    pub sim_wall_ms: f64,
    /// Total simulated cycles across the document's runs.
    pub sim_cycles_total: u64,
    /// Aggregate simulator throughput: `sim_cycles_total` per
    /// wall-clock second.
    pub sim_cycles_per_sec: f64,
}

impl SuiteStats {
    /// Snapshot a [`SimCache`]'s two tiers.
    pub fn from_cache(cache: &crate::coordinator::SimCache) -> SuiteStats {
        SuiteStats {
            sim_cache_entries: cache.len(),
            sim_cache_hits: cache.hits(),
            sim_cache_disk_hits: cache.disk_hits(),
            store: cache.store().map(|s| s.stats()),
            sim_wall_ms: 0.0,
            sim_cycles_total: 0,
            sim_cycles_per_sec: 0.0,
        }
    }

    /// Fold one run's simulator-throughput numbers into the appendix.
    pub fn record_run(&mut self, r: &RunReport) {
        self.sim_wall_ms += r.sim_wall_ms;
        self.sim_cycles_total += r.cycles;
        self.sim_cycles_per_sec = super::sim_rate(self.sim_cycles_total, self.sim_wall_ms);
    }
}

/// Per-machine metrics of one workload run.
#[derive(Clone, Debug, Serialize)]
pub struct MachineEntry {
    pub machine: String,
    pub cycles: u64,
    pub dram_gbps: f64,
    pub energy_j: f64,
    pub correct: bool,
    pub max_err: f32,
    pub near_fraction: f64,
    pub row_miss_rate: f64,
    /// Simulator wall-time of the producing run (append-only v1
    /// addition; zero in documents from older producers).
    pub sim_wall_ms: f64,
    /// Simulated cycles per wall-second of the producing run.
    pub sim_cycles_per_sec: f64,
    pub energy: EnergyBreakdown,
    pub stats: Stats,
}

impl MachineEntry {
    pub fn from_report(r: &RunReport) -> MachineEntry {
        MachineEntry {
            machine: r.machine.to_string(),
            cycles: r.cycles,
            dram_gbps: r.dram_gbps(),
            energy_j: r.energy.total(),
            correct: r.correct,
            max_err: r.max_err,
            near_fraction: r.stats.near_fraction(),
            row_miss_rate: r.stats.row_miss_rate(),
            sim_wall_ms: r.sim_wall_ms,
            sim_cycles_per_sec: r.sim_cycles_per_sec,
            energy: r.energy,
            stats: r.stats.clone(),
        }
    }
}

/// One workload's MPU/GPU pair.
#[derive(Clone, Debug, Serialize)]
pub struct WorkloadEntry {
    pub workload: String,
    pub speedup: f64,
    pub energy_reduction: f64,
    pub mpu: MachineEntry,
    pub gpu: MachineEntry,
}

/// One workload on an extra machine variant, with its speedup relative
/// to the GPU baseline column of the same document.
#[derive(Clone, Debug, Serialize)]
pub struct VariantWorkload {
    pub workload: String,
    pub speedup_vs_gpu: f64,
    pub entry: MachineEntry,
}

/// One extra machine variant's whole-suite results (schema v1 appendix:
/// the `variants` key was absent in earlier documents, which consumers
/// must treat as an empty list).
#[derive(Clone, Debug, Serialize)]
pub struct VariantEntry {
    /// Stable variant name (e.g. `"ideal"`, `"mpu_nooff"`).
    pub variant: String,
    pub geomean_speedup_vs_gpu: f64,
    pub workloads: Vec<VariantWorkload>,
}

/// The whole suite document.
#[derive(Clone, Debug, Serialize)]
pub struct SuiteJson {
    pub schema_version: u32,
    pub suite: String,
    pub scale: String,
    pub geomean_speedup: f64,
    pub geomean_energy_reduction: f64,
    pub workloads: Vec<WorkloadEntry>,
    /// Extra machine variants (append-only addition; empty when the
    /// suite ran without `--variants`).
    pub variants: Vec<VariantEntry>,
    /// Cache/store counters of the producing run (append-only addition;
    /// omitted entirely when not captured, so older documents stay
    /// byte-identical).
    #[serde(skip_serializing_if = "Option::is_none")]
    pub stats: Option<SuiteStats>,
    /// Static-lint appendix (append-only addition): per-workload
    /// diagnostic counts and the dominant predicted global-access class
    /// from `mpu lint`. Empty when a workload failed to lint.
    #[serde(skip_serializing_if = "Vec::is_empty")]
    pub lint: Vec<WorkloadLintSummary>,
    /// Offload-autotuner appendix (append-only addition): best
    /// explicit-policy speedups vs the compiler heuristic, written by
    /// `mpu tune --append-suite` after the suite document exists.
    #[serde(skip_serializing_if = "Option::is_none")]
    pub tuning: Option<crate::tuner::TuningAppendix>,
}

/// Build the suite document from MPU/GPU pairs.
pub fn suite_json(scale: Scale, pairs: &[PairReport]) -> SuiteJson {
    suite_json_with_variants(scale, pairs, &[])
}

/// Build the suite document from MPU/GPU pairs plus any extra machine
/// variants. Each variant's runs must be in the same workload order as
/// `pairs` (the `Workload::ALL` convention of the sweep helpers).
pub fn suite_json_with_variants(
    scale: Scale,
    pairs: &[PairReport],
    variants: &[(String, Vec<RunReport>)],
) -> SuiteJson {
    let speedups: Vec<f64> = pairs.iter().map(|p| p.speedup()).collect();
    let reductions: Vec<f64> = pairs.iter().map(|p| p.energy_reduction()).collect();
    let variants = variants
        .iter()
        .map(|(name, runs)| {
            assert_eq!(
                runs.len(),
                pairs.len(),
                "variant `{name}` must cover the same workloads as the MPU/GPU pairs"
            );
            let workloads: Vec<VariantWorkload> = runs
                .iter()
                .zip(pairs)
                .map(|(r, p)| {
                    assert_eq!(r.workload, p.mpu.workload, "variant `{name}` workload order drift");
                    // Label the entry with the variant name so consumers
                    // grouping by `machine` never conflate (e.g.) the
                    // no-offload column with the main MPU column.
                    let mut entry = MachineEntry::from_report(r);
                    entry.machine = name.clone();
                    VariantWorkload {
                        workload: r.workload.name().to_string(),
                        speedup_vs_gpu: p.gpu.cycles as f64 / r.cycles.max(1) as f64,
                        entry,
                    }
                })
                .collect();
            let sp: Vec<f64> = workloads.iter().map(|w| w.speedup_vs_gpu).collect();
            VariantEntry {
                variant: name.clone(),
                geomean_speedup_vs_gpu: geomean(&sp),
                workloads,
            }
        })
        .collect();
    SuiteJson {
        schema_version: 1,
        suite: "table1".to_string(),
        scale: scale_name(scale).to_string(),
        geomean_speedup: geomean(&speedups),
        geomean_energy_reduction: geomean(&reductions),
        workloads: pairs
            .iter()
            .map(|p| WorkloadEntry {
                workload: p.mpu.workload.name().to_string(),
                speedup: p.speedup(),
                energy_reduction: p.energy_reduction(),
                mpu: MachineEntry::from_report(&p.mpu),
                gpu: MachineEntry::from_report(&p.gpu),
            })
            .collect(),
        variants,
        stats: None,
        tuning: None,
        lint: {
            let wls: Vec<Workload> = pairs.iter().map(|p| p.mpu.workload).collect();
            let warp = crate::config::MachineConfig::scaled().warp_size;
            crate::analysis::suite_lint_summaries(&wls, scale, warp)
        },
    }
}

/// Every correctness flag in the document (MPU, GPU and variant
/// columns) — the CI regression gate's view.
pub fn all_correct(doc: &SuiteJson) -> bool {
    doc.workloads.iter().all(|w| w.mpu.correct && w.gpu.correct)
        && doc.variants.iter().all(|v| v.workloads.iter().all(|w| w.entry.correct))
}

/// Serialize and write a suite document (pretty-printed, trailing newline).
pub fn write_suite_json(path: &Path, doc: &SuiteJson) -> Result<()> {
    let mut body = serde_json::to_string_pretty(doc)?;
    body.push('\n');
    std::fs::write(path, body)?;
    Ok(())
}

// ---------------- simulator-throughput report (`--perf`) ----------------

/// How the `BENCH_simperf.json` timings were taken — recorded in the
/// file so numbers are only ever compared like-for-like across PRs.
#[derive(Clone, Debug, Serialize)]
pub struct SimperfMethodology {
    /// What the per-point timer brackets.
    pub timer: String,
    /// Points ran one at a time (no rayon contention in the numbers).
    pub serial: bool,
    /// Caches/stores bypassed: every point was actually simulated.
    pub fresh: bool,
    pub os: String,
    pub arch: String,
    /// Parallelism available on the producing host (context for the
    /// serial numbers).
    pub host_threads: usize,
    /// Timed passes per point; each `wall_ms` is the median of this
    /// many runs after one untimed warmup pass (append-only v1
    /// addition; 1 in documents from older producers).
    pub repeat: usize,
}

/// One (machine variant × workload) throughput sample.
#[derive(Clone, Debug, Serialize)]
pub struct SimperfPoint {
    pub variant: String,
    pub workload: String,
    pub cycles: u64,
    pub wall_ms: f64,
    pub cycles_per_sec: f64,
}

/// The `BENCH_simperf.json` document (`mpu suite --perf`): wall-ms and
/// simulated-cycles-per-second for every (variant × workload) point, so
/// every PR has a measurable simulator-speed number to move. Schema
/// version 1; fields are append-only like the suite document's.
#[derive(Clone, Debug, Serialize)]
pub struct SimperfJson {
    pub schema_version: u32,
    pub suite: String,
    pub scale: String,
    pub methodology: SimperfMethodology,
    pub total_wall_ms: f64,
    pub geomean_cycles_per_sec: f64,
    pub points: Vec<SimperfPoint>,
}

/// Build the throughput document from sweep results (one per
/// variant × workload, labels are the variant names).
pub fn simperf_json(scale: Scale, results: &[SweepResult], serial: bool, fresh: bool) -> SimperfJson {
    simperf_json_repeated(scale, results, serial, fresh, 1)
}

/// [`simperf_json`] with the timed-pass count recorded in the
/// methodology (`mpu suite --perf --repeat N`): the caller has already
/// folded the median wall-ms of `repeat` passes into each result.
pub fn simperf_json_repeated(
    scale: Scale,
    results: &[SweepResult],
    serial: bool,
    fresh: bool,
    repeat: usize,
) -> SimperfJson {
    let points: Vec<SimperfPoint> = results
        .iter()
        .map(|r| SimperfPoint {
            variant: r.label.clone(),
            workload: r.report.workload.name().to_string(),
            cycles: r.report.cycles,
            wall_ms: r.report.sim_wall_ms,
            cycles_per_sec: r.report.sim_cycles_per_sec,
        })
        .collect();
    let total_wall_ms = points.iter().map(|p| p.wall_ms).sum();
    let cps: Vec<f64> = points.iter().map(|p| p.cycles_per_sec).collect();
    SimperfJson {
        schema_version: 1,
        suite: "simperf".to_string(),
        scale: scale_name(scale).to_string(),
        methodology: SimperfMethodology {
            timer: "std::time::Instant around SimtFrontend::run only (prepare/compile/check excluded)"
                .to_string(),
            serial,
            fresh,
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
            host_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            repeat: repeat.max(1),
        },
        total_wall_ms,
        geomean_cycles_per_sec: geomean(&cps),
        points,
    }
}

/// Serialize and write a throughput document.
pub fn write_simperf_json(path: &Path, doc: &SimperfJson) -> Result<()> {
    let mut body = serde_json::to_string_pretty(doc)?;
    body.push('\n');
    std::fs::write(path, body)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::coordinator::run_pair;
    use crate::workloads::Workload;

    #[test]
    fn suite_json_schema_is_stable() {
        let cfg = MachineConfig::scaled();
        let pair = run_pair(Workload::Axpy, &cfg, Scale::Tiny).unwrap();
        let doc = suite_json(Scale::Tiny, &[pair]);
        assert_eq!(doc.schema_version, 1);
        assert_eq!(doc.scale, "tiny");
        assert_eq!(doc.workloads.len(), 1);
        assert!(doc.geomean_speedup > 0.0);
        let s = serde_json::to_string(&doc).unwrap();
        for key in [
            "schema_version",
            "suite",
            "scale",
            "geomean_speedup",
            "geomean_energy_reduction",
            "workloads",
            "workload",
            "speedup",
            "energy_reduction",
            "machine",
            "cycles",
            "dram_gbps",
            "energy_j",
            "correct",
            "near_fraction",
            "row_miss_rate",
        ] {
            assert!(s.contains(&format!("\"{key}\"")), "missing key {key}");
        }
        // Static-lint appendix: one entry per workload in the document,
        // with counts and the dominant predicted coalescing class.
        assert_eq!(doc.lint.len(), 1);
        assert_eq!(doc.lint[0].workload, "axpy");
        assert_eq!(doc.lint[0].errors, 0);
        assert_eq!(doc.lint[0].warnings, 0);
        assert_eq!(doc.lint[0].coalescing, "coalesced");
        for key in ["lint", "coalescing", "global_classes"] {
            assert!(s.contains(&format!("\"{key}\"")), "missing key {key}");
        }
    }

    #[test]
    fn variants_appendix_serializes_and_keeps_schema_v1() {
        let cfg = MachineConfig::scaled();
        let pair = run_pair(Workload::Axpy, &cfg, Scale::Tiny).unwrap();
        let ideal = crate::coordinator::sweep::run_suite_kind(
            &cfg,
            Scale::Tiny,
            crate::config::MachineKind::IdealBw,
        )
        .unwrap();
        // One-workload document: slice the matching ideal run.
        let axpy_ideal = vec![ideal[Workload::ALL.iter().position(|w| *w == Workload::Axpy).unwrap()].clone()];
        let doc = suite_json_with_variants(
            Scale::Tiny,
            &[pair],
            &[("ideal".to_string(), axpy_ideal)],
        );
        assert_eq!(doc.schema_version, 1);
        assert_eq!(doc.variants.len(), 1);
        assert_eq!(doc.variants[0].variant, "ideal");
        assert_eq!(doc.variants[0].workloads.len(), 1);
        assert!(doc.variants[0].workloads[0].speedup_vs_gpu > 0.0);
        assert!(all_correct(&doc));
        let s = serde_json::to_string(&doc).unwrap();
        for key in ["variants", "variant", "speedup_vs_gpu", "geomean_speedup_vs_gpu"] {
            assert!(s.contains(&format!("\"{key}\"")), "missing key {key}");
        }
    }

    #[test]
    fn simperf_json_schema_is_stable() {
        let cfg = MachineConfig::scaled();
        let results = crate::coordinator::Sweep::new()
            .point(
                "mpu",
                Workload::Axpy,
                Scale::Tiny,
                crate::coordinator::Target::Mpu(cfg.clone()),
            )
            .fresh()
            .run()
            .unwrap();
        let doc = simperf_json(Scale::Tiny, &results, true, true);
        assert_eq!(doc.schema_version, 1);
        assert_eq!(doc.suite, "simperf");
        assert_eq!(doc.scale, "tiny");
        assert_eq!(doc.points.len(), 1);
        assert_eq!(doc.points[0].variant, "mpu");
        assert_eq!(doc.points[0].workload, "axpy");
        assert!(doc.points[0].wall_ms >= 0.0);
        assert!(doc.total_wall_ms >= doc.points[0].wall_ms);
        assert_eq!(doc.methodology.repeat, 1);
        let repeated = simperf_json_repeated(Scale::Tiny, &results, true, true, 5);
        assert_eq!(repeated.methodology.repeat, 5);
        let s = serde_json::to_string(&doc).unwrap();
        for key in [
            "schema_version",
            "methodology",
            "timer",
            "serial",
            "fresh",
            "host_threads",
            "repeat",
            "total_wall_ms",
            "geomean_cycles_per_sec",
            "points",
            "wall_ms",
            "cycles_per_sec",
        ] {
            assert!(s.contains(&format!("\"{key}\"")), "missing key {key}");
        }
    }

    #[test]
    fn machine_entry_and_stats_carry_sim_throughput() {
        // The suite JSON's per-machine columns and `stats` appendix now
        // carry the simulator-throughput fields (append-only, schema v1
        // preserved).
        let cfg = MachineConfig::scaled();
        let pair = run_pair(Workload::Axpy, &cfg, Scale::Tiny).unwrap();
        let mut stats = SuiteStats::from_cache(crate::coordinator::SimCache::global());
        stats.record_run(&pair.mpu);
        stats.record_run(&pair.gpu);
        assert_eq!(stats.sim_cycles_total, pair.mpu.cycles + pair.gpu.cycles);
        let mut doc = suite_json(Scale::Tiny, &[pair]);
        doc.stats = Some(stats);
        assert_eq!(doc.schema_version, 1);
        let s = serde_json::to_string(&doc).unwrap();
        for key in ["sim_wall_ms", "sim_cycles_per_sec", "sim_cycles_total"] {
            assert!(s.contains(&format!("\"{key}\"")), "missing key {key}");
        }
    }

    #[test]
    fn write_emits_valid_json_file() {
        let cfg = MachineConfig::scaled();
        let pair = run_pair(Workload::Knn, &cfg, Scale::Tiny).unwrap();
        let doc = suite_json(Scale::Tiny, &[pair]);
        let dir = std::env::temp_dir().join("mpu_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(SUITE_JSON);
        write_suite_json(&path, &doc).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let v: serde_json::Value = serde_json::from_str(&body).unwrap();
        assert_eq!(v["schema_version"], 1);
        assert_eq!(v["workloads"][0]["workload"], "knn");
    }
}
