//! Offload-policy autotuner (`mpu tune`).
//!
//! The paper's Algorithm-1 compiler pass (§V-B) fixes a near/far
//! placement for every instruction statically. This module treats that
//! decision as a *searchable artifact* instead: a candidate policy is an
//! explicit per-kernel, per-pc [`OffloadPolicyTable`] carried inside
//! [`MachineConfig`], so each candidate has its own config fingerprint
//! and rides the existing caching stack — [`SimCache`] memory tier, the
//! persistent disk store, and federation dedup — for free. Re-tuning
//! against a warm store performs zero fresh simulations for candidates
//! that were already evaluated.
//!
//! [`search`] enumerates the candidate space exhaustively when the
//! kernel's tunable (ALU, non-mandated) pc set is small enough for the
//! budget, and otherwise runs deterministic greedy bit-flips followed by
//! seeded simulated annealing ([`crate::sim::Prng`]; no ambient
//! randomness, so the same seed and budget reproduce the same best
//! policy). The Algorithm-1 annotation is always candidate #0, so the
//! tuned policy is never worse than the compiler heuristic.

pub mod search;

use crate::compiler::LocStats;
use crate::config::{MachineConfig, OffloadPolicyTable, SmemLocation};
use crate::coordinator::proto::{PointSpec, SubmitRequest};
use crate::coordinator::sweep::{compile_kernel, CacheTier, SweepPoint, Target};
use crate::coordinator::{geomean, Federation, KernelCache, SimCache};
use crate::isa::instr::Loc;
use crate::workloads::{Scale, Workload};
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;

/// Default report file name.
pub const TUNE_REPORT: &str = "TUNE_report.json";

/// Schema version of [`TuneReport`].
pub const TUNE_SCHEMA_VERSION: u64 = 1;

/// Options for one `tune` invocation.
#[derive(Clone, Debug)]
pub struct TuneOptions {
    pub workloads: Vec<Workload>,
    pub scale: Scale,
    /// Maximum candidate-policy evaluations per workload (the
    /// Algorithm-1 seed counts as the first; baselines do not).
    pub budget: usize,
    /// Annealing seed — same seed and budget reproduce the same search.
    pub seed: u64,
    /// Simulation threads per local evaluation.
    pub threads: usize,
    /// Worker daemon addresses; empty means evaluate in-process.
    pub workers: Vec<String>,
    /// Base config overrides applied under every candidate.
    pub base_overrides: Vec<(String, String)>,
}

impl Default for TuneOptions {
    fn default() -> TuneOptions {
        TuneOptions {
            workloads: Workload::ALL.to_vec(),
            scale: Scale::Tiny,
            budget: 32,
            seed: 0xA11CE,
            threads: 1,
            workers: Vec::new(),
            base_overrides: Vec::new(),
        }
    }
}

/// How the evaluations were served, by tier.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalCounters {
    pub evaluations: usize,
    pub simulated: usize,
    pub mem_hits: usize,
    pub disk_hits: usize,
}

impl EvalCounters {
    pub fn cached(&self) -> usize {
        self.mem_hits + self.disk_hits
    }
}

/// One candidate's measured objective.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub cycles: u64,
    pub energy_j: f64,
    pub correct: bool,
}

enum EvalMode<'a> {
    /// In-process: simulate through the shared two-tier cache.
    Local { cache: &'a SimCache, kernels: KernelCache, threads: usize },
    /// Ship each candidate to worker daemons; their stores dedup.
    Federated { fed: Federation },
}

/// Evaluates candidate configs for the tuner. Both modes express a
/// candidate as config-override *pairs* on top of shared base pairs —
/// the federation wire format — so a local evaluation and a federated
/// one build identical configs and therefore identical fingerprints and
/// cache keys.
pub struct Evaluator<'a> {
    base_pairs: Vec<(String, String)>,
    base: MachineConfig,
    mode: EvalMode<'a>,
    counters: EvalCounters,
}

impl<'a> Evaluator<'a> {
    fn base_config(pairs: &[(String, String)]) -> Result<MachineConfig> {
        let mut cfg = MachineConfig::scaled();
        for (k, v) in pairs {
            cfg.set(k, v).map_err(|e| anyhow::anyhow!("bad base override {k}={v}: {e}"))?;
        }
        Ok(cfg)
    }

    /// In-process evaluator over `cache` (attach a disk store to the
    /// cache beforehand for persistent dedup).
    pub fn local(
        base_pairs: Vec<(String, String)>,
        cache: &'a SimCache,
        threads: usize,
    ) -> Result<Evaluator<'a>> {
        let base = Evaluator::base_config(&base_pairs)?;
        Ok(Evaluator {
            base_pairs,
            base,
            mode: EvalMode::Local { cache, kernels: KernelCache::new(), threads },
            counters: EvalCounters::default(),
        })
    }

    /// Federated evaluator fanning candidates out over worker daemons.
    pub fn federated(
        base_pairs: Vec<(String, String)>,
        workers: Vec<String>,
    ) -> Result<Evaluator<'a>> {
        let base = Evaluator::base_config(&base_pairs)?;
        let fed = Federation::new(workers)?;
        fed.handshake()?;
        Ok(Evaluator {
            base_pairs,
            base,
            mode: EvalMode::Federated { fed },
            counters: EvalCounters::default(),
        })
    }

    /// The shared base config every candidate is applied on top of.
    pub fn base(&self) -> &MachineConfig {
        &self.base
    }

    pub fn counters(&self) -> EvalCounters {
        self.counters
    }

    /// Evaluate the base config plus `extra` override pairs on one
    /// workload/scale point.
    pub fn eval(
        &mut self,
        w: Workload,
        scale: Scale,
        extra: &[(String, String)],
    ) -> Result<EvalResult> {
        self.counters.evaluations += 1;
        match &mut self.mode {
            EvalMode::Local { cache, kernels, threads } => {
                let mut cfg = self.base.clone();
                for (k, v) in extra {
                    cfg.set(k, v).map_err(|e| anyhow::anyhow!("bad override {k}={v}: {e}"))?;
                }
                let pt = SweepPoint {
                    label: "tune".to_string(),
                    workload: w,
                    scale,
                    target: Target::Mpu(cfg),
                };
                let threads = *threads;
                let (r, tier) =
                    cache.get_or_run_traced(&pt, || pt.simulate_with_threads(kernels, threads))?;
                match tier {
                    CacheTier::Memory => self.counters.mem_hits += 1,
                    CacheTier::Disk => self.counters.disk_hits += 1,
                    CacheTier::Simulated => self.counters.simulated += 1,
                }
                Ok(EvalResult { cycles: r.cycles, energy_j: r.energy.total(), correct: r.correct })
            }
            EvalMode::Federated { fed } => {
                let mut config = self.base_pairs.clone();
                config.extend(extra.iter().cloned());
                let req = SubmitRequest {
                    scale: scale.name().to_string(),
                    config,
                    point_specs: vec![PointSpec {
                        workload: w.name().to_string(),
                        variant: "mpu".to_string(),
                        config: vec![],
                    }],
                    ..SubmitRequest::default()
                };
                let res = fed.submit_streamed(&req, |_| {})?;
                let reply = res.reply;
                self.counters.simulated += reply.simulated;
                self.counters.mem_hits += reply.mem_hits + reply.deduped;
                self.counters.disk_hits += reply.disk_hits;
                let p = reply
                    .results
                    .into_iter()
                    .next()
                    .context("federated tune evaluation returned no result")?;
                Ok(EvalResult { cycles: p.cycles, energy_j: p.energy_j, correct: p.correct })
            }
        }
    }

    /// Evaluate many candidates of one workload in a single round.
    /// Locally this is a plain loop through the cache; federated it is
    /// ONE `point_specs` submit whose specs carry the per-candidate
    /// override pairs (v4 `spec_config`), so a whole search generation
    /// costs one coordinator round trip instead of one per candidate.
    /// Results come back in `extras` order.
    pub fn eval_batch(
        &mut self,
        w: Workload,
        scale: Scale,
        extras: &[Vec<(String, String)>],
    ) -> Result<Vec<EvalResult>> {
        if extras.is_empty() {
            return Ok(Vec::new());
        }
        if matches!(self.mode, EvalMode::Local { .. }) {
            return extras.iter().map(|extra| self.eval(w, scale, extra)).collect();
        }
        self.counters.evaluations += extras.len();
        let EvalMode::Federated { fed } = &mut self.mode else { unreachable!() };
        let req = SubmitRequest {
            scale: scale.name().to_string(),
            config: self.base_pairs.clone(),
            point_specs: extras
                .iter()
                .map(|extra| PointSpec {
                    workload: w.name().to_string(),
                    variant: "mpu".to_string(),
                    config: extra.clone(),
                })
                .collect(),
            ..SubmitRequest::default()
        };
        let res = fed.submit_streamed(&req, |_| {})?;
        let reply = res.reply;
        self.counters.simulated += reply.simulated;
        self.counters.mem_hits += reply.mem_hits + reply.deduped;
        self.counters.disk_hits += reply.disk_hits;
        ensure!(
            reply.results.len() == extras.len(),
            "federated tune batch returned {} of {} results",
            reply.results.len(),
            extras.len()
        );
        Ok(reply
            .results
            .into_iter()
            .map(|p| EvalResult { cycles: p.cycles, energy_j: p.energy_j, correct: p.correct })
            .collect())
    }
}

/// The config-override pairs carrying one candidate policy table (the
/// federation wire format; local evaluation routes the same pairs
/// through [`MachineConfig::set`], producing an identical fingerprint).
pub fn policy_pairs(table: &OffloadPolicyTable) -> Vec<(String, String)> {
    vec![
        ("offload_policy".to_string(), "explicit".to_string()),
        (
            "offload_table".to_string(),
            serde_json::to_string(table).expect("policy tables always serialize"),
        ),
    ]
}

/// One point of the best-so-far search trajectory.
#[derive(Clone, Copy, Debug, serde::Serialize, serde::Deserialize)]
pub struct TrajectoryPoint {
    /// Candidate-evaluation index at which this best was found (0 = the
    /// Algorithm-1 seed).
    pub evaluation: usize,
    pub cycles: u64,
}

/// Per-workload tuning result.
#[derive(Clone, Debug, serde::Serialize, serde::Deserialize)]
pub struct WorkloadTune {
    pub workload: String,
    pub kernel: String,
    /// `"seed-only"`, `"exhaustive"` or `"greedy+anneal"`.
    pub search_mode: String,
    /// Size of the tunable (ALU) pc set.
    pub candidate_pcs: usize,
    /// Candidate policies evaluated (≤ budget; intra-search duplicates
    /// are not re-evaluated).
    pub evaluations: usize,
    /// Winning per-pc assignment over the tunable set.
    pub best_policy: BTreeMap<u32, Loc>,
    pub tuned_cycles: u64,
    pub annotated_cycles: u64,
    pub hw_default_cycles: u64,
    pub nooff_cycles: u64,
    pub tuned_energy_j: f64,
    pub annotated_energy_j: f64,
    pub speedup_vs_annotated: f64,
    pub speedup_vs_hw_default: f64,
    pub speedup_vs_nooff: f64,
    /// Fig.-14 register-location breakdown of the compiled kernel.
    pub loc_stats: LocStats,
    /// Tunable pcs the compiler annotated near-bank.
    pub near_pcs_annotated: usize,
    /// Tunable pcs the winning policy places near-bank.
    pub near_pcs_tuned: usize,
    /// Best-so-far improvements in evaluation order.
    pub trajectory: Vec<TrajectoryPoint>,
}

/// The `TUNE_report.json` schema (versioned; validated by
/// `mpu check-json`).
#[derive(Clone, Debug, serde::Serialize, serde::Deserialize)]
pub struct TuneReport {
    pub schema_version: u64,
    /// Report discriminator, always `"tune"`.
    pub report: String,
    pub scale: String,
    pub budget: usize,
    pub seed: u64,
    pub federated: bool,
    pub geomean_speedup_vs_annotated: f64,
    /// Total evaluations across workloads, baselines included.
    pub evaluations: usize,
    /// Evaluations that actually simulated (the rest were served by the
    /// memory/disk/federation cache tiers).
    pub simulated: usize,
    pub mem_hits: usize,
    pub disk_hits: usize,
    pub workloads: Vec<WorkloadTune>,
}

/// One row of the suite doc's `tuning` appendix.
#[derive(Clone, Debug, serde::Serialize, serde::Deserialize)]
pub struct TuningWorkload {
    pub workload: String,
    pub tuned_cycles: u64,
    pub annotated_cycles: u64,
    pub speedup_vs_annotated: f64,
    pub speedup_vs_hw_default: f64,
    pub speedup_vs_nooff: f64,
}

/// The append-only `tuning` appendix of `BENCH_suite.json`: the tuned
/// best-vs-heuristic speedups per workload plus suite geomeans. Written
/// by `mpu tune --append-suite`, validated by `mpu check-json`.
#[derive(Clone, Debug, serde::Serialize, serde::Deserialize)]
pub struct TuningAppendix {
    pub scale: String,
    pub budget: usize,
    pub seed: u64,
    pub geomean_speedup_vs_annotated: f64,
    pub geomean_speedup_vs_hw_default: f64,
    pub geomean_speedup_vs_nooff: f64,
    pub workloads: Vec<TuningWorkload>,
}

impl TuneReport {
    /// Condense this report into the suite appendix.
    pub fn appendix(&self) -> TuningAppendix {
        let col = |f: fn(&WorkloadTune) -> f64| -> Vec<f64> {
            self.workloads.iter().map(f).collect()
        };
        TuningAppendix {
            scale: self.scale.clone(),
            budget: self.budget,
            seed: self.seed,
            geomean_speedup_vs_annotated: geomean(&col(|w| w.speedup_vs_annotated)),
            geomean_speedup_vs_hw_default: geomean(&col(|w| w.speedup_vs_hw_default)),
            geomean_speedup_vs_nooff: geomean(&col(|w| w.speedup_vs_nooff)),
            workloads: self
                .workloads
                .iter()
                .map(|w| TuningWorkload {
                    workload: w.workload.clone(),
                    tuned_cycles: w.tuned_cycles,
                    annotated_cycles: w.annotated_cycles,
                    speedup_vs_annotated: w.speedup_vs_annotated,
                    speedup_vs_hw_default: w.speedup_vs_hw_default,
                    speedup_vs_nooff: w.speedup_vs_nooff,
                })
                .collect(),
        }
    }
}

/// Tune every requested workload and assemble the report.
pub fn tune(opts: &TuneOptions, cache: &SimCache) -> Result<TuneReport> {
    ensure!(!opts.workloads.is_empty(), "no workloads to tune");
    ensure!(opts.budget >= 1, "budget must be at least 1 (the Algorithm-1 seed)");
    let mut ev = if opts.workers.is_empty() {
        Evaluator::local(opts.base_overrides.clone(), cache, opts.threads.max(1))?
    } else {
        Evaluator::federated(opts.base_overrides.clone(), opts.workers.clone())?
    };
    let mut entries = Vec::new();
    for &w in &opts.workloads {
        entries.push(tune_workload(&mut ev, w, opts)?);
    }
    let speedups: Vec<f64> = entries.iter().map(|e| e.speedup_vs_annotated).collect();
    let c = ev.counters();
    Ok(TuneReport {
        schema_version: TUNE_SCHEMA_VERSION,
        report: "tune".to_string(),
        scale: opts.scale.name().to_string(),
        budget: opts.budget,
        seed: opts.seed,
        federated: !opts.workers.is_empty(),
        geomean_speedup_vs_annotated: geomean(&speedups),
        evaluations: c.evaluations,
        simulated: c.simulated,
        mem_hits: c.mem_hits,
        disk_hits: c.disk_hits,
        workloads: entries,
    })
}

fn tune_workload(ev: &mut Evaluator, w: Workload, opts: &TuneOptions) -> Result<WorkloadTune> {
    // Baselines go through the same evaluator, so they share the caches
    // and show up in the tier counters like any candidate.
    let ann = ev.eval(w, opts.scale, &[])?;
    ensure!(ann.correct, "{}: incorrect under CompilerAnnotated", w.name());
    let hw =
        ev.eval(w, opts.scale, &[("offload_policy".to_string(), "hw".to_string())])?;
    // `all_fb` is exactly the `mpu_nooff` machine variant
    // (`Target::for_kind` builds it as `cfg.no_offload()`), so this hits
    // the same cache entries a suite run produced.
    let nooff =
        ev.eval(w, opts.scale, &[("offload_policy".to_string(), "all_fb".to_string())])?;

    // The candidate pc set and the Algorithm-1 seed come from a local
    // compile. Compilation is deterministic, so federated workers see
    // exactly this kernel.
    let smem_near = ev.base().smem_location == SmemLocation::NearBank;
    let kernel = compile_kernel(w, smem_near)?;
    let out = search::search_policy(ev, w, opts.scale, &kernel, opts.budget, opts.seed)?;

    // The seed reproduces CompilerAnnotated timing exactly, so the best
    // candidate can never lose to it.
    ensure!(
        out.best_cycles <= ann.cycles,
        "{}: tuned {} cycles worse than annotated {} — seed candidate lost",
        w.name(),
        out.best_cycles,
        ann.cycles
    );

    let near_pcs_tuned = out.best.values().filter(|&&l| l == Loc::N).count();
    let near_pcs_annotated =
        kernel.tunable_pcs().iter().filter(|&&pc| kernel.ops[pc].hint == Loc::N).count();
    Ok(WorkloadTune {
        workload: w.name().to_string(),
        kernel: kernel.name.clone(),
        search_mode: out.mode.to_string(),
        candidate_pcs: kernel.tunable_pcs().len(),
        evaluations: out.evaluations,
        best_policy: out.best,
        tuned_cycles: out.best_cycles,
        annotated_cycles: ann.cycles,
        hw_default_cycles: hw.cycles,
        nooff_cycles: nooff.cycles,
        tuned_energy_j: out.best_energy_j,
        annotated_energy_j: ann.energy_j,
        speedup_vs_annotated: ann.cycles as f64 / out.best_cycles.max(1) as f64,
        speedup_vs_hw_default: hw.cycles as f64 / out.best_cycles.max(1) as f64,
        speedup_vs_nooff: nooff.cycles as f64 / out.best_cycles.max(1) as f64,
        loc_stats: kernel.loc_stats.clone(),
        near_pcs_annotated,
        near_pcs_tuned,
        trajectory: out.trajectory,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn axpy_opts(budget: usize, seed: u64) -> TuneOptions {
        TuneOptions {
            workloads: vec![Workload::Axpy],
            budget,
            seed,
            ..TuneOptions::default()
        }
    }

    #[test]
    fn policy_pairs_round_trip_through_config_set() {
        let mut table = OffloadPolicyTable::default();
        table.set("axpy", 3, Loc::N);
        table.set("axpy", 7, Loc::F);
        let mut cfg = MachineConfig::scaled();
        for (k, v) in policy_pairs(&table) {
            cfg.set(&k, &v).unwrap();
        }
        assert_eq!(cfg.offload_policy, crate::config::OffloadPolicy::Explicit);
        assert_eq!(cfg.offload_table, table);
    }

    #[test]
    fn tune_axpy_never_worse_and_warm_rerun_is_all_cached() {
        let cache = SimCache::default();
        let opts = axpy_opts(6, 42);
        let r1 = tune(&opts, &cache).unwrap();
        assert_eq!(r1.schema_version, TUNE_SCHEMA_VERSION);
        assert_eq!(r1.report, "tune");
        let wt = &r1.workloads[0];
        assert!(
            wt.tuned_cycles <= wt.annotated_cycles,
            "tuned {} > annotated {}",
            wt.tuned_cycles,
            wt.annotated_cycles
        );
        assert!(wt.speedup_vs_annotated >= 1.0);
        assert!(r1.simulated > 0, "cold run must simulate");
        assert!(!wt.trajectory.is_empty(), "seed eval must appear in the trajectory");

        // Same cache, same options: every candidate the deterministic
        // search revisits is served from the memory tier.
        let r2 = tune(&opts, &cache).unwrap();
        assert_eq!(r2.simulated, 0, "warm rerun must not simulate");
        assert_eq!(r2.workloads[0].best_policy, wt.best_policy);
        assert_eq!(r2.workloads[0].tuned_cycles, wt.tuned_cycles);
    }

    #[test]
    fn tune_is_deterministic_for_a_seed() {
        let a = tune(&axpy_opts(5, 7), &SimCache::default()).unwrap();
        let b = tune(&axpy_opts(5, 7), &SimCache::default()).unwrap();
        assert_eq!(a.workloads[0].best_policy, b.workloads[0].best_policy);
        assert_eq!(a.workloads[0].tuned_cycles, b.workloads[0].tuned_cycles);
        assert_eq!(a.workloads[0].search_mode, b.workloads[0].search_mode);
    }
}
