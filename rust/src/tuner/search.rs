//! Search strategies over the per-pc offload-policy space.
//!
//! A candidate is one bit per tunable pc (near-bank vs far-bank). Small
//! kernels are enumerated exhaustively; past the budget the search runs
//! deterministic greedy bit-flips from the Algorithm-1 seed, then seeded
//! simulated annealing. All randomness comes from [`Prng`] seeded with
//! `seed ^ stable_hash(kernel)`, so the same seed and budget always
//! reproduce the same best policy.
//!
//! Candidates are evaluated in *generations*: each search phase proposes
//! a batch of masks up front and hands them to
//! [`SearchState::eval_many`], which answers memo hits for free and
//! sends the fresh remainder through [`Evaluator::eval_batch`] — against
//! a federation that is ONE `point_specs` submit per generation instead
//! of one round trip per candidate.

use super::{policy_pairs, Evaluator, TrajectoryPoint};
use crate::compiler::DecodedKernel;
use crate::config::OffloadPolicyTable;
use crate::coordinator::sweep::stable_hash;
use crate::isa::instr::Loc;
use crate::sim::prng::Prng;
use crate::workloads::{Scale, Workload};
use anyhow::{ensure, Result};
use std::collections::{BTreeMap, HashMap};

/// Exhaustive enumeration is considered only below this candidate-set
/// size (and only when `2^k` also fits the evaluation budget).
const EXHAUSTIVE_MAX_PCS: usize = 16;

/// Candidates proposed (and submitted as one federated batch) per
/// search generation.
const GENERATION: usize = 8;

/// Result of one per-kernel search.
pub struct SearchOutcome {
    /// Winning assignment over the tunable pc set.
    pub best: BTreeMap<u32, Loc>,
    pub best_cycles: u64,
    pub best_energy_j: f64,
    /// `"seed-only"`, `"exhaustive"` or `"greedy+anneal"`.
    pub mode: &'static str,
    /// Unique candidates evaluated (duplicates are served from the
    /// intra-search memo and cost nothing).
    pub evaluations: usize,
    pub trajectory: Vec<TrajectoryPoint>,
}

/// Objective order: cycles first, energy breaks ties.
fn lt(a: (u64, f64), b: (u64, f64)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

struct SearchState<'s, 'c> {
    ev: &'s mut Evaluator<'c>,
    w: Workload,
    scale: Scale,
    kernel: &'s DecodedKernel,
    /// Tunable pcs; candidate masks index this vector.
    pcs: Vec<usize>,
    budget: usize,
    evaluations: usize,
    /// Intra-search memo: mask → (cycles, energy).
    seen: HashMap<Vec<bool>, (u64, f64)>,
    best_mask: Vec<bool>,
    best: (u64, f64),
    trajectory: Vec<TrajectoryPoint>,
}

impl SearchState<'_, '_> {
    fn table_of(&self, mask: &[bool]) -> OffloadPolicyTable {
        let mut t = OffloadPolicyTable::default();
        for (&pc, &near) in self.pcs.iter().zip(mask) {
            t.set(&self.kernel.name, pc as u32, if near { Loc::N } else { Loc::F });
        }
        t
    }

    /// Evaluate one mask. Returns `None` once the budget is exhausted
    /// (already-seen masks are free and always answer).
    fn eval(&mut self, mask: &[bool]) -> Result<Option<(u64, f64)>> {
        if let Some(&obj) = self.seen.get(mask) {
            return Ok(Some(obj));
        }
        if self.evaluations >= self.budget {
            return Ok(None);
        }
        let table = self.table_of(mask);
        let r = self.ev.eval(self.w, self.scale, &policy_pairs(&table))?;
        ensure!(
            r.correct,
            "{}: candidate policy changed functional output — placement must be timing-only",
            self.w.name()
        );
        let obj = (r.cycles, r.energy_j);
        let idx = self.evaluations;
        self.evaluations += 1;
        self.seen.insert(mask.to_vec(), obj);
        if lt(obj, self.best) {
            self.best = obj;
            self.best_mask = mask.to_vec();
            self.trajectory.push(TrajectoryPoint { evaluation: idx, cycles: r.cycles });
        }
        Ok(Some(obj))
    }

    /// Evaluate a whole generation of masks in one shot. Memo hits
    /// answer for free; the unique fresh remainder — capped so the
    /// budget is never exceeded — goes through
    /// [`Evaluator::eval_batch`] as a single submit. Returns one slot
    /// per input mask, `None` where the budget ran out first.
    fn eval_many(&mut self, masks: &[Vec<bool>]) -> Result<Vec<Option<(u64, f64)>>> {
        let mut fresh: Vec<Vec<bool>> = Vec::new();
        for mask in masks {
            if self.seen.contains_key(mask) || fresh.contains(mask) {
                continue;
            }
            if self.evaluations + fresh.len() >= self.budget {
                continue;
            }
            fresh.push(mask.clone());
        }
        if !fresh.is_empty() {
            let extras: Vec<Vec<(String, String)>> =
                fresh.iter().map(|m| policy_pairs(&self.table_of(m))).collect();
            let results = self.ev.eval_batch(self.w, self.scale, &extras)?;
            for (mask, r) in fresh.iter().zip(results) {
                ensure!(
                    r.correct,
                    "{}: candidate policy changed functional output — placement must be timing-only",
                    self.w.name()
                );
                let obj = (r.cycles, r.energy_j);
                let idx = self.evaluations;
                self.evaluations += 1;
                self.seen.insert(mask.clone(), obj);
                if lt(obj, self.best) {
                    self.best = obj;
                    self.best_mask = mask.clone();
                    self.trajectory.push(TrajectoryPoint { evaluation: idx, cycles: r.cycles });
                }
            }
        }
        Ok(masks.iter().map(|m| self.seen.get(m).copied()).collect())
    }

    fn finish(self, mode: &'static str) -> SearchOutcome {
        let best: BTreeMap<u32, Loc> = self
            .pcs
            .iter()
            .zip(&self.best_mask)
            .map(|(&pc, &near)| (pc as u32, if near { Loc::N } else { Loc::F }))
            .collect();
        SearchOutcome {
            best,
            best_cycles: self.best.0,
            best_energy_j: self.best.1,
            mode,
            evaluations: self.evaluations,
            trajectory: self.trajectory,
        }
    }
}

/// Search the policy space of one kernel within `budget` evaluations.
pub fn search_policy(
    ev: &mut Evaluator,
    w: Workload,
    scale: Scale,
    kernel: &DecodedKernel,
    budget: usize,
    seed: u64,
) -> Result<SearchOutcome> {
    let pcs = kernel.tunable_pcs();
    let k = pcs.len();
    let budget = budget.max(1);
    // Seed assignment = the Algorithm-1 annotation with the decode-time
    // unknown → far fallback applied; under `Explicit` it reproduces
    // CompilerAnnotated timing bit-for-bit.
    let seed_mask: Vec<bool> = pcs.iter().map(|&pc| kernel.ops[pc].hint == Loc::N).collect();

    let mut st = SearchState {
        ev,
        w,
        scale,
        kernel,
        pcs,
        budget,
        evaluations: 0,
        seen: HashMap::new(),
        best_mask: seed_mask.clone(),
        best: (u64::MAX, f64::INFINITY),
        trajectory: Vec::new(),
    };
    // The seed is always candidate #0: with it in the space the tuned
    // policy can never lose to the compiler heuristic.
    st.eval(&seed_mask)?;

    let mode = if k == 0 {
        "seed-only"
    } else if k <= EXHAUSTIVE_MAX_PCS && (1usize << k) <= budget {
        // Enumerate LSB-first, one generation-sized batch per submit.
        let mut bits = 0u64;
        'enumerate: while bits < (1u64 << k) {
            let gen: Vec<Vec<bool>> = (0..GENERATION as u64)
                .map_while(|off| {
                    let b = bits + off;
                    (b < (1u64 << k)).then(|| (0..k).map(|i| b >> i & 1 == 1).collect())
                })
                .collect();
            bits += gen.len() as u64;
            for obj in st.eval_many(&gen)? {
                if obj.is_none() {
                    break 'enumerate;
                }
            }
        }
        "exhaustive"
    } else {
        let cur = greedy(&mut st, &seed_mask)?;
        anneal(&mut st, cur, seed ^ stable_hash(&kernel.name))?;
        "greedy+anneal"
    };
    Ok(st.finish(mode))
}

/// Deterministic bit-flip hill climbing from `start`. Each pass
/// proposes every single-bit flip of the current mask as one batch,
/// then takes improvements in pc order against the pass results.
fn greedy(st: &mut SearchState, start: &[bool]) -> Result<Vec<bool>> {
    let mut cur = start.to_vec();
    let mut cur_obj = match st.eval(&cur)? {
        Some(o) => o,
        None => return Ok(cur),
    };
    loop {
        let flips: Vec<Vec<bool>> = (0..cur.len())
            .map(|i| {
                let mut cand = cur.clone();
                cand[i] = !cand[i];
                cand
            })
            .collect();
        let mut improved = false;
        for (cand, obj) in flips.iter().zip(st.eval_many(&flips)?) {
            let obj = match obj {
                Some(o) => o,
                None => return Ok(cur),
            };
            if lt(obj, cur_obj) {
                cur = cand.clone();
                cur_obj = obj;
                improved = true;
            }
        }
        if !improved {
            return Ok(cur);
        }
    }
}

/// Seeded simulated annealing from `start` until the budget runs out.
/// Proposals come in generations of [`GENERATION`] mutations of the
/// current mask, evaluated as one batch and then accepted or rejected
/// in proposal order by the Metropolis criterion — so an accepted move
/// takes effect from the next generation, and all `Prng` draws happen
/// in a fixed order regardless of how the batch was served.
fn anneal(st: &mut SearchState, start: Vec<bool>, seed: u64) -> Result<()> {
    let n = start.len();
    if n == 0 {
        return Ok(());
    }
    let mut rng = Prng::new(seed);
    let mut cur = start;
    let mut cur_obj = match st.eval(&cur)? {
        Some(o) => o,
        None => return Ok(()),
    };
    // The step cap bounds re-visits of already-memoized masks once the
    // budget outpaces the reachable neighborhood.
    let max_steps = st.budget.saturating_mul(64).max(256);
    let mut steps = 0usize;
    while steps < max_steps && st.evaluations < st.budget {
        let gen: Vec<Vec<bool>> = (0..GENERATION)
            .map(|_| {
                let mut cand = cur.clone();
                cand[rng.below(n as u64) as usize] ^= true;
                if rng.chance(0.3) {
                    cand[rng.below(n as u64) as usize] ^= true;
                }
                cand
            })
            .collect();
        for (cand, obj) in gen.iter().zip(st.eval_many(&gen)?) {
            steps += 1;
            let obj = match obj {
                Some(o) => o,
                None => return Ok(()),
            };
            // Relative-cycles Metropolis criterion; temperature cools
            // linearly with spent budget.
            let progress = st.evaluations as f64 / st.budget as f64;
            let t = (0.08 * (1.0 - progress)).max(0.005);
            let accept = if lt(obj, cur_obj) {
                true
            } else {
                let delta = (obj.0 as f64 - cur_obj.0 as f64) / cur_obj.0.max(1) as f64;
                (rng.f32() as f64) < (-delta / t).exp()
            };
            if accept {
                cur = cand.clone();
                cur_obj = obj;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_order_is_cycles_then_energy() {
        assert!(lt((10, 5.0), (11, 0.0)));
        assert!(lt((10, 1.0), (10, 2.0)));
        assert!(!lt((10, 2.0), (10, 2.0)));
        assert!(!lt((12, 0.0), (11, 9.0)));
    }
}
