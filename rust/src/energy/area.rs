//! Area model (Table III): DRAM-die overhead of MPU's near-bank
//! components, with the conservative 2× DRAM-process penalty already
//! folded into the per-unit numbers (as in the paper).

use crate::config::MachineConfig;

/// One Table-III row.
#[derive(Clone, Debug)]
pub struct AreaRow {
    pub name: &'static str,
    pub count: usize,
    /// mm² per DRAM die for this component class.
    pub area_mm2: f64,
    /// Percent of a 96 mm² HBM DRAM die.
    pub overhead_pct: f64,
}

/// Table-III per-unit areas (mm², DRAM process, 20 nm), derived from the
/// paper's totals divided by the per-die instance counts.
mod unit {
    pub const SMEM: f64 = 0.84 / 4.0;
    pub const RF: f64 = 9.71 / 16.0;
    pub const MEMCTRL: f64 = 0.63 / 16.0;
    pub const OPC: f64 = 2.43 / 64.0;
    pub const VALU: f64 = 3.74 / 16.0;
    pub const LSU_EXT: f64 = 2.43 / 16.0;
    pub const MULTI_ROWBUF: f64 = 0.01 / 64.0;
}

/// HBM DRAM die footprint (mm²) [68].
pub const DRAM_DIE_MM2: f64 = 96.0;

/// Area report for one DRAM die.
#[derive(Clone, Debug)]
pub struct AreaReport {
    pub rows: Vec<AreaRow>,
}

impl AreaReport {
    /// Build the report for a machine configuration. In the paper's
    /// horizontal core structure, 4 cores share one DRAM die (8 procs ×
    /// 4 dies × 16 cores → 4 cores/die with 4 NBUs each → 16 NBUs/die).
    pub fn for_config(cfg: &MachineConfig) -> AreaReport {
        let cores_per_die = 4;
        let nbus = cores_per_die * cfg.nbus_per_core;
        let banks = nbus * cfg.banks_per_nbu;
        let opcs = nbus * 4; // 4 operand collectors per NBU
        // The near-bank RF is half the far-bank size (§VI-B, thanks to
        // the Fig.-14 register-location separation); Table III already
        // reflects the halved size, scale if configured differently.
        let rf_scale = cfg.nb_rf_bytes as f64 / (16.0 * 1024.0);
        // Multi-row-buffer support scales with extra row-buffer count.
        let extra_bufs = cfg.row_buffers_per_bank.saturating_sub(1) as f64 / 3.0;

        let rows = vec![
            row("Shared Memory", cores_per_die, unit::SMEM * cores_per_die as f64),
            row("Register File", nbus, unit::RF * rf_scale * nbus as f64),
            row("Memory Controller", nbus, unit::MEMCTRL * nbus as f64),
            row("Operand Collector", opcs, unit::OPC * opcs as f64),
            row("Vector ALU", nbus, unit::VALU * nbus as f64),
            row("LSU-extension", nbus, unit::LSU_EXT * nbus as f64),
            row("Multi-row-buffer Support", banks, unit::MULTI_ROWBUF * extra_bufs * banks as f64),
        ];
        AreaReport { rows }
    }

    pub fn total_mm2(&self) -> f64 {
        self.rows.iter().map(|r| r.area_mm2).sum()
    }

    pub fn total_overhead_pct(&self) -> f64 {
        self.total_mm2() / DRAM_DIE_MM2 * 100.0
    }

    /// Overhead if the *whole* core were placed on the DRAM die instead
    /// of the hybrid split (§VI-B: ~2× the hybrid overhead).
    pub fn whole_core_overhead_pct(&self) -> f64 {
        // Frontend + full-size RF + LSU + I-cache roughly double the
        // near-bank area (Harmonica synthesis 3.4 mm²/core × 4 cores ×
        // 2 (DRAM process) on top, with the RF at full size).
        let full_rf_extra = self.rows[1].area_mm2; // RF doubles
        let frontend = 3.4 * 4.0 * 2.0 - self.total_mm2() * 0.3;
        ((self.total_mm2() + full_rf_extra + frontend.max(0.0)) / DRAM_DIE_MM2) * 100.0
    }
}

fn row(name: &'static str, count: usize, area: f64) -> AreaRow {
    AreaRow { name, count, area_mm2: area, overhead_pct: area / DRAM_DIE_MM2 * 100.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_matches_table3_total() {
        let r = AreaReport::for_config(&MachineConfig::paper());
        // Table III: total 19.80 mm², 20.62% overhead.
        assert!((r.total_mm2() - 19.80).abs() < 0.3, "total {}", r.total_mm2());
        assert!((r.total_overhead_pct() - 20.62).abs() < 0.5, "pct {}", r.total_overhead_pct());
    }

    #[test]
    fn individual_rows_match_table3() {
        let r = AreaReport::for_config(&MachineConfig::paper());
        let get = |n: &str| r.rows.iter().find(|x| x.name == n).unwrap().area_mm2;
        assert!((get("Shared Memory") - 0.84).abs() < 0.01);
        assert!((get("Register File") - 9.71).abs() < 0.01);
        assert!((get("Vector ALU") - 3.74).abs() < 0.01);
        assert!((get("Multi-row-buffer Support") - 0.01).abs() < 0.005);
    }

    #[test]
    fn full_rf_raises_overhead_toward_30pct() {
        // §VI-B: without the compiler's register-location separation the
        // near-bank RF is full-size → overhead ≈ 30.74%.
        let mut cfg = MachineConfig::paper();
        cfg.nb_rf_bytes = 32 << 10;
        let r = AreaReport::for_config(&cfg);
        assert!(
            (r.total_overhead_pct() - 30.74).abs() < 1.0,
            "pct {}",
            r.total_overhead_pct()
        );
    }

    #[test]
    fn whole_core_costs_roughly_double() {
        let r = AreaReport::for_config(&MachineConfig::paper());
        let whole = r.whole_core_overhead_pct();
        assert!(whole > 1.7 * r.total_overhead_pct(), "whole {} hybrid {}", whole, r.total_overhead_pct());
    }

    #[test]
    fn single_row_buffer_has_no_masa_area() {
        let mut cfg = MachineConfig::paper();
        cfg.row_buffers_per_bank = 1;
        let r = AreaReport::for_config(&cfg);
        let masa = r.rows.iter().find(|x| x.name == "Multi-row-buffer Support").unwrap();
        assert_eq!(masa.area_mm2, 0.0);
    }
}
