//! Energy and area models (§VI-A methodology, Table II coefficients,
//! Table III area, Figs. 9–10).

pub mod area;

use crate::config::{EnergyCoeffs, GpuEnergyCoeffs};
use crate::sim::Stats;

/// Energy breakdown in joules, by the Fig.-10 categories.
///
/// Serializes with stable field names (part of the `BENCH_suite.json`
/// schema, see [`crate::coordinator::bench`], and of the on-disk result
/// store, see [`crate::coordinator::store`]).
#[derive(Clone, Copy, Debug, Default, serde::Serialize, serde::Deserialize)]
#[serde(default)]
pub struct EnergyBreakdown {
    /// Vector-ALU lane operations.
    pub alu: f64,
    /// Front pipeline: fetch/decode/issue/scoreboard/commit.
    pub frontend: f64,
    /// Operand collectors + register files ("OPC+RF").
    pub rf_opc: f64,
    /// DRAM column accesses + activations + refresh.
    pub dram: f64,
    /// Shared memory.
    pub smem: f64,
    /// TSV traffic.
    pub tsv: f64,
    /// On-chip mesh + off-chip SERDES ("Network").
    pub network: f64,
    /// LSU-Extension request handling.
    pub lsu_ext: f64,
    /// GPU-only: L2/crossbar/L1/PHY data path.
    pub cache_path: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.alu
            + self.frontend
            + self.rf_opc
            + self.dram
            + self.smem
            + self.tsv
            + self.network
            + self.lsu_ext
            + self.cache_path
    }

    /// Category shares, same order as the struct fields.
    pub fn shares(&self) -> [(&'static str, f64); 9] {
        let t = self.total().max(1e-30);
        [
            ("ALU", self.alu / t),
            ("Frontend", self.frontend / t),
            ("OPC+RF", self.rf_opc / t),
            ("DRAM", self.dram / t),
            ("SMEM", self.smem / t),
            ("TSV", self.tsv / t),
            ("Network", self.network / t),
            ("LSU-Ext", self.lsu_ext / t),
            ("CachePath", self.cache_path / t),
        ]
    }
}

/// MPU energy from run statistics (Table II coefficients).
pub fn mpu_energy(s: &Stats, c: &EnergyCoeffs) -> EnergyBreakdown {
    EnergyBreakdown {
        alu: s.alu_lane_ops as f64 * c.alu_op,
        frontend: s.instrs_total() as f64 * c.frontend_instr,
        rf_opc: (s.rf_far_accesses + s.rf_near_accesses) as f64 * c.rf
            + s.opc_accesses as f64 * c.operand_collector,
        dram: (s.dram_reads + s.dram_writes) as f64 * c.dram_rdwr
            + s.dram_acts as f64 * c.dram_preact
            + s.dram_refs as f64 * c.dram_ref,
        smem: s.smem_accesses as f64 * c.smem,
        tsv: s.tsv_total_bytes() as f64 * 8.0 * c.tsv_bit,
        // mesh_hops counts 32-B flit-hops.
        network: s.mesh_hops as f64 * 32.0 * 8.0 * c.onchip_bit
            + s.offchip_bytes as f64 * 8.0 * c.offchip_bit,
        lsu_ext: s.lsu_ext_requests as f64 * c.lsu_ext,
        cache_path: 0.0,
    }
}

/// GPU baseline energy: the long compute-centric data path — every DRAM
/// byte traverses HBM-internal TSVs, the interposer PHY and the
/// L2/crossbar/L1 path (§VI-B narrative).
pub fn gpu_energy(s: &Stats, c: &GpuEnergyCoeffs) -> EnergyBreakdown {
    let dram_bits = s.dram_bytes as f64 * 8.0;
    let l2_bits = s.l2_bytes as f64 * 8.0;
    EnergyBreakdown {
        alu: s.alu_lane_ops as f64 * c.alu_op,
        frontend: s.instrs_total() as f64 * c.frontend_instr,
        rf_opc: (s.rf_far_accesses + s.rf_near_accesses) as f64 * c.rf
            + s.opc_accesses as f64 * c.operand_collector,
        dram: (s.dram_reads + s.dram_writes) as f64 * c.dram_rdwr
            // Streaming activations: one ACT per row's worth of sectors
            // (2 KiB row / 32 B sector = 64), folded as an amortized cost.
            + (s.dram_reads + s.dram_writes) as f64 / 64.0 * c.dram_preact,
        smem: s.smem_accesses as f64 * c.smem,
        tsv: dram_bits * c.tsv_bit,
        network: 0.0,
        lsu_ext: 0.0,
        cache_path: dram_bits * (c.phy_bit + c.cache_path_bit) + l2_bits * c.cache_path_bit,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnergyCoeffs, GpuEnergyCoeffs};

    fn streaming_stats() -> Stats {
        // Roughly AXPY-shaped: 3 memory ops per 8 instructions.
        Stats {
            cycles: 1000,
            instrs_far: 6_000,
            instrs_near: 2_000,
            alu_lane_ops: 8_000 * 32,
            dram_reads: 2_000,
            dram_writes: 1_000,
            dram_acts: 60,
            dram_bytes: 96_000,
            rf_far_accesses: 20_000,
            rf_near_accesses: 8_000,
            opc_accesses: 16_000,
            tsv_bytes: [32_000, 16_000, 0, 0, 8_000],
            ..Default::default()
        }
    }

    #[test]
    fn mpu_energy_positive_and_additive() {
        let e = mpu_energy(&streaming_stats(), &EnergyCoeffs::default());
        assert!(e.total() > 0.0);
        let sum: f64 = e.shares().iter().map(|(_, s)| s).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(e.alu > 0.0 && e.dram > 0.0 && e.tsv > 0.0);
    }

    #[test]
    fn gpu_pays_for_the_data_path() {
        // Same work: the GPU's per-byte data-path energy dominates the
        // MPU's near-bank path — the Fig.-9 energy-reduction mechanism.
        let s = streaming_stats();
        let mpu = mpu_energy(&s, &EnergyCoeffs::default());
        let gpu = gpu_energy(&s, &GpuEnergyCoeffs::default());
        assert!(
            gpu.total() > 1.5 * mpu.total(),
            "gpu {} vs mpu {}",
            gpu.total(),
            mpu.total()
        );
        assert!(gpu.cache_path > 0.0);
        assert_eq!(mpu.cache_path, 0.0);
    }

    #[test]
    fn alu_energy_dominates_opc_rf_at_fig10_ratio() {
        // Fig. 10: ALU ≈ 39.8%, OPC+RF ≈ 15.5% → ratio ≈ 2.6.
        let e = mpu_energy(&streaming_stats(), &EnergyCoeffs::default());
        let ratio = e.alu / e.rf_opc;
        assert!(ratio > 1.5 && ratio < 6.0, "ALU/(OPC+RF) ratio {ratio}");
    }
}
