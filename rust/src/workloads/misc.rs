//! HIST (CUB-style 256-bin histogram) and NW (Rodinia Needleman–Wunsch).

use super::{Device, Prepared, Scale, Workload};
use crate::isa::program::ParamValue;
use crate::isa::{KernelSource, LaunchConfig, Reg};
use crate::sim::Prng;
use anyhow::Result;

/// HIST: 256-bin histogram with privatized shared-memory bins and a
/// global atomic flush — the CUB recipe. Bin counts are kept in f32 so
/// the XLA golden compares exactly.
pub fn hist(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let n: usize = match scale {
        Scale::Tiny => 8192,
        Scale::Small => 65536,
    };
    let bins = 256usize;
    let kernel = KernelSource::assemble(
        "hist",
        &[Reg::r(10), Reg::r(11), Reg::r(14)],
        r#"
            mov.u32   %r1, %tid.x
            shl.u32   %r2, %r1, 2
            mov.f32   %f0, 0.0
            st.shared.f32 [%r2+0], %f0
            st.shared.f32 [%r2+512], %f0
            bar.sync
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            mul.u32   %r9, %nctaid.x, %ntid.x
        LOOP:
            setp.ge.s32 %p1, %r3, %r14
            @%p1 bra  FLUSH
            shl.u32   %r4, %r3, 2
            add.u32   %r4, %r10, %r4
            ld.global.f32 %f1, [%r4+0]
            cvt.rzi.s32.f32 %r5, %f1
            shl.u32   %r5, %r5, 2
            mov.f32   %f2, 1.0
            red.shared.add.f32 [%r5+0], %f2
            add.u32   %r3, %r3, %r9
            bra       LOOP
        FLUSH:
            bar.sync
            ld.shared.f32 %f3, [%r2+0]
            add.u32   %r6, %r11, %r2
            red.global.add.f32 [%r6+0], %f3
            ld.shared.f32 %f4, [%r2+512]
            add.u32   %r7, %r6, 512
            red.global.add.f32 [%r7+0], %f4
            exit
        "#,
    )?;
    let mut rng = Prng::new(0x33);
    let data: Vec<f32> = (0..n).map(|_| rng.below(bins as u64) as f32).collect();
    let pdata = dev.alloc_bytes(n * 4);
    let pbins = dev.alloc_bytes(bins * 4);
    dev.write_f32(pdata, &data);
    let zero_bins = vec![0.0; bins];
    dev.write_f32(pbins, &zero_bins);
    let mut golden = vec![0f32; bins];
    for v in &data {
        golden[*v as usize] += 1.0;
    }
    Ok(Prepared {
        workload: Workload::Hist,
        kernel,
        launch: LaunchConfig::with_smem(32, 128, (bins * 4) as u32),
        params: vec![
            ParamValue::U32(pdata as u32),
            ParamValue::U32(pbins as u32),
            ParamValue::U32(n as u32),
        ],
        home: Some((pdata, 512)),
        out_addr: pbins,
        out_len: bins,
        golden,
        tol: 0.0,
        xla_inputs: vec![data],
        meta: vec![("n".into(), n as u32), ("bins".into(), bins as u32)],
    })
}

/// NW: Needleman–Wunsch global sequence alignment, anti-diagonal
/// wavefront with a block barrier between diagonals (match +1,
/// mismatch −1, gap −1). Single thread block — the long-dependency,
/// latency-bound workload of the suite (§VI-B: low bandwidth
/// utilization on both machines).
pub fn nw(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let n: usize = match scale {
        Scale::Tiny => 64,
        Scale::Small => 128,
    };
    let rs = n + 1; // row stride of the score matrix
    let kernel = KernelSource::assemble(
        "nw",
        &[Reg::r(10), Reg::r(11), Reg::r(12), Reg::r(13)],
        r#"
            mov.u32   %r1, %tid.x
            add.u32   %r14, %r13, 1           // row stride N+1
            mov.u32   %r2, 2                  // d = i+j
        DLOOP:
            shl.u32   %r3, %r13, 1
            setp.gt.s32 %p1, %r2, %r3
            @%p1 bra  END
            sub.s32   %r4, %r2, %r13
            max.s32   %r4, %r4, 1             // lo
            add.s32   %r5, %r2, -1
            min.s32   %r5, %r5, %r13          // hi
            add.u32   %r6, %r4, %r1           // i = lo + tid
            setp.gt.s32 %p2, %r6, %r5
            @%p2 bra  SYNC
            sub.u32   %r7, %r2, %r6           // j = d - i
            add.s32   %r8, %r6, -1
            shl.u32   %r8, %r8, 2
            add.u32   %r8, %r10, %r8
            ld.global.f32 %f1, [%r8+0]        // a[i-1]
            add.s32   %r9, %r7, -1
            shl.u32   %r9, %r9, 2
            add.u32   %r9, %r11, %r9
            ld.global.f32 %f2, [%r9+0]        // b[j-1]
            setp.eq.f32 %p3, %f1, %f2
            selp.f32  %f3, 1.0, -1.0, %p3     // match score
            add.s32   %r15, %r6, -1
            mul.u32   %r16, %r15, %r14
            add.s32   %r17, %r7, -1
            add.u32   %r18, %r16, %r17
            shl.u32   %r18, %r18, 2
            add.u32   %r18, %r12, %r18        // &F[i-1][j-1]
            ld.global.f32 %f4, [%r18+0]
            add.f32   %f4, %f4, %f3
            ld.global.f32 %f5, [%r18+4]       // F[i-1][j]
            add.f32   %f5, %f5, -1.0
            shl.u32   %r19, %r14, 2
            add.u32   %r20, %r18, %r19        // &F[i][j-1]
            ld.global.f32 %f6, [%r20+0]
            add.f32   %f6, %f6, -1.0
            max.f32   %f4, %f4, %f5
            max.f32   %f4, %f4, %f6
            st.global.f32 [%r20+4], %f4       // F[i][j]
        SYNC:
            bar.sync
            add.u32   %r2, %r2, 1
            bra       DLOOP
        END:
            exit
        "#,
    )?;
    let mut rng = Prng::new(0x44);
    // Sequences over a 4-letter alphabet, stored as small floats.
    let a: Vec<f32> = (0..n).map(|_| rng.below(4) as f32).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.below(4) as f32).collect();
    let pa = dev.alloc_bytes(n * 4);
    let pb = dev.alloc_bytes(n * 4);
    let pf = dev.alloc_bytes(rs * rs * 4);
    dev.write_f32(pa, &a);
    dev.write_f32(pb, &b);
    // Host initializes the borders (the CUDA host code does the same).
    let mut f0 = vec![0f32; rs * rs];
    for i in 0..rs {
        f0[i * rs] = -(i as f32);
        f0[i] = -(i as f32);
    }
    dev.write_f32(pf, &f0);
    let golden = nw_golden(&a, &b, n);
    Ok(Prepared {
        workload: Workload::Nw,
        kernel,
        launch: LaunchConfig::new(1, n as u32),
        params: vec![
            ParamValue::U32(pa as u32),
            ParamValue::U32(pb as u32),
            ParamValue::U32(pf as u32),
            ParamValue::U32(n as u32),
        ],
        home: None,
        out_addr: pf,
        out_len: rs * rs,
        golden,
        tol: 0.0,
        xla_inputs: vec![a, b],
        meta: vec![("n".into(), n as u32)],
    })
}

pub(crate) fn nw_golden(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let rs = n + 1;
    let mut f = vec![0f32; rs * rs];
    for i in 0..rs {
        f[i * rs] = -(i as f32);
        f[i] = -(i as f32);
    }
    for i in 1..=n {
        for j in 1..=n {
            let s = if a[i - 1] == b[j - 1] { 1.0 } else { -1.0 };
            let diag = f[(i - 1) * rs + (j - 1)] + s;
            let up = f[(i - 1) * rs + j] - 1.0;
            let left = f[i * rs + (j - 1)] - 1.0;
            f[i * rs + j] = diag.max(up).max(left);
        }
    }
    f
}
