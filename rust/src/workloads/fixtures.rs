//! Deliberately-misbehaving fixture kernels, one per `mpu lint`
//! diagnostic code.
//!
//! These are **not** part of the workload suite: each one exists to prove
//! a lint diagnostic live (the lint tests assert each fixture triggers
//! exactly its code) and, for the two error classes with dynamic
//! consequences, to demonstrate the misbehavior on the simulator:
//! the barrier-divergence fixture deadlocks under the reference run loop,
//! and the shared-memory race fixture produces a different output than
//! its barrier-fixed twin.

use crate::isa::{KernelSource, LaunchConfig, Reg};

/// A fixture kernel plus the launch/parameter context to lint it under.
pub struct Fixture {
    pub name: &'static str,
    /// The diagnostic code this fixture exists to trigger.
    pub expect_code: &'static str,
    pub kernel: KernelSource,
    pub launch: LaunchConfig,
    /// Parameter registers with placeholder concrete values for linting
    /// (tests running on a machine substitute real device addresses).
    pub params: Vec<(Reg, Option<i64>)>,
}

fn asm(name: &'static str, params: &[Reg], body: &str) -> KernelSource {
    KernelSource::assemble(name, params, body).expect("fixture kernel must assemble")
}

/// E001: `%f1` is stored to global memory but never assigned.
pub fn uninit_use() -> Fixture {
    let p = Reg::r(10);
    Fixture {
        name: "fix_uninit",
        expect_code: "E001",
        kernel: asm(
            "fix_uninit",
            &[p],
            "mov.u32 %r1, %tid.x\n\
             shl.u32 %r2, %r1, 2\n\
             add.u32 %r3, %r10, %r2\n\
             st.global.f32 [%r3+0], %f1\n\
             exit\n",
        ),
        launch: LaunchConfig::new(1, 32),
        params: vec![(p, Some(4096))],
    }
}

/// E002: a `bar.sync` only the lower warp reaches — the upper warp spins
/// on a shared flag that is set only *after* the barrier, so the block
/// deadlocks (the reference run loop hits `max_cycles`).
pub fn barrier_divergence() -> Fixture {
    Fixture {
        name: "fix_bar_div",
        expect_code: "E002",
        kernel: asm(
            "fix_bar_div",
            &[],
            "mov.u32 %r1, %tid.x\n\
             mov.u32 %r2, 0\n\
             setp.lt.s32 %p1, %r1, 32\n\
             @!%p1 bra SPIN\n\
             bar.sync\n\
             mov.u32 %r4, 1\n\
             red.shared.add.u32 [%r2+0], %r4\n\
             bra DONE\n\
             SPIN:\n\
             ld.shared.u32 %r3, [%r2+0]\n\
             setp.eq.s32 %p2, %r3, 0\n\
             @%p2 bra SPIN\n\
             DONE:\n\
             exit\n",
        ),
        launch: LaunchConfig::with_smem(1, 64, 64),
        params: vec![],
    }
}

fn smem_race_body(with_barrier: bool) -> String {
    // Every thread stores `t+2` into its own slot, then reads its right
    // neighbor's slot. The upper warp is delayed by a long uniform loop,
    // so without a barrier thread 31 reads slot 32 before warp 1 has
    // written it.
    format!(
        "mov.u32 %r1, %tid.x\n\
         shl.u32 %r2, %r1, 2\n\
         setp.lt.s32 %p1, %r1, 32\n\
         @%p1 bra STORE\n\
         mov.u32 %r5, 0\n\
         DELAY:\n\
         add.u32 %r5, %r5, 1\n\
         setp.lt.s32 %p2, %r5, 200\n\
         @%p2 bra DELAY\n\
         STORE:\n\
         add.u32 %r4, %r1, 2\n\
         cvt.f32.s32 %f1, %r4\n\
         st.shared.f32 [%r2+0], %f1\n\
         {}\
         ld.shared.f32 %f2, [%r2+4]\n\
         add.u32 %r3, %r10, %r2\n\
         st.global.f32 [%r3+0], %f2\n\
         exit\n",
        if with_barrier { "bar.sync\n" } else { "" }
    )
}

/// E003: store to `smem[4t]`, read `smem[4t+4]` with no barrier between
/// — thread `t` races with thread `t+1` across the warp boundary.
pub fn smem_race() -> Fixture {
    let p = Reg::r(10);
    Fixture {
        name: "fix_smem_race",
        expect_code: "E003",
        kernel: asm("fix_smem_race", &[p], &smem_race_body(false)),
        launch: LaunchConfig::with_smem(1, 64, 260),
        params: vec![(p, Some(4096))],
    }
}

/// The barrier-fixed twin of [`smem_race`] — lints clean and gives the
/// deterministic output the race test compares against.
pub fn smem_race_fixed() -> Fixture {
    let p = Reg::r(10);
    Fixture {
        name: "fix_smem_race_fixed",
        expect_code: "",
        kernel: asm("fix_smem_race_fixed", &[p], &smem_race_body(true)),
        launch: LaunchConfig::with_smem(1, 64, 260),
        params: vec![(p, Some(4096))],
    }
}

/// W004: shared accesses with a 128-byte lane stride — all 32 lanes hit
/// bank 0 (predicted and observed 32-way conflict).
pub fn bank_conflict() -> Fixture {
    let p = Reg::r(10);
    Fixture {
        name: "fix_bank_conflict",
        expect_code: "W004",
        kernel: asm(
            "fix_bank_conflict",
            &[p],
            "mov.u32 %r1, %tid.x\n\
             shl.u32 %r2, %r1, 7\n\
             cvt.f32.s32 %f1, %r1\n\
             st.shared.f32 [%r2+0], %f1\n\
             bar.sync\n\
             ld.shared.f32 %f2, [%r2+0]\n\
             shl.u32 %r4, %r1, 2\n\
             add.u32 %r3, %r10, %r4\n\
             st.global.f32 [%r3+0], %f2\n\
             exit\n",
        ),
        launch: LaunchConfig::with_smem(1, 32, 4096),
        params: vec![(p, Some(4096))],
    }
}

/// I005: a tid-dependent branch (and nothing else of note).
pub fn divergent_branch() -> Fixture {
    Fixture {
        name: "fix_div_branch",
        expect_code: "I005",
        kernel: asm(
            "fix_div_branch",
            &[],
            "mov.u32 %r1, %tid.x\n\
             setp.lt.s32 %p1, %r1, 7\n\
             @%p1 bra SKIP\n\
             mov.u32 %r2, 1\n\
             SKIP:\n\
             exit\n",
        ),
        launch: LaunchConfig::new(1, 32),
        params: vec![],
    }
}

/// I006: a strided global load (8-byte lane stride) next to a coalesced
/// store.
pub fn strided_global() -> Fixture {
    let (pin, pout) = (Reg::r(10), Reg::r(11));
    Fixture {
        name: "fix_strided",
        expect_code: "I006",
        kernel: asm(
            "fix_strided",
            &[pin, pout],
            "mov.u32 %r1, %tid.x\n\
             shl.u32 %r2, %r1, 3\n\
             add.u32 %r3, %r10, %r2\n\
             ld.global.f32 %f1, [%r3+0]\n\
             shl.u32 %r4, %r1, 2\n\
             add.u32 %r5, %r11, %r4\n\
             st.global.f32 [%r5+0], %f1\n\
             exit\n",
        ),
        launch: LaunchConfig::new(1, 32),
        params: vec![(pin, Some(4096)), (pout, Some(8192))],
    }
}

/// I007: conflict-free per-thread shared slots with a proper barrier.
pub fn smem_clean() -> Fixture {
    let p = Reg::r(10);
    Fixture {
        name: "fix_smem_clean",
        expect_code: "I007",
        kernel: asm(
            "fix_smem_clean",
            &[p],
            "mov.u32 %r1, %tid.x\n\
             shl.u32 %r2, %r1, 2\n\
             cvt.f32.s32 %f1, %r1\n\
             st.shared.f32 [%r2+0], %f1\n\
             bar.sync\n\
             ld.shared.f32 %f2, [%r2+0]\n\
             add.u32 %r3, %r10, %r2\n\
             st.global.f32 [%r3+0], %f2\n\
             exit\n",
        ),
        launch: LaunchConfig::with_smem(1, 32, 128),
        params: vec![(p, Some(4096))],
    }
}

/// All diagnostic fixtures, one per code (the fixed race twin excluded).
pub fn fixtures() -> Vec<Fixture> {
    vec![
        uninit_use(),
        barrier_divergence(),
        smem_race(),
        bank_conflict(),
        divergent_branch(),
        strided_global(),
        smem_clean(),
    ]
}
