//! The benchmark suite (Table I): twelve data-intensive CUDA workloads
//! re-authored in the mini-PTX ISA, with deterministic input generators,
//! pure-Rust golden models, and block→core home hints for the runtime's
//! data-local dispatch (§V-A).
//!
//! | Workload | Domain | Description |
//! |---|---|---|
//! | BLUR | Image Processing | 3×3 blur |
//! | CONV | Machine Learning | 3×3 convolution |
//! | GEMV | Linear Algebra | matrix–vector multiply |
//! | HIST | Image Processing | 256-bin histogram |
//! | KMEANS | Machine Learning | k-means assignment step |
//! | KNN | Machine Learning | k-NN distance kernel |
//! | TTRANS | Linear Algebra | tensor transposition |
//! | MAXP | Machine Learning | 2×2 max-pooling |
//! | NW | Bioinformatics | Needleman–Wunsch alignment |
//! | UPSAMP | Image Processing | 2× nearest upsample |
//! | AXPY | Linear Algebra | vector a·x+y |
//! | PR | Linear Algebra | parallel reduction |

pub mod linalg;
pub mod stencil;
pub mod ml;
pub mod misc;
pub mod fixtures;

use crate::isa::program::ParamValue;
use crate::isa::{KernelSource, LaunchConfig};

/// Device-memory interface the workload builders target — implemented by
/// both the MPU [`crate::core::Machine`] and the GPU baseline
/// [`crate::gpu::GpuMachine`], so the *same prepared problem* runs on
/// both (the Fig. 8 comparison).
pub trait Device {
    fn alloc_bytes(&mut self, bytes: usize) -> u64;
    fn write_f32(&mut self, addr: u64, data: &[f32]);
}

impl Device for crate::core::Machine {
    fn alloc_bytes(&mut self, bytes: usize) -> u64 {
        self.alloc(bytes)
    }
    fn write_f32(&mut self, addr: u64, data: &[f32]) {
        self.write_f32s(addr, data);
    }
}

impl Device for crate::gpu::GpuMachine {
    fn alloc_bytes(&mut self, bytes: usize) -> u64 {
        self.alloc(bytes)
    }
    fn write_f32(&mut self, addr: u64, data: &[f32]) {
        self.write_f32s(addr, data);
    }
}

impl Device for crate::gpu::IdealMachine {
    fn alloc_bytes(&mut self, bytes: usize) -> u64 {
        self.alloc(bytes)
    }
    fn write_f32(&mut self, addr: u64, data: &[f32]) {
        self.write_f32s(addr, data);
    }
}

/// A [`Device`] that only tracks allocation sizes — enough to build a
/// workload's kernel text and host-side inputs (goldens, XLA inputs)
/// without instantiating a machine.
#[derive(Debug, Default)]
pub struct SizeOnlyDev {
    top: u64,
}

impl Device for SizeOnlyDev {
    fn alloc_bytes(&mut self, bytes: usize) -> u64 {
        let a = self.top;
        self.top += bytes as u64;
        a
    }
    fn write_f32(&mut self, _addr: u64, _data: &[f32]) {}
}

/// The Table-I workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Workload {
    Blur,
    Conv,
    Gemv,
    Hist,
    Kmeans,
    Knn,
    Ttrans,
    Maxp,
    Nw,
    Upsamp,
    Axpy,
    Pr,
}

impl Workload {
    pub const ALL: [Workload; 12] = [
        Workload::Blur,
        Workload::Conv,
        Workload::Gemv,
        Workload::Hist,
        Workload::Kmeans,
        Workload::Knn,
        Workload::Ttrans,
        Workload::Maxp,
        Workload::Nw,
        Workload::Upsamp,
        Workload::Axpy,
        Workload::Pr,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Workload::Blur => "blur",
            Workload::Conv => "conv",
            Workload::Gemv => "gemv",
            Workload::Hist => "hist",
            Workload::Kmeans => "kmeans",
            Workload::Knn => "knn",
            Workload::Ttrans => "ttrans",
            Workload::Maxp => "maxp",
            Workload::Nw => "nw",
            Workload::Upsamp => "upsamp",
            Workload::Axpy => "axpy",
            Workload::Pr => "pr",
        }
    }

    pub fn from_name(s: &str) -> Option<Workload> {
        Workload::ALL.iter().copied().find(|w| w.name() == s)
    }

    /// Does the kernel use shared memory (relevant set for Fig. 11)?
    pub fn uses_smem(&self) -> bool {
        matches!(
            self,
            Workload::Pr | Workload::Gemv | Workload::Hist | Workload::Kmeans | Workload::Conv
        )
    }
}

/// Problem-size scale for the suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scale {
    /// Quick: used by unit/integration tests.
    Tiny,
    /// Default: used by the benches (DESIGN.md §3 scaled machine).
    Small,
}

impl Scale {
    pub const ALL: [Scale; 2] = [Scale::Tiny, Scale::Small];

    /// Stable lower-case name (part of the `BENCH_suite.json` schema and
    /// the sweep-service protocol/store keys).
    pub fn name(&self) -> &'static str {
        match self {
            Scale::Tiny => "tiny",
            Scale::Small => "small",
        }
    }

    pub fn from_name(s: &str) -> Option<Scale> {
        Scale::ALL.iter().copied().find(|x| x.name() == s)
    }
}

/// A prepared problem: kernel + launch + device state + golden output.
pub struct Prepared {
    pub workload: Workload,
    pub kernel: KernelSource,
    pub launch: LaunchConfig,
    pub params: Vec<ParamValue>,
    /// Block → home-address dispatch hint: `Some((base, stride))` means
    /// block `b` homes at `base + b·stride`.
    pub home: Option<(u64, u64)>,
    /// Output array (device address, f32 count).
    pub out_addr: u64,
    pub out_len: usize,
    /// Pure-Rust golden output.
    pub golden: Vec<f32>,
    /// Comparison tolerance (absolute) vs the golden.
    pub tol: f32,
    /// Input arrays in the order the AOT'd XLA golden expects them.
    pub xla_inputs: Vec<Vec<f32>>,
    /// Static scalar metadata for the XLA golden (shapes etc.), recorded
    /// for documentation; the HLO is specialized to these.
    pub meta: Vec<(String, u32)>,
}

impl Prepared {
    /// The home-dispatch closure for [`crate::core::Machine::launch`].
    pub fn home_fn(&self) -> impl Fn(u32) -> Option<u64> + '_ {
        let home = self.home;
        move |b| home.map(|(base, stride)| base + b as u64 * stride)
    }
}

/// Build a prepared problem on a device.
pub fn prepare(w: Workload, scale: Scale, dev: &mut dyn Device) -> anyhow::Result<Prepared> {
    match w {
        Workload::Axpy => linalg::axpy(scale, dev),
        Workload::Pr => linalg::pr(scale, dev),
        Workload::Gemv => linalg::gemv(scale, dev),
        Workload::Ttrans => linalg::ttrans(scale, dev),
        Workload::Blur => stencil::blur(scale, dev),
        Workload::Conv => stencil::conv(scale, dev),
        Workload::Maxp => stencil::maxp(scale, dev),
        Workload::Upsamp => stencil::upsamp(scale, dev),
        Workload::Kmeans => ml::kmeans(scale, dev),
        Workload::Knn => ml::knn(scale, dev),
        Workload::Hist => misc::hist(scale, dev),
        Workload::Nw => misc::nw(scale, dev),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for w in Workload::ALL {
            assert_eq!(Workload::from_name(w.name()), Some(w));
        }
        assert_eq!(Workload::from_name("nope"), None);
    }

    #[test]
    fn twelve_workloads_match_table1() {
        assert_eq!(Workload::ALL.len(), 12);
    }

    struct FakeDev {
        top: u64,
    }
    impl Device for FakeDev {
        fn alloc_bytes(&mut self, bytes: usize) -> u64 {
            let a = self.top;
            self.top += bytes as u64;
            a
        }
        fn write_f32(&mut self, _addr: u64, _data: &[f32]) {}
    }

    #[test]
    fn all_kernels_assemble_and_compile() {
        for w in Workload::ALL {
            let mut dev = FakeDev { top: 0 };
            let p = prepare(w, Scale::Tiny, &mut dev).unwrap_or_else(|e| panic!("{w:?}: {e}"));
            let k = crate::compiler::compile(&p.kernel).unwrap_or_else(|e| panic!("{w:?}: {e}"));
            assert!(!k.instrs.is_empty());
            assert_eq!(p.params.len(), p.kernel.params.len(), "{w:?} param count");
            assert_eq!(p.golden.len(), p.out_len, "{w:?} golden length");
        }
    }
}
