//! Machine-learning workloads (Table I): KMEANS (assignment step) and
//! KNN (Rodinia `nn` distance kernel).

use super::{Device, Prepared, Scale, Workload};
use crate::isa::program::ParamValue;
use crate::isa::{KernelSource, LaunchConfig, Reg};
use crate::sim::Prng;
use anyhow::Result;

/// KMEANS (Rodinia): the assignment step — for each point, the index of
/// the nearest centroid (squared Euclidean distance, D=4). Points are
/// stored column-major (one array per dimension) for coalescing;
/// centroids are staged in shared memory per block.
pub fn kmeans(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let d = 4usize;
    let k = 8usize;
    let n: usize = match scale {
        Scale::Tiny => 4096,
        Scale::Small => 16384,
    };
    let kernel = KernelSource::assemble(
        "kmeans",
        &[Reg::r(10), Reg::r(11), Reg::r(12), Reg::r(13), Reg::r(14), Reg::r(15)],
        r#"
            mov.u32   %r1, %tid.x
            setp.ge.s32 %p1, %r1, %r15        // KD
            @%p1 bra  CDONE
            shl.u32   %r2, %r1, 2
            add.u32   %r3, %r11, %r2
            ld.global.f32 %f1, [%r3+0]
            st.shared.f32 [%r2+0], %f1
        CDONE:
            bar.sync
            mad.u32   %r4, %ctaid.x, %ntid.x, %r1   // i
            mul.u32   %r20, %nctaid.x, %ntid.x      // grid stride
        ILOOP:
            setp.ge.s32 %p2, %r4, %r13              // N
            @%p2 bra  DONE
            shl.u32   %r5, %r4, 2
            add.u32   %r6, %r10, %r5
            shl.u32   %r7, %r13, 2                  // 4N dim stride
            ld.global.f32 %f10, [%r6+0]
            add.u32   %r6, %r6, %r7
            ld.global.f32 %f11, [%r6+0]
            add.u32   %r6, %r6, %r7
            ld.global.f32 %f12, [%r6+0]
            add.u32   %r6, %r6, %r7
            ld.global.f32 %f13, [%r6+0]
            mov.f32   %f20, 1e30
            mov.u32   %r8, 0
            mov.u32   %r9, 0
        KLOOP:
            setp.ge.s32 %p3, %r9, %r14              // K
            @%p3 bra  WRITE
            shl.u32   %r22, %r9, 4                  // k·D·4 (dedicated smem-index reg:
                                                    // sharing it with an address chain would make it B)
            ld.shared.f32 %f1, [%r22+0]
            sub.f32   %f1, %f1, %f10
            mul.f32   %f2, %f1, %f1
            ld.shared.f32 %f1, [%r22+4]
            sub.f32   %f1, %f1, %f11
            mad.f32   %f2, %f1, %f1, %f2
            ld.shared.f32 %f1, [%r22+8]
            sub.f32   %f1, %f1, %f12
            mad.f32   %f2, %f1, %f1, %f2
            ld.shared.f32 %f1, [%r22+12]
            sub.f32   %f1, %f1, %f13
            mad.f32   %f2, %f1, %f1, %f2
            setp.lt.f32 %p4, %f2, %f20
            @%p4 mov.f32 %f20, %f2
            @%p4 mov.u32 %r8, %r9
            add.u32   %r9, %r9, 1
            bra       KLOOP
        WRITE:
            cvt.f32.s32 %f3, %r8
            add.u32   %r21, %r12, %r5
            st.global.f32 [%r21+0], %f3
            add.u32   %r4, %r4, %r20
            bra       ILOOP
        DONE:
            exit
        "#,
    )?;
    let mut rng = Prng::new(0x11);
    let points = rng.f32_vec(n * d, -2.0, 2.0); // [d][n] column-major
    let cents = rng.f32_vec(k * d, -2.0, 2.0); // [k][d] row-major
    let pp = dev.alloc_bytes(n * d * 4);
    let pc = dev.alloc_bytes(k * d * 4);
    let pa = dev.alloc_bytes(n * 4);
    dev.write_f32(pp, &points);
    dev.write_f32(pc, &cents);
    let mut golden = vec![0f32; n];
    for i in 0..n {
        let mut best = f32::INFINITY;
        let mut arg = 0usize;
        for kk in 0..k {
            let mut dist = 0f32;
            for dd in 0..d {
                let diff = cents[kk * d + dd] - points[dd * n + i];
                dist = diff.mul_add(diff, dist);
            }
            if dist < best {
                best = dist;
                arg = kk;
            }
        }
        golden[i] = arg as f32;
    }
    Ok(Prepared {
        workload: Workload::Kmeans,
        kernel,
        // Grid-stride: 4096 threads sweep all N points (total-thread
        // footprint = one full bank sweep, keeping iterations home).
        launch: LaunchConfig::with_smem(32, 128, (k * d * 4) as u32),
        params: vec![
            ParamValue::U32(pp as u32),
            ParamValue::U32(pc as u32),
            ParamValue::U32(pa as u32),
            ParamValue::U32(n as u32),
            ParamValue::U32(k as u32),
            ParamValue::U32((k * d) as u32),
        ],
        home: Some((pp, 512)),
        out_addr: pa,
        out_len: n,
        golden,
        tol: 0.0,
        xla_inputs: vec![points, cents],
        meta: vec![("n".into(), n as u32), ("k".into(), k as u32), ("d".into(), d as u32)],
    })
}

/// KNN (Rodinia `nn`): Euclidean distance from every record to a query
/// point — the host then selects the k nearest.
pub fn knn(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let n: usize = match scale {
        Scale::Tiny => 4096,
        Scale::Small => 32768,
    };
    let kernel = KernelSource::assemble(
        "knn",
        &[Reg::r(10), Reg::r(11), Reg::r(12), Reg::f(10), Reg::f(11), Reg::r(13)],
        r#"
            mov.u32   %r1, %tid.x
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            setp.ge.s32 %p1, %r3, %r13
            @%p1 bra  DONE
            shl.u32   %r4, %r3, 2
            add.u32   %r5, %r10, %r4
            ld.global.f32 %f1, [%r5+0]
            add.u32   %r6, %r11, %r4
            ld.global.f32 %f2, [%r6+0]
            sub.f32   %f1, %f1, %f10
            sub.f32   %f2, %f2, %f11
            mul.f32   %f3, %f1, %f1
            mad.f32   %f3, %f2, %f2, %f3
            sqrt.f32  %f3, %f3
            add.u32   %r7, %r12, %r4
            st.global.f32 [%r7+0], %f3
        DONE:
            exit
        "#,
    )?;
    let mut rng = Prng::new(0x22);
    let lat = rng.f32_vec(n, 0.0, 90.0);
    let lng = rng.f32_vec(n, 0.0, 180.0);
    let (qlat, qlng) = (45.0f32, 90.0f32);
    let plat = dev.alloc_bytes(n * 4);
    let plng = dev.alloc_bytes(n * 4);
    let pout = dev.alloc_bytes(n * 4);
    dev.write_f32(plat, &lat);
    dev.write_f32(plng, &lng);
    let golden: Vec<f32> = lat
        .iter()
        .zip(&lng)
        .map(|(a, b)| ((a - qlat) * (a - qlat) + (b - qlng) * (b - qlng)).sqrt())
        .collect();
    Ok(Prepared {
        workload: Workload::Knn,
        kernel,
        launch: LaunchConfig::new((n / 128) as u32, 128),
        params: vec![
            ParamValue::U32(plat as u32),
            ParamValue::U32(plng as u32),
            ParamValue::U32(pout as u32),
            ParamValue::F32(qlat),
            ParamValue::F32(qlng),
            ParamValue::U32(n as u32),
        ],
        home: Some((plat, 512)),
        out_addr: pout,
        out_len: n,
        golden,
        tol: 1e-4,
        xla_inputs: vec![lat, lng],
        meta: vec![("n".into(), n as u32)],
    })
}
