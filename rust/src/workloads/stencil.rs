//! Image-processing / stencil workloads (Table I): BLUR, CONV, MAXP,
//! UPSAMP.
//!
//! Image rows are sized so `W·4` bytes equal a whole bank sweep
//! (`total_banks × interleave = 16 KiB` on the scaled machine): a pixel's
//! vertical neighbours then live on the same core, which is exactly the
//! data placement a near-bank mapping wants (DESIGN.md §3).

use super::{Device, Prepared, Scale, Workload};
use crate::isa::program::ParamValue;
use crate::isa::{KernelSource, LaunchConfig, Reg};
use crate::sim::Prng;
use anyhow::Result;

fn img_dims(scale: Scale, w: usize) -> (usize, usize) {
    match scale {
        Scale::Tiny => (w, 4),
        Scale::Small => (w, 16),
    }
}

/// BLUR (Halide 3×3 blur): clamped-edge 3×3 box filter.
pub fn blur(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let (w, h) = img_dims(scale, 4096);
    let n = w * h;
    let kernel = KernelSource::assemble(
        "blur",
        &[Reg::r(10), Reg::r(11), Reg::r(12), Reg::r(13), Reg::r(14)],
        r#"
            mov.u32   %r1, %tid.x
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            setp.ge.s32 %p1, %r3, %r14
            @%p1 bra  DONE
            div.u32   %r4, %r3, %r12          // y
            rem.u32   %r5, %r3, %r12          // x
            add.s32   %r6, %r4, -1
            max.s32   %r6, %r6, 0             // ym
            add.s32   %r7, %r4, 1
            add.s32   %r2, %r13, -1
            min.s32   %r7, %r7, %r2           // yp
            add.s32   %r8, %r5, -1
            max.s32   %r8, %r8, 0             // xm
            add.s32   %r9, %r5, 1
            add.s32   %r2, %r12, -1
            min.s32   %r9, %r9, %r2           // xp
            mul.u32   %r16, %r6, %r12         // ym*W
            mul.u32   %r17, %r4, %r12         // y*W
            mul.u32   %r18, %r7, %r12         // yp*W
            mov.f32   %f1, 0.0
            // row ym
            add.u32   %r19, %r16, %r8
            shl.u32   %r19, %r19, 2
            add.u32   %r19, %r10, %r19
            ld.global.f32 %f2, [%r19+0]
            add.f32   %f1, %f1, %f2
            add.u32   %r19, %r16, %r5
            shl.u32   %r19, %r19, 2
            add.u32   %r19, %r10, %r19
            ld.global.f32 %f2, [%r19+0]
            add.f32   %f1, %f1, %f2
            add.u32   %r19, %r16, %r9
            shl.u32   %r19, %r19, 2
            add.u32   %r19, %r10, %r19
            ld.global.f32 %f2, [%r19+0]
            add.f32   %f1, %f1, %f2
            // row y
            add.u32   %r19, %r17, %r8
            shl.u32   %r19, %r19, 2
            add.u32   %r19, %r10, %r19
            ld.global.f32 %f2, [%r19+0]
            add.f32   %f1, %f1, %f2
            add.u32   %r19, %r17, %r5
            shl.u32   %r19, %r19, 2
            add.u32   %r19, %r10, %r19
            ld.global.f32 %f2, [%r19+0]
            add.f32   %f1, %f1, %f2
            add.u32   %r19, %r17, %r9
            shl.u32   %r19, %r19, 2
            add.u32   %r19, %r10, %r19
            ld.global.f32 %f2, [%r19+0]
            add.f32   %f1, %f1, %f2
            // row yp
            add.u32   %r19, %r18, %r8
            shl.u32   %r19, %r19, 2
            add.u32   %r19, %r10, %r19
            ld.global.f32 %f2, [%r19+0]
            add.f32   %f1, %f1, %f2
            add.u32   %r19, %r18, %r5
            shl.u32   %r19, %r19, 2
            add.u32   %r19, %r10, %r19
            ld.global.f32 %f2, [%r19+0]
            add.f32   %f1, %f1, %f2
            add.u32   %r19, %r18, %r9
            shl.u32   %r19, %r19, 2
            add.u32   %r19, %r10, %r19
            ld.global.f32 %f2, [%r19+0]
            add.f32   %f1, %f1, %f2
            mul.f32   %f1, %f1, 0.111111112
            shl.u32   %r20, %r3, 2
            add.u32   %r20, %r11, %r20
            st.global.f32 [%r20+0], %f1
        DONE:
            exit
        "#,
    )?;
    let mut rng = Prng::new(0xE5);
    let img = rng.f32_vec(n, 0.0, 1.0);
    let pin = dev.alloc_bytes(n * 4);
    let pout = dev.alloc_bytes(n * 4);
    dev.write_f32(pin, &img);
    let golden = blur_golden(&img, w, h);
    Ok(Prepared {
        workload: Workload::Blur,
        kernel,
        launch: LaunchConfig::new((n / 128) as u32, 128),
        params: vec![
            ParamValue::U32(pin as u32),
            ParamValue::U32(pout as u32),
            ParamValue::U32(w as u32),
            ParamValue::U32(h as u32),
            ParamValue::U32(n as u32),
        ],
        home: Some((pin, 512)),
        out_addr: pout,
        out_len: n,
        golden,
        tol: 1e-5,
        xla_inputs: vec![img],
        meta: vec![("w".into(), w as u32), ("h".into(), h as u32)],
    })
}

pub(crate) fn blur_golden(img: &[f32], w: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0f32; w * h];
    for y in 0..h {
        for x in 0..w {
            let mut s = 0f32;
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    let yy = (y as i64 + dy).clamp(0, h as i64 - 1) as usize;
                    let xx = (x as i64 + dx).clamp(0, w as i64 - 1) as usize;
                    s += img[yy * w + xx];
                }
            }
            out[y * w + x] = s * 0.111111112;
        }
    }
    out
}

/// CONV (TensorFlow-style 3×3 convolution, single channel, clamped
/// edges): the nine weights are staged in shared memory per block.
pub fn conv(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let (w, h) = img_dims(scale, 4096);
    let n = w * h;
    // The nine-tap body is long and repetitive; build it
    // programmatically to keep the taps consistent.
    let mut body = String::new();
    body.push_str(
        r#"
            mov.u32   %r1, %tid.x
            setp.ge.s32 %p2, %r1, 9
            @%p2 bra  WDONE
            shl.u32   %r2, %r1, 2
            add.u32   %r19, %r15, %r2
            ld.global.f32 %f9, [%r19+0]
            st.shared.f32 [%r2+0], %f9
        WDONE:
            bar.sync
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            setp.ge.s32 %p1, %r3, %r14
            @%p1 bra  DONE
            div.u32   %r4, %r3, %r12
            rem.u32   %r5, %r3, %r12
            add.s32   %r6, %r4, -1
            max.s32   %r6, %r6, 0
            add.s32   %r7, %r4, 1
            add.s32   %r2, %r13, -1
            min.s32   %r7, %r7, %r2
            add.s32   %r8, %r5, -1
            max.s32   %r8, %r8, 0
            add.s32   %r9, %r5, 1
            add.s32   %r2, %r12, -1
            min.s32   %r9, %r9, %r2
            mul.u32   %r16, %r6, %r12
            mul.u32   %r17, %r4, %r12
            mul.u32   %r18, %r7, %r12
            mov.f32   %f1, 0.0
"#,
    );
    for (ri, row) in ["%r16", "%r17", "%r18"].iter().enumerate() {
        for (ci, col) in ["%r8", "%r5", "%r9"].iter().enumerate() {
            let widx = ri * 3 + ci;
            body.push_str(&format!(
                "            add.u32 %r19, {row}, {col}\n\
                             shl.u32 %r19, %r19, 2\n\
                             add.u32 %r19, %r10, %r19\n\
                             ld.global.f32 %f2, [%r19+0]\n\
                             ld.shared.f32 %f3, [%r21+{off}]\n\
                             mad.f32 %f1, %f2, %f3, %f1\n",
                off = widx * 4,
            ));
        }
    }
    body.push_str(
        r#"
            shl.u32   %r20, %r3, 2
            add.u32   %r20, %r11, %r20
            st.global.f32 [%r20+0], %f1
        DONE:
            exit
        "#,
    );
    // %r21 is a zero base register for the shared-memory weight reads.
    let body = format!("            mov.u32 %r21, 0\n{body}");
    let kernel = KernelSource::assemble(
        "conv",
        &[Reg::r(10), Reg::r(11), Reg::r(12), Reg::r(13), Reg::r(14), Reg::r(15)],
        &body,
    )?;

    let mut rng = Prng::new(0xF6);
    let img = rng.f32_vec(n, 0.0, 1.0);
    let wts = rng.f32_vec(9, -0.5, 0.5);
    let pin = dev.alloc_bytes(n * 4);
    let pout = dev.alloc_bytes(n * 4);
    let pw = dev.alloc_bytes(9 * 4);
    dev.write_f32(pin, &img);
    dev.write_f32(pw, &wts);
    let golden = conv_golden(&img, &wts, w, h);
    Ok(Prepared {
        workload: Workload::Conv,
        kernel,
        launch: LaunchConfig::with_smem((n / 128) as u32, 128, 9 * 4),
        params: vec![
            ParamValue::U32(pin as u32),
            ParamValue::U32(pout as u32),
            ParamValue::U32(w as u32),
            ParamValue::U32(h as u32),
            ParamValue::U32(n as u32),
            ParamValue::U32(pw as u32),
        ],
        home: Some((pin, 512)),
        out_addr: pout,
        out_len: n,
        golden,
        tol: 1e-4,
        xla_inputs: vec![img, wts],
        meta: vec![("w".into(), w as u32), ("h".into(), h as u32)],
    })
}

pub(crate) fn conv_golden(img: &[f32], wts: &[f32], w: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0f32; w * h];
    for y in 0..h {
        for x in 0..w {
            let mut s = 0f32;
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    let yy = (y as i64 + dy).clamp(0, h as i64 - 1) as usize;
                    let xx = (x as i64 + dx).clamp(0, w as i64 - 1) as usize;
                    let widx = ((dy + 1) * 3 + (dx + 1)) as usize;
                    s = img[yy * w + xx].mul_add(wts[widx], s);
                }
            }
            out[y * w + x] = s;
        }
    }
    out
}

/// MAXP (TensorFlow 2×2 max-pooling, stride 2).
pub fn maxp(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let (w, h) = img_dims(scale, 4096);
    let (ow, oh) = (w / 2, h / 2);
    let n_out = ow * oh;
    let kernel = KernelSource::assemble(
        "maxp",
        &[Reg::r(10), Reg::r(11), Reg::r(12), Reg::r(13), Reg::r(14)],
        r#"
            mov.u32   %r1, %tid.x
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            setp.ge.s32 %p1, %r3, %r14
            @%p1 bra  DONE
            div.u32   %r4, %r3, %r12          // oy
            rem.u32   %r5, %r3, %r12          // ox
            shl.u32   %r6, %r4, 1             // 2*oy
            shl.u32   %r7, %r5, 1             // 2*ox
            mad.u32   %r8, %r6, %r13, %r7     // 2oy*W + 2ox
            shl.u32   %r8, %r8, 2
            add.u32   %r8, %r10, %r8
            shl.u32   %r9, %r13, 2            // 4*W
            ld.global.f32 %f1, [%r8+0]
            ld.global.f32 %f2, [%r8+4]
            max.f32   %f1, %f1, %f2
            add.u32   %r8, %r8, %r9
            ld.global.f32 %f2, [%r8+0]
            max.f32   %f1, %f1, %f2
            ld.global.f32 %f2, [%r8+4]
            max.f32   %f1, %f1, %f2
            shl.u32   %r2, %r3, 2
            add.u32   %r2, %r11, %r2
            st.global.f32 [%r2+0], %f1
        DONE:
            exit
        "#,
    )?;
    let n_in = w * h;
    let mut rng = Prng::new(0xA7);
    let img = rng.f32_vec(n_in, -1.0, 1.0);
    let pin = dev.alloc_bytes(n_in * 4);
    let pout = dev.alloc_bytes(n_out * 4);
    dev.write_f32(pin, &img);
    let mut golden = vec![0f32; n_out];
    for oy in 0..oh {
        for ox in 0..ow {
            let b = 2 * oy * w + 2 * ox;
            golden[oy * ow + ox] = img[b].max(img[b + 1]).max(img[b + w]).max(img[b + w + 1]);
        }
    }
    Ok(Prepared {
        workload: Workload::Maxp,
        kernel,
        launch: LaunchConfig::new((n_out / 128) as u32, 128),
        params: vec![
            ParamValue::U32(pin as u32),
            ParamValue::U32(pout as u32),
            ParamValue::U32(ow as u32),
            ParamValue::U32(w as u32),
            ParamValue::U32(n_out as u32),
        ],
        home: Some((pin, 1024)),
        out_addr: pout,
        out_len: n_out,
        golden,
        tol: 0.0,
        xla_inputs: vec![img],
        meta: vec![("w".into(), w as u32), ("h".into(), h as u32)],
    })
}

/// UPSAMP (Halide 2× nearest-neighbour upsample).
pub fn upsamp(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let (w, h) = img_dims(scale, 2048);
    let (ow, oh) = (w * 2, h * 2);
    let n_out = ow * oh;
    let kernel = KernelSource::assemble(
        "upsamp",
        &[Reg::r(10), Reg::r(11), Reg::r(12), Reg::r(13), Reg::r(14)],
        r#"
            mov.u32   %r1, %tid.x
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            setp.ge.s32 %p1, %r3, %r14
            @%p1 bra  DONE
            div.u32   %r4, %r3, %r12          // oy
            rem.u32   %r5, %r3, %r12          // ox
            shr.u32   %r6, %r4, 1             // oy/2
            shr.u32   %r7, %r5, 1             // ox/2
            mad.u32   %r8, %r6, %r13, %r7
            shl.u32   %r8, %r8, 2
            add.u32   %r8, %r10, %r8
            ld.global.f32 %f1, [%r8+0]
            shl.u32   %r2, %r3, 2
            add.u32   %r2, %r11, %r2
            st.global.f32 [%r2+0], %f1
        DONE:
            exit
        "#,
    )?;
    let n_in = w * h;
    let mut rng = Prng::new(0xB8);
    let img = rng.f32_vec(n_in, 0.0, 1.0);
    let pin = dev.alloc_bytes(n_in * 4);
    let pout = dev.alloc_bytes(n_out * 4);
    dev.write_f32(pin, &img);
    let mut golden = vec![0f32; n_out];
    for oy in 0..oh {
        for ox in 0..ow {
            golden[oy * ow + ox] = img[(oy / 2) * w + ox / 2];
        }
    }
    Ok(Prepared {
        workload: Workload::Upsamp,
        kernel,
        launch: LaunchConfig::new((n_out / 128) as u32, 128),
        params: vec![
            ParamValue::U32(pin as u32),
            ParamValue::U32(pout as u32),
            ParamValue::U32(ow as u32),
            ParamValue::U32(w as u32),
            ParamValue::U32(n_out as u32),
        ],
        home: Some((pout, 512)),
        out_addr: pout,
        out_len: n_out,
        golden,
        tol: 0.0,
        xla_inputs: vec![img],
        meta: vec![("w".into(), w as u32), ("h".into(), h as u32)],
    })
}
