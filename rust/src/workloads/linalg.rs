//! Linear-algebra workloads (Table I): AXPY, PR (parallel reduction),
//! GEMV, TTRANS.

use super::{Device, Prepared, Scale, Workload};
use crate::isa::program::ParamValue;
use crate::isa::{KernelSource, LaunchConfig, Reg};
use crate::sim::Prng;
use anyhow::Result;

/// AXPY (cuBLAS `saxpy`): `y[i] = α·x[i] + y[i]`, grid-stride loop — the
/// paper's Listing-1 shape.
pub fn axpy(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let n: usize = match scale {
        Scale::Tiny => 4096,
        Scale::Small => 65536,
    };
    let kernel = KernelSource::assemble(
        "axpy",
        &[Reg::r(10), Reg::r(11), Reg::f(10), Reg::r(12)],
        r#"
            mov.u32   %r1, %tid.x
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            mul.u32   %r9, %nctaid.x, %ntid.x
        LOOP:
            setp.ge.s32 %p1, %r3, %r12
            @%p1 bra  DONE
            shl.u32   %r4, %r3, 2
            add.u32   %r5, %r10, %r4
            add.u32   %r6, %r11, %r4
            ld.global.f32 %f1, [%r5+0]
            ld.global.f32 %f2, [%r6+0]
            mad.f32   %f3, %f1, %f10, %f2
            st.global.f32 [%r6+0], %f3
            add.u32   %r3, %r3, %r9
            bra       LOOP
        DONE:
            exit
        "#,
    )?;
    let mut rng = Prng::new(0xA1);
    let xv = rng.f32_vec(n, -1.0, 1.0);
    let yv = rng.f32_vec(n, -1.0, 1.0);
    let alpha = 1.5f32;
    let x = dev.alloc_bytes(n * 4);
    let y = dev.alloc_bytes(n * 4);
    dev.write_f32(x, &xv);
    dev.write_f32(y, &yv);
    let golden: Vec<f32> = xv.iter().zip(&yv).map(|(a, b)| alpha * a + b).collect();
    Ok(Prepared {
        workload: Workload::Axpy,
        kernel,
        launch: LaunchConfig::new(32, 128),
        params: vec![
            ParamValue::U32(x as u32),
            ParamValue::U32(y as u32),
            ParamValue::F32(alpha),
            ParamValue::U32(n as u32),
        ],
        home: Some((x, 512)),
        out_addr: y,
        out_len: n,
        golden,
        tol: 1e-5,
        xla_inputs: vec![xv, yv, vec![alpha]],
        meta: vec![("n".into(), n as u32)],
    })
}

/// PR (CUB-style parallel reduction): grid-stride partial sums and a
/// fixed-order shared-memory tree reduction per block. Each block writes
/// its partial into a distinct `partials[ctaid]` slot instead of a
/// single-accumulator global f32 atomic: every addition now happens in a
/// schedule-independent order (sequential per thread, then the pairwise
/// tree between barriers), so the output is bit-identical across machine
/// variants and the host golden reproduces it exactly.
pub fn pr(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let n: usize = match scale {
        Scale::Tiny => 4096,
        Scale::Small => 65536,
    };
    const BLOCKS: usize = 32;
    const THREADS: usize = 128;
    let kernel = KernelSource::assemble(
        "pr",
        &[Reg::r(10), Reg::r(11), Reg::r(12)],
        r#"
            mov.u32   %r1, %tid.x
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            mul.u32   %r9, %nctaid.x, %ntid.x
            mov.f32   %f1, 0.0
        LOOP:
            setp.ge.s32 %p1, %r3, %r12
            @%p1 bra  RED
            shl.u32   %r4, %r3, 2
            add.u32   %r5, %r10, %r4
            ld.global.f32 %f2, [%r5+0]
            add.f32   %f1, %f1, %f2
            add.u32   %r3, %r3, %r9
            bra       LOOP
        RED:
            shl.u32   %r6, %r1, 2
            st.shared.f32 [%r6+0], %f1
            bar.sync
            mov.u32   %r7, 64
        RLOOP:
            setp.eq.s32 %p2, %r7, 0
            @%p2 bra  WRITE
            setp.ge.s32 %p3, %r1, %r7
            @%p3 bra  SKIP
            add.u32   %r8, %r1, %r7
            shl.u32   %r2, %r8, 2
            ld.shared.f32 %f3, [%r2+0]
            ld.shared.f32 %f4, [%r6+0]
            add.f32   %f4, %f4, %f3
            st.shared.f32 [%r6+0], %f4
        SKIP:
            bar.sync
            shr.u32   %r7, %r7, 1
            bra       RLOOP
        WRITE:
            setp.ne.s32 %p4, %r1, 0
            @%p4 bra  DONE
            ld.shared.f32 %f5, [%r6+0]
            mov.u32   %r2, %ctaid.x
            shl.u32   %r2, %r2, 2
            add.u32   %r2, %r11, %r2
            st.global.f32 [%r2+0], %f5
        DONE:
            exit
        "#,
    )?;
    let mut rng = Prng::new(0xB2);
    let xv = rng.f32_vec(n, 0.0, 1.0);
    let x = dev.alloc_bytes(n * 4);
    let out = dev.alloc_bytes(BLOCKS * 4);
    dev.write_f32(x, &xv);
    dev.write_f32(out, &[0.0; BLOCKS]);
    // Golden: replay the device's exact f32 addition order — per-thread
    // grid-stride accumulation, then the pairwise tree (threads `t < off`
    // add slot `t + off`, barrier, halve `off`). Bit-exact, so tol = 0.
    let stride = BLOCKS * THREADS;
    let mut golden = vec![0f32; BLOCKS];
    for (b, out_slot) in golden.iter_mut().enumerate() {
        let mut sm = [0f32; THREADS];
        for (t, slot) in sm.iter_mut().enumerate() {
            let mut acc = 0f32;
            let mut i = b * THREADS + t;
            while i < n {
                acc += xv[i];
                i += stride;
            }
            *slot = acc;
        }
        let mut off = THREADS / 2;
        while off > 0 {
            for t in 0..off {
                sm[t] += sm[t + off];
            }
            off /= 2;
        }
        *out_slot = sm[0];
    }
    Ok(Prepared {
        workload: Workload::Pr,
        kernel,
        launch: LaunchConfig::with_smem(BLOCKS as u32, THREADS as u32, (THREADS * 4) as u32),
        params: vec![
            ParamValue::U32(x as u32),
            ParamValue::U32(out as u32),
            ParamValue::U32(n as u32),
        ],
        home: Some((x, 512)),
        out_addr: out,
        out_len: BLOCKS,
        golden,
        tol: 0.0,
        xla_inputs: vec![xv],
        meta: vec![("n".into(), n as u32), ("blocks".into(), BLOCKS as u32)],
    })
}

/// GEMV (cuBLAS `sgemv`): `y = A·x` with `A` in column-major `M×N`
/// layout (the BLAS default) — one thread per row, `x` staged in shared
/// memory per block.
pub fn gemv(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let (m, nn): (usize, usize) = match scale {
        Scale::Tiny => (4096, 16),
        Scale::Small => (8192, 64),
    };
    let kernel = KernelSource::assemble(
        "gemv",
        &[Reg::r(10), Reg::r(11), Reg::r(12), Reg::r(13), Reg::r(14)],
        r#"
            mov.u32   %r1, %tid.x
            setp.ge.s32 %p1, %r1, %r14
            @%p1 bra  XDONE
            shl.u32   %r4, %r1, 2
            add.u32   %r5, %r11, %r4
            ld.global.f32 %f1, [%r5+0]
            st.shared.f32 [%r4+0], %f1
        XDONE:
            bar.sync
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            setp.ge.s32 %p2, %r3, %r13
            @%p2 bra  DONE
            mov.f32   %f2, 0.0
            mov.u32   %r6, 0
            shl.u32   %r7, %r3, 2
            add.u32   %r8, %r10, %r7
            shl.u32   %r9, %r13, 2
        JLOOP:
            setp.ge.s32 %p3, %r6, %r14
            @%p3 bra  STORE
            ld.global.f32 %f3, [%r8+0]
            shl.u32   %r2, %r6, 2
            ld.shared.f32 %f4, [%r2+0]
            mad.f32   %f2, %f3, %f4, %f2
            add.u32   %r8, %r8, %r9
            add.u32   %r6, %r6, 1
            bra       JLOOP
        STORE:
            add.u32   %r21, %r12, %r7
            st.global.f32 [%r21+0], %f2
        DONE:
            exit
        "#,
    )?;
    let mut rng = Prng::new(0xC3);
    let a = rng.f32_vec(m * nn, -1.0, 1.0); // column-major: a[j*m + i]
    let xv = rng.f32_vec(nn, -1.0, 1.0);
    let pa = dev.alloc_bytes(m * nn * 4);
    let px = dev.alloc_bytes(nn * 4);
    let py = dev.alloc_bytes(m * 4);
    dev.write_f32(pa, &a);
    dev.write_f32(px, &xv);
    let golden: Vec<f32> = (0..m)
        .map(|i| (0..nn).map(|j| a[j * m + i] as f64 * xv[j] as f64).sum::<f64>() as f32)
        .collect();
    Ok(Prepared {
        workload: Workload::Gemv,
        kernel,
        launch: LaunchConfig::with_smem((m / 128) as u32, 128, nn as u32 * 4),
        params: vec![
            ParamValue::U32(pa as u32),
            ParamValue::U32(px as u32),
            ParamValue::U32(py as u32),
            ParamValue::U32(m as u32),
            ParamValue::U32(nn as u32),
        ],
        home: Some((pa, 512)),
        out_addr: py,
        out_len: m,
        golden,
        tol: 1e-3,
        xla_inputs: vec![a, xv],
        meta: vec![("m".into(), m as u32), ("n".into(), nn as u32)],
    })
}

/// TTRANS (cuBLAS-style tensor transposition): `out[j·M+i] = in[i·N+j]`.
/// Coalesced reads, scattered row-buffer-unfriendly writes — the paper's
/// low-speedup case.
pub fn ttrans(scale: Scale, dev: &mut dyn Device) -> Result<Prepared> {
    let (m, nn): (usize, usize) = match scale {
        Scale::Tiny => (64, 64),
        Scale::Small => (128, 128),
    };
    let total = m * nn;
    let kernel = KernelSource::assemble(
        "ttrans",
        &[Reg::r(10), Reg::r(11), Reg::r(12), Reg::r(13), Reg::r(14)],
        r#"
            mov.u32   %r1, %tid.x
            mad.u32   %r3, %ctaid.x, %ntid.x, %r1
            setp.ge.s32 %p1, %r3, %r14
            @%p1 bra  DONE
            div.u32   %r4, %r3, %r13
            rem.u32   %r5, %r3, %r13
            shl.u32   %r6, %r3, 2
            add.u32   %r6, %r10, %r6
            ld.global.f32 %f1, [%r6+0]
            mad.u32   %r7, %r5, %r12, %r4
            shl.u32   %r7, %r7, 2
            add.u32   %r7, %r11, %r7
            st.global.f32 [%r7+0], %f1
        DONE:
            exit
        "#,
    )?;
    let mut rng = Prng::new(0xD4);
    let input = rng.f32_vec(total, -1.0, 1.0);
    let pin = dev.alloc_bytes(total * 4);
    let pout = dev.alloc_bytes(total * 4);
    dev.write_f32(pin, &input);
    let mut golden = vec![0f32; total];
    for i in 0..m {
        for j in 0..nn {
            golden[j * m + i] = input[i * nn + j];
        }
    }
    Ok(Prepared {
        workload: Workload::Ttrans,
        kernel,
        launch: LaunchConfig::new((total / 128) as u32, 128),
        params: vec![
            ParamValue::U32(pin as u32),
            ParamValue::U32(pout as u32),
            ParamValue::U32(m as u32),
            ParamValue::U32(nn as u32),
            ParamValue::U32(total as u32),
        ],
        home: Some((pin, 512)),
        out_addr: pout,
        out_len: total,
        golden,
        tol: 0.0,
        xla_inputs: vec![input],
        meta: vec![("m".into(), m as u32), ("n".into(), nn as u32)],
    })
}
