//! The ideal-bandwidth roofline machine.
//!
//! Same shared SIMT frontend, with a memory system that has *infinite
//! bandwidth*: every global access completes after a fixed pipe latency
//! regardless of how many requests are in flight. No contention, no
//! row-buffer behaviour, no interconnect serialization.
//!
//! This is the "how far from the wall are we" column of every speedup
//! plot: the gap between any real machine (MPU or GPU) and this variant
//! is exactly the cost of its memory system, because everything else —
//! scheduler, scoreboard, ALU latencies, functional semantics — is the
//! same frontend code.

use crate::compiler::DecodedKernel;
use crate::config::IdealConfig;
use crate::core::frontend::{
    AccessCtx, Completion, FrontendParams, MemorySystem, OffloadModel, SimtFrontend,
};
use crate::core::warp::Warp;
use crate::core::ExecLoc;
use crate::isa::instr::Loc;
use crate::isa::program::ParamValue;
use crate::isa::{LaunchConfig, MacroOp, Op, Reg};
use crate::sim::Stats;
use anyhow::Result;
use std::sync::Arc;

/// Fixed-latency, infinite-bandwidth memory system.
pub struct IdealMemory {
    cfg: IdealConfig,
}

impl IdealMemory {
    pub fn new(cfg: &IdealConfig) -> IdealMemory {
        IdealMemory { cfg: cfg.clone() }
    }
}

impl MemorySystem for IdealMemory {
    fn issue_access(&mut self, ctx: &AccessCtx, w: &mut Warp, stats: &mut Stats) {
        stats.instrs_far += 1;
        // Account the same 32-B sectors as the GPU baseline so achieved
        // bandwidth (`dram_gbps`) stays comparable — the pipe just never
        // saturates.
        let mut sectors: Vec<u64> = ctx.addrs.iter().map(|&(_, a)| a & !31).collect();
        sectors.sort_unstable();
        sectors.dedup();
        let is_write = matches!(ctx.instr.op, Op::St | Op::Red);
        for _ in &sectors {
            stats.dram_bytes += 32;
            if is_write {
                stats.dram_writes += 1;
            } else {
                stats.dram_reads += 1;
            }
        }
        stats.rf_far_accesses += 2;
        if let Some(d) = ctx.instr.dst {
            w.reg_ready.insert(d, ctx.now + self.cfg.mem_latency + 1);
        }
    }

    fn advance(&mut self, _now: u64, _stats: &mut Stats) {}

    fn drain_completed(&mut self, _now: u64, _out: &mut Vec<Completion>) {}

    fn next_event(&self) -> Option<u64> {
        None
    }

    fn idle(&self) -> bool {
        true
    }

    fn seed_param(&self, w: &mut Warp, r: Reg) {
        w.track.write_fb(r);
    }
}

impl OffloadModel for IdealMemory {
    fn pre_issue(
        &mut self,
        _core: usize,
        _w: &mut Warp,
        _instr: &MacroOp,
        _hint: Loc,
        now: u64,
        _stats: &mut Stats,
    ) -> (ExecLoc, u64) {
        (ExecLoc::Far, now)
    }

    fn alu_start(&mut self, _core: usize, _loc: ExecLoc, ready: u64, now: u64, _stats: &mut Stats) -> u64 {
        now.max(ready)
    }

    fn retire_dst(&mut self, w: &mut Warp, instr: &MacroOp, _loc: ExecLoc, done: u64) {
        if let Some(d) = instr.dst {
            w.reg_ready.insert(d, done);
        }
    }
}

/// The roofline machine: shared SIMT frontend + ideal memory.
pub struct IdealMachine {
    pub cfg: IdealConfig,
    fe: SimtFrontend<IdealMemory>,
}

impl FrontendParams {
    /// Frontend parameters of the ideal-bandwidth roofline machine.
    pub fn for_ideal(cfg: &IdealConfig) -> FrontendParams {
        FrontendParams {
            cores: cfg.sms,
            subcores_per_core: cfg.subcores_per_sm,
            warp_size: cfg.warp_size,
            max_warps_per_subcore: cfg.max_warps_per_subcore,
            max_blocks_per_core: cfg.max_blocks_per_sm,
            issue_width: 1,
            smem_bytes: cfg.smem_bytes,
            sched_policy: cfg.sched_policy,
            alu_latency: cfg.alu_latency,
            sfu_latency: cfg.sfu_latency,
            opc_latency: 2,
            smem_latency: cfg.smem_latency,
            mem_bytes: 256 << 20,
            max_cycles: cfg.max_cycles,
            threads: 1,
        }
    }
}

impl IdealMachine {
    pub fn new(cfg: &IdealConfig) -> IdealMachine {
        IdealMachine {
            cfg: cfg.clone(),
            fe: SimtFrontend::new(FrontendParams::for_ideal(cfg), IdealMemory::new(cfg)),
        }
    }

    pub fn alloc(&mut self, bytes: usize) -> u64 {
        self.fe.alloc(bytes)
    }
    pub fn write_f32s(&mut self, addr: u64, data: &[f32]) {
        self.fe.write_f32s(addr, data)
    }
    pub fn read_f32s(&self, addr: u64, n: usize) -> Vec<f32> {
        self.fe.read_f32s(addr, n)
    }
    pub fn write_u32s(&mut self, addr: u64, data: &[u32]) {
        self.fe.write_u32s(addr, data)
    }
    pub fn read_u32s(&self, addr: u64, n: usize) -> Vec<u32> {
        self.fe.read_u32s(addr, n)
    }

    pub fn launch(
        &mut self,
        kernel: impl Into<Arc<DecodedKernel>>,
        launch: LaunchConfig,
        params: &[ParamValue],
    ) -> Result<()> {
        self.fe.launch(kernel, launch, params, |_| None)
    }

    pub fn run(&mut self) -> Result<Stats> {
        self.fe.run()
    }

    /// Run with the per-cycle reference loop (the event-driven `run`'s
    /// timing oracle; see `SimtFrontend::run_reference`).
    pub fn run_reference(&mut self) -> Result<Stats> {
        self.fe.run_reference()
    }

    /// Shard the issue phase across `n` worker threads (byte-identical
    /// output for any `n` — see `SimtFrontend::set_threads`).
    pub fn set_threads(&mut self, n: usize) {
        self.fe.set_threads(n);
    }

    /// Statistics accumulated so far.
    pub fn stats(&self) -> &Stats {
        &self.fe.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::config::{GpuConfig, MachineConfig};
    use crate::coordinator::sweep::compile_kernel;
    use crate::workloads::{prepare, Scale, Workload};

    #[test]
    fn ideal_machine_runs_axpy_correctly_and_fast() {
        let mpu_cfg = MachineConfig::scaled();
        let icfg = IdealConfig::matched(&mpu_cfg);
        let mut m = IdealMachine::new(&icfg);
        let p = prepare(Workload::Axpy, Scale::Tiny, &mut m).unwrap();
        let k = compile(&p.kernel).unwrap();
        m.launch(k, p.launch, &p.params).unwrap();
        let stats = m.run().unwrap();
        let out = m.read_f32s(p.out_addr, p.out_len);
        for (i, (a, b)) in out.iter().zip(&p.golden).enumerate() {
            assert!((a - b).abs() <= p.tol, "at {i}: {a} vs {b}");
        }
        assert!(stats.cycles > 0);
        assert!(stats.dram_bytes > 0);
    }

    #[test]
    fn ideal_is_a_roofline_for_the_gpu() {
        // With the same frontend geometry and a latency no worse than an
        // L2 hit, the infinite-bandwidth machine bounds the GPU baseline
        // from below on a streaming kernel.
        let mpu_cfg = MachineConfig::scaled();
        let gcfg = GpuConfig::matched(&mpu_cfg);
        let icfg = IdealConfig::matched(&mpu_cfg);
        let kernel = compile_kernel(Workload::Axpy, true).unwrap();

        let mut g = crate::gpu::GpuMachine::new(&gcfg);
        let pg = prepare(Workload::Axpy, Scale::Tiny, &mut g).unwrap();
        g.launch(kernel.clone(), pg.launch, &pg.params).unwrap();
        let gs = g.run().unwrap();

        let mut i = IdealMachine::new(&icfg);
        let pi = prepare(Workload::Axpy, Scale::Tiny, &mut i).unwrap();
        i.launch(kernel, pi.launch, &pi.params).unwrap();
        let is = i.run().unwrap();

        assert!(
            is.cycles <= gs.cycles,
            "ideal {} must not be slower than GPU {}",
            is.cycles,
            gs.cycles
        );
    }
}
