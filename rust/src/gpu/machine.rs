//! The GPU baseline machine.
//!
//! Keeps the MPU model's SIMT semantics (same compiled kernels, same
//! functional execution, same warp scheduler) and swaps the memory
//! system: a chip-wide HBM bandwidth pipe (V100 per-SM share) with
//! ~400-cycle latency behind a flat-hit-rate L2. No TSVs, no offloading,
//! no track table — every value lives in the SM register file.
//!
//! This is exactly the comparison the paper makes: identical programs,
//! compute-centric vs near-bank memory systems.

use crate::compiler::CompiledKernel;
use crate::config::{GpuConfig, SchedPolicy};
use crate::core::exec::{alu_lane, operand_value, LaneCtx};
use crate::core::warp::{Warp, WarpState};
use crate::isa::program::ParamValue;
use crate::isa::{LaunchConfig, Op, Space};
use crate::mem::SharedMem;
use crate::sim::{BandwidthBus, Prng, Stats};
use anyhow::{bail, Result};
use std::collections::VecDeque;

#[derive(Debug)]
struct BlockState {
    id: u32,
    warps_live: usize,
    at_barrier: usize,
    smem: SharedMem,
}

struct Sm {
    warps: Vec<Warp>,
    blocks: Vec<BlockState>,
    last_issued: Vec<Option<usize>>,
    rr_next: Vec<usize>,
    pending_blocks: VecDeque<u32>,
    /// Live warp indices per subcore (scheduler scans only these).
    sc_warps: Vec<Vec<usize>>,
}

/// The simulated GPU.
pub struct GpuMachine {
    pub cfg: GpuConfig,
    kernel: Option<CompiledKernel>,
    launch: Option<LaunchConfig>,
    params: Vec<ParamValue>,
    mem: Vec<u8>,
    alloc_top: u64,
    sms: Vec<Sm>,
    hbm: BandwidthBus,
    l2_rng: Prng,
    pub stats: Stats,
    now: u64,
    blocks_done: u32,
    warp_size: usize,
}

impl GpuMachine {
    pub fn new(cfg: &GpuConfig) -> GpuMachine {
        GpuMachine {
            cfg: cfg.clone(),
            kernel: None,
            launch: None,
            params: Vec::new(),
            mem: vec![0; 256 << 20],
            alloc_top: 0,
            sms: (0..cfg.sms)
                .map(|_| Sm {
                    warps: Vec::new(),
                    blocks: Vec::new(),
                    last_issued: vec![None; cfg.subcores_per_sm],
                    rr_next: vec![0; cfg.subcores_per_sm],
                    pending_blocks: VecDeque::new(),
                    sc_warps: vec![Vec::new(); cfg.subcores_per_sm],
                })
                .collect(),
            hbm: BandwidthBus::new(cfg.hbm_bytes_per_cycle, cfg.mem_latency),
            l2_rng: Prng::new(0xD1CE),
            stats: Stats::default(),
            now: 0,
            blocks_done: 0,
            warp_size: cfg.warp_size,
        }
    }

    pub fn alloc(&mut self, bytes: usize) -> u64 {
        let base = (self.alloc_top + 255) & !255;
        self.alloc_top = base + bytes as u64;
        assert!((self.alloc_top as usize) <= self.mem.len(), "GPU device OOM");
        base
    }

    pub fn write_f32s(&mut self, addr: u64, data: &[f32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.mem[addr as usize..addr as usize + bytes.len()].copy_from_slice(&bytes);
    }

    pub fn write_u32s(&mut self, addr: u64, data: &[u32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.mem[addr as usize..addr as usize + bytes.len()].copy_from_slice(&bytes);
    }

    pub fn read_f32s(&self, addr: u64, n: usize) -> Vec<f32> {
        self.mem[addr as usize..addr as usize + 4 * n]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn read_u32s(&self, addr: u64, n: usize) -> Vec<u32> {
        self.mem[addr as usize..addr as usize + 4 * n]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    fn mem_read_u32(&self, addr: u64) -> u32 {
        let a = addr as usize;
        if a + 4 > self.mem.len() {
            return 0;
        }
        u32::from_le_bytes(self.mem[a..a + 4].try_into().unwrap())
    }

    fn mem_write_u32(&mut self, addr: u64, v: u32) {
        let a = addr as usize;
        if a + 4 > self.mem.len() {
            return;
        }
        self.mem[a..a + 4].copy_from_slice(&v.to_le_bytes());
    }

    pub fn launch(
        &mut self,
        kernel: CompiledKernel,
        launch: LaunchConfig,
        params: &[ParamValue],
    ) -> Result<()> {
        if kernel.params.len() != params.len() {
            bail!("param count mismatch");
        }
        self.kernel = Some(kernel);
        self.launch = Some(launch);
        self.params = params.to_vec();
        let n = self.sms.len();
        for b in 0..launch.grid {
            self.sms[b as usize % n].pending_blocks.push_back(b);
        }
        for s in 0..n {
            while self.try_dispatch(s) {}
        }
        Ok(())
    }

    fn try_dispatch(&mut self, s: usize) -> bool {
        let launch = self.launch.unwrap();
        let kernel = self.kernel.as_ref().unwrap();
        let sm = &mut self.sms[s];
        if sm.blocks.len() >= self.cfg.max_blocks_per_sm {
            return false;
        }
        let wpb = launch.warps_per_block(self.warp_size);
        let live = sm.warps.iter().filter(|w| w.state != WarpState::Done).count();
        if live + wpb > self.cfg.max_warps_per_subcore * self.cfg.subcores_per_sm {
            return false;
        }
        let Some(b) = sm.pending_blocks.pop_front() else { return false };
        sm.blocks.push(BlockState {
            id: b,
            warps_live: wpb,
            at_barrier: 0,
            smem: SharedMem::new((launch.smem_bytes as usize).min(self.cfg.smem_bytes).max(4)),
        });
        for wi in 0..wpb {
            let lanes = (launch.block as usize - wi * self.warp_size).min(self.warp_size);
            let sc = wi % self.cfg.subcores_per_sm;
            let mut w = Warp::new(b, wi, lanes, sc, kernel.reg_counts, self.warp_size);
            w.ready_at = self.now + 1;
            for (p, v) in kernel.params.iter().zip(&self.params) {
                w.write_all(*p, v.bits());
                w.track.write_fb(*p);
            }
            sm.sc_warps[sc].push(sm.warps.len());
            sm.warps.push(w);
        }
        true
    }

    pub fn run(&mut self) -> Result<Stats> {
        let grid = self.launch.map(|l| l.grid).unwrap_or(0);
        loop {
            let issued = self.issue_all();
            if self.blocks_done >= grid {
                break;
            }
            if self.now >= self.cfg.max_cycles {
                bail!("GPU simulation exceeded max_cycles (deadlock?)");
            }
            if issued {
                self.now += 1;
            } else {
                match self.next_interesting() {
                    Some(t) if t > self.now => self.now = t,
                    _ => self.now += 1,
                }
            }
        }
        self.stats.cycles = self.now;
        Ok(self.stats.clone())
    }

    fn next_interesting(&self) -> Option<u64> {
        let kernel = self.kernel.as_ref().unwrap();
        let mut best: Option<u64> = None;
        for sm in &self.sms {
            for w in sm.sc_warps.iter().flatten().map(|&wi| &sm.warps[wi]) {
                if w.state != WarpState::Ready {
                    continue;
                }
                let pc = w.pc();
                if pc >= kernel.instrs.len() {
                    continue;
                }
                let i = &kernel.instrs[pc];
                let dep = w.instr_ready_at(i);
                if dep == u64::MAX {
                    continue;
                }
                let t = dep.max(w.ready_at);
                best = Some(best.map_or(t, |b: u64| b.min(t)));
            }
        }
        best
    }

    fn issue_all(&mut self) -> bool {
        let mut any = false;
        for s in 0..self.sms.len() {
            for sc in 0..self.cfg.subcores_per_sm {
                if let Some(wi) = self.pick_warp(s, sc) {
                    self.issue(s, wi);
                    self.sms[s].last_issued[sc] = Some(wi);
                    any = true;
                }
            }
        }
        any
    }

    fn pick_warp(&self, s: usize, sc: usize) -> Option<usize> {
        let sm = &self.sms[s];
        let kernel = self.kernel.as_ref().unwrap();
        let can = |wi: usize| {
            let w = &sm.warps[wi];
            if w.state != WarpState::Ready || w.subcore != sc || w.ready_at > self.now {
                return false;
            }
            let pc = w.pc();
            if pc >= kernel.instrs.len() {
                return false;
            }
            let i = &kernel.instrs[pc];
            w.instr_ready_at(i) <= self.now
        };
        let live = &sm.sc_warps[sc];
        match self.cfg.sched_policy {
            SchedPolicy::Gto => {
                if let Some(last) = sm.last_issued[sc] {
                    if last < sm.warps.len() && can(last) {
                        return Some(last);
                    }
                }
                live.iter().copied().find(|&wi| can(wi))
            }
            SchedPolicy::RoundRobin => {
                let n = live.len();
                if n == 0 {
                    return None;
                }
                let start = sm.rr_next[sc] % n;
                (0..n).map(|k| live[(start + k) % n]).find(|&wi| can(wi))
            }
        }
    }

    fn issue(&mut self, s: usize, wi: usize) {
        let launch = self.launch.unwrap();
        let pc = self.sms[s].warps[wi].pc();
        let (instr, reconv_pc) = {
            let kernel = self.kernel.as_ref().unwrap();
            (kernel.instrs[pc].clone(), kernel.reconv[pc])
        };
        if self.cfg.sched_policy == SchedPolicy::RoundRobin {
            let sc = self.sms[s].warps[wi].subcore;
            let pos = self.sms[s].sc_warps[sc].iter().position(|&x| x == wi).unwrap_or(0);
            self.sms[s].rr_next[sc] = pos + 1;
        }
        {
            let w = &mut self.sms[s].warps[wi];
            w.ready_at = self.now + 1;
            w.last_issue = self.now;
        }

        let (exec_mask, active_mask) = {
            let w = &self.sms[s].warps[wi];
            let active = w.active_mask();
            let m = match instr.guard {
                None => active,
                Some((p, neg)) => {
                    let mut m = 0u64;
                    for lane in 0..w.lanes {
                        if active >> lane & 1 == 1 && (w.read(p, lane) != 0) != neg {
                            m |= 1 << lane;
                        }
                    }
                    m
                }
            };
            (m, active)
        };

        self.stats.instrs_far += 1;
        match instr.op {
            Op::Bra => {
                let target = instr.target.unwrap_or(pc + 1);
                let rpc = reconv_pc.unwrap_or(usize::MAX);
                let w = &mut self.sms[s].warps[wi];
                let taken = if instr.guard.is_none() { active_mask } else { exec_mask };
                w.branch(taken, target, pc + 1, rpc);
                return;
            }
            Op::Bar => {
                self.stats.barriers += 1;
                self.barrier(s, wi, pc);
                return;
            }
            Op::Exit => {
                self.exit(s, wi, active_mask);
                return;
            }
            _ => {}
        }
        if exec_mask == 0 {
            self.stats.predicated_off += 1;
            self.sms[s].warps[wi].set_pc(pc + 1);
            return;
        }

        match (instr.op, instr.space) {
            (Op::Ld | Op::St | Op::Red, Some(Space::Global)) => {
                self.issue_global(s, wi, pc, &instr, exec_mask, launch)
            }
            (Op::Ld | Op::St | Op::Red, Some(Space::Shared)) => {
                self.issue_shared(s, wi, pc, &instr, exec_mask, launch)
            }
            _ => self.issue_alu(s, wi, pc, &instr, exec_mask, launch),
        }
    }

    fn issue_alu(&mut self, s: usize, wi: usize, pc: usize, instr: &crate::isa::Instr, exec_mask: u64, launch: LaunchConfig) {
        let (block, wib, lanes) = {
            let w = &self.sms[s].warps[wi];
            (w.block, w.warp_in_block, w.lanes)
        };
        for lane in 0..lanes {
            if exec_mask >> lane & 1 == 0 {
                continue;
            }
            let ctx = LaneCtx {
                tid: (wib * self.warp_size + lane) as u32,
                ntid: launch.block,
                ctaid: block,
                nctaid: launch.grid,
            };
            let w = &self.sms[s].warps[wi];
            let srcs: Vec<u32> = instr.srcs.iter().map(|o| operand_value(o, &ctx, &|r| w.read(r, lane))).collect();
            let v = alu_lane(instr, &srcs);
            if let Some(d) = instr.dst {
                self.sms[s].warps[wi].write(d, lane, v);
            }
        }
        let lat = if instr.op.is_sfu() { self.cfg.sfu_latency } else { self.cfg.alu_latency };
        self.stats.alu_lane_ops += exec_mask.count_ones() as u64;
        self.stats.rf_far_accesses += instr.srcs.len() as u64 + 1;
        self.stats.opc_accesses += instr.srcs.len() as u64;
        let w = &mut self.sms[s].warps[wi];
        if let Some(d) = instr.dst {
            w.reg_ready.insert(d, self.now + 2 + lat);
        }
        w.set_pc(pc + 1);
    }

    fn issue_global(&mut self, s: usize, wi: usize, pc: usize, instr: &crate::isa::Instr, exec_mask: u64, launch: LaunchConfig) {
        self.stats.global_mem_instrs += 1;
        let m = instr.mem.unwrap();
        let (block, wib, lanes) = {
            let w = &self.sms[s].warps[wi];
            (w.block, w.warp_in_block, w.lanes)
        };
        let addrs: Vec<(usize, u64)> = (0..lanes)
            .filter(|l| exec_mask >> l & 1 == 1)
            .map(|l| {
                let w = &self.sms[s].warps[wi];
                (l, (w.read(m.base, l) as i64 + m.offset as i64) as u64)
            })
            .collect();

        // Functional.
        match instr.op {
            Op::Ld => {
                let dst = instr.dst.unwrap();
                let vals: Vec<(usize, u32)> = addrs.iter().map(|&(l, a)| (l, self.mem_read_u32(a))).collect();
                for (l, v) in vals {
                    self.sms[s].warps[wi].write(dst, l, v);
                }
            }
            Op::St | Op::Red => {
                let src = instr.srcs[0];
                for &(l, a) in &addrs {
                    let ctx = LaneCtx {
                        tid: (wib * self.warp_size + l) as u32,
                        ntid: launch.block,
                        ctaid: block,
                        nctaid: launch.grid,
                    };
                    let v = {
                        let w = &self.sms[s].warps[wi];
                        operand_value(&src, &ctx, &|r| w.read(r, l))
                    };
                    if instr.op == Op::St {
                        self.mem_write_u32(a, v);
                    } else {
                        let old = self.mem_read_u32(a);
                        let new = if instr.ty == crate::isa::Ty::F32 {
                            (f32::from_bits(old) + f32::from_bits(v)).to_bits()
                        } else {
                            old.wrapping_add(v)
                        };
                        self.mem_write_u32(a, new);
                    }
                }
            }
            _ => unreachable!(),
        }

        // Timing: coalesce into 32-B sectors; L2 hits skip the HBM pipe.
        let mut sectors: Vec<u64> = addrs.iter().map(|&(_, a)| a & !31).collect();
        sectors.sort_unstable();
        sectors.dedup();
        let is_write = matches!(instr.op, Op::St | Op::Red);
        let mut done = self.now;
        for _ in &sectors {
            let hit = self.l2_rng.chance(self.cfg.l2_hit_rate);
            let t = if hit && !is_write {
                self.stats.l2_bytes += 32;
                self.now + self.cfg.l2_latency
            } else {
                self.stats.dram_bytes += 32;
                if is_write {
                    self.stats.dram_writes += 1;
                } else {
                    self.stats.dram_reads += 1;
                }
                self.hbm.reserve(self.now, 32)
            };
            done = done.max(t);
        }
        self.stats.rf_far_accesses += 2;
        let w = &mut self.sms[s].warps[wi];
        if let Some(d) = instr.dst {
            w.reg_ready.insert(d, done + 1);
        }
        w.set_pc(pc + 1);
    }

    fn issue_shared(&mut self, s: usize, wi: usize, pc: usize, instr: &crate::isa::Instr, exec_mask: u64, launch: LaunchConfig) {
        self.stats.shared_mem_instrs += 1;
        let m = instr.mem.unwrap();
        let (block, wib, lanes) = {
            let w = &self.sms[s].warps[wi];
            (w.block, w.warp_in_block, w.lanes)
        };
        let bslot = self.sms[s].blocks.iter().position(|b| b.id == block).expect("block resident");
        let addrs: Vec<(usize, u64)> = (0..lanes)
            .filter(|l| exec_mask >> l & 1 == 1)
            .map(|l| {
                let w = &self.sms[s].warps[wi];
                (l, (w.read(m.base, l) as i64 + m.offset as i64) as u64)
            })
            .collect();
        match instr.op {
            Op::Ld => {
                let dst = instr.dst.unwrap();
                let vals: Vec<(usize, u32)> = addrs
                    .iter()
                    .map(|&(l, a)| (l, self.sms[s].blocks[bslot].smem.read_u32(a as u32)))
                    .collect();
                for (l, v) in vals {
                    self.sms[s].warps[wi].write(dst, l, v);
                }
            }
            Op::St | Op::Red => {
                let src = instr.srcs[0];
                for &(l, a) in &addrs {
                    let ctx = LaneCtx {
                        tid: (wib * self.warp_size + l) as u32,
                        ntid: launch.block,
                        ctaid: block,
                        nctaid: launch.grid,
                    };
                    let v = {
                        let w = &self.sms[s].warps[wi];
                        operand_value(&src, &ctx, &|r| w.read(r, l))
                    };
                    let smem = &mut self.sms[s].blocks[bslot].smem;
                    if instr.op == Op::St {
                        smem.write_u32(a as u32, v);
                    } else if instr.ty == crate::isa::Ty::F32 {
                        smem.red_add_f32(a as u32, f32::from_bits(v));
                    } else {
                        smem.red_add_u32(a as u32, v);
                    }
                }
            }
            _ => unreachable!(),
        }
        let a32: Vec<u32> = addrs.iter().map(|&(_, a)| a as u32).collect();
        let conflicts = self.sms[s].blocks[bslot].smem.conflict_factor(&a32);
        self.stats.smem_accesses += conflicts;
        let done = self.now + self.cfg.smem_latency + (conflicts - 1);
        let w = &mut self.sms[s].warps[wi];
        if let Some(d) = instr.dst {
            w.reg_ready.insert(d, done);
        }
        w.set_pc(pc + 1);
    }

    fn barrier(&mut self, s: usize, wi: usize, pc: usize) {
        let block = self.sms[s].warps[wi].block;
        self.sms[s].warps[wi].set_pc(pc + 1);
        self.sms[s].warps[wi].state = WarpState::AtBarrier;
        let bslot = self.sms[s].blocks.iter().position(|b| b.id == block).expect("block resident");
        self.sms[s].blocks[bslot].at_barrier += 1;
        if self.sms[s].blocks[bslot].at_barrier >= self.sms[s].blocks[bslot].warps_live {
            self.sms[s].blocks[bslot].at_barrier = 0;
            for w in self.sms[s].warps.iter_mut() {
                if w.block == block && w.state == WarpState::AtBarrier {
                    w.state = WarpState::Ready;
                    w.ready_at = self.now + 1;
                }
            }
        }
    }

    fn exit(&mut self, s: usize, wi: usize, mask: u64) {
        let done = self.sms[s].warps[wi].exit_lanes(mask);
        if !done {
            return;
        }
        let block = self.sms[s].warps[wi].block;
        let bslot = self.sms[s].blocks.iter().position(|b| b.id == block).expect("block resident");
        {
            let b = &mut self.sms[s].blocks[bslot];
            b.warps_live -= 1;
            if b.warps_live > 0 {
                if b.at_barrier >= b.warps_live {
                    b.at_barrier = 0;
                    for w in self.sms[s].warps.iter_mut() {
                        if w.block == block && w.state == WarpState::AtBarrier {
                            w.state = WarpState::Ready;
                            w.ready_at = self.now + 1;
                        }
                    }
                }
                return;
            }
        }
        self.sms[s].blocks.remove(bslot);
        {
            let sm = &mut self.sms[s];
            for sc in 0..sm.sc_warps.len() {
                let warps = &sm.warps;
                sm.sc_warps[sc].retain(|&wi| warps[wi].block != block);
            }
        }
        self.blocks_done += 1;
        while self.try_dispatch(s) {}
    }

    /// HBM bandwidth utilization over the run (Fig. 1 metric).
    pub fn bw_utilization(&self) -> f64 {
        self.stats.bw_utilization(self.cfg.hbm_bytes_per_cycle)
    }

    /// ALU utilization: lane-ops per available lane-cycle (Fig. 1).
    pub fn alu_utilization(&self) -> f64 {
        self.stats.alu_utilization(self.cfg.total_lanes() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::config::MachineConfig;
    use crate::isa::{KernelSource, Reg};

    fn axpy() -> KernelSource {
        KernelSource::assemble(
            "axpy",
            &[Reg::r(10), Reg::r(11), Reg::f(10), Reg::r(12)],
            r#"
                mov.u32   %r1, %tid.x
                mad.u32   %r3, %ctaid.x, %ntid.x, %r1
                mul.u32   %r9, %nctaid.x, %ntid.x
            LOOP:
                setp.ge.s32 %p1, %r3, %r12
                @%p1 bra  DONE
                shl.u32   %r4, %r3, 2
                add.u32   %r5, %r10, %r4
                add.u32   %r6, %r11, %r4
                ld.global.f32 %f1, [%r5+0]
                ld.global.f32 %f2, [%r6+0]
                mad.f32   %f3, %f1, %f10, %f2
                st.global.f32 [%r6+0], %f3
                add.u32   %r3, %r3, %r9
                bra       LOOP
            DONE:
                exit
            "#,
        )
        .unwrap()
    }

    #[test]
    fn gpu_axpy_correct_and_bandwidth_bound() {
        let mpu_cfg = MachineConfig::scaled();
        let cfg = GpuConfig::matched(&mpu_cfg);
        let mut g = GpuMachine::new(&cfg);
        let n = 8192usize;
        let x = g.alloc(n * 4);
        let y = g.alloc(n * 4);
        let xv: Vec<f32> = (0..n).map(|i| (i % 97) as f32).collect();
        let yv = vec![1.0f32; n];
        g.write_f32s(x, &xv);
        g.write_f32s(y, &yv);
        let k = compile(&axpy()).unwrap();
        g.launch(
            k,
            crate::isa::LaunchConfig::new(32, 128),
            &[
                ParamValue::U32(x as u32),
                ParamValue::U32(y as u32),
                ParamValue::F32(3.0),
                ParamValue::U32(n as u32),
            ],
        )
        .unwrap();
        let stats = g.run().unwrap();
        let got = g.read_f32s(y, n);
        for (i, v) in got.iter().enumerate() {
            let want = 3.0 * xv[i] + 1.0;
            assert!((v - want).abs() < 1e-5, "at {i}");
        }
        // A streaming kernel saturates the HBM pipe and starves ALUs —
        // the Fig.-1 signature.
        assert!(g.bw_utilization() > 0.3, "bw util {}", g.bw_utilization());
        assert!(g.alu_utilization() < 0.2, "alu util {}", g.alu_utilization());
        assert!(stats.dram_bytes > 0);
    }

    #[test]
    fn mpu_beats_gpu_on_streaming() {
        // The headline claim, in miniature (Fig. 8).
        let mpu_cfg = MachineConfig::scaled();
        let n = 8192usize;

        let k = compile(&axpy()).unwrap();
        let mut m = crate::core::Machine::new(&mpu_cfg);
        let x = m.alloc(n * 4);
        let y = m.alloc(n * 4);
        let xv: Vec<f32> = (0..n).map(|i| (i % 31) as f32).collect();
        m.write_f32s(x, &xv);
        m.write_f32s(y, &vec![0.5; n]);
        m.launch(
            k.clone(),
            crate::isa::LaunchConfig::new(32, 128),
            &[
                ParamValue::U32(x as u32),
                ParamValue::U32(y as u32),
                ParamValue::F32(3.0),
                ParamValue::U32(n as u32),
            ],
            |b| Some(x + b as u64 * 512),
        )
        .unwrap();
        let mpu_stats = m.run().unwrap();

        let gcfg = GpuConfig::matched(&mpu_cfg);
        let mut g = GpuMachine::new(&gcfg);
        let gx = g.alloc(n * 4);
        let gy = g.alloc(n * 4);
        g.write_f32s(gx, &xv);
        g.write_f32s(gy, &vec![0.5; n]);
        g.launch(
            k,
            crate::isa::LaunchConfig::new(32, 128),
            &[
                ParamValue::U32(gx as u32),
                ParamValue::U32(gy as u32),
                ParamValue::F32(3.0),
                ParamValue::U32(n as u32),
            ],
        )
        .unwrap();
        let gpu_stats = g.run().unwrap();

        let speedup = gpu_stats.cycles as f64 / mpu_stats.cycles as f64;
        assert!(speedup > 1.5, "MPU speedup only {speedup:.2}× ({} vs {})", mpu_stats.cycles, gpu_stats.cycles);
    }
}
