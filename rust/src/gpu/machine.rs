//! The GPU baseline machine.
//!
//! The *same* shared SIMT frontend as the MPU (same compiled kernels,
//! same functional execution, same warp scheduler — see
//! [`crate::core::frontend`]) with the memory system swapped: a
//! chip-wide HBM bandwidth pipe (V100 per-SM share) with ~400-cycle
//! latency behind a flat-hit-rate L2. No TSVs, no offloading, no track
//! table — every value lives in the SM register file.
//!
//! This is exactly the comparison the paper makes: identical programs,
//! compute-centric vs near-bank memory systems.

use crate::compiler::DecodedKernel;
use crate::config::GpuConfig;
use crate::core::frontend::{
    AccessCtx, Completion, FrontendParams, MemorySystem, OffloadModel, SimtFrontend,
};
use crate::core::warp::Warp;
use crate::core::ExecLoc;
use crate::isa::instr::Loc;
use crate::isa::program::ParamValue;
use crate::isa::{LaunchConfig, MacroOp, Op, Reg};
use crate::sim::{BandwidthBus, Prng, Stats};
use anyhow::Result;
use std::sync::Arc;

/// The compute-centric memory system: coalesced 32-B sectors through a
/// flat-hit-rate L2 in front of a single chip-wide HBM bandwidth pipe.
pub struct HbmMemory {
    cfg: GpuConfig,
    hbm: BandwidthBus,
    l2_rng: Prng,
}

impl HbmMemory {
    pub fn new(cfg: &GpuConfig) -> HbmMemory {
        HbmMemory {
            cfg: cfg.clone(),
            hbm: BandwidthBus::new(cfg.hbm_bytes_per_cycle, cfg.mem_latency),
            l2_rng: Prng::new(0xD1CE),
        }
    }
}

impl MemorySystem for HbmMemory {
    fn issue_access(&mut self, ctx: &AccessCtx, w: &mut Warp, stats: &mut Stats) {
        stats.instrs_far += 1;
        // Coalesce into 32-B sectors; L2 hits skip the HBM pipe.
        let mut sectors: Vec<u64> = ctx.addrs.iter().map(|&(_, a)| a & !31).collect();
        sectors.sort_unstable();
        sectors.dedup();
        let is_write = matches!(ctx.instr.op, Op::St | Op::Red);
        let mut done = ctx.now;
        for _ in &sectors {
            let hit = self.l2_rng.chance(self.cfg.l2_hit_rate);
            let t = if hit && !is_write {
                stats.l2_bytes += 32;
                ctx.now + self.cfg.l2_latency
            } else {
                stats.dram_bytes += 32;
                if is_write {
                    stats.dram_writes += 1;
                } else {
                    stats.dram_reads += 1;
                }
                self.hbm.reserve(ctx.now, 32)
            };
            done = done.max(t);
        }
        stats.rf_far_accesses += 2;
        if let Some(d) = ctx.instr.dst {
            w.reg_ready.insert(d, done + 1);
        }
    }

    fn advance(&mut self, _now: u64, _stats: &mut Stats) {}

    fn drain_completed(&mut self, _now: u64, _out: &mut Vec<Completion>) {}

    fn next_event(&self) -> Option<u64> {
        // The HBM pipe is fully synchronous: bandwidth reservations are
        // made at issue time and loads resolve into `reg_ready`
        // directly, so there is never internal work to advance (and the
        // inherited `advance_to` is a no-op returning `target`).
        None
    }

    fn idle(&self) -> bool {
        true
    }

    fn seed_param(&self, w: &mut Warp, r: Reg) {
        w.track.write_fb(r);
    }
}

impl OffloadModel for HbmMemory {
    fn pre_issue(
        &mut self,
        _core: usize,
        _w: &mut Warp,
        _instr: &MacroOp,
        _hint: Loc,
        now: u64,
        _stats: &mut Stats,
    ) -> (ExecLoc, u64) {
        // No near-bank units: everything executes on the SM.
        (ExecLoc::Far, now)
    }

    fn alu_start(&mut self, _core: usize, _loc: ExecLoc, ready: u64, now: u64, _stats: &mut Stats) -> u64 {
        now.max(ready)
    }

    fn retire_dst(&mut self, w: &mut Warp, instr: &MacroOp, _loc: ExecLoc, done: u64) {
        if let Some(d) = instr.dst {
            w.reg_ready.insert(d, done);
        }
    }
}

/// The simulated GPU: shared SIMT frontend + HBM-pipe backend.
pub struct GpuMachine {
    pub cfg: GpuConfig,
    fe: SimtFrontend<HbmMemory>,
}

impl FrontendParams {
    /// Frontend parameters of a GPU baseline configuration.
    pub fn for_gpu(cfg: &GpuConfig) -> FrontendParams {
        FrontendParams {
            cores: cfg.sms,
            subcores_per_core: cfg.subcores_per_sm,
            warp_size: cfg.warp_size,
            max_warps_per_subcore: cfg.max_warps_per_subcore,
            max_blocks_per_core: cfg.max_blocks_per_sm,
            issue_width: 1,
            smem_bytes: cfg.smem_bytes,
            sched_policy: cfg.sched_policy,
            alu_latency: cfg.alu_latency,
            sfu_latency: cfg.sfu_latency,
            opc_latency: 2,
            smem_latency: cfg.smem_latency,
            mem_bytes: 256 << 20,
            max_cycles: cfg.max_cycles,
            threads: 1,
        }
    }
}

impl GpuMachine {
    pub fn new(cfg: &GpuConfig) -> GpuMachine {
        GpuMachine {
            cfg: cfg.clone(),
            fe: SimtFrontend::new(FrontendParams::for_gpu(cfg), HbmMemory::new(cfg)),
        }
    }

    pub fn alloc(&mut self, bytes: usize) -> u64 {
        self.fe.alloc(bytes)
    }
    pub fn write_f32s(&mut self, addr: u64, data: &[f32]) {
        self.fe.write_f32s(addr, data)
    }
    pub fn read_f32s(&self, addr: u64, n: usize) -> Vec<f32> {
        self.fe.read_f32s(addr, n)
    }
    pub fn write_u32s(&mut self, addr: u64, data: &[u32]) {
        self.fe.write_u32s(addr, data)
    }
    pub fn read_u32s(&self, addr: u64, n: usize) -> Vec<u32> {
        self.fe.read_u32s(addr, n)
    }

    pub fn launch(
        &mut self,
        kernel: impl Into<Arc<DecodedKernel>>,
        launch: LaunchConfig,
        params: &[ParamValue],
    ) -> Result<()> {
        self.fe.launch(kernel, launch, params, |_| None)
    }

    pub fn run(&mut self) -> Result<Stats> {
        self.fe.run()
    }

    /// Run with the per-cycle reference loop (the event-driven `run`'s
    /// timing oracle; see `SimtFrontend::run_reference`).
    pub fn run_reference(&mut self) -> Result<Stats> {
        self.fe.run_reference()
    }

    /// Shard the issue phase across `n` worker threads (byte-identical
    /// output for any `n` — see `SimtFrontend::set_threads`).
    pub fn set_threads(&mut self, n: usize) {
        self.fe.set_threads(n);
    }

    /// Statistics accumulated so far.
    pub fn stats(&self) -> &Stats {
        &self.fe.stats
    }

    /// HBM bandwidth utilization over the run (Fig. 1 metric).
    pub fn bw_utilization(&self) -> f64 {
        self.fe.stats.bw_utilization(self.cfg.hbm_bytes_per_cycle)
    }

    /// ALU utilization: lane-ops per available lane-cycle (Fig. 1).
    pub fn alu_utilization(&self) -> f64 {
        self.fe.stats.alu_utilization(self.cfg.total_lanes() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::config::MachineConfig;
    use crate::isa::{KernelSource, Reg};

    fn axpy() -> KernelSource {
        KernelSource::assemble(
            "axpy",
            &[Reg::r(10), Reg::r(11), Reg::f(10), Reg::r(12)],
            r#"
                mov.u32   %r1, %tid.x
                mad.u32   %r3, %ctaid.x, %ntid.x, %r1
                mul.u32   %r9, %nctaid.x, %ntid.x
            LOOP:
                setp.ge.s32 %p1, %r3, %r12
                @%p1 bra  DONE
                shl.u32   %r4, %r3, 2
                add.u32   %r5, %r10, %r4
                add.u32   %r6, %r11, %r4
                ld.global.f32 %f1, [%r5+0]
                ld.global.f32 %f2, [%r6+0]
                mad.f32   %f3, %f1, %f10, %f2
                st.global.f32 [%r6+0], %f3
                add.u32   %r3, %r3, %r9
                bra       LOOP
            DONE:
                exit
            "#,
        )
        .unwrap()
    }

    #[test]
    fn gpu_axpy_correct_and_bandwidth_bound() {
        let mpu_cfg = MachineConfig::scaled();
        let cfg = GpuConfig::matched(&mpu_cfg);
        let mut g = GpuMachine::new(&cfg);
        let n = 8192usize;
        let x = g.alloc(n * 4);
        let y = g.alloc(n * 4);
        let xv: Vec<f32> = (0..n).map(|i| (i % 97) as f32).collect();
        let yv = vec![1.0f32; n];
        g.write_f32s(x, &xv);
        g.write_f32s(y, &yv);
        let k = compile(&axpy()).unwrap();
        g.launch(
            k,
            crate::isa::LaunchConfig::new(32, 128),
            &[
                ParamValue::U32(x as u32),
                ParamValue::U32(y as u32),
                ParamValue::F32(3.0),
                ParamValue::U32(n as u32),
            ],
        )
        .unwrap();
        let stats = g.run().unwrap();
        let got = g.read_f32s(y, n);
        for (i, v) in got.iter().enumerate() {
            let want = 3.0 * xv[i] + 1.0;
            assert!((v - want).abs() < 1e-5, "at {i}");
        }
        // A streaming kernel saturates the HBM pipe and starves ALUs —
        // the Fig.-1 signature.
        assert!(g.bw_utilization() > 0.3, "bw util {}", g.bw_utilization());
        assert!(g.alu_utilization() < 0.2, "alu util {}", g.alu_utilization());
        assert!(stats.dram_bytes > 0);
    }

    #[test]
    fn mpu_beats_gpu_on_streaming() {
        // The headline claim, in miniature (Fig. 8).
        let mpu_cfg = MachineConfig::scaled();
        let n = 8192usize;

        let k = compile(&axpy()).unwrap();
        let mut m = crate::core::Machine::new(&mpu_cfg);
        let x = m.alloc(n * 4);
        let y = m.alloc(n * 4);
        let xv: Vec<f32> = (0..n).map(|i| (i % 31) as f32).collect();
        let yv = vec![0.5f32; n];
        m.write_f32s(x, &xv);
        m.write_f32s(y, &yv);
        m.launch(
            k.clone(),
            crate::isa::LaunchConfig::new(32, 128),
            &[
                ParamValue::U32(x as u32),
                ParamValue::U32(y as u32),
                ParamValue::F32(3.0),
                ParamValue::U32(n as u32),
            ],
            |b| Some(x + b as u64 * 512),
        )
        .unwrap();
        let mpu_stats = m.run().unwrap();

        let gcfg = GpuConfig::matched(&mpu_cfg);
        let mut g = GpuMachine::new(&gcfg);
        let gx = g.alloc(n * 4);
        let gy = g.alloc(n * 4);
        g.write_f32s(gx, &xv);
        g.write_f32s(gy, &yv);
        g.launch(
            k,
            crate::isa::LaunchConfig::new(32, 128),
            &[
                ParamValue::U32(gx as u32),
                ParamValue::U32(gy as u32),
                ParamValue::F32(3.0),
                ParamValue::U32(n as u32),
            ],
        )
        .unwrap();
        let gpu_stats = g.run().unwrap();

        let speedup = gpu_stats.cycles as f64 / mpu_stats.cycles as f64;
        assert!(speedup > 1.5, "MPU speedup only {speedup:.2}× ({} vs {})", mpu_stats.cycles, gpu_stats.cycles);
    }
}
