//! Compute-centric baselines built on the shared SIMT frontend
//! ([`crate::core::frontend`]): the V100-like GPU (DESIGN.md §2
//! substitution for the authors' Tesla V100 measurements) with an L2 +
//! HBM bandwidth-pipe memory system, and the ideal-bandwidth roofline
//! machine (infinite bandwidth, fixed latency) that bounds every real
//! memory system from below.

pub mod ideal;
pub mod machine;

pub use ideal::IdealMachine;
pub use machine::GpuMachine;
