//! Compute-centric GPU baseline (DESIGN.md §2 substitution for the
//! authors' Tesla V100 measurements): the same SIMT front end as the MPU
//! model, but with a conventional memory hierarchy — coalesced accesses
//! go through an L2 model and a shared HBM bandwidth pipe with long
//! latency, and all data lands in the (far-bank) register file.

pub mod machine;

pub use machine::GpuMachine;
