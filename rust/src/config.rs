//! Machine configuration: the paper's Table II, plus a scaled-down default
//! used by tests and benches (same ratios, smaller geometry — see
//! DESIGN.md §3).

/// Where instructions may execute (paper §IV-B, §VI-C/D ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq, serde::Serialize)]
pub enum PipelineMode {
    /// Full MPU hybrid pipeline with instruction offloading (the paper).
    Hybrid,
    /// Processing-on-base-logic-die baseline: every instruction executes
    /// far-bank; all DRAM data crosses the TSVs (Fig. 13).
    PonB,
}

/// Instruction-location policy used at issue time (Fig. 15).
#[derive(Clone, Copy, Debug, PartialEq, Eq, serde::Serialize)]
pub enum OffloadPolicy {
    /// Use the compiler's Algorithm-1 annotations (the paper's proposal).
    CompilerAnnotated,
    /// Hardware default: offload when all source registers have valid
    /// near-bank copies (register-track-table policy, §IV-B1).
    HardwareDefault,
    /// Naive: offload every offloadable instruction near-bank.
    AllNearBank,
    /// Naive: keep every instruction far-bank.
    AllFarBank,
    /// Consult the explicit per-kernel, per-pc [`OffloadPolicyTable`]
    /// first; instructions the table leaves `U` fall back to the
    /// compiler annotation, then to the hardware default — so an empty
    /// table reproduces `CompilerAnnotated` exactly. This is the policy
    /// the `mpu tune` autotuner searches over.
    Explicit,
}

/// An explicit offload policy: per-kernel, per-pc `Loc` overrides.
///
/// This is the artifact the autotuner searches over. `BTreeMap`s (not
/// hash maps) keep the serde output deterministically ordered, so the
/// table folds into the FNV-1a config fingerprint stably: every
/// candidate policy is just another config hash, and the `SimCache` /
/// `DiskStore` / federation layers dedup its evaluation for free.
#[derive(Clone, Debug, Default, PartialEq, Eq, serde::Serialize, serde::Deserialize)]
pub struct OffloadPolicyTable {
    /// kernel name -> (pc -> forced location). `Loc::U` entries are
    /// legal and mean "no override at this pc".
    pub kernels: std::collections::BTreeMap<String, std::collections::BTreeMap<u32, crate::isa::instr::Loc>>,
}

impl OffloadPolicyTable {
    /// True when no kernel carries any override.
    pub fn is_empty(&self) -> bool {
        self.kernels.values().all(|m| m.is_empty())
    }

    /// Force `loc` at `pc` of `kernel` (overwrites a previous entry).
    pub fn set(&mut self, kernel: &str, pc: u32, loc: crate::isa::instr::Loc) {
        self.kernels.entry(kernel.to_string()).or_default().insert(pc, loc);
    }

    /// Resolve the table into a dense per-pc vector for one kernel
    /// (`Loc::U` where the table has no entry). Out-of-range pcs are
    /// ignored rather than erroring: a table tuned for one kernel
    /// version stays harmless on another.
    pub fn resolve(&self, kernel: &str, n_ops: usize) -> Vec<crate::isa::instr::Loc> {
        let mut dense = vec![crate::isa::instr::Loc::U; n_ops];
        if let Some(m) = self.kernels.get(kernel) {
            for (&pc, &loc) in m {
                if let Some(slot) = dense.get_mut(pc as usize) {
                    *slot = loc;
                }
            }
        }
        dense
    }
}

/// Shared-memory placement (Fig. 11 ablation; §IV-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq, serde::Serialize)]
pub enum SmemLocation {
    /// Near-bank shared memory on the DRAM die (horizontal core
    /// structure; the paper's design).
    NearBank,
    /// Shared memory on the base logic die (vertical structure baseline).
    FarBank,
}

/// Warp scheduling discipline (GTO is the paper's implicit default; RR is
/// an extension ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, serde::Serialize)]
pub enum SchedPolicy {
    /// Greedy-then-oldest.
    Gto,
    /// Loose round-robin.
    RoundRobin,
}

/// DRAM timing parameters, in memory-controller cycles (Table II row
/// `tRCD/tCCD/tRTP/tRP/tRAS/tRFC/tREFI`).
#[derive(Clone, Copy, Debug, serde::Serialize)]
pub struct DramTiming {
    pub t_rcd: u64,
    pub t_ccd: u64,
    pub t_rtp: u64,
    pub t_rp: u64,
    pub t_ras: u64,
    pub t_rfc: u64,
    pub t_refi: u64,
    /// Column (CAS) latency from RD command to data, not separately listed
    /// in Table II; HBM-class value.
    pub t_cl: u64,
}

impl Default for DramTiming {
    fn default() -> Self {
        DramTiming { t_rcd: 14, t_ccd: 2, t_rtp: 4, t_rp: 14, t_ras: 33, t_rfc: 350, t_refi: 3900, t_cl: 14 }
    }
}

/// Per-access / per-bit energy coefficients in joules (Table II rows
/// `RD,WR/PRE,ACT/REF/RF/SMEM` and `TSV / (on)off-chip bus`).
#[derive(Clone, Copy, Debug, serde::Serialize)]
pub struct EnergyCoeffs {
    /// DRAM read or write, J per 256-bit column access.
    pub dram_rdwr: f64,
    /// DRAM precharge+activate pair, J per event.
    pub dram_preact: f64,
    /// DRAM refresh, J per event.
    pub dram_ref: f64,
    /// Register-file access, J per 32-bit access.
    pub rf: f64,
    /// Shared-memory access, J per 32-bit access.
    pub smem: f64,
    /// Operand collector, J per operand.
    pub operand_collector: f64,
    /// LSU-Extension, J per request.
    pub lsu_ext: f64,
    /// TSV, J per bit.
    pub tsv_bit: f64,
    /// On-chip (mesh) bus, J per bit.
    pub onchip_bit: f64,
    /// Off-chip (SERDES) bus, J per bit.
    pub offchip_bit: f64,
    /// Vector-ALU op, J per 32-bit lane-op (measured PTX numbers [8,9]).
    pub alu_op: f64,
    /// Front-pipeline (fetch/decode/issue/scoreboard) J per instruction.
    pub frontend_instr: f64,
}

impl Default for EnergyCoeffs {
    fn default() -> Self {
        EnergyCoeffs {
            dram_rdwr: 0.15e-9,
            dram_preact: 0.27e-9,
            dram_ref: 1.13e-9,
            rf: 40.0e-12,
            smem: 22.2e-12,
            operand_collector: 41.49e-12,
            lsu_ext: 39.67e-12,
            tsv_bit: 4.53e-12,
            onchip_bit: 0.72e-12,
            offchip_bit: 4.50e-12,
            alu_op: 20.0e-12,
            frontend_instr: 60.0e-12,
        }
    }
}

/// Full machine configuration (Table II + ablation knobs).
#[derive(Clone, Debug, serde::Serialize)]
pub struct MachineConfig {
    // ---- geometry ----
    /// Number of 3D-stacked processors (cubes).
    pub processors: usize,
    /// SIMT cores per processor (on the base logic die).
    pub cores_per_proc: usize,
    /// Subcores per core.
    pub subcores_per_core: usize,
    /// Near-bank units per core (one per subcore in the paper).
    pub nbus_per_core: usize,
    /// DRAM banks behind each NBU's memory controller.
    pub banks_per_nbu: usize,
    /// Simultaneously activated row-buffers per bank (MASA; 1 disables).
    pub row_buffers_per_bank: usize,

    // ---- SIMT ----
    /// Threads per warp (Table II: SIMT 32).
    pub warp_size: usize,
    /// Maximum resident warps per subcore.
    pub max_warps_per_subcore: usize,
    /// Instructions issued per subcore per cycle.
    pub issue_width: usize,

    // ---- capacities (bytes) ----
    /// DRAM bank capacity.
    pub bank_bytes: usize,
    /// DRAM row (page) size per bank.
    pub row_bytes: usize,
    /// Bank column-IO width in bits (Table II: 256 b).
    pub bank_io_bits: usize,
    /// Far-bank register file per subcore.
    pub fb_rf_bytes: usize,
    /// Near-bank register file per NBU (half of far-bank; §VI-B).
    pub nb_rf_bytes: usize,
    /// Shared memory per core.
    pub smem_bytes: usize,

    // ---- interconnect ----
    /// TSV data-bus width per core, bits (Table II: 64 b buses, 1024 per
    /// stack).
    pub tsv_bits_per_core: usize,
    /// TSV clock relative to core clock (fTSV/fCore = 2).
    pub tsv_clock_mult: u64,
    /// Mesh link width, bits (on-chip bus 256 b).
    pub mesh_link_bits: usize,
    /// Mesh per-hop latency in core cycles.
    pub mesh_hop_latency: u64,
    /// Off-chip (inter-processor) link width, bits.
    pub offchip_link_bits: usize,
    /// Off-chip serialization + flight latency, core cycles.
    pub offchip_latency: u64,

    // ---- latencies (core cycles) ----
    /// ALU latency for simple int/fp ops.
    pub alu_latency: u64,
    /// Latency of special ops (div/sqrt).
    pub sfu_latency: u64,
    /// Operand-collector latency.
    pub opc_latency: u64,
    /// Shared-memory access latency (near-bank).
    pub smem_latency: u64,
    /// One-way TSV latency (command/packet), core cycles.
    pub tsv_latency: u64,
    /// Offloaded-instruction packet size on the TSVs (64-bit encoded
    /// instruction: opcode + register ids + SIMT mask), bytes.
    pub offload_packet_bytes: u64,

    // ---- models / policies ----
    pub timing: DramTiming,
    pub energy: EnergyCoeffs,
    pub pipeline_mode: PipelineMode,
    pub offload_policy: OffloadPolicy,
    /// Explicit per-kernel, per-pc overrides, consulted only under
    /// [`OffloadPolicy::Explicit`]. Serialized with the rest of the
    /// config, so a different table means a different fingerprint.
    pub offload_table: OffloadPolicyTable,
    pub smem_location: SmemLocation,
    pub sched_policy: SchedPolicy,
    /// Interleave consecutive DRAM rows across subarrays so MASA
    /// row-buffers capture streaming (§IV-C). Turn off to ablate.
    pub subarray_interleave: bool,
    /// Maximum thread blocks resident per core.
    pub max_blocks_per_core: usize,
    /// Address-interleave granularity across (nbu, bank) in bytes.
    pub interleave_bytes: usize,
    /// Safety valve for the simulator: abort after this many cycles.
    pub max_cycles: u64,
}

impl MachineConfig {
    /// The paper's full Table-II configuration:
    /// `Proc/(3D,Core)/(Subcore,NBU/Bank/RowBuf) = 8/(4,16)/(4,4/4/4)`.
    pub fn paper() -> Self {
        MachineConfig {
            processors: 8,
            cores_per_proc: 16,
            subcores_per_core: 4,
            nbus_per_core: 4,
            banks_per_nbu: 4,
            row_buffers_per_bank: 4,
            warp_size: 32,
            max_warps_per_subcore: 16,
            issue_width: 1,
            bank_bytes: 16 << 20,
            row_bytes: 2048,
            bank_io_bits: 256,
            fb_rf_bytes: 32 << 10,
            nb_rf_bytes: 16 << 10,
            smem_bytes: 64 << 10,
            tsv_bits_per_core: 64,
            tsv_clock_mult: 2,
            mesh_link_bits: 256,
            mesh_hop_latency: 2,
            offchip_link_bits: 128,
            offchip_latency: 32,
            alu_latency: 4,
            sfu_latency: 16,
            opc_latency: 2,
            smem_latency: 8,
            tsv_latency: 2,
            offload_packet_bytes: 8,
            timing: DramTiming::default(),
            energy: EnergyCoeffs::default(),
            pipeline_mode: PipelineMode::Hybrid,
            offload_policy: OffloadPolicy::CompilerAnnotated,
            offload_table: OffloadPolicyTable::default(),
            smem_location: SmemLocation::NearBank,
            sched_policy: SchedPolicy::Gto,
            subarray_interleave: true,
            max_blocks_per_core: 8,
            interleave_bytes: 256,
            max_cycles: 2_000_000_000,
        }
    }

    /// Scaled-down configuration for tests/benches: 1 processor, 4 cores,
    /// same per-core geometry and all the same ratios (DESIGN.md §3).
    pub fn scaled() -> Self {
        let mut c = Self::paper();
        c.processors = 1;
        c.cores_per_proc = 4;
        c.bank_bytes = 1 << 20;
        c.max_cycles = 200_000_000;
        c
    }

    /// The PIM-style "MPU without instruction offload" variant: the same
    /// near-bank memory system (loads still land in the near-bank RF,
    /// coalesced accesses still qualify for LSU offload), but every ALU
    /// instruction is forced onto the base logic die, so far-bank
    /// compute must pull loaded values up over the TSVs. The third
    /// column of the Fig.-8-style comparison.
    pub fn no_offload(&self) -> Self {
        let mut c = self.clone();
        c.offload_policy = OffloadPolicy::AllFarBank;
        c
    }

    /// Total cores in the machine.
    pub fn total_cores(&self) -> usize {
        self.processors * self.cores_per_proc
    }

    /// Total DRAM banks in the machine.
    pub fn total_banks(&self) -> usize {
        self.total_cores() * self.nbus_per_core * self.banks_per_nbu
    }

    /// Total global-memory capacity in bytes.
    pub fn total_mem_bytes(&self) -> usize {
        self.total_banks() * self.bank_bytes
    }

    /// Peak bank-level bandwidth in bytes per core-cycle for the whole
    /// machine (each bank moves `bank_io_bits` per `tCCD`).
    pub fn peak_bank_bytes_per_cycle(&self) -> f64 {
        self.total_banks() as f64 * (self.bank_io_bits as f64 / 8.0) / self.timing.t_ccd as f64
    }

    /// Peak TSV bandwidth in bytes per core-cycle for the whole machine.
    pub fn peak_tsv_bytes_per_cycle(&self) -> f64 {
        self.total_cores() as f64 * (self.tsv_bits_per_core as f64 / 8.0) * self.tsv_clock_mult as f64
    }

    /// Apply a `key=value` override (used by the CLI). Returns an error
    /// string on unknown keys or malformed values.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn p<T: std::str::FromStr>(v: &str) -> Result<T, String> {
            v.parse::<T>().map_err(|_| format!("bad value `{v}`"))
        }
        match key {
            "processors" => self.processors = p(value)?,
            "cores_per_proc" => self.cores_per_proc = p(value)?,
            "subcores_per_core" => self.subcores_per_core = p(value)?,
            "nbus_per_core" => self.nbus_per_core = p(value)?,
            "banks_per_nbu" => self.banks_per_nbu = p(value)?,
            "row_buffers_per_bank" => self.row_buffers_per_bank = p(value)?,
            "max_warps_per_subcore" => self.max_warps_per_subcore = p(value)?,
            "max_blocks_per_core" => self.max_blocks_per_core = p(value)?,
            "row_bytes" => self.row_bytes = p(value)?,
            "interleave_bytes" => self.interleave_bytes = p(value)?,
            "subarray_interleave" => self.subarray_interleave = p(value)?,
            "pipeline_mode" => {
                self.pipeline_mode = match value {
                    "hybrid" => PipelineMode::Hybrid,
                    "ponb" => PipelineMode::PonB,
                    _ => return Err(format!("bad pipeline_mode `{value}`")),
                }
            }
            "offload_policy" => {
                self.offload_policy = match value {
                    "annotated" => OffloadPolicy::CompilerAnnotated,
                    "hw" => OffloadPolicy::HardwareDefault,
                    "all_nb" => OffloadPolicy::AllNearBank,
                    "all_fb" => OffloadPolicy::AllFarBank,
                    "explicit" => OffloadPolicy::Explicit,
                    _ => return Err(format!("bad offload_policy `{value}`")),
                }
            }
            // The federation wire format for candidate policies: configs
            // travel as `key=value` string pairs, so the table rides as
            // its canonical JSON.
            "offload_table" => {
                self.offload_table = serde_json::from_str(value)
                    .map_err(|e| format!("bad offload_table JSON: {e}"))?
            }
            "smem_location" => {
                self.smem_location = match value {
                    "near" => SmemLocation::NearBank,
                    "far" => SmemLocation::FarBank,
                    _ => return Err(format!("bad smem_location `{value}`")),
                }
            }
            "sched" => {
                self.sched_policy = match value {
                    "gto" => SchedPolicy::Gto,
                    "rr" => SchedPolicy::RoundRobin,
                    _ => return Err(format!("bad sched `{value}`")),
                }
            }
            _ => return Err(format!("unknown config key `{key}`")),
        }
        Ok(())
    }
}

/// V100-like GPU baseline configuration (DESIGN.md §2 substitution).
///
/// The model keeps the *per-SM* ratios of a Tesla V100 (80 SMs sharing
/// 900 GB/s of HBM2, ~400-cycle memory latency) but is instantiated with
/// the same number of SMs as the MPU config has cores so runtimes compare
/// one-to-one.
#[derive(Clone, Debug, serde::Serialize)]
pub struct GpuConfig {
    /// Streaming multiprocessors.
    pub sms: usize,
    pub subcores_per_sm: usize,
    pub warp_size: usize,
    pub max_warps_per_subcore: usize,
    pub max_blocks_per_sm: usize,
    /// HBM bandwidth in bytes per core cycle, whole chip.
    pub hbm_bytes_per_cycle: f64,
    /// Average DRAM access latency (core cycles).
    pub mem_latency: u64,
    /// L2 hit latency.
    pub l2_latency: u64,
    /// Fraction of accesses served by L2 (streaming workloads: low).
    pub l2_hit_rate: f64,
    pub alu_latency: u64,
    pub sfu_latency: u64,
    pub smem_latency: u64,
    pub smem_bytes: usize,
    pub energy: GpuEnergyCoeffs,
    pub sched_policy: SchedPolicy,
    pub max_cycles: u64,
}

/// GPU baseline energy coefficients: the long compute-centric data path
/// (HBM cell → TSV → off-chip PHY → L2 → crossbar → L1 → RF), per §VI-B's
/// narrative, built from the same Table-II primitives.
#[derive(Clone, Copy, Debug, serde::Serialize)]
pub struct GpuEnergyCoeffs {
    /// DRAM cell read/write, J per 256-bit access (same cell energy).
    pub dram_rdwr: f64,
    pub dram_preact: f64,
    /// HBM-internal TSV traversal, J per bit.
    pub tsv_bit: f64,
    /// Interposer/off-chip PHY, J per bit.
    pub phy_bit: f64,
    /// L2 + crossbar + L1 path, J per bit.
    pub cache_path_bit: f64,
    pub rf: f64,
    pub smem: f64,
    pub operand_collector: f64,
    pub alu_op: f64,
    pub frontend_instr: f64,
}

impl Default for GpuEnergyCoeffs {
    fn default() -> Self {
        GpuEnergyCoeffs {
            dram_rdwr: 0.15e-9,
            dram_preact: 0.27e-9,
            tsv_bit: 4.53e-12,
            phy_bit: 4.50e-12,
            cache_path_bit: 3.00e-12,
            rf: 40.0e-12,
            smem: 22.2e-12,
            operand_collector: 41.49e-12,
            alu_op: 20.0e-12,
            frontend_instr: 60.0e-12,
        }
    }
}

/// Configuration of the ideal-bandwidth roofline machine: the GPU
/// baseline's SIMT geometry with an infinite-bandwidth, fixed-latency
/// memory system (every speedup plot's "how far from the wall" column).
#[derive(Clone, Debug, serde::Serialize)]
pub struct IdealConfig {
    pub sms: usize,
    pub subcores_per_sm: usize,
    pub warp_size: usize,
    pub max_warps_per_subcore: usize,
    pub max_blocks_per_sm: usize,
    /// Fixed latency of every global access (core cycles); bandwidth is
    /// unlimited.
    pub mem_latency: u64,
    pub alu_latency: u64,
    pub sfu_latency: u64,
    pub smem_latency: u64,
    pub smem_bytes: usize,
    pub energy: GpuEnergyCoeffs,
    pub sched_policy: SchedPolicy,
    pub max_cycles: u64,
}

impl IdealConfig {
    /// Roofline matched to an MPU machine config: same SM count as MPU
    /// cores, a short fixed memory latency (an L1-hit-class 40 cycles),
    /// no bandwidth limit. Every *frontend* latency deliberately equals
    /// the [`GpuConfig::matched`] baseline's, so the ideal-vs-GPU gap
    /// measures the memory system alone.
    pub fn matched(mpu: &MachineConfig) -> Self {
        let gpu = GpuConfig::matched(mpu);
        IdealConfig {
            sms: gpu.sms,
            subcores_per_sm: gpu.subcores_per_sm,
            warp_size: gpu.warp_size,
            max_warps_per_subcore: gpu.max_warps_per_subcore,
            max_blocks_per_sm: gpu.max_blocks_per_sm,
            mem_latency: 40,
            alu_latency: gpu.alu_latency,
            sfu_latency: gpu.sfu_latency,
            smem_latency: gpu.smem_latency,
            smem_bytes: gpu.smem_bytes,
            energy: gpu.energy,
            sched_policy: gpu.sched_policy,
            max_cycles: gpu.max_cycles,
        }
    }

    /// Total ALU lanes across the machine.
    pub fn total_lanes(&self) -> usize {
        self.sms * self.subcores_per_sm * self.warp_size
    }
}

/// The machine variants the sweep engine / CLI can target, all built on
/// the shared SIMT frontend ([`crate::core::frontend`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MachineKind {
    /// The paper's MPU (hybrid near-bank pipeline).
    Mpu,
    /// V100-like compute-centric baseline.
    Gpu,
    /// Infinite-bandwidth, fixed-latency roofline.
    IdealBw,
    /// MPU memory system with instruction offload forced off (PIM-style).
    MpuNoOffload,
}

impl MachineKind {
    pub const ALL: [MachineKind; 4] =
        [MachineKind::Mpu, MachineKind::Gpu, MachineKind::IdealBw, MachineKind::MpuNoOffload];

    /// Stable lower-case name (sweep labels, JSON, CLI).
    pub fn name(&self) -> &'static str {
        match self {
            MachineKind::Mpu => "mpu",
            MachineKind::Gpu => "gpu",
            MachineKind::IdealBw => "ideal",
            MachineKind::MpuNoOffload => "mpu_nooff",
        }
    }

    pub fn from_name(s: &str) -> Option<MachineKind> {
        MachineKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// One resolvable serving knob: the CLI flag that sets it, the `MPU_*`
/// environment variable behind it, the built-in default (as the string
/// the parser would accept), and the `--help` line. [`SERVE_KNOBS`] is
/// the single table driving parsing, precedence and help text.
pub struct Knob {
    pub flag: &'static str,
    pub env: &'static str,
    pub default: &'static str,
    pub help: &'static str,
}

/// Every serving knob, resolved with precedence **CLI flag > `MPU_*`
/// env > default** by [`ServeConfigBuilder`].
pub const SERVE_KNOBS: &[Knob] = &[
    Knob {
        flag: "--addr",
        env: "MPU_ADDR",
        default: "127.0.0.1:7117",
        help: "daemon listen / client connect address",
    },
    Knob {
        flag: "--store",
        env: "MPU_STORE_DIR",
        default: ".mpu-store",
        help: "on-disk result-store root (empty disables the persistent tier)",
    },
    Knob {
        flag: "--store-max-mb",
        env: "MPU_STORE_MAX_MB",
        default: "512",
        help: "store size cap in MiB",
    },
    Knob {
        flag: "--workers",
        env: "MPU_WORKERS",
        default: "",
        help: "comma-separated worker addresses (serve: coordinator mode; submit: client-side federation)",
    },
    Knob {
        flag: "--connect-timeout-ms",
        env: "MPU_CONNECT_TIMEOUT_MS",
        default: "5000",
        help: "TCP connect deadline for client and federation sockets",
    },
    Knob {
        flag: "--io-timeout-ms",
        env: "MPU_IO_TIMEOUT_MS",
        default: "300000",
        help: "read/write deadline on streamed and probe sockets",
    },
    Knob {
        flag: "--retries",
        env: "MPU_RETRIES",
        default: "4",
        help: "attempts per socket operation before a failure is fatal/dead",
    },
    Knob {
        flag: "--backoff-ms",
        env: "MPU_BACKOFF_MS",
        default: "50",
        help: "base retry backoff; grows exponentially with seeded jitter",
    },
    Knob {
        flag: "--max-queue",
        env: "MPU_MAX_QUEUE",
        default: "4096",
        help: "admission cap on queued points before submits get `busy` (0 = unbounded)",
    },
    Knob {
        flag: "--faults",
        env: "MPU_FAULTS",
        default: "",
        help: "deterministic fault-injection spec (empty disables the chaos plane)",
    },
    Knob {
        flag: "--client-id",
        env: "MPU_CLIENT_ID",
        default: "",
        help: "client identity for fair-share scheduling (empty = anonymous)",
    },
    Knob {
        flag: "--max-client-queue",
        env: "MPU_MAX_CLIENT_QUEUE",
        default: "0",
        help: "per-client admission cap on queued points (0 = unbounded)",
    },
    Knob {
        flag: "--client-weights",
        env: "MPU_CLIENT_WEIGHTS",
        default: "",
        help: "deficit-round-robin weights, e.g. `alice=3,bob=1` (unlisted clients weigh 1)",
    },
    Knob {
        flag: "--coordinator",
        env: "MPU_COORDINATOR",
        default: "",
        help: "coordinator address a worker self-registers with (join on boot, drain on shutdown)",
    },
];

/// Where a knob's resolved value came from (precedence order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnobOrigin {
    Cli,
    Env,
    Default,
}

/// Resolves [`SERVE_KNOBS`] into a [`ServeConfig`] with precedence CLI
/// flag > `MPU_*` env > default. A malformed **CLI** value is an error
/// (the operator typed it just now); a malformed **environment** value
/// falls back to the default (a daemon must boot even under a junk
/// environment — the historical `from_env` behavior).
pub struct ServeConfigBuilder {
    cli: Vec<(String, String)>,
    env: Box<dyn Fn(&str) -> Option<String>>,
}

impl ServeConfigBuilder {
    /// Record a CLI override for `flag` (a no-op on `None`, so call
    /// sites can pass `flag_value(..)` straight through). Panics on a
    /// flag absent from [`SERVE_KNOBS`] — that is a programming error,
    /// not operator input.
    pub fn cli_flag(mut self, flag: &str, value: Option<String>) -> Self {
        assert!(
            SERVE_KNOBS.iter().any(|k| k.flag == flag),
            "unknown serve knob `{flag}`"
        );
        if let Some(v) = value {
            self.cli.push((flag.to_string(), v));
        }
        self
    }

    /// Replace the environment source (tests inject a map here instead
    /// of racing on the real process environment).
    pub fn env_source(mut self, f: impl Fn(&str) -> Option<String> + 'static) -> Self {
        self.env = Box::new(f);
        self
    }

    /// The raw resolved string for `flag` and where it came from.
    pub fn raw(&self, flag: &str) -> (String, KnobOrigin) {
        let knob = SERVE_KNOBS
            .iter()
            .find(|k| k.flag == flag)
            .unwrap_or_else(|| panic!("unknown serve knob `{flag}`"));
        if let Some((_, v)) = self.cli.iter().rev().find(|(f, _)| f == flag) {
            return (v.clone(), KnobOrigin::Cli);
        }
        if let Some(v) = (self.env)(knob.env) {
            return (v, KnobOrigin::Env);
        }
        (knob.default.to_string(), KnobOrigin::Default)
    }

    fn u64_knob(&self, flag: &str) -> anyhow::Result<u64> {
        let (raw, origin) = self.raw(flag);
        match raw.trim().parse::<u64>() {
            Ok(v) => Ok(v),
            Err(_) if origin == KnobOrigin::Env => {
                let knob = SERVE_KNOBS.iter().find(|k| k.flag == flag).unwrap();
                Ok(knob.default.parse().expect("table defaults parse"))
            }
            Err(_) => anyhow::bail!("{flag} needs an unsigned integer, got `{raw}`"),
        }
    }

    /// An optional-string knob: empty resolves to `None`.
    fn opt_knob(&self, flag: &str) -> Option<String> {
        let (raw, _) = self.raw(flag);
        let raw = raw.trim().to_string();
        (!raw.is_empty()).then_some(raw)
    }

    pub fn build(self) -> anyhow::Result<ServeConfig> {
        let weights = {
            let (raw, origin) = self.raw("--client-weights");
            match ServeConfig::parse_client_weights(&raw) {
                Ok(w) => w,
                Err(_) if origin == KnobOrigin::Env => std::collections::HashMap::new(),
                Err(e) => anyhow::bail!("--client-weights: {e}"),
            }
        };
        Ok(ServeConfig {
            addr: self.raw("--addr").0,
            store_dir: self.opt_knob("--store").map(std::path::PathBuf::from),
            store_max_bytes: self.u64_knob("--store-max-mb")? * 1024 * 1024,
            workers: ServeConfig::parse_workers(&self.raw("--workers").0),
            connect_timeout: std::time::Duration::from_millis(
                self.u64_knob("--connect-timeout-ms")?,
            ),
            io_timeout: std::time::Duration::from_millis(self.u64_knob("--io-timeout-ms")?),
            retries: (self.u64_knob("--retries")? as u32).max(1),
            backoff: std::time::Duration::from_millis(self.u64_knob("--backoff-ms")?),
            max_queue: self.u64_knob("--max-queue")? as usize,
            faults: self.opt_knob("--faults"),
            client_id: self.opt_knob("--client-id"),
            max_client_queue: self.u64_knob("--max-client-queue")? as usize,
            client_weights: weights,
            coordinator: self.opt_knob("--coordinator"),
        })
    }
}

/// Defaults for the sweep service (`mpu serve` / `submit` / `status`),
/// overridable by environment and then by CLI flags — see
/// [`SERVE_KNOBS`] for the full table.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Daemon listen / client connect address (`MPU_ADDR`).
    pub addr: String,
    /// On-disk result-store root (`MPU_STORE_DIR`); `None` disables the
    /// persistent tier.
    pub store_dir: Option<std::path::PathBuf>,
    /// Store size cap in bytes (`MPU_STORE_MAX_MB`).
    pub store_max_bytes: u64,
    /// Worker daemon addresses (`MPU_WORKERS`, comma-separated). When
    /// non-empty, `mpu serve` runs as a federation coordinator and
    /// `mpu submit` fans out client-side instead of talking to one
    /// daemon.
    pub workers: Vec<String>,
    /// TCP connect deadline for client and federation sockets
    /// (`MPU_CONNECT_TIMEOUT_MS`).
    pub connect_timeout: std::time::Duration,
    /// Read/write deadline on streamed and probe sockets
    /// (`MPU_IO_TIMEOUT_MS`). Generous by default — a cold tiny suite
    /// takes seconds, a large fresh batch minutes.
    pub io_timeout: std::time::Duration,
    /// Attempts per socket operation before a failure is treated as
    /// fatal/dead (`MPU_RETRIES`).
    pub retries: u32,
    /// Base backoff delay between retries (`MPU_BACKOFF_MS`); grows
    /// exponentially with seeded jitter, capped internally.
    pub backoff: std::time::Duration,
    /// Admission cap on queued points before submits get `busy`
    /// (`MPU_MAX_QUEUE`); 0 disables the cap.
    pub max_queue: usize,
    /// Fault-injection spec (`MPU_FAULTS`); `None` disables the chaos
    /// plane.
    pub faults: Option<String>,
    /// Client identity stamped onto submits (`MPU_CLIENT_ID`); `None`
    /// lands in the server's anonymous fair-share bucket.
    pub client_id: Option<String>,
    /// Per-client admission cap on queued points
    /// (`MPU_MAX_CLIENT_QUEUE`); 0 disables the cap.
    pub max_client_queue: usize,
    /// Deficit-round-robin weights per client id
    /// (`MPU_CLIENT_WEIGHTS`, `alice=3,bob=1`); unlisted clients
    /// weigh 1.
    pub client_weights: std::collections::HashMap<String, u64>,
    /// Coordinator address a worker self-registers with
    /// (`MPU_COORDINATOR`): `join` once serving, `drain` on graceful
    /// shutdown.
    pub coordinator: Option<String>,
}

impl ServeConfig {
    /// Start resolving [`SERVE_KNOBS`] against the real process
    /// environment (override with
    /// [`env_source`](ServeConfigBuilder::env_source)).
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cli: Vec::new(), env: Box::new(|key| std::env::var(key).ok()) }
    }

    /// Built-in defaults with environment overrides applied — the
    /// no-CLI case of [`ServeConfig::builder`], which cannot fail
    /// (malformed environment values fall back to the defaults).
    pub fn from_env() -> ServeConfig {
        Self::builder().build().expect("no CLI overrides: resolution is infallible")
    }

    /// The serving-knob section of `--help`, rendered from
    /// [`SERVE_KNOBS`] so flags, environment variables, defaults and
    /// help text cannot drift apart.
    pub fn knob_help() -> String {
        let width = SERVE_KNOBS.iter().map(|k| k.flag.len()).max().unwrap_or(0);
        SERVE_KNOBS
            .iter()
            .map(|k| {
                let default = if k.default.is_empty() { "(empty)" } else { k.default };
                format!(
                    "  {:<width$}  {} [{}, default {}]",
                    k.flag, k.help, k.env, default
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Split a comma-separated worker list, dropping empty segments.
    pub fn parse_workers(s: &str) -> Vec<String> {
        s.split(',').map(|w| w.trim().to_string()).filter(|w| !w.is_empty()).collect()
    }

    /// Parse a `client=weight,...` list. Weights clamp to ≥ 1 (a
    /// zero-weight client would never be scheduled at all — quotas are
    /// the starvation tool, not weights).
    pub fn parse_client_weights(
        s: &str,
    ) -> anyhow::Result<std::collections::HashMap<String, u64>> {
        let mut out = std::collections::HashMap::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((client, weight)) = part.split_once('=') else {
                anyhow::bail!("`{part}` is not a client=weight pair");
            };
            let client = client.trim();
            anyhow::ensure!(!client.is_empty(), "`{part}` has an empty client id");
            let weight: u64 = weight
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("`{part}` has a non-integer weight"))?;
            out.insert(client.to_string(), weight.max(1));
        }
        Ok(out)
    }
}

impl GpuConfig {
    /// Total ALU lanes across the chip (the Fig.-1 ALU-utilization
    /// denominator — single source of truth for machine and benches).
    pub fn total_lanes(&self) -> usize {
        self.sms * self.subcores_per_sm * self.warp_size
    }

    /// Baseline matched to an MPU machine config: same SM count as MPU
    /// cores, V100 per-SM bandwidth share (900 GB/s / 80 SMs @ ~1.4 GHz
    /// ≈ 8 B/cycle/SM).
    pub fn matched(mpu: &MachineConfig) -> Self {
        let sms = mpu.total_cores();
        GpuConfig {
            sms,
            subcores_per_sm: 4,
            warp_size: mpu.warp_size,
            max_warps_per_subcore: 16,
            max_blocks_per_sm: 8,
            hbm_bytes_per_cycle: 8.0 * sms as f64,
            mem_latency: 400,
            l2_latency: 130,
            l2_hit_rate: 0.15,
            alu_latency: 4,
            sfu_latency: 16,
            smem_latency: 24,
            smem_bytes: 96 << 10,
            energy: GpuEnergyCoeffs::default(),
            sched_policy: SchedPolicy::Gto,
            max_cycles: mpu.max_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry_matches_table2() {
        let c = MachineConfig::paper();
        assert_eq!(c.processors, 8);
        assert_eq!(c.cores_per_proc, 16);
        assert_eq!(c.subcores_per_core, 4);
        assert_eq!(c.nbus_per_core, 4);
        assert_eq!(c.banks_per_nbu, 4);
        assert_eq!(c.row_buffers_per_bank, 4);
        assert_eq!(c.warp_size, 32);
        assert_eq!(c.bank_bytes, 16 << 20);
        assert_eq!(c.fb_rf_bytes, 32 << 10);
        assert_eq!(c.nb_rf_bytes, 16 << 10);
        assert_eq!(c.smem_bytes, 64 << 10);
        assert_eq!(c.timing.t_rcd, 14);
        assert_eq!(c.timing.t_rfc, 350);
    }

    #[test]
    fn bank_bandwidth_dwarfs_tsv_bandwidth() {
        // The whole premise of near-bank computing (§III): bank-internal
        // bandwidth is roughly an order of magnitude above TSV bandwidth.
        let c = MachineConfig::paper();
        let ratio = c.peak_bank_bytes_per_cycle() / c.peak_tsv_bytes_per_cycle();
        assert!(ratio >= 8.0, "bank/TSV bandwidth ratio {ratio} too low");
    }

    #[test]
    fn set_overrides_work() {
        let mut c = MachineConfig::scaled();
        c.set("row_buffers_per_bank", "2").unwrap();
        assert_eq!(c.row_buffers_per_bank, 2);
        c.set("offload_policy", "all_nb").unwrap();
        assert_eq!(c.offload_policy, OffloadPolicy::AllNearBank);
        c.set("smem_location", "far").unwrap();
        assert_eq!(c.smem_location, SmemLocation::FarBank);
        assert!(c.set("nonsense", "1").is_err());
        assert!(c.set("sched", "nonsense").is_err());
    }

    #[test]
    fn gpu_matched_has_same_sm_count() {
        let m = MachineConfig::scaled();
        let g = GpuConfig::matched(&m);
        assert_eq!(g.sms, m.total_cores());
        assert!(g.hbm_bytes_per_cycle > 0.0);
    }

    #[test]
    fn machine_kinds_roundtrip_and_cover_four_variants() {
        assert_eq!(MachineKind::ALL.len(), 4);
        for k in MachineKind::ALL {
            assert_eq!(MachineKind::from_name(k.name()), Some(k));
        }
        assert_eq!(MachineKind::from_name("nope"), None);
    }

    #[test]
    fn ideal_matched_and_no_offload_presets() {
        let m = MachineConfig::scaled();
        let i = IdealConfig::matched(&m);
        assert_eq!(i.sms, m.total_cores());
        assert!(i.mem_latency > 0);
        let n = m.no_offload();
        assert_eq!(n.offload_policy, OffloadPolicy::AllFarBank);
        assert_eq!(n.pipeline_mode, m.pipeline_mode, "memory system unchanged");
    }

    /// A builder over an injected (empty or synthetic) environment —
    /// never the real one, so parallel tests cannot race on env vars.
    fn builder_with_env(vars: &[(&str, &str)]) -> ServeConfigBuilder {
        let map: std::collections::HashMap<String, String> =
            vars.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        ServeConfig::builder().env_source(move |key| map.get(key).cloned())
    }

    #[test]
    fn every_knob_resolves_cli_over_env_over_default() {
        for knob in SERVE_KNOBS {
            let empty = builder_with_env(&[]);
            assert_eq!(
                empty.raw(knob.flag),
                (knob.default.to_string(), KnobOrigin::Default),
                "{} without overrides",
                knob.flag
            );
            let env_only = builder_with_env(&[(knob.env, "from-env")]);
            assert_eq!(
                env_only.raw(knob.flag),
                ("from-env".to_string(), KnobOrigin::Env),
                "{} must honor {}",
                knob.flag,
                knob.env
            );
            let both = builder_with_env(&[(knob.env, "from-env")])
                .cli_flag(knob.flag, Some("from-cli".to_string()));
            assert_eq!(
                both.raw(knob.flag),
                ("from-cli".to_string(), KnobOrigin::Cli),
                "{} must prefer the CLI flag over {}",
                knob.flag,
                knob.env
            );
        }
    }

    #[test]
    fn builder_builds_typed_config_with_documented_precedence() {
        let cfg = builder_with_env(&[
            ("MPU_ADDR", "10.0.0.1:9"),
            ("MPU_MAX_QUEUE", "77"),
            ("MPU_CLIENT_WEIGHTS", "alice=3, bob=1"),
        ])
        .cli_flag("--addr", Some("10.0.0.2:9".into()))
        .cli_flag("--max-client-queue", Some("5".into()))
        .build()
        .unwrap();
        assert_eq!(cfg.addr, "10.0.0.2:9", "CLI beats env");
        assert_eq!(cfg.max_queue, 77, "env beats default");
        assert_eq!(cfg.max_client_queue, 5);
        assert_eq!(cfg.client_weights.get("alice"), Some(&3));
        assert_eq!(cfg.client_weights.get("bob"), Some(&1));
        assert_eq!(cfg.client_id, None, "empty default resolves to None");
        assert_eq!(cfg.coordinator, None);
        assert_eq!(cfg.retries, 4);
        assert_eq!(cfg.io_timeout, std::time::Duration::from_millis(300_000));
        assert_eq!(cfg.store_dir.as_deref(), Some(std::path::Path::new(".mpu-store")));
    }

    #[test]
    fn malformed_env_falls_back_but_malformed_cli_errors() {
        // A daemon must boot under a junk environment...
        let cfg = builder_with_env(&[("MPU_MAX_QUEUE", "lots")]).build().unwrap();
        assert_eq!(cfg.max_queue, 4096);
        let cfg = builder_with_env(&[("MPU_CLIENT_WEIGHTS", "not-a-pair")]).build().unwrap();
        assert!(cfg.client_weights.is_empty());
        // ...but an operator typo on the command line is an error.
        let bad = builder_with_env(&[])
            .cli_flag("--max-queue", Some("lots".into()))
            .build();
        assert!(bad.is_err());
        let bad = builder_with_env(&[])
            .cli_flag("--client-weights", Some("alice".into()))
            .build();
        assert!(bad.is_err());
    }

    #[test]
    fn client_weight_parsing_clamps_and_rejects() {
        let w = ServeConfig::parse_client_weights("alice=0, bob=2,, ").unwrap();
        assert_eq!(w.get("alice"), Some(&1), "zero weights clamp to 1");
        assert_eq!(w.get("bob"), Some(&2));
        assert!(ServeConfig::parse_client_weights("=3").is_err());
        assert!(ServeConfig::parse_client_weights("alice=x").is_err());
        assert!(ServeConfig::parse_client_weights("").unwrap().is_empty());
    }

    #[test]
    fn knob_help_covers_every_knob() {
        let help = ServeConfig::knob_help();
        for knob in SERVE_KNOBS {
            assert!(help.contains(knob.flag), "help must mention {}", knob.flag);
            assert!(help.contains(knob.env), "help must mention {}", knob.env);
        }
    }
}
