//! Machine configuration: the paper's Table II, plus a scaled-down default
//! used by tests and benches (same ratios, smaller geometry — see
//! DESIGN.md §3).

/// Where instructions may execute (paper §IV-B, §VI-C/D ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq, serde::Serialize)]
pub enum PipelineMode {
    /// Full MPU hybrid pipeline with instruction offloading (the paper).
    Hybrid,
    /// Processing-on-base-logic-die baseline: every instruction executes
    /// far-bank; all DRAM data crosses the TSVs (Fig. 13).
    PonB,
}

/// Instruction-location policy used at issue time (Fig. 15).
#[derive(Clone, Copy, Debug, PartialEq, Eq, serde::Serialize)]
pub enum OffloadPolicy {
    /// Use the compiler's Algorithm-1 annotations (the paper's proposal).
    CompilerAnnotated,
    /// Hardware default: offload when all source registers have valid
    /// near-bank copies (register-track-table policy, §IV-B1).
    HardwareDefault,
    /// Naive: offload every offloadable instruction near-bank.
    AllNearBank,
    /// Naive: keep every instruction far-bank.
    AllFarBank,
    /// Consult the explicit per-kernel, per-pc [`OffloadPolicyTable`]
    /// first; instructions the table leaves `U` fall back to the
    /// compiler annotation, then to the hardware default — so an empty
    /// table reproduces `CompilerAnnotated` exactly. This is the policy
    /// the `mpu tune` autotuner searches over.
    Explicit,
}

/// An explicit offload policy: per-kernel, per-pc `Loc` overrides.
///
/// This is the artifact the autotuner searches over. `BTreeMap`s (not
/// hash maps) keep the serde output deterministically ordered, so the
/// table folds into the FNV-1a config fingerprint stably: every
/// candidate policy is just another config hash, and the `SimCache` /
/// `DiskStore` / federation layers dedup its evaluation for free.
#[derive(Clone, Debug, Default, PartialEq, Eq, serde::Serialize, serde::Deserialize)]
pub struct OffloadPolicyTable {
    /// kernel name -> (pc -> forced location). `Loc::U` entries are
    /// legal and mean "no override at this pc".
    pub kernels: std::collections::BTreeMap<String, std::collections::BTreeMap<u32, crate::isa::instr::Loc>>,
}

impl OffloadPolicyTable {
    /// True when no kernel carries any override.
    pub fn is_empty(&self) -> bool {
        self.kernels.values().all(|m| m.is_empty())
    }

    /// Force `loc` at `pc` of `kernel` (overwrites a previous entry).
    pub fn set(&mut self, kernel: &str, pc: u32, loc: crate::isa::instr::Loc) {
        self.kernels.entry(kernel.to_string()).or_default().insert(pc, loc);
    }

    /// Resolve the table into a dense per-pc vector for one kernel
    /// (`Loc::U` where the table has no entry). Out-of-range pcs are
    /// ignored rather than erroring: a table tuned for one kernel
    /// version stays harmless on another.
    pub fn resolve(&self, kernel: &str, n_ops: usize) -> Vec<crate::isa::instr::Loc> {
        let mut dense = vec![crate::isa::instr::Loc::U; n_ops];
        if let Some(m) = self.kernels.get(kernel) {
            for (&pc, &loc) in m {
                if let Some(slot) = dense.get_mut(pc as usize) {
                    *slot = loc;
                }
            }
        }
        dense
    }
}

/// Shared-memory placement (Fig. 11 ablation; §IV-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq, serde::Serialize)]
pub enum SmemLocation {
    /// Near-bank shared memory on the DRAM die (horizontal core
    /// structure; the paper's design).
    NearBank,
    /// Shared memory on the base logic die (vertical structure baseline).
    FarBank,
}

/// Warp scheduling discipline (GTO is the paper's implicit default; RR is
/// an extension ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, serde::Serialize)]
pub enum SchedPolicy {
    /// Greedy-then-oldest.
    Gto,
    /// Loose round-robin.
    RoundRobin,
}

/// DRAM timing parameters, in memory-controller cycles (Table II row
/// `tRCD/tCCD/tRTP/tRP/tRAS/tRFC/tREFI`).
#[derive(Clone, Copy, Debug, serde::Serialize)]
pub struct DramTiming {
    pub t_rcd: u64,
    pub t_ccd: u64,
    pub t_rtp: u64,
    pub t_rp: u64,
    pub t_ras: u64,
    pub t_rfc: u64,
    pub t_refi: u64,
    /// Column (CAS) latency from RD command to data, not separately listed
    /// in Table II; HBM-class value.
    pub t_cl: u64,
}

impl Default for DramTiming {
    fn default() -> Self {
        DramTiming { t_rcd: 14, t_ccd: 2, t_rtp: 4, t_rp: 14, t_ras: 33, t_rfc: 350, t_refi: 3900, t_cl: 14 }
    }
}

/// Per-access / per-bit energy coefficients in joules (Table II rows
/// `RD,WR/PRE,ACT/REF/RF/SMEM` and `TSV / (on)off-chip bus`).
#[derive(Clone, Copy, Debug, serde::Serialize)]
pub struct EnergyCoeffs {
    /// DRAM read or write, J per 256-bit column access.
    pub dram_rdwr: f64,
    /// DRAM precharge+activate pair, J per event.
    pub dram_preact: f64,
    /// DRAM refresh, J per event.
    pub dram_ref: f64,
    /// Register-file access, J per 32-bit access.
    pub rf: f64,
    /// Shared-memory access, J per 32-bit access.
    pub smem: f64,
    /// Operand collector, J per operand.
    pub operand_collector: f64,
    /// LSU-Extension, J per request.
    pub lsu_ext: f64,
    /// TSV, J per bit.
    pub tsv_bit: f64,
    /// On-chip (mesh) bus, J per bit.
    pub onchip_bit: f64,
    /// Off-chip (SERDES) bus, J per bit.
    pub offchip_bit: f64,
    /// Vector-ALU op, J per 32-bit lane-op (measured PTX numbers [8,9]).
    pub alu_op: f64,
    /// Front-pipeline (fetch/decode/issue/scoreboard) J per instruction.
    pub frontend_instr: f64,
}

impl Default for EnergyCoeffs {
    fn default() -> Self {
        EnergyCoeffs {
            dram_rdwr: 0.15e-9,
            dram_preact: 0.27e-9,
            dram_ref: 1.13e-9,
            rf: 40.0e-12,
            smem: 22.2e-12,
            operand_collector: 41.49e-12,
            lsu_ext: 39.67e-12,
            tsv_bit: 4.53e-12,
            onchip_bit: 0.72e-12,
            offchip_bit: 4.50e-12,
            alu_op: 20.0e-12,
            frontend_instr: 60.0e-12,
        }
    }
}

/// Full machine configuration (Table II + ablation knobs).
#[derive(Clone, Debug, serde::Serialize)]
pub struct MachineConfig {
    // ---- geometry ----
    /// Number of 3D-stacked processors (cubes).
    pub processors: usize,
    /// SIMT cores per processor (on the base logic die).
    pub cores_per_proc: usize,
    /// Subcores per core.
    pub subcores_per_core: usize,
    /// Near-bank units per core (one per subcore in the paper).
    pub nbus_per_core: usize,
    /// DRAM banks behind each NBU's memory controller.
    pub banks_per_nbu: usize,
    /// Simultaneously activated row-buffers per bank (MASA; 1 disables).
    pub row_buffers_per_bank: usize,

    // ---- SIMT ----
    /// Threads per warp (Table II: SIMT 32).
    pub warp_size: usize,
    /// Maximum resident warps per subcore.
    pub max_warps_per_subcore: usize,
    /// Instructions issued per subcore per cycle.
    pub issue_width: usize,

    // ---- capacities (bytes) ----
    /// DRAM bank capacity.
    pub bank_bytes: usize,
    /// DRAM row (page) size per bank.
    pub row_bytes: usize,
    /// Bank column-IO width in bits (Table II: 256 b).
    pub bank_io_bits: usize,
    /// Far-bank register file per subcore.
    pub fb_rf_bytes: usize,
    /// Near-bank register file per NBU (half of far-bank; §VI-B).
    pub nb_rf_bytes: usize,
    /// Shared memory per core.
    pub smem_bytes: usize,

    // ---- interconnect ----
    /// TSV data-bus width per core, bits (Table II: 64 b buses, 1024 per
    /// stack).
    pub tsv_bits_per_core: usize,
    /// TSV clock relative to core clock (fTSV/fCore = 2).
    pub tsv_clock_mult: u64,
    /// Mesh link width, bits (on-chip bus 256 b).
    pub mesh_link_bits: usize,
    /// Mesh per-hop latency in core cycles.
    pub mesh_hop_latency: u64,
    /// Off-chip (inter-processor) link width, bits.
    pub offchip_link_bits: usize,
    /// Off-chip serialization + flight latency, core cycles.
    pub offchip_latency: u64,

    // ---- latencies (core cycles) ----
    /// ALU latency for simple int/fp ops.
    pub alu_latency: u64,
    /// Latency of special ops (div/sqrt).
    pub sfu_latency: u64,
    /// Operand-collector latency.
    pub opc_latency: u64,
    /// Shared-memory access latency (near-bank).
    pub smem_latency: u64,
    /// One-way TSV latency (command/packet), core cycles.
    pub tsv_latency: u64,
    /// Offloaded-instruction packet size on the TSVs (64-bit encoded
    /// instruction: opcode + register ids + SIMT mask), bytes.
    pub offload_packet_bytes: u64,

    // ---- models / policies ----
    pub timing: DramTiming,
    pub energy: EnergyCoeffs,
    pub pipeline_mode: PipelineMode,
    pub offload_policy: OffloadPolicy,
    /// Explicit per-kernel, per-pc overrides, consulted only under
    /// [`OffloadPolicy::Explicit`]. Serialized with the rest of the
    /// config, so a different table means a different fingerprint.
    pub offload_table: OffloadPolicyTable,
    pub smem_location: SmemLocation,
    pub sched_policy: SchedPolicy,
    /// Interleave consecutive DRAM rows across subarrays so MASA
    /// row-buffers capture streaming (§IV-C). Turn off to ablate.
    pub subarray_interleave: bool,
    /// Maximum thread blocks resident per core.
    pub max_blocks_per_core: usize,
    /// Address-interleave granularity across (nbu, bank) in bytes.
    pub interleave_bytes: usize,
    /// Safety valve for the simulator: abort after this many cycles.
    pub max_cycles: u64,
}

impl MachineConfig {
    /// The paper's full Table-II configuration:
    /// `Proc/(3D,Core)/(Subcore,NBU/Bank/RowBuf) = 8/(4,16)/(4,4/4/4)`.
    pub fn paper() -> Self {
        MachineConfig {
            processors: 8,
            cores_per_proc: 16,
            subcores_per_core: 4,
            nbus_per_core: 4,
            banks_per_nbu: 4,
            row_buffers_per_bank: 4,
            warp_size: 32,
            max_warps_per_subcore: 16,
            issue_width: 1,
            bank_bytes: 16 << 20,
            row_bytes: 2048,
            bank_io_bits: 256,
            fb_rf_bytes: 32 << 10,
            nb_rf_bytes: 16 << 10,
            smem_bytes: 64 << 10,
            tsv_bits_per_core: 64,
            tsv_clock_mult: 2,
            mesh_link_bits: 256,
            mesh_hop_latency: 2,
            offchip_link_bits: 128,
            offchip_latency: 32,
            alu_latency: 4,
            sfu_latency: 16,
            opc_latency: 2,
            smem_latency: 8,
            tsv_latency: 2,
            offload_packet_bytes: 8,
            timing: DramTiming::default(),
            energy: EnergyCoeffs::default(),
            pipeline_mode: PipelineMode::Hybrid,
            offload_policy: OffloadPolicy::CompilerAnnotated,
            offload_table: OffloadPolicyTable::default(),
            smem_location: SmemLocation::NearBank,
            sched_policy: SchedPolicy::Gto,
            subarray_interleave: true,
            max_blocks_per_core: 8,
            interleave_bytes: 256,
            max_cycles: 2_000_000_000,
        }
    }

    /// Scaled-down configuration for tests/benches: 1 processor, 4 cores,
    /// same per-core geometry and all the same ratios (DESIGN.md §3).
    pub fn scaled() -> Self {
        let mut c = Self::paper();
        c.processors = 1;
        c.cores_per_proc = 4;
        c.bank_bytes = 1 << 20;
        c.max_cycles = 200_000_000;
        c
    }

    /// The PIM-style "MPU without instruction offload" variant: the same
    /// near-bank memory system (loads still land in the near-bank RF,
    /// coalesced accesses still qualify for LSU offload), but every ALU
    /// instruction is forced onto the base logic die, so far-bank
    /// compute must pull loaded values up over the TSVs. The third
    /// column of the Fig.-8-style comparison.
    pub fn no_offload(&self) -> Self {
        let mut c = self.clone();
        c.offload_policy = OffloadPolicy::AllFarBank;
        c
    }

    /// Total cores in the machine.
    pub fn total_cores(&self) -> usize {
        self.processors * self.cores_per_proc
    }

    /// Total DRAM banks in the machine.
    pub fn total_banks(&self) -> usize {
        self.total_cores() * self.nbus_per_core * self.banks_per_nbu
    }

    /// Total global-memory capacity in bytes.
    pub fn total_mem_bytes(&self) -> usize {
        self.total_banks() * self.bank_bytes
    }

    /// Peak bank-level bandwidth in bytes per core-cycle for the whole
    /// machine (each bank moves `bank_io_bits` per `tCCD`).
    pub fn peak_bank_bytes_per_cycle(&self) -> f64 {
        self.total_banks() as f64 * (self.bank_io_bits as f64 / 8.0) / self.timing.t_ccd as f64
    }

    /// Peak TSV bandwidth in bytes per core-cycle for the whole machine.
    pub fn peak_tsv_bytes_per_cycle(&self) -> f64 {
        self.total_cores() as f64 * (self.tsv_bits_per_core as f64 / 8.0) * self.tsv_clock_mult as f64
    }

    /// Apply a `key=value` override (used by the CLI). Returns an error
    /// string on unknown keys or malformed values.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn p<T: std::str::FromStr>(v: &str) -> Result<T, String> {
            v.parse::<T>().map_err(|_| format!("bad value `{v}`"))
        }
        match key {
            "processors" => self.processors = p(value)?,
            "cores_per_proc" => self.cores_per_proc = p(value)?,
            "subcores_per_core" => self.subcores_per_core = p(value)?,
            "nbus_per_core" => self.nbus_per_core = p(value)?,
            "banks_per_nbu" => self.banks_per_nbu = p(value)?,
            "row_buffers_per_bank" => self.row_buffers_per_bank = p(value)?,
            "max_warps_per_subcore" => self.max_warps_per_subcore = p(value)?,
            "max_blocks_per_core" => self.max_blocks_per_core = p(value)?,
            "row_bytes" => self.row_bytes = p(value)?,
            "interleave_bytes" => self.interleave_bytes = p(value)?,
            "subarray_interleave" => self.subarray_interleave = p(value)?,
            "pipeline_mode" => {
                self.pipeline_mode = match value {
                    "hybrid" => PipelineMode::Hybrid,
                    "ponb" => PipelineMode::PonB,
                    _ => return Err(format!("bad pipeline_mode `{value}`")),
                }
            }
            "offload_policy" => {
                self.offload_policy = match value {
                    "annotated" => OffloadPolicy::CompilerAnnotated,
                    "hw" => OffloadPolicy::HardwareDefault,
                    "all_nb" => OffloadPolicy::AllNearBank,
                    "all_fb" => OffloadPolicy::AllFarBank,
                    "explicit" => OffloadPolicy::Explicit,
                    _ => return Err(format!("bad offload_policy `{value}`")),
                }
            }
            // The federation wire format for candidate policies: configs
            // travel as `key=value` string pairs, so the table rides as
            // its canonical JSON.
            "offload_table" => {
                self.offload_table = serde_json::from_str(value)
                    .map_err(|e| format!("bad offload_table JSON: {e}"))?
            }
            "smem_location" => {
                self.smem_location = match value {
                    "near" => SmemLocation::NearBank,
                    "far" => SmemLocation::FarBank,
                    _ => return Err(format!("bad smem_location `{value}`")),
                }
            }
            "sched" => {
                self.sched_policy = match value {
                    "gto" => SchedPolicy::Gto,
                    "rr" => SchedPolicy::RoundRobin,
                    _ => return Err(format!("bad sched `{value}`")),
                }
            }
            _ => return Err(format!("unknown config key `{key}`")),
        }
        Ok(())
    }
}

/// V100-like GPU baseline configuration (DESIGN.md §2 substitution).
///
/// The model keeps the *per-SM* ratios of a Tesla V100 (80 SMs sharing
/// 900 GB/s of HBM2, ~400-cycle memory latency) but is instantiated with
/// the same number of SMs as the MPU config has cores so runtimes compare
/// one-to-one.
#[derive(Clone, Debug, serde::Serialize)]
pub struct GpuConfig {
    /// Streaming multiprocessors.
    pub sms: usize,
    pub subcores_per_sm: usize,
    pub warp_size: usize,
    pub max_warps_per_subcore: usize,
    pub max_blocks_per_sm: usize,
    /// HBM bandwidth in bytes per core cycle, whole chip.
    pub hbm_bytes_per_cycle: f64,
    /// Average DRAM access latency (core cycles).
    pub mem_latency: u64,
    /// L2 hit latency.
    pub l2_latency: u64,
    /// Fraction of accesses served by L2 (streaming workloads: low).
    pub l2_hit_rate: f64,
    pub alu_latency: u64,
    pub sfu_latency: u64,
    pub smem_latency: u64,
    pub smem_bytes: usize,
    pub energy: GpuEnergyCoeffs,
    pub sched_policy: SchedPolicy,
    pub max_cycles: u64,
}

/// GPU baseline energy coefficients: the long compute-centric data path
/// (HBM cell → TSV → off-chip PHY → L2 → crossbar → L1 → RF), per §VI-B's
/// narrative, built from the same Table-II primitives.
#[derive(Clone, Copy, Debug, serde::Serialize)]
pub struct GpuEnergyCoeffs {
    /// DRAM cell read/write, J per 256-bit access (same cell energy).
    pub dram_rdwr: f64,
    pub dram_preact: f64,
    /// HBM-internal TSV traversal, J per bit.
    pub tsv_bit: f64,
    /// Interposer/off-chip PHY, J per bit.
    pub phy_bit: f64,
    /// L2 + crossbar + L1 path, J per bit.
    pub cache_path_bit: f64,
    pub rf: f64,
    pub smem: f64,
    pub operand_collector: f64,
    pub alu_op: f64,
    pub frontend_instr: f64,
}

impl Default for GpuEnergyCoeffs {
    fn default() -> Self {
        GpuEnergyCoeffs {
            dram_rdwr: 0.15e-9,
            dram_preact: 0.27e-9,
            tsv_bit: 4.53e-12,
            phy_bit: 4.50e-12,
            cache_path_bit: 3.00e-12,
            rf: 40.0e-12,
            smem: 22.2e-12,
            operand_collector: 41.49e-12,
            alu_op: 20.0e-12,
            frontend_instr: 60.0e-12,
        }
    }
}

/// Configuration of the ideal-bandwidth roofline machine: the GPU
/// baseline's SIMT geometry with an infinite-bandwidth, fixed-latency
/// memory system (every speedup plot's "how far from the wall" column).
#[derive(Clone, Debug, serde::Serialize)]
pub struct IdealConfig {
    pub sms: usize,
    pub subcores_per_sm: usize,
    pub warp_size: usize,
    pub max_warps_per_subcore: usize,
    pub max_blocks_per_sm: usize,
    /// Fixed latency of every global access (core cycles); bandwidth is
    /// unlimited.
    pub mem_latency: u64,
    pub alu_latency: u64,
    pub sfu_latency: u64,
    pub smem_latency: u64,
    pub smem_bytes: usize,
    pub energy: GpuEnergyCoeffs,
    pub sched_policy: SchedPolicy,
    pub max_cycles: u64,
}

impl IdealConfig {
    /// Roofline matched to an MPU machine config: same SM count as MPU
    /// cores, a short fixed memory latency (an L1-hit-class 40 cycles),
    /// no bandwidth limit. Every *frontend* latency deliberately equals
    /// the [`GpuConfig::matched`] baseline's, so the ideal-vs-GPU gap
    /// measures the memory system alone.
    pub fn matched(mpu: &MachineConfig) -> Self {
        let gpu = GpuConfig::matched(mpu);
        IdealConfig {
            sms: gpu.sms,
            subcores_per_sm: gpu.subcores_per_sm,
            warp_size: gpu.warp_size,
            max_warps_per_subcore: gpu.max_warps_per_subcore,
            max_blocks_per_sm: gpu.max_blocks_per_sm,
            mem_latency: 40,
            alu_latency: gpu.alu_latency,
            sfu_latency: gpu.sfu_latency,
            smem_latency: gpu.smem_latency,
            smem_bytes: gpu.smem_bytes,
            energy: gpu.energy,
            sched_policy: gpu.sched_policy,
            max_cycles: gpu.max_cycles,
        }
    }

    /// Total ALU lanes across the machine.
    pub fn total_lanes(&self) -> usize {
        self.sms * self.subcores_per_sm * self.warp_size
    }
}

/// The machine variants the sweep engine / CLI can target, all built on
/// the shared SIMT frontend ([`crate::core::frontend`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MachineKind {
    /// The paper's MPU (hybrid near-bank pipeline).
    Mpu,
    /// V100-like compute-centric baseline.
    Gpu,
    /// Infinite-bandwidth, fixed-latency roofline.
    IdealBw,
    /// MPU memory system with instruction offload forced off (PIM-style).
    MpuNoOffload,
}

impl MachineKind {
    pub const ALL: [MachineKind; 4] =
        [MachineKind::Mpu, MachineKind::Gpu, MachineKind::IdealBw, MachineKind::MpuNoOffload];

    /// Stable lower-case name (sweep labels, JSON, CLI).
    pub fn name(&self) -> &'static str {
        match self {
            MachineKind::Mpu => "mpu",
            MachineKind::Gpu => "gpu",
            MachineKind::IdealBw => "ideal",
            MachineKind::MpuNoOffload => "mpu_nooff",
        }
    }

    pub fn from_name(s: &str) -> Option<MachineKind> {
        MachineKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// Defaults for the sweep service (`mpu serve` / `submit` / `status`),
/// overridable by environment and then by CLI flags.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Daemon listen / client connect address (`MPU_ADDR`).
    pub addr: String,
    /// On-disk result-store root (`MPU_STORE_DIR`); `None` disables the
    /// persistent tier.
    pub store_dir: Option<std::path::PathBuf>,
    /// Store size cap in bytes (`MPU_STORE_MAX_MB`).
    pub store_max_bytes: u64,
    /// Worker daemon addresses (`MPU_WORKERS`, comma-separated). When
    /// non-empty, `mpu serve` runs as a federation coordinator and
    /// `mpu submit` fans out client-side instead of talking to one
    /// daemon.
    pub workers: Vec<String>,
    /// TCP connect deadline for client and federation sockets
    /// (`MPU_CONNECT_TIMEOUT_MS`).
    pub connect_timeout: std::time::Duration,
    /// Read/write deadline on streamed and probe sockets
    /// (`MPU_IO_TIMEOUT_MS`). Generous by default — a cold tiny suite
    /// takes seconds, a large fresh batch minutes.
    pub io_timeout: std::time::Duration,
    /// Attempts per socket operation before a failure is treated as
    /// fatal/dead (`MPU_RETRIES`).
    pub retries: u32,
    /// Base backoff delay between retries (`MPU_BACKOFF_MS`); grows
    /// exponentially with seeded jitter, capped internally.
    pub backoff: std::time::Duration,
    /// Admission cap on queued points before submits get `busy`
    /// (`MPU_MAX_QUEUE`); 0 disables the cap.
    pub max_queue: usize,
    /// Fault-injection spec (`MPU_FAULTS`); `None` disables the chaos
    /// plane.
    pub faults: Option<String>,
}

impl ServeConfig {
    pub const DEFAULT_ADDR: &'static str = "127.0.0.1:7117";
    pub const DEFAULT_STORE_DIR: &'static str = ".mpu-store";
    pub const DEFAULT_STORE_MAX_MB: u64 = 512;
    pub const DEFAULT_CONNECT_TIMEOUT_MS: u64 = 5_000;
    pub const DEFAULT_IO_TIMEOUT_MS: u64 = 300_000;
    pub const DEFAULT_RETRIES: u32 = 4;
    pub const DEFAULT_BACKOFF_MS: u64 = 50;
    pub const DEFAULT_MAX_QUEUE: usize = 4096;

    /// Built-in defaults with environment overrides applied.
    pub fn from_env() -> ServeConfig {
        fn env_u64(key: &str) -> Option<u64> {
            std::env::var(key).ok().and_then(|v| v.parse::<u64>().ok())
        }
        let addr =
            std::env::var("MPU_ADDR").unwrap_or_else(|_| Self::DEFAULT_ADDR.to_string());
        let store_dir = std::env::var("MPU_STORE_DIR")
            .unwrap_or_else(|_| Self::DEFAULT_STORE_DIR.to_string());
        let max_mb = env_u64("MPU_STORE_MAX_MB").unwrap_or(Self::DEFAULT_STORE_MAX_MB);
        let workers = std::env::var("MPU_WORKERS")
            .map(|v| Self::parse_workers(&v))
            .unwrap_or_default();
        let connect_ms =
            env_u64("MPU_CONNECT_TIMEOUT_MS").unwrap_or(Self::DEFAULT_CONNECT_TIMEOUT_MS);
        let io_ms = env_u64("MPU_IO_TIMEOUT_MS").unwrap_or(Self::DEFAULT_IO_TIMEOUT_MS);
        let retries =
            env_u64("MPU_RETRIES").map(|v| v as u32).unwrap_or(Self::DEFAULT_RETRIES);
        let backoff_ms = env_u64("MPU_BACKOFF_MS").unwrap_or(Self::DEFAULT_BACKOFF_MS);
        let max_queue = env_u64("MPU_MAX_QUEUE")
            .map(|v| v as usize)
            .unwrap_or(Self::DEFAULT_MAX_QUEUE);
        let faults = std::env::var("MPU_FAULTS").ok().filter(|v| !v.trim().is_empty());
        ServeConfig {
            addr,
            store_dir: Some(std::path::PathBuf::from(store_dir)),
            store_max_bytes: max_mb * 1024 * 1024,
            workers,
            connect_timeout: std::time::Duration::from_millis(connect_ms),
            io_timeout: std::time::Duration::from_millis(io_ms),
            retries: retries.max(1),
            backoff: std::time::Duration::from_millis(backoff_ms),
            max_queue,
            faults,
        }
    }

    /// Split a comma-separated worker list, dropping empty segments.
    pub fn parse_workers(s: &str) -> Vec<String> {
        s.split(',').map(|w| w.trim().to_string()).filter(|w| !w.is_empty()).collect()
    }
}

impl GpuConfig {
    /// Total ALU lanes across the chip (the Fig.-1 ALU-utilization
    /// denominator — single source of truth for machine and benches).
    pub fn total_lanes(&self) -> usize {
        self.sms * self.subcores_per_sm * self.warp_size
    }

    /// Baseline matched to an MPU machine config: same SM count as MPU
    /// cores, V100 per-SM bandwidth share (900 GB/s / 80 SMs @ ~1.4 GHz
    /// ≈ 8 B/cycle/SM).
    pub fn matched(mpu: &MachineConfig) -> Self {
        let sms = mpu.total_cores();
        GpuConfig {
            sms,
            subcores_per_sm: 4,
            warp_size: mpu.warp_size,
            max_warps_per_subcore: 16,
            max_blocks_per_sm: 8,
            hbm_bytes_per_cycle: 8.0 * sms as f64,
            mem_latency: 400,
            l2_latency: 130,
            l2_hit_rate: 0.15,
            alu_latency: 4,
            sfu_latency: 16,
            smem_latency: 24,
            smem_bytes: 96 << 10,
            energy: GpuEnergyCoeffs::default(),
            sched_policy: SchedPolicy::Gto,
            max_cycles: mpu.max_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry_matches_table2() {
        let c = MachineConfig::paper();
        assert_eq!(c.processors, 8);
        assert_eq!(c.cores_per_proc, 16);
        assert_eq!(c.subcores_per_core, 4);
        assert_eq!(c.nbus_per_core, 4);
        assert_eq!(c.banks_per_nbu, 4);
        assert_eq!(c.row_buffers_per_bank, 4);
        assert_eq!(c.warp_size, 32);
        assert_eq!(c.bank_bytes, 16 << 20);
        assert_eq!(c.fb_rf_bytes, 32 << 10);
        assert_eq!(c.nb_rf_bytes, 16 << 10);
        assert_eq!(c.smem_bytes, 64 << 10);
        assert_eq!(c.timing.t_rcd, 14);
        assert_eq!(c.timing.t_rfc, 350);
    }

    #[test]
    fn bank_bandwidth_dwarfs_tsv_bandwidth() {
        // The whole premise of near-bank computing (§III): bank-internal
        // bandwidth is roughly an order of magnitude above TSV bandwidth.
        let c = MachineConfig::paper();
        let ratio = c.peak_bank_bytes_per_cycle() / c.peak_tsv_bytes_per_cycle();
        assert!(ratio >= 8.0, "bank/TSV bandwidth ratio {ratio} too low");
    }

    #[test]
    fn set_overrides_work() {
        let mut c = MachineConfig::scaled();
        c.set("row_buffers_per_bank", "2").unwrap();
        assert_eq!(c.row_buffers_per_bank, 2);
        c.set("offload_policy", "all_nb").unwrap();
        assert_eq!(c.offload_policy, OffloadPolicy::AllNearBank);
        c.set("smem_location", "far").unwrap();
        assert_eq!(c.smem_location, SmemLocation::FarBank);
        assert!(c.set("nonsense", "1").is_err());
        assert!(c.set("sched", "nonsense").is_err());
    }

    #[test]
    fn gpu_matched_has_same_sm_count() {
        let m = MachineConfig::scaled();
        let g = GpuConfig::matched(&m);
        assert_eq!(g.sms, m.total_cores());
        assert!(g.hbm_bytes_per_cycle > 0.0);
    }

    #[test]
    fn machine_kinds_roundtrip_and_cover_four_variants() {
        assert_eq!(MachineKind::ALL.len(), 4);
        for k in MachineKind::ALL {
            assert_eq!(MachineKind::from_name(k.name()), Some(k));
        }
        assert_eq!(MachineKind::from_name("nope"), None);
    }

    #[test]
    fn ideal_matched_and_no_offload_presets() {
        let m = MachineConfig::scaled();
        let i = IdealConfig::matched(&m);
        assert_eq!(i.sms, m.total_cores());
        assert!(i.mem_latency > 0);
        let n = m.no_offload();
        assert_eq!(n.offload_policy, OffloadPolicy::AllFarBank);
        assert_eq!(n.pipeline_mode, m.pipeline_mode, "memory system unchanged");
    }
}
