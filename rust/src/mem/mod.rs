//! Memory-system substrates: the global address layout (DRAM geometry
//! mapping, §IV-C subarray interleaving) and the near-bank shared memory
//! (§IV-C).

pub mod layout;
pub mod smem;

pub use layout::{AddrMap, BankCoord};
pub use smem::SharedMem;
