//! Global-memory address mapping.
//!
//! MPU has its own flat device address space (§V-A). Physical placement
//! interleaves `interleave_bytes`-sized chunks across all banks of the
//! machine (core-major), so streaming accesses spread over every bank
//! while a single coalesced warp access stays within one bank chunk.
//!
//! Row addresses are additionally interleaved across subarrays when MASA
//! is enabled (§IV-C): "continuous DRAM row addresses will be mapped to
//! interleaved subarrays' physical rows".

use crate::config::MachineConfig;

/// Physical coordinates of an address.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BankCoord {
    /// Processor (cube) index.
    pub proc: usize,
    /// Core index within the processor.
    pub core: usize,
    /// NBU index within the core.
    pub nbu: usize,
    /// Bank index behind the NBU's memory controller.
    pub bank: usize,
    /// DRAM row within the bank.
    pub row: usize,
    /// Byte offset within the row.
    pub col: usize,
}

impl BankCoord {
    /// Flat global core id.
    pub fn core_global(&self, cfg: &MachineConfig) -> usize {
        self.proc * cfg.cores_per_proc + self.core
    }
}

/// The address map for a machine configuration.
#[derive(Clone, Debug)]
pub struct AddrMap {
    interleave: usize,
    total_banks: usize,
    nbus: usize,
    banks_per_nbu: usize,
    cores_per_proc: usize,
    row_bytes: usize,
    rows_per_bank: usize,
    row_buffers: usize,
    subarray_interleave: bool,
}

impl AddrMap {
    pub fn new(cfg: &MachineConfig) -> AddrMap {
        assert!(cfg.row_bytes.is_power_of_two());
        assert!(cfg.interleave_bytes.is_power_of_two());
        assert!(cfg.interleave_bytes <= cfg.row_bytes);
        AddrMap {
            interleave: cfg.interleave_bytes,
            total_banks: cfg.total_banks(),
            nbus: cfg.nbus_per_core,
            banks_per_nbu: cfg.banks_per_nbu,
            cores_per_proc: cfg.cores_per_proc,
            row_bytes: cfg.row_bytes,
            rows_per_bank: cfg.bank_bytes / cfg.row_bytes,
            row_buffers: cfg.row_buffers_per_bank,
            subarray_interleave: cfg.subarray_interleave,
        }
    }

    /// Map a global byte address to its physical location.
    pub fn decode(&self, addr: u64) -> BankCoord {
        let chunk = addr as usize / self.interleave;
        let within = addr as usize % self.interleave;
        let bank_global = chunk % self.total_banks;
        let bank_local_off = (chunk / self.total_banks) * self.interleave + within;

        let banks_per_core = self.nbus * self.banks_per_nbu;
        let core_global = bank_global / banks_per_core;
        let in_core = bank_global % banks_per_core;
        let nbu = in_core / self.banks_per_nbu;
        let bank = in_core % self.banks_per_nbu;

        let row = (bank_local_off / self.row_bytes) % self.rows_per_bank.max(1);
        let col = bank_local_off % self.row_bytes;

        BankCoord {
            proc: core_global / self.cores_per_proc,
            core: core_global % self.cores_per_proc,
            nbu,
            bank,
            row,
            col,
        }
    }

    /// Row-buffer slot (subarray group) serving `row` in a bank.
    ///
    /// With MASA interleaving, consecutive rows rotate across the
    /// `row_buffers` independently-activated subarray groups; without it,
    /// the bank behaves as contiguous subarray groups, so neighbouring
    /// rows contend for the same buffer (the ping-pong the paper fixes).
    pub fn slot_of_row(&self, row: usize) -> usize {
        if self.row_buffers <= 1 {
            return 0;
        }
        if self.subarray_interleave {
            row % self.row_buffers
        } else {
            let group = (self.rows_per_bank / self.row_buffers).max(1);
            (row / group).min(self.row_buffers - 1)
        }
    }

    /// Does `addr..addr+len` stay within a single interleave chunk (and
    /// therefore a single bank)?
    pub fn single_bank(&self, addr: u64, len: usize) -> bool {
        if len == 0 {
            return true;
        }
        (addr as usize / self.interleave) == ((addr as usize + len - 1) / self.interleave)
    }

    pub fn total_banks(&self) -> usize {
        self.total_banks
    }

    pub fn row_bytes(&self) -> usize {
        self.row_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::prng::{check_cases, Prng};

    fn map() -> (MachineConfig, AddrMap) {
        let cfg = MachineConfig::scaled();
        let m = AddrMap::new(&cfg);
        (cfg, m)
    }

    #[test]
    fn consecutive_chunks_hit_consecutive_banks() {
        let (cfg, m) = map();
        let a = m.decode(0);
        let b = m.decode(cfg.interleave_bytes as u64);
        assert_eq!(a.proc, 0);
        assert_eq!((a.nbu, a.bank), (0, 0));
        assert_eq!((b.nbu, b.bank), (0, 1), "next chunk lands in the next bank");
        // One full sweep of all banks returns to bank 0, next row region.
        let c = m.decode((cfg.interleave_bytes * cfg.total_banks()) as u64);
        assert_eq!((c.proc, c.core, c.nbu, c.bank), (0, 0, 0, 0));
        assert_eq!(c.col, a.col + cfg.interleave_bytes);
    }

    #[test]
    fn within_chunk_is_same_bank_different_col() {
        let (_, m) = map();
        let a = m.decode(0);
        let b = m.decode(64);
        assert_eq!((a.nbu, a.bank, a.row), (b.nbu, b.bank, b.row));
        assert_eq!(b.col, 64);
        assert!(m.single_bank(0, 256));
        assert!(!m.single_bank(0, 257));
        assert!(m.single_bank(17, 0));
    }

    #[test]
    fn masa_interleave_rotates_slots() {
        let (mut cfg, _) = map();
        cfg.row_buffers_per_bank = 4;
        cfg.subarray_interleave = true;
        let m = AddrMap::new(&cfg);
        assert_eq!(m.slot_of_row(0), 0);
        assert_eq!(m.slot_of_row(1), 1);
        assert_eq!(m.slot_of_row(2), 2);
        assert_eq!(m.slot_of_row(3), 3);
        assert_eq!(m.slot_of_row(4), 0);
    }

    #[test]
    fn linear_mapping_groups_slots() {
        let (mut cfg, _) = map();
        cfg.row_buffers_per_bank = 4;
        cfg.subarray_interleave = false;
        let m = AddrMap::new(&cfg);
        // Neighbouring rows share a slot.
        assert_eq!(m.slot_of_row(0), m.slot_of_row(1));
        // Far-apart rows use different slots.
        let rows = cfg.bank_bytes / cfg.row_bytes;
        assert_ne!(m.slot_of_row(0), m.slot_of_row(rows - 1));
    }

    #[test]
    fn single_row_buffer_always_slot_zero() {
        let (mut cfg, _) = map();
        cfg.row_buffers_per_bank = 1;
        let m = AddrMap::new(&cfg);
        for row in 0..64 {
            assert_eq!(m.slot_of_row(row), 0);
        }
    }

    #[test]
    fn decode_is_total_and_in_range_property() {
        let (cfg, m) = map();
        check_cases("decode_in_range", 64, |rng: &mut Prng| {
            let addr = rng.below(cfg.total_mem_bytes() as u64);
            let c = m.decode(addr);
            assert!(c.proc < cfg.processors);
            assert!(c.core < cfg.cores_per_proc);
            assert!(c.nbu < cfg.nbus_per_core);
            assert!(c.bank < cfg.banks_per_nbu);
            assert!(c.row < cfg.bank_bytes / cfg.row_bytes);
            assert!(c.col < cfg.row_bytes);
        });
    }

    #[test]
    fn distinct_addresses_distinct_cells_property() {
        // decode() must be injective on (bank, row, col) for addresses in
        // range — two different addresses never alias the same cell.
        let (cfg, m) = map();
        check_cases("decode_injective", 16, |rng: &mut Prng| {
            let a = rng.below(cfg.total_mem_bytes() as u64) & !3;
            let b = rng.below(cfg.total_mem_bytes() as u64) & !3;
            if a == b {
                return;
            }
            let ca = m.decode(a);
            let cb = m.decode(b);
            let key = |c: &BankCoord| (c.proc, c.core, c.nbu, c.bank, c.row, c.col);
            assert_ne!(key(&ca), key(&cb), "aliased cells for {a} vs {b}");
        });
    }
}
