//! Shared-memory model (§IV-C "Near-bank Shared Memory Design").
//!
//! One shared memory per core. In the paper's horizontal core structure
//! it sits on the DRAM die next to all four NBUs; the Fig.-11 baseline
//! places it on the base logic die instead (`SmemLocation::FarBank`),
//! which drags every inter-thread communication across the TSVs.
//!
//! Functionally it is a flat per-block byte array; timing-wise it is a
//! 32-bank SRAM: a warp access costs `smem_latency` plus one extra cycle
//! per bank conflict.

/// Functional + timing model of one thread block's shared memory.
#[derive(Clone, Debug)]
pub struct SharedMem {
    data: Vec<u8>,
    banks: usize,
}

impl SharedMem {
    pub fn new(bytes: usize) -> SharedMem {
        SharedMem { data: vec![0; bytes], banks: 32 }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Read a 32-bit word. Out-of-bounds reads return 0 (the simulator
    /// flags them separately at the LSU level).
    pub fn read_u32(&self, addr: u32) -> u32 {
        let a = addr as usize;
        if a + 4 > self.data.len() {
            return 0;
        }
        u32::from_le_bytes(self.data[a..a + 4].try_into().unwrap())
    }

    /// Write a 32-bit word; out-of-bounds writes are dropped.
    pub fn write_u32(&mut self, addr: u32, val: u32) {
        let a = addr as usize;
        if a + 4 > self.data.len() {
            return;
        }
        self.data[a..a + 4].copy_from_slice(&val.to_le_bytes());
    }

    /// Atomic add (for `red.shared`): returns the old value.
    pub fn red_add_f32(&mut self, addr: u32, val: f32) -> f32 {
        let old = f32::from_bits(self.read_u32(addr));
        self.write_u32(addr, (old + val).to_bits());
        old
    }

    /// Atomic integer add.
    pub fn red_add_u32(&mut self, addr: u32, val: u32) -> u32 {
        let old = self.read_u32(addr);
        self.write_u32(addr, old.wrapping_add(val));
        old
    }

    /// Bank-conflict serialization factor of a warp's 4-byte accesses:
    /// the maximum number of distinct addresses mapping to one bank.
    /// Accesses to the *same* word broadcast (no conflict).
    pub fn conflict_factor(&self, addrs: &[u32]) -> u64 {
        let mut per_bank: Vec<Vec<u32>> = vec![Vec::new(); self.banks];
        for &a in addrs {
            let word = a / 4;
            let bank = (word as usize) % self.banks;
            if !per_bank[bank].contains(&a) {
                per_bank[bank].push(a);
            }
        }
        per_bank.iter().map(|v| v.len() as u64).max().unwrap_or(0).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rw_roundtrip() {
        let mut s = SharedMem::new(1024);
        s.write_u32(16, 0xDEADBEEF);
        assert_eq!(s.read_u32(16), 0xDEADBEEF);
        assert_eq!(s.read_u32(20), 0);
    }

    #[test]
    fn out_of_bounds_is_silently_dropped() {
        let mut s = SharedMem::new(64);
        s.write_u32(62, 1); // straddles the end
        assert_eq!(s.read_u32(62), 0);
        s.write_u32(4096, 7);
        assert_eq!(s.read_u32(4096), 0);
    }

    #[test]
    fn red_add_returns_old() {
        let mut s = SharedMem::new(64);
        s.write_u32(0, 5f32.to_bits());
        let old = s.red_add_f32(0, 2.5);
        assert_eq!(old, 5.0);
        assert_eq!(f32::from_bits(s.read_u32(0)), 7.5);
        assert_eq!(s.red_add_u32(4, 3), 0);
        assert_eq!(s.read_u32(4), 3);
    }

    #[test]
    fn conflict_free_when_strided_by_word() {
        let s = SharedMem::new(4096);
        let addrs: Vec<u32> = (0..32).map(|i| i * 4).collect();
        assert_eq!(s.conflict_factor(&addrs), 1);
    }

    #[test]
    fn same_word_broadcasts() {
        let s = SharedMem::new(4096);
        let addrs = vec![0u32; 32];
        assert_eq!(s.conflict_factor(&addrs), 1);
    }

    #[test]
    fn power_of_two_stride_conflicts() {
        let s = SharedMem::new(1 << 16);
        // Stride of 32 words → all accesses hit bank 0: factor 32.
        let addrs: Vec<u32> = (0..32).map(|i| i * 32 * 4).collect();
        assert_eq!(s.conflict_factor(&addrs), 32);
        // Stride of 2 words → factor 2.
        let addrs: Vec<u32> = (0..32).map(|i| i * 2 * 4).collect();
        assert_eq!(s.conflict_factor(&addrs), 2);
    }
}
