//! The shared SIMT frontend.
//!
//! Every machine in this repo — the MPU, the GPU baseline, and the
//! roofline variants — executes *identical* SIMT programs and differs
//! only in its memory system. This module owns everything the machines
//! used to duplicate: block residency and dispatch, warp scheduling
//! (GTO / loose round-robin), barrier and exit handling, the scoreboard
//! view, guard evaluation, functional lane execution (ALU, global and
//! shared memory), and the idle fast-forward event loop.
//!
//! The frontend is generic over two seams:
//!
//! * [`MemorySystem`] — the timing model of global memory: where a
//!   coalesced warp access goes (TSVs + near-bank DRAM controllers +
//!   mesh for the MPU; an L2 + HBM bandwidth pipe for the GPU; a fixed
//!   latency for the ideal-bandwidth roofline), how in-flight requests
//!   advance, and when loads complete back into registers.
//! * [`OffloadModel`] — the instruction-placement model: the MPU's
//!   Fig.-3 near/far-bank decision plus register move engine; a no-op
//!   (everything far-bank) for the compute-centric machines.
//!
//! Both traits are implemented by the same backend type so backends can
//! share state (the MPU's register moves ride its TSV buses).

use super::exec::{alu_lane, operand_value, LaneCtx};
use super::offload::ExecLoc;
use super::warp::{Warp, WarpState};
use crate::compiler::CompiledKernel;
use crate::config::SchedPolicy;
use crate::isa::instr::Loc;
use crate::isa::program::ParamValue;
use crate::isa::{Instr, LaunchConfig, Op, Reg, Space};
use crate::mem::SharedMem;
use crate::sim::Stats;
use anyhow::{bail, Result};
use std::collections::VecDeque;

/// Frontend geometry and latency parameters — the subset of a machine
/// configuration the SIMT pipeline itself needs (memory-system
/// parameters live in the backend).
#[derive(Clone, Debug)]
pub struct FrontendParams {
    /// SIMT cores (MPU cores / GPU SMs).
    pub cores: usize,
    pub subcores_per_core: usize,
    pub warp_size: usize,
    pub max_warps_per_subcore: usize,
    pub max_blocks_per_core: usize,
    /// Instructions issued per subcore per cycle.
    pub issue_width: usize,
    pub smem_bytes: usize,
    pub sched_policy: SchedPolicy,
    pub alu_latency: u64,
    pub sfu_latency: u64,
    pub opc_latency: u64,
    pub smem_latency: u64,
    /// Functional device-memory size in bytes.
    pub mem_bytes: usize,
    /// Deadlock safety valve.
    pub max_cycles: u64,
}

/// Which register file a completed load's data landed in (drives the
/// §IV-B1 track-table update; `Untracked` for machines without one).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegPlace {
    Near,
    Far,
    Untracked,
}

/// A load completion delivered by the memory system: register `dst` of
/// warp (`core`, `warp`) becomes ready at cycle `ready`.
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub core: usize,
    pub warp: usize,
    pub dst: Reg,
    pub ready: u64,
    pub place: RegPlace,
}

/// Everything a memory system needs to know about one global-memory
/// warp access (the functional part has already executed).
#[derive(Debug)]
pub struct AccessCtx<'a> {
    pub core: usize,
    /// Index of the warp within its core (stable for completion routing).
    pub warp_index: usize,
    pub instr: &'a Instr,
    /// `(lane, byte address)` of every executing lane.
    pub addrs: &'a [(usize, u64)],
    /// All `warp_size` lanes executing (Fig. 4 offload qualification).
    pub full_warp: bool,
    pub now: u64,
}

/// The pluggable memory system behind the SIMT frontend.
pub trait MemorySystem {
    /// Account timing for one global-memory access. Loads either insert
    /// the destination's ready time directly into `w.reg_ready`, or
    /// block it (`u64::MAX`) and complete later via
    /// [`MemorySystem::drain_completed`]. Stores are fire-and-forget.
    fn issue_access(&mut self, ctx: &AccessCtx, w: &mut Warp, stats: &mut Stats);

    /// Advance internal state (queued events, DRAM controllers, buses)
    /// up to cycle `now`.
    fn advance(&mut self, now: u64, stats: &mut Stats);

    /// Collect load completions; the frontend applies them to the warps.
    fn drain_completed(&mut self, now: u64, out: &mut Vec<Completion>);

    /// Earliest future cycle at which anything internal happens (idle
    /// fast-forward hint). `None` when nothing is pending.
    fn next_event(&self) -> Option<u64>;

    /// No in-flight work (the run loop may terminate).
    fn idle(&self) -> bool;

    /// Core that should host a block given the runtime's home-address
    /// dispatch hint; `None` falls back to round-robin.
    fn home_core(&self, hint: Option<u64>) -> Option<usize> {
        let _ = hint;
        None
    }

    /// Record the register-file placement of a launch parameter.
    fn seed_param(&self, w: &mut Warp, r: Reg);
}

/// The instruction-placement model: decides where non-memory
/// instructions execute and moves registers accordingly. A no-op
/// (everything far-bank, registers never move) for compute-centric
/// machines.
pub trait OffloadModel {
    /// Decide the execution location of an ALU / shared-memory
    /// instruction and perform any required register moves. Returns the
    /// location and the cycle all operands are in place (`>= now`).
    fn pre_issue(
        &mut self,
        core: usize,
        w: &mut Warp,
        instr: &Instr,
        hint: Loc,
        now: u64,
        stats: &mut Stats,
    ) -> (ExecLoc, u64);

    /// Cycle the ALU pipe can start: near-bank execution first sends the
    /// instruction packet down the TSVs.
    fn alu_start(&mut self, core: usize, loc: ExecLoc, ready: u64, now: u64, stats: &mut Stats)
        -> u64;

    /// Retire the destination register at cycle `done` (scoreboard entry
    /// plus register-file placement).
    fn retire_dst(&mut self, w: &mut Warp, instr: &Instr, loc: ExecLoc, done: u64);
}

/// A resident thread block.
#[derive(Debug)]
struct BlockState {
    id: u32,
    warps_live: usize,
    at_barrier: usize,
    smem: SharedMem,
}

/// Per-core SIMT state (warps, blocks, scheduler bookkeeping).
struct CoreState {
    warps: Vec<Warp>,
    blocks: Vec<BlockState>,
    /// GTO bookkeeping: last-issued warp per subcore.
    last_issued: Vec<Option<usize>>,
    /// RR bookkeeping.
    rr_next: Vec<usize>,
    pending_blocks: VecDeque<u32>,
    /// Live (non-retired) warp indices per subcore — the scheduler scans
    /// only these; retired warps stay in `warps` so in-flight completion
    /// indices remain stable.
    sc_warps: Vec<Vec<usize>>,
}

/// The shared SIMT frontend, generic over the memory system.
pub struct SimtFrontend<M: MemorySystem + OffloadModel> {
    pub params: FrontendParams,
    pub mem_sys: M,
    kernel: Option<CompiledKernel>,
    launch: Option<LaunchConfig>,
    kparams: Vec<ParamValue>,
    mem: Vec<u8>,
    alloc_top: u64,
    cores: Vec<CoreState>,
    pub stats: Stats,
    now: u64,
    blocks_done: u32,
}

impl<M: MemorySystem + OffloadModel> SimtFrontend<M> {
    pub fn new(params: FrontendParams, mem_sys: M) -> SimtFrontend<M> {
        let cores = (0..params.cores)
            .map(|_| CoreState {
                warps: Vec::new(),
                blocks: Vec::new(),
                last_issued: vec![None; params.subcores_per_core],
                rr_next: vec![0; params.subcores_per_core],
                pending_blocks: VecDeque::new(),
                sc_warps: vec![Vec::new(); params.subcores_per_core],
            })
            .collect();
        let mem = vec![0; params.mem_bytes];
        SimtFrontend {
            params,
            mem_sys,
            kernel: None,
            launch: None,
            kparams: Vec::new(),
            mem,
            alloc_top: 0,
            cores,
            stats: Stats::default(),
            now: 0,
            blocks_done: 0,
        }
    }

    // ---------------- device memory API ----------------

    /// Bump-allocate device memory (256-B aligned).
    pub fn alloc(&mut self, bytes: usize) -> u64 {
        let base = (self.alloc_top + 255) & !255;
        self.alloc_top = base + bytes as u64;
        assert!(
            (self.alloc_top as usize) <= self.mem.len(),
            "device OOM: {} > {}",
            self.alloc_top,
            self.mem.len()
        );
        base
    }

    pub fn write_mem(&mut self, addr: u64, data: &[u8]) {
        let a = addr as usize;
        self.mem[a..a + data.len()].copy_from_slice(data);
    }

    pub fn read_mem(&self, addr: u64, len: usize) -> &[u8] {
        &self.mem[addr as usize..addr as usize + len]
    }

    pub fn write_f32s(&mut self, addr: u64, data: &[f32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.write_mem(addr, &bytes);
    }

    pub fn read_f32s(&self, addr: u64, n: usize) -> Vec<f32> {
        self.read_mem(addr, n * 4)
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn write_u32s(&mut self, addr: u64, data: &[u32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.write_mem(addr, &bytes);
    }

    pub fn read_u32s(&self, addr: u64, n: usize) -> Vec<u32> {
        self.read_mem(addr, n * 4)
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    fn mem_read_u32(&self, addr: u64) -> u32 {
        let a = addr as usize;
        if a + 4 > self.mem.len() {
            return 0;
        }
        u32::from_le_bytes(self.mem[a..a + 4].try_into().unwrap())
    }

    fn mem_write_u32(&mut self, addr: u64, v: u32) {
        let a = addr as usize;
        if a + 4 > self.mem.len() {
            return;
        }
        self.mem[a..a + 4].copy_from_slice(&v.to_le_bytes());
    }

    // ---------------- launch ----------------

    /// Launch a kernel. `home_addr(block)` is the runtime's dispatch
    /// hint: the block is scheduled on the core owning that address
    /// (§V-A); backends without an address map ignore it and fall back
    /// to round-robin.
    pub fn launch(
        &mut self,
        kernel: CompiledKernel,
        launch: LaunchConfig,
        params: &[ParamValue],
        home_addr: impl Fn(u32) -> Option<u64>,
    ) -> Result<()> {
        let cap =
            self.params.max_warps_per_subcore * self.params.subcores_per_core * self.params.warp_size;
        if launch.block as usize > cap {
            bail!("block size {} exceeds core capacity", launch.block);
        }
        if kernel.params.len() != params.len() {
            bail!("kernel `{}` expects {} params, got {}", kernel.name, kernel.params.len(), params.len());
        }
        self.kernel = Some(kernel);
        self.launch = Some(launch);
        self.kparams = params.to_vec();
        let ncores = self.params.cores;
        for b in 0..launch.grid {
            let core = self
                .mem_sys
                .home_core(home_addr(b))
                .unwrap_or(b as usize % ncores);
            self.cores[core].pending_blocks.push_back(b);
        }
        for c in 0..ncores {
            while self.try_dispatch_block(c) {}
        }
        Ok(())
    }

    /// Dispatch the next pending block on core `c` if resources allow.
    fn try_dispatch_block(&mut self, c: usize) -> bool {
        let launch = self.launch.unwrap();
        let kernel = self.kernel.as_ref().unwrap();
        let core = &mut self.cores[c];
        if core.blocks.len() >= self.params.max_blocks_per_core {
            return false;
        }
        let warps_per_block = launch.warps_per_block(self.params.warp_size);
        let live_warps = core.warps.iter().filter(|w| w.state != WarpState::Done).count();
        if live_warps + warps_per_block
            > self.params.max_warps_per_subcore * self.params.subcores_per_core
        {
            return false;
        }
        let Some(b) = core.pending_blocks.pop_front() else {
            return false;
        };
        let reg_counts = kernel.reg_counts;
        let smem_bytes = (launch.smem_bytes as usize).min(self.params.smem_bytes);
        core.blocks.push(BlockState {
            id: b,
            warps_live: warps_per_block,
            at_barrier: 0,
            smem: SharedMem::new(smem_bytes.max(4)),
        });
        for wi in 0..warps_per_block {
            let lanes = (launch.block as usize - wi * self.params.warp_size).min(self.params.warp_size);
            let subcore = wi % self.params.subcores_per_core;
            let mut w = Warp::new(b, wi, lanes, subcore, reg_counts, self.params.warp_size);
            w.ready_at = self.now + 1;
            // Deliver parameters; the backend records which register
            // file(s) hold them (the MPU seeds both, saving a per-warp
            // register move per parameter).
            for (p, v) in kernel.params.iter().zip(&self.kparams) {
                w.write_all(*p, v.bits());
                self.mem_sys.seed_param(&mut w, *p);
            }
            core.sc_warps[subcore].push(core.warps.len());
            core.warps.push(w);
        }
        true
    }

    // ---------------- main loop ----------------

    /// Run to completion; returns final stats.
    pub fn run(&mut self) -> Result<Stats> {
        let grid = self.launch.map(|l| l.grid).unwrap_or(0);
        let mut completions: Vec<Completion> = Vec::new();
        loop {
            self.mem_sys.advance(self.now, &mut self.stats);
            completions.clear();
            self.mem_sys.drain_completed(self.now, &mut completions);
            for comp in &completions {
                let w = &mut self.cores[comp.core].warps[comp.warp];
                w.reg_ready.insert(comp.dst, comp.ready);
                match comp.place {
                    RegPlace::Near => w.track.write_nb(comp.dst),
                    RegPlace::Far => w.track.write_fb(comp.dst),
                    RegPlace::Untracked => {}
                }
            }
            let issued = self.issue_all();

            let work_left = self.blocks_done < grid || !self.mem_sys.idle();
            if !work_left {
                break;
            }
            if self.now >= self.params.max_cycles {
                bail!("simulation exceeded max_cycles={} (deadlock?)", self.params.max_cycles);
            }
            if issued {
                self.now += 1;
            } else {
                match self.next_interesting() {
                    Some(t) if t > self.now => self.now = t,
                    _ => self.now += 1,
                }
            }
        }
        self.stats.cycles = self.now;
        Ok(self.stats.clone())
    }

    /// Earliest future cycle where anything can happen.
    fn next_interesting(&self) -> Option<u64> {
        let mut best: Option<u64> = self.mem_sys.next_event();
        let kernel = self.kernel.as_ref().unwrap();
        for c in &self.cores {
            for w in c.sc_warps.iter().flatten().map(|&wi| &c.warps[wi]) {
                if w.state != WarpState::Ready {
                    continue;
                }
                let pc = w.pc();
                if pc >= kernel.instrs.len() {
                    continue;
                }
                let dep = w.instr_ready_at(&kernel.instrs[pc]);
                if dep == u64::MAX {
                    continue; // unblocked by a load completion later
                }
                let t = dep.max(w.ready_at);
                best = Some(best.map_or(t, |b| b.min(t)));
            }
        }
        best
    }

    /// Try to issue on every subcore of every core; returns whether any
    /// instruction issued.
    fn issue_all(&mut self) -> bool {
        let mut issued_any = false;
        let ncores = self.cores.len();
        for c in 0..ncores {
            for sc in 0..self.params.subcores_per_core {
                for _ in 0..self.params.issue_width {
                    if let Some(wi) = self.pick_warp(c, sc) {
                        self.issue(c, wi);
                        self.cores[c].last_issued[sc] = Some(wi);
                        issued_any = true;
                    } else {
                        break;
                    }
                }
            }
        }
        issued_any
    }

    /// Scheduler: pick an issueable warp on (core, subcore).
    fn pick_warp(&self, c: usize, sc: usize) -> Option<usize> {
        let core = &self.cores[c];
        let kernel = self.kernel.as_ref().unwrap();
        let can_issue = |wi: usize| -> bool {
            let w = &core.warps[wi];
            if w.state != WarpState::Ready || w.subcore != sc || w.ready_at > self.now {
                return false;
            }
            let pc = w.pc();
            if pc >= kernel.instrs.len() {
                return false;
            }
            w.instr_ready_at(&kernel.instrs[pc]) <= self.now
        };

        let live = &core.sc_warps[sc];
        match self.params.sched_policy {
            SchedPolicy::Gto => {
                // Greedy: stick with the last-issued warp.
                if let Some(last) = core.last_issued[sc] {
                    if last < core.warps.len() && can_issue(last) {
                        return Some(last);
                    }
                }
                // Then oldest (dispatch order).
                live.iter().copied().find(|&wi| can_issue(wi))
            }
            SchedPolicy::RoundRobin => {
                let n = live.len();
                if n == 0 {
                    return None;
                }
                let start = core.rr_next[sc] % n;
                (0..n).map(|k| live[(start + k) % n]).find(|&wi| can_issue(wi))
            }
        }
    }

    // ---------------- instruction issue ----------------

    fn issue(&mut self, c: usize, wi: usize) {
        // Copy out only the per-pc scalars + one instruction — cloning
        // the whole kernel here dominated the profile (EXPERIMENTS.md
        // §Perf iteration 1).
        let pc = self.cores[c].warps[wi].pc();
        let (instr, reconv_pc, hint) = {
            let kernel = self.kernel.as_ref().unwrap();
            (kernel.instrs[pc].clone(), kernel.reconv[pc], kernel.instr_loc(pc))
        };

        if self.params.sched_policy == SchedPolicy::RoundRobin {
            let sc = self.cores[c].warps[wi].subcore;
            let pos = self.cores[c].sc_warps[sc].iter().position(|&x| x == wi).unwrap_or(0);
            self.cores[c].rr_next[sc] = pos + 1;
        }

        {
            let w = &mut self.cores[c].warps[wi];
            w.ready_at = self.now + 1;
            w.last_issue = self.now;
        }

        // Guard evaluation.
        let (exec_mask, active_mask) = {
            let w = &self.cores[c].warps[wi];
            let active = w.active_mask();
            let mask = match instr.guard {
                None => active,
                Some((p, neg)) => {
                    let mut m = 0u64;
                    for lane in 0..w.lanes {
                        if active >> lane & 1 == 1 && (w.read(p, lane) != 0) != neg {
                            m |= 1 << lane;
                        }
                    }
                    m
                }
            };
            (mask, active)
        };

        // Control flow first (always on the front pipeline / far-bank).
        match instr.op {
            Op::Bra => {
                self.stats.instrs_far += 1;
                let target = instr.target.unwrap_or(pc + 1);
                let rpc = reconv_pc.unwrap_or(usize::MAX);
                let taken = if instr.guard.is_none() { active_mask } else { exec_mask };
                self.cores[c].warps[wi].branch(taken, target, pc + 1, rpc);
                return;
            }
            Op::Bar => {
                self.stats.instrs_far += 1;
                self.stats.barriers += 1;
                self.barrier(c, wi, pc);
                return;
            }
            Op::Exit => {
                self.stats.instrs_far += 1;
                self.exit(c, wi, active_mask);
                return;
            }
            _ => {}
        }

        if exec_mask == 0 {
            self.stats.predicated_off += 1;
            self.stats.instrs_far += 1;
            self.cores[c].warps[wi].set_pc(pc + 1);
            return;
        }

        match (instr.op, instr.space) {
            (Op::Ld | Op::St | Op::Red, Some(Space::Global)) => {
                self.issue_global(c, wi, pc, &instr, exec_mask);
            }
            (Op::Ld | Op::St | Op::Red, Some(Space::Shared)) => {
                self.issue_shared(c, wi, pc, &instr, exec_mask, hint);
            }
            _ => {
                self.issue_alu(c, wi, pc, &instr, exec_mask, hint);
            }
        }
    }

    fn lane_addrs(&self, c: usize, wi: usize, instr: &Instr, exec_mask: u64) -> Vec<(usize, u64)> {
        let w = &self.cores[c].warps[wi];
        let m = instr.mem.expect("memory instruction");
        (0..w.lanes)
            .filter(|l| exec_mask >> l & 1 == 1)
            .map(|l| {
                let base = w.read(m.base, l);
                (l, (base as i64 + m.offset as i64) as u64)
            })
            .collect()
    }

    fn issue_alu(&mut self, c: usize, wi: usize, pc: usize, instr: &Instr, exec_mask: u64, hint: Loc) {
        let launch = self.launch.unwrap();
        let (loc, ready) = self.mem_sys.pre_issue(
            c,
            &mut self.cores[c].warps[wi],
            instr,
            hint,
            self.now,
            &mut self.stats,
        );

        // Functional execution.
        let (block, warp_in_block, lanes) = {
            let w = &self.cores[c].warps[wi];
            (w.block, w.warp_in_block, w.lanes)
        };
        let n_srcs = instr.srcs.len() as u64;
        for lane in 0..lanes {
            if exec_mask >> lane & 1 == 0 {
                continue;
            }
            let ctx = LaneCtx {
                tid: (warp_in_block * self.params.warp_size + lane) as u32,
                ntid: launch.block,
                ctaid: block,
                nctaid: launch.grid,
            };
            let w = &self.cores[c].warps[wi];
            let srcs: Vec<u32> = instr
                .srcs
                .iter()
                .map(|o| operand_value(o, &ctx, &|r| w.read(r, lane)))
                .collect();
            let v = alu_lane(instr, &srcs);
            if let Some(d) = instr.dst {
                self.cores[c].warps[wi].write(d, lane, v);
            }
        }

        // Timing + accounting (uniform in the execution location).
        match loc {
            ExecLoc::Near => {
                self.stats.instrs_near += 1;
                self.stats.rf_near_accesses += n_srcs + 1;
            }
            ExecLoc::Far => {
                self.stats.instrs_far += 1;
                self.stats.rf_far_accesses += n_srcs + 1;
            }
        }
        self.stats.opc_accesses += n_srcs;
        self.stats.alu_lane_ops += exec_mask.count_ones() as u64;
        let lat = if instr.op.is_sfu() { self.params.sfu_latency } else { self.params.alu_latency };
        let start = self.mem_sys.alu_start(c, loc, ready, self.now, &mut self.stats);
        let done = start + self.params.opc_latency + lat;

        self.mem_sys.retire_dst(&mut self.cores[c].warps[wi], instr, loc, done);
        self.cores[c].warps[wi].set_pc(pc + 1);
    }

    fn issue_global(&mut self, c: usize, wi: usize, pc: usize, instr: &Instr, exec_mask: u64) {
        self.stats.global_mem_instrs += 1;
        let launch = self.launch.unwrap();
        let addrs = self.lane_addrs(c, wi, instr, exec_mask);

        // Functional execution first (program order per warp).
        match instr.op {
            Op::Ld => {
                let dst = instr.dst.unwrap();
                let vals: Vec<(usize, u32)> =
                    addrs.iter().map(|&(l, a)| (l, self.mem_read_u32(a))).collect();
                let w = &mut self.cores[c].warps[wi];
                for (l, v) in vals {
                    w.write(dst, l, v);
                }
            }
            Op::St => {
                let src = instr.srcs[0];
                let (block, warp_in_block) = {
                    let w = &self.cores[c].warps[wi];
                    (w.block, w.warp_in_block)
                };
                for &(l, a) in &addrs {
                    let ctx = LaneCtx {
                        tid: (warp_in_block * self.params.warp_size + l) as u32,
                        ntid: launch.block,
                        ctaid: block,
                        nctaid: launch.grid,
                    };
                    let w = &self.cores[c].warps[wi];
                    let v = operand_value(&src, &ctx, &|r| w.read(r, l));
                    self.mem_write_u32(a, v);
                }
            }
            Op::Red => {
                // Atomic add (global): sequentialized by simulation.
                let src = instr.srcs[0];
                for &(l, a) in &addrs {
                    let w = &self.cores[c].warps[wi];
                    let v = match src {
                        crate::isa::Operand::Reg(r) => w.read(r, l),
                        o => operand_value(
                            &o,
                            &LaneCtx { tid: 0, ntid: 0, ctaid: 0, nctaid: 0 },
                            &|r| w.read(r, l),
                        ),
                    };
                    let old = self.mem_read_u32(a);
                    let new = match instr.ty {
                        crate::isa::Ty::F32 => (f32::from_bits(old) + f32::from_bits(v)).to_bits(),
                        _ => old.wrapping_add(v),
                    };
                    self.mem_write_u32(a, new);
                }
            }
            _ => unreachable!(),
        }

        // Timing: the memory system owns the whole path.
        let full_warp = {
            let w = &self.cores[c].warps[wi];
            exec_mask.count_ones() as usize == w.lanes && w.lanes == self.params.warp_size
        };
        let ctx = AccessCtx { core: c, warp_index: wi, instr, addrs: &addrs, full_warp, now: self.now };
        self.mem_sys.issue_access(&ctx, &mut self.cores[c].warps[wi], &mut self.stats);
        self.cores[c].warps[wi].set_pc(pc + 1);
    }

    fn issue_shared(&mut self, c: usize, wi: usize, pc: usize, instr: &Instr, exec_mask: u64, hint: Loc) {
        self.stats.shared_mem_instrs += 1;
        let launch = self.launch.unwrap();
        let (loc, ready) = self.mem_sys.pre_issue(
            c,
            &mut self.cores[c].warps[wi],
            instr,
            hint,
            self.now,
            &mut self.stats,
        );
        let addrs = self.lane_addrs(c, wi, instr, exec_mask);
        let (block, warp_in_block) = {
            let w = &self.cores[c].warps[wi];
            (w.block, w.warp_in_block)
        };
        let bslot = self.cores[c].blocks.iter().position(|b| b.id == block).expect("block resident");

        // Functional.
        match instr.op {
            Op::Ld => {
                let dst = instr.dst.unwrap();
                let vals: Vec<(usize, u32)> = addrs
                    .iter()
                    .map(|&(l, a)| (l, self.cores[c].blocks[bslot].smem.read_u32(a as u32)))
                    .collect();
                let w = &mut self.cores[c].warps[wi];
                for (l, v) in vals {
                    w.write(dst, l, v);
                }
            }
            Op::St | Op::Red => {
                let src = instr.srcs[0];
                for &(l, a) in &addrs {
                    let ctx = LaneCtx {
                        tid: (warp_in_block * self.params.warp_size + l) as u32,
                        ntid: launch.block,
                        ctaid: block,
                        nctaid: launch.grid,
                    };
                    let v = {
                        let w = &self.cores[c].warps[wi];
                        operand_value(&src, &ctx, &|r| w.read(r, l))
                    };
                    let smem = &mut self.cores[c].blocks[bslot].smem;
                    if instr.op == Op::St {
                        smem.write_u32(a as u32, v);
                    } else if instr.ty == crate::isa::Ty::F32 {
                        smem.red_add_f32(a as u32, f32::from_bits(v));
                    } else {
                        smem.red_add_u32(a as u32, v);
                    }
                }
            }
            _ => unreachable!(),
        }

        // Timing: smem latency + bank-conflict serialization. The data
        // never crosses the TSVs when the smem and the execution location
        // coincide (§IV-C) — any placement traffic appears through the
        // register moves done by `pre_issue`.
        let a32: Vec<u32> = addrs.iter().map(|&(_, a)| a as u32).collect();
        let conflicts = self.cores[c].blocks[bslot].smem.conflict_factor(&a32);
        self.stats.smem_accesses += conflicts;
        let done = self.now.max(ready) + self.params.smem_latency + (conflicts - 1);
        match loc {
            ExecLoc::Near => self.stats.instrs_near += 1,
            ExecLoc::Far => self.stats.instrs_far += 1,
        }

        self.mem_sys.retire_dst(&mut self.cores[c].warps[wi], instr, loc, done);
        self.cores[c].warps[wi].set_pc(pc + 1);
    }

    fn barrier(&mut self, c: usize, wi: usize, pc: usize) {
        let block = self.cores[c].warps[wi].block;
        self.cores[c].warps[wi].set_pc(pc + 1);
        self.cores[c].warps[wi].state = WarpState::AtBarrier;
        let bslot = self.cores[c].blocks.iter().position(|b| b.id == block).expect("block resident");
        self.cores[c].blocks[bslot].at_barrier += 1;
        if self.cores[c].blocks[bslot].at_barrier >= self.cores[c].blocks[bslot].warps_live {
            self.cores[c].blocks[bslot].at_barrier = 0;
            let release = self.now + 1;
            for w in self.cores[c].warps.iter_mut() {
                if w.block == block && w.state == WarpState::AtBarrier {
                    w.state = WarpState::Ready;
                    w.ready_at = release;
                }
            }
        }
    }

    fn exit(&mut self, c: usize, wi: usize, mask: u64) {
        let done = self.cores[c].warps[wi].exit_lanes(mask);
        if !done {
            return;
        }
        let block = self.cores[c].warps[wi].block;
        let bslot = self.cores[c].blocks.iter().position(|b| b.id == block).expect("block resident");
        {
            let b = &mut self.cores[c].blocks[bslot];
            b.warps_live -= 1;
            if b.warps_live > 0 {
                // A barrier may now be satisfiable with fewer live warps.
                if b.at_barrier >= b.warps_live {
                    b.at_barrier = 0;
                    for w in self.cores[c].warps.iter_mut() {
                        if w.block == block && w.state == WarpState::AtBarrier {
                            w.state = WarpState::Ready;
                            w.ready_at = self.now + 1;
                        }
                    }
                }
                return;
            }
        }
        // Block finished: retire it and dispatch the next. Done warps
        // stay in the vector (in-flight completions hold warp indices);
        // the scheduler scans only the live lists.
        self.cores[c].blocks.remove(bslot);
        {
            let core = &mut self.cores[c];
            for sc in 0..core.sc_warps.len() {
                let warps = &core.warps;
                core.sc_warps[sc].retain(|&wi| warps[wi].block != block);
            }
        }
        self.blocks_done += 1;
        while self.try_dispatch_block(c) {}
    }
}
