//! The shared SIMT frontend.
//!
//! Every machine in this repo — the MPU, the GPU baseline, and the
//! roofline variants — executes *identical* SIMT programs and differs
//! only in its memory system. This module owns everything the machines
//! used to duplicate: block residency and dispatch, warp scheduling
//! (GTO / loose round-robin), barrier and exit handling, the scoreboard
//! view, guard evaluation, functional lane execution (ALU, global and
//! shared memory), and the event-driven run loop.
//!
//! The frontend is generic over two seams:
//!
//! * [`MemorySystem`] — the timing model of global memory: where a
//!   coalesced warp access goes (TSVs + near-bank DRAM controllers +
//!   mesh for the MPU; an L2 + HBM bandwidth pipe for the GPU; a fixed
//!   latency for the ideal-bandwidth roofline), how in-flight requests
//!   advance, and when loads complete back into registers.
//! * [`OffloadModel`] — the instruction-placement model: the MPU's
//!   Fig.-3 near/far-bank decision plus register move engine; a no-op
//!   (everything far-bank) for the compute-centric machines.
//!
//! Both traits are implemented by the same backend type so backends can
//! share state (the MPU's register moves ride its TSV buses).
//!
//! # The event-driven run loop
//!
//! [`SimtFrontend::run`] is event-driven rather than per-cycle polled:
//!
//! * Every warp carries an exact cached wake-up time
//!   ([`Warp::wake_at`]), refreshed on each state transition (issue,
//!   barrier arrive/release, load completion, `ready_at` expiry, block
//!   dispatch). The scheduler reads only this cache; a lazy min-heap of
//!   wake times makes idle fast-forward O(log warps) instead of an
//!   O(cores × warps) rescan, and a per-(core, subcore) lower bound
//!   lets `issue_all` skip subcores with nothing runnable.
//! * [`MemorySystem::advance`] is only called on cycles where
//!   [`MemorySystem::next_event`] shows due work (backends must make
//!   `advance` a no-op otherwise — see the trait contract).
//! * Stretches where only the memory system is active are batched
//!   through [`MemorySystem::advance_to`]: the backend hops between its
//!   own internal event times without re-entering the scheduler,
//!   stopping early as soon as a load completion becomes collectable so
//!   the woken warp is scheduled at exactly the same cycle as before.
//!
//! All of this is cycle-for-cycle and stat-for-stat identical to the
//! retained per-cycle reference loop [`SimtFrontend::run_reference`]
//! (the equivalence tests assert it), which is kept as the timing
//! oracle for future scheduler work.
//!
//! # The decoded issue path
//!
//! The frontend executes the kernel's pre-decoded [`MacroOp`] program
//! (shared behind an `Arc` — the kernel cache decodes once and every
//! machine borrows the same array). Issue copies one fixed-size,
//! pointer-free `MacroOp` off the array and dispatches on its
//! pre-resolved class — no `Instr` clone, no operand-enum walks, no
//! allocation. The *reference* loop deliberately keeps scanning the
//! original `Instr` view ([`Warp::instr_ready_at`]), so the tier-1
//! `run ≡ run_reference` equivalence suite cross-checks the decode on
//! every workload.
//!
//! # Deterministic core-sharded issue (`--threads N`)
//!
//! With [`FrontendParams::threads`] > 1 (GTO scheduling), each cycle's
//! issue pass runs in two phases: a read-only *plan* phase shards cores
//! across a thread pool and computes, per core, exactly the warp picks
//! the serial scan would make; a serial *apply* phase then replays the
//! picks in fixed core/subcore/slot order. This is byte-identical to
//! the serial loop because nothing issued at cycle `now` can enable a
//! new issue at `now`: an issued warp's next wake is `now + 1` or
//! later, barrier releases and block dispatches set `ready_at = now +
//! 1`, completions are only applied between cycles, and all scheduling
//! state is core-local — so per-core plans are a pure function of
//! cycle-top state, and the fixed-order merge touches the memory
//! system, stats and functional memory in exactly the serial order.

use super::exec::{alu_eval, slot_value, LaneCtx};
use super::offload::ExecLoc;
use super::warp::{Warp, WarpState};
use crate::compiler::DecodedKernel;
use crate::config::SchedPolicy;
use crate::isa::instr::Loc;
use crate::isa::program::ParamValue;
use crate::isa::{LaunchConfig, MacroOp, Op, OpClass, Reg, Slot, Space};
use crate::mem::SharedMem;
use crate::sim::Stats;
use anyhow::{bail, Result};
use rayon::prelude::*;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

/// Frontend geometry and latency parameters — the subset of a machine
/// configuration the SIMT pipeline itself needs (memory-system
/// parameters live in the backend).
#[derive(Clone, Debug)]
pub struct FrontendParams {
    /// SIMT cores (MPU cores / GPU SMs).
    pub cores: usize,
    pub subcores_per_core: usize,
    pub warp_size: usize,
    pub max_warps_per_subcore: usize,
    pub max_blocks_per_core: usize,
    /// Instructions issued per subcore per cycle.
    pub issue_width: usize,
    pub smem_bytes: usize,
    pub sched_policy: SchedPolicy,
    pub alu_latency: u64,
    pub sfu_latency: u64,
    pub opc_latency: u64,
    pub smem_latency: u64,
    /// Functional device-memory size in bytes.
    pub mem_bytes: usize,
    /// Deadlock safety valve.
    pub max_cycles: u64,
    /// Issue-phase worker threads (`1` = serial). `run()` output is
    /// byte-identical for any value — see the module docs.
    pub threads: usize,
}

/// Which register file a completed load's data landed in (drives the
/// §IV-B1 track-table update; `Untracked` for machines without one).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegPlace {
    Near,
    Far,
    Untracked,
}

/// A load completion delivered by the memory system: register `dst` of
/// warp (`core`, `warp`) becomes ready at cycle `ready`.
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub core: usize,
    pub warp: usize,
    pub dst: Reg,
    pub ready: u64,
    pub place: RegPlace,
}

/// Everything a memory system needs to know about one global-memory
/// warp access (the functional part has already executed).
#[derive(Debug)]
pub struct AccessCtx<'a> {
    pub core: usize,
    /// Index of the warp within its core (stable for completion routing).
    pub warp_index: usize,
    pub instr: &'a MacroOp,
    /// `(lane, byte address)` of every executing lane.
    pub addrs: &'a [(usize, u64)],
    /// All `warp_size` lanes executing (Fig. 4 offload qualification).
    pub full_warp: bool,
    pub now: u64,
}

/// The pluggable memory system behind the SIMT frontend.
///
/// # Timing contract (event-driven loop)
///
/// The frontend calls [`MemorySystem::advance`] only on cycles where
/// [`MemorySystem::next_event`] is `Some(t)` with `t <= now`, so
/// `next_event` must cover *every* cycle at which `advance` would do
/// work (equivalently: `advance(now)` must be a no-op whenever
/// `next_event() > now`). Backends that deliver load completions
/// asynchronously (via [`MemorySystem::drain_completed`]) must also
/// override [`MemorySystem::completions_pending`] — it bounds how far
/// [`MemorySystem::advance_to`] may run ahead of the scheduler.
pub trait MemorySystem {
    /// Account timing for one global-memory access. Loads either insert
    /// the destination's ready time directly into `w.reg_ready`, or
    /// block it (`u64::MAX`) and complete later via
    /// [`MemorySystem::drain_completed`]. Stores are fire-and-forget.
    fn issue_access(&mut self, ctx: &AccessCtx, w: &mut Warp, stats: &mut Stats);

    /// Advance internal state (queued events, DRAM controllers, buses)
    /// up to cycle `now`. Must be a no-op when
    /// [`MemorySystem::next_event`] is later than `now` (the frontend
    /// skips the call in that case).
    fn advance(&mut self, now: u64, stats: &mut Stats);

    /// Collect load completions; the frontend applies them to the warps.
    /// Must not change [`MemorySystem::next_event`]'s value: the run
    /// loop reuses a pre-drain `next_event` probe on iterations where
    /// `advance` was skipped and nothing issued.
    fn drain_completed(&mut self, now: u64, out: &mut Vec<Completion>);

    /// Earliest future cycle at which anything internal happens (idle
    /// fast-forward hint). `None` when nothing is pending.
    fn next_event(&self) -> Option<u64>;

    /// No in-flight work (the run loop may terminate).
    fn idle(&self) -> bool;

    /// Batched fast-forward: advance internal state through every
    /// internal event at a cycle `<= target`, in order, exactly as if
    /// [`MemorySystem::advance`] were called at each event time — but
    /// stop after the first cycle that makes a load completion
    /// collectable (the frontend must observe it before scheduling
    /// anything later). Returns the last event cycle processed (the
    /// early-stop cycle when a completion is pending), or `target` when
    /// no internal event was due at all.
    ///
    /// The default implementation is correct for any backend that obeys
    /// the `next_event`/`advance`/`completions_pending` contract;
    /// purely synchronous backends (no internal events — the HBM pipe,
    /// the roofline) inherit a no-op. Backends with real event queues
    /// make this loop fast by keeping `next_event` cheap — the
    /// near-bank backend's DRAM controllers cache their next-event
    /// times so each hop is O(controllers), not a queue rescan.
    fn advance_to(&mut self, target: u64, stats: &mut Stats) -> u64 {
        let mut reached = target;
        while let Some(t) = self.next_event() {
            if t > target {
                break;
            }
            self.advance(t, stats);
            reached = t;
            if self.completions_pending() {
                break;
            }
        }
        reached
    }

    /// Whether load completions are waiting to be collected by
    /// [`MemorySystem::drain_completed`]. Backends that complete loads
    /// asynchronously MUST override this; the default (`false`) is only
    /// correct for backends whose loads resolve at issue time.
    fn completions_pending(&self) -> bool {
        false
    }

    /// Core that should host a block given the runtime's home-address
    /// dispatch hint; `None` falls back to round-robin.
    fn home_core(&self, hint: Option<u64>) -> Option<usize> {
        let _ = hint;
        None
    }

    /// Record the register-file placement of a launch parameter.
    fn seed_param(&self, w: &mut Warp, r: Reg);
}

/// The instruction-placement model: decides where non-memory
/// instructions execute and moves registers accordingly. A no-op
/// (everything far-bank, registers never move) for compute-centric
/// machines.
pub trait OffloadModel {
    /// Decide the execution location of an ALU / shared-memory
    /// instruction and perform any required register moves. Returns the
    /// location and the cycle all operands are in place (`>= now`).
    fn pre_issue(
        &mut self,
        core: usize,
        w: &mut Warp,
        instr: &MacroOp,
        hint: Loc,
        now: u64,
        stats: &mut Stats,
    ) -> (ExecLoc, u64);

    /// Cycle the ALU pipe can start: near-bank execution first sends the
    /// instruction packet down the TSVs.
    fn alu_start(&mut self, core: usize, loc: ExecLoc, ready: u64, now: u64, stats: &mut Stats)
        -> u64;

    /// Retire the destination register at cycle `done` (scoreboard entry
    /// plus register-file placement).
    fn retire_dst(&mut self, w: &mut Warp, instr: &MacroOp, loc: ExecLoc, done: u64);
}

/// A resident thread block.
#[derive(Debug)]
struct BlockState {
    id: u32,
    warps_live: usize,
    at_barrier: usize,
    smem: SharedMem,
}

/// Per-core SIMT state (warps, blocks, scheduler bookkeeping).
struct CoreState {
    warps: Vec<Warp>,
    blocks: Vec<BlockState>,
    /// GTO bookkeeping: last-issued warp per subcore.
    last_issued: Vec<Option<usize>>,
    /// RR bookkeeping.
    rr_next: Vec<usize>,
    pending_blocks: VecDeque<u32>,
    /// Live (non-retired) warp indices per subcore — the scheduler scans
    /// only these; retired warps stay in `warps` so in-flight completion
    /// indices remain stable.
    sc_warps: Vec<Vec<usize>>,
    /// Lower bound on the minimum `wake_at` of this subcore's live
    /// warps. `issue_all` skips the whole subcore while the bound is in
    /// the future; a failed scan tightens it to the exact minimum, and
    /// `refresh_wake` lowers it whenever a warp's wake time drops. Lower
    /// bounds are always safe (a stale-low bound only costs a scan that
    /// finds nothing), so correctness never depends on tightening.
    sc_min_wake: Vec<u64>,
}

/// One dynamically observed memory access — a (warp, instruction)
/// issue — captured when address tracing is enabled
/// ([`SimtFrontend::enable_mem_trace`]). The static analysis
/// ([`crate::analysis`]) is validated against these records.
#[derive(Clone, Debug)]
pub struct MemTraceRec {
    /// pc of the memory instruction (source pcs == compiled pcs; the
    /// compiler preserves instruction count).
    pub pc: usize,
    pub space: Space,
    /// `(tid within block, byte address)` per executing lane, in lane
    /// order.
    pub lanes: Vec<(u32, u64)>,
    /// Bank-conflict serialization factor (shared accesses; 1 for
    /// global ones).
    pub conflicts: u64,
    /// All `warp_size` lanes executed.
    pub full_warp: bool,
}

/// Reusable hot-path buffers: the run loop drains completions and the
/// issue paths gather lane addresses/values/operands through these
/// instead of allocating per iteration.
#[derive(Default)]
struct Scratch {
    completions: Vec<Completion>,
    addrs: Vec<(usize, u64)>,
    vals: Vec<(usize, u32)>,
    srcs: Vec<u32>,
    a32: Vec<u32>,
}

/// One core's planned issue work for the current cycle (the read-only
/// phase of the sharded issue pass). Buffers are reused across cycles.
#[derive(Clone, Default)]
struct CorePlan {
    /// `(subcore, warp)` picks in serial scan order.
    picks: Vec<(usize, usize)>,
    /// Subcores whose pick loop ended on a failed scan (the apply phase
    /// tightens their wake lower bound, like the serial loop does).
    tighten: Vec<usize>,
}

/// The shared SIMT frontend, generic over the memory system.
pub struct SimtFrontend<M: MemorySystem + OffloadModel> {
    pub params: FrontendParams,
    pub mem_sys: M,
    /// The decoded kernel, shared with the cache that decoded it. The
    /// issue path reads `kernel.ops`; the reference loop reads
    /// `kernel.instrs` (see the module docs).
    kernel: Option<Arc<DecodedKernel>>,
    launch: Option<LaunchConfig>,
    /// `(param register, value bits)` pairs delivered to every warp at
    /// dispatch — invariant per launch, precomputed so block dispatch
    /// allocates nothing.
    param_seed: Vec<(Reg, u32)>,
    mem: Vec<u8>,
    alloc_top: u64,
    cores: Vec<CoreState>,
    pub stats: Stats,
    now: u64,
    blocks_done: u32,
    /// Lazy min-heap of `(wake_at, core, warp)` — entries are hints;
    /// one whose wake time no longer matches the warp's cached value is
    /// stale and discarded on sight.
    wake_heap: BinaryHeap<Reverse<(u64, u32, u32)>>,
    /// Heap size that triggers a rebuild (the lazy heap retains one
    /// entry per wake refresh until it surfaces).
    wake_heap_cap: usize,
    scratch: Scratch,
    /// Per-core issue plans for the sharded issue pass (empty unless
    /// `params.threads > 1`).
    plans: Vec<CorePlan>,
    /// Address trace, recorded only when enabled (zero cost otherwise).
    mem_trace: Option<Vec<MemTraceRec>>,
}

impl<M: MemorySystem + OffloadModel> SimtFrontend<M> {
    pub fn new(params: FrontendParams, mem_sys: M) -> SimtFrontend<M> {
        let cores = (0..params.cores)
            .map(|_| CoreState {
                warps: Vec::new(),
                blocks: Vec::new(),
                last_issued: vec![None; params.subcores_per_core],
                rr_next: vec![0; params.subcores_per_core],
                pending_blocks: VecDeque::new(),
                sc_warps: vec![Vec::new(); params.subcores_per_core],
                sc_min_wake: vec![u64::MAX; params.subcores_per_core],
            })
            .collect();
        let mem = vec![0; params.mem_bytes];
        SimtFrontend {
            params,
            mem_sys,
            kernel: None,
            launch: None,
            param_seed: Vec::new(),
            mem,
            alloc_top: 0,
            cores,
            stats: Stats::default(),
            now: 0,
            blocks_done: 0,
            wake_heap: BinaryHeap::new(),
            wake_heap_cap: 1024,
            scratch: Scratch::default(),
            plans: Vec::new(),
            mem_trace: None,
        }
    }

    /// Shard cores across `n` worker threads during the issue phase
    /// (`n <= 1` keeps the serial path; either way `run()` output is
    /// byte-identical — see the module docs).
    pub fn set_threads(&mut self, n: usize) {
        self.params.threads = n.max(1);
    }

    /// Start recording every warp memory access into an address trace.
    pub fn enable_mem_trace(&mut self) {
        self.mem_trace = Some(Vec::new());
    }

    /// Take the recorded address trace (and stop recording).
    pub fn take_mem_trace(&mut self) -> Option<Vec<MemTraceRec>> {
        self.mem_trace.take()
    }

    /// Append one record to the address trace, if enabled.
    fn record_mem_trace(
        &mut self,
        c: usize,
        wi: usize,
        pc: usize,
        space: Space,
        addrs: &[(usize, u64)],
        conflicts: u64,
    ) {
        if self.mem_trace.is_none() {
            return;
        }
        let ws = self.params.warp_size;
        let (warp_in_block, lanes) = {
            let w = &self.cores[c].warps[wi];
            (w.warp_in_block, w.lanes)
        };
        let rec = MemTraceRec {
            pc,
            space,
            lanes: addrs.iter().map(|&(l, a)| ((warp_in_block * ws + l) as u32, a)).collect(),
            conflicts,
            full_warp: addrs.len() == lanes && lanes == ws,
        };
        self.mem_trace.as_mut().expect("checked above").push(rec);
    }

    // ---------------- device memory API ----------------

    /// Bump-allocate device memory (256-B aligned).
    pub fn alloc(&mut self, bytes: usize) -> u64 {
        let base = (self.alloc_top + 255) & !255;
        self.alloc_top = base + bytes as u64;
        assert!(
            (self.alloc_top as usize) <= self.mem.len(),
            "device OOM: {} > {}",
            self.alloc_top,
            self.mem.len()
        );
        base
    }

    pub fn write_mem(&mut self, addr: u64, data: &[u8]) {
        let a = addr as usize;
        self.mem[a..a + data.len()].copy_from_slice(data);
    }

    pub fn read_mem(&self, addr: u64, len: usize) -> &[u8] {
        &self.mem[addr as usize..addr as usize + len]
    }

    pub fn write_f32s(&mut self, addr: u64, data: &[f32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.write_mem(addr, &bytes);
    }

    pub fn read_f32s(&self, addr: u64, n: usize) -> Vec<f32> {
        self.read_mem(addr, n * 4)
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn write_u32s(&mut self, addr: u64, data: &[u32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.write_mem(addr, &bytes);
    }

    pub fn read_u32s(&self, addr: u64, n: usize) -> Vec<u32> {
        self.read_mem(addr, n * 4)
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    fn mem_read_u32(&self, addr: u64) -> u32 {
        let a = addr as usize;
        if a + 4 > self.mem.len() {
            return 0;
        }
        u32::from_le_bytes(self.mem[a..a + 4].try_into().unwrap())
    }

    fn mem_write_u32(&mut self, addr: u64, v: u32) {
        let a = addr as usize;
        if a + 4 > self.mem.len() {
            return;
        }
        self.mem[a..a + 4].copy_from_slice(&v.to_le_bytes());
    }

    // ---------------- launch ----------------

    /// Launch a kernel. `home_addr(block)` is the runtime's dispatch
    /// hint: the block is scheduled on the core owning that address
    /// (§V-A); backends without an address map ignore it and fall back
    /// to round-robin.
    pub fn launch(
        &mut self,
        kernel: impl Into<Arc<DecodedKernel>>,
        launch: LaunchConfig,
        params: &[ParamValue],
        home_addr: impl Fn(u32) -> Option<u64>,
    ) -> Result<()> {
        let kernel: Arc<DecodedKernel> = kernel.into();
        let cap =
            self.params.max_warps_per_subcore * self.params.subcores_per_core * self.params.warp_size;
        if launch.block as usize > cap {
            bail!("block size {} exceeds core capacity", launch.block);
        }
        if kernel.params.len() != params.len() {
            bail!("kernel `{}` expects {} params, got {}", kernel.name, kernel.params.len(), params.len());
        }
        self.kernel = Some(kernel);
        self.launch = Some(launch);
        self.param_seed = self
            .kernel
            .as_ref()
            .unwrap()
            .params
            .iter()
            .copied()
            .zip(params.iter().map(|v| v.bits()))
            .collect();
        let ncores = self.params.cores;
        for b in 0..launch.grid {
            let core = self
                .mem_sys
                .home_core(home_addr(b))
                .unwrap_or(b as usize % ncores);
            self.cores[core].pending_blocks.push_back(b);
        }
        for c in 0..ncores {
            while self.try_dispatch_block(c) {}
        }
        Ok(())
    }

    /// Dispatch the next pending block on core `c` if resources allow.
    fn try_dispatch_block(&mut self, c: usize) -> bool {
        let launch = self.launch.unwrap();
        if self.cores[c].blocks.len() >= self.params.max_blocks_per_core {
            return false;
        }
        let warps_per_block = launch.warps_per_block(self.params.warp_size);
        let live_warps =
            self.cores[c].warps.iter().filter(|w| w.state != WarpState::Done).count();
        if live_warps + warps_per_block
            > self.params.max_warps_per_subcore * self.params.subcores_per_core
        {
            return false;
        }
        let Some(b) = self.cores[c].pending_blocks.pop_front() else {
            return false;
        };
        let reg_counts = self.kernel.as_ref().unwrap().reg_counts;
        let smem_bytes = (launch.smem_bytes as usize).min(self.params.smem_bytes);
        self.cores[c].blocks.push(BlockState {
            id: b,
            warps_live: warps_per_block,
            at_barrier: 0,
            smem: SharedMem::new(smem_bytes.max(4)),
        });
        for wi in 0..warps_per_block {
            let lanes = (launch.block as usize - wi * self.params.warp_size).min(self.params.warp_size);
            let subcore = wi % self.params.subcores_per_core;
            let mut w = Warp::new(b, wi, lanes, subcore, reg_counts, self.params.warp_size);
            w.ready_at = self.now + 1;
            // Deliver parameters; the backend records which register
            // file(s) hold them (the MPU seeds both, saving a per-warp
            // register move per parameter).
            for pi in 0..self.param_seed.len() {
                let (p, bits) = self.param_seed[pi];
                w.write_all(p, bits);
                self.mem_sys.seed_param(&mut w, p);
            }
            let widx = self.cores[c].warps.len();
            self.cores[c].sc_warps[subcore].push(widx);
            self.cores[c].warps.push(w);
            self.refresh_wake(c, widx);
        }
        true
    }

    // ---------------- wake bookkeeping ----------------

    /// Recompute the cached wake-up time of warp `(c, wi)` after any
    /// transition that affects its issueability (issue, barrier
    /// arrive/release, load completion, block dispatch). `wake_at` is
    /// exact: `u64::MAX` while the warp cannot issue without a further
    /// event, otherwise the earliest cycle `pick_warp` may select it.
    fn refresh_wake(&mut self, c: usize, wi: usize) {
        let (wake, sc) = {
            let kernel = self.kernel.as_ref().unwrap();
            let w = &self.cores[c].warps[wi];
            let wake = if w.state != WarpState::Ready {
                u64::MAX
            } else {
                let pc = w.pc();
                if pc >= kernel.ops.len() {
                    u64::MAX
                } else {
                    let dep = w.macro_ready_at(&kernel.ops[pc]);
                    if dep == u64::MAX {
                        u64::MAX // unblocked by a load completion later
                    } else {
                        dep.max(w.ready_at)
                    }
                }
            };
            (wake, w.subcore)
        };
        self.cores[c].warps[wi].wake_at = wake;
        if wake != u64::MAX {
            self.wake_heap.push(Reverse((wake, c as u32, wi as u32)));
            if wake < self.cores[c].sc_min_wake[sc] {
                self.cores[c].sc_min_wake[sc] = wake;
            }
            if self.wake_heap.len() >= self.wake_heap_cap {
                self.rebuild_wake_heap();
            }
        }
    }

    /// The lazy heap accumulates one entry per wake refresh; rebuild it
    /// from live warp state once stale entries dominate.
    fn rebuild_wake_heap(&mut self) {
        self.wake_heap.clear();
        let mut live = 0usize;
        for (c, core) in self.cores.iter().enumerate() {
            for &wi in core.sc_warps.iter().flatten() {
                live += 1;
                let wake = core.warps[wi].wake_at;
                if wake != u64::MAX {
                    self.wake_heap.push(Reverse((wake, c as u32, wi as u32)));
                }
            }
        }
        self.wake_heap_cap = (live * 8).max(1024);
    }

    /// Earliest wake-up among live warps, from the lazy heap (stale
    /// entries — warps whose wake time moved since they were pushed —
    /// are discarded on sight). `None` when every warp is blocked on a
    /// memory completion, at a barrier, or retired.
    fn next_warp_wake(&mut self) -> Option<u64> {
        while let Some(&Reverse((t, c, wi))) = self.wake_heap.peek() {
            if self.cores[c as usize].warps[wi as usize].wake_at == t {
                return Some(t);
            }
            self.wake_heap.pop();
        }
        None
    }

    /// After a scan found nothing issueable, reset the subcore's wake
    /// lower bound to the exact minimum so subsequent cycles skip the
    /// scan entirely until something can actually run.
    fn tighten_sc_min(&mut self, c: usize, sc: usize) {
        let core = &self.cores[c];
        let min = core.sc_warps[sc]
            .iter()
            .map(|&wi| core.warps[wi].wake_at)
            .min()
            .unwrap_or(u64::MAX);
        self.cores[c].sc_min_wake[sc] = min;
    }

    /// Apply drained load completions to their warps (scoreboard entry
    /// plus §IV-B1 track-table placement) and wake them.
    fn apply_completions(&mut self, completions: &[Completion]) {
        for comp in completions {
            {
                let w = &mut self.cores[comp.core].warps[comp.warp];
                w.reg_ready.insert(comp.dst, comp.ready);
                match comp.place {
                    RegPlace::Near => w.track.write_nb(comp.dst),
                    RegPlace::Far => w.track.write_fb(comp.dst),
                    RegPlace::Untracked => {}
                }
            }
            self.refresh_wake(comp.core, comp.warp);
        }
    }

    // ---------------- main loop ----------------

    /// Run to completion; returns final stats.
    ///
    /// Event-driven: `advance` runs only on cycles with memory work
    /// due, idle stretches jump through the warp wake-up heap, and
    /// memory-only stretches are batched through
    /// [`MemorySystem::advance_to`]. Cycle-for-cycle identical to
    /// [`SimtFrontend::run_reference`].
    pub fn run(&mut self) -> Result<Stats> {
        let grid = self.launch.map(|l| l.grid).unwrap_or(0);
        let mut completions = std::mem::take(&mut self.scratch.completions);
        loop {
            // Memory work due this cycle? (`advance` is a no-op when the
            // backend's next event is still in the future — the trait
            // contract the backends uphold.)
            let mem_next = self.mem_sys.next_event();
            let advanced = mem_next.is_some_and(|t| t <= self.now);
            if advanced {
                self.mem_sys.advance(self.now, &mut self.stats);
            }
            completions.clear();
            self.mem_sys.drain_completed(self.now, &mut completions);
            self.apply_completions(&completions);
            let issued = if self.params.threads > 1 {
                self.issue_all_parallel()
            } else {
                self.issue_all()
            };

            let work_left = self.blocks_done < grid || !self.mem_sys.idle();
            if !work_left {
                break;
            }
            if self.now >= self.params.max_cycles {
                self.scratch.completions = completions;
                bail!("simulation exceeded max_cycles={} (deadlock?)", self.params.max_cycles);
            }
            if issued {
                self.now += 1;
            } else {
                // The loop-top `next_event` is still current unless this
                // iteration advanced the memory system or issued an
                // access (nothing issued here, and drains don't touch
                // event state) — skip the per-controller recompute then.
                let mem_next = if advanced { self.mem_sys.next_event() } else { mem_next };
                self.fast_forward(mem_next);
            }
        }
        self.stats.cycles = self.now;
        self.scratch.completions = completions;
        Ok(self.stats.clone())
    }

    /// Nothing issued at `now`: jump to the next cycle anything can
    /// happen. Pure-memory stretches (the long DRAM stalls of
    /// memory-bound kernels) are handed to the backend in one
    /// `advance_to` call instead of being re-polled per event.
    /// `mem_next` is the backend's current `next_event()` (passed in so
    /// the run loop can reuse its loop-top probe when still valid).
    fn fast_forward(&mut self, mem_next: Option<u64>) {
        let wake = self.next_warp_wake();
        let next = match (wake, mem_next) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        match next {
            Some(t) if t > self.now => {
                let mem_only = match (mem_next, wake) {
                    (Some(m), Some(w)) => m < w,
                    (Some(_), None) => true,
                    _ => false,
                };
                if mem_only {
                    // No warp can issue before `wake` (or ever): let the
                    // backend burn through its own event chain up to the
                    // cycle before, stopping early at the first load
                    // completion. Clamped to the max_cycles valve —
                    // beyond it the loop degrades to the old
                    // one-event-per-iteration jumps — and `.max(t)`
                    // keeps time monotonic in the degenerate cases.
                    let cap = wake
                        .map(|w| w - 1)
                        .unwrap_or(u64::MAX)
                        .min(self.params.max_cycles)
                        .max(t);
                    self.now = self.mem_sys.advance_to(cap, &mut self.stats).max(t);
                } else {
                    self.now = t;
                }
            }
            _ => self.now += 1,
        }
    }

    /// The pre-event-driven per-cycle loop, kept verbatim as the timing
    /// oracle: `run` must match it cycle-for-cycle and stat-for-stat
    /// (asserted by the equivalence tests). It recomputes issueability
    /// from first principles every cycle and polls the memory system
    /// unconditionally, so it shares none of the event-driven caches'
    /// failure modes.
    pub fn run_reference(&mut self) -> Result<Stats> {
        let grid = self.launch.map(|l| l.grid).unwrap_or(0);
        let mut completions = std::mem::take(&mut self.scratch.completions);
        loop {
            self.mem_sys.advance(self.now, &mut self.stats);
            completions.clear();
            self.mem_sys.drain_completed(self.now, &mut completions);
            self.apply_completions(&completions);
            let issued = self.issue_all_scan();

            let work_left = self.blocks_done < grid || !self.mem_sys.idle();
            if !work_left {
                break;
            }
            if self.now >= self.params.max_cycles {
                self.scratch.completions = completions;
                bail!("simulation exceeded max_cycles={} (deadlock?)", self.params.max_cycles);
            }
            if issued {
                self.now += 1;
            } else {
                match self.next_interesting_scan() {
                    Some(t) if t > self.now => self.now = t,
                    _ => self.now += 1,
                }
            }
        }
        self.stats.cycles = self.now;
        self.scratch.completions = completions;
        Ok(self.stats.clone())
    }

    /// Earliest future cycle where anything can happen — the
    /// O(cores × warps) rescan the event-driven loop replaced; kept for
    /// [`SimtFrontend::run_reference`].
    fn next_interesting_scan(&self) -> Option<u64> {
        let mut best: Option<u64> = self.mem_sys.next_event();
        let kernel = self.kernel.as_ref().unwrap();
        for c in &self.cores {
            for w in c.sc_warps.iter().flatten().map(|&wi| &c.warps[wi]) {
                if w.state != WarpState::Ready {
                    continue;
                }
                let pc = w.pc();
                if pc >= kernel.instrs.len() {
                    continue;
                }
                let dep = w.instr_ready_at(&kernel.instrs[pc]);
                if dep == u64::MAX {
                    continue; // unblocked by a load completion later
                }
                let t = dep.max(w.ready_at);
                best = Some(best.map_or(t, |b| b.min(t)));
            }
        }
        best
    }

    /// Try to issue on every subcore of every core; returns whether any
    /// instruction issued. Subcores whose wake lower bound is in the
    /// future are skipped without scanning their warps.
    fn issue_all(&mut self) -> bool {
        let mut issued_any = false;
        let ncores = self.cores.len();
        for c in 0..ncores {
            for sc in 0..self.params.subcores_per_core {
                if self.cores[c].sc_min_wake[sc] > self.now {
                    continue; // lower bound: nothing here can issue yet
                }
                for _ in 0..self.params.issue_width {
                    if let Some(wi) = self.pick_warp(c, sc) {
                        self.issue(c, wi);
                        self.cores[c].last_issued[sc] = Some(wi);
                        issued_any = true;
                    } else {
                        self.tighten_sc_min(c, sc);
                        break;
                    }
                }
            }
        }
        issued_any
    }

    /// Two-phase sharded issue pass (`params.threads > 1`): plan
    /// read-only in parallel, apply serially in fixed order — see the
    /// module docs for why the result is byte-identical to
    /// [`SimtFrontend::issue_all`]. Falls back to the serial scan under
    /// round-robin scheduling, where a plan computed against cycle-top
    /// state can diverge: mid-cycle block retirement shrinks
    /// `sc_warps`, shifting the rotation base `rr_next % n` for later
    /// picks in the same cycle.
    fn issue_all_parallel(&mut self) -> bool {
        if self.params.sched_policy != SchedPolicy::Gto {
            return self.issue_all();
        }
        let ncores = self.cores.len();
        if ncores == 0 {
            return false;
        }
        let mut plans = std::mem::take(&mut self.plans);
        plans.resize_with(ncores, CorePlan::default);

        // Phase A: read-only planning, cores sharded across the pool.
        let threads = self.params.threads.min(ncores).max(1);
        let chunk = ncores.div_ceil(threads);
        {
            let params = &self.params;
            let cores = &self.cores;
            let now = self.now;
            plans.par_chunks_mut(chunk).enumerate().for_each(|(t, ps)| {
                for (i, plan) in ps.iter_mut().enumerate() {
                    plan_core(params, &cores[t * chunk + i], now, plan);
                }
            });
        }

        // Phase B: serial apply in core/subcore/slot order — exactly
        // the mutation sequence the serial loop performs, interleaving
        // each subcore's issues with its wake-bound tightening.
        let mut issued_any = false;
        for c in 0..ncores {
            let mut next = 0;
            for sc in 0..self.params.subcores_per_core {
                while next < plans[c].picks.len() && plans[c].picks[next].0 == sc {
                    let wi = plans[c].picks[next].1;
                    next += 1;
                    self.issue(c, wi);
                    self.cores[c].last_issued[sc] = Some(wi);
                    issued_any = true;
                }
                if plans[c].tighten.contains(&sc) {
                    self.tighten_sc_min(c, sc);
                }
            }
        }
        self.plans = plans;
        issued_any
    }

    /// Reference issue pass used by `run_reference`: full scan, no wake
    /// gating.
    fn issue_all_scan(&mut self) -> bool {
        let mut issued_any = false;
        let ncores = self.cores.len();
        for c in 0..ncores {
            for sc in 0..self.params.subcores_per_core {
                for _ in 0..self.params.issue_width {
                    if let Some(wi) = self.pick_warp_scan(c, sc) {
                        self.issue(c, wi);
                        self.cores[c].last_issued[sc] = Some(wi);
                        issued_any = true;
                    } else {
                        break;
                    }
                }
            }
        }
        issued_any
    }

    /// Scheduler: pick an issueable warp on (core, subcore). Reads only
    /// the cached wake times (`refresh_wake` keeps them exact).
    fn pick_warp(&self, c: usize, sc: usize) -> Option<usize> {
        let core = &self.cores[c];
        let can_issue = |wi: usize| -> bool {
            let w = &core.warps[wi];
            w.subcore == sc && w.wake_at <= self.now
        };

        let live = &core.sc_warps[sc];
        match self.params.sched_policy {
            SchedPolicy::Gto => {
                // Greedy: stick with the last-issued warp.
                if let Some(last) = core.last_issued[sc] {
                    if last < core.warps.len() && can_issue(last) {
                        return Some(last);
                    }
                }
                // Then oldest (dispatch order).
                live.iter().copied().find(|&wi| can_issue(wi))
            }
            SchedPolicy::RoundRobin => {
                let n = live.len();
                if n == 0 {
                    return None;
                }
                let start = core.rr_next[sc] % n;
                (0..n).map(|k| live[(start + k) % n]).find(|&wi| can_issue(wi))
            }
        }
    }

    /// Reference scheduler (same policy as `pick_warp`, recomputing
    /// issueability from warp state + scoreboard instead of the cached
    /// wake times) — `run_reference` only.
    fn pick_warp_scan(&self, c: usize, sc: usize) -> Option<usize> {
        let core = &self.cores[c];
        let kernel = self.kernel.as_ref().unwrap();
        let can_issue = |wi: usize| -> bool {
            let w = &core.warps[wi];
            if w.state != WarpState::Ready || w.subcore != sc || w.ready_at > self.now {
                return false;
            }
            let pc = w.pc();
            if pc >= kernel.instrs.len() {
                return false;
            }
            w.instr_ready_at(&kernel.instrs[pc]) <= self.now
        };

        let live = &core.sc_warps[sc];
        match self.params.sched_policy {
            SchedPolicy::Gto => {
                if let Some(last) = core.last_issued[sc] {
                    if last < core.warps.len() && can_issue(last) {
                        return Some(last);
                    }
                }
                live.iter().copied().find(|&wi| can_issue(wi))
            }
            SchedPolicy::RoundRobin => {
                let n = live.len();
                if n == 0 {
                    return None;
                }
                let start = core.rr_next[sc] % n;
                (0..n).map(|k| live[(start + k) % n]).find(|&wi| can_issue(wi))
            }
        }
    }

    // ---------------- instruction issue ----------------

    fn issue(&mut self, c: usize, wi: usize) {
        self.issue_inner(c, wi);
        // Every path through issue changes the warp's pc, ready time,
        // scoreboard or state — recompute its wake time once here.
        // (Barrier release and block dispatch refresh the *other*
        // affected warps where they happen.)
        self.refresh_wake(c, wi);
    }

    fn issue_inner(&mut self, c: usize, wi: usize) {
        // One `Copy` out of the pre-decoded array — no clones, no
        // allocation, no per-issue operand interpretation (the `Instr`
        // clone that preceded this dominated the profile; see
        // EXPERIMENTS.md §Perf iteration 1 and ISSUE.md PR 7).
        let pc = self.cores[c].warps[wi].pc();
        let mop = self.kernel.as_ref().unwrap().ops[pc];

        if self.params.sched_policy == SchedPolicy::RoundRobin {
            let sc = self.cores[c].warps[wi].subcore;
            let pos = self.cores[c].sc_warps[sc].iter().position(|&x| x == wi).unwrap_or(0);
            self.cores[c].rr_next[sc] = pos + 1;
        }

        {
            let w = &mut self.cores[c].warps[wi];
            w.ready_at = self.now + 1;
            w.last_issue = self.now;
        }

        // Guard evaluation.
        let (exec_mask, active_mask) = {
            let w = &self.cores[c].warps[wi];
            let active = w.active_mask();
            let mask = match mop.guard {
                None => active,
                Some((p, neg)) => {
                    let mut m = 0u64;
                    for lane in 0..w.lanes {
                        if active >> lane & 1 == 1 && (w.read(p, lane) != 0) != neg {
                            m |= 1 << lane;
                        }
                    }
                    m
                }
            };
            (mask, active)
        };

        // Control flow first (always on the front pipeline / far-bank).
        // The dispatch class was resolved at decode time: one jump, no
        // nested `(op, space)` matching.
        match mop.class {
            OpClass::Branch => {
                self.stats.instrs_far += 1;
                let taken = if mop.guard.is_none() { active_mask } else { exec_mask };
                self.cores[c].warps[wi].branch(taken, mop.target, pc + 1, mop.reconv);
                return;
            }
            OpClass::Bar => {
                self.stats.instrs_far += 1;
                self.stats.barriers += 1;
                self.barrier(c, wi, pc);
                return;
            }
            OpClass::Exit => {
                self.stats.instrs_far += 1;
                self.exit(c, wi, active_mask);
                return;
            }
            _ => {}
        }

        if exec_mask == 0 {
            self.stats.predicated_off += 1;
            self.stats.instrs_far += 1;
            self.cores[c].warps[wi].set_pc(pc + 1);
            return;
        }

        match mop.class {
            OpClass::Global => self.issue_global(c, wi, pc, &mop, exec_mask),
            OpClass::Shared => self.issue_shared(c, wi, pc, &mop, exec_mask, mop.hint),
            _ => self.issue_alu(c, wi, pc, &mop, exec_mask, mop.hint),
        }
    }

    /// Gather `(lane, byte address)` of every executing lane into the
    /// reusable scratch buffer (caller returns it via `self.scratch`).
    fn fill_lane_addrs(&mut self, c: usize, wi: usize, instr: &MacroOp, exec_mask: u64) -> Vec<(usize, u64)> {
        let mut addrs = std::mem::take(&mut self.scratch.addrs);
        addrs.clear();
        let w = &self.cores[c].warps[wi];
        debug_assert!(instr.has_mem, "memory instruction");
        for l in 0..w.lanes {
            if exec_mask >> l & 1 == 1 {
                let base = w.read(instr.mem_base, l);
                addrs.push((l, (base as i64 + instr.mem_offset as i64) as u64));
            }
        }
        addrs
    }

    fn issue_alu(&mut self, c: usize, wi: usize, pc: usize, instr: &MacroOp, exec_mask: u64, hint: Loc) {
        let launch = self.launch.unwrap();
        let (loc, ready) = self.mem_sys.pre_issue(
            c,
            &mut self.cores[c].warps[wi],
            instr,
            hint,
            self.now,
            &mut self.stats,
        );

        // Functional execution.
        let (block, warp_in_block, lanes) = {
            let w = &self.cores[c].warps[wi];
            (w.block, w.warp_in_block, w.lanes)
        };
        let n_srcs = instr.n_srcs as u64;
        let mut srcs = std::mem::take(&mut self.scratch.srcs);
        for lane in 0..lanes {
            if exec_mask >> lane & 1 == 0 {
                continue;
            }
            let ctx = LaneCtx {
                tid: (warp_in_block * self.params.warp_size + lane) as u32,
                ntid: launch.block,
                ctaid: block,
                nctaid: launch.grid,
            };
            srcs.clear();
            {
                let w = &self.cores[c].warps[wi];
                for &slot in instr.src_slots() {
                    srcs.push(slot_value(slot, &ctx, &|r| w.read(r, lane)));
                }
            }
            let v = alu_eval(instr.op, instr.ty, instr.src_ty, instr.cmp, &srcs);
            if let Some(d) = instr.dst {
                self.cores[c].warps[wi].write(d, lane, v);
            }
        }
        srcs.clear();
        self.scratch.srcs = srcs;

        // Timing + accounting (uniform in the execution location).
        match loc {
            ExecLoc::Near => {
                self.stats.instrs_near += 1;
                self.stats.rf_near_accesses += n_srcs + 1;
            }
            ExecLoc::Far => {
                self.stats.instrs_far += 1;
                self.stats.rf_far_accesses += n_srcs + 1;
            }
        }
        self.stats.opc_accesses += n_srcs;
        self.stats.alu_lane_ops += exec_mask.count_ones() as u64;
        let lat = if instr.is_sfu { self.params.sfu_latency } else { self.params.alu_latency };
        let start = self.mem_sys.alu_start(c, loc, ready, self.now, &mut self.stats);
        let done = start + self.params.opc_latency + lat;

        self.mem_sys.retire_dst(&mut self.cores[c].warps[wi], instr, loc, done);
        self.cores[c].warps[wi].set_pc(pc + 1);
    }

    fn issue_global(&mut self, c: usize, wi: usize, pc: usize, instr: &MacroOp, exec_mask: u64) {
        self.stats.global_mem_instrs += 1;
        let launch = self.launch.unwrap();
        let addrs = self.fill_lane_addrs(c, wi, instr, exec_mask);
        self.record_mem_trace(c, wi, pc, Space::Global, &addrs, 1);

        // Functional execution first (program order per warp).
        match instr.op {
            Op::Ld => {
                let dst = instr.dst.unwrap();
                let mut vals = std::mem::take(&mut self.scratch.vals);
                vals.clear();
                vals.extend(addrs.iter().map(|&(l, a)| (l, self.mem_read_u32(a))));
                let w = &mut self.cores[c].warps[wi];
                for &(l, v) in &vals {
                    w.write(dst, l, v);
                }
                vals.clear();
                self.scratch.vals = vals;
            }
            Op::St => {
                let src = instr.srcs[0];
                let (block, warp_in_block) = {
                    let w = &self.cores[c].warps[wi];
                    (w.block, w.warp_in_block)
                };
                for &(l, a) in &addrs {
                    let ctx = LaneCtx {
                        tid: (warp_in_block * self.params.warp_size + l) as u32,
                        ntid: launch.block,
                        ctaid: block,
                        nctaid: launch.grid,
                    };
                    let w = &self.cores[c].warps[wi];
                    let v = slot_value(src, &ctx, &|r| w.read(r, l));
                    self.mem_write_u32(a, v);
                }
            }
            Op::Red => {
                // Atomic add (global): sequentialized by simulation.
                let src = instr.srcs[0];
                for &(l, a) in &addrs {
                    let w = &self.cores[c].warps[wi];
                    let v = match src {
                        Slot::Reg(r) => w.read(r, l),
                        s => slot_value(
                            s,
                            &LaneCtx { tid: 0, ntid: 0, ctaid: 0, nctaid: 0 },
                            &|r| w.read(r, l),
                        ),
                    };
                    let old = self.mem_read_u32(a);
                    let new = match instr.ty {
                        crate::isa::Ty::F32 => (f32::from_bits(old) + f32::from_bits(v)).to_bits(),
                        _ => old.wrapping_add(v),
                    };
                    self.mem_write_u32(a, new);
                }
            }
            _ => unreachable!(),
        }

        // Timing: the memory system owns the whole path.
        let full_warp = {
            let w = &self.cores[c].warps[wi];
            exec_mask.count_ones() as usize == w.lanes && w.lanes == self.params.warp_size
        };
        let ctx = AccessCtx { core: c, warp_index: wi, instr, addrs: &addrs, full_warp, now: self.now };
        self.mem_sys.issue_access(&ctx, &mut self.cores[c].warps[wi], &mut self.stats);
        self.cores[c].warps[wi].set_pc(pc + 1);
        self.scratch.addrs = addrs;
    }

    fn issue_shared(&mut self, c: usize, wi: usize, pc: usize, instr: &MacroOp, exec_mask: u64, hint: Loc) {
        self.stats.shared_mem_instrs += 1;
        let launch = self.launch.unwrap();
        let (loc, ready) = self.mem_sys.pre_issue(
            c,
            &mut self.cores[c].warps[wi],
            instr,
            hint,
            self.now,
            &mut self.stats,
        );
        let addrs = self.fill_lane_addrs(c, wi, instr, exec_mask);
        let (block, warp_in_block) = {
            let w = &self.cores[c].warps[wi];
            (w.block, w.warp_in_block)
        };
        let bslot = self.cores[c].blocks.iter().position(|b| b.id == block).expect("block resident");

        // Functional.
        match instr.op {
            Op::Ld => {
                let dst = instr.dst.unwrap();
                let mut vals = std::mem::take(&mut self.scratch.vals);
                vals.clear();
                vals.extend(
                    addrs
                        .iter()
                        .map(|&(l, a)| (l, self.cores[c].blocks[bslot].smem.read_u32(a as u32))),
                );
                let w = &mut self.cores[c].warps[wi];
                for &(l, v) in &vals {
                    w.write(dst, l, v);
                }
                vals.clear();
                self.scratch.vals = vals;
            }
            Op::St | Op::Red => {
                let src = instr.srcs[0];
                for &(l, a) in &addrs {
                    let ctx = LaneCtx {
                        tid: (warp_in_block * self.params.warp_size + l) as u32,
                        ntid: launch.block,
                        ctaid: block,
                        nctaid: launch.grid,
                    };
                    let v = {
                        let w = &self.cores[c].warps[wi];
                        slot_value(src, &ctx, &|r| w.read(r, l))
                    };
                    let smem = &mut self.cores[c].blocks[bslot].smem;
                    if instr.op == Op::St {
                        smem.write_u32(a as u32, v);
                    } else if instr.ty == crate::isa::Ty::F32 {
                        smem.red_add_f32(a as u32, f32::from_bits(v));
                    } else {
                        smem.red_add_u32(a as u32, v);
                    }
                }
            }
            _ => unreachable!(),
        }

        // Timing: smem latency + bank-conflict serialization. The data
        // never crosses the TSVs when the smem and the execution location
        // coincide (§IV-C) — any placement traffic appears through the
        // register moves done by `pre_issue`.
        let mut a32 = std::mem::take(&mut self.scratch.a32);
        a32.clear();
        a32.extend(addrs.iter().map(|&(_, a)| a as u32));
        let conflicts = self.cores[c].blocks[bslot].smem.conflict_factor(&a32);
        a32.clear();
        self.scratch.a32 = a32;
        self.record_mem_trace(c, wi, pc, Space::Shared, &addrs, conflicts);
        self.stats.smem_accesses += conflicts;
        let done = self.now.max(ready) + self.params.smem_latency + (conflicts - 1);
        match loc {
            ExecLoc::Near => self.stats.instrs_near += 1,
            ExecLoc::Far => self.stats.instrs_far += 1,
        }

        self.mem_sys.retire_dst(&mut self.cores[c].warps[wi], instr, loc, done);
        self.cores[c].warps[wi].set_pc(pc + 1);
        self.scratch.addrs = addrs;
    }

    fn barrier(&mut self, c: usize, wi: usize, pc: usize) {
        let block = self.cores[c].warps[wi].block;
        self.cores[c].warps[wi].set_pc(pc + 1);
        self.cores[c].warps[wi].state = WarpState::AtBarrier;
        let bslot = self.cores[c].blocks.iter().position(|b| b.id == block).expect("block resident");
        let release = {
            let b = &mut self.cores[c].blocks[bslot];
            b.at_barrier += 1;
            if b.at_barrier >= b.warps_live {
                b.at_barrier = 0;
                true
            } else {
                false
            }
        };
        if release {
            self.release_barrier(c, block, self.now + 1);
        }
    }

    /// Wake every warp of `block` waiting at the barrier.
    fn release_barrier(&mut self, c: usize, block: u32, release: u64) {
        for wi in 0..self.cores[c].warps.len() {
            let released = {
                let w = &mut self.cores[c].warps[wi];
                if w.block == block && w.state == WarpState::AtBarrier {
                    w.state = WarpState::Ready;
                    w.ready_at = release;
                    true
                } else {
                    false
                }
            };
            if released {
                self.refresh_wake(c, wi);
            }
        }
    }

    fn exit(&mut self, c: usize, wi: usize, mask: u64) {
        let done = self.cores[c].warps[wi].exit_lanes(mask);
        if !done {
            return;
        }
        let block = self.cores[c].warps[wi].block;
        let bslot = self.cores[c].blocks.iter().position(|b| b.id == block).expect("block resident");
        enum After {
            Finished,
            Release,
            Nothing,
        }
        let after = {
            let b = &mut self.cores[c].blocks[bslot];
            b.warps_live -= 1;
            if b.warps_live == 0 {
                After::Finished
            } else if b.at_barrier >= b.warps_live {
                // A barrier may now be satisfiable with fewer live warps.
                b.at_barrier = 0;
                After::Release
            } else {
                After::Nothing
            }
        };
        match after {
            After::Release => {
                self.release_barrier(c, block, self.now + 1);
                return;
            }
            After::Nothing => return,
            After::Finished => {}
        }
        // Block finished: retire it and dispatch the next. Done warps
        // stay in the vector (in-flight completions hold warp indices);
        // the scheduler scans only the live lists.
        self.cores[c].blocks.remove(bslot);
        {
            let core = &mut self.cores[c];
            for sc in 0..core.sc_warps.len() {
                let warps = &core.warps;
                core.sc_warps[sc].retain(|&wj| warps[wj].block != block);
            }
        }
        self.blocks_done += 1;
        while self.try_dispatch_block(c) {}
    }
}

/// Compute the issue picks the serial scan would make on one core at
/// cycle `now`, without mutating anything (phase A of
/// [`SimtFrontend::issue_all_parallel`]; GTO only — see there). Sound
/// because nothing issued at `now` becomes issueable at `now`: an
/// issued warp's refreshed wake is `> now`, so excluding
/// already-picked warps replicates the serial scan's post-issue view.
fn plan_core(params: &FrontendParams, core: &CoreState, now: u64, plan: &mut CorePlan) {
    plan.picks.clear();
    plan.tighten.clear();
    for sc in 0..params.subcores_per_core {
        if core.sc_min_wake[sc] > now {
            continue; // lower bound: nothing here can issue yet
        }
        let mut last = core.last_issued[sc];
        for _ in 0..params.issue_width {
            match plan_pick(core, sc, now, last, &plan.picks) {
                Some(wi) => {
                    plan.picks.push((sc, wi));
                    last = Some(wi);
                }
                None => {
                    plan.tighten.push(sc);
                    break;
                }
            }
        }
    }
}

/// GTO pick over cycle-top state: [`SimtFrontend::pick_warp`] with
/// already-picked warps excluded (their post-issue wake is `> now`).
fn plan_pick(
    core: &CoreState,
    sc: usize,
    now: u64,
    last: Option<usize>,
    picked: &[(usize, usize)],
) -> Option<usize> {
    let can_issue = |wi: usize| -> bool {
        let w = &core.warps[wi];
        w.subcore == sc && w.wake_at <= now && !picked.iter().any(|&(_, p)| p == wi)
    };
    if let Some(l) = last {
        if l < core.warps.len() && can_issue(l) {
            return Some(l);
        }
    }
    core.sc_warps[sc].iter().copied().find(|&wi| can_issue(wi))
}
