//! Instruction-offload decision + register-move planning (§IV-B1, Fig. 3).
//!
//! Step 1: instruction location — hardware-mandated far-bank set first
//! (global ld/st through the LSU, control flow, barriers), then the
//! compiler hint (Algorithm-1 annotation), then the hardware default
//! (offload iff all sources have valid near-bank copies), with far-bank
//! as the universal fallback.
//!
//! Step 2: source-register locations — hardware policy for memory ops
//! (address regs far, value regs near), otherwise follow the
//! instruction.
//!
//! Step 3: register movement — compare against the track table; every
//! miss is one warp-register (128 B) transfer by the register move
//! engine.

use super::warp::TrackTable;
use crate::config::{MachineConfig, OffloadPolicy, PipelineMode, SmemLocation};
use crate::isa::instr::Loc;
use crate::isa::{Instr, Op, Reg, RegClass, Space};

/// Where an instruction executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecLoc {
    Near,
    Far,
}

/// A planned register move.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MoveDir {
    /// Far-bank RF → near-bank RF (down the TSVs).
    ToNb,
    /// Near-bank RF → far-bank RF (up the TSVs).
    ToFb,
}

/// Step 1 of Fig. 3: decide the execution location.
pub fn instr_location(
    instr: &Instr,
    instr_loc_hint: Loc,
    cfg: &MachineConfig,
    track: &TrackTable,
) -> ExecLoc {
    if cfg.pipeline_mode == PipelineMode::PonB {
        return ExecLoc::Far;
    }
    // Hardware-mandated set (highest priority).
    match instr.op {
        Op::Bra | Op::Bar | Op::Exit => return ExecLoc::Far,
        Op::Ld | Op::St | Op::Red => {
            return match instr.space {
                Some(Space::Shared) if cfg.smem_location == SmemLocation::NearBank => ExecLoc::Near,
                // Far-bank smem executes on the base logic die; global
                // accesses always go through the far-bank LSU front half
                // (the near-bank handoff is modelled inside the LSU path).
                _ => ExecLoc::Far,
            };
        }
        _ => {}
    }
    match cfg.offload_policy {
        OffloadPolicy::AllNearBank => ExecLoc::Near,
        OffloadPolicy::AllFarBank => ExecLoc::Far,
        OffloadPolicy::CompilerAnnotated => match instr_loc_hint {
            Loc::N => ExecLoc::Near,
            Loc::F | Loc::B => ExecLoc::Far,
            Loc::U => hardware_default(instr, track),
        },
        OffloadPolicy::HardwareDefault => hardware_default(instr, track),
    }
}

/// The §IV-B1 default policy: offload iff every source register has a
/// valid near-bank copy; far-bank is the fall-back with full pipeline
/// support.
fn hardware_default(instr: &Instr, track: &TrackTable) -> ExecLoc {
    let srcs: Vec<Reg> = instr
        .reads()
        .into_iter()
        .filter(|r| r.class != RegClass::P)
        .collect();
    if !srcs.is_empty() && srcs.iter().all(|r| track.nb_valid(*r)) {
        ExecLoc::Near
    } else {
        ExecLoc::Far
    }
}

/// Required location of each *read* register (step 2 of Fig. 3).
/// Predicates never move — the SIMT mask travels with the instruction
/// packet.
pub fn required_reg_locs(instr: &Instr, loc: ExecLoc, cfg: &MachineConfig) -> Vec<(Reg, ExecLoc)> {
    let mut out = Vec::new();
    match (instr.op, instr.space) {
        (Op::Ld, Some(Space::Global)) => {
            if let Some(a) = instr.addr_reg() {
                out.push((a, ExecLoc::Far));
            }
        }
        (Op::St, Some(Space::Global)) | (Op::Red, Some(Space::Global)) => {
            if let Some(a) = instr.addr_reg() {
                out.push((a, ExecLoc::Far));
            }
            let value_loc = if cfg.pipeline_mode == PipelineMode::PonB {
                ExecLoc::Far
            } else {
                ExecLoc::Near
            };
            for s in instr.srcs.iter().filter_map(|o| o.as_reg()) {
                if s.class != RegClass::P {
                    out.push((s, value_loc));
                }
            }
        }
        (Op::Ld | Op::St | Op::Red, Some(Space::Shared)) => {
            // Shared memory executes wherever the smem lives; all its
            // registers are needed there.
            for r in instr
                .srcs
                .iter()
                .filter_map(|o| o.as_reg())
                .chain(instr.addr_reg())
            {
                if r.class != RegClass::P {
                    out.push((r, loc));
                }
            }
        }
        _ => {
            for r in instr
                .srcs
                .iter()
                .filter_map(|o| o.as_reg())
                .chain(instr.addr_reg())
            {
                if r.class != RegClass::P {
                    out.push((r, loc));
                }
            }
        }
    }
    out
}

/// Step 3 of Fig. 3: plan the register moves against the track table.
/// A register valid in *neither* file has never been written (reads as
/// zero) and is materialized in place without traffic.
pub fn plan_moves(required: &[(Reg, ExecLoc)], track: &TrackTable) -> Vec<(Reg, MoveDir)> {
    let mut moves = Vec::new();
    for (r, want) in required {
        match want {
            ExecLoc::Near if !track.nb_valid(*r) && track.fb_valid(*r) => {
                moves.push((*r, MoveDir::ToNb));
            }
            ExecLoc::Far if !track.fb_valid(*r) && track.nb_valid(*r) => {
                moves.push((*r, MoveDir::ToFb));
            }
            _ => {}
        }
    }
    moves
}

/// Where the destination register is written (updates the track table).
pub fn dst_location(instr: &Instr, loc: ExecLoc, cfg: &MachineConfig) -> Option<(Reg, ExecLoc)> {
    let dst = instr.dst?;
    // Predicates physically live far-bank (control logic).
    if dst.class == RegClass::P {
        return Some((dst, ExecLoc::Far));
    }
    match (instr.op, instr.space) {
        // §IV-B2: global-load data always lands in the near-bank RF
        // first (PonB has no near-bank RF).
        (Op::Ld, Some(Space::Global)) => {
            if cfg.pipeline_mode == PipelineMode::PonB {
                Some((dst, ExecLoc::Far))
            } else {
                Some((dst, ExecLoc::Near))
            }
        }
        _ => Some((dst, loc)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;

    fn cfg() -> MachineConfig {
        MachineConfig::scaled()
    }

    fn annotated(src: &str) -> Vec<Instr> {
        let instrs = assemble(src).unwrap();
        let (instrs, _, _) = crate::compiler::location::annotate(&instrs, &[]);
        instrs
    }

    #[test]
    fn hardware_set_overrides_everything() {
        let cfg = cfg();
        let t = TrackTable::default();
        let i = annotated("ld.global.f32 %f1, [%r1+0]\nexit");
        assert_eq!(instr_location(&i[0], Loc::N, &cfg, &t), ExecLoc::Far);
        let i = annotated("bar.sync\nexit");
        assert_eq!(instr_location(&i[0], Loc::N, &cfg, &t), ExecLoc::Far);
    }

    #[test]
    fn smem_follows_its_location() {
        let mut cfg = cfg();
        let t = TrackTable::default();
        let i = annotated("st.shared.f32 [%r1+0], %f1\nexit");
        assert_eq!(instr_location(&i[0], Loc::N, &cfg, &t), ExecLoc::Near);
        cfg.smem_location = SmemLocation::FarBank;
        assert_eq!(instr_location(&i[0], Loc::N, &cfg, &t), ExecLoc::Far);
    }

    #[test]
    fn compiler_hint_decides_alu() {
        let cfg = cfg();
        let t = TrackTable::default();
        let i = annotated("add.f32 %f1, %f2, %f3\nexit");
        assert_eq!(instr_location(&i[0], Loc::N, &cfg, &t), ExecLoc::Near);
        assert_eq!(instr_location(&i[0], Loc::F, &cfg, &t), ExecLoc::Far);
    }

    #[test]
    fn hardware_default_uses_track_table() {
        let mut cfg = cfg();
        cfg.offload_policy = OffloadPolicy::HardwareDefault;
        let mut t = TrackTable::default();
        let i = annotated("add.f32 %f1, %f2, %f3\nexit");
        assert_eq!(instr_location(&i[0], Loc::N, &cfg, &t), ExecLoc::Far, "no NB copies yet");
        t.write_nb(Reg::f(2));
        t.write_nb(Reg::f(3));
        assert_eq!(instr_location(&i[0], Loc::N, &cfg, &t), ExecLoc::Near);
    }

    #[test]
    fn ponb_never_offloads() {
        let mut cfg = cfg();
        cfg.pipeline_mode = PipelineMode::PonB;
        let mut t = TrackTable::default();
        t.write_nb(Reg::f(2));
        t.write_nb(Reg::f(3));
        let i = annotated("add.f32 %f1, %f2, %f3\nexit");
        assert_eq!(instr_location(&i[0], Loc::N, &cfg, &t), ExecLoc::Far);
        assert_eq!(dst_location(&i[0], ExecLoc::Far, &cfg), Some((Reg::f(1), ExecLoc::Far)));
    }

    #[test]
    fn ld_global_addr_far_data_near() {
        let cfg = cfg();
        let i = annotated("ld.global.f32 %f1, [%r1+0]\nexit");
        let req = required_reg_locs(&i[0], ExecLoc::Far, &cfg);
        assert_eq!(req, vec![(Reg::r(1), ExecLoc::Far)]);
        assert_eq!(dst_location(&i[0], ExecLoc::Far, &cfg), Some((Reg::f(1), ExecLoc::Near)));
    }

    #[test]
    fn st_global_value_near_addr_far() {
        let cfg = cfg();
        let i = annotated("st.global.f32 [%r1+0], %f1\nexit");
        let req = required_reg_locs(&i[0], ExecLoc::Far, &cfg);
        assert!(req.contains(&(Reg::r(1), ExecLoc::Far)));
        assert!(req.contains(&(Reg::f(1), ExecLoc::Near)));
    }

    #[test]
    fn moves_follow_track_table_state() {
        let mut t = TrackTable::default();
        t.write_fb(Reg::f(1)); // only far copy
        t.write_nb(Reg::f(2)); // only near copy
        let req = vec![(Reg::f(1), ExecLoc::Near), (Reg::f(2), ExecLoc::Near)];
        let m = plan_moves(&req, &t);
        assert_eq!(m, vec![(Reg::f(1), MoveDir::ToNb)]);
        let req = vec![(Reg::f(2), ExecLoc::Far)];
        assert_eq!(plan_moves(&req, &t), vec![(Reg::f(2), MoveDir::ToFb)]);
        // Valid in neither file → no traffic.
        let req = vec![(Reg::f(7), ExecLoc::Near)];
        assert!(plan_moves(&req, &t).is_empty());
    }

    #[test]
    fn predicates_never_move() {
        let cfg = cfg();
        let i = annotated("@%p1 add.f32 %f1, %f2, %f3\nexit");
        let req = required_reg_locs(&i[0], ExecLoc::Near, &cfg);
        assert!(req.iter().all(|(r, _)| r.class != RegClass::P));
        // And a setp destination lands far-bank even if issued near.
        let i = annotated("setp.lt.f32 %p1, %f1, %f2\nexit");
        assert_eq!(dst_location(&i[0], ExecLoc::Near, &cfg), Some((Reg::p(1), ExecLoc::Far)));
    }
}
