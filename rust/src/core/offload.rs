//! Instruction-offload decision + register-move planning (§IV-B1, Fig. 3).
//!
//! Step 1: instruction location — hardware-mandated far-bank set first
//! (global ld/st through the LSU, control flow, barriers), then the
//! compiler hint (Algorithm-1 annotation), then the hardware default
//! (offload iff all sources have valid near-bank copies), with far-bank
//! as the universal fallback.
//!
//! Step 2: source-register locations — hardware policy for memory ops
//! (address regs far, value regs near), otherwise follow the
//! instruction.
//!
//! Step 3: register movement — compare against the track table; every
//! miss is one warp-register (128 B) transfer by the register move
//! engine.
//!
//! All decisions run on the issue hot path, so they operate over the
//! pre-decoded [`MacroOp`] form: the operand walks use the inlined
//! register slots and nothing here allocates (step 2 writes into a
//! caller-owned buffer via [`required_reg_locs_into`]).

use super::warp::TrackTable;
use crate::config::{MachineConfig, OffloadPolicy, PipelineMode, SmemLocation};
use crate::isa::instr::Loc;
use crate::isa::{MacroOp, Op, OpClass, Reg, RegClass};

/// Where an instruction executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecLoc {
    Near,
    Far,
}

/// A planned register move.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MoveDir {
    /// Far-bank RF → near-bank RF (down the TSVs).
    ToNb,
    /// Near-bank RF → far-bank RF (up the TSVs).
    ToFb,
}

/// Step 1 of Fig. 3: decide the execution location.
///
/// `explicit` is the per-pc entry of an explicit policy table (resolved
/// at launch; `Loc::U` when the table has no override or the policy is
/// not [`OffloadPolicy::Explicit`]). Under `Explicit` the fallback chain
/// is explicit override → compiler hint → hardware default, so an empty
/// table reproduces `CompilerAnnotated` exactly.
pub fn instr_location(
    m: &MacroOp,
    instr_loc_hint: Loc,
    explicit: Loc,
    cfg: &MachineConfig,
    track: &TrackTable,
) -> ExecLoc {
    if cfg.pipeline_mode == PipelineMode::PonB {
        return ExecLoc::Far;
    }
    // Hardware-mandated set (highest priority).
    match m.class {
        OpClass::Branch | OpClass::Bar | OpClass::Exit => return ExecLoc::Far,
        OpClass::Shared if cfg.smem_location == SmemLocation::NearBank => return ExecLoc::Near,
        // Far-bank smem executes on the base logic die; global accesses
        // always go through the far-bank LSU front half (the near-bank
        // handoff is modelled inside the LSU path).
        OpClass::Shared | OpClass::Global => return ExecLoc::Far,
        OpClass::Alu => {}
    }
    match cfg.offload_policy {
        OffloadPolicy::AllNearBank => ExecLoc::Near,
        OffloadPolicy::AllFarBank => ExecLoc::Far,
        OffloadPolicy::CompilerAnnotated => match instr_loc_hint {
            Loc::N => ExecLoc::Near,
            Loc::F | Loc::B => ExecLoc::Far,
            Loc::U => hardware_default(m, track),
        },
        OffloadPolicy::HardwareDefault => hardware_default(m, track),
        OffloadPolicy::Explicit => match explicit {
            Loc::N => ExecLoc::Near,
            Loc::F | Loc::B => ExecLoc::Far,
            Loc::U => match instr_loc_hint {
                Loc::N => ExecLoc::Near,
                Loc::F | Loc::B => ExecLoc::Far,
                Loc::U => hardware_default(m, track),
            },
        },
    }
}

/// The §IV-B1 default policy: offload iff every source register has a
/// valid near-bank copy; far-bank is the fall-back with full pipeline
/// support. Predicates are excluded — they travel with the instruction
/// packet.
fn hardware_default(m: &MacroOp, track: &TrackTable) -> ExecLoc {
    let mut any = false;
    for r in m.src_regs_iter() {
        if r.class == RegClass::P {
            continue;
        }
        if !track.nb_valid(r) {
            return ExecLoc::Far;
        }
        any = true;
    }
    if any {
        ExecLoc::Near
    } else {
        ExecLoc::Far
    }
}

/// Required location of each *read* register (step 2 of Fig. 3), pushed
/// into `out` (cleared first) so the per-issue path reuses one buffer.
/// Predicates never move — the SIMT mask travels with the instruction
/// packet.
pub fn required_reg_locs_into(
    m: &MacroOp,
    loc: ExecLoc,
    cfg: &MachineConfig,
    out: &mut Vec<(Reg, ExecLoc)>,
) {
    out.clear();
    match (m.op, m.class) {
        (Op::Ld, OpClass::Global) => {
            if m.has_mem {
                out.push((m.mem_base, ExecLoc::Far));
            }
        }
        (Op::St | Op::Red, OpClass::Global) => {
            if m.has_mem {
                out.push((m.mem_base, ExecLoc::Far));
            }
            let value_loc = if cfg.pipeline_mode == PipelineMode::PonB {
                ExecLoc::Far
            } else {
                ExecLoc::Near
            };
            for s in m.src_regs_iter() {
                if s.class != RegClass::P {
                    out.push((s, value_loc));
                }
            }
        }
        (_, OpClass::Shared) => {
            // Shared memory executes wherever the smem lives; all its
            // registers are needed there.
            for r in m.src_regs_iter().chain(m.has_mem.then_some(m.mem_base)) {
                if r.class != RegClass::P {
                    out.push((r, loc));
                }
            }
        }
        _ => {
            for r in m.src_regs_iter().chain(m.has_mem.then_some(m.mem_base)) {
                if r.class != RegClass::P {
                    out.push((r, loc));
                }
            }
        }
    }
}

/// Allocating convenience wrapper over [`required_reg_locs_into`]
/// (tests and analysis; the simulator uses the buffer form).
pub fn required_reg_locs(m: &MacroOp, loc: ExecLoc, cfg: &MachineConfig) -> Vec<(Reg, ExecLoc)> {
    let mut out = Vec::new();
    required_reg_locs_into(m, loc, cfg, &mut out);
    out
}

/// The per-register move decision of step 3: does `r` need a transfer to
/// be readable at `want`? A register valid in *neither* file has never
/// been written (reads as zero) and is materialized in place without
/// traffic.
#[inline]
pub fn move_for(r: Reg, want: ExecLoc, track: &TrackTable) -> Option<MoveDir> {
    match want {
        ExecLoc::Near if !track.nb_valid(r) && track.fb_valid(r) => Some(MoveDir::ToNb),
        ExecLoc::Far if !track.fb_valid(r) && track.nb_valid(r) => Some(MoveDir::ToFb),
        _ => None,
    }
}

/// Step 3 of Fig. 3: plan the register moves against the track table.
pub fn plan_moves(required: &[(Reg, ExecLoc)], track: &TrackTable) -> Vec<(Reg, MoveDir)> {
    required
        .iter()
        .filter_map(|&(r, want)| move_for(r, want, track).map(|d| (r, d)))
        .collect()
}

/// Where the destination register is written (updates the track table).
pub fn dst_location(m: &MacroOp, loc: ExecLoc, cfg: &MachineConfig) -> Option<(Reg, ExecLoc)> {
    let dst = m.dst?;
    // Predicates physically live far-bank (control logic).
    if dst.class == RegClass::P {
        return Some((dst, ExecLoc::Far));
    }
    match (m.op, m.class) {
        // §IV-B2: global-load data always lands in the near-bank RF
        // first (PonB has no near-bank RF).
        (Op::Ld, OpClass::Global) => {
            if cfg.pipeline_mode == PipelineMode::PonB {
                Some((dst, ExecLoc::Far))
            } else {
                Some((dst, ExecLoc::Near))
            }
        }
        _ => Some((dst, loc)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;
    use crate::isa::Instr;

    fn cfg() -> MachineConfig {
        MachineConfig::scaled()
    }

    fn annotated(src: &str) -> Vec<Instr> {
        let instrs = assemble(src).unwrap();
        let (instrs, _, _) = crate::compiler::location::annotate(&instrs, &[]);
        instrs
    }

    /// Decode instruction 0 of `src` (hint is supplied per-test, so the
    /// macro-op's own pre-resolved hint is irrelevant here).
    fn mop(src: &str) -> MacroOp {
        let i = annotated(src);
        MacroOp::decode(&i[0], 0, None, i[0].loc)
    }

    #[test]
    fn hardware_set_overrides_everything() {
        let cfg = cfg();
        let t = TrackTable::default();
        let m = mop("ld.global.f32 %f1, [%r1+0]\nexit");
        assert_eq!(instr_location(&m, Loc::N, Loc::U, &cfg, &t), ExecLoc::Far);
        let m = mop("bar.sync\nexit");
        assert_eq!(instr_location(&m, Loc::N, Loc::U, &cfg, &t), ExecLoc::Far);
    }

    #[test]
    fn smem_follows_its_location() {
        let mut cfg = cfg();
        let t = TrackTable::default();
        let m = mop("st.shared.f32 [%r1+0], %f1\nexit");
        assert_eq!(instr_location(&m, Loc::N, Loc::U, &cfg, &t), ExecLoc::Near);
        cfg.smem_location = SmemLocation::FarBank;
        assert_eq!(instr_location(&m, Loc::N, Loc::U, &cfg, &t), ExecLoc::Far);
    }

    #[test]
    fn compiler_hint_decides_alu() {
        let cfg = cfg();
        let t = TrackTable::default();
        let m = mop("add.f32 %f1, %f2, %f3\nexit");
        assert_eq!(instr_location(&m, Loc::N, Loc::U, &cfg, &t), ExecLoc::Near);
        assert_eq!(instr_location(&m, Loc::F, Loc::U, &cfg, &t), ExecLoc::Far);
    }

    #[test]
    fn hardware_default_uses_track_table() {
        let mut cfg = cfg();
        cfg.offload_policy = OffloadPolicy::HardwareDefault;
        let mut t = TrackTable::default();
        let m = mop("add.f32 %f1, %f2, %f3\nexit");
        assert_eq!(instr_location(&m, Loc::N, Loc::U, &cfg, &t), ExecLoc::Far, "no NB copies yet");
        t.write_nb(Reg::f(2));
        t.write_nb(Reg::f(3));
        assert_eq!(instr_location(&m, Loc::N, Loc::U, &cfg, &t), ExecLoc::Near);
    }

    #[test]
    fn ponb_never_offloads() {
        let mut cfg = cfg();
        cfg.pipeline_mode = PipelineMode::PonB;
        let mut t = TrackTable::default();
        t.write_nb(Reg::f(2));
        t.write_nb(Reg::f(3));
        let m = mop("add.f32 %f1, %f2, %f3\nexit");
        assert_eq!(instr_location(&m, Loc::N, Loc::U, &cfg, &t), ExecLoc::Far);
        assert_eq!(dst_location(&m, ExecLoc::Far, &cfg), Some((Reg::f(1), ExecLoc::Far)));
    }

    #[test]
    fn ld_global_addr_far_data_near() {
        let cfg = cfg();
        let m = mop("ld.global.f32 %f1, [%r1+0]\nexit");
        let req = required_reg_locs(&m, ExecLoc::Far, &cfg);
        assert_eq!(req, vec![(Reg::r(1), ExecLoc::Far)]);
        assert_eq!(dst_location(&m, ExecLoc::Far, &cfg), Some((Reg::f(1), ExecLoc::Near)));
    }

    #[test]
    fn st_global_value_near_addr_far() {
        let cfg = cfg();
        let m = mop("st.global.f32 [%r1+0], %f1\nexit");
        let req = required_reg_locs(&m, ExecLoc::Far, &cfg);
        assert!(req.contains(&(Reg::r(1), ExecLoc::Far)));
        assert!(req.contains(&(Reg::f(1), ExecLoc::Near)));
    }

    #[test]
    fn moves_follow_track_table_state() {
        let mut t = TrackTable::default();
        t.write_fb(Reg::f(1)); // only far copy
        t.write_nb(Reg::f(2)); // only near copy
        let req = vec![(Reg::f(1), ExecLoc::Near), (Reg::f(2), ExecLoc::Near)];
        let m = plan_moves(&req, &t);
        assert_eq!(m, vec![(Reg::f(1), MoveDir::ToNb)]);
        let req = vec![(Reg::f(2), ExecLoc::Far)];
        assert_eq!(plan_moves(&req, &t), vec![(Reg::f(2), MoveDir::ToFb)]);
        // Valid in neither file → no traffic.
        let req = vec![(Reg::f(7), ExecLoc::Near)];
        assert!(plan_moves(&req, &t).is_empty());
    }

    #[test]
    fn predicates_never_move() {
        let cfg = cfg();
        let m = mop("@%p1 add.f32 %f1, %f2, %f3\nexit");
        let req = required_reg_locs(&m, ExecLoc::Near, &cfg);
        assert!(req.iter().all(|(r, _)| r.class != RegClass::P));
        // And a setp destination lands far-bank even if issued near.
        let m = mop("setp.lt.f32 %p1, %f1, %f2\nexit");
        assert_eq!(dst_location(&m, ExecLoc::Near, &cfg), Some((Reg::p(1), ExecLoc::Far)));
    }

    #[test]
    fn explicit_override_beats_the_compiler_hint() {
        let mut cfg = cfg();
        cfg.offload_policy = OffloadPolicy::Explicit;
        let t = TrackTable::default();
        let m = mop("add.f32 %f1, %f2, %f3\nexit");
        // The table's entry wins over the hint in both directions.
        assert_eq!(instr_location(&m, Loc::N, Loc::F, &cfg, &t), ExecLoc::Far);
        assert_eq!(instr_location(&m, Loc::F, Loc::N, &cfg, &t), ExecLoc::Near);
        // B is "either file is valid" — treated as far (full pipeline).
        assert_eq!(instr_location(&m, Loc::N, Loc::B, &cfg, &t), ExecLoc::Far);
    }

    #[test]
    fn explicit_without_override_matches_compiler_annotated() {
        // The seed-in-search-space guarantee: an empty table under
        // `Explicit` must reproduce `CompilerAnnotated` for every hint.
        let ann = cfg();
        let mut exp = cfg();
        exp.offload_policy = OffloadPolicy::Explicit;
        let mut t = TrackTable::default();
        let m = mop("add.f32 %f1, %f2, %f3\nexit");
        for hint in [Loc::U, Loc::N, Loc::F, Loc::B] {
            assert_eq!(
                instr_location(&m, hint, Loc::U, &exp, &t),
                instr_location(&m, hint, Loc::U, &ann, &t),
                "hint {hint:?} (empty track)"
            );
        }
        t.write_nb(Reg::f(2));
        t.write_nb(Reg::f(3));
        for hint in [Loc::U, Loc::N, Loc::F, Loc::B] {
            assert_eq!(
                instr_location(&m, hint, Loc::U, &exp, &t),
                instr_location(&m, hint, Loc::U, &ann, &t),
                "hint {hint:?} (NB-valid track)"
            );
        }
    }

    #[test]
    fn explicit_never_overrides_the_mandated_set() {
        let mut cfg = cfg();
        cfg.offload_policy = OffloadPolicy::Explicit;
        let t = TrackTable::default();
        let m = mop("ld.global.f32 %f1, [%r1+0]\nexit");
        assert_eq!(instr_location(&m, Loc::N, Loc::N, &cfg, &t), ExecLoc::Far);
        let m = mop("bar.sync\nexit");
        assert_eq!(instr_location(&m, Loc::N, Loc::N, &cfg, &t), ExecLoc::Far);
    }
}
