//! Functional (value-level) execution of mini-PTX instructions, one warp
//! at a time. Pure functions over lane vectors — the timing model lives
//! in [`crate::core::machine`]; this module only computes *what* the
//! hardware computes, so the simulator's memory image can be validated
//! bit-for-bit against the JAX/Pallas golden models.

use crate::isa::{CmpOp, Instr, Op, Operand, Slot, Special, Ty};

/// Lane context: per-thread special values.
#[derive(Clone, Copy, Debug)]
pub struct LaneCtx {
    pub tid: u32,
    pub ntid: u32,
    pub ctaid: u32,
    pub nctaid: u32,
}

/// Evaluate an operand for one lane given a register-read closure.
pub fn operand_value(op: &Operand, ctx: &LaneCtx, read: &impl Fn(crate::isa::Reg) -> u32) -> u32 {
    match op {
        Operand::Reg(r) => read(*r),
        Operand::ImmI(i) => *i as u32,
        Operand::ImmF(f) => f.to_bits(),
        Operand::Special(s) => match s {
            Special::TidX => ctx.tid,
            Special::NTidX => ctx.ntid,
            Special::CtaIdX => ctx.ctaid,
            Special::NCtaIdX => ctx.nctaid,
        },
    }
}

/// Evaluate a pre-decoded operand slot for one lane: the [`MacroOp`]
/// path's twin of [`operand_value`], with the immediate bits inlined.
///
/// [`MacroOp`]: crate::isa::MacroOp
#[inline]
pub fn slot_value(slot: Slot, ctx: &LaneCtx, read: &impl Fn(crate::isa::Reg) -> u32) -> u32 {
    match slot {
        Slot::Reg(r) => read(r),
        Slot::Imm(bits) => bits,
        Slot::Tid => ctx.tid,
        Slot::NTid => ctx.ntid,
        Slot::CtaId => ctx.ctaid,
        Slot::NCtaId => ctx.nctaid,
    }
}

/// Execute an ALU-class instruction for one lane. `srcs` are the already
/// evaluated source bit patterns. Returns the destination bit pattern.
/// Semantics are keyed entirely off `(op, ty, src_ty, cmp)` so both the
/// `Instr` interpreter and the decoded [`MacroOp`] path share one
/// implementation ([`alu_lane`] is the `Instr` wrapper).
///
/// [`MacroOp`]: crate::isa::MacroOp
#[inline]
pub fn alu_eval(op: Op, ty: Ty, src_ty: Ty, cmp: Option<CmpOp>, srcs: &[u32]) -> u32 {
    let f = |i: usize| f32::from_bits(srcs[i]);
    let s = |i: usize| srcs[i] as i32;
    let u = |i: usize| srcs[i];
    match op {
        Op::Mov => srcs[0],
        Op::Cvt => {
            match (ty, src_ty) {
                (Ty::F32, Ty::S32) => (s(0) as f32).to_bits(),
                (Ty::F32, Ty::U32) => (u(0) as f32).to_bits(),
                (Ty::S32, Ty::F32) => (f(0) as i32) as u32,
                (Ty::U32, Ty::F32) => f(0) as u32,
                _ => srcs[0],
            }
        }
        Op::Add => match ty {
            Ty::F32 => (f(0) + f(1)).to_bits(),
            _ => u(0).wrapping_add(u(1)),
        },
        Op::Sub => match ty {
            Ty::F32 => (f(0) - f(1)).to_bits(),
            _ => u(0).wrapping_sub(u(1)),
        },
        Op::Mul => match ty {
            Ty::F32 => (f(0) * f(1)).to_bits(),
            Ty::S32 => (s(0).wrapping_mul(s(1))) as u32,
            _ => u(0).wrapping_mul(u(1)),
        },
        Op::Mad => match ty {
            Ty::F32 => (f(0) * f(1) + f(2)).to_bits(),
            Ty::S32 => (s(0).wrapping_mul(s(1)).wrapping_add(s(2))) as u32,
            _ => u(0).wrapping_mul(u(1)).wrapping_add(u(2)),
        },
        Op::Div => match ty {
            Ty::F32 => (f(0) / f(1)).to_bits(),
            Ty::S32 => {
                if s(1) == 0 { 0 } else { (s(0).wrapping_div(s(1))) as u32 }
            }
            _ => {
                if u(1) == 0 { 0 } else { u(0) / u(1) }
            }
        },
        Op::Rem => match ty {
            Ty::F32 => (f(0) % f(1)).to_bits(),
            Ty::S32 => {
                if s(1) == 0 { 0 } else { (s(0).wrapping_rem(s(1))) as u32 }
            }
            _ => {
                if u(1) == 0 { 0 } else { u(0) % u(1) }
            }
        },
        Op::Min => match ty {
            Ty::F32 => f(0).min(f(1)).to_bits(),
            Ty::S32 => s(0).min(s(1)) as u32,
            _ => u(0).min(u(1)),
        },
        Op::Max => match ty {
            Ty::F32 => f(0).max(f(1)).to_bits(),
            Ty::S32 => s(0).max(s(1)) as u32,
            _ => u(0).max(u(1)),
        },
        Op::And => u(0) & u(1),
        Op::Or => u(0) | u(1),
        Op::Xor => u(0) ^ u(1),
        Op::Shl => u(0).wrapping_shl(u(1) & 31),
        Op::Shr => match ty {
            Ty::S32 => (s(0).wrapping_shr(u(1) & 31)) as u32,
            _ => u(0).wrapping_shr(u(1) & 31),
        },
        Op::Neg => match ty {
            Ty::F32 => (-f(0)).to_bits(),
            _ => (s(0).wrapping_neg()) as u32,
        },
        Op::Abs => match ty {
            Ty::F32 => f(0).abs().to_bits(),
            _ => (s(0).wrapping_abs()) as u32,
        },
        Op::Sqrt => f(0).sqrt().to_bits(),
        Op::Setp => {
            let c = cmp.expect("setp has cmp");
            let t = match ty {
                Ty::F32 => cmp_f32(c, f(0), f(1)),
                Ty::S32 => cmp_i(c, s(0) as i64, s(1) as i64),
                _ => cmp_i(c, u(0) as i64, u(1) as i64),
            };
            t as u32
        }
        Op::Selp => {
            if srcs[2] != 0 {
                srcs[0]
            } else {
                srcs[1]
            }
        }
        _ => panic!("alu_eval called on non-ALU op {op:?}"),
    }
}

/// [`alu_eval`] over the `Instr` representation (analysis/reference use;
/// the hot path goes through the decoded form directly).
pub fn alu_lane(instr: &Instr, srcs: &[u32]) -> u32 {
    alu_eval(instr.op, instr.ty, instr.src_ty.unwrap_or(instr.ty), instr.cmp, srcs)
}

fn cmp_f32(c: CmpOp, a: f32, b: f32) -> bool {
    match c {
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
        CmpOp::Gt => a > b,
        CmpOp::Ge => a >= b,
    }
}

fn cmp_i(c: CmpOp, a: i64, b: i64) -> bool {
    match c {
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
        CmpOp::Gt => a > b,
        CmpOp::Ge => a >= b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::instr::Loc;
    use crate::isa::Reg;

    fn instr(op: Op, ty: Ty) -> Instr {
        Instr {
            op,
            ty,
            src_ty: None,
            dst: Some(Reg::r(0)),
            srcs: vec![],
            mem: None,
            space: None,
            cmp: None,
            guard: None,
            target: None,
            loc: Loc::U,
        }
    }

    #[test]
    fn f32_arith() {
        let i = instr(Op::Mad, Ty::F32);
        let r = alu_lane(&i, &[2.0f32.to_bits(), 3.0f32.to_bits(), 1.0f32.to_bits()]);
        assert_eq!(f32::from_bits(r), 7.0);
        let i = instr(Op::Sqrt, Ty::F32);
        assert_eq!(f32::from_bits(alu_lane(&i, &[9.0f32.to_bits()])), 3.0);
        let i = instr(Op::Min, Ty::F32);
        assert_eq!(f32::from_bits(alu_lane(&i, &[1.5f32.to_bits(), (-2.0f32).to_bits()])), -2.0);
    }

    #[test]
    fn integer_wrapping_and_shifts() {
        let i = instr(Op::Add, Ty::U32);
        assert_eq!(alu_lane(&i, &[u32::MAX, 1]), 0);
        let i = instr(Op::Shl, Ty::U32);
        assert_eq!(alu_lane(&i, &[1, 4]), 16);
        let i = instr(Op::Shr, Ty::S32);
        assert_eq!(alu_lane(&i, &[(-8i32) as u32, 1]) as i32, -4);
        let i = instr(Op::Shr, Ty::U32);
        assert_eq!(alu_lane(&i, &[0x8000_0000, 31]), 1);
    }

    #[test]
    fn division_by_zero_yields_zero_int() {
        let i = instr(Op::Div, Ty::S32);
        assert_eq!(alu_lane(&i, &[5, 0]), 0);
        let i = instr(Op::Rem, Ty::U32);
        assert_eq!(alu_lane(&i, &[5, 0]), 0);
    }

    #[test]
    fn setp_and_selp() {
        let mut i = instr(Op::Setp, Ty::S32);
        i.cmp = Some(CmpOp::Lt);
        assert_eq!(alu_lane(&i, &[(-1i32) as u32, 0]), 1);
        assert_eq!(alu_lane(&i, &[3, 0]), 0);
        let mut i = instr(Op::Setp, Ty::F32);
        i.cmp = Some(CmpOp::Ge);
        assert_eq!(alu_lane(&i, &[1.0f32.to_bits(), 1.0f32.to_bits()]), 1);
        let i = instr(Op::Selp, Ty::U32);
        assert_eq!(alu_lane(&i, &[7, 9, 1]), 7);
        assert_eq!(alu_lane(&i, &[7, 9, 0]), 9);
    }

    #[test]
    fn cvt_conversions() {
        let mut i = instr(Op::Cvt, Ty::F32);
        i.src_ty = Some(Ty::S32);
        assert_eq!(f32::from_bits(alu_lane(&i, &[(-3i32) as u32])), -3.0);
        let mut i = instr(Op::Cvt, Ty::S32);
        i.src_ty = Some(Ty::F32);
        assert_eq!(alu_lane(&i, &[3.7f32.to_bits()]) as i32, 3, "cvt truncates toward zero");
        assert_eq!(alu_lane(&i, &[(-3.7f32).to_bits()]) as i32, -3);
    }

    #[test]
    fn specials_resolve_from_ctx() {
        let ctx = LaneCtx { tid: 5, ntid: 128, ctaid: 2, nctaid: 16 };
        let read = |_r: Reg| 0u32;
        assert_eq!(operand_value(&Operand::Special(Special::TidX), &ctx, &read), 5);
        assert_eq!(operand_value(&Operand::Special(Special::NTidX), &ctx, &read), 128);
        assert_eq!(operand_value(&Operand::Special(Special::CtaIdX), &ctx, &read), 2);
        assert_eq!(operand_value(&Operand::Special(Special::NCtaIdX), &ctx, &read), 16);
        assert_eq!(operand_value(&Operand::ImmF(2.5), &ctx, &read), 2.5f32.to_bits());
    }
}
