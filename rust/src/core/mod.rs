//! The MPU core model (§IV): hybrid far-bank/near-bank SIMT pipeline.
//!
//! * [`exec`] — functional per-lane execution of the mini-PTX ISA;
//! * [`warp`] — warp state: registers, SIMT stack, scoreboard, and the
//!   §IV-B1 register track table;
//! * [`offload`] — the Fig.-3 instruction-offload decision and register
//!   move planning;
//! * [`lsu`] — LSU front half: range check, coalescing, and the Fig.-4
//!   near-bank-offload qualification;
//! * [`machine`] — the assembled machine: cores, subcores, NBUs, TSVs,
//!   DRAM controllers, mesh, barriers, and the timing main loop.

pub mod exec;
pub mod warp;
pub mod offload;
pub mod lsu;
pub mod machine;

pub use machine::Machine;
pub use offload::ExecLoc;
