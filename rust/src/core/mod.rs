//! The MPU core model (§IV): hybrid far-bank/near-bank SIMT pipeline.
//!
//! * [`exec`] — functional per-lane execution of the mini-PTX ISA;
//! * [`warp`] — warp state: registers, SIMT stack, scoreboard, and the
//!   §IV-B1 register track table;
//! * [`frontend`] — the *shared* SIMT frontend (block dispatch, warp
//!   scheduling, barriers, functional issue, fast-forward event loop),
//!   generic over a pluggable [`frontend::MemorySystem`] +
//!   [`frontend::OffloadModel`] backend — every machine in the repo
//!   (MPU, GPU, roofline variants) is this frontend plus a backend;
//! * [`offload`] — the Fig.-3 instruction-offload decision and register
//!   move planning;
//! * [`lsu`] — LSU front half: range check, coalescing, and the Fig.-4
//!   near-bank-offload qualification;
//! * [`machine`] — the near-bank backend (TSVs, FR-FCFS + MASA DRAM
//!   controllers, mesh, track table, register move engine) and the
//!   assembled MPU [`Machine`].

pub mod exec;
pub mod frontend;
pub mod warp;
pub mod offload;
pub mod lsu;
pub mod machine;

pub use frontend::{FrontendParams, MemorySystem, OffloadModel, SimtFrontend};
pub use machine::{Machine, NearBankMemory};
pub use offload::ExecLoc;
