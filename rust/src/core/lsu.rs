//! Load-store-unit front half (§IV-B2, Fig. 4): address-range checking
//! (local vs remote split), memory coalescing into bank-IO-width chunks,
//! and the near-bank-offload qualification test (all lanes valid + single
//! NBU + perfectly coalesced).

use crate::mem::{AddrMap, BankCoord};

/// One coalesced bank-IO-width DRAM chunk of a warp access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    /// Chunk-aligned base address.
    pub addr: u64,
    pub coord: BankCoord,
    /// Flat global core id owning the chunk.
    pub core_global: usize,
}

/// A warp's memory access after LSU processing.
#[derive(Clone, Debug)]
pub struct WarpAccess {
    /// Unique chunks, in first-touch lane order.
    pub chunks: Vec<Chunk>,
    /// All active lanes' addresses form one contiguous ascending 4-byte
    /// run (Fig. 4: "perfectly coalesced").
    pub contiguous: bool,
    /// All chunks map to a single (core, NBU) pair.
    pub single_nbu: bool,
    /// All chunks map to a single core.
    pub single_core: bool,
}

/// Coalesce per-lane 4-byte accesses into unique chunks of
/// `chunk_bytes` (the bank IO width).
pub fn coalesce(addrs: &[u64], map: &AddrMap, chunk_bytes: u64, cores_per_proc: usize) -> WarpAccess {
    let mut chunks: Vec<Chunk> = Vec::new();
    for &a in addrs {
        // A 4-byte access may straddle two chunks only if misaligned;
        // the ISA is word-aligned so one chunk suffices.
        let base = a & !(chunk_bytes - 1);
        if !chunks.iter().any(|c| c.addr == base) {
            let coord = map.decode(base);
            let core_global = coord.proc * cores_per_proc + coord.core;
            chunks.push(Chunk { addr: base, coord, core_global });
        }
    }

    let contiguous = {
        let mut sorted: Vec<u64> = addrs.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        sorted.len() == addrs.len()
            && sorted.windows(2).all(|w| w[1] == w[0] + 4)
    };

    let single_nbu = {
        let mut it = chunks.iter();
        match it.next() {
            None => true,
            Some(first) => it.all(|c| {
                c.core_global == first.core_global && c.coord.nbu == first.coord.nbu
            }),
        }
    };
    let single_core = {
        let mut it = chunks.iter();
        match it.next() {
            None => true,
            Some(first) => it.all(|c| c.core_global == first.core_global),
        }
    };

    WarpAccess { chunks, contiguous, single_nbu, single_core }
}

impl WarpAccess {
    /// Split chunk indices into (local, remote) relative to `home_core`.
    pub fn split(&self, home_core: usize) -> (Vec<usize>, Vec<usize>) {
        let mut local = Vec::new();
        let mut remote = Vec::new();
        for (i, c) in self.chunks.iter().enumerate() {
            if c.core_global == home_core {
                local.push(i);
            } else {
                remote.push(i);
            }
        }
        (local, remote)
    }

    /// Fig. 4 (6): qualify for near-bank offloading — every thread
    /// active (`full_warp`), all addresses in the issuing core's own
    /// DRAM die, and perfectly coalesced. When it qualifies, only the
    /// leading address crosses the TSVs.
    ///
    /// Fidelity note: the paper checks the *NBU* id against the warp's
    /// NBU; under the §IV-C horizontal core structure all four NBUs of a
    /// core share one DRAM die, so we qualify at core granularity and
    /// model the cross-NBU on-die hop as free (DESIGN.md §2). The strict
    /// per-NBU condition is still exposed via `single_nbu` for analysis.
    pub fn offloadable(&self, full_warp: bool, home_core: usize) -> bool {
        full_warp
            && self.contiguous
            && self.single_core
            && !self.chunks.is_empty()
            && self.chunks[0].core_global == home_core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn setup() -> (MachineConfig, AddrMap) {
        let cfg = MachineConfig::scaled();
        let m = AddrMap::new(&cfg);
        (cfg, m)
    }

    #[test]
    fn contiguous_warp_access_coalesces_to_four_chunks() {
        let (cfg, m) = setup();
        // 32 lanes × 4 B = 128 B = 4 chunks of 32 B.
        let addrs: Vec<u64> = (0..32).map(|i| i * 4).collect();
        let wa = coalesce(&addrs, &m, 32, cfg.cores_per_proc);
        assert_eq!(wa.chunks.len(), 4);
        assert!(wa.contiguous);
        assert!(wa.single_nbu, "128 B run stays inside one 256 B interleave chunk");
    }

    #[test]
    fn broadcast_coalesces_to_one_chunk_not_contiguous() {
        let (cfg, m) = setup();
        let addrs = vec![64u64; 32];
        let wa = coalesce(&addrs, &m, 32, cfg.cores_per_proc);
        assert_eq!(wa.chunks.len(), 1);
        assert!(!wa.contiguous, "replicated addresses are not a contiguous run");
    }

    #[test]
    fn strided_access_explodes_chunks() {
        let (cfg, m) = setup();
        // Stride 32 B: every lane its own chunk.
        let addrs: Vec<u64> = (0..32).map(|i| i * 32).collect();
        let wa = coalesce(&addrs, &m, 32, cfg.cores_per_proc);
        assert_eq!(wa.chunks.len(), 32);
        assert!(!wa.contiguous);
    }

    #[test]
    fn offloadable_requires_all_three_conditions() {
        let (cfg, m) = setup();
        let addrs: Vec<u64> = (0..32).map(|i| i * 4).collect();
        let wa = coalesce(&addrs, &m, 32, cfg.cores_per_proc);
        let home = wa.chunks[0].core_global;
        assert!(wa.offloadable(true, home));
        assert!(!wa.offloadable(false, home), "divergent warp");
        assert!(!wa.offloadable(true, home + 1), "wrong core");
        // Broadcast (non-contiguous) never offloads.
        let wb = coalesce(&[0u64; 32], &m, 32, cfg.cores_per_proc);
        assert!(!wb.offloadable(true, wb.chunks[0].core_global));
    }

    #[test]
    fn split_partitions_by_core() {
        let (cfg, m) = setup();
        // Two accesses far apart → different banks, possibly different
        // cores. Build addresses in interleave chunks of different cores.
        let banks_per_core = cfg.nbus_per_core * cfg.banks_per_nbu;
        let other_core_addr = (cfg.interleave_bytes * banks_per_core) as u64;
        let wa = coalesce(&[0, other_core_addr], &m, 32, cfg.cores_per_proc);
        assert_eq!(wa.chunks.len(), 2);
        let home = wa.chunks[0].core_global;
        let (local, remote) = wa.split(home);
        assert_eq!(local.len(), 1);
        assert_eq!(remote.len(), 1);
        assert!(!wa.single_nbu);
    }
}
