//! Warp state: per-lane registers, the SIMT re-convergence stack (§III),
//! the scoreboard view (`reg_ready`), and the register track table that
//! the offload machinery consults (§IV-B1: *FBValid*/*NBValid* bits).

use crate::isa::{Instr, MacroOp, Operand, Reg, RegClass};
use std::collections::HashSet;

/// One SIMT-stack entry: execution resumes at `pc` under `mask`, popping
/// when `pc` reaches `rpc` (the re-convergence PC).
#[derive(Clone, Copy, Debug)]
pub struct SimtEntry {
    pub pc: usize,
    pub mask: u64,
    pub rpc: usize,
}

/// Dense per-register write-completion times (the scoreboard's data).
/// Indexed by (class, idx) — no hashing on the issue hot path
/// (EXPERIMENTS.md §Perf iteration 2).
#[derive(Clone, Debug)]
pub struct RegReady {
    t: [Vec<u64>; 3],
}

impl RegReady {
    fn new(counts: [usize; 3]) -> RegReady {
        RegReady { t: [vec![0; counts[0]], vec![0; counts[1]], vec![0; counts[2]]] }
    }

    #[inline]
    fn slot(&mut self, r: Reg) -> &mut u64 {
        let c = Warp::class_idx(r.class);
        let v = &mut self.t[c];
        if r.idx as usize >= v.len() {
            v.resize(r.idx as usize + 1, 0);
        }
        &mut v[r.idx as usize]
    }

    /// Record a pending write completing at `at`.
    pub fn insert(&mut self, r: Reg, at: u64) {
        *self.slot(r) = at;
    }

    /// Completion time of the last write to `r` (0 = ready since launch).
    #[inline]
    pub fn get(&self, r: Reg) -> u64 {
        let v = &self.t[Warp::class_idx(r.class)];
        v.get(r.idx as usize).copied().unwrap_or(0)
    }
}

/// Register track table (§IV-B1): which physical file(s) hold a valid
/// copy of each register for this warp.
#[derive(Clone, Debug, Default)]
pub struct TrackTable {
    nb: HashSet<Reg>,
    fb: HashSet<Reg>,
}

impl TrackTable {
    pub fn nb_valid(&self, r: Reg) -> bool {
        self.nb.contains(&r)
    }
    pub fn fb_valid(&self, r: Reg) -> bool {
        self.fb.contains(&r)
    }
    /// A register move copies (does not invalidate the source side).
    pub fn copy_to_nb(&mut self, r: Reg) {
        self.nb.insert(r);
    }
    pub fn copy_to_fb(&mut self, r: Reg) {
        self.fb.insert(r);
    }
    /// A write lands in exactly one file and invalidates the other copy.
    pub fn write_nb(&mut self, r: Reg) {
        self.nb.insert(r);
        self.fb.remove(&r);
    }
    pub fn write_fb(&mut self, r: Reg) {
        self.fb.insert(r);
        self.nb.remove(&r);
    }
}

/// Warp execution status (scheduler's view).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WarpState {
    Ready,
    /// Waiting at a block barrier.
    AtBarrier,
    /// All lanes exited.
    Done,
}

/// A resident warp.
#[derive(Clone, Debug)]
pub struct Warp {
    /// Block id this warp belongs to (grid-level).
    pub block: u32,
    /// Warp index within the block.
    pub warp_in_block: usize,
    /// Number of live threads (last warp of a block may be partial).
    pub lanes: usize,
    /// Subcore (and therefore NBU) this warp is bound to.
    pub subcore: usize,
    pub state: WarpState,
    /// SIMT stack; `stack.last()` is the executing entry.
    pub stack: Vec<SimtEntry>,
    /// Cycle at which the warp may next issue.
    pub ready_at: u64,
    /// Cached earliest cycle the scheduler may select this warp
    /// (`max(ready_at, current-instruction operand readiness)`), or
    /// `u64::MAX` while it cannot issue without a further event (at a
    /// barrier, retired, or an operand blocked on an in-flight load).
    /// Maintained by the frontend's `refresh_wake` on every transition;
    /// the event-driven scheduler and its wake-up heap read only this.
    pub wake_at: u64,
    /// Cycle of last issue (GTO greedy bookkeeping).
    pub last_issue: u64,
    /// Pending-write completion times (scoreboard).
    pub reg_ready: RegReady,
    pub track: TrackTable,
    /// Register values: [class][reg][lane].
    regs: [Vec<Vec<u32>>; 3],
    warp_size: usize,
}

impl Warp {
    pub fn new(
        block: u32,
        warp_in_block: usize,
        lanes: usize,
        subcore: usize,
        reg_counts: [usize; 3],
        warp_size: usize,
    ) -> Warp {
        let full: u64 = if lanes >= 64 { !0 } else { (1u64 << lanes) - 1 };
        Warp {
            block,
            warp_in_block,
            lanes,
            subcore,
            state: WarpState::Ready,
            stack: vec![SimtEntry { pc: 0, mask: full, rpc: usize::MAX }],
            ready_at: 0,
            wake_at: u64::MAX,
            last_issue: 0,
            reg_ready: RegReady::new(reg_counts),
            track: TrackTable::default(),
            regs: [
                vec![vec![0; warp_size]; reg_counts[0]],
                vec![vec![0; warp_size]; reg_counts[1]],
                vec![vec![0; warp_size]; reg_counts[2]],
            ],
            warp_size,
        }
    }

    #[inline]
    pub(crate) fn class_idx(c: RegClass) -> usize {
        match c {
            RegClass::R => 0,
            RegClass::F => 1,
            RegClass::P => 2,
        }
    }

    pub fn read(&self, r: Reg, lane: usize) -> u32 {
        self.regs[Self::class_idx(r.class)][r.idx as usize][lane]
    }

    pub fn write(&mut self, r: Reg, lane: usize, v: u32) {
        self.regs[Self::class_idx(r.class)][r.idx as usize][lane] = v;
    }

    /// Broadcast-write a value to all lanes (parameter delivery).
    pub fn write_all(&mut self, r: Reg, v: u32) {
        for lane in 0..self.warp_size {
            self.write(r, lane, v);
        }
    }

    /// Current PC (top of SIMT stack).
    pub fn pc(&self) -> usize {
        self.stack.last().map(|e| e.pc).unwrap_or(usize::MAX)
    }

    /// Current active mask.
    pub fn active_mask(&self) -> u64 {
        self.stack.last().map(|e| e.mask).unwrap_or(0)
    }

    pub fn is_lane_active(&self, lane: usize) -> bool {
        self.active_mask() >> lane & 1 == 1
    }

    /// Active lane indices.
    pub fn active_lanes(&self) -> Vec<usize> {
        let m = self.active_mask();
        (0..self.lanes).filter(|&l| m >> l & 1 == 1).collect()
    }

    /// Step the top PC to `pc`, then pop any entries that reached their
    /// re-convergence point.
    pub fn set_pc(&mut self, pc: usize) {
        if let Some(top) = self.stack.last_mut() {
            top.pc = pc;
        }
        while self.stack.len() > 1 {
            let top = *self.stack.last().unwrap();
            if top.pc == top.rpc || top.mask == 0 {
                self.stack.pop();
            } else {
                break;
            }
        }
    }

    /// Execute a (possibly divergent) branch: lanes in `taken` jump to
    /// `target`, the rest fall through to `fall`; both re-converge at
    /// `rpc`. Standard GPGPU-Sim stack discipline.
    pub fn branch(&mut self, taken: u64, target: usize, fall: usize, rpc: usize) {
        let cur = self.active_mask();
        let taken = taken & cur;
        let not_taken = cur & !taken;
        if taken == cur {
            self.set_pc(target);
        } else if taken == 0 {
            self.set_pc(fall);
        } else {
            // Divergence: current entry becomes the re-convergence entry.
            // A path that starts *at* the re-convergence point is empty —
            // pushing it would let those lanes run ahead of the other
            // path (e.g. `@%p bra SKIP` where SKIP is the join): its
            // lanes simply wait in the re-convergence entry.
            if let Some(top) = self.stack.last_mut() {
                top.pc = rpc;
            }
            if fall != rpc {
                self.stack.push(SimtEntry { pc: fall, mask: not_taken, rpc });
            }
            if target != rpc {
                self.stack.push(SimtEntry { pc: target, mask: taken, rpc });
            }
        }
    }

    /// Retire `mask` lanes (exit instruction). Returns true if the warp
    /// has fully terminated.
    pub fn exit_lanes(&mut self, mask: u64) -> bool {
        for e in self.stack.iter_mut() {
            e.mask &= !mask;
        }
        self.stack.retain(|e| e.mask != 0);
        if self.stack.is_empty() {
            self.state = WarpState::Done;
            true
        } else {
            false
        }
    }

    /// Scoreboard check: can this instruction's operands be used at
    /// `now`? Returns the earliest cycle all reads+writes are resolved.
    pub fn operands_ready_at(&self, reads: &[Reg], writes: &[Reg]) -> u64 {
        reads.iter().chain(writes.iter()).map(|r| self.reg_ready.get(*r)).max().unwrap_or(0)
    }

    /// Allocation-free scoreboard check for an instruction (the issue
    /// hot path; equivalent to `operands_ready_at(reads(), writes())`).
    #[inline]
    pub fn instr_ready_at(&self, i: &Instr) -> u64 {
        let mut t = 0u64;
        for o in &i.srcs {
            if let Operand::Reg(r) = o {
                t = t.max(self.reg_ready.get(*r));
            }
        }
        if let Some(m) = i.mem {
            t = t.max(self.reg_ready.get(m.base));
        }
        if let Some((p, _)) = i.guard {
            t = t.max(self.reg_ready.get(p));
        }
        if let Some(d) = i.dst {
            t = t.max(self.reg_ready.get(d));
        }
        t
    }

    /// Scoreboard check over a pre-decoded macro-op: one pass over the
    /// precomputed read set (must agree with [`Warp::instr_ready_at`] on
    /// the corresponding `Instr` — the decode builds the set from the
    /// same fields).
    #[inline]
    pub fn macro_ready_at(&self, m: &MacroOp) -> u64 {
        let mut t = 0u64;
        for &r in m.read_set() {
            t = t.max(self.reg_ready.get(r));
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn warp() -> Warp {
        Warp::new(0, 0, 32, 0, [8, 8, 2], 32)
    }

    #[test]
    fn full_mask_for_32_lanes() {
        let w = warp();
        assert_eq!(w.active_mask(), 0xFFFF_FFFF);
        assert_eq!(w.active_lanes().len(), 32);
        let w = Warp::new(0, 0, 5, 0, [1, 1, 1], 32);
        assert_eq!(w.active_mask(), 0b11111);
    }

    #[test]
    fn uniform_branch_no_divergence() {
        let mut w = warp();
        w.branch(0xFFFF_FFFF, 10, 1, 20);
        assert_eq!(w.pc(), 10);
        assert_eq!(w.stack.len(), 1);
        w.branch(0, 5, 11, 20);
        assert_eq!(w.pc(), 11);
    }

    #[test]
    fn divergent_branch_pushes_and_reconverges() {
        let mut w = warp();
        // Half the lanes take the branch to 10, rest fall to 2; rpc 20.
        w.branch(0x0000_FFFF, 10, 2, 20);
        assert_eq!(w.stack.len(), 3);
        assert_eq!(w.pc(), 10);
        assert_eq!(w.active_mask(), 0x0000_FFFF);
        // Taken path reaches rpc → pops to fall path.
        w.set_pc(20);
        assert_eq!(w.pc(), 2);
        assert_eq!(w.active_mask(), 0xFFFF_0000);
        // Fall path reaches rpc → pops to re-converged entry.
        w.set_pc(20);
        assert_eq!(w.pc(), 20);
        assert_eq!(w.active_mask(), 0xFFFF_FFFF);
        assert_eq!(w.stack.len(), 1);
    }

    #[test]
    fn branch_to_reconvergence_point_does_not_run_ahead() {
        // `@%p bra SKIP` guarding a preload: taken lanes jump straight
        // to the join. They must NOT execute the join-side code before
        // the fall-through lanes finish the guarded region.
        let mut w = warp();
        w.branch(0xFFFF_FE00, 5, 1, 5); // lanes ≥9 skip to pc 5 (= rpc)
        // Fall path (lanes 0..9) executes first.
        assert_eq!(w.pc(), 1);
        assert_eq!(w.active_mask(), 0x0000_01FF);
        // When it reaches the join, everyone re-converges together.
        w.set_pc(5);
        assert_eq!(w.pc(), 5);
        assert_eq!(w.active_mask(), 0xFFFF_FFFF);
        assert_eq!(w.stack.len(), 1);
    }

    #[test]
    fn exit_terminates_warp() {
        let mut w = warp();
        assert!(!w.exit_lanes(0x0000_0001));
        assert_eq!(w.active_mask(), 0xFFFF_FFFE);
        assert!(w.exit_lanes(0xFFFF_FFFE));
        assert_eq!(w.state, WarpState::Done);
    }

    #[test]
    fn divergent_exit_keeps_other_path_alive() {
        let mut w = warp();
        w.branch(0x0000_00FF, 10, 2, 20);
        // Taken lanes (mask FF) exit.
        assert!(!w.exit_lanes(0x0000_00FF));
        // Stack popped to the fall-through path.
        assert_eq!(w.pc(), 2);
        assert_eq!(w.active_mask(), 0xFFFF_FF00);
    }

    #[test]
    fn registers_read_write() {
        let mut w = warp();
        w.write(Reg::f(3), 7, 42);
        assert_eq!(w.read(Reg::f(3), 7), 42);
        assert_eq!(w.read(Reg::f(3), 6), 0);
        w.write_all(Reg::r(1), 9);
        assert_eq!(w.read(Reg::r(1), 0), 9);
        assert_eq!(w.read(Reg::r(1), 31), 9);
    }

    #[test]
    fn scoreboard_max_of_pending() {
        let mut w = warp();
        w.reg_ready.insert(Reg::f(1), 100);
        w.reg_ready.insert(Reg::r(2), 50);
        assert_eq!(w.operands_ready_at(&[Reg::f(1)], &[]), 100);
        assert_eq!(w.operands_ready_at(&[Reg::r(2)], &[Reg::f(1)]), 100);
        assert_eq!(w.operands_ready_at(&[Reg::r(3)], &[]), 0);
    }

    #[test]
    fn track_table_write_invalidates_other_side() {
        let mut t = TrackTable::default();
        t.write_fb(Reg::f(1));
        assert!(t.fb_valid(Reg::f(1)) && !t.nb_valid(Reg::f(1)));
        t.copy_to_nb(Reg::f(1));
        assert!(t.fb_valid(Reg::f(1)) && t.nb_valid(Reg::f(1)));
        t.write_nb(Reg::f(1));
        assert!(!t.fb_valid(Reg::f(1)) && t.nb_valid(Reg::f(1)));
    }
}
