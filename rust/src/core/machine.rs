//! The MPU machine: the shared SIMT frontend wrapped around the
//! near-bank memory system (§IV).
//!
//! All SIMT mechanics (warp scheduling, barriers, scoreboard, functional
//! execution) live in [`super::frontend`]; this module contributes the
//! near-bank backend: instruction offloading and the register move
//! engine over the TSV buses, the hybrid LSU (local / remote /
//! LSU-Extension paths), per-NBU FR-FCFS + MASA DRAM controllers, the
//! 2D mesh and the off-chip links — i.e. everything the paper changes
//! relative to a compute-centric GPU.
//!
//! Execution model: warp-level issue with scoreboard stalls. Issued
//! instructions execute *functionally* immediately (so the memory image
//! is exact and can be checked against the XLA golden model) while
//! their *timing* is tracked through latency reservations on the TSV
//! buses, DRAM controllers, the mesh, and per-register ready times.
//! Idle stretches are fast-forwarded.

use super::frontend::{
    AccessCtx, Completion, FrontendParams, MemorySystem, OffloadModel, RegPlace, SimtFrontend,
};
use super::lsu::{coalesce, WarpAccess};
use super::offload::{self, ExecLoc, MoveDir};
use super::warp::Warp;
use crate::compiler::DecodedKernel;
use crate::config::{MachineConfig, OffloadPolicy, PipelineMode};
use crate::dram::{DramRequest, MemController};
use crate::isa::instr::Loc;
use crate::isa::program::ParamValue;
use crate::isa::{LaunchConfig, MacroOp, Op, Reg, RegClass};
use crate::mem::AddrMap;
use crate::noc::{Mesh, OffchipLink, Tsv};
use crate::sim::stats::TsvTraffic;
use crate::sim::Stats;
use anyhow::Result;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

/// Simulation events (things that happen at a future cycle on another
/// component).
#[derive(Debug)]
enum Event {
    /// DRAM column requests arrive at a core's NBU controller (after a
    /// TSV command transfer or a mesh hop).
    EnqueueDram { core: usize, nbu: usize, reqs: Vec<DramRequest> },
    /// A remote-serviced (or locally TSV-delayed) chunk credits a token.
    TokenCredit { token: u64 },
}

#[derive(Debug)]
struct QueuedEvent {
    at: u64,
    seq: u64,
    ev: Event,
}

impl PartialEq for QueuedEvent {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl Eq for QueuedEvent {}
impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for QueuedEvent {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // Min-heap via reverse.
        (o.at, o.seq).cmp(&(self.at, self.seq))
    }
}

/// What happens when all of a memory token's chunks have arrived.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TokenKind {
    /// Near-bank-offloaded load: data lands in the NB RF directly.
    OffloadedLoad,
    /// Plain load (hybrid): compose a register write and send it down
    /// for near-bank writeback (§IV-B2).
    PlainLoad,
    /// PonB load: data was lifted over the TSVs per chunk; write FB RF.
    PonbLoad,
}

#[derive(Debug)]
struct Token {
    remaining: usize,
    core: usize,
    warp: usize,
    dst: Reg,
    kind: TokenKind,
}

/// Per-chunk routing info for completion handling.
#[derive(Clone, Copy, Debug)]
struct ChunkRoute {
    token: u64,
    /// Core that services the chunk.
    service_core: usize,
    /// Home core that issued the access (worth a mesh response if
    /// different from `service_core`).
    home_core: usize,
    is_write: bool,
}

/// One core's slice of the memory system: its TSV bus and the NBU DRAM
/// controllers on its DRAM die.
struct CoreLink {
    tsv: Tsv,
    controllers: Vec<MemController>,
}

/// The near-bank memory system (the paper's §IV memory path), pluggable
/// behind the shared SIMT frontend.
pub struct NearBankMemory {
    cfg: MachineConfig,
    map: AddrMap,
    links: Vec<CoreLink>,
    mesh: Mesh,
    offchip: OffchipLink,
    events: BinaryHeap<QueuedEvent>,
    seq: u64,
    tokens: HashMap<u64, Token>,
    routes: HashMap<u64, ChunkRoute>,
    next_id: u64,
    completed: Vec<Completion>,
    /// Reusable step-2 buffer: the per-issue required-register list
    /// (kept warm so the offload path never allocates).
    req_buf: Vec<(Reg, ExecLoc)>,
    /// Dense per-pc explicit offload overrides for the launched kernel
    /// (resolved from `cfg.offload_table` at launch; empty unless the
    /// policy is `Explicit`). Indexed by `MacroOp::pc`; out-of-range or
    /// `Loc::U` entries mean "no override".
    explicit: Vec<Loc>,
}

impl NearBankMemory {
    pub fn new(cfg: &MachineConfig) -> NearBankMemory {
        NearBankMemory {
            cfg: cfg.clone(),
            map: AddrMap::new(cfg),
            links: (0..cfg.total_cores())
                .map(|_| CoreLink {
                    tsv: Tsv::new(cfg),
                    controllers: (0..cfg.nbus_per_core).map(|_| MemController::new(cfg)).collect(),
                })
                .collect(),
            mesh: Mesh::new(cfg),
            offchip: OffchipLink::new(cfg),
            events: BinaryHeap::new(),
            seq: 0,
            tokens: HashMap::new(),
            routes: HashMap::new(),
            next_id: 1,
            completed: Vec::new(),
            req_buf: Vec::new(),
            explicit: Vec::new(),
        }
    }

    fn push_event(&mut self, now: u64, at: u64, ev: Event) {
        self.seq += 1;
        self.events.push(QueuedEvent { at: at.max(now), seq: self.seq, ev });
    }

    /// A DRAM column access finished: route its data and credit its
    /// token (if any).
    ///
    /// Local loads never lift data over the TSVs in hybrid mode: the
    /// LSU-Extension stores the returned data straight into the
    /// near-bank register file on the DRAM die (§IV-B2). Far-bank
    /// consumers trigger a lazy register move later. PonB lifts every
    /// chunk.
    fn chunk_completed(&mut self, id: u64, now: u64, stats: &mut Stats) {
        let Some(route) = self.routes.remove(&id) else {
            return;
        };
        let io_bytes = (self.cfg.bank_io_bits / 8) as u64;
        if route.is_write {
            return; // stores are fire-and-forget
        }
        let ponb = self.cfg.pipeline_mode == PipelineMode::PonB;
        if route.service_core == route.home_core {
            if ponb {
                // Data lifts over the TSVs into the far-bank RF.
                let up = self.links[route.service_core].tsv.transfer(
                    now,
                    io_bytes,
                    TsvTraffic::DramData,
                    stats,
                );
                self.push_event(now, up, Event::TokenCredit { token: route.token });
            } else {
                self.credit_token(route.token, 1, now, stats);
            }
            return;
        }
        // Remote chunk: lift at the servicing core, cross the mesh (and
        // the off-chip link if cross-cube), then in hybrid mode descend
        // into the home core's near-bank RF.
        let up = self.links[route.service_core].tsv.transfer(
            now,
            io_bytes,
            TsvTraffic::DramData,
            stats,
        );
        let (sp, hp) = (
            route.service_core / self.cfg.cores_per_proc,
            route.home_core / self.cfg.cores_per_proc,
        );
        let mut t = self.mesh.send(up, route.service_core, route.home_core, io_bytes + 8, stats);
        if sp != hp {
            t = self.offchip.send(t, sp, hp, io_bytes + 8, stats);
        }
        if !ponb {
            t = self.links[route.home_core].tsv.transfer(t, io_bytes, TsvTraffic::RegMove, stats);
        }
        self.push_event(now, t, Event::TokenCredit { token: route.token });
    }

    fn credit_token(&mut self, token: u64, n: usize, now: u64, stats: &mut Stats) {
        let finalize = {
            let Some(t) = self.tokens.get_mut(&token) else { return };
            t.remaining = t.remaining.saturating_sub(n);
            t.remaining == 0
        };
        if !finalize {
            return;
        }
        let t = self.tokens.remove(&token).unwrap();
        let (ready, place) = match t.kind {
            TokenKind::OffloadedLoad | TokenKind::PlainLoad => {
                // LSU-Extension wrote the gathered data into the
                // near-bank RF (remote chunks already descended the home
                // TSVs in `chunk_completed`).
                stats.rf_near_accesses += 1;
                stats.lsu_ext_requests += 1;
                (now + 1, RegPlace::Near)
            }
            TokenKind::PonbLoad => {
                stats.rf_far_accesses += 1;
                (now + 1, RegPlace::Far)
            }
        };
        self.completed.push(Completion { core: t.core, warp: t.warp, dst: t.dst, ready, place });
    }

    /// Execute register moves required before running at a location;
    /// returns the cycle all moved registers have arrived.
    fn do_moves(
        &mut self,
        c: usize,
        w: &mut Warp,
        required: &[(Reg, ExecLoc)],
        now: u64,
        stats: &mut Stats,
    ) -> u64 {
        let warp_bytes = (self.cfg.warp_size * 4) as u64;
        let mut ready = now;
        // Plan first (all decisions against the pre-move track state —
        // a duplicated source register plans one move per occurrence,
        // like `offload::plan_moves`), then execute. The list fits on
        // the stack: at most 3 sources + an address register.
        assert!(required.len() <= 8, "required-register list overflow");
        let mut moves = [(Reg::r(0), MoveDir::ToNb); 8];
        let mut n_moves = 0;
        for &(r, want) in required {
            if let Some(dir) = offload::move_for(r, want, &w.track) {
                moves[n_moves] = (r, dir);
                n_moves += 1;
            }
        }
        for &(r, dir) in &moves[..n_moves] {
            let dep = w.reg_ready.get(r);
            let start = now.max(dep);
            let arr = self.links[c].tsv.transfer(start, warp_bytes, TsvTraffic::RegMove, stats);
            stats.reg_moves += 1;
            stats.rf_near_accesses += 1;
            stats.rf_far_accesses += 1;
            match dir {
                MoveDir::ToNb => w.track.copy_to_nb(r),
                MoveDir::ToFb => w.track.copy_to_fb(r),
            }
            ready = ready.max(arr);
        }
        // Registers valid in neither file materialize where needed.
        for (r, want) in required {
            if !w.track.nb_valid(*r) && !w.track.fb_valid(*r) {
                match want {
                    ExecLoc::Near => w.track.copy_to_nb(*r),
                    ExecLoc::Far => w.track.copy_to_fb(*r),
                }
            }
        }
        ready
    }
}

impl MemorySystem for NearBankMemory {
    fn issue_access(&mut self, ctx: &AccessCtx, w: &mut Warp, stats: &mut Stats) {
        let (c, wi, instr, now) = (ctx.core, ctx.warp_index, ctx.instr, ctx.now);
        let io_bytes = (self.cfg.bank_io_bits / 8) as u64;
        let ponb = self.cfg.pipeline_mode == PipelineMode::PonB;
        let wa: WarpAccess = coalesce(
            &ctx.addrs.iter().map(|&(_, a)| a).collect::<Vec<_>>(),
            &self.map,
            io_bytes,
            self.cfg.cores_per_proc,
        );
        let is_write = matches!(instr.op, Op::St | Op::Red);
        let offloadable = !ponb && wa.offloadable(ctx.full_warp, c);

        // Address register must be far-bank (LSU); store data stays in
        // the near-bank RF in hybrid mode (value registers are N by
        // §IV-B1 hardware policy) and far-bank on PonB.
        let mut required = std::mem::take(&mut self.req_buf);
        required.clear();
        if instr.has_mem {
            required.push((instr.mem_base, ExecLoc::Far));
        }
        if is_write {
            for s in instr.src_regs_iter() {
                if s.class != RegClass::P {
                    let want = if ponb { ExecLoc::Far } else { ExecLoc::Near };
                    required.push((s, want));
                }
            }
        }
        let moves_done = self.do_moves(c, w, &required, now, stats);
        self.req_buf = required;

        if offloadable {
            stats.instrs_near += 1;
        } else {
            stats.instrs_far += 1;
        }
        stats.rf_far_accesses += 1; // address operand read
        if is_write {
            if ponb {
                stats.rf_far_accesses += 1;
            } else {
                stats.rf_near_accesses += 1;
            }
        }

        let (local, remote) = wa.split(c);
        let token = if is_write {
            0
        } else {
            let id = self.next_id;
            self.next_id += 1;
            let kind = if ponb {
                TokenKind::PonbLoad
            } else if offloadable {
                TokenKind::OffloadedLoad
            } else {
                TokenKind::PlainLoad
            };
            self.tokens.insert(
                id,
                Token { remaining: wa.chunks.len(), core: c, warp: wi, dst: instr.dst.unwrap(), kind },
            );
            // Block the destination until the token finalizes.
            w.reg_ready.insert(instr.dst.unwrap(), u64::MAX);
            id
        };

        // Local chunks. Command traffic down the TSVs: the leading
        // address only when offloaded (Fig. 4-6), per-chunk addresses
        // otherwise. Store *data* descends only on PonB — in hybrid mode
        // it is already in the near-bank RF on the DRAM die.
        if !local.is_empty() {
            let mut cmd_bytes = if offloadable { 8 } else { local.len() as u64 * 8 };
            let mut class = TsvTraffic::Command;
            if is_write && ponb {
                cmd_bytes += local.len() as u64 * io_bytes;
                class = TsvTraffic::DramData;
            }
            let arr = self.links[c].tsv.transfer(now.max(moves_done), cmd_bytes, class, stats);
            let mut per_nbu: HashMap<usize, Vec<DramRequest>> = HashMap::new();
            for &ci in &local {
                let ch = wa.chunks[ci];
                let id = self.next_id;
                self.next_id += 1;
                self.routes.insert(id, ChunkRoute { token, service_core: c, home_core: c, is_write });
                per_nbu.entry(ch.coord.nbu).or_default().push(DramRequest {
                    id,
                    bank: ch.coord.bank,
                    row: ch.coord.row,
                    slot: self.map.slot_of_row(ch.coord.row),
                    is_write,
                });
            }
            for (nbu, reqs) in per_nbu {
                self.push_event(now, arr, Event::EnqueueDram { core: c, nbu, reqs });
            }
        }

        // Remote chunks: request over the mesh to the owning core's
        // LSU-Remote, which issues through that core's TSVs (§IV-B2).
        // Hybrid store data starts in the home NB RF, so it first lifts
        // over the home TSVs.
        if !remote.is_empty() {
            let mut per_core: HashMap<usize, Vec<usize>> = HashMap::new();
            for &ci in &remote {
                per_core.entry(wa.chunks[ci].core_global).or_default().push(ci);
            }
            let my_proc = c / self.cfg.cores_per_proc;
            for (rc, cis) in per_core {
                let data_bytes = if is_write { io_bytes } else { 0 };
                let req_bytes = cis.len() as u64 * (8 + data_bytes);
                let mut t = now.max(moves_done);
                if is_write && !ponb {
                    // Store data: NB RF → base logic die.
                    t = self.links[c].tsv.transfer(t, cis.len() as u64 * io_bytes, TsvTraffic::DramData, stats);
                }
                t = self.mesh.send(t, c, rc, req_bytes, stats);
                let rproc = rc / self.cfg.cores_per_proc;
                if rproc != my_proc {
                    t = self.offchip.send(t, my_proc, rproc, req_bytes, stats);
                }
                // At the remote core: TSV command (+ data) down, then DRAM.
                let arr2 = self.links[rc].tsv.transfer(
                    t,
                    cis.len() as u64 * (8 + data_bytes),
                    if is_write { TsvTraffic::DramData } else { TsvTraffic::Command },
                    stats,
                );
                let mut per_nbu: HashMap<usize, Vec<DramRequest>> = HashMap::new();
                for ci in cis {
                    let ch = wa.chunks[ci];
                    let id = self.next_id;
                    self.next_id += 1;
                    self.routes.insert(id, ChunkRoute { token, service_core: rc, home_core: c, is_write });
                    per_nbu.entry(ch.coord.nbu).or_default().push(DramRequest {
                        id,
                        bank: ch.coord.bank,
                        row: ch.coord.row,
                        slot: self.map.slot_of_row(ch.coord.row),
                        is_write,
                    });
                }
                for (nbu, reqs) in per_nbu {
                    self.push_event(now, arr2, Event::EnqueueDram { core: rc, nbu, reqs });
                }
            }
        }
    }

    fn advance(&mut self, now: u64, stats: &mut Stats) {
        // Deliver due events first (same order as the pre-refactor
        // machine: events, then controller scheduling).
        while let Some(top) = self.events.peek() {
            if top.at > now {
                break;
            }
            let q = self.events.pop().unwrap();
            match q.ev {
                Event::EnqueueDram { core, nbu, reqs } => {
                    for r in reqs {
                        self.links[core].controllers[nbu].push(now, r);
                    }
                }
                Event::TokenCredit { token } => self.credit_token(token, 1, now, stats),
            }
        }
        for c in 0..self.links.len() {
            for nbu in 0..self.cfg.nbus_per_core {
                self.links[c].controllers[nbu].advance(now, stats);
                let done = self.links[c].controllers[nbu].drain_completed(now);
                for id in done {
                    self.chunk_completed(id, now, stats);
                }
            }
        }
    }

    fn drain_completed(&mut self, _now: u64, out: &mut Vec<Completion>) {
        out.append(&mut self.completed);
    }

    fn next_event(&self) -> Option<u64> {
        // Controllers cache their own next-event time, so this is one
        // O(1) read per controller rather than a queue rescan.
        let mut best: Option<u64> = self.events.peek().map(|e| e.at);
        for l in &self.links {
            for m in &l.controllers {
                if let Some(t) = m.next_event() {
                    best = Some(best.map_or(t, |b| b.min(t)));
                }
            }
        }
        best
    }

    // `advance_to` is inherited: the default trait loop hops directly
    // between this backend's internal event times — queued
    // TSV/mesh/off-chip events and the FR-FCFS+MASA controllers' own
    // (cached, O(1)) next-event times — performing exactly what
    // `advance(t)` would have done at each cycle, and `completions_pending`
    // below makes it stop at the first cycle that produces a load
    // completion so the frontend wakes the owning warp at exactly the
    // same cycle as the per-cycle reference loop.

    fn completions_pending(&self) -> bool {
        !self.completed.is_empty()
    }

    fn idle(&self) -> bool {
        // `routes` covers every in-flight DRAM chunk (whether it is
        // still inside a queued `EnqueueDram` event, a controller's
        // queue, or its un-drained done list), and `events` covers the
        // token credits that outlive their chunks — so this O(1) check
        // is equivalent to scanning every controller, without paying
        // O(cores × NBUs) on the run loop's per-iteration termination
        // test.
        self.events.is_empty() && self.completed.is_empty() && self.routes.is_empty()
    }

    fn home_core(&self, hint: Option<u64>) -> Option<usize> {
        hint.map(|a| {
            let c = self.map.decode(a);
            c.proc * self.cfg.cores_per_proc + c.core
        })
    }

    fn seed_param(&self, w: &mut Warp, r: Reg) {
        // The launch path writes the (uniform) parameter values into
        // both register files: seeding the near-bank copies costs
        // nothing at runtime and saves a per-warp register move per
        // parameter.
        w.track.write_fb(r);
        w.track.copy_to_nb(r);
    }
}

impl OffloadModel for NearBankMemory {
    fn pre_issue(
        &mut self,
        core: usize,
        w: &mut Warp,
        instr: &MacroOp,
        hint: Loc,
        now: u64,
        stats: &mut Stats,
    ) -> (ExecLoc, u64) {
        // Fig. 3 step 1: location decision; step 2: source-register
        // locations; step 3: register movement. The step-2 list lives in
        // a reused buffer — nothing here allocates per issue.
        let explicit = self.explicit.get(instr.pc as usize).copied().unwrap_or(Loc::U);
        let loc = offload::instr_location(instr, hint, explicit, &self.cfg, &w.track);
        let mut required = std::mem::take(&mut self.req_buf);
        offload::required_reg_locs_into(instr, loc, &self.cfg, &mut required);
        let ready = self.do_moves(core, w, &required, now, stats);
        self.req_buf = required;
        (loc, ready)
    }

    fn alu_start(&mut self, core: usize, loc: ExecLoc, ready: u64, now: u64, stats: &mut Stats) -> u64 {
        match loc {
            ExecLoc::Near => {
                // Instruction packet down the TSVs.
                let arr = self.links[core].tsv.transfer(
                    now,
                    self.cfg.offload_packet_bytes,
                    TsvTraffic::InstrOffload,
                    stats,
                );
                arr.max(ready)
            }
            ExecLoc::Far => now.max(ready),
        }
    }

    fn retire_dst(&mut self, w: &mut Warp, instr: &MacroOp, loc: ExecLoc, done: u64) {
        if let Some((d, where_)) = offload::dst_location(instr, loc, &self.cfg) {
            w.reg_ready.insert(d, done);
            match where_ {
                ExecLoc::Near => w.track.write_nb(d),
                ExecLoc::Far => w.track.write_fb(d),
            }
        }
    }
}

/// The simulated MPU machine: shared SIMT frontend + near-bank backend.
pub struct Machine {
    pub cfg: MachineConfig,
    fe: SimtFrontend<NearBankMemory>,
}

impl FrontendParams {
    /// Frontend parameters of an MPU machine configuration.
    pub fn for_mpu(cfg: &MachineConfig) -> FrontendParams {
        FrontendParams {
            cores: cfg.total_cores(),
            subcores_per_core: cfg.subcores_per_core,
            warp_size: cfg.warp_size,
            max_warps_per_subcore: cfg.max_warps_per_subcore,
            max_blocks_per_core: cfg.max_blocks_per_core,
            issue_width: cfg.issue_width,
            smem_bytes: cfg.smem_bytes,
            sched_policy: cfg.sched_policy,
            alu_latency: cfg.alu_latency,
            sfu_latency: cfg.sfu_latency,
            opc_latency: cfg.opc_latency,
            smem_latency: cfg.smem_latency,
            // Functional memory: cap to something simulatable.
            mem_bytes: cfg.total_mem_bytes().min(256 << 20),
            max_cycles: cfg.max_cycles,
            threads: 1,
        }
    }
}

impl Machine {
    pub fn new(cfg: &MachineConfig) -> Machine {
        Machine {
            cfg: cfg.clone(),
            fe: SimtFrontend::new(FrontendParams::for_mpu(cfg), NearBankMemory::new(cfg)),
        }
    }

    // Device-memory API (delegated to the frontend).
    pub fn alloc(&mut self, bytes: usize) -> u64 {
        self.fe.alloc(bytes)
    }
    pub fn write_mem(&mut self, addr: u64, data: &[u8]) {
        self.fe.write_mem(addr, data)
    }
    pub fn read_mem(&self, addr: u64, len: usize) -> &[u8] {
        self.fe.read_mem(addr, len)
    }
    pub fn write_f32s(&mut self, addr: u64, data: &[f32]) {
        self.fe.write_f32s(addr, data)
    }
    pub fn read_f32s(&self, addr: u64, n: usize) -> Vec<f32> {
        self.fe.read_f32s(addr, n)
    }
    pub fn write_u32s(&mut self, addr: u64, data: &[u32]) {
        self.fe.write_u32s(addr, data)
    }
    pub fn read_u32s(&self, addr: u64, n: usize) -> Vec<u32> {
        self.fe.read_u32s(addr, n)
    }

    /// Launch a kernel; `home_addr(block)` is the §V-A data-local
    /// dispatch hint. Accepts a `CompiledKernel` by value (decoded here)
    /// or a shared `Arc<DecodedKernel>` (the kernel cache's zero-copy
    /// path).
    pub fn launch(
        &mut self,
        kernel: impl Into<Arc<DecodedKernel>>,
        launch: LaunchConfig,
        params: &[ParamValue],
        home_addr: impl Fn(u32) -> Option<u64>,
    ) -> Result<()> {
        let kernel: Arc<DecodedKernel> = kernel.into();
        // Resolve the explicit policy table into a dense per-pc override
        // vector for this kernel. Resolution happens here — not at
        // decode time — so the decoded kernel stays shareable across
        // configurations (the kernel cache hands the same `Arc` to every
        // candidate policy).
        self.fe.mem_sys.explicit = if self.cfg.offload_policy == OffloadPolicy::Explicit {
            self.cfg.offload_table.resolve(&kernel.name, kernel.ops.len())
        } else {
            Vec::new()
        };
        self.fe.launch(kernel, launch, params, home_addr)
    }

    /// Shard cores across `n` worker threads during issue (deterministic;
    /// `run()` output is byte-identical for any `n`). `n <= 1` keeps the
    /// serial path.
    pub fn set_threads(&mut self, n: usize) {
        self.fe.set_threads(n);
    }

    /// Run to completion; returns final stats.
    pub fn run(&mut self) -> Result<Stats> {
        self.fe.run()
    }

    /// Run with the per-cycle reference loop (the event-driven `run`'s
    /// timing oracle; see `SimtFrontend::run_reference`).
    pub fn run_reference(&mut self) -> Result<Stats> {
        self.fe.run_reference()
    }

    /// Statistics accumulated so far.
    pub fn stats(&self) -> &Stats {
        &self.fe.stats
    }

    /// Record every warp memory access into an address trace (for
    /// validating the static analysis; see [`crate::analysis`]).
    pub fn enable_mem_trace(&mut self) {
        self.fe.enable_mem_trace()
    }

    /// Take the recorded address trace (and stop recording).
    pub fn take_mem_trace(&mut self) -> Option<Vec<crate::core::frontend::MemTraceRec>> {
        self.fe.take_mem_trace()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::isa::{KernelSource, Reg};

    fn axpy_kernel() -> KernelSource {
        KernelSource::assemble(
            "axpy",
            &[Reg::r(10), Reg::r(11), Reg::f(10), Reg::r(12)],
            r#"
                mov.u32   %r1, %tid.x
                mov.u32   %r2, %ctaid.x
                mad.u32   %r3, %r2, %ntid.x, %r1
                mov.u32   %r9, %nctaid.x
                mul.u32   %r9, %r9, %ntid.x
            LOOP:
                setp.ge.s32 %p1, %r3, %r12
                @%p1 bra  DONE
                shl.u32   %r4, %r3, 2
                add.u32   %r5, %r10, %r4
                add.u32   %r6, %r11, %r4
                ld.global.f32 %f1, [%r5+0]
                ld.global.f32 %f2, [%r6+0]
                mad.f32   %f3, %f1, %f10, %f2
                st.global.f32 [%r6+0], %f3
                add.u32   %r3, %r3, %r9
                bra       LOOP
            DONE:
                exit
            "#,
        )
        .unwrap()
    }

    fn run_axpy(cfg: &MachineConfig, n: usize) -> (Vec<f32>, Stats, Vec<f32>) {
        let k = compile(&axpy_kernel()).unwrap();
        let mut m = Machine::new(cfg);
        let x = m.alloc(n * 4);
        let y = m.alloc(n * 4);
        let mut rng = crate::sim::Prng::new(42);
        let xv = rng.f32_vec(n, -1.0, 1.0);
        let yv = rng.f32_vec(n, -1.0, 1.0);
        m.write_f32s(x, &xv);
        m.write_f32s(y, &yv);
        let alpha = 1.5f32;
        // 32 blocks × 128 threads = 4096 threads → the grid-stride
        // (16 KiB) equals one full bank sweep (64 banks × 256 B), so
        // every iteration of a block stays on its home core.
        let launch = LaunchConfig::new(32, 128);
        m.launch(
            k,
            launch,
            &[
                ParamValue::U32(x as u32),
                ParamValue::U32(y as u32),
                ParamValue::F32(alpha),
                ParamValue::U32(n as u32),
            ],
            |b| Some(x + b as u64 * 128 * 4),
        )
        .unwrap();
        let stats = m.run().unwrap();
        let got = m.read_f32s(y, n);
        let want: Vec<f32> = xv.iter().zip(&yv).map(|(a, b)| alpha * a + b).collect();
        (got, stats, want)
    }

    #[test]
    fn debug_hybrid_stats() {
        let cfg = MachineConfig::scaled();
        let (_, s, _) = run_axpy(&cfg, 8192);
        eprintln!("cycles={} near={} far={} nearfrac={:.3}", s.cycles, s.instrs_near, s.instrs_far, s.near_fraction());
        eprintln!("tsv: offload={} regmove={} dramdata={} smem={} cmd={}", s.tsv_bytes[0], s.tsv_bytes[1], s.tsv_bytes[2], s.tsv_bytes[3], s.tsv_bytes[4]);
        eprintln!("reg_moves={} mesh={} rowmiss={:.3} dram_bytes={} bpc={:.2}", s.reg_moves, s.mesh_bytes, s.row_miss_rate(), s.dram_bytes, s.dram_bytes_per_cycle());
        eprintln!("reads={} writes={} acts={} pres={}", s.dram_reads, s.dram_writes, s.dram_acts, s.dram_pres);
    }

    #[test]
    fn axpy_functional_correctness() {
        let cfg = MachineConfig::scaled();
        let (got, stats, want) = run_axpy(&cfg, 4096);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-6, "mismatch at {i}: {g} vs {w}");
        }
        assert!(stats.cycles > 0);
        assert!(stats.instrs_total() > 0);
        assert!(stats.dram_reads > 0);
        assert!(stats.dram_writes > 0);
    }

    #[test]
    fn axpy_offloads_value_chain() {
        let cfg = MachineConfig::scaled();
        let (_, stats, _) = run_axpy(&cfg, 4096);
        assert!(stats.instrs_near > 0, "fma + coalesced ld/st should offload");
        assert!(stats.near_fraction() > 0.1, "near fraction {}", stats.near_fraction());
    }

    #[test]
    fn ponb_mode_runs_and_never_offloads() {
        let mut cfg = MachineConfig::scaled();
        cfg.pipeline_mode = PipelineMode::PonB;
        let (got, stats, want) = run_axpy(&cfg, 2048);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6);
        }
        assert_eq!(stats.instrs_near, 0);
        assert!(stats.tsv_bytes[TsvTraffic::DramData as usize] > 0, "PonB lifts all data over TSVs");
    }

    #[test]
    fn hybrid_beats_ponb_on_streaming() {
        let cfg = MachineConfig::scaled();
        let (_, hybrid, _) = run_axpy(&cfg, 8192);
        let mut pcfg = cfg.clone();
        pcfg.pipeline_mode = PipelineMode::PonB;
        let (_, ponb, _) = run_axpy(&pcfg, 8192);
        assert!(
            hybrid.cycles < ponb.cycles,
            "hybrid {} should beat PonB {}",
            hybrid.cycles,
            ponb.cycles
        );
    }

    #[test]
    fn no_offload_variant_runs_all_far_bank() {
        // The PIM-style variant: near-bank banks, offload forced off.
        let cfg = MachineConfig::scaled().no_offload();
        let (got, stats, want) = run_axpy(&cfg, 2048);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6);
        }
        // ALU work never offloads; only the hardware-mandated near-bank
        // paths (coalesced-access LSU offload, near smem) remain.
        assert!(stats.near_fraction() < 0.5, "near fraction {}", stats.near_fraction());
        assert!(stats.reg_moves > 0, "far-bank compute must pull loaded values up");
    }

    #[test]
    fn partial_warp_and_odd_sizes() {
        let cfg = MachineConfig::scaled();
        // n not a multiple of anything nice; blocks of 96 threads → 3
        // warps, last one partial vs n boundary.
        let k = compile(&axpy_kernel()).unwrap();
        let mut m = Machine::new(&cfg);
        let n = 1000usize;
        let x = m.alloc(n * 4);
        let y = m.alloc(n * 4);
        let xv: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let yv = vec![1.0f32; n];
        m.write_f32s(x, &xv);
        m.write_f32s(y, &yv);
        m.launch(
            k,
            LaunchConfig::new(3, 96),
            &[
                ParamValue::U32(x as u32),
                ParamValue::U32(y as u32),
                ParamValue::F32(2.0),
                ParamValue::U32(n as u32),
            ],
            |_| None,
        )
        .unwrap();
        m.run().unwrap();
        let got = m.read_f32s(y, n);
        for (i, g) in got.iter().enumerate() {
            let w = 2.0 * i as f32 + 1.0;
            assert!((g - w).abs() < 1e-5, "at {i}: {g} vs {w}");
        }
    }

    #[test]
    fn event_driven_loop_matches_reference_on_axpy() {
        // The event-driven run loop (wake heap + gated advance +
        // batched advance_to) must be indistinguishable from the
        // per-cycle reference loop: same cycles, same stats, same
        // memory image.
        let cfg = MachineConfig::scaled();
        let k = compile(&axpy_kernel()).unwrap();
        let n = 4096usize;
        let mut runs = Vec::new();
        for reference in [false, true] {
            let mut m = Machine::new(&cfg);
            let x = m.alloc(n * 4);
            let y = m.alloc(n * 4);
            let mut rng = crate::sim::Prng::new(7);
            let xv = rng.f32_vec(n, -1.0, 1.0);
            let yv = rng.f32_vec(n, -1.0, 1.0);
            m.write_f32s(x, &xv);
            m.write_f32s(y, &yv);
            m.launch(
                k.clone(),
                LaunchConfig::new(32, 128),
                &[
                    ParamValue::U32(x as u32),
                    ParamValue::U32(y as u32),
                    ParamValue::F32(1.5),
                    ParamValue::U32(n as u32),
                ],
                |b| Some(x + b as u64 * 128 * 4),
            )
            .unwrap();
            let stats = if reference { m.run_reference().unwrap() } else { m.run().unwrap() };
            let out: Vec<u32> = m.read_f32s(y, n).iter().map(|v| v.to_bits()).collect();
            runs.push((stats, out));
        }
        let (fast, slow) = (&runs[0], &runs[1]);
        assert_eq!(fast.0, slow.0, "event-driven stats diverge from the reference loop");
        assert_eq!(fast.1, slow.1, "memory image diverges from the reference loop");
    }

    #[test]
    fn masa_reduces_row_misses_on_pingpong() {
        // Two warps streaming two different row regions from the same
        // bank ping-pong a single row buffer; 4 buffers fix it.
        let mut cfg1 = MachineConfig::scaled();
        cfg1.row_buffers_per_bank = 1;
        let (_, s1, _) = run_axpy(&cfg1, 8192);
        let mut cfg4 = MachineConfig::scaled();
        cfg4.row_buffers_per_bank = 4;
        let (_, s4, _) = run_axpy(&cfg4, 8192);
        assert!(
            s4.row_miss_rate() <= s1.row_miss_rate() + 1e-9,
            "MASA should not increase miss rate: {} vs {}",
            s4.row_miss_rate(),
            s1.row_miss_rate()
        );
    }
}
