//! The MPU machine: functional + timing simulation of the full system
//! (§IV). This is where the hybrid pipeline, offload engine, register
//! move engine, hybrid LSU, NBUs, TSVs, DRAM controllers, mesh and
//! barriers come together.
//!
//! Execution model: warp-level issue with scoreboard stalls. Each cycle
//! every subcore may issue one instruction from a ready warp (GTO or RR).
//! Issued instructions execute *functionally* immediately (so the memory
//! image is exact and can be checked against the XLA golden model) while
//! their *timing* is tracked through latency reservations on the TSV
//! buses, DRAM controllers (FR-FCFS + MASA row-buffers), the mesh, and
//! per-register ready times. Idle stretches are fast-forwarded.

use super::exec::{alu_lane, operand_value, LaneCtx};
use super::lsu::{coalesce, WarpAccess};
use super::offload::{self, ExecLoc, MoveDir};
use super::warp::{Warp, WarpState};
use crate::compiler::CompiledKernel;
use crate::config::{MachineConfig, PipelineMode, SchedPolicy};
use crate::dram::{DramRequest, MemController};
use crate::isa::program::ParamValue;
use crate::isa::{LaunchConfig, Op, Reg, RegClass, Space};
use crate::mem::{AddrMap, SharedMem};
use crate::noc::{Mesh, OffchipLink, Tsv};
use crate::sim::stats::TsvTraffic;
use crate::sim::Stats;
use anyhow::{bail, Result};
use std::collections::{BinaryHeap, HashMap, VecDeque};

/// A resident thread block.
#[derive(Debug)]
struct BlockState {
    id: u32,
    warps_live: usize,
    at_barrier: usize,
    smem: SharedMem,
}

/// Simulation events (things that happen at a future cycle on another
/// component).
#[derive(Debug)]
enum Event {
    /// DRAM column requests arrive at a core's NBU controller (after a
    /// TSV command transfer or a mesh hop).
    EnqueueDram { core: usize, nbu: usize, reqs: Vec<DramRequest> },
    /// A remote-serviced (or locally TSV-delayed) chunk credits a token.
    TokenCredit { token: u64 },
}

#[derive(Debug)]
struct QueuedEvent {
    at: u64,
    seq: u64,
    ev: Event,
}

impl PartialEq for QueuedEvent {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl Eq for QueuedEvent {}
impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for QueuedEvent {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // Min-heap via reverse.
        (o.at, o.seq).cmp(&(self.at, self.seq))
    }
}

/// What happens when all of a memory token's chunks have arrived.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TokenKind {
    /// Near-bank-offloaded load: data lands in the NB RF directly.
    OffloadedLoad,
    /// Plain load (hybrid): compose a register write and send it down
    /// for near-bank writeback (§IV-B2).
    PlainLoad,
    /// PonB load: data was lifted over the TSVs per chunk; write FB RF.
    PonbLoad,
}

#[derive(Debug)]
struct Token {
    remaining: usize,
    core: usize,
    warp: usize,
    dst: Reg,
    kind: TokenKind,
}

/// Per-chunk routing info for completion handling.
#[derive(Clone, Copy, Debug)]
struct ChunkRoute {
    token: u64,
    /// Core that services the chunk.
    service_core: usize,
    /// Home core that issued the access (worth a mesh response if
    /// different from `service_core`).
    home_core: usize,
    is_write: bool,
}

struct Core {
    warps: Vec<Warp>,
    blocks: Vec<BlockState>,
    tsv: Tsv,
    controllers: Vec<MemController>,
    /// GTO bookkeeping: last-issued warp per subcore.
    last_issued: Vec<Option<usize>>,
    /// RR bookkeeping.
    rr_next: Vec<usize>,
    pending_blocks: VecDeque<u32>,
    /// Live (non-retired) warp indices per subcore — the scheduler scans
    /// only these (EXPERIMENTS.md §Perf iteration 3); retired warps stay
    /// in `warps` so in-flight token indices remain stable.
    sc_warps: Vec<Vec<usize>>,
}

/// The simulated MPU machine.
pub struct Machine {
    pub cfg: MachineConfig,
    pub map: AddrMap,
    kernel: Option<CompiledKernel>,
    launch: Option<LaunchConfig>,
    params: Vec<ParamValue>,
    mem: Vec<u8>,
    alloc_top: u64,
    cores: Vec<Core>,
    mesh: Mesh,
    offchip: OffchipLink,
    events: BinaryHeap<QueuedEvent>,
    seq: u64,
    tokens: HashMap<u64, Token>,
    routes: HashMap<u64, ChunkRoute>,
    next_id: u64,
    pub stats: Stats,
    now: u64,
    blocks_done: u32,
    warp_size: usize,
}

impl Machine {
    pub fn new(cfg: &MachineConfig) -> Machine {
        let map = AddrMap::new(cfg);
        let cores = (0..cfg.total_cores())
            .map(|_| Core {
                warps: Vec::new(),
                blocks: Vec::new(),
                tsv: Tsv::new(cfg),
                controllers: (0..cfg.nbus_per_core).map(|_| MemController::new(cfg)).collect(),
                last_issued: vec![None; cfg.subcores_per_core],
                rr_next: vec![0; cfg.subcores_per_core],
                pending_blocks: VecDeque::new(),
                sc_warps: vec![Vec::new(); cfg.subcores_per_core],
            })
            .collect();
        // Functional memory: cap to something simulatable.
        let mem_bytes = cfg.total_mem_bytes().min(256 << 20);
        Machine {
            cfg: cfg.clone(),
            map,
            kernel: None,
            launch: None,
            params: Vec::new(),
            mem: vec![0; mem_bytes],
            alloc_top: 0,
            cores,
            mesh: Mesh::new(cfg),
            offchip: OffchipLink::new(cfg),
            events: BinaryHeap::new(),
            seq: 0,
            tokens: HashMap::new(),
            routes: HashMap::new(),
            next_id: 1,
            stats: Stats::default(),
            now: 0,
            blocks_done: 0,
            warp_size: cfg.warp_size,
        }
    }

    // ---------------- device memory API ----------------

    /// Bump-allocate device memory (256-B aligned).
    pub fn alloc(&mut self, bytes: usize) -> u64 {
        let base = (self.alloc_top + 255) & !255;
        self.alloc_top = base + bytes as u64;
        assert!(
            (self.alloc_top as usize) <= self.mem.len(),
            "device OOM: {} > {}",
            self.alloc_top,
            self.mem.len()
        );
        base
    }

    pub fn write_mem(&mut self, addr: u64, data: &[u8]) {
        let a = addr as usize;
        self.mem[a..a + data.len()].copy_from_slice(data);
    }

    pub fn read_mem(&self, addr: u64, len: usize) -> &[u8] {
        &self.mem[addr as usize..addr as usize + len]
    }

    pub fn write_f32s(&mut self, addr: u64, data: &[f32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.write_mem(addr, &bytes);
    }

    pub fn read_f32s(&self, addr: u64, n: usize) -> Vec<f32> {
        self.read_mem(addr, n * 4)
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn write_u32s(&mut self, addr: u64, data: &[u32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.write_mem(addr, &bytes);
    }

    pub fn read_u32s(&self, addr: u64, n: usize) -> Vec<u32> {
        self.read_mem(addr, n * 4)
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    fn mem_read_u32(&self, addr: u64) -> u32 {
        let a = addr as usize;
        if a + 4 > self.mem.len() {
            return 0;
        }
        u32::from_le_bytes(self.mem[a..a + 4].try_into().unwrap())
    }

    fn mem_write_u32(&mut self, addr: u64, v: u32) {
        let a = addr as usize;
        if a + 4 > self.mem.len() {
            return;
        }
        self.mem[a..a + 4].copy_from_slice(&v.to_le_bytes());
    }

    // ---------------- launch ----------------

    /// Launch a kernel. `home_addr(block)` is the runtime's dispatch
    /// hint: the block is scheduled on the core owning that address
    /// (§V-A: "MPU runtime dispatches the workload of thread blocks to
    /// MPU cores"); `None` falls back to round-robin.
    pub fn launch(
        &mut self,
        kernel: CompiledKernel,
        launch: LaunchConfig,
        params: &[ParamValue],
        home_addr: impl Fn(u32) -> Option<u64>,
    ) -> Result<()> {
        if launch.block as usize > self.cfg.max_warps_per_subcore * self.cfg.subcores_per_core * self.warp_size {
            bail!("block size {} exceeds core capacity", launch.block);
        }
        if kernel.params.len() != params.len() {
            bail!("kernel `{}` expects {} params, got {}", kernel.name, kernel.params.len(), params.len());
        }
        self.kernel = Some(kernel);
        self.launch = Some(launch);
        self.params = params.to_vec();
        let ncores = self.cfg.total_cores();
        for b in 0..launch.grid {
            let core = match home_addr(b) {
                Some(a) => {
                    let c = self.map.decode(a);
                    c.proc * self.cfg.cores_per_proc + c.core
                }
                None => b as usize % ncores,
            };
            self.cores[core].pending_blocks.push_back(b);
        }
        for c in 0..ncores {
            while self.try_dispatch_block(c) {}
        }
        Ok(())
    }

    /// Dispatch the next pending block on core `c` if resources allow.
    fn try_dispatch_block(&mut self, c: usize) -> bool {
        let launch = self.launch.unwrap();
        let kernel = self.kernel.as_ref().unwrap();
        let core = &mut self.cores[c];
        if core.blocks.len() >= self.cfg.max_blocks_per_core {
            return false;
        }
        let warps_per_block = launch.warps_per_block(self.warp_size);
        let live_warps = core.warps.iter().filter(|w| w.state != WarpState::Done).count();
        if live_warps + warps_per_block > self.cfg.max_warps_per_subcore * self.cfg.subcores_per_core {
            return false;
        }
        let Some(b) = core.pending_blocks.pop_front() else {
            return false;
        };
        let reg_counts = kernel.reg_counts;
        let smem_bytes = (launch.smem_bytes as usize).min(self.cfg.smem_bytes);
        core.blocks.push(BlockState {
            id: b,
            warps_live: warps_per_block,
            at_barrier: 0,
            smem: SharedMem::new(smem_bytes.max(4)),
        });
        for wi in 0..warps_per_block {
            let lanes = (launch.block as usize - wi * self.warp_size).min(self.warp_size);
            let subcore = wi % self.cfg.subcores_per_core;
            let mut w = Warp::new(b, wi, lanes, subcore, reg_counts, self.warp_size);
            w.ready_at = self.now + 1;
            // Deliver parameters into both register files: the kernel
            // launch path writes the (uniform) parameter values anyway,
            // so seeding the near-bank copies costs nothing at runtime
            // and saves a per-warp register move per parameter.
            for (p, v) in kernel.params.iter().zip(&self.params) {
                w.write_all(*p, v.bits());
                w.track.write_fb(*p);
                w.track.copy_to_nb(*p);
            }
            core.sc_warps[subcore].push(core.warps.len());
            core.warps.push(w);
        }
        true
    }

    // ---------------- main loop ----------------

    /// Run to completion; returns final stats.
    pub fn run(&mut self) -> Result<Stats> {
        let grid = self.launch.map(|l| l.grid).unwrap_or(0);
        loop {
            self.process_events();
            self.advance_memory();
            let issued = self.issue_all();

            let work_left = self.blocks_done < grid
                || !self.events.is_empty()
                || self.cores.iter().any(|c| c.controllers.iter().any(|m| !m.idle()));
            if !work_left {
                break;
            }
            if self.now >= self.cfg.max_cycles {
                bail!("simulation exceeded max_cycles={} (deadlock?)", self.cfg.max_cycles);
            }
            if issued {
                self.now += 1;
            } else {
                let next = self.next_interesting();
                match next {
                    Some(t) if t > self.now => self.now = t,
                    _ => self.now += 1,
                }
            }
        }
        self.stats.cycles = self.now;
        Ok(self.stats.clone())
    }

    fn push_event(&mut self, at: u64, ev: Event) {
        self.seq += 1;
        self.events.push(QueuedEvent { at: at.max(self.now), seq: self.seq, ev });
    }

    fn process_events(&mut self) {
        while let Some(top) = self.events.peek() {
            if top.at > self.now {
                break;
            }
            let q = self.events.pop().unwrap();
            match q.ev {
                Event::EnqueueDram { core, nbu, reqs } => {
                    for r in reqs {
                        self.cores[core].controllers[nbu].push(self.now, r);
                    }
                }
                Event::TokenCredit { token } => self.credit_token(token, 1),
            }
        }
    }

    fn advance_memory(&mut self) {
        let ncores = self.cores.len();
        for c in 0..ncores {
            for nbu in 0..self.cfg.nbus_per_core {
                let mut st = std::mem::take(&mut self.stats);
                self.cores[c].controllers[nbu].advance(self.now, &mut st);
                self.stats = st;
                let done = self.cores[c].controllers[nbu].drain_completed(self.now);
                for id in done {
                    self.chunk_completed(id);
                }
            }
        }
    }

    /// A DRAM column access finished: route its data and credit its
    /// token (if any).
    ///
    /// Local loads never lift data over the TSVs in hybrid mode: the
    /// LSU-Extension stores the returned data straight into the
    /// near-bank register file on the DRAM die (§IV-B2; "the reason to
    /// load the DRAM data first to the near-bank register file is that
    /// it can benefit near-bank execution due to the reduction of TSV
    /// traffic"). Far-bank consumers trigger a lazy register move later.
    /// PonB lifts every chunk.
    fn chunk_completed(&mut self, id: u64) {
        let Some(route) = self.routes.remove(&id) else {
            return;
        };
        let io_bytes = (self.cfg.bank_io_bits / 8) as u64;
        if route.is_write {
            return; // stores are fire-and-forget
        }
        let ponb = self.cfg.pipeline_mode == PipelineMode::PonB;
        if route.service_core == route.home_core {
            if ponb {
                // Data lifts over the TSVs into the far-bank RF.
                let up = self.cores[route.service_core].tsv.transfer(
                    self.now,
                    io_bytes,
                    TsvTraffic::DramData,
                    &mut self.stats,
                );
                self.push_event(up, Event::TokenCredit { token: route.token });
            } else {
                self.credit_token(route.token, 1);
            }
            return;
        }
        // Remote chunk: lift at the servicing core, cross the mesh (and
        // the off-chip link if cross-cube), then in hybrid mode descend
        // into the home core's near-bank RF.
        let up = self.cores[route.service_core].tsv.transfer(
            self.now,
            io_bytes,
            TsvTraffic::DramData,
            &mut self.stats,
        );
        let (sp, hp) = (
            route.service_core / self.cfg.cores_per_proc,
            route.home_core / self.cfg.cores_per_proc,
        );
        let mut t = self.mesh.send(up, route.service_core, route.home_core, io_bytes + 8, &mut self.stats);
        if sp != hp {
            t = self.offchip.send(t, sp, hp, io_bytes + 8, &mut self.stats);
        }
        if !ponb {
            t = self.cores[route.home_core].tsv.transfer(t, io_bytes, TsvTraffic::RegMove, &mut self.stats);
        }
        self.push_event(t, Event::TokenCredit { token: route.token });
    }

    fn credit_token(&mut self, token: u64, n: usize) {
        let finalize = {
            let Some(t) = self.tokens.get_mut(&token) else { return };
            t.remaining = t.remaining.saturating_sub(n);
            t.remaining == 0
        };
        if !finalize {
            return;
        }
        let t = self.tokens.remove(&token).unwrap();
        let ready = match t.kind {
            TokenKind::OffloadedLoad | TokenKind::PlainLoad => {
                // LSU-Extension wrote the gathered data into the
                // near-bank RF (remote chunks already descended the home
                // TSVs in chunk_completed).
                self.stats.rf_near_accesses += 1;
                self.stats.lsu_ext_requests += 1;
                self.now + 1
            }
            TokenKind::PonbLoad => {
                self.stats.rf_far_accesses += 1;
                self.now + 1
            }
        };
        let w = &mut self.cores[t.core].warps[t.warp];
        w.reg_ready.insert(t.dst, ready);
        match t.kind {
            TokenKind::PonbLoad => w.track.write_fb(t.dst),
            _ => w.track.write_nb(t.dst),
        }
    }

    /// Earliest future cycle where anything can happen.
    fn next_interesting(&self) -> Option<u64> {
        let mut best: Option<u64> = self.events.peek().map(|e| e.at);
        let mut fold = |t: Option<u64>| {
            if let Some(t) = t {
                best = Some(best.map_or(t, |b| b.min(t)));
            }
        };
        for c in &self.cores {
            for m in &c.controllers {
                fold(m.next_event());
            }
            let kernel = self.kernel.as_ref().unwrap();
            for w in c.sc_warps.iter().flatten().map(|&wi| &c.warps[wi]) {
                if w.state != WarpState::Ready {
                    continue;
                }
                let pc = w.pc();
                if pc >= kernel.instrs.len() {
                    continue;
                }
                let i = &kernel.instrs[pc];
                let dep = w.instr_ready_at(i);
                if dep == u64::MAX {
                    continue; // unblocked by a token finalize later
                }
                fold(Some(dep.max(w.ready_at)));
            }
        }
        best
    }

    /// Try to issue on every subcore of every core; returns whether any
    /// instruction issued.
    fn issue_all(&mut self) -> bool {
        let mut issued_any = false;
        let ncores = self.cores.len();
        for c in 0..ncores {
            for sc in 0..self.cfg.subcores_per_core {
                for _ in 0..self.cfg.issue_width {
                    if let Some(w) = self.pick_warp(c, sc) {
                        self.issue(c, w);
                        self.cores[c].last_issued[sc] = Some(w);
                        issued_any = true;
                    } else {
                        break;
                    }
                }
            }
        }
        issued_any
    }

    /// Scheduler: pick an issueable warp on (core, subcore).
    fn pick_warp(&self, c: usize, sc: usize) -> Option<usize> {
        let core = &self.cores[c];
        let kernel = self.kernel.as_ref().unwrap();
        let can_issue = |wi: usize| -> bool {
            let w = &core.warps[wi];
            if w.state != WarpState::Ready || w.subcore != sc || w.ready_at > self.now {
                return false;
            }
            let pc = w.pc();
            if pc >= kernel.instrs.len() {
                return false;
            }
            let i = &kernel.instrs[pc];
            w.instr_ready_at(i) <= self.now
        };

        let live = &core.sc_warps[sc];
        match self.cfg.sched_policy {
            SchedPolicy::Gto => {
                // Greedy: stick with the last-issued warp.
                if let Some(last) = core.last_issued[sc] {
                    if last < core.warps.len() && can_issue(last) {
                        return Some(last);
                    }
                }
                // Then oldest (dispatch order).
                live.iter().copied().find(|&wi| can_issue(wi))
            }
            SchedPolicy::RoundRobin => {
                let n = live.len();
                if n == 0 {
                    return None;
                }
                let start = core.rr_next[sc] % n;
                (0..n).map(|k| live[(start + k) % n]).find(|&wi| can_issue(wi))
            }
        }
    }

    // ---------------- instruction issue ----------------

    fn issue(&mut self, c: usize, wi: usize) {
        // Copy out only the per-pc scalars + one instruction — cloning
        // the whole kernel here dominated the profile (EXPERIMENTS.md
        // §Perf iteration 1).
        let launch = self.launch.unwrap();
        let pc = self.cores[c].warps[wi].pc();
        let (instr, reconv_pc, hint) = {
            let kernel = self.kernel.as_ref().unwrap();
            (kernel.instrs[pc].clone(), kernel.reconv[pc], kernel.instr_loc(pc))
        };

        if self.cfg.sched_policy == SchedPolicy::RoundRobin {
            let sc = self.cores[c].warps[wi].subcore;
            let pos = self.cores[c].sc_warps[sc].iter().position(|&x| x == wi).unwrap_or(0);
            self.cores[c].rr_next[sc] = pos + 1;
        }

        {
            let w = &mut self.cores[c].warps[wi];
            w.ready_at = self.now + 1;
            w.last_issue = self.now;
        }

        // Guard evaluation.
        let (exec_mask, active_mask) = {
            let w = &self.cores[c].warps[wi];
            let active = w.active_mask();
            let mask = match instr.guard {
                None => active,
                Some((p, neg)) => {
                    let mut m = 0u64;
                    for lane in 0..w.lanes {
                        if active >> lane & 1 == 1 {
                            let v = w.read(p, lane) != 0;
                            if v != neg {
                                m |= 1 << lane;
                            }
                        }
                    }
                    m
                }
            };
            (mask, active)
        };

        // Control flow first (always far-bank).
        match instr.op {
            Op::Bra => {
                self.stats.instrs_far += 1;
                let target = instr.target.unwrap_or(pc + 1);
                let rpc = reconv_pc.unwrap_or(usize::MAX);
                let w = &mut self.cores[c].warps[wi];
                if instr.guard.is_none() {
                    w.branch(active_mask, target, pc + 1, rpc);
                } else {
                    w.branch(exec_mask, target, pc + 1, rpc);
                }
                return;
            }
            Op::Bar => {
                self.stats.instrs_far += 1;
                self.stats.barriers += 1;
                self.barrier(c, wi, pc);
                return;
            }
            Op::Exit => {
                self.stats.instrs_far += 1;
                self.exit(c, wi, active_mask);
                return;
            }
            _ => {}
        }

        if exec_mask == 0 {
            self.stats.predicated_off += 1;
            self.stats.instrs_far += 1;
            let w = &mut self.cores[c].warps[wi];
            w.set_pc(pc + 1);
            return;
        }

        // Location decision (Fig. 3 step 1).
        let loc = {
            let w = &self.cores[c].warps[wi];
            offload::instr_location(&instr, hint, &self.cfg, &w.track)
        };

        match (instr.op, instr.space) {
            (Op::Ld | Op::St | Op::Red, Some(Space::Global)) => {
                self.issue_global_mem(c, wi, pc, &instr, exec_mask);
            }
            (Op::Ld | Op::St | Op::Red, Some(Space::Shared)) => {
                self.issue_shared_mem(c, wi, pc, &instr, exec_mask, loc, launch);
            }
            _ => {
                self.issue_alu(c, wi, pc, &instr, exec_mask, loc);
            }
        }
    }

    /// Execute register moves required before running at `loc`; returns
    /// the cycle all moved registers have arrived.
    fn do_moves(&mut self, c: usize, wi: usize, required: &[(Reg, ExecLoc)]) -> u64 {
        let moves = {
            let w = &self.cores[c].warps[wi];
            offload::plan_moves(required, &w.track)
        };
        let warp_bytes = (self.warp_size * 4) as u64;
        let mut ready = self.now;
        for (r, dir) in moves {
            let dep = self.cores[c].warps[wi].reg_ready.get(r);
            let start = self.now.max(dep);
            let arr = self.cores[c].tsv.transfer(start, warp_bytes, TsvTraffic::RegMove, &mut self.stats);
            self.stats.reg_moves += 1;
            self.stats.rf_near_accesses += 1;
            self.stats.rf_far_accesses += 1;
            let w = &mut self.cores[c].warps[wi];
            match dir {
                MoveDir::ToNb => w.track.copy_to_nb(r),
                MoveDir::ToFb => w.track.copy_to_fb(r),
            }
            ready = ready.max(arr);
        }
        // Registers valid in neither file materialize where needed.
        for (r, want) in required {
            let w = &mut self.cores[c].warps[wi];
            if !w.track.nb_valid(*r) && !w.track.fb_valid(*r) {
                match want {
                    ExecLoc::Near => w.track.copy_to_nb(*r),
                    ExecLoc::Far => w.track.copy_to_fb(*r),
                }
            }
        }
        ready
    }

    fn issue_alu(&mut self, c: usize, wi: usize, pc: usize, instr: &crate::isa::Instr, exec_mask: u64, loc: ExecLoc) {
        let required = offload::required_reg_locs(instr, loc, &self.cfg);
        let moves_done = self.do_moves(c, wi, &required);

        // Functional execution.
        let (block, warp_in_block, lanes) = {
            let w = &self.cores[c].warps[wi];
            (w.block, w.warp_in_block, w.lanes)
        };
        let launch = self.launch.unwrap();
        let n_srcs = instr.srcs.len() as u64;
        for lane in 0..lanes {
            if exec_mask >> lane & 1 == 0 {
                continue;
            }
            let ctx = LaneCtx {
                tid: (warp_in_block * self.warp_size + lane) as u32,
                ntid: launch.block,
                ctaid: block,
                nctaid: launch.grid,
            };
            let w = &self.cores[c].warps[wi];
            let srcs: Vec<u32> = instr
                .srcs
                .iter()
                .map(|o| operand_value(o, &ctx, &|r| w.read(r, lane)))
                .collect();
            let v = alu_lane(instr, &srcs);
            let w = &mut self.cores[c].warps[wi];
            if let Some(d) = instr.dst {
                w.write(d, lane, v);
            }
        }

        // Timing + accounting.
        let lat = if instr.op.is_sfu() { self.cfg.sfu_latency } else { self.cfg.alu_latency };
        let start = match loc {
            ExecLoc::Near => {
                self.stats.instrs_near += 1;
                self.stats.rf_near_accesses += n_srcs + 1;
                // Instruction packet down the TSVs.
                let arr = self.cores[c].tsv.transfer(
                    self.now,
                    self.cfg.offload_packet_bytes,
                    TsvTraffic::InstrOffload,
                    &mut self.stats,
                );
                arr.max(moves_done)
            }
            ExecLoc::Far => {
                self.stats.instrs_far += 1;
                self.stats.rf_far_accesses += n_srcs + 1;
                self.now.max(moves_done)
            }
        };
        self.stats.opc_accesses += n_srcs;
        self.stats.alu_lane_ops += exec_mask.count_ones() as u64;
        let done = start + self.cfg.opc_latency + lat;

        let w = &mut self.cores[c].warps[wi];
        if let Some((d, where_)) = offload::dst_location(instr, loc, &self.cfg) {
            w.reg_ready.insert(d, done);
            match where_ {
                ExecLoc::Near => w.track.write_nb(d),
                ExecLoc::Far => w.track.write_fb(d),
            }
        }
        w.set_pc(pc + 1);
    }

    fn lane_addrs(&self, c: usize, wi: usize, instr: &crate::isa::Instr, exec_mask: u64) -> Vec<(usize, u64)> {
        let w = &self.cores[c].warps[wi];
        let m = instr.mem.expect("memory instruction");
        (0..w.lanes)
            .filter(|l| exec_mask >> l & 1 == 1)
            .map(|l| {
                let base = w.read(m.base, l);
                (l, (base as i64 + m.offset as i64) as u64)
            })
            .collect()
    }

    fn issue_global_mem(&mut self, c: usize, wi: usize, pc: usize, instr: &crate::isa::Instr, exec_mask: u64) {
        self.stats.global_mem_instrs += 1;
        let addrs = self.lane_addrs(c, wi, instr, exec_mask);
        let ponb = self.cfg.pipeline_mode == PipelineMode::PonB;

        // Functional execution first (program order per warp).
        match instr.op {
            Op::Ld => {
                let dst = instr.dst.unwrap();
                let vals: Vec<(usize, u32)> =
                    addrs.iter().map(|&(l, a)| (l, self.mem_read_u32(a))).collect();
                let w = &mut self.cores[c].warps[wi];
                for (l, v) in vals {
                    w.write(dst, l, v);
                }
            }
            Op::St => {
                let src = instr.srcs[0];
                let launch = self.launch.unwrap();
                let (block, warp_in_block) = {
                    let w = &self.cores[c].warps[wi];
                    (w.block, w.warp_in_block)
                };
                for &(l, a) in &addrs {
                    let ctx = LaneCtx {
                        tid: (warp_in_block * self.warp_size + l) as u32,
                        ntid: launch.block,
                        ctaid: block,
                        nctaid: launch.grid,
                    };
                    let w = &self.cores[c].warps[wi];
                    let v = operand_value(&src, &ctx, &|r| w.read(r, l));
                    self.mem_write_u32(a, v);
                }
            }
            Op::Red => {
                // Atomic add (global): sequentialized by simulation.
                let src = instr.srcs[0];
                for &(l, a) in &addrs {
                    let w = &self.cores[c].warps[wi];
                    let v = match src {
                        crate::isa::Operand::Reg(r) => w.read(r, l),
                        o => operand_value(
                            &o,
                            &LaneCtx { tid: 0, ntid: 0, ctaid: 0, nctaid: 0 },
                            &|r| w.read(r, l),
                        ),
                    };
                    let old = self.mem_read_u32(a);
                    let new = match instr.ty {
                        crate::isa::Ty::F32 => (f32::from_bits(old) + f32::from_bits(v)).to_bits(),
                        _ => old.wrapping_add(v),
                    };
                    self.mem_write_u32(a, new);
                }
            }
            _ => unreachable!(),
        }

        // ---- timing ----
        let io_bytes = (self.cfg.bank_io_bits / 8) as u64;
        let wa: WarpAccess = coalesce(
            &addrs.iter().map(|&(_, a)| a).collect::<Vec<_>>(),
            &self.map,
            io_bytes,
            self.cfg.cores_per_proc,
        );
        let is_write = matches!(instr.op, Op::St | Op::Red);
        let full_warp = {
            let w = &self.cores[c].warps[wi];
            exec_mask.count_ones() as usize == w.lanes && w.lanes == self.warp_size
        };
        let offloadable = !ponb && wa.offloadable(full_warp, c);

        // Address register must be far-bank (LSU); store data stays in
        // the near-bank RF in hybrid mode (value registers are N by
        // §IV-B1 hardware policy) and far-bank on PonB.
        let mut required: Vec<(Reg, ExecLoc)> = Vec::new();
        if let Some(a) = instr.addr_reg() {
            required.push((a, ExecLoc::Far));
        }
        if is_write {
            for s in instr.srcs.iter().filter_map(|o| o.as_reg()) {
                if s.class != RegClass::P {
                    let want = if ponb { ExecLoc::Far } else { ExecLoc::Near };
                    required.push((s, want));
                }
            }
        }
        let moves_done = self.do_moves(c, wi, &required);

        if offloadable {
            self.stats.instrs_near += 1;
        } else {
            self.stats.instrs_far += 1;
        }
        self.stats.rf_far_accesses += 1; // address operand read
        if is_write {
            if ponb {
                self.stats.rf_far_accesses += 1;
            } else {
                self.stats.rf_near_accesses += 1;
            }
        }

        let (local, remote) = wa.split(c);
        let token = if is_write {
            0
        } else {
            let id = self.next_id;
            self.next_id += 1;
            let kind = if ponb {
                TokenKind::PonbLoad
            } else if offloadable {
                TokenKind::OffloadedLoad
            } else {
                TokenKind::PlainLoad
            };
            self.tokens.insert(
                id,
                Token { remaining: wa.chunks.len(), core: c, warp: wi, dst: instr.dst.unwrap(), kind },
            );
            // Block the destination until the token finalizes.
            self.cores[c].warps[wi].reg_ready.insert(instr.dst.unwrap(), u64::MAX);
            id
        };

        // Local chunks. Command traffic down the TSVs: the leading
        // address only when offloaded (Fig. 4-6), per-chunk addresses
        // otherwise. Store *data* descends only on PonB — in hybrid mode
        // it is already in the near-bank RF on the DRAM die.
        if !local.is_empty() {
            let mut cmd_bytes = if offloadable { 8 } else { local.len() as u64 * 8 };
            let mut class = TsvTraffic::Command;
            if is_write && ponb {
                cmd_bytes += local.len() as u64 * io_bytes;
                class = TsvTraffic::DramData;
            }
            let arr = self.cores[c].tsv.transfer(self.now.max(moves_done), cmd_bytes, class, &mut self.stats);
            let mut per_nbu: HashMap<usize, Vec<DramRequest>> = HashMap::new();
            for &ci in &local {
                let ch = wa.chunks[ci];
                let id = self.next_id;
                self.next_id += 1;
                self.routes.insert(id, ChunkRoute { token, service_core: c, home_core: c, is_write });
                per_nbu.entry(ch.coord.nbu).or_default().push(DramRequest {
                    id,
                    bank: ch.coord.bank,
                    row: ch.coord.row,
                    slot: self.map.slot_of_row(ch.coord.row),
                    is_write,
                });
            }
            for (nbu, reqs) in per_nbu {
                self.push_event(arr, Event::EnqueueDram { core: c, nbu, reqs });
            }
        }

        // Remote chunks: request over the mesh to the owning core's
        // LSU-Remote, which issues through that core's TSVs (§IV-B2).
        // Hybrid store data starts in the home NB RF, so it first lifts
        // over the home TSVs.
        if !remote.is_empty() {
            let mut per_core: HashMap<usize, Vec<usize>> = HashMap::new();
            for &ci in &remote {
                per_core.entry(wa.chunks[ci].core_global).or_default().push(ci);
            }
            let my_proc = c / self.cfg.cores_per_proc;
            for (rc, cis) in per_core {
                let data_bytes = if is_write { io_bytes } else { 0 };
                let req_bytes = cis.len() as u64 * (8 + data_bytes);
                let mut t = self.now.max(moves_done);
                if is_write && !ponb {
                    // Store data: NB RF → base logic die.
                    t = self.cores[c].tsv.transfer(t, cis.len() as u64 * io_bytes, TsvTraffic::DramData, &mut self.stats);
                }
                t = self.mesh.send(t, c, rc, req_bytes, &mut self.stats);
                let rproc = rc / self.cfg.cores_per_proc;
                if rproc != my_proc {
                    t = self.offchip.send(t, my_proc, rproc, req_bytes, &mut self.stats);
                }
                // At the remote core: TSV command (+ data) down, then DRAM.
                let arr2 = self.cores[rc].tsv.transfer(
                    t,
                    cis.len() as u64 * (8 + data_bytes),
                    if is_write { TsvTraffic::DramData } else { TsvTraffic::Command },
                    &mut self.stats,
                );
                let mut per_nbu: HashMap<usize, Vec<DramRequest>> = HashMap::new();
                for ci in cis {
                    let ch = wa.chunks[ci];
                    let id = self.next_id;
                    self.next_id += 1;
                    self.routes.insert(id, ChunkRoute { token, service_core: rc, home_core: c, is_write });
                    per_nbu.entry(ch.coord.nbu).or_default().push(DramRequest {
                        id,
                        bank: ch.coord.bank,
                        row: ch.coord.row,
                        slot: self.map.slot_of_row(ch.coord.row),
                        is_write,
                    });
                }
                for (nbu, reqs) in per_nbu {
                    self.push_event(arr2, Event::EnqueueDram { core: rc, nbu, reqs });
                }
            }
        }

        self.cores[c].warps[wi].set_pc(pc + 1);
    }

    fn issue_shared_mem(
        &mut self,
        c: usize,
        wi: usize,
        pc: usize,
        instr: &crate::isa::Instr,
        exec_mask: u64,
        loc: ExecLoc,
        launch: LaunchConfig,
    ) {
        self.stats.shared_mem_instrs += 1;
        let required = offload::required_reg_locs(instr, loc, &self.cfg);
        let moves_done = self.do_moves(c, wi, &required);
        let addrs = self.lane_addrs(c, wi, instr, exec_mask);
        let (block, warp_in_block) = {
            let w = &self.cores[c].warps[wi];
            (w.block, w.warp_in_block)
        };
        let bslot = self.cores[c].blocks.iter().position(|b| b.id == block).expect("block resident");

        // Functional.
        match instr.op {
            Op::Ld => {
                let dst = instr.dst.unwrap();
                let vals: Vec<(usize, u32)> = addrs
                    .iter()
                    .map(|&(l, a)| (l, self.cores[c].blocks[bslot].smem.read_u32(a as u32)))
                    .collect();
                let w = &mut self.cores[c].warps[wi];
                for (l, v) in vals {
                    w.write(dst, l, v);
                }
            }
            Op::St | Op::Red => {
                let src = instr.srcs[0];
                for &(l, a) in &addrs {
                    let ctx = LaneCtx {
                        tid: (warp_in_block * self.warp_size + l) as u32,
                        ntid: launch.block,
                        ctaid: block,
                        nctaid: launch.grid,
                    };
                    let v = {
                        let w = &self.cores[c].warps[wi];
                        operand_value(&src, &ctx, &|r| w.read(r, l))
                    };
                    let smem = &mut self.cores[c].blocks[bslot].smem;
                    if instr.op == Op::St {
                        smem.write_u32(a as u32, v);
                    } else if instr.ty == crate::isa::Ty::F32 {
                        smem.red_add_f32(a as u32, f32::from_bits(v));
                    } else {
                        smem.red_add_u32(a as u32, v);
                    }
                }
            }
            _ => unreachable!(),
        }

        // Timing: smem latency + bank-conflict serialization. The data
        // never crosses the TSVs when the smem and the execution location
        // coincide (that's the whole §IV-C argument) — the ablation's
        // traffic appears through the register moves above.
        let a32: Vec<u32> = addrs.iter().map(|&(_, a)| a as u32).collect();
        let conflicts = self.cores[c].blocks[bslot].smem.conflict_factor(&a32);
        self.stats.smem_accesses += conflicts;
        let done = self.now.max(moves_done) + self.cfg.smem_latency + (conflicts - 1);
        match loc {
            ExecLoc::Near => self.stats.instrs_near += 1,
            ExecLoc::Far => self.stats.instrs_far += 1,
        }

        let w = &mut self.cores[c].warps[wi];
        if let Some((d, where_)) = offload::dst_location(instr, loc, &self.cfg) {
            w.reg_ready.insert(d, done);
            match where_ {
                ExecLoc::Near => w.track.write_nb(d),
                ExecLoc::Far => w.track.write_fb(d),
            }
        }
        w.set_pc(pc + 1);
    }

    fn barrier(&mut self, c: usize, wi: usize, pc: usize) {
        let block = self.cores[c].warps[wi].block;
        self.cores[c].warps[wi].set_pc(pc + 1);
        self.cores[c].warps[wi].state = WarpState::AtBarrier;
        let bslot = self.cores[c].blocks.iter().position(|b| b.id == block).expect("block resident");
        self.cores[c].blocks[bslot].at_barrier += 1;
        if self.cores[c].blocks[bslot].at_barrier >= self.cores[c].blocks[bslot].warps_live {
            self.cores[c].blocks[bslot].at_barrier = 0;
            let release = self.now + 1;
            for w in self.cores[c].warps.iter_mut() {
                if w.block == block && w.state == WarpState::AtBarrier {
                    w.state = WarpState::Ready;
                    w.ready_at = release;
                }
            }
        }
    }

    fn exit(&mut self, c: usize, wi: usize, mask: u64) {
        let done = self.cores[c].warps[wi].exit_lanes(mask);
        if !done {
            return;
        }
        let block = self.cores[c].warps[wi].block;
        let bslot = self.cores[c].blocks.iter().position(|b| b.id == block).expect("block resident");
        {
            let b = &mut self.cores[c].blocks[bslot];
            b.warps_live -= 1;
            if b.warps_live > 0 {
                // A barrier may now be satisfiable with fewer live warps.
                if b.at_barrier >= b.warps_live {
                    b.at_barrier = 0;
                    for w in self.cores[c].warps.iter_mut() {
                        if w.block == block && w.state == WarpState::AtBarrier {
                            w.state = WarpState::Ready;
                            w.ready_at = self.now + 1;
                        }
                    }
                }
                return;
            }
        }
        // Block finished: retire it and dispatch the next. Done warps
        // stay in the vector (in-flight tokens hold warp indices); the
        // scheduler scans only the live lists.
        self.cores[c].blocks.remove(bslot);
        {
            let core = &mut self.cores[c];
            for sc in 0..core.sc_warps.len() {
                let warps = &core.warps;
                core.sc_warps[sc].retain(|&wi| warps[wi].block != block);
            }
        }
        self.blocks_done += 1;
        while self.try_dispatch_block(c) {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::isa::{KernelSource, Reg};

    fn axpy_kernel() -> KernelSource {
        KernelSource::assemble(
            "axpy",
            &[Reg::r(10), Reg::r(11), Reg::f(10), Reg::r(12)],
            r#"
                mov.u32   %r1, %tid.x
                mov.u32   %r2, %ctaid.x
                mad.u32   %r3, %r2, %ntid.x, %r1
                mov.u32   %r9, %nctaid.x
                mul.u32   %r9, %r9, %ntid.x
            LOOP:
                setp.ge.s32 %p1, %r3, %r12
                @%p1 bra  DONE
                shl.u32   %r4, %r3, 2
                add.u32   %r5, %r10, %r4
                add.u32   %r6, %r11, %r4
                ld.global.f32 %f1, [%r5+0]
                ld.global.f32 %f2, [%r6+0]
                mad.f32   %f3, %f1, %f10, %f2
                st.global.f32 [%r6+0], %f3
                add.u32   %r3, %r3, %r9
                bra       LOOP
            DONE:
                exit
            "#,
        )
        .unwrap()
    }

    fn run_axpy(cfg: &MachineConfig, n: usize) -> (Vec<f32>, Stats, Vec<f32>) {
        let k = compile(&axpy_kernel()).unwrap();
        let mut m = Machine::new(cfg);
        let x = m.alloc(n * 4);
        let y = m.alloc(n * 4);
        let mut rng = crate::sim::Prng::new(42);
        let xv = rng.f32_vec(n, -1.0, 1.0);
        let yv = rng.f32_vec(n, -1.0, 1.0);
        m.write_f32s(x, &xv);
        m.write_f32s(y, &yv);
        let alpha = 1.5f32;
        // 32 blocks × 128 threads = 4096 threads → the grid-stride
        // (16 KiB) equals one full bank sweep (64 banks × 256 B), so
        // every iteration of a block stays on its home core.
        let launch = LaunchConfig::new(32, 128);
        m.launch(
            k,
            launch,
            &[
                ParamValue::U32(x as u32),
                ParamValue::U32(y as u32),
                ParamValue::F32(alpha),
                ParamValue::U32(n as u32),
            ],
            |b| Some(x + b as u64 * 128 * 4),
        )
        .unwrap();
        let stats = m.run().unwrap();
        let got = m.read_f32s(y, n);
        let want: Vec<f32> = xv.iter().zip(&yv).map(|(a, b)| alpha * a + b).collect();
        (got, stats, want)
    }

    #[test]
    fn debug_hybrid_stats() {
        let cfg = MachineConfig::scaled();
        let (_, s, _) = run_axpy(&cfg, 8192);
        eprintln!("cycles={} near={} far={} nearfrac={:.3}", s.cycles, s.instrs_near, s.instrs_far, s.near_fraction());
        eprintln!("tsv: offload={} regmove={} dramdata={} smem={} cmd={}", s.tsv_bytes[0], s.tsv_bytes[1], s.tsv_bytes[2], s.tsv_bytes[3], s.tsv_bytes[4]);
        eprintln!("reg_moves={} mesh={} rowmiss={:.3} dram_bytes={} bpc={:.2}", s.reg_moves, s.mesh_bytes, s.row_miss_rate(), s.dram_bytes, s.dram_bytes_per_cycle());
        eprintln!("reads={} writes={} acts={} pres={}", s.dram_reads, s.dram_writes, s.dram_acts, s.dram_pres);
    }

    #[test]
    fn axpy_functional_correctness() {
        let cfg = MachineConfig::scaled();
        let (got, stats, want) = run_axpy(&cfg, 4096);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-6, "mismatch at {i}: {g} vs {w}");
        }
        assert!(stats.cycles > 0);
        assert!(stats.instrs_total() > 0);
        assert!(stats.dram_reads > 0);
        assert!(stats.dram_writes > 0);
    }

    #[test]
    fn axpy_offloads_value_chain() {
        let cfg = MachineConfig::scaled();
        let (_, stats, _) = run_axpy(&cfg, 4096);
        assert!(stats.instrs_near > 0, "fma + coalesced ld/st should offload");
        assert!(stats.near_fraction() > 0.1, "near fraction {}", stats.near_fraction());
    }

    #[test]
    fn ponb_mode_runs_and_never_offloads() {
        let mut cfg = MachineConfig::scaled();
        cfg.pipeline_mode = PipelineMode::PonB;
        let (got, stats, want) = run_axpy(&cfg, 2048);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6);
        }
        assert_eq!(stats.instrs_near, 0);
        assert!(stats.tsv_bytes[TsvTraffic::DramData as usize] > 0, "PonB lifts all data over TSVs");
    }

    #[test]
    fn hybrid_beats_ponb_on_streaming() {
        let cfg = MachineConfig::scaled();
        let (_, hybrid, _) = run_axpy(&cfg, 8192);
        let mut pcfg = cfg.clone();
        pcfg.pipeline_mode = PipelineMode::PonB;
        let (_, ponb, _) = run_axpy(&pcfg, 8192);
        assert!(
            hybrid.cycles < ponb.cycles,
            "hybrid {} should beat PonB {}",
            hybrid.cycles,
            ponb.cycles
        );
    }

    #[test]
    fn partial_warp_and_odd_sizes() {
        let cfg = MachineConfig::scaled();
        // n not a multiple of anything nice; blocks of 96 threads → 3
        // warps, last one partial vs n boundary.
        let k = compile(&axpy_kernel()).unwrap();
        let mut m = Machine::new(&cfg);
        let n = 1000usize;
        let x = m.alloc(n * 4);
        let y = m.alloc(n * 4);
        let xv: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let yv = vec![1.0f32; n];
        m.write_f32s(x, &xv);
        m.write_f32s(y, &yv);
        m.launch(
            k,
            LaunchConfig::new(3, 96),
            &[
                ParamValue::U32(x as u32),
                ParamValue::U32(y as u32),
                ParamValue::F32(2.0),
                ParamValue::U32(n as u32),
            ],
            |_| None,
        )
        .unwrap();
        m.run().unwrap();
        let got = m.read_f32s(y, n);
        for (i, g) in got.iter().enumerate() {
            let w = 2.0 * i as f32 + 1.0;
            assert!((g - w).abs() < 1e-5, "at {i}: {g} vs {w}");
        }
    }

    #[test]
    fn masa_reduces_row_misses_on_pingpong() {
        // Two warps streaming two different row regions from the same
        // bank ping-pong a single row buffer; 4 buffers fix it.
        let mut cfg1 = MachineConfig::scaled();
        cfg1.row_buffers_per_bank = 1;
        let (_, s1, _) = run_axpy(&cfg1, 8192);
        let mut cfg4 = MachineConfig::scaled();
        cfg4.row_buffers_per_bank = 4;
        let (_, s4, _) = run_axpy(&cfg4, 8192);
        assert!(
            s4.row_miss_rate() <= s1.row_miss_rate() + 1e-9,
            "MASA should not increase miss rate: {} vs {}",
            s4.row_miss_rate(),
            s1.row_miss_rate()
        );
    }
}
