//! DRAM bank timing state machine.
//!
//! Each bank has `row_buffers` independently-activated subarray-group
//! row buffers (MASA [33]); with one buffer this degenerates to a
//! conventional bank. Column commands serialize on the bank IO at
//! `tCCD`; a row-buffer miss pays `tRAS`-constrained PRE + `tRP` + ACT
//! `tRCD`; data returns `tCL` after the column command. Refresh stalls
//! the whole bank for `tRFC` every `tREFI`.
//!
//! Simplification (documented in DESIGN.md): reads and writes share the
//! column timing (`tCL`); `tRTP`/write-recovery are folded into `tRAS`
//! enforcement. At the fidelity of the paper's evaluation (row-hit rate
//! and bandwidth shape) this is inconsequential.

use crate::config::DramTiming;

/// Outcome class of a column access (drives Fig. 12's miss-rate metric
/// and PRE/ACT energy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    /// Open row matched: column access only.
    Hit,
    /// Buffer empty: ACT + column.
    Empty,
    /// Conflict: PRE + ACT + column.
    Miss,
}

/// One DRAM bank.
#[derive(Clone, Debug)]
pub struct Bank {
    /// Open row per row-buffer slot.
    slots: Vec<Option<usize>>,
    /// Activation time of each slot's open row (tRAS enforcement).
    slot_act: Vec<u64>,
    /// Column-IO free time (tCCD serialization).
    io_free: u64,
    /// Next scheduled refresh.
    next_refresh: u64,
    /// Bank unavailable until (refresh in progress).
    refresh_busy: u64,
    /// Refresh events issued.
    pub refreshes: u64,
}

impl Bank {
    pub fn new(row_buffers: usize, timing: &DramTiming) -> Bank {
        let n = row_buffers.max(1);
        Bank {
            slots: vec![None; n],
            slot_act: vec![0; n],
            io_free: 0,
            next_refresh: timing.t_refi,
            refresh_busy: 0,
            refreshes: 0,
        }
    }

    /// Open row in `slot`, if any.
    pub fn open_row(&self, slot: usize) -> Option<usize> {
        self.slots[slot]
    }

    /// Would an access to (`row`, `slot`) hit right now?
    pub fn would_hit(&self, row: usize, slot: usize) -> bool {
        self.slots[slot] == Some(row)
    }

    /// Earliest cycle at which the bank can accept a column command.
    pub fn io_free_at(&self) -> u64 {
        self.io_free.max(self.refresh_busy)
    }

    /// Perform one column access to `row` via row-buffer `slot` starting
    /// no earlier than `now`. Returns `(data_ready_cycle, kind)`.
    pub fn access(&mut self, now: u64, row: usize, slot: usize, t: &DramTiming) -> (u64, AccessKind) {
        // Refresh: all-bank refresh every tREFI.
        if now >= self.next_refresh {
            let start = self.io_free.max(self.next_refresh);
            self.refresh_busy = start + t.t_rfc;
            // Refresh closes all row buffers.
            for s in self.slots.iter_mut() {
                *s = None;
            }
            while self.next_refresh <= now {
                self.next_refresh += t.t_refi;
            }
            self.refreshes += 1;
        }

        let start = now.max(self.io_free).max(self.refresh_busy);
        let (col_cmd, kind) = match self.slots[slot] {
            Some(r) if r == row => (start, AccessKind::Hit),
            Some(_) => {
                // PRE may not issue before tRAS has elapsed since ACT.
                let pre = start.max(self.slot_act[slot] + t.t_ras);
                let act = pre + t.t_rp;
                self.slot_act[slot] = act;
                (act + t.t_rcd, AccessKind::Miss)
            }
            None => {
                self.slot_act[slot] = start;
                (start + t.t_rcd, AccessKind::Empty)
            }
        };
        self.slots[slot] = Some(row);
        self.io_free = col_cmd + t.t_ccd;
        (col_cmd + t.t_cl, kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DramTiming;

    fn t() -> DramTiming {
        DramTiming::default()
    }

    #[test]
    fn first_access_is_empty_activation() {
        let mut b = Bank::new(1, &t());
        let (ready, kind) = b.access(0, 5, 0, &t());
        assert_eq!(kind, AccessKind::Empty);
        assert_eq!(ready, t().t_rcd + t().t_cl);
        assert_eq!(b.open_row(0), Some(5));
    }

    #[test]
    fn same_row_hits_and_serializes_on_tccd() {
        let mut b = Bank::new(1, &t());
        b.access(0, 5, 0, &t());
        let io = b.io_free_at();
        let (r1, k1) = b.access(0, 5, 0, &t());
        assert_eq!(k1, AccessKind::Hit);
        assert_eq!(r1, io + t().t_cl);
        let (r2, k2) = b.access(0, 5, 0, &t());
        assert_eq!(k2, AccessKind::Hit);
        assert_eq!(r2, r1 + t().t_ccd, "column commands pace at tCCD");
    }

    #[test]
    fn row_conflict_pays_pre_act() {
        let tm = t();
        let mut b = Bank::new(1, &tm);
        b.access(0, 5, 0, &tm);
        // Access a different row long after tRAS expired.
        let now = 200;
        let (ready, kind) = b.access(now, 9, 0, &tm);
        assert_eq!(kind, AccessKind::Miss);
        assert_eq!(ready, now + tm.t_rp + tm.t_rcd + tm.t_cl);
        assert_eq!(b.open_row(0), Some(9));
    }

    #[test]
    fn tras_delays_early_precharge() {
        let tm = t();
        let mut b = Bank::new(1, &tm);
        b.access(0, 5, 0, &tm); // ACT at 0
        // Conflict immediately: PRE must wait until tRAS.
        let (ready, kind) = b.access(1, 9, 0, &tm);
        assert_eq!(kind, AccessKind::Miss);
        assert_eq!(ready, tm.t_ras + tm.t_rp + tm.t_rcd + tm.t_cl);
    }

    #[test]
    fn masa_slots_are_independent() {
        let tm = t();
        let mut b = Bank::new(4, &tm);
        b.access(0, 0, 0, &tm);
        // Different row in a different slot: no PRE needed (Empty), and
        // the previously opened row stays open.
        let (_, kind) = b.access(100, 1, 1, &tm);
        assert_eq!(kind, AccessKind::Empty);
        assert_eq!(b.open_row(0), Some(0));
        assert_eq!(b.open_row(1), Some(1));
        // Ping-pong between the two rows now hits both ways.
        let (_, k0) = b.access(200, 0, 0, &tm);
        let (_, k1) = b.access(201, 1, 1, &tm);
        assert_eq!((k0, k1), (AccessKind::Hit, AccessKind::Hit));
    }

    #[test]
    fn single_buffer_ping_pongs() {
        let tm = t();
        let mut b = Bank::new(1, &tm);
        b.access(0, 0, 0, &tm);
        let (_, k1) = b.access(100, 1, 0, &tm);
        let (_, k2) = b.access(200, 0, 0, &tm);
        assert_eq!(k1, AccessKind::Miss);
        assert_eq!(k2, AccessKind::Miss, "same two rows keep conflicting");
    }

    #[test]
    fn masa_hit_miss_conflict_timing_across_buffer_counts() {
        // The §IV-C MASA semantics for every supported buffer count:
        // a hit costs tCL after the IO frees; an empty slot costs
        // tRCD + tCL; a conflict costs tRP + tRCD + tCL (plus any tRAS
        // residue). The per-access timing must not depend on how many
        // *other* slots exist.
        let tm = t();
        for bufs in [1usize, 2, 4] {
            let mut b = Bank::new(bufs, &tm);
            // Cold activation in slot 0.
            let (r0, k0) = b.access(0, 10, 0, &tm);
            assert_eq!(k0, AccessKind::Empty, "bufs={bufs}");
            assert_eq!(r0, tm.t_rcd + tm.t_cl, "bufs={bufs}");
            // Hit in slot 0, long after the IO freed.
            let (r1, k1) = b.access(1000, 10, 0, &tm);
            assert_eq!(k1, AccessKind::Hit, "bufs={bufs}");
            assert_eq!(r1, 1000 + tm.t_cl, "bufs={bufs}");
            // Conflict in slot 0 (tRAS long expired).
            let (r2, k2) = b.access(2000, 11, 0, &tm);
            assert_eq!(k2, AccessKind::Miss, "bufs={bufs}");
            assert_eq!(r2, 2000 + tm.t_rp + tm.t_rcd + tm.t_cl, "bufs={bufs}");
        }
    }

    #[test]
    fn masa_would_hit_and_open_row_track_slots_independently() {
        let tm = t();
        for bufs in [2usize, 4] {
            let mut b = Bank::new(bufs, &tm);
            for slot in 0..bufs {
                assert_eq!(b.open_row(slot), None, "bufs={bufs} slot={slot}");
                assert!(!b.would_hit(slot + 100, slot));
            }
            // Open row `7 + slot` in each slot (all before tREFI so no
            // refresh closes them mid-test).
            for slot in 0..bufs {
                b.access(100 * (slot as u64 + 1), 7 + slot, slot, &tm);
            }
            for slot in 0..bufs {
                assert_eq!(b.open_row(slot), Some(7 + slot), "bufs={bufs} slot={slot}");
                assert!(b.would_hit(7 + slot, slot), "bufs={bufs} slot={slot}");
                assert!(!b.would_hit(7 + slot, (slot + 1) % bufs), "row is open in its own slot only");
            }
            // A conflict in slot 0 must leave the other slots' rows open.
            b.access(1000, 99, 0, &tm);
            assert_eq!(b.open_row(0), Some(99), "bufs={bufs}");
            for slot in 1..bufs {
                assert_eq!(b.open_row(slot), Some(7 + slot), "bufs={bufs} slot={slot}");
            }
        }
    }

    #[test]
    fn masa_two_buffers_fix_two_row_pingpong_but_not_three() {
        let tm = t();
        // Two rows alternating over 2 buffers (each to its own slot):
        // everything after the activations hits.
        let mut b2 = Bank::new(2, &tm);
        b2.access(0, 0, 0, &tm);
        b2.access(100, 1, 1, &tm);
        let mut t_hit = 1000;
        for i in 0..6 {
            let (_, k) = b2.access(t_hit, i % 2, i % 2, &tm);
            assert_eq!(k, AccessKind::Hit, "iteration {i}");
            t_hit += 100; // stay well below tREFI
        }
        // Three rows sharing one slot of the same bank keep conflicting
        // even though a second (idle) buffer exists.
        let mut b = Bank::new(2, &tm);
        b.access(0, 0, 0, &tm);
        let mut t_miss = 200;
        let mut misses = 0;
        for i in 1..7 {
            let (_, k) = b.access(t_miss, i % 3, 0, &tm);
            if k == AccessKind::Miss {
                misses += 1;
            }
            t_miss += 100;
        }
        assert_eq!(misses, 6, "slot-mapped rows cannot borrow the idle buffer");
    }

    #[test]
    fn masa_io_serialization_is_shared_across_slots() {
        // MASA multiplies row buffers, not column IO: back-to-back hits
        // to two different slots still pace at tCCD on the shared bus.
        let tm = t();
        let mut b = Bank::new(4, &tm);
        b.access(0, 0, 0, &tm);
        b.access(500, 1, 1, &tm);
        let io = b.io_free_at();
        let (r0, k0) = b.access(1000, 0, 0, &tm);
        let (r1, k1) = b.access(1000, 1, 1, &tm);
        assert!(io <= 1000);
        assert_eq!((k0, k1), (AccessKind::Hit, AccessKind::Hit));
        assert_eq!(r1, r0 + tm.t_ccd, "column commands share one IO bus");
    }

    #[test]
    fn refresh_closes_rows_and_stalls() {
        let tm = t();
        let mut b = Bank::new(2, &tm);
        b.access(0, 3, 0, &tm);
        let (ready, kind) = b.access(tm.t_refi + 1, 3, 0, &tm);
        // Refresh fired: row was closed → Empty, delayed by tRFC.
        assert_eq!(kind, AccessKind::Empty);
        assert!(ready >= tm.t_refi + tm.t_rfc + tm.t_rcd + tm.t_cl);
        assert_eq!(b.refreshes, 1);
    }
}
