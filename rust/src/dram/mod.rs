//! DRAM substrate: bank timing FSM with multiple activated row-buffers
//! (MASA, §IV-C) and a per-NBU FR-FCFS open-page memory controller
//! (Table II: `open_page / FR-FCFS`; the controller sits on the DRAM die,
//! §IV-B).

pub mod bank;
pub mod controller;

pub use bank::{AccessKind, Bank};
pub use controller::{DramRequest, MemController};
