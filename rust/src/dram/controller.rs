//! Per-NBU memory controller: FR-FCFS scheduling over the NBU's banks
//! with an open-page policy (Table II). The controller lives on the DRAM
//! die next to its banks (§IV-B), so commands never cross the TSVs for
//! near-bank requests.

use super::bank::{AccessKind, Bank};
use crate::config::MachineConfig;
use crate::sim::Stats;

/// One column-granularity DRAM request (bank-IO width, 32 B at the
/// Table-II 256-bit bank IO).
#[derive(Clone, Copy, Debug)]
pub struct DramRequest {
    /// Caller-assigned completion tag.
    pub id: u64,
    /// Bank index local to this NBU.
    pub bank: usize,
    /// DRAM row.
    pub row: usize,
    /// Row-buffer slot (from `AddrMap::slot_of_row`).
    pub slot: usize,
    pub is_write: bool,
}

#[derive(Clone, Debug)]
struct Pending {
    arrival: u64,
    req: DramRequest,
}

/// FR-FCFS memory controller over `banks_per_nbu` banks.
#[derive(Clone, Debug)]
pub struct MemController {
    banks: Vec<Bank>,
    queue: Vec<Pending>,
    /// (ready_cycle, id) completions not yet collected.
    done: Vec<(u64, u64)>,
    timing: crate::config::DramTiming,
    io_bytes: u64,
    /// Cached [`MemController::next_event`] value, kept exact across
    /// `push`/`advance`/`drain_completed` so the machine's event loop
    /// can jump between controller event times in O(1) per controller
    /// instead of rescanning every queue each frontend cycle.
    next_at: Option<u64>,
}

impl MemController {
    pub fn new(cfg: &MachineConfig) -> MemController {
        MemController {
            banks: (0..cfg.banks_per_nbu)
                .map(|_| Bank::new(cfg.row_buffers_per_bank, &cfg.timing))
                .collect(),
            queue: Vec::new(),
            done: Vec::new(),
            timing: cfg.timing,
            io_bytes: (cfg.bank_io_bits / 8) as u64,
            next_at: None,
        }
    }

    /// Enqueue a request at cycle `now`.
    pub fn push(&mut self, now: u64, req: DramRequest) {
        // Folding the new request's bank-IO time keeps the cache exact:
        // no other queue entry changed.
        let free = self.banks[req.bank].io_free_at();
        self.queue.push(Pending { arrival: now, req });
        self.next_at = Some(self.next_at.map_or(free, |t| t.min(free)));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Advance scheduling up to cycle `now`: issue every request whose
    /// bank can accept a column command, first-ready (row hit) first,
    /// then oldest. Returns nothing; completions are collected with
    /// [`MemController::drain_completed`].
    pub fn advance(&mut self, now: u64, stats: &mut Stats) {
        loop {
            // Candidate requests whose bank IO is free at `now`.
            let mut pick: Option<usize> = None;
            let mut pick_hit = false;
            let mut pick_arrival = u64::MAX;
            for (qi, p) in self.queue.iter().enumerate() {
                let bank = &self.banks[p.req.bank];
                if bank.io_free_at() > now {
                    continue;
                }
                let hit = bank.would_hit(p.req.row, p.req.slot);
                // FR-FCFS: row hits beat older non-hits; ties by age.
                let better = match (hit, pick_hit) {
                    (true, false) => true,
                    (false, true) => false,
                    _ => p.arrival < pick_arrival,
                };
                if pick.is_none() || better {
                    pick = Some(qi);
                    pick_hit = hit;
                    pick_arrival = p.arrival;
                }
            }
            let Some(qi) = pick else { break };
            let p = self.queue.swap_remove(qi);
            let bank = &mut self.banks[p.req.bank];
            let (ready, kind) = bank.access(now, p.req.row, p.req.slot, &self.timing);
            match kind {
                AccessKind::Hit => stats.row_hits += 1,
                AccessKind::Empty => {
                    stats.row_misses += 1;
                    stats.dram_acts += 1;
                }
                AccessKind::Miss => {
                    stats.row_misses += 1;
                    stats.dram_acts += 1;
                    stats.dram_pres += 1;
                }
            }
            if p.req.is_write {
                stats.dram_writes += 1;
            } else {
                stats.dram_reads += 1;
            }
            stats.dram_bytes += self.io_bytes;
            self.done.push((ready, p.req.id));
        }
        // Fold bank refresh counts into stats lazily.
        let refs: u64 = self.banks.iter().map(|b| b.refreshes).sum();
        if refs > stats.dram_refs {
            stats.dram_refs = refs;
        }
        self.recompute_next();
    }

    /// Collect ids whose data is ready by `now`.
    pub fn drain_completed(&mut self, now: u64) -> Vec<u64> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.done.len() {
            if self.done[i].0 <= now {
                out.push(self.done.swap_remove(i).1);
            } else {
                i += 1;
            }
        }
        if !out.is_empty() {
            self.recompute_next();
        }
        out
    }

    /// Earliest cycle at which anything interesting can happen (used by
    /// the machine's idle fast-forward and batched `advance_to`). O(1):
    /// reads the cache maintained by the mutating operations.
    pub fn next_event(&self) -> Option<u64> {
        self.next_at
    }

    fn recompute_next(&mut self) {
        let q = self
            .queue
            .iter()
            .map(|p| self.banks[p.req.bank].io_free_at())
            .min();
        let d = self.done.iter().map(|(r, _)| *r).min();
        self.next_at = match (q, d) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
    }

    /// Is the controller completely idle?
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.done.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> (MemController, Stats) {
        (MemController::new(&MachineConfig::scaled()), Stats::default())
    }

    fn req(id: u64, bank: usize, row: usize, slot: usize) -> DramRequest {
        DramRequest { id, bank, row, slot, is_write: false }
    }

    #[test]
    fn single_request_completes() {
        let (mut mc, mut st) = mc();
        mc.push(0, req(1, 0, 0, 0));
        mc.advance(0, &mut st);
        assert!(mc.drain_completed(5).is_empty(), "not ready yet");
        let done = mc.drain_completed(1000);
        assert_eq!(done, vec![1]);
        assert!(mc.idle());
        assert_eq!(st.dram_reads, 1);
        assert_eq!(st.row_misses, 1, "cold access counts as a miss");
    }

    #[test]
    fn frfcfs_prefers_row_hits() {
        let (mut mc, mut st) = mc();
        // Open row 0.
        mc.push(0, req(1, 0, 0, 0));
        mc.advance(0, &mut st);
        for _ in 0..100 {
            mc.advance(100, &mut st);
        }
        mc.drain_completed(10_000);
        // Now queue an older row-1 (conflict) and a newer row-0 (hit).
        mc.push(200, DramRequest { id: 2, bank: 0, row: 1, slot: 0, is_write: false });
        mc.push(201, DramRequest { id: 3, bank: 0, row: 0, slot: 0, is_write: false });
        // One scheduling round at a time: the hit (id 3) goes first.
        mc.advance(300, &mut st);
        let first = mc.drain_completed(100_000);
        assert_eq!(first, vec![3], "row hit bypasses the older conflict");
        mc.advance(10_000, &mut st);
        let mut all = mc.drain_completed(100_000);
        all.extend(first);
        all.sort_unstable();
        assert_eq!(all, vec![2, 3]);
        assert!(st.row_hits >= 1, "the row-0 request must have hit");
    }

    #[test]
    fn banks_operate_in_parallel() {
        let (mut mc, mut st) = mc();
        mc.push(0, req(1, 0, 0, 0));
        mc.push(0, req(2, 1, 0, 0));
        mc.advance(0, &mut st);
        // Both issued at cycle 0 (different banks) → same ready time.
        let done_times: Vec<u64> = mc.done.iter().map(|(r, _)| *r).collect();
        assert_eq!(done_times.len(), 2);
        assert_eq!(done_times[0], done_times[1]);
    }

    #[test]
    fn same_bank_serializes() {
        let (mut mc, mut st) = mc();
        mc.push(0, req(1, 0, 0, 0));
        mc.push(0, req(2, 0, 0, 0));
        mc.advance(0, &mut st);
        // Second same-bank request can't issue at cycle 0: the first is
        // an empty-row activation, so the IO frees at tRCD + tCCD.
        assert_eq!(mc.pending(), 1);
        let t = MachineConfig::scaled().timing;
        mc.advance(t.t_ccd, &mut st);
        assert_eq!(mc.pending(), 1, "still waiting on the ACT");
        mc.advance(t.t_rcd + t.t_ccd, &mut st);
        assert_eq!(mc.pending(), 0);
    }

    #[test]
    fn writes_counted_separately() {
        let (mut mc, mut st) = mc();
        mc.push(0, DramRequest { id: 1, bank: 0, row: 0, slot: 0, is_write: true });
        mc.advance(0, &mut st);
        assert_eq!(st.dram_writes, 1);
        assert_eq!(st.dram_reads, 0);
    }

    #[test]
    fn next_event_guides_fast_forward() {
        let (mut mc, mut st) = mc();
        assert_eq!(mc.next_event(), None);
        mc.push(0, req(1, 0, 0, 0));
        mc.advance(0, &mut st);
        let e = mc.next_event().unwrap();
        assert!(e > 0, "completion is in the future");
    }

    #[test]
    fn cached_next_event_stays_exact() {
        // The O(1) cache must equal the from-scratch computation after
        // every mutating operation (the event-driven machine loop leans
        // on this being exact, not just a lower bound).
        let expect = |mc: &MemController| -> Option<u64> {
            let q = mc.queue.iter().map(|p| mc.banks[p.req.bank].io_free_at()).min();
            let d = mc.done.iter().map(|(r, _)| *r).min();
            match (q, d) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            }
        };
        let (mut mc, mut st) = mc();
        assert_eq!(mc.next_event(), expect(&mc));
        mc.push(0, req(1, 0, 0, 0));
        mc.push(0, req(2, 0, 1, 0));
        mc.push(0, req(3, 1, 0, 0));
        assert_eq!(mc.next_event(), expect(&mc));
        let mut guard = 0;
        while let Some(t) = mc.next_event() {
            mc.advance(t, &mut st);
            assert_eq!(mc.next_event(), expect(&mc));
            let drained = mc.drain_completed(t);
            assert_eq!(mc.next_event(), expect(&mc), "after draining {drained:?}");
            guard += 1;
            assert!(guard < 1000, "controller failed to drain");
        }
        assert!(mc.idle());
    }
}
