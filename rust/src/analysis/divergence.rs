//! Divergence analysis: tid-taint through def-use chains, plus the
//! barrier-divergence check.
//!
//! Data dependence: any value computed from `%tid.x` is *divergent*
//! (per-lane). Sync dependence: a value defined inside the influence
//! region of a divergent branch and still live at the branch's
//! reconvergence point is divergent too — after reconvergence,
//! previously-split lanes are simultaneously active with values from
//! different paths. The two rules iterate to a fixpoint (the divergent
//! branch set only grows).
//!
//! A `bar.sync` strictly inside the influence region of a divergent
//! branch is the classic CUDA deadlock class: some lanes of the block
//! arrive at the barrier while sibling lanes are parked on the other
//! side of the branch.

use super::dataflow::{self, Analysis};
use crate::compiler::cfg::Cfg;
use crate::compiler::liveness::Liveness;
use crate::compiler::postdom;
use crate::isa::instr::Special;
use crate::isa::{Instr, Op, Operand, Reg};
use std::collections::BTreeSet;

struct Taint<'a> {
    /// pcs whose definitions are forcibly divergent (sync dependence).
    forced: &'a BTreeSet<usize>,
}

impl Analysis for Taint<'_> {
    type Fact = BTreeSet<Reg>;

    fn boundary(&self) -> Self::Fact {
        BTreeSet::new() // parameters are uniform
    }

    fn join(&self, a: &Self::Fact, b: &Self::Fact, _block: usize) -> Self::Fact {
        a.union(b).cloned().collect()
    }

    fn transfer(&self, pc: usize, i: &Instr, fact: &mut Self::Fact) {
        let tainted = i.reads().iter().any(|r| fact.contains(r))
            || i.srcs.iter().any(|o| matches!(o, Operand::Special(Special::TidX)))
            || self.forced.contains(&pc);
        if let Some(d) = i.dst {
            if tainted {
                fact.insert(d);
            } else if i.guard.is_none() {
                // A guarded write is partial: inactive lanes keep the old
                // (possibly divergent) value, so it does not clean `d`.
                fact.remove(&d);
            }
        }
    }
}

/// Result of the divergence fixpoint.
pub struct DivergenceInfo {
    /// Tainted-register set immediately before each pc (`None` =
    /// unreachable instruction).
    pub taint_before: Vec<Option<BTreeSet<Reg>>>,
    /// pcs of branches whose guard predicate is tid-dependent.
    pub divergent_branches: Vec<usize>,
    /// Blocks that are the reconvergence point of some divergent branch:
    /// value joins there mix lanes that took different paths.
    pub divergent_join_blocks: BTreeSet<usize>,
    /// Reconvergence pc per instruction (branches only).
    pub reconv: Vec<Option<usize>>,
}

impl DivergenceInfo {
    /// Is the guard predicate of the instruction at `pc` divergent?
    pub fn guard_divergent(&self, pc: usize, i: &Instr) -> bool {
        match (i.guard, &self.taint_before[pc]) {
            (Some((p, _)), Some(t)) => t.contains(&p),
            _ => false,
        }
    }
}

/// Blocks reachable from the successors of the branch at `br` without
/// entering the reconvergence block — the branch's influence region.
fn influence_region(cfg: &Cfg, br: usize, reconv_pc: Option<usize>) -> BTreeSet<usize> {
    let stop = reconv_pc.map(|pc| cfg.block_of[pc]);
    let mut seen = BTreeSet::new();
    let mut work: Vec<usize> = cfg.blocks[cfg.block_of[br]]
        .succs
        .iter()
        .copied()
        .filter(|b| Some(*b) != stop)
        .collect();
    while let Some(b) = work.pop() {
        if !seen.insert(b) {
            continue;
        }
        for &s in &cfg.blocks[b].succs {
            if Some(s) != stop && !seen.contains(&s) {
                work.push(s);
            }
        }
    }
    seen
}

/// Run the taint + sync-dependence fixpoint.
pub fn analyze(instrs: &[Instr], cfg: &Cfg) -> DivergenceInfo {
    let reconv = postdom::reconvergence_points(instrs, cfg);
    let live = Liveness::compute(instrs, cfg);
    let mut forced: BTreeSet<usize> = BTreeSet::new();
    loop {
        let t = Taint { forced: &forced };
        let sol = dataflow::solve(&t, cfg, instrs);
        let before = dataflow::facts_before(&t, cfg, instrs, &sol);
        let divergent: Vec<usize> = instrs
            .iter()
            .enumerate()
            .filter(|(pc, i)| {
                i.op == Op::Bra
                    && matches!((i.guard, &before[*pc]),
                        (Some((p, _)), Some(f)) if f.contains(&p))
            })
            .map(|(pc, _)| pc)
            .collect();

        // Sync dependence: defs inside a divergent region that survive to
        // the reconvergence point become divergent.
        let mut new_forced = forced.clone();
        for &br in &divergent {
            let Some(rpc) = reconv[br] else { continue };
            let region = influence_region(cfg, br, Some(rpc));
            for &b in &region {
                let blk = &cfg.blocks[b];
                for pc in blk.start..blk.end {
                    if let Some(d) = instrs[pc].dst {
                        if live.live_in[rpc].contains(&d) {
                            new_forced.insert(pc);
                        }
                    }
                }
            }
        }
        if new_forced == forced {
            let divergent_join_blocks = divergent
                .iter()
                .filter_map(|&br| reconv[br].map(|pc| cfg.block_of[pc]))
                .collect();
            return DivergenceInfo {
                taint_before: before,
                divergent_branches: divergent,
                divergent_join_blocks,
                reconv,
            };
        }
        forced = new_forced;
    }
}

/// Barrier-divergence check: every `bar.sync` strictly inside the
/// influence region of a divergent branch. Returns `(bar_pc, branch_pc)`
/// pairs, at most one per barrier.
pub fn barrier_divergence(
    instrs: &[Instr],
    cfg: &Cfg,
    info: &DivergenceInfo,
) -> Vec<(usize, usize)> {
    let mut flagged: BTreeSet<usize> = BTreeSet::new();
    let mut out = Vec::new();
    for &br in &info.divergent_branches {
        let region = influence_region(cfg, br, info.reconv[br]);
        for &b in &region {
            let blk = &cfg.blocks[b];
            for pc in blk.start..blk.end {
                if instrs[pc].op == Op::Bar && flagged.insert(pc) {
                    out.push((pc, br));
                }
            }
        }
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{KernelSource, Reg};

    fn build(body: &str) -> (Vec<Instr>, Cfg) {
        let k = KernelSource::assemble("t", &[Reg::r(10)], body).unwrap();
        let cfg = Cfg::build(&k.instrs);
        (k.instrs, cfg)
    }

    #[test]
    fn tid_taints_through_def_use() {
        let (instrs, cfg) = build(
            "mov.u32 %r1, %tid.x\n\
             add.u32 %r2, %r1, 4\n\
             setp.lt.s32 %p1, %r2, %r10\n\
             @%p1 bra DONE\n\
             mov.u32 %r3, 7\n\
             DONE:\nexit\n",
        );
        let info = analyze(&instrs, &cfg);
        assert_eq!(info.divergent_branches, vec![3]);
        // %r3 = 7 is uniform even inside the divergent region (dead at
        // reconvergence).
        let t = info.taint_before[5].as_ref().unwrap();
        assert!(!t.contains(&Reg::r(3)));
    }

    #[test]
    fn uniform_branch_is_not_divergent() {
        let (instrs, cfg) = build(
            "mov.u32 %r1, %ctaid.x\n\
             setp.lt.s32 %p1, %r1, %r10\n\
             @%p1 bra DONE\n\
             bar.sync\n\
             DONE:\nexit\n",
        );
        let info = analyze(&instrs, &cfg);
        assert!(info.divergent_branches.is_empty());
        assert!(barrier_divergence(&instrs, &cfg, &info).is_empty());
    }

    #[test]
    fn sync_dependence_taints_merged_values() {
        // r2 is 1 or 2 depending on tid — uniform on each path, divergent
        // after the merge.
        let (instrs, cfg) = build(
            "mov.u32 %r1, %tid.x\n\
             setp.lt.s32 %p1, %r1, 16\n\
             @%p1 bra A\n\
             mov.u32 %r2, 1\n\
             bra B\n\
             A:\n\
             mov.u32 %r2, 2\n\
             B:\n\
             setp.eq.s32 %p2, %r2, 1\n\
             @%p2 bra DONE\n\
             bar.sync\n\
             DONE:\nexit\n",
        );
        let info = analyze(&instrs, &cfg);
        // Both the tid branch and the merged-value branch are divergent,
        // and the barrier under the second is flagged.
        assert!(info.divergent_branches.contains(&2));
        assert!(info.divergent_branches.contains(&8));
        let bars = barrier_divergence(&instrs, &cfg, &info);
        assert_eq!(bars.len(), 1);
        assert_eq!(instrs[bars[0].0].op, Op::Bar);
    }

    #[test]
    fn barrier_under_divergent_guard_is_flagged() {
        let (instrs, cfg) = build(
            "mov.u32 %r1, %tid.x\n\
             setp.lt.s32 %p1, %r1, 16\n\
             @%p1 bra DONE\n\
             bar.sync\n\
             DONE:\nexit\n",
        );
        let info = analyze(&instrs, &cfg);
        let bars = barrier_divergence(&instrs, &cfg, &info);
        assert_eq!(bars, vec![(3, 2)]);
    }
}
